//! C1 (§1 "Resource contention"): ad-hoc unmanaged pool vs TonY/YARN
//! managed pool under increasing oversubscription.  Job success rate and
//! makespan; regenerates the EXPERIMENTS.md C1 table.

use tony::baseline::{run_adhoc_pool, run_managed_pool, synthetic_jobs, AdhocOutcome, AdhocParams};
use tony::bench::{f1, n, Table};
use tony::yarn::Resource;

fn main() {
    let hosts = vec![Resource::mem_cores(8192, 8); 4];
    let mut table = Table::new(&[
        "jobs", "demand%", "adhoc-ok%", "oom%", "misconf%", "tony-ok%", "tony-makespan-s",
    ]);
    for n_jobs in [4u32, 8, 12, 16, 24, 32, 48] {
        let jobs = synthetic_jobs(n_jobs, 2, 2048, 60_000);
        let demand = (n_jobs as f64 * 2.0 * 2048.0) / (4.0 * 8192.0) * 100.0;
        let (mut ok, mut oom, mut mis) = (0usize, 0usize, 0usize);
        let seeds = 50u64;
        for seed in 0..seeds {
            let params = AdhocParams { per_host_config_error: 0.02, seed };
            for r in run_adhoc_pool(&hosts, &jobs, &params) {
                match r.outcome {
                    AdhocOutcome::Succeeded => ok += 1,
                    AdhocOutcome::OomKilled => oom += 1,
                    AdhocOutcome::Misconfigured => mis += 1,
                }
            }
        }
        let tot = (n_jobs as u64 * seeds) as f64;
        let managed = run_managed_pool(&hosts, &jobs);
        let tony_ok = managed.iter().filter(|r| r.outcome == AdhocOutcome::Succeeded).count();
        let makespan = managed.iter().map(|r| r.finished_at_ms).max().unwrap_or(0);
        table.row(&[
            n(n_jobs),
            f1(demand),
            f1(ok as f64 / tot * 100.0),
            f1(oom as f64 / tot * 100.0),
            f1(mis as f64 / tot * 100.0),
            f1(tony_ok as f64 / n_jobs as f64 * 100.0),
            f1(makespan as f64 / 1e3),
        ]);
    }
    table.print("C1: contention — ad-hoc pool vs TonY (4 hosts x 8 GiB; 2 x 2 GiB tasks/job; 50 seeds)");
    println!("\nexpected shape: TonY holds 100% success with queue-growth makespan; ad-hoc success collapses past 100% demand.");
}
