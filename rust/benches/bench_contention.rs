//! C1 (§1 "Resource contention"): ad-hoc unmanaged pool vs TonY/YARN
//! managed pool under increasing oversubscription, plus the **gang vs
//! legacy** scheduler contrast: N concurrent jobs that each need a whole
//! gang of workers on a cluster that fits only a subset at once.
//!
//! Legacy per-container mode reproduces the classic partial-allocation
//! deadlock (every job holds a fraction of its gang and waits forever);
//! gang mode serializes whole waves and completes them all, so the table
//! reports completion, deadlock-freedom, and makespan per mode.
//!
//! `TONY_BENCH_SMOKE=1` runs the reduced gang-mode table only (CI).

use tony::baseline::{run_adhoc_pool, run_managed_pool, synthetic_jobs, AdhocOutcome, AdhocParams};
use tony::bench::cluster::{run, ClusterSpec, Scenario};
use tony::bench::{f1, f2, n, Table};
use tony::util::ids::{ApplicationId, ContainerId, NodeId};
use tony::yarn::scheduler::SchedNode;
use tony::yarn::{CapacityScheduler, ContainerRequest, QueueConf, Resource, VictimCandidate};

const GANG_SIZE: u32 = 4;
const TASK: Resource = Resource { memory_mb: 2048, vcores: 2, gpus: 0 };
const JOB_MS: u64 = 10_000;
/// Elastic batch jobs may balloon to this many workers on idle capacity.
const ELASTIC_MAX: u32 = 12;
const ARRIVAL_MS: u64 = 2_000;
const TICK_MS: u64 = 250;

struct SimJob {
    app: ApplicationId,
    granted: Vec<(u32, Resource)>,
    finish_at: Option<u64>,
    done: bool,
}

/// Discrete-event simulation of N contending gang jobs over the
/// CapacityScheduler (virtual time; no threads): returns
/// `(completed, deadlocked, makespan_ms, grants)`.
fn run_contention(n_jobs: u32, gang_mode: bool) -> (u32, bool, u64, usize) {
    let nodes: Vec<SchedNode> =
        (0..4).map(|i| SchedNode::new(i, None, Resource::new(8192, 8, 0))).collect();
    let total = nodes.iter().fold(Resource::ZERO, |a, x| a + x.capacity);
    let mut sched = CapacityScheduler::new(QueueConf::default_only(), total);
    sched.set_nodes(nodes);
    let mut jobs: Vec<SimJob> = (0..n_jobs)
        .map(|i| SimJob {
            app: ApplicationId { cluster_ts: 1, seq: i as u64 + 1 },
            granted: Vec::new(),
            finish_at: None,
            done: false,
        })
        .collect();

    // Enqueue the demand.  Legacy mode interleaves per-container asks
    // (the trickle an AM heartbeat loop produces under contention);
    // gang mode submits each job's wave as one all-or-nothing gang.
    let mut tag = 0u64;
    if gang_mode {
        for j in &jobs {
            let intake = sched.add_asks_gang(
                j.app,
                "default",
                &[ContainerRequest::new(TASK, GANG_SIZE)],
                tag,
                Some(j.app.seq),
            );
            tag = intake.next_tag;
        }
    } else {
        for _ in 0..GANG_SIZE {
            for j in &jobs {
                tag = sched.add_asks(j.app, "default", &[ContainerRequest::new(TASK, 1)], tag);
            }
        }
    }

    let mut now = 0u64;
    let mut grants_total = 0usize;
    let mut makespan = 0u64;
    loop {
        let grants = sched.schedule();
        grants_total += grants.len();
        for g in &grants {
            let ji = (g.ask.app.seq - 1) as usize;
            jobs[ji].granted.push((g.node.0, g.ask.resource));
            if jobs[ji].granted.len() == GANG_SIZE as usize {
                // Whole gang acquired: the job trains for JOB_MS.
                jobs[ji].finish_at = Some(now + JOB_MS);
            }
        }
        let next_finish = jobs
            .iter()
            .filter(|j| !j.done)
            .filter_map(|j| j.finish_at)
            .min();
        match next_finish {
            Some(t) => {
                now = t;
                makespan = makespan.max(now);
                for ji in 0..jobs.len() {
                    if jobs[ji].done || jobs[ji].finish_at != Some(t) {
                        continue;
                    }
                    jobs[ji].done = true;
                    for (node, r) in std::mem::take(&mut jobs[ji].granted) {
                        sched.release_container("default", NodeId(node), r);
                    }
                }
            }
            None => {
                // No job will ever finish.  Anything still pending (or
                // holding a partial gang) is deadlocked — unless the
                // cluster is simply drained and everyone completed.
                let all_done = jobs.iter().all(|j| j.done);
                let deadlocked = !all_done;
                let completed = jobs.iter().filter(|j| j.done).count() as u32;
                return (completed, deadlocked, makespan, grants_total);
            }
        }
    }
}

struct ElasticJob {
    app: ApplicationId,
    queue: &'static str,
    elastic: bool,
    submitted_at: u64,
    started_at: Option<u64>,
    finished_at: Option<u64>,
    /// `(node, shape, grant seq)` — grant order, so the tail is newest.
    held: Vec<(NodeId, Resource, u64)>,
    work_ms: u64,
}

/// Discrete-event simulation of staggered gang arrivals on two queues
/// (`prod` rigid / `batch` elastic, 50/50 guarantees): elastic batch
/// jobs grow into idle capacity via `elastic_grow_plan` and hand the
/// extra workers back through `elastic_shrink_plan` when a blocked gang
/// needs them — the RM's shrink-before-preempt pass, minus the threads.
/// Each job needs `GANG_SIZE * JOB_MS` worker-ms of compute, so growing
/// finishes it sooner; rigid-only mode runs the identical arrival
/// sequence with elasticity off.  Returns
/// `(goodput [avg busy workers], makespan_ms, avg_wait_ms, grows, released)`.
fn run_elastic_contention(n_jobs: u32, elasticity: bool) -> (f64, u64, f64, u64, u64) {
    let nodes: Vec<SchedNode> =
        (0..4).map(|i| SchedNode::new(i, None, Resource::new(8192, 8, 0))).collect();
    let total = nodes.iter().fold(Resource::ZERO, |a, x| a + x.capacity);
    let queues = vec![QueueConf::new("prod", 0.5, 1.0), QueueConf::new("batch", 0.5, 1.0)];
    let mut sched = CapacityScheduler::new(queues, total);
    sched.set_nodes(nodes);
    let mut jobs: Vec<ElasticJob> = (0..n_jobs)
        .map(|i| ElasticJob {
            app: ApplicationId { cluster_ts: 2, seq: i as u64 + 1 },
            queue: if i % 2 == 1 { "batch" } else { "prod" },
            elastic: elasticity && i % 2 == 1,
            submitted_at: i as u64 * ARRIVAL_MS,
            started_at: None,
            finished_at: None,
            held: Vec::new(),
            work_ms: 0,
        })
        .collect();
    const WORK: u64 = GANG_SIZE as u64 * JOB_MS;
    let (mut tag, mut cseq, mut grows, mut released) = (0u64, 1u64, 0u64, 0u64);
    let mut now = 0u64;
    let mut next_arrival = 0usize;
    loop {
        while next_arrival < jobs.len() && jobs[next_arrival].submitted_at <= now {
            let j = &jobs[next_arrival];
            tag = sched
                .add_asks_gang(
                    j.app,
                    j.queue,
                    &[ContainerRequest::new(TASK, GANG_SIZE)],
                    tag,
                    Some(j.app.seq),
                )
                .next_tag;
            next_arrival += 1;
        }
        // Cooperative shrink first (before scheduling), so a blocked
        // gang lands in the same tick its hole is opened — the ordering
        // the RM's elasticity pass uses.
        if elasticity {
            let candidates: Vec<VictimCandidate> = jobs
                .iter()
                .filter(|j| j.elastic && j.started_at.is_some() && j.finished_at.is_none())
                .flat_map(|j| {
                    j.held.iter().map(move |(node, r, seq)| VictimCandidate {
                        container: ContainerId { app: j.app, seq: *seq },
                        app: j.app,
                        queue: std::sync::Arc::from(j.queue),
                        node: *node,
                        resource: *r,
                        gang: None,
                        seq: *seq,
                    })
                })
                .collect();
            for (app, target) in
                sched.elastic_shrink_plan(&candidates, GANG_SIZE as usize, GANG_SIZE)
            {
                let ji = (app.seq - 1) as usize;
                let q = jobs[ji].queue;
                while jobs[ji].held.len() as u32 > target {
                    let (node, r, _) = jobs[ji].held.pop().expect("target below held count");
                    sched.release_container(q, node, r);
                    released += 1;
                }
                sched.set_elastic_current(app, target);
            }
        }
        for gr in sched.schedule() {
            let ji = (gr.ask.app.seq - 1) as usize;
            jobs[ji].held.push((gr.node, gr.ask.resource, cseq));
            cseq += 1;
            if jobs[ji].started_at.is_none() && jobs[ji].held.len() >= GANG_SIZE as usize {
                jobs[ji].started_at = Some(now);
                if jobs[ji].elastic {
                    sched.register_elastic(
                        jobs[ji].app,
                        "batch",
                        TASK,
                        None,
                        GANG_SIZE,
                        ELASTIC_MAX,
                        GANG_SIZE,
                    );
                }
            }
        }
        // Grow one job per tick into genuinely idle capacity.
        if elasticity {
            for j in &jobs {
                if j.elastic && j.started_at.is_some() && j.finished_at.is_none() {
                    sched.set_elastic_current(j.app, j.held.len() as u32);
                }
            }
            if let Some((app, target)) = sched.elastic_grow_plan(GANG_SIZE, &|_| true) {
                let ji = (app.seq - 1) as usize;
                let delta = target.saturating_sub(jobs[ji].held.len() as u32);
                if delta > 0 {
                    tag = sched.add_asks(app, "batch", &[ContainerRequest::new(TASK, delta)], tag);
                    grows += delta as u64;
                    for gr in sched.schedule() {
                        let gi = (gr.ask.app.seq - 1) as usize;
                        jobs[gi].held.push((gr.node, gr.ask.resource, cseq));
                        cseq += 1;
                    }
                    sched.set_elastic_current(app, jobs[ji].held.len() as u32);
                }
            }
        }
        now += TICK_MS;
        let mut all_done = true;
        for j in jobs.iter_mut() {
            if j.finished_at.is_some() {
                continue;
            }
            if j.started_at.is_some() {
                j.work_ms += j.held.len() as u64 * TICK_MS;
                if j.work_ms >= WORK {
                    j.finished_at = Some(now);
                    for (node, r, _) in std::mem::take(&mut j.held) {
                        sched.release_container(j.queue, node, r);
                    }
                    if j.elastic {
                        sched.deregister_elastic(j.app);
                    }
                    continue;
                }
            }
            all_done = false;
        }
        if all_done || now > n_jobs as u64 * (ARRIVAL_MS + JOB_MS) * 4 {
            break;
        }
    }
    sched.verify_invariants();
    let makespan = jobs.iter().filter_map(|j| j.finished_at).max().unwrap_or(now).max(1);
    let done_work: u64 = jobs.iter().map(|j| j.work_ms.min(WORK)).sum();
    let waits: Vec<u64> = jobs
        .iter()
        .filter_map(|j| j.started_at.map(|s| s - j.submitted_at))
        .collect();
    let avg_wait =
        if waits.is_empty() { 0.0 } else { waits.iter().sum::<u64>() as f64 / waits.len() as f64 };
    (done_work as f64 / makespan as f64, makespan, avg_wait, grows, released)
}

fn elastic_vs_rigid_table(sizes: &[u32]) {
    let mut table = Table::new(&[
        "jobs", "mode", "goodput-w", "makespan-s", "avg-wait-s", "grows", "released",
    ]);
    for &nj in sizes {
        for (mode, e) in [("elastic", true), ("rigid", false)] {
            let (goodput, makespan, wait, grows, released) = run_elastic_contention(nj, e);
            table.row(&[
                n(nj),
                mode.to_string(),
                f2(goodput),
                f1(makespan as f64 / 1e3),
                f1(wait / 1e3),
                n(grows),
                n(released),
            ]);
        }
    }
    table.print(
        "C-elastic: mixed elastic/rigid gangs vs rigid-only (4 hosts x 8 GiB / 8 cores; \
         4 x 2 GiB+2c per gang, batch jobs stretch to 12 workers; arrivals 2 s apart)",
    );
    println!(
        "\nexpected shape: elastic batch jobs soak idle capacity and finish early, then \
         hand workers back when a rigid gang blocks — goodput (avg busy workers) never \
         drops below the rigid-only baseline and makespan shortens."
    );
}

fn gang_vs_legacy_table(sizes: &[u32]) {
    let mut table =
        Table::new(&["jobs", "mode", "completed", "deadlock", "makespan-s", "grants"]);
    for &n_jobs in sizes {
        for (mode, gang) in [("gang", true), ("legacy", false)] {
            let (completed, deadlocked, makespan, grants) = run_contention(n_jobs, gang);
            table.row(&[
                n(n_jobs),
                mode.to_string(),
                n(completed),
                (if deadlocked { "YES" } else { "no" }).to_string(),
                f1(makespan as f64 / 1e3),
                n(grants),
            ]);
        }
    }
    table.print(
        "C1b: gang vs legacy under contention (4 hosts x 8 GiB / 8 cores; \
         4 x 2 GiB+2c workers per job; 10 s/job)",
    );
    println!(
        "\nexpected shape: gang mode completes every job (makespan grows in waves of 4); \
         legacy deadlocks once jobs > cluster gangs — each holds a partial gang forever."
    );
}

fn main() {
    let smoke = std::env::var("TONY_BENCH_SMOKE").is_ok();
    if smoke {
        gang_vs_legacy_table(&[2, 8]);
        // CI gate: gang mode must be deadlock-free and complete all jobs.
        for n_jobs in [2u32, 8] {
            let (completed, deadlocked, _, _) = run_contention(n_jobs, true);
            assert!(!deadlocked, "gang mode deadlocked at {n_jobs} jobs");
            assert_eq!(completed, n_jobs, "gang mode must complete all {n_jobs} jobs");
        }
        elastic_vs_rigid_table(&[2, 8]);
        // CI gate: elasticity must never cost goodput against the
        // identical rigid-only arrival sequence, and must actually grow.
        for n_jobs in [2u32, 8] {
            let (elastic_goodput, ..) = run_elastic_contention(n_jobs, true);
            let (rigid_goodput, ..) = run_elastic_contention(n_jobs, false);
            assert!(
                elastic_goodput + 1e-9 >= rigid_goodput,
                "elastic goodput {elastic_goodput:.3} fell below rigid-only \
                 {rigid_goodput:.3} at {n_jobs} jobs"
            );
        }
        let (_, _, _, grows, _) = run_elastic_contention(2, true);
        assert!(grows >= GANG_SIZE as u64, "elastic mode never grew into idle capacity");
        println!(
            "\nsmoke OK: gang mode deadlock-free at 2/8 jobs; \
             elastic goodput >= rigid-only at 2/8 jobs"
        );
        return;
    }

    let hosts = vec![Resource::mem_cores(8192, 8); 4];
    let mut table = Table::new(&[
        "jobs", "demand%", "adhoc-ok%", "oom%", "misconf%", "tony-ok%", "tony-makespan-s",
    ]);
    for n_jobs in [4u32, 8, 12, 16, 24, 32, 48] {
        let jobs = synthetic_jobs(n_jobs, 2, 2048, 60_000);
        let demand = (n_jobs as f64 * 2.0 * 2048.0) / (4.0 * 8192.0) * 100.0;
        let (mut ok, mut oom, mut mis) = (0usize, 0usize, 0usize);
        let seeds = 50u64;
        for seed in 0..seeds {
            let params = AdhocParams { per_host_config_error: 0.02, seed };
            for r in run_adhoc_pool(&hosts, &jobs, &params) {
                match r.outcome {
                    AdhocOutcome::Succeeded => ok += 1,
                    AdhocOutcome::OomKilled => oom += 1,
                    AdhocOutcome::Misconfigured => mis += 1,
                }
            }
        }
        let tot = (n_jobs as u64 * seeds) as f64;
        let managed = run_managed_pool(&hosts, &jobs);
        let tony_ok = managed.iter().filter(|r| r.outcome == AdhocOutcome::Succeeded).count();
        let makespan = managed.iter().map(|r| r.finished_at_ms).max().unwrap_or(0);
        table.row(&[
            n(n_jobs),
            f1(demand),
            f1(ok as f64 / tot * 100.0),
            f1(oom as f64 / tot * 100.0),
            f1(mis as f64 / tot * 100.0),
            f1(tony_ok as f64 / n_jobs as f64 * 100.0),
            f1(makespan as f64 / 1e3),
        ]);
    }
    table.print("C1: contention — ad-hoc pool vs TonY (4 hosts x 8 GiB; 2 x 2 GiB tasks/job; 50 seeds)");
    println!("\nexpected shape: TonY holds 100% success with queue-growth makespan; ad-hoc success collapses past 100% demand.");

    gang_vs_legacy_table(&[2, 8, 32]);
    elastic_vs_rigid_table(&[8, 32]);
    large_gang_contention();
}

/// C1c: many contending gangs at generator scale — 2k nodes / 200
/// queues / 800 gang jobs through the discrete-event runner, the
/// contention profile (most rounds re-test blocked gangs) rather than
/// the throughput profile C5 measures.
fn large_gang_contention() {
    let mut table =
        Table::new(&["scenario", "rounds", "grants", "median-ms", "p99-ms"]);
    let sc = Scenario::generate(ClusterSpec::smoke());
    let mut sched = sc.build_scheduler(false);
    let report = run(&sc, &mut sched);
    sched.verify_invariants();
    table.row(&[
        format!("{}n/{}q/{}j", sc.spec.nodes, sc.spec.queues, sc.spec.jobs),
        n(report.rounds),
        n(report.grants),
        f2(report.pass.median_ms()),
        f2(report.pass.p99_ms()),
    ]);
    table.print("C1c: gang contention at generator scale (indexed path)");
}
