//! PERF: hot-path microbenches feeding EXPERIMENTS.md §Perf — RPC
//! round-trip + bulk gradient transfer, wire codec, JSON/XML parse,
//! scheduler pass, checkpoint encode, and PJRT step latency.

use std::sync::Arc;
use std::time::Duration;

use tony::bench::{bench, f1, f2, Table};
use tony::net::rpc::{RpcClient, RpcServer};
use tony::net::wire::{Wire, Writer};
use tony::runtime::{Engine, Tensor};

fn main() {
    tony::util::logging::init_from_env();
    let mut table = Table::new(&["bench", "mean", "unit", "throughput"]);

    // --- RPC round-trip (empty payload) ---
    let srv = RpcServer::serve(Arc::new(|_m: u16, p: &[u8]| Ok(p.to_vec()))).unwrap();
    let cli = RpcClient::connect(&srv.addr()).unwrap();
    let s = bench(50, 20_000, Duration::from_secs(2), || {
        std::hint::black_box(cli.call(1, b"x").unwrap());
    });
    table.row(&[
        "rpc round-trip (1B)".into(),
        f1(s.mean_ns / 1e3),
        "us".into(),
        format!("{:.0}/s", s.per_sec()),
    ]);

    // --- RPC bulk transfer (1 MiB f32 gradients, like a PS push) ---
    let grads = vec![1.0f32; 256 * 1024];
    let payload = grads.to_bytes();
    let s = bench(5, 2000, Duration::from_secs(2), || {
        std::hint::black_box(cli.call(2, &payload).unwrap());
    });
    let mibps = (payload.len() as f64 * 2.0) / (s.mean_ns / 1e9) / (1 << 20) as f64;
    table.row(&[
        "rpc 1MiB f32 echo".into(),
        f2(s.mean_ms()),
        "ms".into(),
        format!("{mibps:.0} MiB/s"),
    ]);

    // --- wire codec: encode/decode 1M f32 ---
    let v = vec![0.5f32; 1 << 20];
    let s = bench(3, 500, Duration::from_secs(1), || {
        let mut w = Writer::with_capacity(v.len() * 4 + 8);
        w.f32_slice(&v);
        std::hint::black_box(w.buf.len());
    });
    let gbps = (v.len() * 4) as f64 / (s.mean_ns / 1e9) / 1e9;
    table.row(&["wire encode 4MiB f32".into(), f2(s.mean_ms()), "ms".into(), format!("{gbps:.1} GB/s")]);
    let bytes = v.to_bytes();
    let s = bench(3, 500, Duration::from_secs(1), || {
        std::hint::black_box(Vec::<f32>::from_bytes(&bytes).unwrap());
    });
    let gbps = (v.len() * 4) as f64 / (s.mean_ns / 1e9) / 1e9;
    table.row(&["wire decode 4MiB f32".into(), f2(s.mean_ms()), "ms".into(), format!("{gbps:.1} GB/s")]);

    // --- JSON parse (a realistic cluster-spec doc) ---
    let mut spec = tony::framework::ClusterSpec::new(1);
    for i in 0..64u16 {
        spec.tasks
            .entry(if i % 2 == 0 { "worker".into() } else { "ps".into() })
            .or_default()
            .push(tony::util::HostPort::localhost(10_000 + i));
    }
    let doc = spec.to_tf_config("worker", 0);
    let s = bench(10, 20_000, Duration::from_secs(1), || {
        std::hint::black_box(tony::json::Json::parse(&doc).unwrap());
    });
    table.row(&[
        format!("json parse ({}B spec)", doc.len()),
        f1(s.mean_ns / 1e3),
        "us".into(),
        format!("{:.0} MB/s", doc.len() as f64 / (s.mean_ns / 1e9) / 1e6),
    ]);

    // --- XML conf parse ---
    let conf = tony::tonyconf::JobConfBuilder::new("x")
        .instances("worker", 4)
        .memory("worker", "4g")
        .instances("ps", 2)
        .train("artifacts", "tiny", 100)
        .build();
    let xml = conf.to_xml();
    let s = bench(10, 20_000, Duration::from_secs(1), || {
        std::hint::black_box(tony::xmlconf::Configuration::from_xml_str(&xml).unwrap());
    });
    table.row(&[
        format!("xml conf parse ({}B)", xml.len()),
        f1(s.mean_ns / 1e3),
        "us".into(),
        format!("{:.0} MB/s", xml.len() as f64 / (s.mean_ns / 1e9) / 1e6),
    ]);

    // --- checkpoint encode (1M params + moments) ---
    let ckpt = tony::checkpoint::Checkpoint {
        step: 100,
        params: vec![0.1; 1 << 20],
        moments: Some((vec![0.0; 1 << 20], vec![0.0; 1 << 20])),
    };
    let s = bench(2, 100, Duration::from_secs(2), || {
        std::hint::black_box(ckpt.encode().len());
    });
    let gbps = (3 * (1 << 20) * 4) as f64 / (s.mean_ns / 1e9) / 1e9;
    table.row(&["checkpoint encode 12MiB".into(), f2(s.mean_ms()), "ms".into(), format!("{gbps:.1} GB/s")]);

    // --- PJRT step latency (tiny preset) ---
    let artifacts = std::path::Path::new("artifacts/tiny");
    if artifacts.join("meta.json").exists() {
        let engine = Engine::start(artifacts, Some(&["worker_step", "init_params", "ps_adam"])).unwrap();
        let h = engine.handle();
        let meta = h.meta().clone();
        let params = h
            .execute("init_params", vec![Tensor::scalar_u32(0)])
            .unwrap()
            .remove(0);
        let corpus = tony::data::SyntheticCorpus::new(meta.dims.vocab, 0);
        let tokens = corpus.batch(0, 0, meta.dims.batch, meta.dims.seq_len);
        let batch = Tensor::i32(&[meta.dims.batch, meta.dims.seq_len + 1], tokens);
        let s = bench(3, 200, Duration::from_secs(5), || {
            std::hint::black_box(
                h.execute("worker_step", vec![params.clone(), batch.clone()]).unwrap(),
            );
        });
        let flops = meta.flops_per_step();
        table.row(&[
            "pjrt worker_step (tiny)".into(),
            f2(s.mean_ms()),
            "ms".into(),
            format!("{:.2} GFLOP/s", flops / (s.mean_ns / 1e9) / 1e9),
        ]);
        let chunk = meta.chunk_len;
        let z = Tensor::f32(&[chunk], vec![0.0; chunk]);
        let s = bench(3, 500, Duration::from_secs(3), || {
            std::hint::black_box(
                h.execute(
                    "ps_adam",
                    vec![
                        z.clone(),
                        z.clone(),
                        z.clone(),
                        z.clone(),
                        Tensor::scalar_f32(1.0),
                        Tensor::scalar_f32(1e-3),
                    ],
                )
                .unwrap(),
            );
        });
        table.row(&[
            format!("pjrt ps_adam ({chunk} f32)"),
            f2(s.mean_ms()),
            "ms".into(),
            format!("{:.2} Gelem/s", chunk as f64 / (s.mean_ns / 1e9) / 1e9),
        ]);
    } else {
        eprintln!("(pjrt rows skipped: run `make artifacts`)");
    }

    table.print("PERF: hot-path microbenches");
}
