//! FIG1: walk every arrow of the paper's Figure 1 and time each stage of
//! the job lifecycle: client submit → AM up → containers granted → all
//! TaskExecutors registered (cluster spec built) → training running →
//! job finished.  Regenerates the EXPERIMENTS.md FIG1 table.

use std::time::{Duration, Instant};

use tony::am::JobPhase;
use tony::bench::{f1, n, Table};
use tony::client::TonyClient;
use tony::tonyconf::JobConfBuilder;
use tony::yarn::{AppState, Resource, ResourceManager};

fn main() {
    tony::util::logging::init_from_env();
    let artifacts = std::path::Path::new("artifacts/tiny");
    if !artifacts.join("meta.json").exists() {
        eprintln!("SKIP bench_fig1_lifecycle: run `make artifacts`");
        return;
    }
    let mut table = Table::new(&[
        "topology", "submit→AM", "AM→spec", "spec→step1", "train", "teardown", "total(ms)",
    ]);

    for (workers, ps) in [(1u32, 1u32), (2, 2), (4, 2)] {
        let rm = ResourceManager::start_uniform(6, Resource::new(8192, 8, 0));
        let ckpt = std::env::temp_dir().join(format!("tony-fig1-{workers}-{ps}"));
        let _ = std::fs::remove_dir_all(&ckpt);
        let steps = 3u64;
        let conf = JobConfBuilder::new("fig1")
            .instances("worker", workers)
            .memory("worker", "1g")
            .instances("ps", ps)
            .memory("ps", "1g")
            .train(artifacts.to_str().unwrap(), "tiny", steps)
            .set("tony.train.checkpoint-dir", ckpt.to_str().unwrap())
            .set("tony.train.checkpoint-every", "0")
            .build();

        let t0 = Instant::now();
        let client = TonyClient::new(rm.clone());
        let handle = client.submit(&conf, artifacts).unwrap();

        // Sample phase transitions.
        let mut am_up_ms = None;
        let mut spec_ms = None;
        let mut step1_ms = None;
        loop {
            let el = t0.elapsed().as_secs_f64() * 1e3;
            let phase = handle.am_state.phase();
            if am_up_ms.is_none() && handle.am_state.attempt() >= 1 {
                am_up_ms = Some(el);
            }
            if spec_ms.is_none() && phase == JobPhase::Running {
                spec_ms = Some(el);
            }
            if step1_ms.is_none()
                && handle.am_state.chief_metrics().map(|m| m.step).unwrap_or(0) >= 1
            {
                step1_ms = Some(el);
            }
            if matches!(phase, JobPhase::Succeeded | JobPhase::Failed) {
                break;
            }
            if el > 240_000.0 {
                break;
            }
            tony::util::clock::real_sleep(Duration::from_millis(2));
        }
        let trained_ms = t0.elapsed().as_secs_f64() * 1e3;
        let report = handle.wait(Duration::from_secs(60)).unwrap();
        let total_ms = t0.elapsed().as_secs_f64() * 1e3;
        assert_eq!(report.state, AppState::Finished, "{}", report.diagnostics);

        let am_up = am_up_ms.unwrap_or(0.0);
        let spec = spec_ms.unwrap_or(total_ms);
        let step1 = step1_ms.unwrap_or(total_ms);
        table.row(&[
            format!("{workers}w+{ps}ps"),
            f1(am_up),
            f1(spec - am_up),
            f1(step1 - spec),
            f1(trained_ms - step1),
            f1(total_ms - trained_ms),
            n(total_ms as u64),
        ]);
        let _ = std::fs::remove_dir_all(&ckpt);
    }
    table.print("FIG1: lifecycle stage latency (tiny preset, 3 steps; spec column includes PJRT compile)");
    println!("\nnote: AM→spec is dominated by per-executor PJRT compilation of the AOT artifacts.");
}
