//! C7 (§3 / Dr. Elephant): heuristic analyzer quality + throughput.
//! Plants known issues into synthetic telemetry and checks the analyzer
//! finds exactly them (precision/recall over a seeded corpus), then
//! measures analysis cost.

use std::time::Duration;

use tony::bench::{bench, f1, f2, n, Table};
use tony::drelephant::{analyze, JobTelemetry};
use tony::framework::TaskMetrics;
use tony::util::SplitMix64;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum Planted {
    OverMem,
    Straggler,
    PsImbalance,
    NoCheckpoint,
}

fn gen_case(rng: &mut SplitMix64, plant: &[Planted]) -> (JobTelemetry, Vec<&'static str>) {
    let workers = 4u32;
    let base_ms = 10.0 + rng.next_f64() * 5.0;
    let mut tasks = Vec::new();
    for i in 0..workers {
        let mut ms = base_ms * (1.0 + rng.next_f64() * 0.05);
        if plant.contains(&Planted::Straggler) && i == workers - 1 {
            ms *= 4.0;
        }
        tasks.push((
            format!("worker:{i}"),
            TaskMetrics { step: 100, step_ms_avg: ms, mem_used_mb: 256, ..Default::default() },
        ));
    }
    for i in 0..2u32 {
        let updates = if plant.contains(&Planted::PsImbalance) && i == 0 { 500 } else { 100 };
        tasks.push((
            format!("ps:{i}"),
            TaskMetrics { updates_applied: updates, ..Default::default() },
        ));
    }
    let req_mem = if plant.contains(&Planted::OverMem) { 8192 } else { 512 };
    let telemetry = JobTelemetry {
        tasks,
        requested_mem_mb: vec![("worker".into(), req_mem), ("ps".into(), 512)],
        checkpoint_every: if plant.contains(&Planted::NoCheckpoint) { 0 } else { 25 },
        flops_per_step: 5e10, // keeps low-utilization out of the way
    };
    let mut expect = Vec::new();
    for p in plant {
        expect.push(match p {
            Planted::OverMem => "memory-over-provisioning",
            Planted::Straggler => "straggler",
            Planted::PsImbalance => "ps-imbalance",
            Planted::NoCheckpoint => "checkpointing-disabled",
        });
    }
    (telemetry, expect)
}

fn main() {
    let all = [Planted::OverMem, Planted::Straggler, Planted::PsImbalance, Planted::NoCheckpoint];
    let mut rng = SplitMix64::new(42);
    let (mut tp, mut fn_, mut fp) = (0usize, 0usize, 0usize);
    let cases = 500;
    for case in 0..cases {
        // Random subset of planted issues.
        let mut plant = Vec::new();
        for p in all {
            if rng.chance(0.4) {
                plant.push(p);
            }
        }
        let (telemetry, expect) = gen_case(&mut rng, &plant);
        let findings = analyze(&telemetry);
        let found: std::collections::BTreeSet<&str> =
            findings.iter().map(|f| f.heuristic).collect();
        for e in &expect {
            if found.contains(e) {
                tp += 1;
            } else {
                fn_ += 1;
                eprintln!("case {case}: missed {e}");
            }
        }
        for f in &found {
            if !expect.contains(f) {
                fp += 1;
            }
        }
    }
    let precision = tp as f64 / (tp + fp).max(1) as f64;
    let recall = tp as f64 / (tp + fn_).max(1) as f64;

    let (telemetry, _) = gen_case(&mut rng, &all);
    let speed = bench(10, 10_000, Duration::from_secs(2), || {
        std::hint::black_box(analyze(&telemetry));
    });

    let mut table = Table::new(&["cases", "precision", "recall", "analyze-us"]);
    table.row(&[n(cases), f2(precision), f2(recall), f1(speed.mean_ns / 1e3)]);
    table.print("C7: Dr. Elephant heuristic quality over seeded-issue corpus");
    assert!(recall > 0.99, "analyzer must find every planted issue");
    assert!(precision > 0.9, "analyzer must not spam false findings");
}
