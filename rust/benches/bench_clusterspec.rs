//! C2 (§1 "Tedious and error-prone configuration"): central cluster-spec
//! assembly.  Measures AM-side spec construction + TF_CONFIG rendering +
//! parse-back cost vs task count, and verifies the spec is complete,
//! consistent and duplicate-free at every size; contrasts with the
//! per-host manual-config error model of the ad-hoc baseline.

use std::sync::Arc;
use std::time::Duration;

use tony::am::protocol::{RegisterMsg, AM_REGISTER};
use tony::am::state::{AmRpcHandler, AmState};
use tony::bench::{bench, f2, n, Table};
use tony::framework::ClusterSpec;
use tony::net::rpc::RpcHandler;
use tony::net::wire::Wire;
use tony::tonyconf::{JobConfBuilder, JobSpec};

fn main() {
    let mut table = Table::new(&[
        "tasks", "register-all(ms)", "render-TF_CONFIG(us)", "parse(us)", "consistent",
    ]);
    for total in [2u32, 4, 8, 16, 32, 64, 128, 256] {
        let workers = total / 2;
        let ps = total - workers;
        let conf = JobConfBuilder::new("spec")
            .instances("worker", workers)
            .instances("ps", ps)
            .build();
        let job = JobSpec::from_conf(&conf).unwrap();

        // Time the full registration+build path through the RPC handler.
        let (reg_stats, spec) = {
            let state = Arc::new(AmState::new(&job));
            let handler = AmRpcHandler::new(state.clone());
            let register_all = |state: &Arc<AmState>, handler: &AmRpcHandler| {
                state.begin_attempt(1);
                // The spec version is monotonic across begin_attempt
                // calls, so each bench iteration registers at the live
                // version rather than a hardcoded 1.
                let version = state.spec_version();
                let mut port = 10_000u16;
                for ty in ["worker", "ps"] {
                    let count = if ty == "worker" { workers } else { ps };
                    for i in 0..count {
                        let msg = RegisterMsg {
                            task_type: ty.to_string(),
                            index: i,
                            host: "127.0.0.1".into(),
                            port,
                            ui_url: None,
                            spec_version: version,
                        };
                        handler.handle(AM_REGISTER, &msg.to_bytes()).unwrap();
                        port += 1;
                    }
                }
                assert!(state.try_build_spec(version));
            };
            let stats = bench(1, 200, Duration::from_millis(400), || {
                register_all(&state, &handler);
            });
            register_all(&state, &handler);
            let json = state.snapshot_json();
            let _ = json;
            // Re-derive the spec for validation below.
            let handler2 = AmRpcHandler::new(state.clone());
            let bytes = handler2
                .handle(tony::am::protocol::AM_GET_SPEC,
                        &tony::am::protocol::GetSpecMsg { spec_version: 1, timeout_ms: 100 }.to_bytes())
                .unwrap();
            let (spec, _, _) = ClusterSpec::from_tf_config(&String::from_utf8(bytes).unwrap()).unwrap();
            (stats, spec)
        };

        // Consistency invariants: complete, no duplicate endpoints.
        let mut endpoints = std::collections::BTreeSet::new();
        let mut complete = spec.endpoints("worker").len() == workers as usize
            && spec.endpoints("ps").len() == ps as usize;
        for eps in spec.tasks.values() {
            for e in eps {
                complete &= endpoints.insert(e.to_string());
            }
        }

        let tf = spec.to_tf_config("worker", 0);
        let render = bench(3, 2000, Duration::from_millis(300), || {
            std::hint::black_box(spec.to_tf_config("worker", 0));
        });
        let parse = bench(3, 2000, Duration::from_millis(300), || {
            std::hint::black_box(ClusterSpec::from_tf_config(&tf).unwrap());
        });
        table.row(&[
            n(total),
            f2(reg_stats.mean_ms()),
            f2(render.mean_ns / 1e3),
            f2(parse.mean_ns / 1e3),
            n(complete),
        ]);
    }
    table.print("C2: cluster-spec assembly vs task count (central, always consistent)");
    println!(
        "\ncontrast: ad-hoc per-host config at 2% error/host gives P(all correct) = 0.98^N \
         (N=64 → {:.0}%); TonY's central spec is consistent at every size above.",
        0.98f64.powi(64) * 100.0
    );
}
