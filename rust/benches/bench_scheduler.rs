//! C5 (§2.2 heterogeneous requests): CapacityScheduler allocation
//! throughput and placement correctness under mixed CPU/GPU/labeled asks
//! across queues, plus the 10k-node / 1k-queue / 5k-gang-job scenario
//! from the discrete-event generator (`tony::bench::cluster`) contrasting
//! the indexed placement path against the retained linear reference.
//!
//! Setup (scheduler construction, ask intake) happens *outside* the
//! timed window via `bench_sampled` — `pass-ms` is `schedule()` alone.
//!
//! `TONY_BENCH_SMOKE=1` (CI) runs the 10k scenario once on the indexed
//! path with an asserted p99 allocate-round bound (override with
//! `TONY_SCHED_P99_MS`), and asserts the indexed path is >= 10x faster
//! per grant than a budgeted linear-baseline run of the same scenario.

use std::time::{Duration, Instant};

use tony::bench::cluster::{run, run_budgeted, ClusterSpec, Scenario};
use tony::bench::{bench_sampled, f1, f2, n, Table};
use tony::util::ids::ApplicationId;
use tony::yarn::scheduler::SchedNode;
use tony::yarn::{CapacityScheduler, ContainerRequest, QueueConf, Resource};

fn nodes(count: u32) -> Vec<SchedNode> {
    (0..count)
        .map(|i| {
            let label = if i % 4 == 0 { Some("gpu".to_string()) } else { None };
            let cap = if i % 4 == 0 {
                Resource::new(16384, 16, 4)
            } else {
                Resource::new(16384, 16, 0)
            };
            SchedNode::new(i, label, cap)
        })
        .collect()
}

fn asks(count: u32) -> Vec<ContainerRequest> {
    vec![
        ContainerRequest::new(Resource::new(1024, 1, 1), count / 4).with_label("gpu"),
        ContainerRequest::new(Resource::new(2048, 2, 0), count / 2),
        ContainerRequest::new(Resource::new(512, 1, 0), count / 4).with_priority(3),
    ]
}

/// One C5 row: build the scheduler + intake untimed, time `schedule()`.
fn c5_row(queues: &[QueueConf], n_asks: u32, n_nodes: u32, linear: bool) -> (usize, tony::bench::Stats) {
    let total = nodes(n_nodes).iter().fold(Resource::ZERO, |acc, x| acc + x.free);
    let mut granted = 0usize;
    let stats = bench_sampled(1, 50, Duration::from_secs(3), || {
        // Untimed setup: fresh scheduler, nodes, and asks per iteration
        // (schedule() consumes the pending asks).
        let mut sched = CapacityScheduler::new(queues.to_vec(), total);
        sched.set_linear_reference(linear);
        sched.set_nodes(nodes(n_nodes));
        let app1 = ApplicationId { cluster_ts: 1, seq: 1 };
        let app2 = ApplicationId { cluster_ts: 1, seq: 2 };
        let t = sched.add_asks(app1, "ml", &asks(n_asks / 2), 0);
        sched.add_asks(app2, "etl", &asks(n_asks / 2), t);
        // The measured window: one allocate pass.
        let timer = Instant::now();
        let grants = sched.schedule();
        let elapsed = timer.elapsed();
        // Placement correctness on every pass (untimed).
        for g in &grants {
            if g.ask.node_label.as_deref() == Some("gpu") {
                assert_eq!(g.node.0 % 4, 0, "gpu ask landed off-partition");
            }
        }
        granted = grants.len();
        std::hint::black_box(grants);
        elapsed
    });
    (granted, stats)
}

/// The generator scenario: full indexed run + budgeted linear baseline.
/// Returns (indexed ns/grant, linear ns/grant, indexed p99 ms).
fn scenario_contrast(spec: ClusterSpec, linear_budget: Duration, table: &mut Table) -> (f64, f64, f64) {
    let label = format!("{}n/{}q/{}j", spec.nodes, spec.queues, spec.jobs);
    let sc = Scenario::generate(spec);

    let mut sched = sc.build_scheduler(false);
    let ri = run(&sc, &mut sched);
    sched.verify_invariants();
    let indexed_ns_per_grant =
        ri.pass.mean_ns * ri.pass.iters as f64 / (ri.grants.max(1)) as f64;
    table.row(&[
        label.clone(),
        "indexed".to_string(),
        n(ri.rounds),
        n(ri.grants),
        f2(ri.pass.median_ms()),
        f2(ri.pass.p99_ms()),
        f1(indexed_ns_per_grant / 1e3),
    ]);

    let mut lsched = sc.build_scheduler(true);
    let rl = run_budgeted(&sc, &mut lsched, linear_budget);
    let linear_ns_per_grant =
        rl.pass.mean_ns * rl.pass.iters as f64 / (rl.grants.max(1)) as f64;
    table.row(&[
        label,
        "linear".to_string(),
        n(rl.rounds),
        n(rl.grants),
        f2(rl.pass.median_ms()),
        f2(rl.pass.p99_ms()),
        f1(linear_ns_per_grant / 1e3),
    ]);

    (indexed_ns_per_grant, linear_ns_per_grant, ri.pass.p99_ms())
}

fn p99_bound_ms() -> f64 {
    std::env::var("TONY_SCHED_P99_MS").ok().and_then(|v| v.parse().ok()).unwrap_or(100.0)
}

fn main() {
    let smoke = std::env::var("TONY_BENCH_SMOKE").is_ok();
    let queues = vec![QueueConf::new("ml", 0.6, 0.8), QueueConf::new("etl", 0.4, 1.0)];

    if smoke {
        // CI gate: the ISSUE 9 operating point must complete with a
        // bounded p99 allocate round, and the indexed path must beat
        // the measured linear baseline by >= 10x per grant.
        let mut table =
            Table::new(&["scenario", "path", "rounds", "grants", "median-ms", "p99-ms", "us/grant"]);
        let (indexed, linear, p99_ms) =
            scenario_contrast(ClusterSpec::large(), Duration::from_secs(5), &mut table);
        table.print("C5-smoke: 10k-node generator scenario, indexed vs linear");
        let bound = p99_bound_ms();
        assert!(
            p99_ms < bound,
            "indexed p99 allocate round {p99_ms:.2} ms exceeds the {bound:.0} ms bound"
        );
        assert!(
            linear >= 10.0 * indexed,
            "indexed path must be >= 10x the linear baseline per grant \
             (indexed {:.1} us/grant, linear {:.1} us/grant)",
            indexed / 1e3,
            linear / 1e3,
        );
        println!(
            "\nsmoke OK: p99 {:.2} ms < {:.0} ms; indexed {:.1} us/grant vs linear {:.1} us/grant ({:.1}x)",
            p99_ms,
            bound,
            indexed / 1e3,
            linear / 1e3,
            linear / indexed.max(1e-9),
        );
        return;
    }

    // Classic C5 ladder (two queues, mixed labeled asks), pass-ms now
    // measuring schedule() alone, with a 10k-node row.
    let mut table = Table::new(&["asks", "nodes", "path", "granted", "alloc/s", "pass-ms"]);
    for (n_asks, n_nodes) in
        [(256u32, 16u32), (1024, 64), (4096, 256), (16384, 1024), (16384, 10_000)]
    {
        for (path, linear) in [("indexed", false), ("linear", true)] {
            let (granted, stats) = c5_row(&queues, n_asks, n_nodes, linear);
            table.row(&[
                n(n_asks),
                n(n_nodes),
                path.to_string(),
                n(granted),
                f1(granted as f64 / (stats.mean_ns / 1e9)),
                f1(stats.mean_ms()),
            ]);
        }
    }
    table.print("C5: CapacityScheduler pass (two queues, 25% GPU-labeled asks)");

    // Generator scenarios: discrete-event runs at increasing scale.
    let mut gtable =
        Table::new(&["scenario", "path", "rounds", "grants", "median-ms", "p99-ms", "us/grant"]);
    let small = ClusterSpec { nodes: 1_000, queues: 100, jobs: 1_000, rounds: 100, gpu_fraction: 0.1, seed: 0x70_6e_79 };
    scenario_contrast(small, Duration::from_secs(10), &mut gtable);
    let (indexed, linear, p99_ms) =
        scenario_contrast(ClusterSpec::large(), Duration::from_secs(15), &mut gtable);
    gtable.print("C5b: discrete-event cluster scenarios, indexed vs linear");
    println!(
        "\n10k-node: indexed p99 {:.2} ms; {:.1}x faster than linear per grant",
        p99_ms,
        linear / indexed.max(1e-9),
    );
    assert!(p99_ms < p99_bound_ms(), "10k-node indexed p99 out of bound");
}
