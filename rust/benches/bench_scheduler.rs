//! C5 (§2.2 heterogeneous requests): CapacityScheduler allocation
//! throughput and placement correctness under mixed CPU/GPU/labeled asks
//! across queues.  containers/sec for the scheduling inner loop.

use std::time::Duration;

use tony::bench::{bench, f1, n, Table};
use tony::util::ids::ApplicationId;
use tony::yarn::scheduler::SchedNode;
use tony::yarn::{CapacityScheduler, ContainerRequest, QueueConf, Resource};

fn nodes(count: u32) -> Vec<SchedNode> {
    (0..count)
        .map(|i| {
            let label = if i % 4 == 0 { Some("gpu".to_string()) } else { None };
            let cap = if i % 4 == 0 {
                Resource::new(16384, 16, 4)
            } else {
                Resource::new(16384, 16, 0)
            };
            SchedNode::new(i, label, cap)
        })
        .collect()
}

fn asks(count: u32) -> Vec<ContainerRequest> {
    vec![
        ContainerRequest::new(Resource::new(1024, 1, 1), count / 4).with_label("gpu"),
        ContainerRequest::new(Resource::new(2048, 2, 0), count / 2),
        ContainerRequest::new(Resource::new(512, 1, 0), count / 4).with_priority(3),
    ]
}

fn main() {
    let queues = vec![QueueConf::new("ml", 0.6, 0.8), QueueConf::new("etl", 0.4, 1.0)];
    let mut table = Table::new(&["asks", "nodes", "granted", "alloc/s", "pass-ms"]);
    for (n_asks, n_nodes) in [(256u32, 16u32), (1024, 64), (4096, 256), (16384, 1024)] {
        let total = nodes(n_nodes)
            .iter()
            .fold(Resource::ZERO, |acc, x| acc + x.free);
        let mut granted = 0usize;
        let stats = bench(1, 50, Duration::from_secs(3), || {
            let mut sched = CapacityScheduler::new(queues.clone(), total);
            let mut view = nodes(n_nodes);
            let app1 = ApplicationId { cluster_ts: 1, seq: 1 };
            let app2 = ApplicationId { cluster_ts: 1, seq: 2 };
            let t = sched.add_asks(app1, "ml", &asks(n_asks / 2), 0);
            sched.add_asks(app2, "etl", &asks(n_asks / 2), t);
            let grants = sched.schedule(&mut view);
            // Placement correctness on every pass.
            for g in &grants {
                if g.ask.node_label.as_deref() == Some("gpu") {
                    assert_eq!(g.node.0 % 4, 0, "gpu ask landed off-partition");
                }
            }
            granted = grants.len();
            std::hint::black_box(grants);
        });
        table.row(&[
            n(n_asks),
            n(n_nodes),
            n(granted),
            f1(granted as f64 / (stats.mean_ns / 1e9)),
            f1(stats.mean_ms()),
        ]);
    }
    table.print("C5: CapacityScheduler pass (two queues, 25% GPU-labeled asks)");
}
