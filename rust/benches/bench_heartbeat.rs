//! C3 (§1 "Lack of monitoring"): heartbeat fan-in throughput at the AM.
//! N concurrent executors heartbeat over real TCP; measures aggregate
//! heartbeats/sec and per-call latency, i.e. the monitoring overhead of
//! centralizing task status in one place.
//!
//! Since the live-metrics pipeline landed, every heartbeat also folds
//! into the AM's time-series registry (`tony::metrics`) and carries an
//! incremental loss-history delta.  Each row therefore runs twice —
//! collection disabled (`tony.metrics.sample-interval-ms = 0`) and at
//! the default sampling interval — and reports the hot-path overhead of
//! metrics folding, which must stay small (target: under ~5%).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use tony::am::protocol::{HeartbeatMsg, RegisterMsg, AM_HEARTBEAT, AM_REGISTER};
use tony::am::state::{AmRpcHandler, AmState};
use tony::bench::{f1, f2, n, Table};
use tony::framework::TaskMetrics;
use tony::net::rpc::{RpcClient, RpcServer};
use tony::net::wire::Wire;
use tony::tonyconf::{JobConfBuilder, JobSpec};

/// One measurement: N executors heartbeating for `window`.  `pipeline`
/// turns the whole metrics path on (default 500 ms sampling interval +
/// a one-entry loss-history delta per beat, like a live training
/// executor) or off (registry disabled via sample-interval 0 AND no
/// history entries on the wire — the pre-pipeline heartbeat shape, so
/// the delta serialization + AM-side fold are part of what the
/// comparison measures).  Returns (heartbeats/sec, mean latency µs).
fn run_config(executors: u32, pipeline: bool, window: Duration) -> (f64, f64) {
    let interval = if pipeline { "500" } else { "0" };
    let conf = JobConfBuilder::new("hb")
        .instances("worker", executors)
        .set("tony.metrics.sample-interval-ms", interval)
        .build();
    let job = JobSpec::from_conf(&conf).unwrap();
    let state = Arc::new(AmState::new(&job));
    state.begin_attempt(1);
    let server = RpcServer::serve(Arc::new(AmRpcHandler::new(state.clone()))).unwrap();
    let addr = server.addr();

    let stop = Arc::new(AtomicBool::new(false));
    let count = Arc::new(AtomicU64::new(0));
    let lat_ns = Arc::new(AtomicU64::new(0));
    let mut threads = Vec::new();
    for i in 0..executors {
        let addr = addr.clone();
        let stop = stop.clone();
        let count = count.clone();
        let lat_ns = lat_ns.clone();
        threads.push(std::thread::spawn(move || {
            let cli = RpcClient::connect(&addr).unwrap();
            let reg = RegisterMsg {
                task_type: "worker".into(),
                index: i,
                host: "127.0.0.1".into(),
                port: 20_000 + i as u16,
                ui_url: None,
                spec_version: 1,
            };
            cli.call(AM_REGISTER, &reg.to_bytes()).unwrap();
            // Each beat advances the step; with the pipeline on it also
            // ships a one-entry loss-history delta, exercising the
            // AM-side fold exactly like a live training executor does.
            let mut step = 0u64;
            while !stop.load(Ordering::Relaxed) {
                step += 1;
                let hb = HeartbeatMsg {
                    task_type: "worker".into(),
                    index: i,
                    spec_version: 1,
                    metrics: TaskMetrics {
                        step,
                        loss: 2.0,
                        step_ms_avg: 10.0,
                        mem_used_mb: 64,
                        loss_history: if pipeline { vec![(step, 2.0)] } else { Vec::new() },
                        ..Default::default()
                    },
                };
                let t = Instant::now();
                cli.call(AM_HEARTBEAT, &hb.to_bytes()).unwrap();
                lat_ns.fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
                count.fetch_add(1, Ordering::Relaxed);
            }
        }));
    }
    // Measure a window after a brief warmup.
    tony::util::clock::real_sleep(Duration::from_millis(300));
    count.store(0, Ordering::Relaxed);
    lat_ns.store(0, Ordering::Relaxed);
    let t0 = Instant::now();
    tony::util::clock::real_sleep(window);
    let calls = count.load(Ordering::Relaxed);
    let total_lat = lat_ns.load(Ordering::Relaxed);
    let dt = t0.elapsed().as_secs_f64();
    stop.store(true, Ordering::Relaxed);
    for t in threads {
        let _ = t.join();
    }
    let mean_us = total_lat as f64 / calls.max(1) as f64 / 1e3;
    (calls as f64 / dt, mean_us)
}

fn main() {
    let smoke = std::env::var("TONY_BENCH_SMOKE").is_ok();
    let window = if smoke { Duration::from_millis(500) } else { Duration::from_secs(2) };
    let sizes: &[u32] = if smoke { &[4] } else { &[4, 16, 64, 256] };
    let mut table = Table::new(&["executors", "hb/s (off)", "hb/s (on)", "overhead %", "mean-us (on)"]);
    for &executors in sizes {
        let (off_rate, _) = run_config(executors, false, window);
        let (on_rate, on_us) = run_config(executors, true, window);
        let overhead = (off_rate - on_rate) / off_rate.max(1.0) * 100.0;
        table.row(&[
            n(executors),
            f1(off_rate),
            f1(on_rate),
            f2(overhead),
            f2(on_us),
        ]);
    }
    table.print("C3: AM heartbeat fan-in, metrics folding off vs on (real TCP)");
    println!(
        "\n'off' is the pre-pipeline heartbeat: registry disabled (sample-interval-ms = 0)\n\
         and no loss-history entries on the wire.  'on' is the full metrics path: default\n\
         500 ms sampling interval plus a one-entry loss-history delta per beat.  Overhead\n\
         is therefore the end-to-end hot-path cost of the pipeline — delta serialization,\n\
         AM-side fold, and registry sampling (target: < ~5%).\n\
         At the default 50 ms interval, 256 executors need only ~5.1k hb/s — far below capacity."
    );
}
