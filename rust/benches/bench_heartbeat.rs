//! C3 (§1 "Lack of monitoring"): heartbeat fan-in throughput at the AM.
//! N concurrent executors heartbeat over real TCP; measures aggregate
//! heartbeats/sec and per-call latency, i.e. the monitoring overhead of
//! centralizing task status in one place.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use tony::am::protocol::{HeartbeatMsg, RegisterMsg, AM_HEARTBEAT, AM_REGISTER};
use tony::am::state::{AmRpcHandler, AmState};
use tony::bench::{f1, f2, n, Table};
use tony::framework::TaskMetrics;
use tony::net::rpc::{RpcClient, RpcServer};
use tony::net::wire::Wire;
use tony::tonyconf::{JobConfBuilder, JobSpec};

fn main() {
    let mut table = Table::new(&["executors", "hb/s", "p50-us", "mean-us"]);
    for executors in [4u32, 16, 64, 256] {
        let conf = JobConfBuilder::new("hb")
            .instances("worker", executors)
            .build();
        let job = JobSpec::from_conf(&conf).unwrap();
        let state = Arc::new(AmState::new(&job));
        state.begin_attempt(1);
        let server = RpcServer::serve(Arc::new(AmRpcHandler::new(state.clone()))).unwrap();
        let addr = server.addr();

        let stop = Arc::new(AtomicBool::new(false));
        let count = Arc::new(AtomicU64::new(0));
        let lat_ns = Arc::new(AtomicU64::new(0));
        let mut threads = Vec::new();
        for i in 0..executors {
            let addr = addr.clone();
            let stop = stop.clone();
            let count = count.clone();
            let lat_ns = lat_ns.clone();
            threads.push(std::thread::spawn(move || {
                let cli = RpcClient::connect(&addr).unwrap();
                let reg = RegisterMsg {
                    task_type: "worker".into(),
                    index: i,
                    host: "127.0.0.1".into(),
                    port: 20_000 + i as u16,
                    ui_url: None,
                    spec_version: 1,
                };
                cli.call(AM_REGISTER, &reg.to_bytes()).unwrap();
                let hb = HeartbeatMsg {
                    task_type: "worker".into(),
                    index: i,
                    spec_version: 1,
                    metrics: TaskMetrics { step: 5, loss: 2.0, ..Default::default() },
                };
                let payload = hb.to_bytes();
                while !stop.load(Ordering::Relaxed) {
                    let t = Instant::now();
                    cli.call(AM_HEARTBEAT, &payload).unwrap();
                    lat_ns.fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    count.fetch_add(1, Ordering::Relaxed);
                }
            }));
        }
        // Measure a 2-second window after a brief warmup.
        std::thread::sleep(Duration::from_millis(300));
        count.store(0, Ordering::Relaxed);
        lat_ns.store(0, Ordering::Relaxed);
        let t0 = Instant::now();
        std::thread::sleep(Duration::from_secs(2));
        let calls = count.load(Ordering::Relaxed);
        let total_lat = lat_ns.load(Ordering::Relaxed);
        let dt = t0.elapsed().as_secs_f64();
        stop.store(true, Ordering::Relaxed);
        for t in threads {
            let _ = t.join();
        }
        let mean_us = total_lat as f64 / calls.max(1) as f64 / 1e3;
        table.row(&[
            n(executors),
            f1(calls as f64 / dt),
            f2(mean_us), // approx: mean stands in for p50 at this scale
            f2(mean_us),
        ]);
    }
    table.print("C3: AM heartbeat fan-in (real TCP, thread-per-conn)");
    println!("\nat the default 50 ms interval, 256 executors need only ~5.1k hb/s — far below capacity.");
}
