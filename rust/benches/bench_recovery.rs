//! R1 (surgical recovery): kill-to-training-resumed latency after a
//! single worker kill, surgical per-task recovery vs the paper's
//! full-restart loop, at 4/16/64 workers.
//!
//! Measured window: from the moment the AM leaves `Running`
//! (Recovering/Restarting) until the chief's step counter advances past
//! its value at that moment — i.e. until training has *regained* the
//! progress point it was at when the fault hit.  This charges the
//! full-restart policy for its rollback-and-recompute, which is exactly
//! the cost surgical recovery exists to avoid.
//!
//! Also verified per run: the surgical path relaunches exactly ONE
//! container and every survivor keeps its original ContainerId.
//!
//! `TONY_BENCH_SMOKE=1` runs the 4-worker pair only (CI smoke).

use std::time::{Duration, Instant};

use tony::am::JobPhase;
use tony::bench::{f1, n, Table};
use tony::chaos::{ChaosInjector, Fault};
use tony::client::TonyClient;
use tony::tonyconf::JobConfBuilder;
use tony::util::ids::TaskId;
use tony::yarn::{AppState, Resource, ResourceManager};

struct Outcome {
    kill_to_resume_ms: f64,
    relaunched: usize,
    survivors_stable: bool,
    attempts: u32,
    recoveries: u32,
    finished: bool,
}

fn run_case(workers: u32, surgical: bool, dir: &std::path::Path) -> Outcome {
    let per_node = Resource::new(((workers as u64) * 256).max(2048), workers.max(8), 0);
    let rm = ResourceManager::start_uniform(4, per_node);
    let ckpt = std::env::temp_dir().join(format!(
        "tony-rec-{workers}-{surgical}-{}",
        tony::util::ids::next_seq()
    ));
    let _ = std::fs::remove_dir_all(&ckpt);
    // Enough post-kill steps that the chaos injector's 10ms poll cannot
    // miss its firing window on a fast sim run.
    let steps = 40u64;
    let conf = JobConfBuilder::new("recovery")
        .instances("worker", workers)
        .memory("worker", "256m")
        .instances("ps", 1)
        .memory("ps", "256m")
        .train(dir.to_str().unwrap(), "tiny", steps)
        .set("tony.train.checkpoint-dir", ckpt.to_str().unwrap())
        .set("tony.train.checkpoint-every", "5")
        .set("tony.application.max-attempts", "3")
        .set("tony.task.max-restarts", if surgical { "3" } else { "0" })
        .build();
    let client = TonyClient::new(rm.clone());
    let handle = client.submit(&conf, dir).unwrap();
    let victim = TaskId::new("worker", workers - 1); // never the chief

    // Pre-kill container map, captured once the rendezvous completes.
    let t_end = Instant::now() + Duration::from_secs(300);
    while Instant::now() < t_end {
        if handle.am_state.phase() == JobPhase::Running
            && handle.am_state.container_map().values().all(|c| c.is_some())
        {
            break;
        }
        tony::util::clock::real_sleep(Duration::from_millis(2));
    }
    let pre = handle.am_state.container_map();

    let chaos = ChaosInjector::start(
        rm.clone(),
        handle.am_state.clone(),
        vec![Fault::KillTask {
            task_type: "worker".into(),
            index: workers - 1,
            after_step: 2,
        }],
    );

    // Watch for the disruption window (latency, best-effort) and capture
    // the post-recovery container map deterministically: the moment the
    // victim has a fresh container and every task has one (mid-flight,
    // before successful exits start clearing container records).
    let mut t_disrupt: Option<(Instant, u64)> = None; // (when, chief step then)
    let mut resume_ms: Option<f64> = None;
    let mut post: Option<_> = None;
    while Instant::now() < t_end {
        let phase = handle.am_state.phase();
        if post.is_none() {
            let m = handle.am_state.container_map();
            let replaced = m.get(&victim).copied().flatten().is_some()
                && m.get(&victim).copied().flatten() != pre.get(&victim).copied().flatten();
            let rendezvous_done = if surgical {
                handle.am_state.recoveries() >= 1
            } else {
                handle.am_state.attempt() >= 2
            };
            if rendezvous_done && replaced && m.values().all(|c| c.is_some()) {
                post = Some(m);
            }
        }
        match phase {
            JobPhase::Recovering | JobPhase::Restarting => {
                if t_disrupt.is_none() {
                    let step = handle.am_state.chief_metrics().map(|m| m.step).unwrap_or(0);
                    t_disrupt = Some((Instant::now(), step));
                }
            }
            JobPhase::Running => {
                if let (Some((t0, step0)), None) = (t_disrupt, resume_ms) {
                    let step = handle.am_state.chief_metrics().map(|m| m.step).unwrap_or(0);
                    if step > step0 {
                        resume_ms = Some(t0.elapsed().as_secs_f64() * 1e3);
                    }
                }
            }
            JobPhase::Succeeded | JobPhase::Failed => break,
            _ => {}
        }
        tony::util::clock::real_sleep(Duration::from_millis(1));
    }
    let report = handle.wait(Duration::from_secs(60)).unwrap();
    let records = chaos.join();
    assert_eq!(records.len(), 1, "fault must fire ({workers} workers, surgical={surgical})");

    let post = post.expect("post-recovery container map captured");
    let mut relaunched = 0usize;
    let mut survivors_stable = true;
    for (task, pre_cid) in &pre {
        let post_cid = post.get(task).copied().flatten();
        if post_cid != *pre_cid {
            relaunched += 1;
            if *task != victim {
                survivors_stable = false;
            }
        }
    }
    let _ = std::fs::remove_dir_all(&ckpt);
    Outcome {
        kill_to_resume_ms: resume_ms.unwrap_or(f64::NAN),
        relaunched,
        survivors_stable,
        attempts: handle.am_state.attempt(),
        recoveries: handle.am_state.recoveries(),
        finished: report.state == AppState::Finished,
    }
}

fn main() {
    tony::util::logging::init_from_env();
    if !tony::runtime::synthetic::sim_backend_active() {
        eprintln!("SKIP bench_recovery: pjrt build, synthetic preset unavailable");
        return;
    }
    let dir = tony::runtime::synthetic::default_dir().unwrap();
    let smoke = std::env::var("TONY_BENCH_SMOKE").is_ok();
    let sizes: &[u32] = if smoke { &[4] } else { &[4, 16, 64] };

    let mut table = Table::new(&[
        "workers",
        "policy",
        "kill->resume(ms)",
        "relaunched",
        "survivors-stable",
        "attempts",
        "recoveries",
        "outcome",
    ]);
    for &workers in sizes {
        let mut pair = Vec::new();
        for (label, surgical) in [("surgical", true), ("full-restart", false)] {
            let o = run_case(workers, surgical, &dir);
            assert!(o.finished, "{label} job at {workers} workers must finish");
            if surgical {
                assert_eq!(o.relaunched, 1, "surgical must relaunch exactly one container");
                assert!(o.survivors_stable, "survivors must keep their ContainerIds");
                assert_eq!(o.attempts, 1, "surgical recovery stays within the attempt");
            } else {
                assert!(o.attempts >= 2, "full-restart must burn an attempt");
            }
            table.row(&[
                n(workers),
                label.to_string(),
                f1(o.kill_to_resume_ms),
                n(o.relaunched),
                n(o.survivors_stable),
                n(o.attempts),
                n(o.recoveries),
                n(if o.finished { "Finished" } else { "Failed" }),
            ]);
            pair.push(o.kill_to_resume_ms);
        }
        if pair.len() == 2 && pair[0].is_finite() && pair[1].is_finite() {
            println!(
                "  {workers} workers: surgical {:.1}ms vs full-restart {:.1}ms ({:.1}x)",
                pair[0],
                pair[1],
                pair[1] / pair[0].max(1e-9)
            );
        }
    }
    table.print("R1: single-worker-kill recovery, surgical vs full restart (tiny preset, sync)");
    println!(
        "\nkill->resume = AM leaves Running -> chief step passes its pre-fault value;\n\
         surgical relaunches 1 container and never restarts survivors, so it dodges\n\
         the re-negotiation + re-registration + rollback the full restart pays."
    );
}
