//! C4 (§1/§2.2/§3 fault tolerance): time-to-recover after a mid-training
//! task kill — teardown → re-negotiate → relaunch → restore-from-
//! checkpoint — and the work preserved by checkpointing, vs the ad-hoc
//! baseline where a failed job is simply lost.
//!
//! This bench pins `tony.task.max-restarts=0` to measure the paper's
//! *full-restart* loop in isolation; `bench_recovery` compares it
//! against the surgical per-task recovery path.

use std::time::{Duration, Instant};

use tony::am::JobPhase;
use tony::bench::{f1, n, Table};
use tony::chaos::{ChaosInjector, Fault};
use tony::client::TonyClient;
use tony::tonyconf::JobConfBuilder;
use tony::yarn::{AppState, Resource, ResourceManager};

fn run_case(ckpt_every: u64, artifacts: &std::path::Path) -> (f64, u64, bool) {
    let rm = ResourceManager::start_uniform(4, Resource::new(8192, 8, 0));
    let ckpt = std::env::temp_dir().join(format!("tony-c4-{ckpt_every}-{}", tony::util::ids::next_seq()));
    let _ = std::fs::remove_dir_all(&ckpt);
    let steps = 12u64;
    let conf = JobConfBuilder::new("c4")
        .instances("worker", 2)
        .memory("worker", "1g")
        .instances("ps", 1)
        .memory("ps", "1g")
        .train(artifacts.to_str().unwrap(), "tiny", steps)
        .set("tony.train.checkpoint-dir", ckpt.to_str().unwrap())
        .set("tony.train.checkpoint-every", &ckpt_every.to_string())
        .set("tony.application.max-attempts", "3")
        .set("tony.task.max-restarts", "0") // full-restart policy under test
        .build();
    let client = TonyClient::new(rm.clone());
    let handle = client.submit(&conf, artifacts).unwrap();
    let chaos = ChaosInjector::start(
        rm.clone(),
        handle.am_state.clone(),
        vec![Fault::KillTask { task_type: "worker".into(), index: 1, after_step: 6 }],
    );

    // Recovery time: first Restarting sighting -> back to Running.
    let mut restart_seen: Option<Instant> = None;
    let mut recovery_ms: Option<f64> = None;
    let t_end = Instant::now() + Duration::from_secs(400);
    loop {
        match handle.am_state.phase() {
            JobPhase::Restarting | JobPhase::Recovering => {
                restart_seen.get_or_insert_with(Instant::now);
            }
            JobPhase::Running => {
                if let (Some(t), None) = (restart_seen, recovery_ms) {
                    recovery_ms = Some(t.elapsed().as_secs_f64() * 1e3);
                }
            }
            JobPhase::Succeeded | JobPhase::Failed => break,
            _ => {}
        }
        if Instant::now() > t_end {
            break;
        }
        tony::util::clock::real_sleep(Duration::from_millis(2));
    }
    let report = handle.wait(Duration::from_secs(60)).unwrap();
    let _ = chaos.join();
    let ok = report.state == AppState::Finished;

    // Steps preserved: restore point (checkpoint) vs restart-from-zero.
    let preserved = if ckpt_every > 0 { (6 / ckpt_every) * ckpt_every } else { 0 };
    let _ = std::fs::remove_dir_all(&ckpt);
    (recovery_ms.unwrap_or(f64::NAN), preserved, ok)
}

fn main() {
    tony::util::logging::init_from_env();
    let artifacts = std::path::Path::new("artifacts/tiny");
    if !artifacts.join("meta.json").exists() {
        eprintln!("SKIP bench_fault_tolerance: run `make artifacts`");
        return;
    }
    let mut table = Table::new(&[
        "policy", "recovered", "recovery-ms", "steps-preserved", "job-outcome",
    ]);
    for (name, every) in [("ckpt-every-3", 3u64), ("ckpt-every-6", 6), ("no-checkpoint", 0)] {
        let (ms, preserved, ok) = run_case(every, artifacts);
        table.row(&[
            name.to_string(),
            n(true),
            f1(ms),
            n(preserved),
            n(if ok { "Finished" } else { "Failed" }),
        ]);
    }
    table.row(&[
        "ad-hoc baseline".into(),
        n(false),
        "∞ (manual)".into(),
        n(0),
        "job lost".into(),
    ]);
    table.print("C4: recovery after worker kill at step 6 (tiny preset, 2w+1ps, 12 steps)");
    println!(
        "\nrecovery-ms = teardown + re-grant + executor relaunch (dominated by PJRT re-compile);\n\
         checkpointing converts lost work from 'all steps' to 'steps since last snapshot'."
    );
}
