//! C6 (§2.2 "communicate via the ML framework's distributed protocol"):
//! end-to-end PS/worker training throughput through the full TonY stack —
//! steps/s and tokens/s vs worker count, sync vs async — demonstrating
//! that the orchestration layer (Rust, Python off the hot path) adds no
//! steady-state overhead over the bare engine.

use std::time::{Duration, Instant};

use tony::bench::{f1, f2, Table};
use tony::client::TonyClient;
use tony::runtime::{ArtifactMeta, Engine, Tensor};
use tony::tonyconf::JobConfBuilder;
use tony::yarn::{AppState, Resource, ResourceManager};

/// Bare-engine baseline: single-process worker_step+adam loop, no
/// orchestration, no TCP — the "ideal" this testbed can reach.
fn bare_engine_steps_per_sec(artifacts: &std::path::Path, steps: u64) -> f64 {
    let engine = Engine::start(artifacts, Some(&["worker_step", "init_params", "ps_adam"])).unwrap();
    let h = engine.handle();
    let meta = h.meta().clone();
    let mut params = h
        .execute("init_params", vec![Tensor::scalar_u32(0)])
        .unwrap()
        .remove(0)
        .into_f32()
        .unwrap();
    let corpus = tony::data::SyntheticCorpus::new(meta.dims.vocab, 0);
    let chunk = meta.chunk_len;
    let n_chunks = meta.n_chunks();
    let mut m = vec![vec![0f32; chunk]; n_chunks];
    let mut v = vec![vec![0f32; chunk]; n_chunks];
    let t0 = Instant::now();
    for step in 0..steps {
        let tokens = corpus.batch(0, step, meta.dims.batch, meta.dims.seq_len);
        let out = h
            .execute(
                "worker_step",
                vec![
                    Tensor::f32(&[meta.n_params], params.clone()),
                    Tensor::i32(&[meta.dims.batch, meta.dims.seq_len + 1], tokens),
                ],
            )
            .unwrap();
        let grads = out[1].as_f32().unwrap();
        for c in 0..n_chunks {
            let lo = c * chunk;
            let hi = ((c + 1) * chunk).min(meta.n_params);
            let mut pc = vec![0f32; chunk];
            let mut gc = vec![0f32; chunk];
            pc[..hi - lo].copy_from_slice(&params[lo..hi]);
            gc[..hi - lo].copy_from_slice(&grads[lo..hi]);
            let out = h
                .execute(
                    "ps_adam",
                    vec![
                        Tensor::f32(&[chunk], pc),
                        Tensor::f32(&[chunk], gc),
                        Tensor::f32(&[chunk], m[c].clone()),
                        Tensor::f32(&[chunk], v[c].clone()),
                        Tensor::scalar_f32((step + 1) as f32),
                        Tensor::scalar_f32(1e-3),
                    ],
                )
                .unwrap();
            let mut it = out.into_iter();
            let pc = it.next().unwrap().into_f32().unwrap();
            m[c] = it.next().unwrap().into_f32().unwrap();
            v[c] = it.next().unwrap().into_f32().unwrap();
            params[lo..hi].copy_from_slice(&pc[..hi - lo]);
        }
    }
    steps as f64 / t0.elapsed().as_secs_f64()
}

fn run_stack(
    artifacts: &std::path::Path,
    workers: u32,
    ps: u32,
    mode: &str,
    steps: u64,
) -> (f64, f64) {
    let rm = ResourceManager::start_uniform(6, Resource::new(8192, 8, 0));
    let ckpt = std::env::temp_dir().join(format!(
        "tony-c6-{workers}-{mode}-{}",
        tony::util::ids::next_seq()
    ));
    let _ = std::fs::remove_dir_all(&ckpt);
    let conf = JobConfBuilder::new("c6")
        .instances("worker", workers)
        .memory("worker", "1g")
        .instances("ps", ps)
        .memory("ps", "1g")
        .train(artifacts.to_str().unwrap(), "tiny", steps)
        .set("tony.train.mode", mode)
        .set("tony.train.checkpoint-dir", ckpt.to_str().unwrap())
        .set("tony.train.checkpoint-every", "0")
        .build();
    let client = TonyClient::new(rm.clone());
    let handle = client.submit(&conf, artifacts).unwrap();

    // Time only the steady-state training window (exclude startup/compile):
    // from chief step 1 to completion.
    let mut train_start = None;
    let deadline = Instant::now() + Duration::from_secs(400);
    loop {
        let step = handle.am_state.chief_metrics().map(|m| m.step).unwrap_or(0);
        if train_start.is_none() && step >= 1 {
            train_start = Some((Instant::now(), step));
        }
        let phase = handle.am_state.phase();
        if matches!(phase, tony::am::JobPhase::Succeeded | tony::am::JobPhase::Failed) {
            break;
        }
        if Instant::now() > deadline {
            break;
        }
        tony::util::clock::real_sleep(Duration::from_millis(2));
    }
    let report = handle.wait(Duration::from_secs(60)).unwrap();
    assert_eq!(report.state, AppState::Finished, "{}", report.diagnostics);
    let m = handle.am_state.chief_metrics().unwrap();
    let (t1, s1) = train_start.unwrap();
    let dt = t1.elapsed().as_secs_f64();
    let chief_steps_per_s = (m.step - s1) as f64 / dt;
    // Aggregate throughput: workers run data-parallel on distinct shards.
    let tokens_per_s = chief_steps_per_s * workers as f64 * 256.0; // tiny: 4x64
    let _ = std::fs::remove_dir_all(&ckpt);
    (chief_steps_per_s, tokens_per_s)
}

fn main() {
    tony::util::logging::init_from_env();
    let artifacts = std::path::Path::new("artifacts/tiny");
    if !artifacts.join("meta.json").exists() {
        eprintln!("SKIP bench_training: run `make artifacts`");
        return;
    }
    let meta = ArtifactMeta::load(artifacts).unwrap();
    println!(
        "preset tiny: {} params, batch {} x seq {}",
        meta.n_params, meta.dims.batch, meta.dims.seq_len
    );

    let bare = bare_engine_steps_per_sec(artifacts, 30);
    println!("bare-engine baseline (no orchestration, no TCP): {bare:.1} steps/s");

    let mut table = Table::new(&["topology", "mode", "steps/s", "tokens/s", "vs-bare"]);
    for (w, ps, mode) in [
        (1u32, 1u32, "sync"),
        (2, 1, "sync"),
        (2, 2, "sync"),
        (4, 2, "sync"),
        (2, 2, "async"),
        (4, 2, "async"),
    ] {
        let (sps, tps) = run_stack(artifacts, w, ps, mode, 30);
        table.row(&[
            format!("{w}w+{ps}ps"),
            mode.to_string(),
            f1(sps),
            f1(tps),
            f2(sps / bare),
        ]);
    }
    table.print("C6: full-stack training throughput (tiny preset, steady state)");
    println!(
        "\nexpected shape: sync throughput tracks the bare engine within protocol overhead \
         and scales tokens/s with workers until the PS barrier dominates; async trades \
         staleness for higher step rate."
    );
}
