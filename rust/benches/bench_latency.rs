//! L1 (event-driven control plane): reaction latency and idle-CPU cost,
//! event wakeups vs the legacy poll fallback (`tony.event.poll-mode`).
//!
//! Part 1 — reaction latency, direct client, N repetitions per mode:
//!   - submit → AM phase Running (grant/launch/register/spec rendezvous);
//!   - kill a worker container → AM requests its replacement
//!     (`recoveries` bump) — the paper's recover-fast axis.
//!   Poll mode quantizes both to the 10–20 ms loop intervals; event mode
//!   reacts at wakeup time.  Measurement spins (yield) for precision so
//!   the probe itself adds no poll floor.
//!
//! Part 2 — idle-CPU proxy at 1/8/32 concurrent gateway jobs: total AM
//! monitor-loop iterations per job-second.  Event-driven loops iterate
//! per *event*; poll loops iterate per interval regardless of activity.
//!
//! Part 3 — tracing overhead: submit → Running with the lifecycle span
//! store on (default) vs `tony.trace.enable=false`, plus the per-stage
//! wall-clock breakdown the traced run recorded.  Under
//! `TONY_BENCH_SMOKE=1` the overhead is asserted below ~5% (with a small
//! absolute floor so a fast machine's noise doesn't fail the gate).
//!
//! `TONY_BENCH_SMOKE=1` trims repetitions and runs the 1-job level only.

use std::time::{Duration, Instant};

use tony::am::JobPhase;
use tony::bench::{f1, f2, n, Table};
use tony::client::{SubmitOpts, TonyClient};
use tony::gateway::{Gateway, GatewayConf, SubmitOutcome};
use tony::tonyconf::JobConfBuilder;
use tony::util::ids::TaskId;
use tony::xmlconf::Configuration;
use tony::yarn::{Resource, ResourceManager};

fn job_conf(name: &str, steps: u64, poll_mode: bool) -> Configuration {
    let mut b = JobConfBuilder::new(name)
        .instances("worker", 2)
        .memory("worker", "512m")
        .instances("ps", 1)
        .memory("ps", "512m")
        .set("tony.am.memory", "256m")
        .set("tony.train.steps", &steps.to_string())
        .set("tony.train.checkpoint-every", "20");
    if poll_mode {
        b = b.set("tony.event.poll-mode", "true");
    }
    b.build()
}

/// Busy-spin (yield) until `pred`, returning elapsed ms — the probe has
/// microsecond resolution so the measured floor is the system's, not the
/// harness's.
fn spin_until(pred: impl Fn() -> bool, timeout: Duration) -> f64 {
    let t0 = Instant::now();
    while !pred() {
        if t0.elapsed() > timeout {
            panic!("latency probe timed out after {timeout:?}");
        }
        std::thread::yield_now();
    }
    t0.elapsed().as_secs_f64() * 1e3
}

struct LatencySample {
    submit_to_running_ms: f64,
    kill_to_replacement_ms: f64,
}

fn measure_latency(poll_mode: bool, dir: &std::path::Path, steps: u64) -> LatencySample {
    let rm = ResourceManager::start_uniform(4, Resource::new(4096, 8, 0));
    let ckpt = dir.join(format!("ckpt-{}", tony::util::ids::next_seq()));
    let mut conf = job_conf("lat", steps, poll_mode);
    conf.set("tony.train.checkpoint-dir", ckpt.to_string_lossy().to_string());
    let client = TonyClient::new(rm.clone());
    let t_submit = Instant::now();
    let handle = client
        .submit_opts(&conf, &dir.join("artifacts"), SubmitOpts {
            start_portal: false,
            tracking_url: None,
            trace: None,
        })
        .expect("submit");
    let state = handle.am_state.clone();
    let submit_to_running_ms = {
        let s = state.clone();
        spin_until(move || s.phase() == JobPhase::Running, Duration::from_secs(60));
        t_submit.elapsed().as_secs_f64() * 1e3
    };

    // Kill worker:1's container and time until the AM has begun surgical
    // recovery (replacement requested at a bumped spec version).
    let victim = state
        .live_containers_for(&TaskId::new("worker", 1))
        .expect("worker:1 container");
    rm.stop_container(victim);
    let s = state.clone();
    let kill_to_replacement_ms =
        spin_until(move || s.recoveries() >= 1, Duration::from_secs(60));

    let report = handle.wait(Duration::from_secs(120)).expect("job finished");
    assert!(
        report.state == tony::yarn::AppState::Finished,
        "latency job must survive the kill: {}",
        report.diagnostics
    );
    LatencySample { submit_to_running_ms, kill_to_replacement_ms }
}

/// Submit → Running via the direct client with tracing on or off.
/// Returns the latency and the per-stage wall-clock totals from the
/// job's span store (empty when tracing is off — the disabled store
/// swallows writes without taking its lock).
fn measure_traced(
    trace_on: bool,
    dir: &std::path::Path,
    steps: u64,
) -> (f64, Vec<(tony::trace::Stage, u64)>) {
    let rm = ResourceManager::start_uniform(4, Resource::new(4096, 8, 0));
    let ckpt = dir.join(format!("ckpt-{}", tony::util::ids::next_seq()));
    let mut conf = job_conf("traced", steps, false);
    conf.set("tony.train.checkpoint-dir", ckpt.to_string_lossy().to_string());
    if !trace_on {
        conf.set("tony.trace.enable", "false");
    }
    let client = TonyClient::new(rm);
    let t0 = Instant::now();
    let handle = client
        .submit_opts(&conf, &dir.join("artifacts"), SubmitOpts {
            start_portal: false,
            tracking_url: None,
            trace: None,
        })
        .expect("submit");
    let state = handle.am_state.clone();
    spin_until(move || state.phase() == JobPhase::Running, Duration::from_secs(60));
    let submit_to_running_ms = t0.elapsed().as_secs_f64() * 1e3;
    let report = handle.wait(Duration::from_secs(120)).expect("job finished");
    assert!(report.state == tony::yarn::AppState::Finished, "{}", report.diagnostics);
    (submit_to_running_ms, handle.trace.stage_millis())
}

struct IdleResult {
    jobs: usize,
    wall_s: f64,
    total_iters: u64,
    iters_per_job_sec: f64,
}

fn measure_idle(poll_mode: bool, concurrency: usize, steps: u64, dir: &std::path::Path) -> IdleResult {
    let rm = ResourceManager::start_uniform(16, Resource::new(4096, 16, 0));
    let mut conf = GatewayConf::new(dir.join("artifacts"));
    conf.history_dir = dir.join(format!("history-{}-{}", poll_mode, concurrency));
    conf.workers = concurrency;
    conf.queue_depth = 256;
    conf.quotas.max_active_per_user = 10_000;
    let gw = Gateway::start(rm, conf).expect("gateway");
    let t0 = Instant::now();
    let mut ids = Vec::new();
    for i in 0..concurrency {
        match gw.submit_conf(&format!("u{i}"), 1, job_conf(&format!("idle{i}"), steps, poll_mode))
        {
            SubmitOutcome::Accepted { id } => ids.push(id),
            SubmitOutcome::Rejected { reason, .. } => panic!("rejected: {reason}"),
        }
    }
    // Sample per-job monitor-loop iteration counters while the jobs run
    // (the live handles are dropped at terminalization).
    let mut iters: std::collections::BTreeMap<u64, u64> = std::collections::BTreeMap::new();
    loop {
        for (id, st) in gw.live_am_states() {
            iters.insert(id, st.loop_iters());
        }
        if gw.wait_idle(Duration::from_millis(25)) {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(300), "idle bench stalled");
    }
    let wall_s = t0.elapsed().as_secs_f64();
    gw.shutdown();
    let total_iters: u64 = iters.values().sum();
    IdleResult {
        jobs: concurrency,
        wall_s,
        total_iters,
        iters_per_job_sec: total_iters as f64 / (concurrency as f64 * wall_s).max(1e-9),
    }
}

fn main() {
    let smoke = std::env::var("TONY_BENCH_SMOKE").is_ok();
    let base = std::env::temp_dir().join(format!("tony-bench-latency-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    tony::runtime::synthetic::ensure_preset(&base.join("artifacts")).expect("artifacts");

    // ---- Part 1: reaction latency ----
    let reps = if smoke { 1 } else { 5 };
    let steps = if smoke { 60 } else { 200 };
    let mut t = Table::new(&[
        "mode",
        "reps",
        "submit->RUNNING p50 ms",
        "kill->replacement p50 ms",
    ]);
    for poll_mode in [false, true] {
        let mut running = Vec::new();
        let mut replace = Vec::new();
        for _ in 0..reps {
            let s = measure_latency(poll_mode, &base, steps);
            running.push(s.submit_to_running_ms);
            replace.push(s.kill_to_replacement_ms);
        }
        running.sort_by(|a, b| a.partial_cmp(b).unwrap());
        replace.sort_by(|a, b| a.partial_cmp(b).unwrap());
        t.row(&[
            n(if poll_mode { "poll" } else { "event" }),
            n(reps),
            f2(running[running.len() / 2]),
            f2(replace[replace.len() / 2]),
        ]);
    }
    t.print("L1a: control-plane reaction latency (event wakeups vs poll fallback)");

    // ---- Part 2: idle-CPU proxy at 1/8/32 concurrent gateway jobs ----
    let levels: &[usize] = if smoke { &[1] } else { &[1, 8, 32] };
    let idle_steps = if smoke { 10 } else { 50 };
    let mut t = Table::new(&[
        "mode",
        "jobs",
        "wall s",
        "AM loop iters",
        "iters/job/s",
    ]);
    for &jobs in levels {
        for poll_mode in [false, true] {
            let r = measure_idle(poll_mode, jobs, idle_steps, &base);
            t.row(&[
                n(if poll_mode { "poll" } else { "event" }),
                n(r.jobs),
                f2(r.wall_s),
                n(r.total_iters),
                f1(r.iters_per_job_sec),
            ]);
        }
    }
    t.print("L1b: AM monitor-loop iterations (idle-CPU proxy)");

    // ---- Part 3: tracing overhead + per-stage breakdown ----
    let reps = if smoke { 3 } else { 5 };
    let trace_steps = if smoke { 30 } else { 100 };
    let mut on = Vec::new();
    let mut off = Vec::new();
    let mut last_stages = Vec::new();
    for _ in 0..reps {
        let (ms, stages) = measure_traced(true, &base, trace_steps);
        on.push(ms);
        last_stages = stages;
        let (ms, stages) = measure_traced(false, &base, trace_steps);
        assert!(stages.is_empty(), "disabled span store must record nothing");
        off.push(ms);
    }
    on.sort_by(|a, b| a.partial_cmp(b).unwrap());
    off.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let on_p50 = on[on.len() / 2];
    let off_p50 = off[off.len() / 2];
    let overhead_pct = (on_p50 - off_p50) / off_p50.max(1e-9) * 100.0;
    let mut t = Table::new(&["tracing", "reps", "submit->RUNNING p50 ms", "overhead %"]);
    t.row(&[n("off"), n(reps), f2(off_p50), n("-")]);
    t.row(&[n("on"), n(reps), f2(on_p50), f2(overhead_pct)]);
    t.print("L1c: lifecycle span-store overhead on the submit->RUNNING path");

    let mut t = Table::new(&["stage", "wall ms"]);
    for (stage, ms) in &last_stages {
        t.row(&[n(stage.as_str()), n(*ms)]);
    }
    t.print("L1d: per-stage breakdown of the last traced run");

    if smoke {
        // Compare best-of runs: minima are far less noisy than p50 at
        // smoke rep counts.  Floor the budget so sub-10ms baselines
        // don't turn scheduler jitter into failures.
        let budget = (off[0] * 0.05).max(5.0);
        assert!(
            on[0] - off[0] <= budget,
            "tracing overhead too high: on={:.2}ms off={:.2}ms budget={:.2}ms",
            on[0],
            off[0],
            budget
        );
    }

    let _ = std::fs::remove_dir_all(&base);
    println!("\nbench_latency done.");
}
