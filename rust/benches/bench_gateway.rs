//! Gateway throughput bench: accepted-submissions/sec through the HTTP
//! API, and end-to-end job throughput at 1 / 8 / 32 concurrent jobs on
//! one shared simulated cluster — the multi-tenant operating point the
//! paper's orchestration story targets (and the C1 contention tables
//! only approximate with synthetic jobs).
//!
//! Per level: start `serve`-equivalent machinery (Gateway + API), POST
//! 2×level jobs (min 8), wait for all to reach a terminal state, then
//! verify the invariants the gateway exists to provide: admission
//! decisions visible via `GET /api/v1/jobs`, every job FINISHED, every
//! finished job recorded in the HistoryStore (and serving a per-stage
//! trace from it), and all RM capacity returned.  A second table breaks
//! the end-to-end number down by lifecycle stage
//! (queued/scheduling/launching/…), averaged across the level's jobs —
//! so a throughput regression names the stage that slowed down.
//!
//! A third table (G3) isolates the **WAL cost on the submit path**:
//! per-submission latency with the WAL off, on with fsync (group
//! commit), and on without fsync — the no-fsync row is the pure
//! staging overhead the <10% p50 budget applies to; the fsync row is
//! dominated by the disk sync itself.  See docs/DURABILITY.md.
//!
//! `TONY_BENCH_SMOKE=1` shrinks the levels and submission counts so CI
//! can run the bench as a regression gate.

use std::time::{Duration, Instant};

use tony::bench::{f1, f2, n, Table};
use tony::gateway::{api, Gateway, GatewayConf, JobState, SubmitOutcome};
use tony::json::Json;
use tony::portal::http_request;
use tony::tonyconf::JobConfBuilder;
use tony::xmlconf::Configuration;
use tony::yarn::{Resource, ResourceManager};

fn job_conf(name: &str, steps: u64) -> Configuration {
    JobConfBuilder::new(name)
        .instances("worker", 1)
        .memory("worker", "256m")
        .instances("ps", 1)
        .memory("ps", "256m")
        .set("tony.am.memory", "256m")
        .set("tony.train.steps", &steps.to_string())
        .set("tony.train.checkpoint-every", "0")
        .build()
}

struct LevelResult {
    concurrency: usize,
    jobs: usize,
    submit_per_sec: f64,
    e2e_ms: f64,
    jobs_per_sec: f64,
    peak_running: usize,
    finished: usize,
    in_history: usize,
    /// Jobs whose completed trace replayed from the history store.
    traced: usize,
    /// Summed per-stage wall-clock millis across all traced jobs.
    stage_ms: std::collections::BTreeMap<String, u64>,
}

fn run_level(concurrency: usize) -> LevelResult {
    let base = std::env::temp_dir().join(format!(
        "tony-bench-gw-{}-{}",
        std::process::id(),
        concurrency
    ));
    let _ = std::fs::remove_dir_all(&base);
    // 16 nodes x 4 GiB / 16 cores: 32 jobs (768 MiB each) fit fully, so
    // the bench measures orchestration throughput, not queueing stalls.
    let rm = ResourceManager::start_uniform(16, Resource::new(4096, 16, 0));
    let mut conf = GatewayConf::new(base.join("artifacts"));
    conf.history_dir = base.join("history");
    conf.workers = concurrency;
    conf.queue_depth = 256;
    conf.quotas.max_active_per_user = 10_000; // throughput, not quotas
    let gw = Gateway::start(rm, conf).expect("gateway start");
    let api_srv = api::GatewayApi::start(gw.clone(), 0).expect("api start");
    let hostport = api_srv.addr.to_string();

    let jobs = (concurrency * 2).max(8);
    let t_submit = Instant::now();
    let mut ids = Vec::with_capacity(jobs);
    for i in 0..jobs {
        let user = format!("user{}", i % 8);
        let (id, _) =
            api::submit_remote(&hostport, &user, 1 + (i % 3) as u8, &job_conf(&format!("j{i}"), 3))
                .expect("accept");
        ids.push(id);
    }
    let submit_s = t_submit.elapsed().as_secs_f64();

    // Watch the run: track the peak number of concurrently RUNNING jobs.
    let mut peak_running = 0usize;
    let t0 = Instant::now();
    loop {
        let (_, running) = gw.live_counts();
        peak_running = peak_running.max(running);
        if gw.wait_idle(Duration::from_millis(20)) {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(600),
            "gateway wedged at concurrency {concurrency}"
        );
    }
    let e2e_s = t0.elapsed().as_secs_f64();

    // Admission decisions visible over the API.
    let (status, body) =
        http_request("GET", &format!("http://{hostport}/api/v1/jobs"), "").expect("GET jobs");
    assert_eq!(status, 200);
    let listing = Json::parse(&body).expect("jobs json");
    let listed = listing.get("jobs").and_then(|a| a.as_arr()).map(|a| a.len()).unwrap_or(0);
    assert_eq!(listed, jobs, "every submission visible via GET /api/v1/jobs");

    let finished =
        ids.iter().filter(|id| gw.job_state(**id) == Some(JobState::Finished)).count();
    let in_history = gw.history().list().expect("history list").len();
    for (_, free, cap) in gw.rm().node_usage() {
        assert_eq!(free, cap, "capacity leaked at concurrency {concurrency}");
    }

    // Per-stage breakdown: completed jobs serve their trace from the
    // history store, so this also exercises the replay path at scale.
    let mut stage_ms: std::collections::BTreeMap<String, u64> = std::collections::BTreeMap::new();
    let mut traced = 0usize;
    for id in &ids {
        let Some(t) = gw.job_trace_json(*id) else { continue };
        if let Some(stages) = t.at(&["critical_path", "stages"]).and_then(|s| s.as_obj()) {
            traced += 1;
            for (stage, ms) in stages {
                *stage_ms.entry(stage.clone()).or_insert(0) += ms.as_u64().unwrap_or(0);
            }
        }
    }
    gw.shutdown();
    let _ = std::fs::remove_dir_all(&base);

    LevelResult {
        concurrency,
        jobs,
        submit_per_sec: jobs as f64 / submit_s.max(1e-9),
        e2e_ms: e2e_s * 1e3,
        jobs_per_sec: jobs as f64 / e2e_s.max(1e-9),
        peak_running,
        finished,
        in_history,
        traced,
        stage_ms,
    }
}

fn percentile(sorted_us: &[f64], p: f64) -> f64 {
    if sorted_us.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_us.len() as f64 - 1.0) * p).round() as usize;
    sorted_us[idx.min(sorted_us.len() - 1)]
}

/// Per-submission latency through `submit_conf` (admission + table
/// insert + WAL append when enabled).  One worker, a deep queue, and
/// kill-from-queue afterwards keep job *execution* out of the number.
/// `fsync: None` = WAL off; `Some(true/false)` = WAL on with/without
/// fsync-before-ack.
fn run_wal_mode(mode: &str, fsync: Option<bool>, submissions: usize) -> (f64, f64) {
    let base =
        std::env::temp_dir().join(format!("tony-bench-gwwal-{}-{mode}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    let rm = ResourceManager::start_uniform(4, Resource::new(16384, 64, 0));
    let mut conf = GatewayConf::new(base.join("artifacts"));
    conf.history_dir = base.join("history");
    conf.workers = 1;
    conf.queue_depth = submissions + 8;
    conf.quotas.max_active_per_user = 1_000_000;
    if let Some(fsync) = fsync {
        let mut site = Configuration::new();
        site.set("tony.wal.enable", "true");
        site.set("tony.wal.dir", base.join("wal").to_string_lossy().into_owned());
        // Count-triggered snapshots off so the rows measure append cost
        // alone, not an occasional compaction.
        site.set("tony.wal.snapshot-every", "0");
        site.set("tony.wal.fsync", if fsync { "true" } else { "false" });
        conf.apply_site_conf(&site);
    }
    let gw = Gateway::start(rm, conf).expect("gateway start");

    let mut lat_us = Vec::with_capacity(submissions);
    let mut ids = Vec::with_capacity(submissions);
    for i in 0..submissions {
        let job = job_conf(&format!("w{i}"), 1);
        let t = Instant::now();
        match gw.submit_conf("bench", 1, job) {
            SubmitOutcome::Accepted { id } => {
                lat_us.push(t.elapsed().as_secs_f64() * 1e6);
                ids.push(id);
            }
            other => panic!("submission {i} rejected in {mode} mode: {other:?}"),
        }
    }
    // Tear down without executing the backlog.
    for id in &ids {
        let _ = gw.kill(*id);
    }
    assert!(
        gw.wait_idle(Duration::from_secs(120)),
        "wal bench gateway never drained ({mode})"
    );
    for (_, free, cap) in gw.rm().node_usage() {
        assert_eq!(free, cap, "capacity leaked in wal bench ({mode})");
    }
    gw.shutdown();
    let _ = std::fs::remove_dir_all(&base);

    lat_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (percentile(&lat_us, 0.50), percentile(&lat_us, 0.90))
}

fn main() {
    let smoke = std::env::var("TONY_BENCH_SMOKE").is_ok();
    let mut table = Table::new(&[
        "concurrency",
        "jobs",
        "submits/s",
        "e2e-ms",
        "jobs/s",
        "peak-running",
        "finished",
        "in-history",
    ]);
    let mut results = Vec::new();
    let levels: &[usize] = if smoke { &[1, 4] } else { &[1, 8, 32] };
    for &concurrency in levels {
        let r = run_level(concurrency);
        assert_eq!(r.finished, r.jobs, "all jobs must finish at concurrency {concurrency}");
        assert!(
            r.in_history >= r.jobs,
            "every finished job must land in the history store \
             ({} < {} at concurrency {concurrency})",
            r.in_history,
            r.jobs
        );
        assert_eq!(
            r.traced, r.jobs,
            "every finished job must serve a per-stage trace from history \
             at concurrency {concurrency}"
        );
        table.row(&[
            n(concurrency),
            n(r.jobs),
            f1(r.submit_per_sec),
            f1(r.e2e_ms),
            f2(r.jobs_per_sec),
            n(r.peak_running),
            n(r.finished),
            n(r.in_history),
        ]);
        results.push(r);
    }
    table.print("G1: gateway multi-tenant throughput (accepted submissions + end-to-end jobs)");

    let mut stages = Table::new(&["concurrency", "stage", "avg ms/job"]);
    for r in &results {
        for (stage, total) in &r.stage_ms {
            stages.row(&[
                n(r.concurrency),
                n(stage),
                f1(*total as f64 / r.traced.max(1) as f64),
            ]);
        }
    }
    stages.print("G2: per-stage lifecycle breakdown (from replayed job traces)");
    if !smoke {
        println!(
            "\n(64 jobs at concurrency 32 ran on one shared 16-node simulated cluster; \
             quotas disabled so the table isolates orchestration throughput.)"
        );
    }

    // G3: submit-path cost of the durability WAL (docs/DURABILITY.md).
    let wal_subs = if smoke { 24 } else { 192 };
    let (off50, off90) = run_wal_mode("off", None, wal_subs);
    let (stage50, stage90) = run_wal_mode("on-nofsync", Some(false), wal_subs);
    let (sync50, sync90) = run_wal_mode("on-fsync", Some(true), wal_subs);
    let overhead = |p50: f64| (p50 / off50.max(1e-9) - 1.0) * 100.0;
    let mut wal_table = Table::new(&["wal", "submissions", "p50-us", "p90-us", "p50 vs off"]);
    wal_table.row(&[n("off"), n(wal_subs), f1(off50), f1(off90), n("—")]);
    wal_table.row(&[
        n("on (no fsync)"),
        n(wal_subs),
        f1(stage50),
        f1(stage90),
        format!("{:+.1}%", overhead(stage50)),
    ]);
    wal_table.row(&[
        n("on (fsync)"),
        n(wal_subs),
        f1(sync50),
        f1(sync90),
        format!("{:+.1}%", overhead(sync50)),
    ]);
    wal_table.print("G3: WAL overhead on the submit path (per-submission latency)");
    println!(
        "\n(budget: no-fsync staging overhead within +10% of the WAL-off p50; \
         the fsync row pays the disk sync group commit amortizes across \
         concurrent submitters — see docs/DURABILITY.md)"
    );
}
