//! End-to-end integration: a full TonY job through the whole stack —
//! client → RM → AM container → task containers → TaskExecutors →
//! cluster-spec rendezvous → PS/worker training over TCP → PJRT HLO
//! execution → job completion.  Requires `make artifacts` (tiny preset).

use std::sync::Arc;
use std::time::Duration;

use tony::client::TonyClient;
use tony::tonyconf::JobConfBuilder;
use tony::yarn::{AppState, Resource, ResourceManager};

fn tiny_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    if dir.join("meta.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/tiny missing; run `make artifacts`");
        None
    }
}

fn ckpt_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!(
        "tony-test-{tag}-{}-{}",
        std::process::id(),
        tony::util::ids::next_seq()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn single_worker_single_ps_job_trains() {
    let Some(dir) = tiny_dir() else { return };
    let rm = ResourceManager::start_uniform(3, Resource::new(8192, 8, 0));
    let ckpt = ckpt_dir("1w1p");
    let conf = JobConfBuilder::new("tiny-train")
        .instances("worker", 1)
        .memory("worker", "1g")
        .instances("ps", 1)
        .memory("ps", "1g")
        .train(dir.to_str().unwrap(), "tiny", 8)
        .set("tony.train.checkpoint-dir", ckpt.to_str().unwrap())
        .set("tony.train.checkpoint-every", "4")
        .set("tony.train.eval-every", "4")
        .build();

    let client = TonyClient::new(rm.clone());
    let handle = client.submit(&conf, &dir).unwrap();
    let report = handle.wait(Duration::from_secs(180)).unwrap();
    assert_eq!(report.state, AppState::Finished, "{}", report.diagnostics);

    // Chief trained to the target step and recorded losses.
    let metrics = handle.am_state.chief_metrics().unwrap();
    assert_eq!(metrics.step, 8);
    assert!(metrics.loss.is_finite() && metrics.loss > 0.0);
    assert!(metrics.finished);
    assert!(!metrics.loss_history.is_empty());
    assert!(metrics.eval_loss > 0.0, "eval ran");

    // Checkpoints exist (steps 4 and 8).
    let store = tony::checkpoint::CheckpointStore::new(&ckpt);
    let steps = store.list().unwrap();
    assert!(steps.contains(&8), "final checkpoint saved: {steps:?}");

    // Cluster capacity fully returned.
    for (_, free, cap) in rm.node_usage() {
        assert_eq!(free, cap, "capacity leaked");
    }
    let _ = std::fs::remove_dir_all(&ckpt);
}

#[test]
fn multi_worker_multi_ps_sync_training_converges() {
    let Some(dir) = tiny_dir() else { return };
    let rm = ResourceManager::start_uniform(4, Resource::new(8192, 8, 0));
    let ckpt = ckpt_dir("2w2p");
    let steps = 20u64;
    let conf = JobConfBuilder::new("sync-train")
        .instances("worker", 2)
        .memory("worker", "1g")
        .instances("ps", 2)
        .memory("ps", "1g")
        .train(dir.to_str().unwrap(), "tiny", steps)
        .set("tony.train.checkpoint-dir", ckpt.to_str().unwrap())
        .set("tony.train.checkpoint-every", "10")
        .set("tony.train.lr", "0.002")
        .build();

    let client = TonyClient::new(rm.clone());
    let handle = client.submit(&conf, &dir).unwrap();
    // The chief's UI URL must flow back to the client (paper §2.2).
    let report = handle.wait(Duration::from_secs(300)).unwrap();
    assert_eq!(report.state, AppState::Finished, "{}", report.diagnostics);
    assert!(handle.ui_url().is_some(), "worker:0 registered a UI URL");

    let metrics = handle.am_state.chief_metrics().unwrap();
    assert_eq!(metrics.step, steps);
    // Loss must drop from the ~ln(256)=5.55 random-init level.
    let first = metrics.loss_history.first().unwrap().1;
    let last = metrics.loss_history.last().unwrap().1;
    assert!(
        last < first && last < 5.0,
        "loss should decrease: first={first} last={last}"
    );
    let _ = std::fs::remove_dir_all(&ckpt);
}

#[test]
fn async_mode_trains_too() {
    let Some(dir) = tiny_dir() else { return };
    let rm = ResourceManager::start_uniform(3, Resource::new(8192, 8, 0));
    let ckpt = ckpt_dir("async");
    let conf = JobConfBuilder::new("async-train")
        .instances("worker", 2)
        .memory("worker", "1g")
        .instances("ps", 1)
        .memory("ps", "1g")
        .train(dir.to_str().unwrap(), "tiny", 6)
        .set("tony.train.mode", "async")
        .set("tony.train.checkpoint-dir", ckpt.to_str().unwrap())
        .set("tony.train.checkpoint-every", "0")
        .build();
    let client = TonyClient::new(rm.clone());
    let handle = client.submit(&conf, &dir).unwrap();
    let report = handle.wait(Duration::from_secs(180)).unwrap();
    assert_eq!(report.state, AppState::Finished, "{}", report.diagnostics);
    let _ = std::fs::remove_dir_all(&ckpt);
}

#[test]
fn client_rejects_impossible_and_stale_jobs() {
    let Some(dir) = tiny_dir() else { return };
    let rm = ResourceManager::start_uniform(1, Resource::new(2048, 2, 0));
    let client = TonyClient::new(rm.clone());
    // Too big for the cluster, ever.
    let conf = JobConfBuilder::new("huge")
        .instances("worker", 64)
        .memory("worker", "4g")
        .train(dir.to_str().unwrap(), "tiny", 1)
        .build();
    assert!(client.submit(&conf, &dir).is_err());
    // Bad artifacts dir.
    let conf = JobConfBuilder::new("noart")
        .instances("worker", 1)
        .train("/nonexistent", "tiny", 1)
        .build();
    assert!(client.submit(&conf, std::path::Path::new("/nonexistent")).is_err());
}

#[test]
fn gpu_labeled_workers_schedule_on_gpu_nodes() {
    let Some(dir) = tiny_dir() else { return };
    use tony::yarn::{NodeSpec, QueueConf};
    let specs = vec![
        NodeSpec::new(0, Resource::new(8192, 8, 0)),
        NodeSpec::new(1, Resource::new(8192, 8, 2)).with_label("gpu"),
    ];
    let rm = ResourceManager::start(specs, QueueConf::default_only());
    let ckpt = ckpt_dir("gpu");
    let conf = JobConfBuilder::new("gpu-job")
        .instances("worker", 2)
        .memory("worker", "1g")
        .gpus("worker", 1)
        .node_label("worker", "gpu")
        .instances("ps", 1)
        .memory("ps", "1g")
        .train(dir.to_str().unwrap(), "tiny", 4)
        .set("tony.train.checkpoint-dir", ckpt.to_str().unwrap())
        .set("tony.train.checkpoint-every", "0")
        .build();
    let client = TonyClient::new(rm.clone());
    let handle = client.submit(&conf, &dir).unwrap();
    let report = handle.wait(Duration::from_secs(180)).unwrap();
    assert_eq!(report.state, AppState::Finished, "{}", report.diagnostics);
    let _ = std::fs::remove_dir_all(&ckpt);
}
