//! End-to-end tests for the live observability plane: the gateway's
//! aggregated Prometheus scrape across concurrent tenant jobs, the
//! per-job JSON series endpoint (live and from history after
//! completion), and the JSON-404 contract across the HTTP surface.

use std::sync::Arc;
use std::time::{Duration, Instant};

use tony::gateway::{Gateway, GatewayApi, GatewayConf, SubmitOutcome};
use tony::json::Json;
use tony::portal::{http_get, http_request};
use tony::tonyconf::JobConfBuilder;
use tony::xmlconf::Configuration;
use tony::yarn::{Resource, ResourceManager};

fn gateway(tag: &str, workers: usize) -> Arc<Gateway> {
    let base = std::env::temp_dir().join(format!(
        "tony-obs-{tag}-{}-{}",
        std::process::id(),
        tony::util::ids::next_seq()
    ));
    let mut conf = GatewayConf::new(base.join("artifacts"));
    conf.history_dir = base.join("history");
    conf.workers = workers;
    conf.job_timeout = Duration::from_secs(120);
    let rm = ResourceManager::start_uniform(2, Resource::new(4096, 8, 0));
    Gateway::start(rm, conf).unwrap()
}

fn long_job(name: &str, steps: u64) -> Configuration {
    JobConfBuilder::new(name)
        .instances("worker", 1)
        .memory("worker", "512m")
        .instances("ps", 1)
        .memory("ps", "512m")
        .set("tony.am.memory", "256m")
        .set("tony.train.steps", &steps.to_string())
        // Sample aggressively so even a short run stores a series.
        .set("tony.metrics.sample-interval-ms", "5")
        .build()
}

#[test]
fn gateway_metrics_aggregate_across_concurrent_jobs() {
    let gw = gateway("agg", 2);
    let api = GatewayApi::start(gw.clone(), 0).unwrap();
    let url = api.url();
    let SubmitOutcome::Accepted { id: a } = gw.submit_conf("alice", 1, long_job("job-a", 5000))
    else {
        panic!("job-a rejected")
    };
    let SubmitOutcome::Accepted { id: b } = gw.submit_conf("bob", 1, long_job("job-b", 5000))
    else {
        panic!("job-b rejected")
    };

    // Poll the aggregated scrape until both tenants' tasks appear.
    let deadline = Instant::now() + Duration::from_secs(60);
    let body = loop {
        let (code, body) = http_get(&format!("{url}/metrics")).unwrap();
        assert_eq!(code, 200);
        if body.contains("user=\"alice\"") && body.contains("user=\"bob\"") {
            break body;
        }
        assert!(
            Instant::now() < deadline,
            "both jobs never appeared in /metrics:\n{body}"
        );
        tony::util::clock::real_sleep(Duration::from_millis(50));
    };
    // Per-task gauges carry job/id/user/queue labels per tenant job.
    assert!(
        body.contains(&format!(
            "tony_task_step{{job=\"job-a\",id=\"{a}\",user=\"alice\",queue=\"default\",task=\"worker:0\"}}"
        )),
        "{body}"
    );
    assert!(
        body.contains(&format!(
            "tony_task_step{{job=\"job-b\",id=\"{b}\",user=\"bob\",queue=\"default\",task=\"worker:0\"}}"
        )),
        "{body}"
    );
    // Cluster gauges and gateway counters ride along in the same scrape.
    assert!(body.contains("tony_queue_utilization{queue=\"default\"}"), "{body}");
    assert!(body.contains("# TYPE tony_gateway_jobs_total counter"), "{body}");
    assert!(body.contains("tony_gateway_jobs_total{outcome=\"accepted\"} 2"), "{body}");

    // Live per-job series + phase while the job runs.
    let (code, jbody) = http_get(&format!("{url}/api/v1/jobs/{a}/metrics")).unwrap();
    assert_eq!(code, 200);
    assert!(Json::parse(&jbody).unwrap().get("tasks").is_some(), "{jbody}");
    let (code, jbody) = http_get(&format!("{url}/api/v1/jobs/{a}")).unwrap();
    assert_eq!(code, 200);
    let j = Json::parse(&jbody).unwrap();
    assert!(j.get("phase").is_some(), "running job exposes live phase: {jbody}");

    // Finished jobs stay inspectable: the series endpoint switches to
    // the down-sampled history record.
    gw.kill(a);
    gw.kill(b);
    assert!(gw.wait_idle(Duration::from_secs(60)), "killed jobs never settled");
    let (code, jbody) = http_get(&format!("{url}/api/v1/jobs/{a}/metrics")).unwrap();
    assert_eq!(code, 200);
    let j = Json::parse(&jbody).unwrap();
    assert!(
        j.at(&["tasks", "worker:0"]).is_some(),
        "history series served after completion: {jbody}"
    );
    gw.shutdown();
}

#[test]
fn gateway_unknown_routes_and_ids_return_json_404() {
    let gw = gateway("404", 1);
    let api = GatewayApi::start(gw.clone(), 0).unwrap();
    let url = api.url();
    for (method, path) in [
        ("GET", "/nope"),
        ("GET", "/api/v1/nope"),
        ("GET", "/api/v1/jobs/999"),
        ("GET", "/api/v1/jobs/abc"),
        ("GET", "/api/v1/jobs/999/metrics"),
        ("GET", "/api/v1/jobs/abc/metrics"),
        ("DELETE", "/api/v1/jobs/999"),
        ("POST", "/api/v1/cluster"),
    ] {
        let (code, body) = http_request(method, &format!("{url}{path}"), "").unwrap();
        assert_eq!(code, 404, "{method} {path}: {body}");
        let j = Json::parse(&body)
            .unwrap_or_else(|e| panic!("{method} {path}: non-JSON 404 body ({e}): {body}"));
        assert_eq!(
            j.get("code").and_then(|c| c.as_str()),
            Some("not-found"),
            "{method} {path}: {body}"
        );
        assert!(j.get("error").is_some(), "{method} {path}: {body}");
    }
    gw.shutdown();
}
