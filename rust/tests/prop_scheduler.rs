//! Property tests: CapacityScheduler invariants under random workloads
//! (DESIGN.md §8 testing tiers) — the coordinator-correctness core of the repro.
//!
//! Two families: the classic placement/gang/preemption invariants, and
//! the PR 9 index-consistency suite — the skyline-indexed placement path
//! must match the retained linear reference **exactly** on randomized
//! cluster/ask/release/preemption sequences, and every cached structure
//! (skylines, dominant shares, gang/reservation counters) must agree
//! with a from-scratch recompute after every mutation
//! (`CapacityScheduler::verify_invariants`).

use std::collections::BTreeMap;

use tony::proptest::{check, Gen};
use tony::util::ids::{ApplicationId, ContainerId, NodeId};
use tony::yarn::scheduler::SchedNode;
use tony::yarn::{CapacityScheduler, ContainerRequest, QueueConf, Resource, VictimCandidate};
use tony::{prop_assert, prop_assert_eq};

fn gen_nodes(g: &mut Gen) -> Vec<SchedNode> {
    let n = g.range(1, 20) as u32;
    (0..n)
        .map(|i| {
            let label = match g.usize_up_to(3) {
                0 => Some("gpu".to_string()),
                1 => Some("high-memory".to_string()),
                _ => None,
            };
            let cap =
                Resource::new(g.range(1024, 32768), g.range(1, 32) as u32, g.range(0, 4) as u32);
            SchedNode::new(i, label, cap)
        })
        .collect()
}

fn gen_asks(g: &mut Gen) -> Vec<ContainerRequest> {
    let n = g.range(1, 12);
    (0..n)
        .map(|_| {
            let mut req = ContainerRequest::new(
                Resource::new(g.range(128, 8192), g.range(1, 8) as u32, g.range(0, 2) as u32),
                g.range(1, 6) as u32,
            )
            .with_priority(g.range(1, 5) as u8);
            match g.usize_up_to(3) {
                0 => req = req.with_label("gpu"),
                1 => req = req.with_label("high-memory"),
                _ => {}
            }
            req
        })
        .collect()
}

#[test]
fn never_oversubscribes_any_dimension() {
    check("no oversubscription", 200, |g| {
        let nodes = gen_nodes(g);
        let orig: BTreeMap<u32, Resource> = nodes.iter().map(|n| (n.id.0, n.free)).collect();
        let total = nodes.iter().fold(Resource::ZERO, |a, n| a + n.free);
        let mut sched = CapacityScheduler::new(QueueConf::default_only(), total);
        sched.set_nodes(nodes);
        let app = ApplicationId { cluster_ts: 1, seq: 1 };
        sched.add_asks(app, "default", &gen_asks(g), 0);
        let grants = sched.schedule();

        // Per-node conservation: free + granted == original, no negatives.
        let mut granted_per_node: BTreeMap<u32, Resource> = BTreeMap::new();
        for gr in &grants {
            *granted_per_node.entry(gr.node.0).or_insert(Resource::ZERO) += gr.ask.resource;
        }
        for n in sched.nodes() {
            let used = granted_per_node.get(&n.id.0).copied().unwrap_or(Resource::ZERO);
            let orig_free = orig[&n.id.0];
            prop_assert_eq!(n.free + used, orig_free);
            prop_assert!(
                orig_free.fits(&used),
                "node {} oversubscribed: {used} > {orig_free}",
                n.id.0
            );
        }
        sched.verify_invariants();
        Ok(())
    });
}

#[test]
fn labels_always_respected() {
    check("label partitions", 200, |g| {
        let nodes = gen_nodes(g);
        let labels: BTreeMap<u32, Option<String>> =
            nodes.iter().map(|n| (n.id.0, n.label.clone())).collect();
        let total = nodes.iter().fold(Resource::ZERO, |a, n| a + n.free);
        let mut sched = CapacityScheduler::new(QueueConf::default_only(), total);
        sched.set_nodes(nodes);
        let app = ApplicationId { cluster_ts: 1, seq: 1 };
        sched.add_asks(app, "default", &gen_asks(g), 0);
        for gr in sched.schedule() {
            prop_assert_eq!(&labels[&gr.node.0], &gr.ask.node_label);
        }
        Ok(())
    });
}

#[test]
fn queue_max_capacity_is_never_exceeded() {
    check("queue ceilings", 200, |g| {
        let cap_a = 0.1 + g.f64() * 0.8;
        let max_a = (cap_a + g.f64() * (1.0 - cap_a)).min(1.0);
        let queues = vec![
            QueueConf::new("a", cap_a, max_a),
            QueueConf::new("b", 1.0 - cap_a, 1.0),
        ];
        let nodes = gen_nodes(g);
        let total = nodes.iter().fold(Resource::ZERO, |a, n| a + n.free);
        let mut sched = CapacityScheduler::new(queues, total);
        sched.set_nodes(nodes);
        let app1 = ApplicationId { cluster_ts: 1, seq: 1 };
        let app2 = ApplicationId { cluster_ts: 1, seq: 2 };
        let t = sched.add_asks(app1, "a", &gen_asks(g), 0);
        sched.add_asks(app2, "b", &gen_asks(g), t);
        sched.schedule();
        let used_a = sched.queue_used("a").unwrap();
        prop_assert!(
            used_a.dominant_share(&total) <= max_a + 1e-6,
            "queue a used {used_a} > {max_a} of {total}"
        );
        Ok(())
    });
}

#[test]
fn scheduling_is_deterministic() {
    check("determinism", 100, |g| {
        let nodes = gen_nodes(g);
        let asks = gen_asks(g);
        let total = nodes.iter().fold(Resource::ZERO, |a, n| a + n.free);
        let app = ApplicationId { cluster_ts: 1, seq: 1 };
        let run = || {
            let mut sched = CapacityScheduler::new(QueueConf::default_only(), total);
            sched.set_nodes(nodes.clone());
            sched.add_asks(app, "default", &asks, 0);
            sched.schedule()
        };
        prop_assert_eq!(run(), run());
        Ok(())
    });
}

#[test]
fn release_enables_pending_work() {
    check("release unblocks", 100, |g| {
        // One node exactly big enough for one container at a time.
        let shape = Resource::new(1024 + g.range(0, 1024), 1, 0);
        let mut sched = CapacityScheduler::new(QueueConf::default_only(), shape);
        sched.set_nodes(vec![SchedNode::new(0, None, shape)]);
        let app = ApplicationId { cluster_ts: 1, seq: 1 };
        let count = g.range(2, 6) as u32;
        sched.add_asks(app, "default", &[ContainerRequest::new(shape, count)], 0);
        let mut granted = 0;
        for _ in 0..count {
            let grants = sched.schedule();
            prop_assert_eq!(grants.len(), 1);
            granted += 1;
            // Simulate completion: return queue charge + node capacity.
            sched.release_container("default", NodeId(0), shape);
        }
        prop_assert_eq!(granted, count);
        prop_assert_eq!(sched.pending_count(), 0);
        sched.verify_invariants();
        Ok(())
    });
}

/// Random mix of gangs (one per app) and loose singles.  Returns the
/// scheduler with everything enqueued plus the size of each gang.
fn gen_gang_mix(
    g: &mut Gen,
    queues: Vec<QueueConf>,
    nodes: Vec<SchedNode>,
    total: Resource,
) -> (CapacityScheduler, BTreeMap<u64, u32>) {
    let qnames: Vec<String> = queues.iter().map(|q| q.name.clone()).collect();
    let mut sched = CapacityScheduler::new(queues, total);
    sched.set_nodes(nodes);
    let n_gangs = g.range(1, 6);
    let mut sizes = BTreeMap::new();
    let mut tag = 0;
    for k in 0..n_gangs {
        let app = ApplicationId { cluster_ts: 1, seq: k + 1 };
        let count = g.range(1, 6) as u32;
        let mut req = ContainerRequest::new(
            Resource::new(g.range(128, 8192), g.range(1, 8) as u32, g.range(0, 2) as u32),
            count,
        )
        .with_priority(g.range(1, 5) as u8);
        if g.usize_up_to(4) == 0 {
            req = req.with_label("gpu");
        }
        let q = &qnames[g.usize_up_to(qnames.len() - 1)];
        tag = sched.add_asks_gang(app, q, &[req], tag, Some(k + 1)).next_tag;
        sizes.insert(k + 1, count);
    }
    // Loose singles riding along.
    let app = ApplicationId { cluster_ts: 1, seq: 99 };
    let q = &qnames[g.usize_up_to(qnames.len() - 1)];
    sched.add_asks(app, q, &gen_asks(g), tag);
    (sched, sizes)
}

#[test]
fn gangs_are_granted_fully_or_not_at_all() {
    check("gang atomicity", 200, |g| {
        let nodes = gen_nodes(g);
        let total = nodes.iter().fold(Resource::ZERO, |a, n| a + n.free);
        let (mut sched, sizes) = gen_gang_mix(g, QueueConf::default_only(), nodes, total);
        let grants = sched.schedule();
        let mut granted: BTreeMap<u64, u32> = BTreeMap::new();
        for gr in &grants {
            if let Some(id) = gr.ask.gang {
                *granted.entry(id).or_insert(0) += 1;
            }
        }
        for (id, n) in granted {
            prop_assert!(
                n == sizes[&id],
                "gang {id} partially granted: {n}/{} containers",
                sizes[&id]
            );
        }
        sched.verify_invariants();
        Ok(())
    });
}

#[test]
fn no_oversubscription_under_gang_mixes() {
    check("gang no-oversubscription", 200, |g| {
        let nodes = gen_nodes(g);
        let orig: BTreeMap<u32, Resource> = nodes.iter().map(|n| (n.id.0, n.free)).collect();
        let total = nodes.iter().fold(Resource::ZERO, |a, n| a + n.free);
        let queues = vec![QueueConf::new("a", 0.5, 0.8), QueueConf::new("b", 0.5, 1.0)];
        let (mut sched, _) = gen_gang_mix(g, queues, nodes, total);
        let grants = sched.schedule();
        let mut granted_per_node: BTreeMap<u32, Resource> = BTreeMap::new();
        for gr in &grants {
            *granted_per_node.entry(gr.node.0).or_insert(Resource::ZERO) += gr.ask.resource;
        }
        for n in sched.nodes() {
            let used = granted_per_node.get(&n.id.0).copied().unwrap_or(Resource::ZERO);
            let orig_free = orig[&n.id.0];
            prop_assert_eq!(n.free + used, orig_free);
            prop_assert!(
                orig_free.fits(&used),
                "node {} oversubscribed: {used} > {orig_free}",
                n.id.0
            );
        }
        // Queue ceilings hold too.
        for q in sched.queue_snapshots() {
            prop_assert!(
                q.used.dominant_share(&total) <= q.max_capacity + 1e-6,
                "queue {} burst past its ceiling",
                q.name
            );
        }
        Ok(())
    });
}

#[test]
fn preemption_never_drives_a_queue_below_its_guarantee() {
    check("preemption guarantee floor", 150, |g| {
        let cap_a = 0.2 + g.f64() * 0.6;
        let queues = vec![
            QueueConf::new("a", cap_a, 1.0),
            QueueConf::new("b", 1.0 - cap_a, 1.0),
        ];
        let nodes = gen_nodes(g);
        let total = nodes.iter().fold(Resource::ZERO, |a, n| a + n.free);
        let mut sched = CapacityScheduler::new(queues, total);
        sched.set_nodes(nodes);
        // Queue b grabs as much as it can (possibly over its guarantee).
        let app_b = ApplicationId { cluster_ts: 1, seq: 2 };
        sched.add_asks(app_b, "b", &gen_asks(g), 0);
        let b_grants = sched.schedule();
        let candidates: Vec<VictimCandidate> = b_grants
            .iter()
            .enumerate()
            .map(|(i, gr)| VictimCandidate {
                container: ContainerId { app: gr.ask.app, seq: i as u64 + 1 },
                app: gr.ask.app,
                queue: gr.ask.queue.clone(),
                node: gr.node,
                resource: gr.ask.resource,
                gang: gr.ask.gang,
                seq: i as u64 + 1,
            })
            .collect();
        // Queue a (starved) asks a random gang.
        let app_a = ApplicationId { cluster_ts: 1, seq: 1 };
        let req = ContainerRequest::new(
            Resource::new(g.range(128, 4096), g.range(1, 4) as u32, 0),
            g.range(1, 5) as u32,
        );
        sched.add_asks_gang(app_a, "a", &[req], 1000, Some(1));
        let used_b_before = sched.queue_used("b").unwrap();
        let victims = sched.preemption_plan(&candidates, g.range(1, 8) as usize);
        let freed = victims.iter().fold(Resource::ZERO, |a, v| a + v.resource);
        let after = used_b_before - freed;
        if !victims.is_empty() {
            prop_assert!(
                after.dominant_share(&total) >= (1.0 - cap_a) - 1e-6,
                "queue b driven below its guarantee: {after} of {total}"
            );
        }
        sched.verify_invariants();
        Ok(())
    });
}

#[test]
fn reservations_eventually_drain() {
    check("reservation drain", 100, |g| {
        // One node fully occupied by out-of-band work the scheduler does
        // not charge to any queue (so the blocked gang is *node*-blocked,
        // not ceiling-blocked — ceiling-blocked gangs wait unreserved).
        // As occupants finish, the reservation must convert into a full
        // gang grant despite a stream of poacher singles — no livelock.
        let slot = Resource::new(1024, 1, 0);
        let n_slots = g.range(2, 6) as u32;
        let cap = Resource::new(1024 * n_slots as u64, n_slots, 0);
        let mut node = SchedNode::new(0, None, cap);
        node.free = Resource::ZERO;
        let mut sched = CapacityScheduler::new(QueueConf::default_only(), cap);
        sched.set_nodes(vec![node]);
        let gang_app = ApplicationId { cluster_ts: 1, seq: 1 };
        sched.add_asks_gang(
            gang_app,
            "default",
            &[ContainerRequest::new(slot, n_slots)],
            100,
            Some(1),
        );
        prop_assert!(sched.schedule().is_empty());
        prop_assert_eq!(sched.reservation_count(), 1);
        // Occupants finish one per round; more singles keep arriving but
        // must not steal the reserved node.
        let mut gang_granted = false;
        let mut extra_tag = 1000;
        for round in 0..(n_slots + 2) {
            sched.add_node_free(NodeId(0), slot);
            extra_tag = sched.add_asks(
                ApplicationId { cluster_ts: 1, seq: 50 },
                "default",
                &[ContainerRequest::new(slot, 1)],
                extra_tag,
            );
            let grants = sched.schedule();
            if grants.iter().any(|gr| gr.ask.gang == Some(1)) {
                let whole = grants.iter().filter(|gr| gr.ask.gang == Some(1)).count();
                prop_assert!(
                    whole == n_slots as usize,
                    "gang granted but not whole in round {round}: {whole}/{n_slots}"
                );
                gang_granted = true;
                break;
            }
            // Until the gang lands, nobody may poach the reserved node.
            prop_assert!(
                grants.is_empty(),
                "single ask poached a reserved node in round {round}: {grants:?}"
            );
        }
        prop_assert!(gang_granted, "reservation never drained into a grant (livelock)");
        sched.verify_invariants();
        Ok(())
    });
}

#[test]
fn grants_never_exceed_asks() {
    check("grant conservation", 150, |g| {
        let nodes = gen_nodes(g);
        let asks = gen_asks(g);
        let asked: u32 = asks.iter().map(|a| a.count).sum();
        let total = nodes.iter().fold(Resource::ZERO, |a, n| a + n.free);
        let mut sched = CapacityScheduler::new(QueueConf::default_only(), total);
        sched.set_nodes(nodes);
        let app = ApplicationId { cluster_ts: 1, seq: 1 };
        sched.add_asks(app, "default", &asks, 0);
        let grants = sched.schedule();
        prop_assert!(grants.len() as u32 <= asked);
        prop_assert_eq!(grants.len() + sched.pending_count(), asked as usize);
        // Second pass with no new capacity grants nothing.
        let again = sched.schedule();
        prop_assert_eq!(again.len(), 0);
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// PR 9 index-consistency suite: indexed placement ≡ linear reference on
// randomized mutation sequences, with every cache checked per step.
// ---------------------------------------------------------------------------

/// One step of a randomized scheduler script.  Both the indexed and the
/// linear scheduler replay the same script; grants and victims must be
/// identical at every point.
#[derive(Debug, Clone)]
enum Op {
    Singles { app: u64, queue: usize, asks: Vec<ContainerRequest> },
    Gang { app: u64, queue: usize, ask: ContainerRequest, gang: u64 },
    Schedule,
    /// Release the k-th oldest live container (mod live count).
    Release { k: usize },
    /// Remove the node holding the k-th live container (mod live count),
    /// releasing everything that ran on it (RM kill_node semantics).
    KillNode { k: usize },
    /// Plan a preemption round over the current live containers.
    Preempt { max_victims: usize },
    /// Register the k-th seen app (mod app count) as elastic with a
    /// random `[min, max]` band around its current registration count.
    RegisterElastic { k: usize, min: u32, span: u32 },
    /// Plan one elastic grow (everyone cooldown-eligible).
    ElasticGrow { max_delta: u32 },
    /// Plan one elastic shrink round over the current live containers,
    /// then apply it the way the RM/AM pair would: newest containers of
    /// each shrunk app are released.
    ElasticShrink { max_victims: usize, max_per_app: u32 },
}

fn gen_script(g: &mut Gen, n_queues: usize) -> Vec<Op> {
    let n_ops = g.range(8, 30);
    let mut gang = 1u64;
    let mut app = 1u64;
    (0..n_ops)
        .map(|_| match g.usize_up_to(12) {
            0 | 1 => {
                app += 1;
                Op::Singles {
                    app,
                    queue: g.usize_up_to(n_queues - 1),
                    asks: gen_asks(g),
                }
            }
            2 | 3 => {
                app += 1;
                gang += 1;
                let mut ask = ContainerRequest::new(
                    Resource::new(g.range(128, 4096), g.range(1, 4) as u32, 0),
                    g.range(1, 6) as u32,
                );
                if g.usize_up_to(4) == 0 {
                    ask = ask.with_label("gpu");
                }
                Op::Gang { app, queue: g.usize_up_to(n_queues - 1), ask, gang }
            }
            4 | 5 | 6 => Op::Schedule,
            7 => Op::Release { k: g.usize_up_to(31) },
            8 => Op::Preempt { max_victims: g.range(1, 8) as usize },
            9 => Op::RegisterElastic {
                k: g.usize_up_to(31),
                min: g.range(1, 3) as u32,
                span: g.range(0, 6) as u32,
            },
            10 => Op::ElasticGrow { max_delta: g.range(1, 5) as u32 },
            11 => Op::ElasticShrink {
                max_victims: g.range(1, 8) as usize,
                max_per_app: g.range(1, 5) as u32,
            },
            _ => Op::KillNode { k: g.usize_up_to(31) },
        })
        .collect()
}

/// Replay `script` on one scheduler; returns a trace of every observable
/// outcome (grants, victims) for cross-mode comparison.  `strict` runs
/// `verify_invariants` after every mutation.
fn replay(
    script: &[Op],
    queues: &[QueueConf],
    nodes: &[SchedNode],
    total: Resource,
    linear: bool,
    strict: bool,
) -> Vec<String> {
    let qnames: Vec<String> = queues.iter().map(|q| q.name.clone()).collect();
    let mut sched = CapacityScheduler::new(queues.to_vec(), total);
    sched.set_linear_reference(linear);
    sched.set_nodes(nodes.to_vec());
    let mut live: Vec<(ContainerId, u64, usize, NodeId, Resource, Option<u64>)> = Vec::new();
    let mut trace = Vec::new();
    let mut tag = 0u64;
    let mut cseq = 1u64;
    let verify = |s: &CapacityScheduler| {
        if strict {
            s.verify_invariants();
        }
    };
    for op in script {
        match op {
            Op::Singles { app, queue, asks } => {
                let a = ApplicationId { cluster_ts: 1, seq: *app };
                tag = sched.add_asks(a, &qnames[*queue], asks, tag);
            }
            Op::Gang { app, queue, ask, gang } => {
                let a = ApplicationId { cluster_ts: 1, seq: *app };
                tag = sched
                    .add_asks_gang(a, &qnames[*queue], std::slice::from_ref(ask), tag, Some(*gang))
                    .next_tag;
            }
            Op::Schedule => {
                for gr in sched.schedule() {
                    trace.push(format!("grant {} -> {}", gr.ask.tag, gr.node.0));
                    let qi = qnames.iter().position(|q| **q == *gr.ask.queue).unwrap();
                    live.push((
                        ContainerId { app: gr.ask.app, seq: cseq },
                        gr.ask.app.seq,
                        qi,
                        gr.node,
                        gr.ask.resource,
                        gr.ask.gang,
                    ));
                    cseq += 1;
                }
            }
            Op::Release { k } => {
                if !live.is_empty() {
                    let (_, _, qi, node, r, _) = live.remove(k % live.len());
                    sched.release_container(&qnames[qi], node, r);
                    trace.push(format!("release {} {}", node.0, r.memory_mb));
                }
            }
            Op::KillNode { k } => {
                if !live.is_empty() {
                    let node = live[k % live.len()].3;
                    sched.remove_node(node);
                    // Containers on the dead node die; their queue charge
                    // comes back, the node-side credit is a no-op.
                    let dead: Vec<_> = live.iter().filter(|c| c.3 == node).cloned().collect();
                    live.retain(|c| c.3 != node);
                    for (_, _, qi, n, r, _) in dead {
                        sched.release_container(&qnames[qi], n, r);
                    }
                    trace.push(format!("killnode {}", node.0));
                }
            }
            Op::Preempt { max_victims } => {
                let candidates: Vec<VictimCandidate> = live
                    .iter()
                    .enumerate()
                    .map(|(i, (cid, app, qi, node, r, gang))| VictimCandidate {
                        container: *cid,
                        app: ApplicationId { cluster_ts: 1, seq: *app },
                        queue: std::sync::Arc::from(qnames[*qi].as_str()),
                        node: *node,
                        resource: *r,
                        gang: *gang,
                        seq: i as u64 + 1,
                    })
                    .collect();
                let victims = sched.preemption_plan(&candidates, *max_victims);
                for v in &victims {
                    trace.push(format!("victim {} {}", v.container.seq, v.node.0));
                    // The RM kills the victim; its capacity returns.
                    let pos = live.iter().position(|c| c.0 == v.container).unwrap();
                    let (_, _, qi, node, r, _) = live.remove(pos);
                    sched.release_container(&qnames[qi], node, r);
                }
            }
            Op::RegisterElastic { k, min, span } => {
                if !live.is_empty() {
                    let (_, app, qi, _, r, _) = live[k % live.len()].clone();
                    let current = live.iter().filter(|c| c.1 == app).count() as u32;
                    let mn = (*min).min(current).max(1);
                    let mx = (current + span).max(mn);
                    let a = ApplicationId { cluster_ts: 1, seq: app };
                    sched.register_elastic(a, &qnames[qi], r, None, mn, mx, current);
                    trace.push(format!("elastic {app} {mn}..{mx} @{current}"));
                }
            }
            Op::ElasticGrow { max_delta } => {
                if let Some((app, target)) = sched.elastic_grow_plan(*max_delta, &|_| true) {
                    let p = sched.elastic_profile(app).expect("grow target for unregistered app");
                    assert!(
                        target > p.current && target <= p.max,
                        "grow target {target} outside ({}, {}] for app {}",
                        p.current,
                        p.max,
                        app.seq
                    );
                    // The AM would launch the delta wave; the replay only
                    // acknowledges the new target (worker containers land
                    // through ordinary asks, which later ops may add).
                    sched.set_elastic_current(app, target);
                    trace.push(format!("grow {} -> {target}", app.seq));
                }
            }
            Op::ElasticShrink { max_victims, max_per_app } => {
                let candidates: Vec<VictimCandidate> = live
                    .iter()
                    .enumerate()
                    .map(|(i, (cid, app, qi, node, r, gang))| VictimCandidate {
                        container: *cid,
                        app: ApplicationId { cluster_ts: 1, seq: *app },
                        queue: std::sync::Arc::from(qnames[*qi].as_str()),
                        node: *node,
                        resource: *r,
                        gang: *gang,
                        seq: i as u64 + 1,
                    })
                    .collect();
                for (app, target) in sched.elastic_shrink_plan(&candidates, *max_victims, *max_per_app)
                {
                    let p = sched.elastic_profile(app).expect("shrink target for unregistered app");
                    assert!(
                        target >= p.min && target <= p.max,
                        "shrink target {target} outside [{}, {}] for app {}",
                        p.min,
                        p.max,
                        app.seq
                    );
                    let old = p.current;
                    sched.set_elastic_current(app, target);
                    trace.push(format!("shrink {} {old} -> {target}", app.seq));
                    // The owning AM releases its newest workers; capacity
                    // returns exactly as a cooperative release would.
                    for _ in target..old {
                        let pos = match live.iter().rposition(|c| c.1 == app.seq) {
                            Some(p) => p,
                            None => break,
                        };
                        let (_, _, qi, node, r, _) = live.remove(pos);
                        sched.release_container(&qnames[qi], node, r);
                        trace.push(format!("eshrink-release {} {}", node.0, r.memory_mb));
                    }
                }
            }
        }
        verify(&sched);
    }
    // Final drain pass so scripts ending in releases still compare
    // placement behaviour.
    for gr in sched.schedule() {
        trace.push(format!("grant {} -> {}", gr.ask.tag, gr.node.0));
    }
    verify(&sched);
    trace
}

#[test]
fn indexed_placement_equals_linear_reference() {
    check("indexed == linear", 150, |g| {
        let queues = vec![
            QueueConf::new("a", 0.4, 0.8),
            QueueConf::new("b", 0.35, 1.0),
            QueueConf::new("c", 0.25, 0.6),
        ];
        let nodes = gen_nodes(g);
        let total = nodes.iter().fold(Resource::ZERO, |a, n| a + n.free);
        let script = gen_script(g, queues.len());
        let indexed = replay(&script, &queues, &nodes, total, false, false);
        let linear = replay(&script, &queues, &nodes, total, true, false);
        prop_assert!(
            indexed == linear,
            "indexed and linear traces diverge:\n  indexed: {indexed:?}\n  linear:  {linear:?}\n  script: {script:?}"
        );
        Ok(())
    });
}

#[test]
fn index_invariants_hold_after_every_mutation() {
    check("index invariants per step", 100, |g| {
        let queues = vec![QueueConf::new("a", 0.6, 1.0), QueueConf::new("b", 0.4, 0.9)];
        let nodes = gen_nodes(g);
        let total = nodes.iter().fold(Resource::ZERO, |a, n| a + n.free);
        let script = gen_script(g, queues.len());
        // `replay` panics (via verify_invariants) on the first skyline /
        // cached-share / counter inconsistency after any step.
        replay(&script, &queues, &nodes, total, false, true);
        replay(&script, &queues, &nodes, total, true, true);
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// PR 10 elasticity: whenever a kill-based preemption round would fire
// against an elastic job with release budget for every victim, the
// cooperative shrink planner must find a plan too — the RM runs shrink
// first, so those kills never happen.
// ---------------------------------------------------------------------------

#[test]
fn shrink_plan_exists_whenever_preemption_would_kill_an_elastic_job() {
    check("shrink preferred over preemption", 150, |g| {
        // Slot-uniform cluster so the victim arithmetic is exact: one
        // node of `n_slots` identical slots, fully occupied by queue b's
        // elastic app; queue a's blocked gang needs `k` of them, with
        // `k` inside both b's elastic release budget and a's guarantee.
        let slot = Resource::new(1024, 1, 0);
        let n_slots = g.range(3, 8) as u32;
        let mn = g.range(1, n_slots as u64 - 1) as u32;
        let k = g.range(1, (n_slots - mn) as u64) as u32;
        // a's guarantee must cover the gang (k/n slots) and b's must
        // survive losing k slots — both reduce to cap_a >= k/n.
        let cap_a = ((k as f64 / n_slots as f64) + g.f64() * 0.3).min(0.95);
        let queues = vec![
            QueueConf::new("a", cap_a, 1.0),
            QueueConf::new("b", 1.0 - cap_a, 1.0),
        ];
        let cap = Resource::new(1024 * n_slots as u64, n_slots, 0);
        let max_victims = g.range(k as u64, 8) as usize;
        let max_per_app = g.range(k as u64, 8) as u32;
        let app_a = ApplicationId { cluster_ts: 1, seq: 1 };
        let app_b = ApplicationId { cluster_ts: 1, seq: 2 };
        // Two identically-built schedulers (placement is deterministic —
        // tested above): one answers "would preemption kill?", the other
        // "does a cooperative shrink plan exist?".  Both planners mutate
        // reservations on success, so they cannot share an instance.
        let build = || {
            let mut sched = CapacityScheduler::new(queues.clone(), cap);
            sched.set_nodes(vec![SchedNode::new(0, None, cap)]);
            sched.add_asks(app_b, "b", &[ContainerRequest::new(slot, n_slots)], 0);
            let grants = sched.schedule();
            assert_eq!(grants.len(), n_slots as usize, "b fills the node exactly");
            let candidates: Vec<VictimCandidate> = grants
                .iter()
                .enumerate()
                .map(|(i, gr)| VictimCandidate {
                    container: ContainerId { app: app_b, seq: i as u64 + 1 },
                    app: app_b,
                    queue: std::sync::Arc::from("b"),
                    node: gr.node,
                    resource: gr.ask.resource,
                    gang: None,
                    seq: i as u64 + 1,
                })
                .collect();
            sched.register_elastic(app_b, "b", slot, None, mn, n_slots, n_slots);
            sched.add_asks_gang(app_a, "a", &[ContainerRequest::new(slot, k)], 1000, Some(1));
            (sched, candidates)
        };

        let (mut s_kill, cands) = build();
        let victims = s_kill.preemption_plan(&cands, max_victims);
        prop_assert_eq!(victims.len(), k as usize, "preemption frees exactly the gang's hole");

        let (mut s_coop, cands2) = build();
        let plan = s_coop.elastic_shrink_plan(&cands2, max_victims, max_per_app);
        prop_assert!(
            !plan.is_empty(),
            "preemption would kill {} container(s) of an elastic job with budget {} — \
             shrink must offer a plan first",
            victims.len(),
            (n_slots - mn).min(max_per_app)
        );
        prop_assert_eq!(&plan, &vec![(app_b, n_slots - k)]);
        for (app, target) in &plan {
            let p = s_coop.elastic_profile(*app).unwrap();
            prop_assert!(
                *target >= p.min && *target <= p.max,
                "shrink target {} outside [{}, {}]",
                target,
                p.min,
                p.max
            );
        }
        s_kill.verify_invariants();
        s_coop.verify_invariants();
        Ok(())
    });
}
