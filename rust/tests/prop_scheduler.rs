//! Property tests: CapacityScheduler invariants under random workloads
//! (DESIGN.md §8 testing tiers) — the coordinator-correctness core of the repro.

use std::collections::BTreeMap;

use tony::proptest::{check, Gen};
use tony::util::ids::{ApplicationId, NodeId};
use tony::yarn::scheduler::SchedNode;
use tony::yarn::{CapacityScheduler, ContainerRequest, QueueConf, Resource};
use tony::{prop_assert, prop_assert_eq};

fn gen_nodes(g: &mut Gen) -> Vec<SchedNode> {
    let n = g.range(1, 20) as u32;
    (0..n)
        .map(|i| SchedNode {
            id: NodeId(i),
            label: match g.usize_up_to(3) {
                0 => Some("gpu".to_string()),
                1 => Some("high-memory".to_string()),
                _ => None,
            },
            free: Resource::new(g.range(1024, 32768), g.range(1, 32) as u32, g.range(0, 4) as u32),
        })
        .collect()
}

fn gen_asks(g: &mut Gen) -> Vec<ContainerRequest> {
    let n = g.range(1, 12);
    (0..n)
        .map(|_| {
            let mut req = ContainerRequest::new(
                Resource::new(g.range(128, 8192), g.range(1, 8) as u32, g.range(0, 2) as u32),
                g.range(1, 6) as u32,
            )
            .with_priority(g.range(1, 5) as u8);
            match g.usize_up_to(3) {
                0 => req = req.with_label("gpu"),
                1 => req = req.with_label("high-memory"),
                _ => {}
            }
            req
        })
        .collect()
}

#[test]
fn never_oversubscribes_any_dimension() {
    check("no oversubscription", 200, |g| {
        let mut nodes = gen_nodes(g);
        let orig: BTreeMap<u32, Resource> = nodes.iter().map(|n| (n.id.0, n.free)).collect();
        let total = nodes.iter().fold(Resource::ZERO, |a, n| a + n.free);
        let mut sched = CapacityScheduler::new(QueueConf::default_only(), total);
        let app = ApplicationId { cluster_ts: 1, seq: 1 };
        sched.add_asks(app, "default", &gen_asks(g), 0);
        let grants = sched.schedule(&mut nodes);

        // Per-node conservation: free + granted == original, no negatives.
        let mut granted_per_node: BTreeMap<u32, Resource> = BTreeMap::new();
        for gr in &grants {
            *granted_per_node.entry(gr.node.0).or_insert(Resource::ZERO) += gr.ask.resource;
        }
        for n in &nodes {
            let used = granted_per_node.get(&n.id.0).copied().unwrap_or(Resource::ZERO);
            let orig_free = orig[&n.id.0];
            prop_assert_eq!(n.free + used, orig_free);
            prop_assert!(
                orig_free.fits(&used),
                "node {} oversubscribed: {used} > {orig_free}",
                n.id.0
            );
        }
        Ok(())
    });
}

#[test]
fn labels_always_respected() {
    check("label partitions", 200, |g| {
        let mut nodes = gen_nodes(g);
        let labels: BTreeMap<u32, Option<String>> =
            nodes.iter().map(|n| (n.id.0, n.label.clone())).collect();
        let total = nodes.iter().fold(Resource::ZERO, |a, n| a + n.free);
        let mut sched = CapacityScheduler::new(QueueConf::default_only(), total);
        let app = ApplicationId { cluster_ts: 1, seq: 1 };
        sched.add_asks(app, "default", &gen_asks(g), 0);
        for gr in sched.schedule(&mut nodes) {
            prop_assert_eq!(&labels[&gr.node.0], &gr.ask.node_label);
        }
        Ok(())
    });
}

#[test]
fn queue_max_capacity_is_never_exceeded() {
    check("queue ceilings", 200, |g| {
        let cap_a = 0.1 + g.f64() * 0.8;
        let max_a = (cap_a + g.f64() * (1.0 - cap_a)).min(1.0);
        let queues = vec![
            QueueConf::new("a", cap_a, max_a),
            QueueConf::new("b", 1.0 - cap_a, 1.0),
        ];
        let mut nodes = gen_nodes(g);
        let total = nodes.iter().fold(Resource::ZERO, |a, n| a + n.free);
        let mut sched = CapacityScheduler::new(queues, total);
        let app1 = ApplicationId { cluster_ts: 1, seq: 1 };
        let app2 = ApplicationId { cluster_ts: 1, seq: 2 };
        let t = sched.add_asks(app1, "a", &gen_asks(g), 0);
        sched.add_asks(app2, "b", &gen_asks(g), t);
        sched.schedule(&mut nodes);
        let used_a = sched.queue_used("a").unwrap();
        prop_assert!(
            used_a.dominant_share(&total) <= max_a + 1e-6,
            "queue a used {used_a} > {max_a} of {total}"
        );
        Ok(())
    });
}

#[test]
fn scheduling_is_deterministic() {
    check("determinism", 100, |g| {
        let nodes = gen_nodes(g);
        let asks = gen_asks(g);
        let total = nodes.iter().fold(Resource::ZERO, |a, n| a + n.free);
        let app = ApplicationId { cluster_ts: 1, seq: 1 };
        let run = || {
            let mut sched = CapacityScheduler::new(QueueConf::default_only(), total);
            sched.add_asks(app, "default", &asks, 0);
            let mut view = nodes.clone();
            sched.schedule(&mut view)
        };
        prop_assert_eq!(run(), run());
        Ok(())
    });
}

#[test]
fn release_enables_pending_work() {
    check("release unblocks", 100, |g| {
        // One node exactly big enough for one container at a time.
        let shape = Resource::new(1024 + g.range(0, 1024), 1, 0);
        let mut nodes = vec![SchedNode { id: NodeId(0), label: None, free: shape }];
        let mut sched = CapacityScheduler::new(QueueConf::default_only(), shape);
        let app = ApplicationId { cluster_ts: 1, seq: 1 };
        let count = g.range(2, 6) as u32;
        sched.add_asks(app, "default", &[ContainerRequest::new(shape, count)], 0);
        let mut granted = 0;
        for _ in 0..count {
            let grants = sched.schedule(&mut nodes);
            prop_assert_eq!(grants.len(), 1);
            granted += 1;
            // Simulate completion: return capacity.
            sched.release("default", shape);
            nodes[0].free += shape;
        }
        prop_assert_eq!(granted, count);
        prop_assert_eq!(sched.pending_count(), 0);
        Ok(())
    });
}

#[test]
fn grants_never_exceed_asks() {
    check("grant conservation", 150, |g| {
        let mut nodes = gen_nodes(g);
        let asks = gen_asks(g);
        let asked: u32 = asks.iter().map(|a| a.count).sum();
        let total = nodes.iter().fold(Resource::ZERO, |a, n| a + n.free);
        let mut sched = CapacityScheduler::new(QueueConf::default_only(), total);
        let app = ApplicationId { cluster_ts: 1, seq: 1 };
        sched.add_asks(app, "default", &asks, 0);
        let grants = sched.schedule(&mut nodes);
        prop_assert!(grants.len() as u32 <= asked);
        prop_assert_eq!(grants.len() + sched.pending_count(), asked as usize);
        // Second pass with no new capacity grants nothing.
        let again = sched.schedule(&mut nodes);
        prop_assert_eq!(again.len(), 0);
        Ok(())
    });
}
