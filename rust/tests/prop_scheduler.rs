//! Property tests: CapacityScheduler invariants under random workloads
//! (DESIGN.md §8 testing tiers) — the coordinator-correctness core of the repro.

use std::collections::BTreeMap;

use tony::proptest::{check, Gen};
use tony::util::ids::{ApplicationId, ContainerId};
use tony::yarn::scheduler::SchedNode;
use tony::yarn::{CapacityScheduler, ContainerRequest, QueueConf, Resource, VictimCandidate};
use tony::{prop_assert, prop_assert_eq};

fn gen_nodes(g: &mut Gen) -> Vec<SchedNode> {
    let n = g.range(1, 20) as u32;
    (0..n)
        .map(|i| {
            let label = match g.usize_up_to(3) {
                0 => Some("gpu".to_string()),
                1 => Some("high-memory".to_string()),
                _ => None,
            };
            let cap =
                Resource::new(g.range(1024, 32768), g.range(1, 32) as u32, g.range(0, 4) as u32);
            SchedNode::new(i, label, cap)
        })
        .collect()
}

fn gen_asks(g: &mut Gen) -> Vec<ContainerRequest> {
    let n = g.range(1, 12);
    (0..n)
        .map(|_| {
            let mut req = ContainerRequest::new(
                Resource::new(g.range(128, 8192), g.range(1, 8) as u32, g.range(0, 2) as u32),
                g.range(1, 6) as u32,
            )
            .with_priority(g.range(1, 5) as u8);
            match g.usize_up_to(3) {
                0 => req = req.with_label("gpu"),
                1 => req = req.with_label("high-memory"),
                _ => {}
            }
            req
        })
        .collect()
}

#[test]
fn never_oversubscribes_any_dimension() {
    check("no oversubscription", 200, |g| {
        let mut nodes = gen_nodes(g);
        let orig: BTreeMap<u32, Resource> = nodes.iter().map(|n| (n.id.0, n.free)).collect();
        let total = nodes.iter().fold(Resource::ZERO, |a, n| a + n.free);
        let mut sched = CapacityScheduler::new(QueueConf::default_only(), total);
        let app = ApplicationId { cluster_ts: 1, seq: 1 };
        sched.add_asks(app, "default", &gen_asks(g), 0);
        let grants = sched.schedule(&mut nodes);

        // Per-node conservation: free + granted == original, no negatives.
        let mut granted_per_node: BTreeMap<u32, Resource> = BTreeMap::new();
        for gr in &grants {
            *granted_per_node.entry(gr.node.0).or_insert(Resource::ZERO) += gr.ask.resource;
        }
        for n in &nodes {
            let used = granted_per_node.get(&n.id.0).copied().unwrap_or(Resource::ZERO);
            let orig_free = orig[&n.id.0];
            prop_assert_eq!(n.free + used, orig_free);
            prop_assert!(
                orig_free.fits(&used),
                "node {} oversubscribed: {used} > {orig_free}",
                n.id.0
            );
        }
        Ok(())
    });
}

#[test]
fn labels_always_respected() {
    check("label partitions", 200, |g| {
        let mut nodes = gen_nodes(g);
        let labels: BTreeMap<u32, Option<String>> =
            nodes.iter().map(|n| (n.id.0, n.label.clone())).collect();
        let total = nodes.iter().fold(Resource::ZERO, |a, n| a + n.free);
        let mut sched = CapacityScheduler::new(QueueConf::default_only(), total);
        let app = ApplicationId { cluster_ts: 1, seq: 1 };
        sched.add_asks(app, "default", &gen_asks(g), 0);
        for gr in sched.schedule(&mut nodes) {
            prop_assert_eq!(&labels[&gr.node.0], &gr.ask.node_label);
        }
        Ok(())
    });
}

#[test]
fn queue_max_capacity_is_never_exceeded() {
    check("queue ceilings", 200, |g| {
        let cap_a = 0.1 + g.f64() * 0.8;
        let max_a = (cap_a + g.f64() * (1.0 - cap_a)).min(1.0);
        let queues = vec![
            QueueConf::new("a", cap_a, max_a),
            QueueConf::new("b", 1.0 - cap_a, 1.0),
        ];
        let mut nodes = gen_nodes(g);
        let total = nodes.iter().fold(Resource::ZERO, |a, n| a + n.free);
        let mut sched = CapacityScheduler::new(queues, total);
        let app1 = ApplicationId { cluster_ts: 1, seq: 1 };
        let app2 = ApplicationId { cluster_ts: 1, seq: 2 };
        let t = sched.add_asks(app1, "a", &gen_asks(g), 0);
        sched.add_asks(app2, "b", &gen_asks(g), t);
        sched.schedule(&mut nodes);
        let used_a = sched.queue_used("a").unwrap();
        prop_assert!(
            used_a.dominant_share(&total) <= max_a + 1e-6,
            "queue a used {used_a} > {max_a} of {total}"
        );
        Ok(())
    });
}

#[test]
fn scheduling_is_deterministic() {
    check("determinism", 100, |g| {
        let nodes = gen_nodes(g);
        let asks = gen_asks(g);
        let total = nodes.iter().fold(Resource::ZERO, |a, n| a + n.free);
        let app = ApplicationId { cluster_ts: 1, seq: 1 };
        let run = || {
            let mut sched = CapacityScheduler::new(QueueConf::default_only(), total);
            sched.add_asks(app, "default", &asks, 0);
            let mut view = nodes.clone();
            sched.schedule(&mut view)
        };
        prop_assert_eq!(run(), run());
        Ok(())
    });
}

#[test]
fn release_enables_pending_work() {
    check("release unblocks", 100, |g| {
        // One node exactly big enough for one container at a time.
        let shape = Resource::new(1024 + g.range(0, 1024), 1, 0);
        let mut nodes = vec![SchedNode::new(0, None, shape)];
        let mut sched = CapacityScheduler::new(QueueConf::default_only(), shape);
        let app = ApplicationId { cluster_ts: 1, seq: 1 };
        let count = g.range(2, 6) as u32;
        sched.add_asks(app, "default", &[ContainerRequest::new(shape, count)], 0);
        let mut granted = 0;
        for _ in 0..count {
            let grants = sched.schedule(&mut nodes);
            prop_assert_eq!(grants.len(), 1);
            granted += 1;
            // Simulate completion: return capacity.
            sched.release("default", shape);
            nodes[0].free += shape;
        }
        prop_assert_eq!(granted, count);
        prop_assert_eq!(sched.pending_count(), 0);
        Ok(())
    });
}

/// Random mix of gangs (one per app) and loose singles.  Returns the
/// scheduler with everything enqueued plus the size of each gang.
fn gen_gang_mix(
    g: &mut Gen,
    queues: Vec<QueueConf>,
    total: Resource,
) -> (CapacityScheduler, BTreeMap<u64, u32>) {
    let qnames: Vec<String> = queues.iter().map(|q| q.name.clone()).collect();
    let mut sched = CapacityScheduler::new(queues, total);
    let n_gangs = g.range(1, 6);
    let mut sizes = BTreeMap::new();
    let mut tag = 0;
    for k in 0..n_gangs {
        let app = ApplicationId { cluster_ts: 1, seq: k + 1 };
        let count = g.range(1, 6) as u32;
        let mut req = ContainerRequest::new(
            Resource::new(g.range(128, 8192), g.range(1, 8) as u32, g.range(0, 2) as u32),
            count,
        )
        .with_priority(g.range(1, 5) as u8);
        if g.usize_up_to(4) == 0 {
            req = req.with_label("gpu");
        }
        let q = &qnames[g.usize_up_to(qnames.len() - 1)];
        tag = sched.add_asks_gang(app, q, &[req], tag, Some(k + 1)).next_tag;
        sizes.insert(k + 1, count);
    }
    // Loose singles riding along.
    let app = ApplicationId { cluster_ts: 1, seq: 99 };
    let q = &qnames[g.usize_up_to(qnames.len() - 1)];
    sched.add_asks(app, q, &gen_asks(g), tag);
    (sched, sizes)
}

#[test]
fn gangs_are_granted_fully_or_not_at_all() {
    check("gang atomicity", 200, |g| {
        let mut nodes = gen_nodes(g);
        let total = nodes.iter().fold(Resource::ZERO, |a, n| a + n.free);
        let (mut sched, sizes) = gen_gang_mix(g, QueueConf::default_only(), total);
        let grants = sched.schedule(&mut nodes);
        let mut granted: BTreeMap<u64, u32> = BTreeMap::new();
        for gr in &grants {
            if let Some(id) = gr.ask.gang {
                *granted.entry(id).or_insert(0) += 1;
            }
        }
        for (id, n) in granted {
            prop_assert!(
                n == sizes[&id],
                "gang {id} partially granted: {n}/{} containers",
                sizes[&id]
            );
        }
        Ok(())
    });
}

#[test]
fn no_oversubscription_under_gang_mixes() {
    check("gang no-oversubscription", 200, |g| {
        let mut nodes = gen_nodes(g);
        let orig: BTreeMap<u32, Resource> = nodes.iter().map(|n| (n.id.0, n.free)).collect();
        let total = nodes.iter().fold(Resource::ZERO, |a, n| a + n.free);
        let queues = vec![QueueConf::new("a", 0.5, 0.8), QueueConf::new("b", 0.5, 1.0)];
        let (mut sched, _) = gen_gang_mix(g, queues, total);
        let grants = sched.schedule(&mut nodes);
        let mut granted_per_node: BTreeMap<u32, Resource> = BTreeMap::new();
        for gr in &grants {
            *granted_per_node.entry(gr.node.0).or_insert(Resource::ZERO) += gr.ask.resource;
        }
        for n in &nodes {
            let used = granted_per_node.get(&n.id.0).copied().unwrap_or(Resource::ZERO);
            let orig_free = orig[&n.id.0];
            prop_assert_eq!(n.free + used, orig_free);
            prop_assert!(
                orig_free.fits(&used),
                "node {} oversubscribed: {used} > {orig_free}",
                n.id.0
            );
        }
        // Queue ceilings hold too.
        for q in sched.queue_snapshots() {
            prop_assert!(
                q.used.dominant_share(&total) <= q.max_capacity + 1e-6,
                "queue {} burst past its ceiling",
                q.name
            );
        }
        Ok(())
    });
}

#[test]
fn preemption_never_drives_a_queue_below_its_guarantee() {
    check("preemption guarantee floor", 150, |g| {
        let cap_a = 0.2 + g.f64() * 0.6;
        let queues = vec![
            QueueConf::new("a", cap_a, 1.0),
            QueueConf::new("b", 1.0 - cap_a, 1.0),
        ];
        let mut nodes = gen_nodes(g);
        let total = nodes.iter().fold(Resource::ZERO, |a, n| a + n.free);
        let mut sched = CapacityScheduler::new(queues, total);
        // Queue b grabs as much as it can (possibly over its guarantee).
        let app_b = ApplicationId { cluster_ts: 1, seq: 2 };
        sched.add_asks(app_b, "b", &gen_asks(g), 0);
        let b_grants = sched.schedule(&mut nodes);
        let candidates: Vec<VictimCandidate> = b_grants
            .iter()
            .enumerate()
            .map(|(i, gr)| VictimCandidate {
                container: ContainerId { app: gr.ask.app, seq: i as u64 + 1 },
                app: gr.ask.app,
                queue: gr.ask.queue.clone(),
                node: gr.node,
                resource: gr.ask.resource,
                gang: gr.ask.gang,
                seq: i as u64 + 1,
            })
            .collect();
        // Queue a (starved) asks a random gang.
        let app_a = ApplicationId { cluster_ts: 1, seq: 1 };
        let req = ContainerRequest::new(
            Resource::new(g.range(128, 4096), g.range(1, 4) as u32, 0),
            g.range(1, 5) as u32,
        );
        sched.add_asks_gang(app_a, "a", &[req], 1000, Some(1));
        let used_b_before = sched.queue_used("b").unwrap();
        let victims = sched.preemption_plan(&nodes, &candidates, g.range(1, 8) as usize);
        let freed = victims.iter().fold(Resource::ZERO, |a, v| a + v.resource);
        let after = used_b_before - freed;
        if !victims.is_empty() {
            prop_assert!(
                after.dominant_share(&total) >= (1.0 - cap_a) - 1e-6,
                "queue b driven below its guarantee: {after} of {total}"
            );
        }
        Ok(())
    });
}

#[test]
fn reservations_eventually_drain() {
    check("reservation drain", 100, |g| {
        // One node fully occupied by out-of-band work the scheduler does
        // not charge to any queue (so the blocked gang is *node*-blocked,
        // not ceiling-blocked — ceiling-blocked gangs wait unreserved).
        // As occupants finish, the reservation must convert into a full
        // gang grant despite a stream of poacher singles — no livelock.
        let slot = Resource::new(1024, 1, 0);
        let n_slots = g.range(2, 6) as u32;
        let cap = Resource::new(1024 * n_slots as u64, n_slots, 0);
        let mut nodes = vec![SchedNode::new(0, None, cap)];
        nodes[0].free = Resource::ZERO;
        let mut sched = CapacityScheduler::new(QueueConf::default_only(), cap);
        let gang_app = ApplicationId { cluster_ts: 1, seq: 1 };
        sched.add_asks_gang(
            gang_app,
            "default",
            &[ContainerRequest::new(slot, n_slots)],
            100,
            Some(1),
        );
        prop_assert!(sched.schedule(&mut nodes).is_empty());
        prop_assert_eq!(sched.reservation_count(), 1);
        // Occupants finish one per round; more singles keep arriving but
        // must not steal the reserved node.
        let mut gang_granted = false;
        let mut extra_tag = 1000;
        for round in 0..(n_slots + 2) {
            nodes[0].free += slot;
            extra_tag = sched.add_asks(
                ApplicationId { cluster_ts: 1, seq: 50 },
                "default",
                &[ContainerRequest::new(slot, 1)],
                extra_tag,
            );
            let grants = sched.schedule(&mut nodes);
            if grants.iter().any(|gr| gr.ask.gang == Some(1)) {
                let whole = grants.iter().filter(|gr| gr.ask.gang == Some(1)).count();
                prop_assert!(
                    whole == n_slots as usize,
                    "gang granted but not whole in round {round}: {whole}/{n_slots}"
                );
                gang_granted = true;
                break;
            }
            // Until the gang lands, nobody may poach the reserved node.
            prop_assert!(
                grants.is_empty(),
                "single ask poached a reserved node in round {round}: {grants:?}"
            );
        }
        prop_assert!(gang_granted, "reservation never drained into a grant (livelock)");
        Ok(())
    });
}

#[test]
fn grants_never_exceed_asks() {
    check("grant conservation", 150, |g| {
        let mut nodes = gen_nodes(g);
        let asks = gen_asks(g);
        let asked: u32 = asks.iter().map(|a| a.count).sum();
        let total = nodes.iter().fold(Resource::ZERO, |a, n| a + n.free);
        let mut sched = CapacityScheduler::new(QueueConf::default_only(), total);
        let app = ApplicationId { cluster_ts: 1, seq: 1 };
        sched.add_asks(app, "default", &asks, 0);
        let grants = sched.schedule(&mut nodes);
        prop_assert!(grants.len() as u32 <= asked);
        prop_assert_eq!(grants.len() + sched.pending_count(), asked as usize);
        // Second pass with no new capacity grants nothing.
        let again = sched.schedule(&mut nodes);
        prop_assert_eq!(again.len(), 0);
        Ok(())
    });
}
