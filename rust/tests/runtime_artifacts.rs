//! Integration: the Rust PJRT engine executes the real AOT artifacts.
//!
//! Requires `make artifacts` (the tiny preset).  Tests skip gracefully if
//! artifacts are absent so `cargo test` stays runnable standalone, but the
//! Makefile's `test` target always builds artifacts first.

use tony::runtime::{Engine, Tensor};

fn tiny_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    if dir.join("meta.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/tiny missing; run `make artifacts`");
        None
    }
}

#[test]
fn init_params_is_deterministic_and_finite() {
    let Some(dir) = tiny_dir() else { return };
    let engine = Engine::start(&dir, Some(&["init_params"])).unwrap();
    let h = engine.handle();
    let n = h.meta().n_params;

    let out1 = h.execute("init_params", vec![Tensor::scalar_u32(42)]).unwrap();
    let out2 = h.execute("init_params", vec![Tensor::scalar_u32(42)]).unwrap();
    let p1 = out1[0].as_f32().unwrap();
    let p2 = out2[0].as_f32().unwrap();
    assert_eq!(p1.len(), n);
    assert_eq!(p1, p2, "same seed must give identical params");
    assert!(p1.iter().all(|v| v.is_finite()));
    // Different seed -> different params.
    let out3 = h.execute("init_params", vec![Tensor::scalar_u32(7)]).unwrap();
    assert_ne!(out3[0].as_f32().unwrap(), p1);
}

#[test]
fn worker_step_produces_loss_and_grads() {
    let Some(dir) = tiny_dir() else { return };
    let engine = Engine::start(&dir, Some(&["init_params", "worker_step", "eval_loss"])).unwrap();
    let h = engine.handle();
    let meta = h.meta();
    let (b, s, v) = (meta.dims.batch, meta.dims.seq_len, meta.dims.vocab);

    let params = h.execute("init_params", vec![Tensor::scalar_u32(0)]).unwrap().remove(0);
    let tokens: Vec<i32> = (0..b * (s + 1)).map(|i| (i * 7 % v) as i32).collect();
    let batch = Tensor::i32(&[b, s + 1], tokens);

    let out = h.execute("worker_step", vec![params.clone(), batch.clone()]).unwrap();
    assert_eq!(out.len(), 2);
    let loss = out[0].scalar().unwrap();
    let grads = out[1].as_f32().unwrap();
    assert!(loss.is_finite() && loss > 0.0, "loss={loss}");
    // Random init on uniform-ish tokens: loss should be near ln(vocab).
    let ln_v = (v as f32).ln();
    assert!((loss - ln_v).abs() < 2.0, "loss={loss} ln_v={ln_v}");
    assert_eq!(grads.len(), meta.n_params);
    assert!(grads.iter().all(|g| g.is_finite()));
    let grad_norm: f32 = grads.iter().map(|g| g * g).sum::<f32>().sqrt();
    assert!(grad_norm > 1e-6, "gradients must be nonzero");

    // eval_loss agrees with worker_step's loss on the same inputs.
    let ev = h.execute("eval_loss", vec![params, batch]).unwrap();
    let eloss = ev[0].scalar().unwrap();
    assert!((eloss - loss).abs() < 1e-4, "{eloss} vs {loss}");
}

#[test]
fn ps_adam_matches_scalar_reference() {
    let Some(dir) = tiny_dir() else { return };
    let engine = Engine::start(&dir, Some(&["ps_adam"])).unwrap();
    let h = engine.handle();
    let c = h.meta().chunk_len;
    let adam = &h.meta().adam;

    let p: Vec<f32> = (0..c).map(|i| (i as f32 * 0.001).sin()).collect();
    let g: Vec<f32> = (0..c).map(|i| (i as f32 * 0.002).cos()).collect();
    let m = vec![0.01f32; c];
    let v = vec![0.5f32; c];
    let (step, lr) = (3.0f32, 1e-3f32);

    let out = h
        .execute(
            "ps_adam",
            vec![
                Tensor::f32(&[c], p.clone()),
                Tensor::f32(&[c], g.clone()),
                Tensor::f32(&[c], m.clone()),
                Tensor::f32(&[c], v.clone()),
                Tensor::scalar_f32(step),
                Tensor::scalar_f32(lr),
            ],
        )
        .unwrap();
    let (p2, m2, v2) = (
        out[0].as_f32().unwrap(),
        out[1].as_f32().unwrap(),
        out[2].as_f32().unwrap(),
    );
    let (b1, b2, eps) = (adam.beta1 as f32, adam.beta2 as f32, adam.eps as f32);
    for i in (0..c).step_by(997) {
        let em = b1 * m[i] + (1.0 - b1) * g[i];
        let ev = b2 * v[i] + (1.0 - b2) * g[i] * g[i];
        let mhat = em / (1.0 - b1.powf(step));
        let vhat = ev / (1.0 - b2.powf(step));
        let ep = p[i] - lr * mhat / (vhat.sqrt() + eps);
        assert!((m2[i] - em).abs() < 1e-5, "m[{i}]");
        assert!((v2[i] - ev).abs() < 1e-5, "v[{i}]");
        assert!((p2[i] - ep).abs() < 1e-5, "p[{i}]: {} vs {ep}", p2[i]);
    }
}

#[test]
fn zero_grad_zero_state_is_fixed_point() {
    // The shard-padding invariant: pad lanes (p=g=m=v=0) stay exactly 0.
    let Some(dir) = tiny_dir() else { return };
    let engine = Engine::start(&dir, Some(&["ps_adam"])).unwrap();
    let h = engine.handle();
    let c = h.meta().chunk_len;
    let z = vec![0.0f32; c];
    let out = h
        .execute(
            "ps_adam",
            vec![
                Tensor::f32(&[c], z.clone()),
                Tensor::f32(&[c], z.clone()),
                Tensor::f32(&[c], z.clone()),
                Tensor::f32(&[c], z.clone()),
                Tensor::scalar_f32(1.0),
                Tensor::scalar_f32(0.1),
            ],
        )
        .unwrap();
    for t in &out {
        assert!(t.as_f32().unwrap().iter().all(|x| *x == 0.0));
    }
}

#[test]
fn engine_rejects_bad_inputs() {
    let Some(dir) = tiny_dir() else { return };
    let engine = Engine::start(&dir, Some(&["worker_step"])).unwrap();
    let h = engine.handle();
    // Wrong arity.
    assert!(h.execute("worker_step", vec![]).is_err());
    // Wrong shape.
    let bad = vec![Tensor::zeros_f32(&[3]), Tensor::i32(&[1], vec![0])];
    assert!(h.execute("worker_step", bad).is_err());
    // Unknown artifact.
    assert!(h.execute("nope", vec![]).is_err());
}
