//! Integration: the fault-tolerance loop (§2.2).  Kill a worker
//! container (and, separately, a whole node) mid-training and watch the
//! job finish anyway.
//!
//! These tests pin `tony.task.max-restarts=0` where they specifically
//! exercise the paper's *full-restart* escalation path (teardown →
//! re-negotiate → relaunch → restore-from-checkpoint).  The surgical
//! per-task recovery path is covered by `tests/am_recovery.rs`, which
//! runs on the synthetic preset in every build.

use std::sync::Arc;
use std::time::Duration;

use tony::chaos::{ChaosInjector, Fault};
use tony::client::TonyClient;
use tony::tonyconf::JobConfBuilder;
use tony::yarn::{AppState, Resource, ResourceManager};

fn tiny_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    if dir.join("meta.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/tiny missing; run `make artifacts`");
        None
    }
}

fn ckpt_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!(
        "tony-ft-{tag}-{}-{}",
        std::process::id(),
        tony::util::ids::next_seq()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn train_conf(dir: &std::path::Path, ckpt: &std::path::Path, steps: u64) -> tony::xmlconf::Configuration {
    JobConfBuilder::new("ft-job")
        .instances("worker", 2)
        .memory("worker", "1g")
        .instances("ps", 1)
        .memory("ps", "1g")
        .train(dir.to_str().unwrap(), "tiny", steps)
        .set("tony.train.checkpoint-dir", ckpt.to_str().unwrap())
        .set("tony.train.checkpoint-every", "5")
        .set("tony.application.max-attempts", "4")
        .build()
}

#[test]
fn worker_kill_full_restart_recovers_from_checkpoint() {
    let Some(dir) = tiny_dir() else { return };
    let rm = ResourceManager::start_uniform(4, Resource::new(8192, 8, 0));
    let ckpt = ckpt_dir("task-kill");
    let mut conf = train_conf(&dir, &ckpt, 16);
    // Pin the paper's all-or-nothing policy: every failure escalates.
    conf.set("tony.task.max-restarts", "0");

    let client = TonyClient::new(rm.clone());
    let handle = client.submit(&conf, &dir).unwrap();
    let chaos = ChaosInjector::start(
        rm.clone(),
        handle.am_state.clone(),
        vec![Fault::KillTask { task_type: "worker".into(), index: 1, after_step: 6 }],
    );
    let report = handle.wait(Duration::from_secs(400)).unwrap();
    let records = chaos.join();
    assert_eq!(report.state, AppState::Finished, "{}", report.diagnostics);
    assert_eq!(records.len(), 1, "fault fired");
    assert!(records[0].chief_step_at_injection >= 6);

    // The job needed more than one attempt and completed all steps.
    assert!(handle.am_state.attempt() >= 2, "expected a full relaunch");
    assert_eq!(handle.am_state.recoveries(), 0, "surgical path was disabled");
    let metrics = handle.am_state.chief_metrics().unwrap();
    assert_eq!(metrics.step, 16);

    // Restore actually happened: attempt 2's start is the last checkpoint
    // (>= 5), not step 0; verify via checkpoint store contents.
    let store = tony::checkpoint::CheckpointStore::new(&ckpt);
    assert!(store.latest().unwrap().unwrap().step == 16);
    // The relaunched attempt recorded a restore marker at a step > 0.
    let markers = store.restore_markers().unwrap();
    assert!(
        markers.iter().any(|(_, step)| *step >= 5),
        "expected a checkpoint restore, got markers {markers:?}"
    );
    let _ = std::fs::remove_dir_all(&ckpt);
}

#[test]
fn node_kill_recovers() {
    let Some(dir) = tiny_dir() else { return };
    // AM on its own high-mem node 0 so the chaos node-kill (node that
    // hosts task containers) never takes the AM down in this test.
    use tony::yarn::{NodeSpec, QueueConf};
    let specs = vec![
        NodeSpec::new(0, Resource::new(1024, 2, 0)), // fits only the AM
        NodeSpec::new(1, Resource::new(8192, 8, 0)),
        NodeSpec::new(2, Resource::new(8192, 8, 0)),
        NodeSpec::new(3, Resource::new(8192, 8, 0)),
    ];
    let rm = ResourceManager::start(specs, QueueConf::default_only());
    let ckpt = ckpt_dir("node-kill");
    let conf = train_conf(&dir, &ckpt, 12);

    let client = TonyClient::new(rm.clone());
    let handle = client.submit(&conf, &dir).unwrap();
    // Kill a *task* node (not node 0): with surgical recovery enabled
    // (the default), only the containers that lived on the dead node are
    // relaunched on the surviving nodes.
    let chaos = ChaosInjector::start(
        rm.clone(),
        handle.am_state.clone(),
        vec![Fault::KillNode { node: 1, after_step: 4 }],
    );
    let report = handle.wait(Duration::from_secs(400)).unwrap();
    let _records = chaos.join();
    assert_eq!(report.state, AppState::Finished, "{}", report.diagnostics);
    assert_eq!(rm.alive_node_count(), 3);
    let metrics = handle.am_state.chief_metrics().unwrap();
    assert_eq!(metrics.step, 12);
    let _ = std::fs::remove_dir_all(&ckpt);
}

#[test]
fn unrecoverable_job_fails_after_max_attempts() {
    let Some(dir) = tiny_dir() else { return };
    let rm = ResourceManager::start_uniform(4, Resource::new(8192, 8, 0));
    let ckpt = ckpt_dir("doom");
    let mut conf = train_conf(&dir, &ckpt, 1000);
    conf.set("tony.application.max-attempts", "2");
    conf.set("tony.train.checkpoint-every", "0"); // no checkpoints
    conf.set("tony.task.max-restarts", "0"); // every failure escalates

    let client = TonyClient::new(rm.clone());
    let handle = client.submit(&conf, &dir).unwrap();
    // Kill a worker in every attempt, early.
    let chaos = ChaosInjector::start(
        rm.clone(),
        handle.am_state.clone(),
        vec![
            Fault::KillTask { task_type: "worker".into(), index: 0, after_step: 1 },
            Fault::KillTask { task_type: "worker".into(), index: 0, after_step: 1 },
        ],
    );
    let report = handle.wait(Duration::from_secs(400)).unwrap();
    let _ = chaos.join();
    assert_eq!(report.state, AppState::Failed);
    assert!(report.diagnostics.contains("exhausted"), "{}", report.diagnostics);
    let _ = std::fs::remove_dir_all(&ckpt);
}
