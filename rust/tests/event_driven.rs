//! End-to-end proof that the control plane is event-driven, not
//! poll-driven: whole liveness paths run under a **manual clock**, where
//! a sleep-poll loop would simply hang (a manual clock's `sleep` is a
//! no-op and its time only moves when the test moves it).
//!
//! Time in these tests is driven by a *clock driver* thread that advances
//! virtual time in small increments — the only real-time waiting is the
//! driver's own pacing.  Every control-plane wait (RM scheduling, AM
//! monitor loop, registration deadlines, executor heartbeats, gateway
//! drain) blocks on `WakeupBus` events bounded by virtual deadlines.
//!
//! Each test runs under a real-time watchdog so a regression (a missed
//! notification, a poll re-introduced somewhere) fails loudly instead of
//! hanging CI.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use tony::gateway::{Gateway, GatewayConf, JobState, SubmitOutcome};
use tony::tonyconf::JobConfBuilder;
use tony::util::ManualClock;
use tony::xmlconf::Configuration;
use tony::yarn::{AppState, NodeSpec, QueueConf, Resource, ResourceManager, RmConf};

/// Drive virtual time forward until `done` flips: +5 ms virtual every
/// ~0.5 ms real.  Advancing notifies every clock-registered bus, which is
/// exactly how production timers fire — just compressed.
fn spawn_clock_driver(clock: Arc<ManualClock>, done: Arc<AtomicBool>) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        while !done.load(Ordering::Relaxed) {
            clock.advance_ms(5);
            tony::util::clock::real_sleep(Duration::from_micros(500));
        }
    })
}

/// Run `body` on its own thread with a real-time watchdog: if the event
/// chain stalls anywhere, the test fails within `secs` instead of
/// hanging the suite.
fn with_watchdog<T: Send + 'static>(secs: u64, body: impl FnOnce() -> T + Send + 'static) -> T {
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        let _ = tx.send(body());
    });
    rx.recv_timeout(Duration::from_secs(secs))
        .expect("event chain stalled: some control-plane path still poll/sleep-driven")
}

fn manual_rm(clock: &Arc<ManualClock>, nodes: u32) -> Arc<ResourceManager> {
    let specs = (0..nodes).map(|i| NodeSpec::new(i, Resource::new(4096, 8, 0))).collect();
    ResourceManager::start_with(
        specs,
        QueueConf::default_only(),
        // Fallback tick disabled: nothing may depend on polling.
        RmConf { clock: clock.clone(), fallback_tick_ms: 0, ..Default::default() },
    )
}

fn temp_base(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "tony-evtest-{tag}-{}-{}",
        std::process::id(),
        tony::util::ids::next_seq()
    ))
}

/// A full gateway job — admission, AM lifecycle, spec rendezvous,
/// training, heartbeats, teardown, history — completes under a manual
/// clock with the RM fallback tick disabled.  Every hop submit →
/// grant → launch → register → spec → train → exit → finalize must be
/// carried by a notification for this to terminate.
#[test]
fn full_gateway_job_completes_under_manual_clock() {
    let state = with_watchdog(120, || {
        let clock = ManualClock::shared();
        let rm = manual_rm(&clock, 2);
        let base = temp_base("full");
        let mut conf = GatewayConf::new(base.join("artifacts"));
        conf.history_dir = base.join("history");
        conf.workers = 2;
        conf.job_timeout = Duration::from_secs(600); // virtual ms
        let gw = Gateway::start(rm, conf).unwrap();

        let job = JobConfBuilder::new("manual-e2e")
            .instances("worker", 1)
            .memory("worker", "512m")
            .instances("ps", 1)
            .memory("ps", "512m")
            .set("tony.am.memory", "256m")
            .set("tony.train.steps", "3")
            .set("tony.train.checkpoint-every", "0")
            // Generous *virtual* liveness budget: the clock driver runs
            // time ~10x faster than real threads make progress.
            .set("tony.task.max-missed-heartbeats", "2000")
            .build();
        let SubmitOutcome::Accepted { id } = gw.submit_conf("alice", 1, job) else {
            panic!("job rejected")
        };

        let done = Arc::new(AtomicBool::new(false));
        let driver = spawn_clock_driver(clock.clone(), done.clone());
        // Virtual-deadline wait, woken per state transition.
        assert!(gw.wait_idle(Duration::from_secs(3000)), "gateway never drained");
        done.store(true, Ordering::Relaxed);
        driver.join().unwrap();

        let state = gw.job_state(id).unwrap();
        let ids = gw.history().list().unwrap();
        assert_eq!(ids.len(), 1, "history records: {ids:?}");
        for (_, free, cap) in gw.rm().node_usage() {
            assert_eq!(free, cap, "capacity leaked");
        }
        gw.shutdown();
        let _ = std::fs::remove_dir_all(&base);
        state
    });
    assert_eq!(state, JobState::Finished);
}

/// The registration-deadline liveness path under a manual clock: an
/// executor that wedges before registering is detected purely by virtual
/// time crossing `tony.task.registration-timeout-ms`, and the job fails
/// with the deadline named — zero real sleeping in any control wait.
#[test]
fn registration_deadline_fires_under_manual_clock() {
    let report = with_watchdog(120, || {
        let clock = ManualClock::shared();
        let rm = manual_rm(&clock, 2);
        let base = temp_base("wedge");
        tony::runtime::synthetic::ensure_preset(&base.join("artifacts")).unwrap();

        let conf: Configuration = JobConfBuilder::new("wedged")
            .instances("worker", 1)
            .memory("worker", "512m")
            .set("tony.am.memory", "256m")
            .set("tony.chaos.wedge-preregister", "worker:0")
            .set("tony.task.registration-timeout-ms", "1000")
            .set("tony.task.max-restarts", "0")
            .set("tony.application.max-attempts", "1")
            .build();
        let client = tony::client::TonyClient::new(rm.clone());
        let handle = client
            .submit_opts(
                &conf,
                &base.join("artifacts"),
                tony::client::SubmitOpts { start_portal: false, tracking_url: None, trace: None },
            )
            .unwrap();

        let done = Arc::new(AtomicBool::new(false));
        let driver = spawn_clock_driver(clock.clone(), done.clone());
        let report = handle.wait(Duration::from_secs(600)).unwrap();
        done.store(true, Ordering::Relaxed);
        driver.join().unwrap();
        for (_, free, cap) in rm.node_usage() {
            assert_eq!(free, cap, "capacity leaked");
        }
        let _ = std::fs::remove_dir_all(&base);
        report
    });
    assert_eq!(report.state, AppState::Failed);
    assert!(
        report.diagnostics.contains("never registered"),
        "diagnostics must name the registration deadline: {}",
        report.diagnostics
    );
}

/// Graceful shutdown racing live WAL appends: `shutdown()` drains the
/// accepted jobs (whose Started/Terminal records are being appended as
/// it runs), flushes + closes the WAL, and leaves a *clean* replayable
/// log — proven by replaying the directory and immediately recovering
/// into a working gateway.
#[test]
fn shutdown_during_wal_append_leaves_replayable_log() {
    with_watchdog(120, || {
        let clock = ManualClock::shared();
        let rm = manual_rm(&clock, 2);
        let base = temp_base("walshutdown");
        let mut conf = GatewayConf::new(base.join("artifacts"));
        conf.history_dir = base.join("history");
        conf.workers = 2;
        conf.job_timeout = Duration::from_secs(600); // virtual ms
        let mut site = Configuration::new();
        site.set("tony.wal.enable", "true");
        site.set("tony.wal.dir", base.join("wal").to_string_lossy().into_owned());
        conf.apply_site_conf(&site);
        let gw = Gateway::start(rm, conf.clone()).unwrap();

        let ids: Vec<u64> = (0..3)
            .map(|i| {
                let job = JobConfBuilder::new(&format!("drain-{i}"))
                    .instances("worker", 1)
                    .memory("worker", "512m")
                    .instances("ps", 1)
                    .memory("ps", "512m")
                    .set("tony.am.memory", "256m")
                    .set("tony.train.steps", "2")
                    .set("tony.train.checkpoint-every", "0")
                    .set("tony.task.max-missed-heartbeats", "2000")
                    .build();
                match gw.submit_conf("alice", 1, job) {
                    SubmitOutcome::Accepted { id } => id,
                    other => panic!("submit {i} rejected: {other:?}"),
                }
            })
            .collect();

        // Shut down while the jobs run: workers drain what was accepted
        // (appending Started/Terminal records as they go), then the WAL
        // is flushed and closed.
        let done = Arc::new(AtomicBool::new(false));
        let driver = spawn_clock_driver(clock.clone(), done.clone());
        gw.shutdown();
        done.store(true, Ordering::Relaxed);
        driver.join().unwrap();

        // The log on disk is complete: clean tail, every acked job's
        // terminal outcome durable.
        let rep = tony::gateway::replay_dir(&base.join("wal")).unwrap();
        assert!(rep.clean_tail, "graceful shutdown must not leave a torn tail");
        for id in &ids {
            assert_eq!(
                rep.state.completed.get(id).map(String::as_str),
                Some("FINISHED"),
                "job {id} must have a durable terminal record: {:?}",
                rep.state
            );
        }
        assert!(rep.state.jobs.is_empty(), "nothing left live: {:?}", rep.state.jobs);

        // Immediate recovery on the shut-down directory: nothing to
        // restore, ids are not reused, fresh work runs.
        let rm2 = manual_rm(&clock, 2);
        let gw2 = Gateway::recover(rm2, conf).unwrap();
        assert_eq!(gw2.live_counts(), (0, 0));
        let job = JobConfBuilder::new("post-restart")
            .instances("worker", 1)
            .memory("worker", "512m")
            .instances("ps", 1)
            .memory("ps", "512m")
            .set("tony.am.memory", "256m")
            .set("tony.train.steps", "2")
            .set("tony.train.checkpoint-every", "0")
            .set("tony.task.max-missed-heartbeats", "2000")
            .build();
        let SubmitOutcome::Accepted { id: fresh } = gw2.submit_conf("bob", 1, job) else {
            panic!("fresh submit rejected after restart")
        };
        assert!(fresh > *ids.iter().max().unwrap(), "ids must not be reused across restarts");
        let done = Arc::new(AtomicBool::new(false));
        let driver = spawn_clock_driver(clock.clone(), done.clone());
        assert!(gw2.wait_idle(Duration::from_secs(3000)), "recovered gateway never drained");
        done.store(true, Ordering::Relaxed);
        driver.join().unwrap();
        assert_eq!(gw2.job_state(fresh), Some(JobState::Finished));
        gw2.shutdown();
        let _ = std::fs::remove_dir_all(&base);
    });
}

/// With a frozen manual clock (no driver at all), jobs that terminalize
/// without running — rejects and kills-from-queue — still drain
/// `wait_idle` purely by notification, and the killed job leaves a
/// durable history record.
#[test]
fn frozen_clock_drain_is_pure_notification() {
    with_watchdog(60, || {
        let clock = ManualClock::shared();
        let rm = manual_rm(&clock, 1);
        let base = temp_base("frozen");
        let mut conf = GatewayConf::new(base.join("artifacts"));
        conf.history_dir = base.join("history");
        conf.workers = 1;
        let gw = Gateway::start(rm, conf).unwrap();

        // Invalid spec: rejected, terminal at submit time.
        let out = gw.submit_conf("alice", 1, JobConfBuilder::new("empty").build());
        assert!(matches!(out, SubmitOutcome::Rejected { .. }));

        // wait_idle with a huge *virtual* timeout returns immediately:
        // the clock never moves, so only the all-terminal predicate (and
        // the notifications that re-check it) can satisfy the wait.
        assert!(gw.wait_idle(Duration::from_secs(3600)));
        assert_eq!(clock.now_ms(), 0, "no virtual time consumed");
        gw.shutdown();
        let _ = std::fs::remove_dir_all(&base);
    });
}
