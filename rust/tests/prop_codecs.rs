//! Property tests: JSON and XML codecs round-trip arbitrary documents;
//! checkpoints survive round-trips and always detect corruption.

use std::collections::BTreeMap;

use tony::checkpoint::Checkpoint;
use tony::json::Json;
use tony::proptest::{check, Gen};
use tony::xmlconf::Configuration;
use tony::{prop_assert, prop_assert_eq};

fn gen_json(g: &mut Gen, depth: u32) -> Json {
    if depth == 0 {
        return match g.usize_up_to(3) {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::Num((g.u32() as f64) - 100_000.0 + if g.bool() { 0.5 } else { 0.0 }),
            _ => Json::Str(g.string(30)),
        };
    }
    match g.usize_up_to(5) {
        0 => Json::Null,
        1 => Json::Bool(g.bool()),
        2 => Json::Num(g.u32() as f64 / 8.0),
        3 => Json::Str(g.string(30)),
        4 => Json::Arr((0..g.len(6)).map(|_| gen_json(g, depth - 1)).collect()),
        _ => {
            let mut m = BTreeMap::new();
            for _ in 0..g.len(6) {
                m.insert(g.string(12), gen_json(g, depth - 1));
            }
            Json::Obj(m)
        }
    }
}

#[test]
fn json_round_trips() {
    check("json round trip", 300, |g| {
        let j = gen_json(g, 4);
        let compact = j.render();
        let pretty = j.render_pretty();
        prop_assert_eq!(Json::parse(&compact).map_err(|e| e.to_string())?, j);
        prop_assert_eq!(Json::parse(&pretty).map_err(|e| e.to_string())?, j);
        Ok(())
    });
}

#[test]
fn xml_configuration_round_trips() {
    check("xml conf round trip", 300, |g| {
        let mut conf = Configuration::new();
        for _ in 0..g.len(20) {
            // Keys are identifiers; values may contain XML specials.
            let key = g.ident(24);
            let mut val = g.string(40);
            // Hadoop-style trims values; normalize so round-trip compares.
            val = val.trim().to_string();
            if val.is_empty() {
                val = "v".to_string();
            }
            conf.set(&key, val);
        }
        if conf.is_empty() {
            conf.set("k", "v");
        }
        let xml = conf.to_xml();
        let back = Configuration::from_xml_str(&xml).map_err(|e| e.to_string())?;
        prop_assert_eq!(back, conf);
        Ok(())
    });
}

#[test]
fn checkpoints_round_trip() {
    check("checkpoint round trip", 100, |g| {
        let params = g.vec_f32(2000);
        let moments = if g.bool() {
            Some((
                params.iter().map(|p| p * 0.5).collect::<Vec<_>>(),
                params.iter().map(|p| p.abs()).collect::<Vec<_>>(),
            ))
        } else {
            None
        };
        let c = Checkpoint { step: g.u64(), params, moments };
        let b = c.encode();
        prop_assert_eq!(Checkpoint::decode(&b).map_err(|e| e.to_string())?, c);
        Ok(())
    });
}

#[test]
fn checkpoint_corruption_always_detected() {
    check("checkpoint corruption", 200, |g| {
        let c = Checkpoint {
            step: g.u64() % 1000,
            params: (0..100).map(|i| i as f32).collect(),
            moments: None,
        };
        let mut b = c.encode();
        let i = g.usize_up_to(b.len() - 1);
        let bit = 1u8 << g.usize_up_to(7);
        b[i] ^= bit;
        match Checkpoint::decode(&b) {
            Err(_) => Ok(()),
            // A flipped bit in the params payload could theoretically
            // collide the checksum — with a 64-bit sum this must never
            // happen for single-bit flips.
            Ok(back) => {
                prop_assert!(back == c, "corruption silently accepted AND changed data");
                Ok(())
            }
        }
    });
}

#[test]
fn size_parse_format_round_trips() {
    check("size round trip", 200, |g| {
        let v = g.u64() % (1u64 << 45);
        let s = tony::util::bytes::format_size(v);
        let back = tony::util::bytes::parse_size(&s).ok_or("parse failed")?;
        // format rounds to 1 decimal: allow 6% slack.
        let hi = v.max(back) as f64;
        let lo = v.min(back) as f64;
        prop_assert!(hi == 0.0 || lo / hi > 0.94, "{v} -> {s} -> {back}");
        Ok(())
    });
}
