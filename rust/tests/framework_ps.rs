//! Framework-level integration: the PS/worker protocol in isolation
//! (no YARN, no AM) — sync barrier semantics (distinct-contributor
//! counting), stale-push drop-and-report, moment fetch for exact
//! checkpoints, async mode, and shutdown.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use tony::framework::protocol::*;
use tony::framework::ps::PsServer;
use tony::framework::worker::PsClient;
use tony::net::rpc::RpcClient;
use tony::net::wire::Wire;
use tony::runtime::Engine;

fn tiny_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    if dir.join("meta.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/tiny missing; run `make artifacts`");
        None
    }
}

struct Shard {
    ps: Vec<PsServer>,
    kill: Arc<AtomicBool>,
    _engines: Vec<Engine>,
}

fn start_ps(dir: &std::path::Path, n_ps: u32) -> Shard {
    let kill = Arc::new(AtomicBool::new(false));
    let mut ps = Vec::new();
    let mut engines = Vec::new();
    for i in 0..n_ps {
        let engine = Engine::start(dir, Some(&["ps_adam"])).unwrap();
        ps.push(PsServer::start(i, n_ps, engine.handle(), kill.clone()).unwrap());
        engines.push(engine);
    }
    Shard { ps, kill, _engines: engines }
}

#[test]
fn init_pull_push_cycle_sync() {
    let Some(dir) = tiny_dir() else { return };
    let shard = start_ps(&dir, 2);
    let meta = tony::runtime::ArtifactMeta::load(&dir).unwrap();
    let endpoints: Vec<_> = shard.ps.iter().map(|p| p.addr()).collect();
    let client = PsClient::connect(&endpoints, meta.n_params, meta.chunk_len).unwrap();

    // Chief-style init at version 0.
    let params: Vec<f32> = (0..meta.n_params).map(|i| (i as f32 * 1e-4).sin()).collect();
    client.init(&params, None, 0).unwrap();

    let (v, got) = client.pull(0).unwrap();
    assert_eq!(v, 0);
    assert_eq!(got, params);

    // Two workers push for step 0; version must advance to 1 exactly once.
    let grads: Vec<f32> = vec![0.01; meta.n_params];
    client.push(&grads, 0, 0, 2, 1e-3, MODE_SYNC).unwrap();
    // Barrier: a pull for version 1 should NOT complete yet — verify the
    // version is still 0 via a non-blocking pull(0).
    let (v, _) = client.pull(0).unwrap();
    assert_eq!(v, 0, "one of two pushes must not advance the barrier");
    // A *duplicate* push from the same worker must not complete the
    // barrier either (relaunched-worker idempotence).
    client.push(&grads, 0, 0, 2, 1e-3, MODE_SYNC).unwrap();
    let (v, _) = client.pull(0).unwrap();
    assert_eq!(v, 0, "duplicate contributor must not advance the barrier");
    client.push(&grads, 0, 1, 2, 1e-3, MODE_SYNC).unwrap();
    let (v, new_params) = client.pull(1).unwrap();
    assert_eq!(v, 1);
    assert_ne!(new_params, params, "adam must have moved the params");

    // Moments are now nonzero and fetchable.
    let (m, vv) = client.moments().unwrap();
    assert_eq!(m.len(), meta.n_params);
    assert!(m.iter().any(|x| *x != 0.0));
    assert!(vv.iter().any(|x| *x != 0.0));
    shard.kill.store(true, Ordering::Relaxed);
}

#[test]
fn stale_push_dropped_and_version_reported() {
    let Some(dir) = tiny_dir() else { return };
    let shard = start_ps(&dir, 1);
    let meta = tony::runtime::ArtifactMeta::load(&dir).unwrap();
    let endpoints: Vec<_> = shard.ps.iter().map(|p| p.addr()).collect();
    let client = PsClient::connect(&endpoints, meta.n_params, meta.chunk_len).unwrap();
    let params = vec![0.0; meta.n_params];
    client.init(&params, None, 5).unwrap();
    // Push tagged for an old step (3) while chunks sit at version 5: the
    // gradient is dropped (not applied, not an error) and the live
    // version comes back so the worker can resync — survivors must not
    // die on straggler pushes during a surgical recovery.
    let seen = client.push(&vec![0.1; meta.n_params], 3, 0, 1, 1e-3, MODE_SYNC).unwrap();
    assert_eq!(seen, 5, "live version reported for resync");
    let (v, got) = client.pull(5).unwrap();
    assert_eq!(v, 5, "stale push must not advance the version");
    assert_eq!(got, params, "stale gradient must not be applied");
    // Same for a push from the *future* (worker ahead of a rolled-back
    // shard): dropped, live version reported.
    let seen = client.push(&vec![0.1; meta.n_params], 9, 0, 1, 1e-3, MODE_SYNC).unwrap();
    assert_eq!(seen, 5);
    let (v, _) = client.pull(5).unwrap();
    assert_eq!(v, 5);
    shard.kill.store(true, Ordering::Relaxed);
}

#[test]
fn push_before_init_rejected() {
    let Some(dir) = tiny_dir() else { return };
    let shard = start_ps(&dir, 1);
    let meta = tony::runtime::ArtifactMeta::load(&dir).unwrap();
    let endpoints: Vec<_> = shard.ps.iter().map(|p| p.addr()).collect();
    let client = PsClient::connect(&endpoints, meta.n_params, meta.chunk_len).unwrap();
    assert!(client.push(&vec![0.1; meta.n_params], 0, 0, 1, 1e-3, MODE_SYNC).is_err());
    shard.kill.store(true, Ordering::Relaxed);
}

#[test]
fn async_mode_applies_immediately() {
    let Some(dir) = tiny_dir() else { return };
    let shard = start_ps(&dir, 2);
    let meta = tony::runtime::ArtifactMeta::load(&dir).unwrap();
    let endpoints: Vec<_> = shard.ps.iter().map(|p| p.addr()).collect();
    let client = PsClient::connect(&endpoints, meta.n_params, meta.chunk_len).unwrap();
    client.init(&vec![1.0; meta.n_params], None, 0).unwrap();
    for k in 0..3 {
        client
            .push(&vec![0.05; meta.n_params], k, 0, 99 /* ignored */, 1e-3, MODE_ASYNC)
            .unwrap();
    }
    let (v, _) = client.pull(3).unwrap();
    assert_eq!(v, 3, "each async push applies immediately");
    let total: u64 = shard.ps.iter().map(|p| p.applied_updates()).sum();
    assert_eq!(total, 3 * meta.n_chunks() as u64);
    shard.kill.store(true, Ordering::Relaxed);
}

#[test]
fn pull_timeout_and_shutdown_wakeups() {
    let Some(dir) = tiny_dir() else { return };
    let shard = start_ps(&dir, 1);
    let meta = tony::runtime::ArtifactMeta::load(&dir).unwrap();
    let addr = shard.ps[0].addr();
    // Raw RPC pull with a short timeout against an uninitialized chunk.
    let cli = RpcClient::connect(&addr).unwrap();
    let req = PullRequest { chunk: 0, min_version: 0, timeout_ms: 100 };
    let t0 = std::time::Instant::now();
    let resp = cli.call(PS_PULL, &req.to_bytes());
    assert!(resp.is_err(), "pull on uninitialized chunk must time out");
    assert!(t0.elapsed().as_millis() >= 90);

    // A parked pull must wake promptly on shutdown.
    let cli2 = RpcClient::connect(&addr).unwrap();
    let waiter = std::thread::spawn(move || {
        let req = PullRequest { chunk: 0, min_version: 0, timeout_ms: 30_000 };
        cli2.call(PS_PULL, &req.to_bytes())
    });
    tony::util::clock::real_sleep(std::time::Duration::from_millis(50));
    shard.ps[0].shutdown();
    let out = waiter.join().unwrap();
    assert!(out.is_err(), "shutdown must fail parked pulls");
    let _ = meta;
}

#[test]
fn chunk_ownership_enforced() {
    let Some(dir) = tiny_dir() else { return };
    let shard = start_ps(&dir, 2);
    let meta = tony::runtime::ArtifactMeta::load(&dir).unwrap();
    // Ask shard 0 for a chunk owned by shard 1.
    let cli = RpcClient::connect(&shard.ps[0].addr()).unwrap();
    let msg = InitChunk {
        chunk: 1, // 1 % 2 == 1 -> owned by ps:1
        version: 0,
        params: vec![0.0; meta.chunk_len],
        m: vec![0.0; meta.chunk_len],
        v: vec![0.0; meta.chunk_len],
    };
    assert!(cli.call(PS_INIT, &msg.to_bytes()).is_err());
    shard.kill.store(true, Ordering::Relaxed);
}

#[test]
fn restore_resumes_from_checkpoint_version() {
    let Some(dir) = tiny_dir() else { return };
    let shard = start_ps(&dir, 2);
    let meta = tony::runtime::ArtifactMeta::load(&dir).unwrap();
    let endpoints: Vec<_> = shard.ps.iter().map(|p| p.addr()).collect();
    let client = PsClient::connect(&endpoints, meta.n_params, meta.chunk_len).unwrap();
    // Restore at step 42 with nonzero moments (as the chief does).
    let params = vec![0.5; meta.n_params];
    let m = vec![0.1; meta.n_params];
    let v = vec![0.2; meta.n_params];
    client.init(&params, Some(&(m.clone(), v.clone())), 42).unwrap();
    let (ver, got) = client.pull(42).unwrap();
    assert_eq!(ver, 42);
    assert_eq!(got, params);
    let (gm, gv) = client.moments().unwrap();
    assert_eq!(gm, m);
    assert_eq!(gv, v);
    shard.kill.store(true, Ordering::Relaxed);
}
