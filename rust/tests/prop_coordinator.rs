//! Property tests on coordinator state: cluster-spec completeness /
//! consistency for random task topologies, AM failure/success detection,
//! and RM teardown capacity conservation under random app mixes.

use std::sync::Arc;
use std::time::Duration;

use tony::am::protocol::{FinishedMsg, RegisterMsg, AM_FINISHED, AM_REGISTER};
use tony::am::state::{AmRpcHandler, AmState};
use tony::framework::ClusterSpec;
use tony::net::rpc::RpcHandler;
use tony::net::wire::Wire;
use tony::proptest::{check, Gen};
use tony::tonyconf::{JobConfBuilder, JobSpec};
use tony::yarn::{Resource, ResourceManager, SubmissionContext};
use tony::{prop_assert, prop_assert_eq};

fn gen_job(g: &mut Gen) -> JobSpec {
    let mut b = JobConfBuilder::new("prop").instances("worker", g.range(1, 6) as u32);
    if g.bool() {
        b = b.instances("ps", g.range(1, 4) as u32);
    }
    if g.bool() {
        b = b.instances("evaluator", 1);
    }
    JobSpec::from_conf(&b.build()).unwrap()
}

#[test]
fn cluster_spec_complete_consistent_duplicate_free() {
    check("spec completeness", 150, |g| {
        let job = gen_job(g);
        let state = Arc::new(AmState::new(&job));
        state.begin_attempt(1);
        let handler = AmRpcHandler::new(state.clone());

        // Register everyone in a random order with unique ports.
        let mut tasks: Vec<(String, u32)> = job
            .task_types
            .iter()
            .flat_map(|t| (0..t.instances).map(move |i| (t.name.clone(), i)))
            .collect();
        g.rng.shuffle(&mut tasks);
        let mut port = 7000u16;
        for (ty, idx) in &tasks {
            // Spec must not exist before the LAST registration.
            prop_assert!(!state.try_build_spec(1) || port > 7000 + tasks.len() as u16 - 1);
            let msg = RegisterMsg {
                task_type: ty.clone(),
                index: *idx,
                host: "127.0.0.1".into(),
                port,
                ui_url: None,
                spec_version: 1,
            };
            handler.handle(AM_REGISTER, &msg.to_bytes()).map_err(|e| e)?;
            port += 1;
        }
        prop_assert!(state.try_build_spec(1), "spec must build once all registered");
        let bytes = handler
            .handle(
                tony::am::protocol::AM_GET_SPEC,
                &tony::am::protocol::GetSpecMsg { spec_version: 1, timeout_ms: 50 }.to_bytes(),
            )
            .map_err(|e| e)?;
        let (spec, _, _) =
            ClusterSpec::from_tf_config(&String::from_utf8_lossy(&bytes)).map_err(|e| e.to_string())?;

        // Complete: every task type has exactly its instance count.
        for t in &job.task_types {
            prop_assert_eq!(spec.endpoints(&t.name).len(), t.instances as usize);
        }
        // Duplicate-free endpoints.
        let mut seen = std::collections::BTreeSet::new();
        for eps in spec.tasks.values() {
            for e in eps {
                prop_assert!(seen.insert(e.to_string()), "duplicate endpoint {e}");
            }
        }
        // Consistent: rendering for any task parses back identically.
        let (ty, idx) = g.pick(&tasks).clone();
        let doc = spec.to_tf_config(&ty, idx);
        let (spec2, pty, pidx) = ClusterSpec::from_tf_config(&doc).map_err(|e| e.to_string())?;
        prop_assert_eq!(spec2, spec);
        prop_assert_eq!(pty, ty);
        prop_assert_eq!(pidx, idx);
        Ok(())
    });
}

#[test]
fn tracked_outcome_detection_is_exact() {
    check("outcome detection", 150, |g| {
        let job = gen_job(g);
        let state = Arc::new(AmState::new(&job));
        state.begin_attempt(1);
        let handler = AmRpcHandler::new(state.clone());

        // Randomly finish tasks with random exit codes.
        let mut any_tracked_failed = false;
        let mut all_tracked_done = true;
        for t in &job.task_types {
            for i in 0..t.instances {
                let finish = g.chance(0.8);
                if !finish {
                    if t.tracked {
                        all_tracked_done = false;
                    }
                    continue;
                }
                let code: i64 = if g.chance(0.3) { g.range(1, 9) as i64 } else { 0 };
                if t.tracked && code != 0 {
                    any_tracked_failed = true;
                }
                let msg = FinishedMsg {
                    task_type: t.name.clone(),
                    index: i,
                    spec_version: 1,
                    exit_code: code,
                };
                handler.handle(AM_FINISHED, &msg.to_bytes()).map_err(|e| e)?;
            }
        }
        prop_assert_eq!(
            state.first_tracked_failure(&job).is_some(),
            any_tracked_failed
        );
        prop_assert_eq!(
            state.all_tracked_succeeded(&job),
            all_tracked_done && !any_tracked_failed
        );
        Ok(())
    });
}

#[test]
fn rm_conserves_capacity_across_random_app_mixes() {
    check("rm capacity conservation", 20, |g| {
        let rm = ResourceManager::start_uniform(g.range(2, 5) as u32, Resource::new(4096, 8, 0));
        let n_apps = g.range(1, 5);
        let mut ids = Vec::new();
        for i in 0..n_apps {
            let crash = g.bool();
            let rm2 = rm.clone();
            let seq = i + 1;
            let id = rm
                .submit_application(
                    SubmissionContext {
                        name: format!("app{i}"),
                        queue: "default".into(),
                        am_resource: Resource::new(512, 1, 0),
                    },
                    Box::new(move |_ctx| {
                        let app = tony::util::ids::ApplicationId {
                            cluster_ts: rm2.cluster_ts,
                            seq,
                        };
                        rm2.register_am(app, None).ok();
                        if crash {
                            3
                        } else {
                            rm2.finish_application(app, true, "ok");
                            0
                        }
                    }),
                )
                .map_err(|e| e.to_string())?;
            ids.push(id);
        }
        for id in ids {
            let report = rm
                .wait_for_completion(id, Duration::from_secs(10))
                .map_err(|e| e.to_string())?;
            prop_assert!(report.state.is_terminal());
        }
        // Give completion callbacks a beat to release capacity.
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        loop {
            let ok = rm.node_usage().iter().all(|(_, free, cap)| free == cap);
            if ok {
                break;
            }
            if std::time::Instant::now() > deadline {
                for (id, free, cap) in rm.node_usage() {
                    prop_assert!(free == cap, "node {id} leaked: {free} != {cap}");
                }
            }
            tony::util::clock::real_sleep(Duration::from_millis(10));
        }
        Ok(())
    });
}
