//! Integration: a job with an untracked evaluator task — the evaluator
//! scores checkpoints as they appear and stops cleanly when the tracked
//! workers finish (TonY's untracked job types).

use std::time::Duration;

use tony::client::TonyClient;
use tony::tonyconf::JobConfBuilder;
use tony::util::ids::TaskId;
use tony::yarn::{AppState, Resource, ResourceManager};

fn tiny_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    if dir.join("meta.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/tiny missing; run `make artifacts`");
        None
    }
}

#[test]
fn evaluator_scores_checkpoints_and_job_finishes() {
    let Some(dir) = tiny_dir() else { return };
    let rm = ResourceManager::start_uniform(4, Resource::new(8192, 8, 0));
    let ckpt = std::env::temp_dir().join(format!(
        "tony-eval-{}-{}",
        std::process::id(),
        tony::util::ids::next_seq()
    ));
    let _ = std::fs::remove_dir_all(&ckpt);
    let conf = JobConfBuilder::new("with-evaluator")
        .instances("worker", 1)
        .memory("worker", "1g")
        .instances("ps", 1)
        .memory("ps", "1g")
        .instances("evaluator", 1)
        .memory("evaluator", "1g")
        .train(dir.to_str().unwrap(), "tiny", 12)
        .set("tony.train.checkpoint-dir", ckpt.to_str().unwrap())
        .set("tony.train.checkpoint-every", "4")
        .build();

    let client = TonyClient::new(rm.clone());
    let handle = client.submit(&conf, &dir).unwrap();
    let report = handle.wait(Duration::from_secs(300)).unwrap();
    // Job success gates only on the tracked worker, per TonY semantics.
    assert_eq!(report.state, AppState::Finished, "{}", report.diagnostics);

    // The evaluator produced held-out scores from at least one checkpoint
    // and exited 0 on the Stop command.
    let snap = handle.status_json();
    let tasks = snap.get("tasks").unwrap().as_arr().unwrap();
    let eval = tasks
        .iter()
        .find(|t| t.get("task").unwrap().as_str() == Some("evaluator:0"))
        .expect("evaluator task present in spec");
    assert_eq!(eval.get("exit").unwrap().as_i64(), Some(0), "{}", snap.render_pretty());
    // AmState should have evaluator metrics with a step > 0.
    let _ = TaskId::new("evaluator", 0);
    assert!(
        eval.get("step").unwrap().as_u64().unwrap() >= 4,
        "evaluator never scored a checkpoint: {}",
        snap.render_pretty()
    );
    let _ = std::fs::remove_dir_all(&ckpt);
}
