//! Property tests: wire codec, tensors, protocol messages round-trip for
//! arbitrary values, and corrupt/truncated buffers never decode silently.

use tony::framework::protocol::{InitChunk, PullRequest, PushRequest, TaskMetrics};
use tony::net::wire::{Reader, Wire, Writer};
use tony::proptest::check;
use tony::runtime::Tensor;
use tony::{prop_assert, prop_assert_eq};

#[test]
fn f32_vectors_round_trip() {
    check("f32 vec round trip", 300, |g| {
        let v = g.vec_f32(5000);
        let b = v.to_bytes();
        let back = Vec::<f32>::from_bytes(&b).map_err(|e| e.to_string())?;
        prop_assert_eq!(v.len(), back.len());
        for (a, x) in v.iter().zip(&back) {
            prop_assert!(a.to_bits() == x.to_bits(), "bit mismatch {a} vs {x}");
        }
        Ok(())
    });
}

#[test]
fn strings_round_trip() {
    check("string round trip", 300, |g| {
        let s = g.string(200);
        let b = s.to_bytes();
        prop_assert_eq!(String::from_bytes(&b).map_err(|e| e.to_string())?, s);
        Ok(())
    });
}

#[test]
fn mixed_frames_round_trip() {
    check("mixed frame", 200, |g| {
        let mut w = Writer::new();
        let a = g.u64();
        let b = g.f32();
        let c = g.string(50);
        let d = g.vec_f32(100);
        let e = g.bool();
        w.u64(a);
        w.f32(b);
        w.str(&c);
        w.f32_slice(&d);
        w.bool(e);
        let mut r = Reader::new(&w.buf);
        prop_assert_eq!(r.u64().map_err(|x| x.to_string())?, a);
        prop_assert!(r.f32().map_err(|x| x.to_string())?.to_bits() == b.to_bits(), "f32");
        prop_assert_eq!(r.str().map_err(|x| x.to_string())?, c);
        prop_assert_eq!(r.f32_vec().map_err(|x| x.to_string())?, d);
        prop_assert_eq!(r.bool().map_err(|x| x.to_string())?, e);
        prop_assert_eq!(r.remaining(), 0);
        Ok(())
    });
}

#[test]
fn truncation_always_errors() {
    check("truncation detected", 300, |g| {
        let v = g.vec_f32(500);
        if v.is_empty() {
            return Ok(());
        }
        let b = v.to_bytes();
        let cut = g.usize_up_to(b.len() - 1);
        // Truncated decode must error OR (if cut lands on a valid prefix
        // boundary) from_bytes still errors due to trailing-byte check.
        prop_assert!(
            Vec::<f32>::from_bytes(&b[..cut]).is_err(),
            "truncated to {cut}/{} decoded",
            b.len()
        );
        Ok(())
    });
}

#[test]
fn tensors_round_trip() {
    check("tensor round trip", 200, |g| {
        let t = match g.usize_up_to(2) {
            0 => {
                let d = g.vec_f32(300);
                Tensor::F32 { shape: vec![d.len()], data: d }
            }
            1 => {
                let n = g.len(100);
                Tensor::I32 {
                    shape: vec![n],
                    data: (0..n).map(|_| g.u32() as i32).collect(),
                }
            }
            _ => Tensor::U32 { shape: vec![], data: vec![g.u32()] },
        };
        let b = t.to_bytes();
        prop_assert_eq!(Tensor::from_bytes(&b).map_err(|e| e.to_string())?, t);
        Ok(())
    });
}

#[test]
fn protocol_messages_round_trip() {
    check("protocol messages", 200, |g| {
        let init = InitChunk {
            chunk: g.u32() % 1000,
            version: g.u64(),
            params: g.vec_f32(200),
            m: g.vec_f32(200),
            v: g.vec_f32(200),
        };
        prop_assert_eq!(
            InitChunk::from_bytes(&init.to_bytes()).map_err(|e| e.to_string())?,
            init
        );
        let push = PushRequest {
            chunk: g.u32(),
            step: g.u64(),
            worker: g.u32() % 100,
            grads: g.vec_f32(300),
            n_workers: g.u32() % 100,
            lr: g.f32(),
            mode: (g.u32() % 2) as u8,
        };
        let back = PushRequest::from_bytes(&push.to_bytes()).map_err(|e| e.to_string())?;
        prop_assert!(back.lr.to_bits() == push.lr.to_bits(), "lr bits");
        prop_assert_eq!(back.grads.len(), push.grads.len());
        let pull = PullRequest { chunk: g.u32(), min_version: g.u64(), timeout_ms: g.u64() };
        prop_assert_eq!(
            PullRequest::from_bytes(&pull.to_bytes()).map_err(|e| e.to_string())?,
            pull
        );
        Ok(())
    });
}

#[test]
fn metrics_round_trip_with_history() {
    check("metrics", 200, |g| {
        let n = g.len(50);
        let m = TaskMetrics {
            step: g.u64(),
            loss: g.f32(),
            eval_loss: g.f32(),
            tokens_done: g.u64(),
            step_ms_avg: g.f64() * 1000.0,
            mem_used_mb: g.u64() % 100_000,
            updates_applied: g.u64(),
            finished: g.bool(),
            loss_history: (0..n).map(|i| (i as u64, g.f32())).collect(),
            history_rewound: g.u64(),
        };
        let back = TaskMetrics::from_bytes(&m.to_bytes()).map_err(|e| e.to_string())?;
        prop_assert_eq!(back.loss_history.len(), m.loss_history.len());
        prop_assert_eq!(back.step, m.step);
        prop_assert_eq!(back.finished, m.finished);
        Ok(())
    });
}
