//! Elastic-job integration tests (docs/SCHEDULING.md "Elasticity"):
//! the RM grows an elastic job into idle capacity, then plans a
//! cooperative *shrink* — never a preemption kill — when a rigid gang
//! arrives in an under-guarantee queue.  Asserted resize invariants:
//! survivor ContainerIds are stable across both waves, released workers
//! exit `Released` (never `Killed`/`Preempted`), chaos kills of
//! survivors keep their real `Killed` status, and no capacity leaks.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use tony::util::clock::SystemClock;
use tony::util::event::WakeupBus;
use tony::util::ids::{ApplicationId, ContainerId};
use tony::yarn::{
    AppState, ContainerCtx, ContainerRequest, ExitStatus, NodeSpec, QueueConf, Resource,
    ResourceManager, RmConf, SchedulerConf, SubmissionContext,
};

/// Task body that blocks (event-driven) until its container is killed.
fn run_until_killed(ctx: ContainerCtx) -> i32 {
    let clock = SystemClock::new();
    let bus = Arc::new(WakeupBus::new());
    ctx.kill_switch().register(&bus);
    while !ctx.killed() {
        bus.wait_until(&clock, clock.now_ms() + 10_000);
    }
    0
}

fn submission(name: &str, queue: &str, am_mb: u64) -> SubmissionContext {
    SubmissionContext {
        name: name.into(),
        queue: queue.into(),
        am_resource: Resource::new(am_mb, 1, 0),
    }
}

fn elastic_sched() -> SchedulerConf {
    SchedulerConf {
        preemption: true,
        preemption_grace_ms: 0,
        // One grow per scenario: a completed resize parks the job for
        // the rest of the test (shrink ignores the cooldown by design).
        elastic_cooldown_ms: 600_000,
        ..Default::default()
    }
}

/// What the elastic mini-AM reports back to the test thread after each
/// wave.
struct ShrinkReport {
    target: u32,
    survivors: Vec<ContainerId>,
    released: Vec<ContainerId>,
    /// Exits observed for containers we did NOT release (must stay
    /// empty: shrink never touches survivors).
    survivor_exits: Vec<(ContainerId, ExitStatus)>,
    /// Exit statuses observed for the released set (must all be
    /// `Released`).
    released_exits: Vec<(ContainerId, ExitStatus)>,
}

/// The tentpole scenario: an elastic job in `ml` grows 2 -> 6 workers
/// into idle capacity, then is shrunk (not preempted) to make room for
/// a rigid gang in the under-guarantee `etl` queue.
#[test]
fn elastic_job_grows_idle_then_shrinks_for_rigid_gang() {
    // One node keeps the arithmetic exact: after the grow the cluster
    // holds AM(512) + 6 workers (6144) + the rigid job's AM (512),
    // leaving 1024 MB free — its 3-worker gang (3072 MB) needs exactly
    // two cooperative releases, and ml's 25% guarantee floor (2048 MB)
    // still holds after both.
    let queues = vec![QueueConf::new("ml", 0.25, 1.0), QueueConf::new("etl", 0.75, 1.0)];
    let rm = ResourceManager::start_with(
        vec![NodeSpec::new(0, Resource::new(8192, 16, 0))],
        queues,
        RmConf { scheduler: elastic_sched(), ..Default::default() },
    );

    let worker = Resource::new(1024, 1, 0);
    let (grown_tx, grown_rx) = mpsc::channel::<Vec<ContainerId>>();
    let (shrunk_tx, shrunk_rx) = mpsc::channel::<ShrinkReport>();
    let (finish_tx, finish_rx) = mpsc::channel::<()>();
    let rm2 = rm.clone();
    let a = rm
        .submit_application(
            submission("elastic-ml", "ml", 512),
            Box::new(move |_| {
                let app = ApplicationId { cluster_ts: rm2.cluster_ts, seq: 1 };
                rm2.register_am(app, None).unwrap();
                let bus = WakeupBus::for_clock(rm2.clock());
                rm2.register_am_waker(app, &bus);
                let clock = rm2.clock().clone();
                rm2.register_elastic(app, worker, None, 2, 6, 2).unwrap();

                // Initial rigid-looking wave of 2, then serve the
                // allocate protocol: grow when commanded, shrink when
                // commanded, release survivors when told to finish.
                let mut held: Vec<ContainerId> = Vec::new();
                let mut expected = 2u32;
                let mut asks = vec![ContainerRequest::new(worker, 2)];
                let mut grow_acked = false;
                let mut doomed: Vec<ContainerId> = Vec::new();
                let mut shrink_target = 0u32;
                let mut released_exits: Vec<(ContainerId, ExitStatus)> = Vec::new();
                let mut survivor_exits: Vec<(ContainerId, ExitStatus)> = Vec::new();
                loop {
                    let send = std::mem::take(&mut asks);
                    let resp = rm2.allocate(app, &send, &[]).unwrap();
                    for c in resp.allocated {
                        rm2.start_container(&c, BTreeMap::new(), Box::new(run_until_killed))
                            .unwrap();
                        held.push(c.id);
                    }
                    for st in resp.completed {
                        if doomed.contains(&st.id) {
                            released_exits.push((st.id, st.exit));
                        } else {
                            survivor_exits.push((st.id, st.exit));
                        }
                    }
                    if let Some(t) = resp.resize_target {
                        if t > expected {
                            asks.push(ContainerRequest::new(worker, t - expected));
                            expected = t;
                        } else if t < expected && doomed.is_empty() {
                            // Cooperative release of the highest-index
                            // (newest) workers, exactly like the real AM.
                            doomed = held.split_off(t as usize);
                            shrink_target = t;
                            rm2.release_workers(app, &doomed);
                            expected = t;
                        }
                    }
                    // Grow wave complete?
                    if !grow_acked && expected > 2 && held.len() as u32 == expected {
                        grow_acked = true;
                        rm2.note_resized(app, expected);
                        grown_tx.send(held.clone()).unwrap();
                    }
                    // Shrink wave complete once every doomed container
                    // reported its exit?
                    if !doomed.is_empty() && released_exits.len() == doomed.len() {
                        rm2.note_resized(app, shrink_target);
                        shrunk_tx
                            .send(ShrinkReport {
                                target: shrink_target,
                                survivors: held.clone(),
                                released: std::mem::take(&mut doomed),
                                survivor_exits: survivor_exits.clone(),
                                released_exits: std::mem::take(&mut released_exits),
                            })
                            .unwrap();
                        break;
                    }
                    bus.wait_until(&*clock, clock.now_ms() + 2_000);
                }

                // Hold the survivors until the rigid gang is done, then
                // drain and finish.
                finish_rx.recv().unwrap();
                let mut done = 0;
                let mut released = false;
                while done < held.len() {
                    let rel: &[ContainerId] = if released { &[] } else { &held };
                    let resp = rm2.allocate(app, &[], rel).unwrap();
                    released = true;
                    done += resp.completed.len();
                    if done < held.len() {
                        bus.wait_until(&*clock, clock.now_ms() + 2_000);
                    }
                }
                rm2.finish_application(app, true, "elastic job survived both waves");
                0
            }),
        )
        .unwrap();

    // ---- wave 1: grow into idle capacity ----
    let after_grow = grown_rx
        .recv_timeout(Duration::from_secs(30))
        .expect("elastic job never received its grow command");
    assert_eq!(after_grow.len(), 6, "deficit 4 within max-resize-per-round 4: 2 -> 6");
    let ml = rm.queue_stats().into_iter().find(|q| &*q.name == "ml").unwrap();
    assert_eq!(ml.elastic_jobs, 1);
    assert_eq!(ml.elastic_workers, 6, "acknowledged count after the grow wave");
    assert_eq!(ml.elastic_grows, 4);

    // ---- wave 2: a rigid gang in under-guarantee etl forces a shrink ----
    let rm3 = rm.clone();
    let b = rm
        .submit_application(
            submission("rigid-etl", "etl", 512),
            Box::new(move |_| {
                let app = ApplicationId { cluster_ts: rm3.cluster_ts, seq: 2 };
                rm3.register_am(app, None).unwrap();
                let bus = WakeupBus::for_clock(rm3.clock());
                rm3.register_am_waker(app, &bus);
                let clock = rm3.clock().clone();
                let asks = vec![ContainerRequest::new(Resource::new(1024, 1, 0), 3)];
                let mut asked = false;
                let mut done = 0;
                while done < 3 {
                    let send: &[ContainerRequest] = if asked { &[] } else { &asks };
                    let resp = rm3.allocate(app, send, &[]).unwrap();
                    asked = true;
                    for c in resp.allocated {
                        rm3.start_container(&c, BTreeMap::new(), Box::new(|_| 0)).unwrap();
                    }
                    done += resp.completed.iter().filter(|s| s.exit.is_success()).count();
                    if done < 3 {
                        bus.wait_until(&*clock, clock.now_ms() + 2_000);
                    }
                }
                rm3.finish_application(app, true, "rigid gang ran on released capacity");
                0
            }),
        )
        .unwrap();

    let report = shrunk_rx
        .recv_timeout(Duration::from_secs(30))
        .expect("elastic job never received its shrink command");
    // Released workers are the newest ones; survivors keep their exact
    // ContainerIds from before the shrink (stability invariant).
    assert_eq!(report.target, 4, "zero-benefit pruning releases exactly what the gang needs");
    assert_eq!(report.released.len(), 2);
    assert_eq!(report.survivors, after_grow[..report.target as usize].to_vec());
    assert_eq!(report.released, after_grow[report.target as usize..].to_vec());
    for (cid, exit) in &report.released_exits {
        assert_eq!(
            *exit,
            ExitStatus::Released,
            "cooperatively released {cid} must exit Released, not a fault"
        );
    }
    assert!(
        report.survivor_exits.is_empty(),
        "shrink must not touch survivors: {:?}",
        report.survivor_exits
    );

    let rb = rm.wait_for_completion(b, Duration::from_secs(60)).unwrap();
    assert_eq!(rb.state, AppState::Finished, "{}", rb.diagnostics);
    finish_tx.send(()).unwrap();
    let ra = rm.wait_for_completion(a, Duration::from_secs(60)).unwrap();
    assert_eq!(ra.state, AppState::Finished, "{}", ra.diagnostics);

    // Shrink was preferred over preemption: zero kills, zero rounds.
    let stats = rm.scheduler_stats();
    assert_eq!(stats.preemptions, 0, "no preemption kill may happen when shrink suffices");
    assert_eq!(stats.preemption_rounds, 0);
    assert_eq!(stats.elastic_grows, 4);
    assert_eq!(stats.elastic_shrink_rounds, 1);
    assert_eq!(stats.elastic_released as usize, report.released.len());
    let ml = rm.queue_stats().into_iter().find(|q| &*q.name == "ml").unwrap();
    assert_eq!(ml.elastic_shrinks as usize, report.released.len());
    assert_eq!(ml.preemptions, 0);
    for (_, free, cap) in rm.node_usage() {
        assert_eq!(free, cap, "capacity leaked");
    }
}

/// Chaos mid-shrink: a *survivor* killed while a release wave is in
/// flight must come back `Killed` (a real fault signal), never
/// `Released` — and the released set must not leak or double-fire.
#[test]
fn chaos_kill_mid_shrink_is_not_mistaken_for_release() {
    let rm = ResourceManager::start_with(
        vec![NodeSpec::new(0, Resource::new(8192, 16, 0))],
        QueueConf::default_only(),
        RmConf { scheduler: elastic_sched(), ..Default::default() },
    );
    let worker = Resource::new(1024, 1, 0);
    let (exits_tx, exits_rx) = mpsc::channel::<Vec<(ContainerId, ExitStatus)>>();
    let rm2 = rm.clone();
    let a = rm
        .submit_application(
            submission("elastic-chaos", "default", 512),
            Box::new(move |_| {
                let app = ApplicationId { cluster_ts: rm2.cluster_ts, seq: 1 };
                rm2.register_am(app, None).unwrap();
                let bus = WakeupBus::for_clock(rm2.clock());
                rm2.register_am_waker(app, &bus);
                let clock = rm2.clock().clone();
                rm2.register_elastic(app, worker, None, 1, 4, 4).unwrap();

                let mut held: Vec<ContainerId> = Vec::new();
                let mut asked = false;
                while held.len() < 4 {
                    let asks = vec![ContainerRequest::new(worker, 4)];
                    let send: &[ContainerRequest] = if asked { &[] } else { &asks };
                    let resp = rm2.allocate(app, send, &[]).unwrap();
                    asked = true;
                    for c in resp.allocated {
                        rm2.start_container(&c, BTreeMap::new(), Box::new(run_until_killed))
                            .unwrap();
                        held.push(c.id);
                    }
                    if held.len() < 4 {
                        bus.wait_until(&*clock, clock.now_ms() + 2_000);
                    }
                }

                // Shrink wave: cooperatively release the two newest
                // workers... and mid-wave, chaos kills a survivor.
                let doomed = held.split_off(2);
                rm2.release_workers(app, &doomed);
                rm2.stop_container(held[1]); // the chaos kill
                let mut exits: Vec<(ContainerId, ExitStatus)> = Vec::new();
                while exits.len() < 3 {
                    let resp = rm2.allocate(app, &[], &[]).unwrap();
                    for st in resp.completed {
                        exits.push((st.id, st.exit));
                    }
                    if exits.len() < 3 {
                        bus.wait_until(&*clock, clock.now_ms() + 2_000);
                    }
                }
                rm2.note_resized(app, 2);
                exits_tx.send(exits).unwrap();

                // Drain the last survivor and finish.
                let last = vec![held[0]];
                let mut done = 0;
                let mut released = false;
                while done < 1 {
                    let rel: &[ContainerId] = if released { &[] } else { &last };
                    let resp = rm2.allocate(app, &[], rel).unwrap();
                    released = true;
                    done += resp.completed.len();
                    if done < 1 {
                        bus.wait_until(&*clock, clock.now_ms() + 2_000);
                    }
                }
                rm2.finish_application(app, true, "reconciled after chaos mid-shrink");
                0
            }),
        )
        .unwrap();

    let exits = exits_rx
        .recv_timeout(Duration::from_secs(30))
        .expect("shrink + chaos exits never arrived");
    let released: Vec<_> =
        exits.iter().filter(|(_, e)| *e == ExitStatus::Released).collect();
    let killed: Vec<_> = exits.iter().filter(|(_, e)| *e == ExitStatus::Killed).collect();
    assert_eq!(released.len(), 2, "exactly the two released workers exit Released: {exits:?}");
    assert_eq!(killed.len(), 1, "the chaos-killed survivor keeps its real Killed status");
    let ra = rm.wait_for_completion(a, Duration::from_secs(60)).unwrap();
    assert_eq!(ra.state, AppState::Finished, "{}", ra.diagnostics);
    for (_, free, cap) in rm.node_usage() {
        assert_eq!(free, cap, "capacity leaked");
    }
}

/// Attempt-restart / re-attach semantics: re-registering the elastic
/// profile mid-resize clears the in-flight command (the dead attempt's
/// wave can no longer complete) and the job re-converges to the planned
/// target from scratch.
#[test]
fn reregistration_clears_inflight_resize_and_reconverges() {
    let sched = SchedulerConf {
        preemption: true,
        preemption_grace_ms: 0,
        elastic_cooldown_ms: 0, // replan immediately after the reset
        ..Default::default()
    };
    let rm = ResourceManager::start_with(
        vec![NodeSpec::new(0, Resource::new(8192, 16, 0))],
        QueueConf::default_only(),
        RmConf { scheduler: sched, ..Default::default() },
    );
    let worker = Resource::new(1024, 1, 0);
    let (done_tx, done_rx) = mpsc::channel::<usize>();
    let rm2 = rm.clone();
    let a = rm
        .submit_application(
            submission("elastic-restart", "default", 512),
            Box::new(move |_| {
                let app = ApplicationId { cluster_ts: rm2.cluster_ts, seq: 1 };
                rm2.register_am(app, None).unwrap();
                let bus = WakeupBus::for_clock(rm2.clock());
                rm2.register_am_waker(app, &bus);
                let clock = rm2.clock().clone();
                rm2.register_elastic(app, worker, None, 2, 6, 2).unwrap();

                let mut held: Vec<ContainerId> = Vec::new();
                let mut expected = 2u32;
                let mut asks = vec![ContainerRequest::new(worker, 2)];
                let mut reregistered = false;
                loop {
                    let send = std::mem::take(&mut asks);
                    let resp = rm2.allocate(app, &send, &[]).unwrap();
                    for c in resp.allocated {
                        rm2.start_container(&c, BTreeMap::new(), Box::new(run_until_killed))
                            .unwrap();
                        held.push(c.id);
                    }
                    if let Some(t) = resp.resize_target {
                        if !reregistered {
                            // Simulate the attempt restart: the wave the
                            // RM just commanded dies with the attempt;
                            // re-registration resets resize state.
                            reregistered = true;
                            rm2.register_elastic(app, worker, None, 2, 6, 2).unwrap();
                        } else if t > expected {
                            asks.push(ContainerRequest::new(worker, t - expected));
                            expected = t;
                        }
                    }
                    if reregistered && expected > 2 && held.len() as u32 == expected {
                        rm2.note_resized(app, expected);
                        break;
                    }
                    bus.wait_until(&*clock, clock.now_ms() + 2_000);
                }
                done_tx.send(held.len()).unwrap();

                // Drain and finish.
                let mut done = 0;
                let mut released = false;
                while done < held.len() {
                    let rel: &[ContainerId] = if released { &[] } else { &held };
                    let resp = rm2.allocate(app, &[], rel).unwrap();
                    released = true;
                    done += resp.completed.len();
                    if done < held.len() {
                        bus.wait_until(&*clock, clock.now_ms() + 2_000);
                    }
                }
                rm2.finish_application(app, true, "reconverged after mid-resize restart");
                0
            }),
        )
        .unwrap();

    let held = done_rx
        .recv_timeout(Duration::from_secs(30))
        .expect("job never reconverged after the restart");
    assert_eq!(held, 6, "the replanned grow converges to the same target, not double-applied");
    let q = rm.queue_stats().into_iter().find(|q| &*q.name == "default").unwrap();
    assert_eq!(q.elastic_workers, 6);
    let ra = rm.wait_for_completion(a, Duration::from_secs(60)).unwrap();
    assert_eq!(ra.state, AppState::Finished, "{}", ra.diagnostics);
    assert_eq!(rm.scheduler_stats().preemptions, 0);
    for (_, free, cap) in rm.node_usage() {
        assert_eq!(free, cap, "capacity leaked");
    }
}
