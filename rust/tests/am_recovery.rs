//! Surgical-recovery integration + regression tests for the two AM
//! bugfixes (container leak, registration hang).  Unlike the legacy
//! `fault_tolerance.rs` suite these run on the synthetic preset, so the
//! recovery path is exercised in every build, not just after
//! `make artifacts`.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use tony::chaos::{ChaosInjector, Fault};
use tony::checkpoint::CheckpointStore;
use tony::client::TonyClient;
use tony::tonyconf::JobConfBuilder;
use tony::util::ids::TaskId;
use tony::yarn::{
    AppState, ContainerRequest, NodeSpec, QueueConf, Resource, ResourceManager,
    SubmissionContext,
};

fn preset_dir() -> Option<std::path::PathBuf> {
    if !tony::runtime::synthetic::sim_backend_active() {
        eprintln!("SKIP: pjrt build; synthetic preset unavailable");
        return None;
    }
    Some(tony::runtime::synthetic::default_dir().unwrap())
}

fn ckpt_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!(
        "tony-amrec-{tag}-{}-{}",
        std::process::id(),
        tony::util::ids::next_seq()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn wait_until(timeout: Duration, mut f: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + timeout;
    while Instant::now() < deadline {
        if f() {
            return true;
        }
        tony::util::clock::real_sleep(Duration::from_millis(5));
    }
    false
}

/// Kill one of three workers mid-training.  The surgical path must
/// relaunch exactly that worker's container while both other workers and
/// the PS keep their original ContainerIds, within the same attempt, and
/// without anyone restoring from a checkpoint (no rollback).
#[test]
fn surgical_worker_kill_keeps_survivor_containers() {
    let Some(dir) = preset_dir() else { return };
    let rm = ResourceManager::start_uniform(4, Resource::new(8192, 8, 0));
    let ckpt = ckpt_dir("surgical");
    let conf = JobConfBuilder::new("surgical")
        .instances("worker", 3)
        .memory("worker", "1g")
        .instances("ps", 1)
        .memory("ps", "1g")
        .train(dir.to_str().unwrap(), "tiny", 12)
        .set("tony.train.checkpoint-dir", ckpt.to_str().unwrap())
        .set("tony.train.checkpoint-every", "4")
        .build();

    let client = TonyClient::new(rm.clone());
    let handle = client.submit(&conf, &dir).unwrap();
    let victim = TaskId::new("worker", 2);

    // Wait for the initial rendezvous so the pre-kill container map is
    // complete.
    assert!(
        wait_until(Duration::from_secs(120), || {
            handle.am_state.phase() == tony::am::JobPhase::Running
                && handle.am_state.container_map().values().all(|c| c.is_some())
        }),
        "job never reached Running"
    );
    let pre = handle.am_state.container_map();

    let chaos = ChaosInjector::start(
        rm.clone(),
        handle.am_state.clone(),
        vec![Fault::KillTask { task_type: "worker".into(), index: 2, after_step: 3 }],
    );

    // Capture the container map the moment the replacement is up (the
    // job is still mid-flight; survivors are blocked on the barrier).
    let mut post = None;
    assert!(
        wait_until(Duration::from_secs(120), || {
            let m = handle.am_state.container_map();
            let replaced = m.get(&victim).copied().flatten();
            if handle.am_state.recoveries() >= 1
                && replaced.is_some()
                && replaced != pre.get(&victim).copied().flatten()
            {
                post = Some(m);
                true
            } else {
                false
            }
        }),
        "replacement for {victim} never launched"
    );
    let post = post.unwrap();

    let report = handle.wait(Duration::from_secs(300)).unwrap();
    let records = chaos.join();
    assert_eq!(report.state, AppState::Finished, "{}", report.diagnostics);
    assert_eq!(records.len(), 1, "fault fired exactly once");

    // Surgical, not full-restart: one attempt, >= 1 recovery.
    assert_eq!(handle.am_state.attempt(), 1, "survivors' attempt never restarted");
    assert!(handle.am_state.recoveries() >= 1);

    // Exactly the victim's container changed; every survivor kept its
    // original ContainerId.
    for (task, pre_cid) in &pre {
        let post_cid = post.get(task).copied().flatten();
        if *task == victim {
            assert_ne!(post_cid, *pre_cid, "victim must have a fresh container");
        } else {
            assert_eq!(post_cid, *pre_cid, "survivor {task} must keep its container");
        }
    }

    // Training completed without a rollback: the only restore marker is
    // the initial seed at step 0 (a surgical worker recovery re-seeds
    // nothing).
    let metrics = handle.am_state.chief_metrics().unwrap();
    assert_eq!(metrics.step, 12);
    let store = CheckpointStore::new(&ckpt);
    let markers = store.restore_markers().unwrap();
    assert_eq!(markers.len(), 1, "no re-seed beyond the initial init: {markers:?}");
    assert_eq!(markers[0].1, 0);
    let _ = std::fs::remove_dir_all(&ckpt);
}

/// Kill the *chief* (worker:0).  Its replacement must join the warm
/// parameter servers as-is — no checkpoint restore, no rollback of the
/// surviving workers — and finish the job in the same attempt.
#[test]
fn surgical_chief_kill_joins_warm_ps() {
    let Some(dir) = preset_dir() else { return };
    let rm = ResourceManager::start_uniform(4, Resource::new(8192, 8, 0));
    let ckpt = ckpt_dir("chief");
    let conf = JobConfBuilder::new("chief-kill")
        .instances("worker", 2)
        .memory("worker", "1g")
        .instances("ps", 1)
        .memory("ps", "1g")
        .train(dir.to_str().unwrap(), "tiny", 12)
        .set("tony.train.checkpoint-dir", ckpt.to_str().unwrap())
        .set("tony.train.checkpoint-every", "4")
        .build();

    let client = TonyClient::new(rm.clone());
    let handle = client.submit(&conf, &dir).unwrap();
    let chaos = ChaosInjector::start(
        rm.clone(),
        handle.am_state.clone(),
        vec![Fault::KillTask { task_type: "worker".into(), index: 0, after_step: 3 }],
    );
    let report = handle.wait(Duration::from_secs(300)).unwrap();
    let records = chaos.join();
    assert_eq!(report.state, AppState::Finished, "{}", report.diagnostics);
    assert_eq!(records.len(), 1);
    assert_eq!(handle.am_state.attempt(), 1, "chief replaced within the attempt");
    assert!(handle.am_state.recoveries() >= 1);
    assert_eq!(handle.am_state.chief_metrics().unwrap().step, 12);

    // The replacement chief probed the PS, found them warm, and did NOT
    // re-seed: still only the initial restore marker.
    let store = CheckpointStore::new(&ckpt);
    let markers = store.restore_markers().unwrap();
    assert_eq!(markers.len(), 1, "replacement chief must not roll training back: {markers:?}");
    let _ = std::fs::remove_dir_all(&ckpt);
}

/// Node loss: kill the node hosting worker:1's container (found
/// dynamically so the test never guesses placement).  Everything that
/// lived there is surgically relaunched on the surviving nodes within
/// the same attempt.
#[test]
fn surgical_node_kill_recovers_in_same_attempt() {
    let Some(dir) = preset_dir() else { return };
    // Node 0 fits only the AM (best-fit placement pins the 512m AM to
    // the 1g node), so the node kill below can never take the AM down.
    let specs = vec![
        NodeSpec::new(0, Resource::new(1024, 2, 0)),
        NodeSpec::new(1, Resource::new(8192, 8, 0)),
        NodeSpec::new(2, Resource::new(8192, 8, 0)),
        NodeSpec::new(3, Resource::new(8192, 8, 0)),
    ];
    let rm = ResourceManager::start(specs, QueueConf::default_only());
    let ckpt = ckpt_dir("nodekill");
    let conf = JobConfBuilder::new("node-kill-surgical")
        .instances("worker", 2)
        .memory("worker", "1g")
        .instances("ps", 1)
        .memory("ps", "1g")
        .train(dir.to_str().unwrap(), "tiny", 10)
        .set("tony.train.checkpoint-dir", ckpt.to_str().unwrap())
        .set("tony.train.checkpoint-every", "3")
        .build();

    let client = TonyClient::new(rm.clone());
    let handle = client.submit(&conf, &dir).unwrap();
    assert!(
        wait_until(Duration::from_secs(120), || {
            handle.am_state.phase() == tony::am::JobPhase::Running
                && handle.am_state.container_map().values().all(|c| c.is_some())
        }),
        "job never reached Running"
    );
    let cid = handle
        .am_state
        .container_map()
        .get(&TaskId::new("worker", 1))
        .copied()
        .flatten()
        .expect("worker:1 has a container");
    let node = rm.container_node(cid).expect("container has a node");
    assert_ne!(node.0, 0, "task containers never fit on the AM node");

    let chaos = ChaosInjector::start(
        rm.clone(),
        handle.am_state.clone(),
        vec![Fault::KillNode { node: node.0, after_step: 2 }],
    );
    let report = handle.wait(Duration::from_secs(300)).unwrap();
    let records = chaos.join();
    assert_eq!(report.state, AppState::Finished, "{}", report.diagnostics);
    assert_eq!(records.len(), 1);
    assert_eq!(rm.alive_node_count(), 3);
    assert_eq!(handle.am_state.attempt(), 1, "node loss handled surgically");
    assert!(handle.am_state.recoveries() >= 1);
    assert_eq!(handle.am_state.chief_metrics().unwrap().step, 10);
    let _ = std::fs::remove_dir_all(&ckpt);
}

/// Regression (registration hang): an executor that launches but wedges
/// before registering used to hang the attempt forever — the launch
/// timeout only fired while containers were still *ungranted*, and the
/// heartbeat staleness check skipped unregistered tasks.  With the
/// registration deadline the attempt must fail promptly.
#[test]
fn wedged_executor_fails_attempt_within_registration_deadline() {
    let Some(dir) = preset_dir() else { return };
    let rm = ResourceManager::start_uniform(3, Resource::new(8192, 8, 0));
    let ckpt = ckpt_dir("wedge");
    let conf = JobConfBuilder::new("wedge")
        .instances("worker", 2)
        .memory("worker", "1g")
        .instances("ps", 1)
        .memory("ps", "1g")
        .train(dir.to_str().unwrap(), "tiny", 4)
        .set("tony.train.checkpoint-dir", ckpt.to_str().unwrap())
        .set("tony.chaos.wedge-preregister", "worker:1")
        .set("tony.task.registration-timeout-ms", "1000")
        .set("tony.application.max-attempts", "1")
        .set("tony.task.max-restarts", "0")
        .build();

    let t0 = Instant::now();
    let client = TonyClient::new(rm.clone());
    let handle = client.submit(&conf, &dir).unwrap();
    let report = handle.wait(Duration::from_secs(120)).unwrap();
    assert_eq!(report.state, AppState::Failed, "{}", report.diagnostics);
    assert!(
        report.diagnostics.contains("never registered"),
        "diagnostics must name the registration deadline: {}",
        report.diagnostics
    );
    assert!(
        t0.elapsed() < Duration::from_secs(60),
        "attempt must fail within the deadline, took {:?}",
        t0.elapsed()
    );
    let _ = std::fs::remove_dir_all(&ckpt);
}

/// Regression (container leak): a granted-but-never-started container
/// handed back through the allocate release list must return its node
/// capacity immediately — this is the release path `run_attempt` now
/// uses for grants that match no task.  Asserted via
/// `ResourceManager::node_usage` *while the application is still
/// running*, because app teardown would mask a leak.
#[test]
fn released_unstarted_grant_returns_node_capacity() {
    let rm = ResourceManager::start_uniform(2, Resource::new(4096, 4, 0));
    let total_cap: u64 = rm.node_usage().iter().map(|(_, _, cap)| cap.memory_mb).sum();

    let (started_tx, started_rx) = mpsc::channel();
    let id = rm
        .submit_application(
            SubmissionContext {
                name: "leak-regression".into(),
                queue: "default".into(),
                am_resource: Resource::new(512, 1, 0),
            },
            Box::new(move |cctx| {
                // Park: the test drives the AM protocol from outside.
                let _ = started_tx.send(());
                while !cctx.killed() {
                    tony::util::clock::real_sleep(Duration::from_millis(5));
                }
                0
            }),
        )
        .unwrap();
    started_rx
        .recv_timeout(Duration::from_secs(10))
        .expect("AM container started");
    rm.register_am(id, None).unwrap();

    // Ask for one task container and wait for the grant.
    let asks = vec![ContainerRequest::new(Resource::new(1024, 1, 0), 1).with_priority(7)];
    let mut asked = false;
    let mut grant = None;
    let deadline = Instant::now() + Duration::from_secs(10);
    while grant.is_none() && Instant::now() < deadline {
        let resp = rm.allocate(id, if asked { &[] } else { &asks }, &[]).unwrap();
        asked = true;
        grant = resp.allocated.into_iter().next();
        tony::util::clock::real_sleep(Duration::from_millis(5));
    }
    let grant = grant.expect("grant arrived");

    // Capacity is reserved from grant time (AM 512 + task 1024).
    let free: u64 = rm.node_usage().iter().map(|(_, f, _)| f.memory_mb).sum();
    assert_eq!(free, total_cap - 512 - 1024);

    // Release the unstarted grant via the allocate release list (the
    // leak-fix path) — capacity must come back while the app still runs.
    rm.allocate(id, &[], &[grant.id]).unwrap();
    let free: u64 = rm.node_usage().iter().map(|(_, f, _)| f.memory_mb).sum();
    assert_eq!(free, total_cap - 512, "released grant must restore node capacity");

    rm.kill_application(id);
    assert_eq!(rm.app_report(id).unwrap().state, AppState::Killed);
}
