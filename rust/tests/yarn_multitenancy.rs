//! YARN-level integration: multiple concurrent applications sharing the
//! simulated cluster — queue isolation, queuing under contention, and
//! capacity conservation across interleaved lifecycles.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use tony::util::ids::ApplicationId;
use tony::yarn::{
    AppState, ContainerRequest, NodeSpec, QueueConf, Resource, ResourceManager,
    SubmissionContext,
};

/// An AM that requests `n` containers of `shape`, runs trivial tasks in
/// them, waits for all to succeed, then finishes.
fn simple_am(
    rm: Arc<ResourceManager>,
    seq: u64,
    n: u32,
    shape: Resource,
    task_ms: u64,
) -> tony::yarn::container::Launchable {
    Box::new(move |_ctx| {
        let app = ApplicationId { cluster_ts: rm.cluster_ts, seq };
        rm.register_am(app, None).unwrap();
        let asks = vec![ContainerRequest::new(shape, n)];
        let mut asked = false;
        let mut done = 0u32;
        while done < n {
            let resp = rm.allocate(app, if asked { &[] } else { &asks }, &[]).unwrap();
            asked = true;
            for c in resp.allocated {
                rm.start_container(
                    &c,
                    BTreeMap::new(),
                    Box::new(move |ctx| {
                        let deadline =
                            std::time::Instant::now() + Duration::from_millis(task_ms);
                        while std::time::Instant::now() < deadline {
                            if ctx.killed() {
                                return 1;
                            }
                            tony::util::clock::real_sleep(Duration::from_millis(2));
                        }
                        0
                    }),
                )
                .unwrap();
            }
            done += resp.completed.iter().filter(|s| s.exit.is_success()).count() as u32;
            tony::util::clock::real_sleep(Duration::from_millis(5));
        }
        rm.finish_application(app, true, "done");
        0
    })
}

#[test]
fn contending_apps_all_finish_by_queuing() {
    // 2 nodes x 4 GiB; 4 apps each wanting 2x 2 GiB tasks + small AM ->
    // heavy contention; everything must still finish.
    let rm = ResourceManager::start_uniform(2, Resource::new(4096, 8, 0));
    let mut ids = Vec::new();
    for i in 0..4u64 {
        let am = simple_am(rm.clone(), i + 1, 2, Resource::new(1536, 1, 0), 80);
        let id = rm
            .submit_application(
                SubmissionContext {
                    name: format!("job{i}"),
                    queue: "default".into(),
                    am_resource: Resource::new(256, 1, 0),
                },
                am,
            )
            .unwrap();
        ids.push(id);
    }
    for id in ids {
        let report = rm.wait_for_completion(id, Duration::from_secs(30)).unwrap();
        assert_eq!(report.state, AppState::Finished, "{}", report.diagnostics);
    }
    for (_, free, cap) in rm.node_usage() {
        assert_eq!(free, cap, "capacity leak after 4 concurrent apps");
    }
}

#[test]
fn queue_isolation_under_pressure() {
    // prod gets 75%, adhoc 25% with a hard 30% ceiling: a greedy adhoc
    // app must never push the prod app out.
    let queues = vec![
        QueueConf::new("prod", 0.75, 1.0),
        QueueConf::new("adhoc", 0.25, 0.3),
    ];
    let specs = vec![
        NodeSpec::new(0, Resource::new(8192, 16, 0)),
        NodeSpec::new(1, Resource::new(8192, 16, 0)),
    ];
    let rm = ResourceManager::start(specs, queues);

    let greedy = simple_am(rm.clone(), 1, 12, Resource::new(1024, 1, 0), 150);
    let greedy_id = rm
        .submit_application(
            SubmissionContext {
                name: "greedy".into(),
                queue: "adhoc".into(),
                am_resource: Resource::new(256, 1, 0),
            },
            greedy,
        )
        .unwrap();
    tony::util::clock::real_sleep(Duration::from_millis(30));
    let prod = simple_am(rm.clone(), 2, 8, Resource::new(1024, 1, 0), 80);
    let prod_id = rm
        .submit_application(
            SubmissionContext {
                name: "prod".into(),
                queue: "prod".into(),
                am_resource: Resource::new(256, 1, 0),
            },
            prod,
        )
        .unwrap();

    // While both run, adhoc usage must respect its 30% ceiling.
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    let total = Resource::new(16384, 32, 0);
    let mut prod_done = false;
    while std::time::Instant::now() < deadline {
        for (q, used) in rm.queue_usage() {
            if q == "adhoc" {
                let share = used.dominant_share(&total);
                assert!(share <= 0.30 + 1e-6, "adhoc at {share} > ceiling");
            }
        }
        if rm.app_report(prod_id).unwrap().state.is_terminal() {
            prod_done = true;
            break;
        }
        tony::util::clock::real_sleep(Duration::from_millis(10));
    }
    assert!(prod_done, "prod app starved by greedy adhoc app");
    assert_eq!(rm.app_report(prod_id).unwrap().state, AppState::Finished);
    let greedy_report = rm.wait_for_completion(greedy_id, Duration::from_secs(60)).unwrap();
    assert_eq!(greedy_report.state, AppState::Finished);
}

#[test]
fn client_kill_releases_everything() {
    let rm = ResourceManager::start_uniform(2, Resource::new(4096, 8, 0));
    let am = simple_am(rm.clone(), 1, 4, Resource::new(1024, 1, 0), 60_000); // long tasks
    let id = rm
        .submit_application(
            SubmissionContext {
                name: "victim".into(),
                queue: "default".into(),
                am_resource: Resource::new(256, 1, 0),
            },
            am,
        )
        .unwrap();
    // Let it get some containers running.
    tony::util::clock::real_sleep(Duration::from_millis(200));
    rm.kill_application(id);
    assert_eq!(rm.app_report(id).unwrap().state, AppState::Killed);
    // All containers die and capacity returns.
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        let clean = rm.node_usage().iter().all(|(_, free, cap)| free == cap);
        if clean {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "capacity not returned after kill");
        tony::util::clock::real_sleep(Duration::from_millis(20));
    }
}
