//! Property tests for the gateway WAL (`rust/src/gateway/wal.rs`) and
//! its replay state machine (`rust/src/gateway/recovery.rs`):
//!
//! 1. the record codec round-trips arbitrary records through the frame
//!    format;
//! 2. decoding tolerates torn/truncated/corrupt tails — it never panics
//!    and only ever drops a *suffix* (records past the damage);
//! 3. compaction is lossless: snapshot-at-k + tail replay produces the
//!    same live-job table as replaying the full log, for every cut point
//!    and even when snapshot and tail overlap (records re-applied).

use tony::gateway::wal::{decode_stream, frame, WalRecord, MAGIC};
use tony::gateway::RecoveredState;
use tony::proptest::{check, Gen};
use tony::{prop_assert, prop_assert_eq};

/// A realistic record sequence: ids are minted monotonically and never
/// reused, and per job the order is Admitted → (Started | KillRequested)*
/// → Terminal — exactly what the submit-path WAL ordering guarantees
/// (the admission record is acked before a job can produce any other).
fn gen_sequence(g: &mut Gen) -> Vec<WalRecord> {
    let mut next_id = 1u64;
    let mut live: Vec<u64> = Vec::new();
    let mut recs = Vec::new();
    for _ in 0..g.len(40) {
        if live.is_empty() || g.chance(0.4) {
            let id = next_id;
            next_id += 1;
            recs.push(WalRecord::Admitted {
                id,
                user: g.ident(8),
                name: g.ident(10),
                queue: g.ident(6),
                priority: g.range(0, 10) as u8,
                conf_xml: format!(
                    "<configuration><property><name>tony.application.name</name>\
                     <value>{}</value></property></configuration>",
                    g.ident(8)
                ),
            });
            live.push(id);
        } else {
            let idx = g.usize_up_to(live.len() - 1);
            let id = live[idx];
            match g.usize_up_to(2) {
                0 => recs.push(WalRecord::Started {
                    id,
                    app_id: format!("application_{}_{:04}", g.range(1, 99), g.range(1, 50)),
                    attempt: g.range(1, 3) as u32,
                }),
                1 => recs.push(WalRecord::KillRequested { id }),
                _ => {
                    recs.push(WalRecord::Terminal {
                        id,
                        state: (*g.pick(&["FINISHED", "FAILED", "KILLED"])).to_string(),
                        detail: g.string(12),
                        wall_ms: g.range(0, 10_000),
                    });
                    live.swap_remove(idx);
                }
            }
        }
    }
    recs
}

fn log_bytes(recs: &[WalRecord]) -> Vec<u8> {
    let mut bytes = MAGIC.to_vec();
    for r in recs {
        bytes.extend_from_slice(&frame(r.to_json().render().as_bytes()));
    }
    bytes
}

#[test]
fn record_codec_round_trips() {
    check("wal codec round trip", 200, |g| {
        let recs = gen_sequence(g);
        for r in &recs {
            let back = WalRecord::from_json(&r.to_json()).map_err(|e| format!("{e:#}"))?;
            prop_assert_eq!(&back, r);
        }
        let (decoded, clean) = decode_stream(&log_bytes(&recs));
        prop_assert!(clean, "untampered stream must decode clean");
        prop_assert_eq!(decoded, recs);
        Ok(())
    });
}

#[test]
fn torn_or_corrupt_tails_only_drop_a_suffix() {
    check("wal torn tail tolerance", 300, |g| {
        let recs = gen_sequence(g);
        let bytes = log_bytes(&recs);
        let mutated = if g.bool() {
            // Truncate anywhere, including inside the magic or a header.
            bytes[..g.usize_up_to(bytes.len())].to_vec()
        } else {
            // Flip one byte anywhere.
            let mut b = bytes.clone();
            let i = g.usize_up_to(b.len() - 1);
            b[i] ^= 1 << g.usize_up_to(7);
            b
        };
        // Must not panic on arbitrary damage, and whatever decodes must
        // be a prefix of the original sequence: damage never reorders,
        // duplicates, or invents records.
        let (decoded, _clean) = decode_stream(&mutated);
        prop_assert!(
            decoded.len() <= recs.len(),
            "decoded more records than were written ({} > {})",
            decoded.len(),
            recs.len()
        );
        prop_assert_eq!(decoded.as_slice(), &recs[..decoded.len()]);
        Ok(())
    });
}

#[test]
fn snapshot_plus_tail_replay_equals_full_replay() {
    check("wal compaction losslessness", 150, |g| {
        let recs = gen_sequence(g);
        let mut full = RecoveredState::new();
        for r in &recs {
            full.apply(r);
        }
        // Every cut point: snapshot the prefix, round-trip it through the
        // snapshot JSON (what the disk actually holds), replay the tail.
        for k in 0..=recs.len() {
            let mut prefix = RecoveredState::new();
            for r in &recs[..k] {
                prefix.apply(r);
            }
            let mut st = RecoveredState::from_snapshot_json(&prefix.to_snapshot_json())
                .map_err(|e| format!("cut {k}: {e:#}"))?;
            for r in &recs[k..] {
                st.apply(r);
            }
            prop_assert_eq!(&st.jobs, &full.jobs);
            prop_assert_eq!(st.next_id, full.next_id);
        }
        // Overlapping tail (snapshot at k, tail from j <= k): epoch
        // rotation intentionally lets the retiring log overlap the
        // snapshot, so re-application must be idempotent.
        if !recs.is_empty() {
            let k = g.usize_up_to(recs.len());
            let j = g.usize_up_to(k);
            let mut prefix = RecoveredState::new();
            for r in &recs[..k] {
                prefix.apply(r);
            }
            let mut st = RecoveredState::from_snapshot_json(&prefix.to_snapshot_json())
                .map_err(|e| format!("overlap {j}..{k}: {e:#}"))?;
            for r in &recs[j..] {
                st.apply(r);
            }
            prop_assert_eq!(&st.jobs, &full.jobs);
            prop_assert_eq!(st.next_id, full.next_id);
        }
        Ok(())
    });
}
