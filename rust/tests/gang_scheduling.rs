//! RM-level gang scheduling + capacity preemption integration tests
//! (docs/SCHEDULING.md): all-or-nothing waves under contention, the
//! preemption lifecycle end to end through real NM container kills, and
//! the unknown-queue remap regression.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use tony::util::clock::SystemClock;
use tony::util::event::WakeupBus;
use tony::util::ids::{ApplicationId, ContainerId};
use tony::yarn::{
    AppSchedState, AppState, ContainerCtx, ContainerRequest, NodeSpec, QueueConf, Resource,
    ResourceManager, RmConf, SchedulerConf, SubmissionContext,
};

/// Task body that blocks (event-driven) until its container is killed.
fn run_until_killed(ctx: ContainerCtx) -> i32 {
    let clock = SystemClock::new();
    let bus = Arc::new(WakeupBus::new());
    ctx.kill_switch().register(&bus);
    while !ctx.killed() {
        bus.wait_until(&clock, clock.now_ms() + 10_000);
    }
    0
}

fn submission(name: &str, queue: &str, am_mb: u64) -> SubmissionContext {
    SubmissionContext {
        name: name.into(),
        queue: queue.into(),
        am_resource: Resource::new(am_mb, 1, 0),
    }
}

/// Two jobs whose gangs each need most of the cluster: gang mode places
/// job A's wave whole, holds job B whole (`WAITING_FOR_GANG`, with a
/// reservation instead of a partial allocation), and lands B's wave the
/// moment A's containers drain.  This is the deadlock-free schedule the
/// legacy per-container mode cannot produce — see
/// `interleaved_singles_deadlock_where_gangs_do_not` in
/// `yarn::scheduler` and `bench_contention` for the A/B contrast.
#[test]
fn contending_gangs_serialize_instead_of_deadlocking() {
    let rm = ResourceManager::start(
        vec![
            NodeSpec::new(0, Resource::new(2048, 4, 0)),
            NodeSpec::new(1, Resource::new(2048, 4, 0)),
        ],
        QueueConf::default_only(),
    );

    let (holding_tx, holding_rx) = mpsc::channel::<Vec<ContainerId>>();
    let (release_tx, release_rx) = mpsc::channel::<()>();
    let rm2 = rm.clone();
    let a = rm
        .submit_application(
            submission("gang-a", "default", 256),
            Box::new(move |_| {
                let app = ApplicationId { cluster_ts: rm2.cluster_ts, seq: 1 };
                rm2.register_am(app, None).unwrap();
                let bus = WakeupBus::for_clock(rm2.clock());
                rm2.register_am_waker(app, &bus);
                let clock = rm2.clock().clone();
                let asks = vec![ContainerRequest::new(Resource::new(1536, 1, 0), 2)];
                let mut held = Vec::new();
                let mut asked = false;
                while held.len() < 2 {
                    let send: &[ContainerRequest] = if asked { &[] } else { &asks };
                    let resp = rm2.allocate(app, send, &[]).unwrap();
                    asked = true;
                    for c in resp.allocated {
                        rm2.start_container(&c, BTreeMap::new(), Box::new(run_until_killed))
                            .unwrap();
                        held.push(c.id);
                    }
                    if held.len() < 2 {
                        bus.wait_until(&*clock, clock.now_ms() + 5_000);
                    }
                }
                holding_tx.send(held.clone()).unwrap();
                release_rx.recv().unwrap();
                let mut done = 0;
                let mut released = false;
                while done < 2 {
                    let rel: &[ContainerId] = if released { &[] } else { &held };
                    let resp = rm2.allocate(app, &[], rel).unwrap();
                    released = true;
                    done += resp.completed.len();
                    if done < 2 {
                        bus.wait_until(&*clock, clock.now_ms() + 5_000);
                    }
                }
                rm2.finish_application(app, true, "released the cluster");
                0
            }),
        )
        .unwrap();

    let held = holding_rx
        .recv_timeout(Duration::from_secs(30))
        .expect("job A never acquired its gang");
    assert_eq!(held.len(), 2, "A's whole wave placed at once");

    let (asked_tx, asked_rx) = mpsc::channel::<()>();
    let rm3 = rm.clone();
    let b = rm
        .submit_application(
            submission("gang-b", "default", 256),
            Box::new(move |_| {
                let app = ApplicationId { cluster_ts: rm3.cluster_ts, seq: 2 };
                rm3.register_am(app, None).unwrap();
                let bus = WakeupBus::for_clock(rm3.clock());
                rm3.register_am_waker(app, &bus);
                let clock = rm3.clock().clone();
                let asks = vec![ContainerRequest::new(Resource::new(1536, 1, 0), 2)];
                let resp = rm3.allocate(app, &asks, &[]).unwrap();
                assert!(
                    resp.allocated.is_empty(),
                    "gang must not place partially while A holds the cluster"
                );
                asked_tx.send(()).unwrap();
                let mut done = 0;
                while done < 2 {
                    let resp = rm3.allocate(app, &[], &[]).unwrap();
                    for c in resp.allocated {
                        rm3.start_container(&c, BTreeMap::new(), Box::new(|_| 0)).unwrap();
                    }
                    done += resp.completed.iter().filter(|s| s.exit.is_success()).count();
                    if done < 2 {
                        bus.wait_until(&*clock, clock.now_ms() + 5_000);
                    }
                }
                rm3.finish_application(app, true, "gang ran after A drained");
                0
            }),
        )
        .unwrap();

    asked_rx
        .recv_timeout(Duration::from_secs(30))
        .expect("job B never reached its first allocate");
    assert_eq!(
        rm.app_sched_state(b),
        AppSchedState::WaitingForGang,
        "B waits whole, holding no partial allocation"
    );

    release_tx.send(()).unwrap();
    let ra = rm.wait_for_completion(a, Duration::from_secs(60)).unwrap();
    let rb = rm.wait_for_completion(b, Duration::from_secs(60)).unwrap();
    assert_eq!(ra.state, AppState::Finished, "{}", ra.diagnostics);
    assert_eq!(rb.state, AppState::Finished, "{}", rb.diagnostics);
    assert_eq!(rm.app_sched_state(b), AppSchedState::Normal);
    assert!(rm.scheduler_stats().gangs_placed >= 2);
    for (_, free, cap) in rm.node_usage() {
        assert_eq!(free, cap, "capacity leaked");
    }
}

/// The full preemption lifecycle: a queue bursting past its guarantee is
/// clawed back — notices through the allocate response, real container
/// kills reported as `Preempted`, the starved queue's gang landing on
/// the freed nodes — within one planning round (+ zero grace here).
#[test]
fn preemption_restores_starved_queue_to_its_guarantee() {
    let queues = vec![
        QueueConf::new("ml", 0.75, 1.0),
        QueueConf::new("etl", 0.25, 1.0),
    ];
    let sched = SchedulerConf {
        gang_mode: true,
        reservation_limit: 2,
        preemption: true,
        preemption_grace_ms: 0,
        preemption_max_victims: 8,
        ..Default::default()
    };
    let rm = ResourceManager::start_with(
        vec![
            NodeSpec::new(0, Resource::new(4096, 8, 0)),
            NodeSpec::new(1, Resource::new(4096, 8, 0)),
        ],
        queues,
        RmConf { scheduler: sched, ..Default::default() },
    );

    // etl bursts to ~78% of the cluster (guarantee: 25%).
    let (holding_tx, holding_rx) = mpsc::channel::<()>();
    let (preempted_tx, preempted_rx) = mpsc::channel::<u64>();
    let rm2 = rm.clone();
    let e = rm
        .submit_application(
            submission("etl-burst", "etl", 256),
            Box::new(move |_| {
                let app = ApplicationId { cluster_ts: rm2.cluster_ts, seq: 1 };
                rm2.register_am(app, None).unwrap();
                let bus = WakeupBus::for_clock(rm2.clock());
                rm2.register_am_waker(app, &bus);
                let clock = rm2.clock().clone();
                let asks = vec![ContainerRequest::new(Resource::new(1024, 1, 0), 6)];
                let mut launched = 0;
                let mut asked = false;
                while launched < 6 {
                    let send: &[ContainerRequest] = if asked { &[] } else { &asks };
                    let resp = rm2.allocate(app, send, &[]).unwrap();
                    asked = true;
                    for c in resp.allocated {
                        rm2.start_container(&c, BTreeMap::new(), Box::new(run_until_killed))
                            .unwrap();
                        launched += 1;
                    }
                    if launched < 6 {
                        bus.wait_until(&*clock, clock.now_ms() + 5_000);
                    }
                }
                holding_tx.send(()).unwrap();
                // Serve the allocate protocol until the preemption round
                // lands fully: notices first, `Preempted` exits after.
                let mut notices = 0u64;
                let mut preempted = 0u64;
                loop {
                    let resp = rm2.allocate(app, &[], &[]).unwrap();
                    notices += resp.preempt_notices.len() as u64;
                    preempted += resp
                        .completed
                        .iter()
                        .filter(|s| s.exit == tony::yarn::ExitStatus::Preempted)
                        .count() as u64;
                    if notices > 0 && preempted >= notices {
                        break;
                    }
                    bus.wait_until(&*clock, clock.now_ms() + 5_000);
                }
                preempted_tx.send(preempted).unwrap();
                rm2.finish_application(app, true, "survived preemption");
                0
            }),
        )
        .unwrap();
    holding_rx
        .recv_timeout(Duration::from_secs(30))
        .expect("etl job never filled the cluster");

    // ml (starved, well under its 75% guarantee) asks a 3-container gang.
    let rm3 = rm.clone();
    let m = rm
        .submit_application(
            submission("ml-gang", "ml", 256),
            Box::new(move |_| {
                let app = ApplicationId { cluster_ts: rm3.cluster_ts, seq: 2 };
                rm3.register_am(app, None).unwrap();
                let bus = WakeupBus::for_clock(rm3.clock());
                rm3.register_am_waker(app, &bus);
                let clock = rm3.clock().clone();
                let asks = vec![ContainerRequest::new(Resource::new(1024, 1, 0), 3)];
                let mut asked = false;
                let mut done = 0;
                while done < 3 {
                    let send: &[ContainerRequest] = if asked { &[] } else { &asks };
                    let resp = rm3.allocate(app, send, &[]).unwrap();
                    asked = true;
                    for c in resp.allocated {
                        rm3.start_container(&c, BTreeMap::new(), Box::new(|_| 0)).unwrap();
                    }
                    done += resp.completed.iter().filter(|s| s.exit.is_success()).count();
                    if done < 3 {
                        bus.wait_until(&*clock, clock.now_ms() + 5_000);
                    }
                }
                rm3.finish_application(app, true, "gang ran on preempted capacity");
                0
            }),
        )
        .unwrap();

    let preempted = preempted_rx
        .recv_timeout(Duration::from_secs(60))
        .expect("etl job never observed its preempted exits");
    assert!(preempted >= 1, "at least one container must have been preempted");

    let rm_report = rm.wait_for_completion(m, Duration::from_secs(60)).unwrap();
    assert_eq!(rm_report.state, AppState::Finished, "{}", rm_report.diagnostics);
    let re = rm.wait_for_completion(e, Duration::from_secs(60)).unwrap();
    assert_eq!(re.state, AppState::Finished, "{}", re.diagnostics);

    let stats = rm.scheduler_stats();
    assert_eq!(stats.preemption_rounds, 1, "one planning round must suffice");
    assert_eq!(stats.preemptions, preempted, "RM stats agree with observed exits");
    let etl = rm
        .queue_stats()
        .into_iter()
        .find(|q| &*q.name == "etl")
        .unwrap();
    assert_eq!(etl.preemptions, preempted, "per-queue victim counter");
    for (_, free, cap) in rm.node_usage() {
        assert_eq!(free, cap, "capacity leaked");
    }
}

/// Regression: an app submitted to an unknown queue used to be silently
/// remapped with no trace.  It still runs (on the fallback queue) but
/// the remap is now counted in scheduler stats.
#[test]
fn unknown_queue_submission_runs_on_fallback_and_is_counted() {
    let rm = ResourceManager::start_uniform(2, Resource::new(2048, 4, 0));
    let rm2 = rm.clone();
    let id = rm
        .submit_application(
            submission("lost-queue", "no-such-queue", 256),
            Box::new(move |_| {
                let app = ApplicationId { cluster_ts: rm2.cluster_ts, seq: 1 };
                rm2.register_am(app, None).unwrap();
                rm2.finish_application(app, true, "ran despite the bogus queue");
                0
            }),
        )
        .unwrap();
    let report = rm.wait_for_completion(id, Duration::from_secs(30)).unwrap();
    assert_eq!(report.state, AppState::Finished, "{}", report.diagnostics);
    assert!(
        rm.scheduler_stats().unknown_queue_asks >= 1,
        "the remap must be counted, not silent"
    );
}
