//! Deterministic crash-point recovery suite: kill the gateway at every
//! named site in the WAL append/snapshot path (`tony.chaos.crash-point`,
//! see [`tony::chaos::CrashSite`]) under a manual clock, restart it with
//! [`Gateway::recover`], and assert the durability invariant:
//!
//! > every **acked** submission survives; every **unacked** submission is
//! > either absent or re-admitted — never duplicated.
//!
//! The chaos panics are in-process stand-ins for `kill -9`: the armed
//! operation dies mid-flight (caught with `catch_unwind`), the halted
//! gateway writes no further bytes, and recovery sees exactly the disk
//! state a real crash at that instant would leave.  docs/DURABILITY.md
//! catalogs what each site persists.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use tony::chaos::{CrashSite, CRASH_PANIC};
use tony::gateway::{replay_dir, Gateway, GatewayConf, JobState, SubmitOutcome};
use tony::tonyconf::JobConfBuilder;
use tony::util::ids::ApplicationId;
use tony::util::ManualClock;
use tony::xmlconf::Configuration;
use tony::yarn::{NodeSpec, QueueConf, Resource, ResourceManager, RmConf};

/// Suppress the backtrace spew from *expected* injected-crash panics
/// (identified by [`CRASH_PANIC`] in the message); real panics still
/// report through the previous hook.
fn silence_chaos_panics() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let msg = info
                .payload()
                .downcast_ref::<String>()
                .map(String::as_str)
                .or_else(|| info.payload().downcast_ref::<&str>().copied())
                .unwrap_or("");
            if !msg.contains(CRASH_PANIC) {
                prev(info);
            }
        }));
    });
}

/// Drive virtual time forward until `done` flips (same pacing as the
/// event-driven suite: +5 ms virtual every ~0.5 ms real).
fn spawn_clock_driver(
    clock: Arc<ManualClock>,
    done: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        while !done.load(Ordering::Relaxed) {
            clock.advance_ms(5);
            tony::util::clock::real_sleep(Duration::from_micros(500));
        }
    })
}

/// Run `f` with the clock driver running, then stop the driver.
fn drive_while<T>(clock: &Arc<ManualClock>, f: impl FnOnce() -> T) -> T {
    let done = Arc::new(AtomicBool::new(false));
    let driver = spawn_clock_driver(clock.clone(), done.clone());
    let out = f();
    done.store(true, Ordering::Relaxed);
    driver.join().unwrap();
    out
}

/// Real-time watchdog: a stalled recovery path fails within `secs`
/// instead of hanging the suite.
fn with_watchdog<T: Send + 'static>(secs: u64, body: impl FnOnce() -> T + Send + 'static) -> T {
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        let _ = tx.send(body());
    });
    rx.recv_timeout(Duration::from_secs(secs))
        .expect("crash-recovery path stalled (watchdog)")
}

fn manual_rm_sized(clock: &Arc<ManualClock>, nodes: u32, each: Resource) -> Arc<ResourceManager> {
    let specs = (0..nodes).map(|i| NodeSpec::new(i, each)).collect();
    ResourceManager::start_with(
        specs,
        QueueConf::default_only(),
        RmConf { clock: clock.clone(), fallback_tick_ms: 0, ..Default::default() },
    )
}

fn manual_rm(clock: &Arc<ManualClock>, nodes: u32) -> Arc<ResourceManager> {
    manual_rm_sized(clock, nodes, Resource::new(4096, 8, 0))
}

fn temp_base(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "tony-crashtest-{tag}-{}-{}",
        std::process::id(),
        tony::util::ids::next_seq()
    ))
}

fn wal_dir(base: &std::path::Path) -> std::path::PathBuf {
    base.join("wal")
}

/// Gateway conf with the WAL on (fsync'd) — routed through
/// [`GatewayConf::apply_site_conf`] exactly like `tony serve` does —
/// optionally armed with a crash point.
fn gw_conf(base: &std::path::Path, crash: Option<CrashSite>, snapshot_every: u64) -> GatewayConf {
    let mut conf = GatewayConf::new(base.join("artifacts"));
    conf.history_dir = base.join("history");
    conf.workers = 2;
    conf.job_timeout = Duration::from_secs(600); // virtual ms
    let mut site = Configuration::new();
    site.set("tony.wal.enable", "true");
    site.set("tony.wal.dir", wal_dir(base).to_string_lossy().into_owned());
    site.set("tony.wal.snapshot-every", snapshot_every.to_string());
    site.set("tony.wal.fsync", "true");
    if let Some(c) = crash {
        site.set("tony.chaos.crash-point", c.as_str());
    }
    conf.apply_site_conf(&site);
    conf
}

fn job_xml(name: &str, steps: u64) -> Configuration {
    JobConfBuilder::new(name)
        .instances("worker", 1)
        .memory("worker", "512m")
        .instances("ps", 1)
        .memory("ps", "512m")
        .set("tony.am.memory", "256m")
        .set("tony.train.steps", &steps.to_string())
        .set("tony.train.checkpoint-every", "0")
        .set("tony.task.max-missed-heartbeats", "2000")
        .build()
}

fn assert_capacity_restored(rm: &ResourceManager) {
    for (_, free, cap) in rm.node_usage() {
        assert_eq!(free, cap, "capacity leaked");
    }
}

/// `wal-before-fsync`: the process dies having written only half the
/// admission frame.  The submitter was never acked, so the job must be
/// absent after recovery — and the torn tail must not poison new work.
#[test]
fn wal_before_fsync_crash_drops_only_the_unacked_submission() {
    silence_chaos_panics();
    with_watchdog(120, || {
        let clock = ManualClock::shared();
        let rm = manual_rm(&clock, 2);
        let base = temp_base("before-fsync");
        let gw =
            Gateway::start(rm, gw_conf(&base, Some(CrashSite::WalBeforeFsync), 256)).unwrap();
        let g = gw.clone();
        let crashed = catch_unwind(AssertUnwindSafe(move || {
            g.submit_conf("alice", 1, job_xml("doomed", 2))
        }));
        assert!(crashed.is_err(), "armed submit must die at the crash point");
        assert!(gw.is_halted(), "the crash point must halt the gateway");
        gw.simulate_crash(); // release the dead incarnation's workers

        // On disk: a half-written frame.  Replay drops it cleanly.
        let rep = replay_dir(&wal_dir(&base)).unwrap();
        assert!(!rep.clean_tail, "the half-written frame must read as torn");
        assert!(rep.state.jobs.is_empty(), "unacked submission must not survive");

        // Restart against a fresh RM (full process restart).
        let rm2 = manual_rm(&clock, 2);
        let gw2 = Gateway::recover(rm2, gw_conf(&base, None, 256)).unwrap();
        assert_eq!(gw2.live_counts(), (0, 0), "nothing to recover");
        // Recovery's boot snapshot rotated past the torn epoch-0 log.
        assert!(
            !wal_dir(&base).join("wal-0.log").exists(),
            "torn log must be retired by the recovery snapshot"
        );
        let SubmitOutcome::Accepted { id } = gw2.submit_conf("alice", 1, job_xml("fresh", 2))
        else {
            panic!("fresh submit rejected after recovery")
        };
        drive_while(&clock, || {
            assert!(gw2.wait_idle(Duration::from_secs(3000)), "gateway never drained");
        });
        assert_eq!(gw2.job_state(id), Some(JobState::Finished));
        assert_capacity_restored(gw2.rm());
        gw2.shutdown();
        let _ = std::fs::remove_dir_all(&base);
    });
}

/// Shared script for the two "record durable, submitter never acked"
/// sites: recovery must re-admit the job exactly once, it must finish,
/// and its id must never be reused.
fn durable_unacked_case(site: CrashSite, tag: &str) {
    silence_chaos_panics();
    with_watchdog(120, || {
        let clock = ManualClock::shared();
        let rm = manual_rm(&clock, 2);
        let base = temp_base(tag);
        let gw = Gateway::start(rm, gw_conf(&base, Some(site), 256)).unwrap();
        let g = gw.clone();
        let crashed = catch_unwind(AssertUnwindSafe(move || {
            g.submit_conf("alice", 1, job_xml("limbo", 2))
        }));
        assert!(crashed.is_err(), "armed submit must die at {site}");
        gw.simulate_crash();

        // The admission frame is whole and durable even though the
        // submitter never got its ack.
        let rep = replay_dir(&wal_dir(&base)).unwrap();
        assert!(rep.clean_tail, "a fully-synced frame must read clean");
        assert_eq!(rep.state.jobs.len(), 1, "durable admission must replay");
        let limbo = *rep.state.jobs.keys().next().unwrap();

        let rm2 = manual_rm(&clock, 2);
        let gw2 = Gateway::recover(rm2, gw_conf(&base, None, 256)).unwrap();
        let (pending, running) = gw2.live_counts();
        assert_eq!(pending + running, 1, "re-admitted exactly once");
        drive_while(&clock, || {
            assert!(gw2.wait_idle(Duration::from_secs(3000)), "gateway never drained");
        });
        assert_eq!(gw2.job_state(limbo), Some(JobState::Finished), "re-admitted job must run");
        let dups = gw2
            .jobs_json()
            .get("jobs")
            .and_then(|v| v.as_arr())
            .unwrap()
            .iter()
            .filter(|j| j.get("name").and_then(|n| n.as_str()) == Some("limbo"))
            .count();
        assert_eq!(dups, 1, "never duplicated");

        // Acked ids are never reused across restarts.
        let SubmitOutcome::Accepted { id: fresh } = gw2.submit_conf("bob", 1, job_xml("fresh", 2))
        else {
            panic!("fresh submit rejected after recovery")
        };
        assert!(fresh > limbo, "acked ids must never be reused (fresh {fresh} vs {limbo})");
        drive_while(&clock, || {
            assert!(gw2.wait_idle(Duration::from_secs(3000)), "gateway never drained");
        });
        assert_eq!(gw2.job_state(fresh), Some(JobState::Finished));
        assert_capacity_restored(gw2.rm());
        gw2.shutdown();
        let _ = std::fs::remove_dir_all(&base);
    });
}

#[test]
fn wal_after_fsync_crash_readmits_the_durable_submission_once() {
    durable_unacked_case(CrashSite::WalAfterFsync, "after-fsync");
}

#[test]
fn post_admit_pre_ack_crash_readmits_the_durable_submission_once() {
    durable_unacked_case(CrashSite::PostAdmitPreAck, "post-admit");
}

/// Shared script for the two snapshot-path sites: two acked jobs are in
/// flight, the gateway dies inside snapshot compaction, and recovery on
/// the *same* cluster must preserve both (re-attaching to still-live
/// applications rather than launching duplicates).
fn snapshot_crash_case(site: CrashSite, tag: &str) {
    silence_chaos_panics();
    with_watchdog(180, || {
        let clock = ManualClock::shared();
        let rm = manual_rm(&clock, 2);
        let base = temp_base(tag);
        // Huge snapshot-every: the explicit force below is the only
        // snapshot attempt, so the armed site fires deterministically.
        let gw = Gateway::start(rm.clone(), gw_conf(&base, Some(site), 1_000_000)).unwrap();
        drive_while(&clock, || {
            let SubmitOutcome::Accepted { id: a } =
                gw.submit_conf("alice", 2, job_xml("acked-a", 40))
            else {
                panic!("submit a rejected")
            };
            let SubmitOutcome::Accepted { id: b } = gw.submit_conf("bob", 1, job_xml("acked-b", 40))
            else {
                panic!("submit b rejected")
            };
            // Wait until each job's fate is WAL-visible beyond admission
            // (Started or Terminal durable) so the crash window is
            // deterministic: no application can be mid-launch with its
            // `Started` record still in flight.
            let deadline = Instant::now() + Duration::from_secs(60);
            loop {
                let rep = replay_dir(&wal_dir(&base)).unwrap();
                let settled = [a, b].iter().all(|id| {
                    rep.state.jobs.get(id).map(|j| j.running).unwrap_or(false)
                        || rep.state.completed.contains_key(id)
                });
                if settled {
                    break;
                }
                assert!(Instant::now() < deadline, "jobs never started: {:?}", rep.state);
                tony::util::clock::real_sleep(Duration::from_millis(10));
            }

            let crashed = catch_unwind(AssertUnwindSafe(|| gw.force_snapshot()));
            assert!(crashed.is_err(), "armed snapshot must die at {site}");
            gw.simulate_crash();

            // No snapshot was published; both acked jobs replay from the
            // log chain alone, and the crash debris is a lone temp file.
            assert!(!wal_dir(&base).join("snapshot.json").exists(), "rename must not happen");
            let rep = replay_dir(&wal_dir(&base)).unwrap();
            assert!(!rep.had_snapshot);
            assert!(rep.clean_tail, "the append path was not involved in this crash");
            for id in [a, b] {
                assert!(
                    rep.state.jobs.contains_key(&id) || rep.state.completed.contains_key(&id),
                    "acked submission {id} must survive: {:?}",
                    rep.state
                );
            }

            // Recover on the SAME cluster: live applications re-attach.
            let gw2 = Gateway::recover(rm.clone(), gw_conf(&base, None, 256)).unwrap();
            assert!(
                wal_dir(&base).join("snapshot.json").exists(),
                "recovery's first act is a fresh snapshot"
            );
            let leftovers: Vec<String> = std::fs::read_dir(wal_dir(&base))
                .unwrap()
                .flatten()
                .map(|e| e.file_name().to_string_lossy().into_owned())
                .filter(|n| n.ends_with(".tmp"))
                .collect();
            assert!(leftovers.is_empty(), "orphaned temp files must be swept: {leftovers:?}");

            assert!(gw2.wait_idle(Duration::from_secs(3000)), "recovered gateway never drained");
            for id in [a, b] {
                match gw2.job_state(id) {
                    Some(state) => assert_eq!(state, JobState::Finished, "job {id}"),
                    // Terminalized before the crash: replay tombstones it
                    // instead of resurrecting it.
                    None => assert_eq!(
                        rep.state.completed.get(&id).map(String::as_str),
                        Some("FINISHED"),
                        "job {id} neither recovered nor tombstoned"
                    ),
                }
            }
            // Exactly one history record per application, however each
            // job's completion was observed (old worker or re-attach
            // monitor — both key the same application id).
            assert_eq!(gw2.history().list().unwrap().len(), 2);

            let SubmitOutcome::Accepted { id: fresh } =
                gw2.submit_conf("carol", 1, job_xml("fresh", 2))
            else {
                panic!("fresh submit rejected after recovery")
            };
            assert!(fresh > a.max(b), "acked ids must never be reused");
            assert!(gw2.wait_idle(Duration::from_secs(3000)), "gateway never drained");
            assert_eq!(gw2.job_state(fresh), Some(JobState::Finished));
            assert_capacity_restored(gw2.rm());
            gw2.shutdown();
        });
        let _ = std::fs::remove_dir_all(&base);
    });
}

#[test]
fn mid_snapshot_crash_preserves_every_acked_job() {
    snapshot_crash_case(CrashSite::MidSnapshot, "mid-snapshot");
}

#[test]
fn before_rename_crash_preserves_every_acked_job() {
    snapshot_crash_case(CrashSite::BeforeRename, "before-rename");
}

/// Kill-and-restart mid-allocate-wave: the gateway dies while a job's
/// gang is WAITING_FOR_GANG at the scheduler.  Recovery must re-attach
/// to the *same* application (no duplicate containers), surface the gang
/// standing through the new gateway, and let the job run to completion
/// once capacity frees up.
#[test]
fn crash_mid_allocate_wave_reattaches_the_waiting_gang() {
    silence_chaos_panics();
    with_watchdog(180, || {
        let clock = ManualClock::shared();
        // One small node: the hog (AM 256 + worker 512 + ps 512) leaves
        // 768 MB — enough for the blocked job's AM but not its gang.
        let rm = manual_rm_sized(&clock, 1, Resource::new(2048, 8, 0));
        let base = temp_base("midwave");
        let gw = Gateway::start(rm.clone(), gw_conf(&base, None, 256)).unwrap();
        drive_while(&clock, || {
            let SubmitOutcome::Accepted { id: hog } =
                gw.submit_conf("alice", 5, job_xml("hog", 50_000))
            else {
                panic!("hog rejected")
            };
            let deadline = Instant::now() + Duration::from_secs(60);
            loop {
                let free = rm.node_usage()[0].1.memory_mb;
                if free <= 768 {
                    break;
                }
                assert!(Instant::now() < deadline, "hog never placed (free {free} MB)");
                tony::util::clock::real_sleep(Duration::from_millis(20));
            }

            let SubmitOutcome::Accepted { id: blocked } =
                gw.submit_conf("bob", 1, job_xml("blocked", 2))
            else {
                panic!("blocked job rejected")
            };
            let deadline = Instant::now() + Duration::from_secs(60);
            let app_b = loop {
                let waiting = gw.job_json(blocked).and_then(|j| {
                    (j.get("sched_state").and_then(|s| s.as_str()) == Some("WAITING_FOR_GANG"))
                        .then(|| j.get("app_id").and_then(|a| a.as_str()).map(str::to_string))
                        .flatten()
                });
                if let Some(app) = waiting {
                    break ApplicationId::parse(&app).expect("app id parses");
                }
                assert!(Instant::now() < deadline, "blocked job never reached WAITING_FOR_GANG");
                tony::util::clock::real_sleep(Duration::from_millis(20));
            };

            // The job table learns the app id a moment before the
            // `Started` record is durable; wait for the WAL to catch up
            // so recovery is guaranteed to re-attach rather than racing
            // into a relaunch of a still-live application.
            let deadline = Instant::now() + Duration::from_secs(60);
            loop {
                let rep = replay_dir(&wal_dir(&base)).unwrap();
                if [hog, blocked]
                    .iter()
                    .all(|id| rep.state.jobs.get(id).map(|j| j.running).unwrap_or(false))
                {
                    break;
                }
                assert!(Instant::now() < deadline, "Started records never durable");
                tony::util::clock::real_sleep(Duration::from_millis(10));
            }

            // kill -9 mid-wave, then restart on the same cluster.
            gw.simulate_crash();
            let gw2 = Gateway::recover(rm.clone(), gw_conf(&base, None, 256)).unwrap();

            let j = gw2.job_json(blocked).expect("blocked job recovered");
            assert_eq!(j.get("state").and_then(|s| s.as_str()), Some("RUNNING"));
            assert_eq!(
                j.get("app_id").and_then(|a| a.as_str()),
                Some(app_b.to_string().as_str()),
                "must re-attach to the same application, not launch a duplicate"
            );
            assert_eq!(
                j.get("sched_state").and_then(|s| s.as_str()),
                Some("WAITING_FOR_GANG"),
                "gang standing must survive the restart: {}",
                j.render_pretty()
            );
            assert!(
                j.get("detail").and_then(|d| d.as_str()).unwrap_or("").contains("re-attached"),
                "detail must say re-attached: {}",
                j.render_pretty()
            );
            let njobs = gw2.jobs_json().get("jobs").and_then(|v| v.as_arr()).unwrap().len();
            assert_eq!(njobs, 2, "exactly the two recovered jobs, no duplicates");

            // Free the node through the NEW gateway: the hog dies, the
            // blocked gang places, everything settles.
            let _ = gw2.kill(hog);
            assert!(gw2.wait_idle(Duration::from_secs(3000)), "recovered gateway never drained");
            assert_eq!(gw2.job_state(blocked), Some(JobState::Finished));
            assert_eq!(gw2.job_state(hog), Some(JobState::Killed));
            assert_capacity_restored(&rm);
            gw2.shutdown();
        });
        let _ = std::fs::remove_dir_all(&base);
    });
}

/// `tony.wal.*` and `tony.chaos.crash-point` route through
/// [`GatewayConf::apply_site_conf`] (the same path `tony serve` uses).
#[test]
fn site_conf_routes_wal_and_chaos_keys() {
    let mut site = Configuration::new();
    site.set("tony.wal.enable", "true");
    site.set("tony.wal.dir", "/tmp/tony-wal-conf-test");
    site.set("tony.wal.snapshot-every", "17");
    site.set("tony.wal.fsync", "false");
    site.set("tony.chaos.crash-point", "mid-snapshot");
    let mut conf = GatewayConf::new(std::env::temp_dir().join("tony-crashconf-artifacts"));
    conf.apply_site_conf(&site);
    assert!(conf.wal.enable);
    assert_eq!(conf.wal.dir, std::path::PathBuf::from("/tmp/tony-wal-conf-test"));
    assert_eq!(conf.wal.snapshot_every, 17);
    assert!(!conf.wal.fsync);
    assert_eq!(conf.crash_point, Some(CrashSite::MidSnapshot));
    for site in CrashSite::ALL {
        assert_eq!(CrashSite::parse(site.as_str()), Some(site), "{site} must round-trip");
    }

    // Unknown crash-point values are tolerated (warn, stay unarmed) —
    // chaos keys must never fail a real boot.
    let mut site = Configuration::new();
    site.set("tony.chaos.crash-point", "not-a-site");
    let mut conf = GatewayConf::new(std::env::temp_dir().join("tony-crashconf-artifacts"));
    conf.apply_site_conf(&site);
    assert_eq!(conf.crash_point, None);
    assert!(!conf.wal.enable, "wal keys absent leave the wal off");
}
