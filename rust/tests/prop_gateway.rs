//! Gateway property test: under random admit/reject/kill mixes across
//! multiple tenants,
//!
//! 1. per-user quotas are never exceeded (sampled after every submit),
//! 2. every accepted job reaches a terminal state (and non-killed jobs
//!    actually FINISH),
//! 3. the RM's capacity is fully returned once the gateway drains, and
//! 4. every job that ran left a history record.
//!
//! Runs entirely on the simulation backend (synthetic artifacts), so it
//! is deterministic-ish in outcomes even though thread interleavings
//! vary.

use std::collections::HashSet;
use std::time::Duration;

use tony::gateway::{Gateway, GatewayConf, JobState, QuotaConf, SubmitOutcome};
use tony::proptest::{check, Gen};
use tony::tonyconf::JobConfBuilder;
use tony::xmlconf::Configuration;
use tony::yarn::{QueueConf, Resource, ResourceManager};
use tony::{prop_assert, prop_assert_eq};

const USERS: &[&str] = &["alice", "bob", "carol"];

fn random_conf(g: &mut Gen, i: usize) -> Configuration {
    let name = format!("prop-{i}");
    match g.usize_up_to(10) {
        // ~20%: invalid spec (no workers at all).
        0 | 1 => JobConfBuilder::new(&name).instances("ps", 1).build(),
        // ~10%: hopeless resources (bounced by admission, not queued).
        2 => JobConfBuilder::new(&name)
            .instances("worker", 8)
            .memory("worker", "64g")
            .build(),
        // ~10%: unknown queue.
        3 => JobConfBuilder::new(&name)
            .queue("etl")
            .instances("worker", 1)
            .memory("worker", "256m")
            .build(),
        // ~60%: legitimate small jobs (1-2 workers + 1 PS; the training
        // framework requires at least one parameter server).
        _ => JobConfBuilder::new(&name)
            .instances("worker", 1 + g.usize_up_to(1) as u32)
            .memory("worker", if g.bool() { "256m" } else { "512m" })
            .instances("ps", 1)
            .memory("ps", "256m")
            .set("tony.am.memory", "256m")
            .set("tony.train.steps", &(1 + g.usize_up_to(3)).to_string())
            .set("tony.train.checkpoint-every", "0")
            .build(),
    }
}

#[test]
fn gateway_quota_terminal_and_capacity_invariants() {
    check("gateway invariants", 3, |g| {
        let base = std::env::temp_dir().join(format!(
            "tony-propgw-{}-{}",
            std::process::id(),
            tony::util::ids::next_seq()
        ));
        let rm = ResourceManager::start(
            (0..4).map(|i| tony::yarn::NodeSpec::new(i, Resource::new(4096, 8, 0))).collect(),
            vec![QueueConf::new("default", 0.7, 1.0), QueueConf::new("ml", 0.3, 1.0)],
        );
        let mut conf = GatewayConf::new(base.join("artifacts"));
        conf.history_dir = base.join("history");
        conf.workers = 4;
        conf.queue_depth = 8;
        conf.max_submit_attempts = 1;
        conf.job_timeout = Duration::from_secs(120);
        conf.quotas = QuotaConf {
            max_active_per_user: 3,
            max_active_per_queue: Some(6),
            max_user_resource: Some(Resource::new(8192, 24, 0)),
            user_queues: [("alice".to_string(), "ml".to_string())].into_iter().collect(),
        };
        let quota = conf.quotas.max_active_per_user;
        let gw = Gateway::start(rm, conf).map_err(|e| format!("gateway start: {e:#}"))?;

        let n_jobs = 8 + g.usize_up_to(6);
        let mut accepted = Vec::new();
        let mut rejected = 0usize;
        for i in 0..n_jobs {
            let user = g.pick(USERS);
            let priority = 1 + g.usize_up_to(5) as u8;
            match gw.submit_conf(user, priority, random_conf(g, i)) {
                SubmitOutcome::Accepted { id } => accepted.push(id),
                SubmitOutcome::Rejected { id, .. } => {
                    rejected += 1;
                    prop_assert_eq!(gw.job_state(id), Some(JobState::Rejected));
                }
            }
            // Invariant 1: quotas hold at every observable instant.
            for (user, active) in gw.user_active_counts() {
                prop_assert!(
                    active <= quota,
                    "user {user} has {active} active jobs (quota {quota})"
                );
            }
            if g.chance(0.3) {
                tony::util::clock::real_sleep(Duration::from_millis(g.range(0, 30)));
            }
        }

        // Random kills on ~25% of accepted jobs, at random moments.
        let mut killed: HashSet<u64> = HashSet::new();
        for id in &accepted {
            if g.chance(0.25) {
                tony::util::clock::real_sleep(Duration::from_millis(g.range(0, 50)));
                if gw.kill(*id).is_some() {
                    killed.insert(*id);
                }
            }
        }

        // Invariant 2: everything accepted reaches a terminal state.
        prop_assert!(
            gw.wait_idle(Duration::from_secs(180)),
            "gateway did not drain: {:?}",
            gw.live_counts()
        );
        for id in &accepted {
            let state = gw.job_state(*id).ok_or("job vanished")?;
            prop_assert!(state.is_terminal(), "job {id} ended non-terminal: {state:?}");
            if !killed.contains(id) {
                prop_assert_eq!(state, JobState::Finished);
            }
        }

        // Invariant 3: all cluster capacity returned.
        for (node, free, cap) in gw.rm().node_usage() {
            prop_assert!(
                free == cap,
                "capacity leaked on {node}: free {free} != cap {cap}"
            );
        }
        // Bookkeeping drained with the jobs.
        for (user, active) in gw.user_active_counts() {
            prop_assert!(active == 0, "user {user} still has {active} active after drain");
        }

        // Invariant 4: at least every accepted-and-run job left a record
        // (kills can land before the first attempt, so allow that gap).
        let records = gw.history().list().map_err(|e| format!("history: {e:#}"))?;
        prop_assert!(
            records.len() >= accepted.len().saturating_sub(killed.len()),
            "history has {} records for {} accepted / {} killed jobs",
            records.len(),
            accepted.len(),
            killed.len()
        );
        let stats = gw.stats();
        prop_assert_eq!(stats.accepted as usize, accepted.len());
        prop_assert_eq!(stats.rejected as usize, rejected);

        gw.shutdown();
        let _ = std::fs::remove_dir_all(&base);
        Ok(())
    });
}
