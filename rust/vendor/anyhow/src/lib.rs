//! Offline-vendored, API-compatible subset of `anyhow` (the real crate is
//! unreachable in this no-network build).  Implements the surface the tony
//! crate uses: [`Error`], [`Result`], the [`Context`] trait on `Result`
//! and `Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Semantics mirror upstream where it matters:
//! - `{}` displays the outermost message only; `{:#}` joins the whole
//!   context chain with `": "`; `{:?}` prints the chain as a
//!   "Caused by:" list.
//! - `Error` deliberately does NOT implement `std::error::Error`, so the
//!   blanket `From<E: std::error::Error>` conversion (what makes `?`
//!   work) cannot collide with the reflexive `From<Error>`.

use std::fmt;

/// An error chain: `chain[0]` is the outermost (most recent) context.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with one more layer of context (what `Context::context` does).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first (upstream: `Error::chain`).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost message (upstream: `Error::root_cause`).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (and to `None`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond))
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*)
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = io_err().into();
        let e = e.context("reading config");
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn question_mark_and_macros() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());

        fn bails(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(bails(2).unwrap(), 2);
        assert!(bails(3).is_err());
        assert!(format!("{:#}", bails(12).unwrap_err()).contains("12"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
    }
}
