//! tony-lint: a control-plane static analyzer for the TonY tree.
//!
//! Four passes over a hand-rolled token scan (no syntax-tree dependency —
//! the workspace builds offline):
//!
//! 1. **Lock order** — tracks guard live ranges, classifies every lock
//!    site against `rust/lint/lock-order.toml`, builds the
//!    acquired-while-held graph (including through the typed call graph),
//!    and fails on reentrancy, cycles, or canonical-order violations.
//! 2. **Blocking under lock** — flags sleeps, condvar/channel waits,
//!    thread joins, TCP I/O and fsync while a guard is live, with witness
//!    call chains for indirect blocking.
//! 3. **Config registry** — every production `tony.*` literal must be
//!    documented in docs/CONFIGURATION.md (and its feature doc) and read
//!    through a tonyconf accessor; documented-but-unused keys are drift.
//! 4. **Metric/sleep hygiene** — `tony_*` families must appear in
//!    docs/METRICS.md; `std::thread::sleep` is banned everywhere.
//!
//! Deliberate violations carry `// lint:allow(rule, reason = "...")` on
//! the offending line or the line above; a missing or empty reason is
//! itself an error.  See docs/LINTS.md.

pub mod analyzer;
pub mod body;
pub mod index;
pub mod lexer;
pub mod manifest;
pub mod walker;

use index::Finding;

pub struct LintOutcome {
    pub findings: Vec<Finding>,
    pub errors: usize,
    pub warnings: usize,
    /// (rule, count), sorted by rule name.
    pub counts: Vec<(String, usize)>,
}

impl LintOutcome {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    pub fn failed(&self, deny_warnings: bool) -> bool {
        self.errors > 0 || (deny_warnings && self.warnings > 0)
    }
}

/// Run the analyzer over `paths` (files or directories of `.rs` files).
pub fn run(manifest_path: &str, docs_dir: &str, paths: &[String]) -> LintOutcome {
    let (locks, rank) = if std::path::Path::new(manifest_path).exists() {
        manifest::parse_manifest(manifest_path)
    } else {
        (Vec::new(), Vec::new())
    };
    let mut az = analyzer::Analyzer::new(locks, rank, docs_dir);
    let files = analyzer::collect_files(paths);
    az.run(&files);
    let mut errors = 0usize;
    let mut warnings = 0usize;
    let mut counts: std::collections::BTreeMap<String, usize> = std::collections::BTreeMap::new();
    for f in &az.findings {
        *counts.entry(f.rule.clone()).or_insert(0) += 1;
        if f.severity() == "error" {
            errors += 1;
        } else {
            warnings += 1;
        }
    }
    LintOutcome {
        findings: az.findings,
        errors,
        warnings,
        counts: counts.into_iter().collect(),
    }
}

/// CLI entry shared by the `tony-lint` binary and the `tony lint`
/// subcommand.  Args: `[--deny warnings] [--manifest PATH] [--docs DIR]
/// paths...`.  Returns the process exit code.
pub fn cli_main(args: &[String]) -> i32 {
    let mut deny = false;
    let mut manifest = "rust/lint/lock-order.toml".to_string();
    let mut docs = "docs".to_string();
    let mut paths: Vec<String> = Vec::new();
    let mut i = 0usize;
    while i < args.len() {
        let a = &args[i];
        if a == "--deny" && i + 1 < args.len() && args[i + 1] == "warnings" {
            deny = true;
            i += 2;
        } else if a == "--manifest" && i + 1 < args.len() {
            manifest = args[i + 1].clone();
            i += 2;
        } else if a == "--docs" && i + 1 < args.len() {
            docs = args[i + 1].clone();
            i += 2;
        } else {
            paths.push(a.clone());
            i += 1;
        }
    }
    if paths.is_empty() {
        // Default sweep, relative to the repo root.
        for p in ["rust/src", "rust/benches", "rust/tests", "examples"] {
            paths.push(p.to_string());
        }
    }
    let out = run(&manifest, &docs, &paths);
    for f in &out.findings {
        println!("{}", f.render());
    }
    println!("-- {} error(s), {} warning(s)", out.errors, out.warnings);
    for (rule, n) in &out.counts {
        println!("   {}: {}", rule, n);
    }
    if out.failed(deny) {
        1
    } else {
        0
    }
}
