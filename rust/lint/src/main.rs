//! `cargo run -p tony-lint -- [--deny warnings] [--manifest PATH]
//! [--docs DIR] paths...`

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(tony_lint::cli_main(&args));
}
