//! Minimal TOML reader for `lock-order.toml`: `[[lock]]` tables with
//! `name` / `match` keys and an `[order]` table with a `rank` array.
//! No general TOML — just what the manifest needs, dependency-free.

use std::fs;

#[derive(Clone, Debug)]
pub struct LockEnt {
    pub name: String,
    pub matches: Vec<String>,
}

pub fn parse_manifest(path: &str) -> (Vec<LockEnt>, Vec<String>) {
    let mut locks: Vec<LockEnt> = Vec::new();
    let mut rank: Vec<String> = Vec::new();
    let text = match fs::read_to_string(path) {
        Ok(t) => t,
        Err(_) => return (locks, rank),
    };
    let mut section = "";
    let mut pending_key: Option<String> = None;
    let mut pending_items: Vec<String> = Vec::new();
    for raw in text.split('\n') {
        let stripped = strip_toml_comment(raw);
        let ln = stripped.trim();
        if ln.is_empty() {
            continue;
        }
        if let Some(key) = pending_key.clone() {
            pending_items.extend(toml_str_items(ln));
            if ln.ends_with(']') {
                finish_toml_array(&mut locks, &mut rank, section, &key, &pending_items);
                pending_key = None;
                pending_items = Vec::new();
            }
            continue;
        }
        if ln == "[[lock]]" {
            locks.push(LockEnt { name: String::new(), matches: Vec::new() });
            section = "lock";
            continue;
        }
        if ln == "[order]" {
            section = "order";
            continue;
        }
        let eq = match ln.find('=') {
            Some(e) => e,
            None => continue,
        };
        let key = ln[..eq].trim().to_string();
        let val = ln[eq + 1..].trim();
        if val.starts_with('[') {
            let items = toml_str_items(&val[1..]);
            if val.ends_with(']') {
                finish_toml_array(&mut locks, &mut rank, section, &key, &items);
            } else {
                pending_key = Some(key);
                pending_items = items;
            }
        } else if val.len() >= 2 && val.starts_with('"') && val.ends_with('"') {
            if section == "lock" && key == "name" {
                if let Some(cur) = locks.last_mut() {
                    cur.name = val[1..val.len() - 1].to_string();
                }
            }
        }
    }
    (locks, rank)
}

fn strip_toml_comment(line: &str) -> String {
    let mut out = String::new();
    let mut in_str = false;
    for ch in line.chars() {
        if ch == '"' {
            in_str = !in_str;
        }
        if ch == '#' && !in_str {
            break;
        }
        out.push(ch);
    }
    out
}

/// Every `"..."` substring on the line, in order.
fn toml_str_items(s: &str) -> Vec<String> {
    let cs: Vec<char> = s.chars().collect();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < cs.len() {
        if cs[i] == '"' {
            let mut j = i + 1;
            while j < cs.len() && cs[j] != '"' {
                j += 1;
            }
            if j < cs.len() {
                out.push(cs[i + 1..j].iter().collect());
                i = j + 1;
                continue;
            }
            break;
        }
        i += 1;
    }
    out
}

fn finish_toml_array(
    locks: &mut Vec<LockEnt>,
    rank: &mut Vec<String>,
    section: &str,
    key: &str,
    items: &[String],
) {
    if section == "lock" && key == "match" {
        if let Some(cur) = locks.last_mut() {
            cur.matches.extend(items.iter().cloned());
        }
    } else if section == "order" && key == "rank" {
        rank.extend(items.iter().cloned());
    }
}
