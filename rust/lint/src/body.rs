//! Pass 2: walk function bodies with the global index available.
//! Tracks guard live ranges, types local bindings, classifies lock sites
//! against the manifest, records the typed call graph, and collects
//! config-key / metric-literal uses.

use crate::analyzer::Analyzer;
use crate::index::{
    collect_type_idents, is_direct_blocking, is_keyword, key_matches, metric_family,
    metric_matches, normalize_key, FnRec, LockSite, Pair,
};
use crate::lexer::{Kind, Tok};
use crate::walker::{impl_header_position, is_i, is_kind, is_p, parse_fn_sig, parse_impl, Guard, Scope};

pub struct BodyWalker<'a> {
    pub az: &'a mut Analyzer,
    pub file: String,
    pub toks: &'a [Tok],
    scopes: Vec<Scope>,
    pending_impl: Option<String>,
    pending_fn: Option<(String, u32, Vec<(String, Vec<String>)>)>,
    pending_cfg_test: bool,
    pending_let: Option<String>,
    stmt_start: bool,
    paren_names: Vec<Option<String>>,
    spawn_paren_depth: Option<usize>,
}

impl<'a> BodyWalker<'a> {
    pub fn new(az: &'a mut Analyzer, file: &str, toks: &'a [Tok], dir_test: bool) -> BodyWalker<'a> {
        BodyWalker {
            az,
            file: file.to_string(),
            toks,
            scopes: vec![Scope::new(String::new(), None, dir_test, false)],
            pending_impl: None,
            pending_fn: None,
            pending_cfg_test: false,
            pending_let: None,
            stmt_start: true,
            paren_names: Vec::new(),
            spawn_paren_depth: None,
        }
    }

    fn cur(&self) -> &Scope {
        self.scopes.last().unwrap()
    }

    fn cur_mut(&mut self) -> &mut Scope {
        self.scopes.last_mut().unwrap()
    }

    fn in_test(&self) -> bool {
        self.cur().is_test
    }

    // ---- typing --------------------------------------------------------

    /// Declared type-ident list of a binding: scope env, then file statics.
    fn lookup_binding(&self, name: &str) -> Option<Vec<String>> {
        for sc in self.scopes.iter().rev() {
            if let Some(tyl) = sc.env.get(name) {
                return Some(tyl.clone());
            }
        }
        self.az.index.statics.get(&(self.file.clone(), name.to_string())).cloned()
    }

    /// Declared type-ident list of a full `a.b.c` chain.  `clone()` and
    /// `upgrade()` segments are type-transparent; other calls end typing.
    fn chain_tylist(&self, chain: &[String]) -> Option<Vec<String>> {
        if chain.is_empty() {
            return None;
        }
        let mut tylist: Option<Vec<String>> = if chain[0] == "self" {
            let it = self.cur().impl_type.clone();
            if it.is_empty() {
                None
            } else {
                Some(vec![it])
            }
        } else {
            self.lookup_binding(&chain[0])
        };
        for seg in &chain[1..] {
            let cur = tylist?;
            if seg == "clone()" || seg == "upgrade()" {
                tylist = Some(cur);
                continue;
            }
            if seg.ends_with("()") {
                return None;
            }
            let ty = self.az.index.core_type(&cur, 0)?;
            tylist = self.az.index.field_type(&ty, seg);
        }
        tylist
    }

    fn resolve_chain_type(&self, chain: &[String]) -> Option<String> {
        let tylist = self.chain_tylist(chain)?;
        self.az.index.core_type(&tylist, 0)
    }

    /// For `a.b.c`: (core type of `a.b`, "c").  Single segment: (None, seg).
    fn chain_owner_and_field(&self, chain: &[String]) -> (Option<String>, Option<String>) {
        if chain.len() < 2 {
            return (None, chain.first().cloned());
        }
        let owner = self.resolve_chain_type(&chain[..chain.len() - 1]);
        (owner, chain.last().cloned())
    }

    fn mutex_inner_of_chain(&self, chain: &[String]) -> Option<String> {
        let tylist = self.chain_tylist(chain)?;
        self.az.index.mutex_inner(&tylist, 0)
    }

    // ---- guard / fn helpers ---------------------------------------------

    /// Lock names currently held on this thread (spawn barriers cut off
    /// the parent's guards), outermost first.
    fn held(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for sc in self.scopes.iter().rev() {
            for g in &sc.guards {
                out.push(g.lock_id.clone());
            }
            if sc.barrier {
                break;
            }
        }
        out.reverse();
        out
    }

    fn fn_key_if_indexed(&self) -> Option<String> {
        let key = self.cur().fn_key.clone()?;
        if self.az.index.fns.contains_key(&key) {
            Some(key)
        } else {
            None
        }
    }

    // ---- main loop -------------------------------------------------------

    pub fn walk(&mut self) {
        let n = self.toks.len();
        let mut i = 0usize;
        while i < n {
            let kind = self.toks[i].kind;
            let line = self.toks[i].line;
            if kind == Kind::Punct {
                let text = self.toks[i].text.clone();
                i = self.punct(i, &text, line);
                continue;
            }
            if kind == Kind::Str {
                let text = self.toks[i].text.clone();
                self.string_lit(&text, line);
                i += 1;
                continue;
            }
            if kind != Kind::Ident {
                i += 1;
                self.stmt_start = false;
                continue;
            }
            let text = self.toks[i].text.clone();
            if self.stmt_start {
                self.cur_mut().stmt_kind = if matches!(text.as_str(), "if" | "while" | "for" | "match") {
                    Some(text.clone())
                } else {
                    None
                };
                self.stmt_start = false;
            }
            if text == "impl" && impl_header_position(self.toks, i) {
                let (ty, _tr) = parse_impl(self.toks, i);
                self.pending_impl = Some(ty);
                i += 1;
                continue;
            }
            if text == "fn" {
                if let Some(sig) = parse_fn_sig(self.toks, i) {
                    self.pending_fn = Some(sig);
                }
                i += 2;
                continue;
            }
            if text == "let" {
                self.handle_let(i);
                i += 1;
                continue;
            }
            if text == "lock" && self.is_lock_call(i) {
                i = self.lock_site(i, line);
                continue;
            }
            if text == "drop" && is_p(self.toks, i + 1, "(") {
                self.handle_drop(i);
                i += 1;
                continue;
            }
            if i + 1 < n
                && self.toks[i + 1].kind == Kind::Punct
                && (self.toks[i + 1].text == "(" || self.toks[i + 1].text == "!")
            {
                i = self.call_site(i, &text, line);
                continue;
            }
            i += 1;
        }
    }

    // ---- let inference ---------------------------------------------------

    fn handle_let(&mut self, i: usize) {
        let toks = self.toks;
        let n = toks.len();
        let mut j = i + 1;
        if is_i(toks, j, "mut") {
            j += 1;
        }
        if j >= n || toks[j].kind != Kind::Ident {
            self.pending_let = None;
            return;
        }
        // Optional Some(x) / Ok(x) pattern (if-let / while-let / let-else).
        let mut wrapped = false;
        if (toks[j].text == "Some" || toks[j].text == "Ok")
            && j + 3 < n
            && is_p(toks, j + 1, "(")
            && toks[j + 2].kind == Kind::Ident
            && is_p(toks, j + 3, ")")
        {
            wrapped = true;
            j += 2;
            if is_i(toks, j, "mut") && is_kind(toks, j + 1, Kind::Ident) {
                j += 1;
            }
        }
        let name = toks[j].text.clone();
        j += 1;
        if wrapped {
            j += 1; // past `)`
        }
        let mut ann: Option<Vec<String>> = None;
        if !wrapped && is_p(toks, j, ":") {
            // Explicit annotation: tokens up to `=` or `;` at depth 0.
            let mut depth = 0i32;
            let mut tybuf: Vec<Pair> = Vec::new();
            j += 1;
            while j < n {
                let t = &toks[j];
                if t.kind == Kind::Punct && matches!(t.text.as_str(), "<" | "(" | "[") {
                    depth += 1;
                } else if t.kind == Kind::Punct && matches!(t.text.as_str(), ">" | ")" | "]") {
                    depth -= 1;
                } else if t.kind == Kind::Punct && (t.text == "=" || t.text == ";") && depth <= 0 {
                    break;
                }
                tybuf.push((t.kind, t.text.clone()));
                j += 1;
            }
            ann = Some(collect_type_idents(&tybuf));
        }
        if !is_p(toks, j, "=") {
            self.pending_let = None;
            return;
        }
        self.pending_let = if wrapped { None } else { Some(name.clone()) };
        if let Some(a) = ann {
            if !a.is_empty() {
                self.cur_mut().env.insert(name, a);
                return;
            }
        }
        // Infer simple chains: ident(.field|.clone()|.upgrade())* ending at
        // `;` (plain let), `{` (if/while-let) or `else` (let-else), and
        // `Type::new(..)` / `Type::default(..)` constructors.
        j += 1;
        let mut chain: Vec<String> = Vec::new();
        let mut k = j;
        let mut ok = true;
        while k < n {
            let t = &toks[k];
            if t.kind == Kind::Ident {
                if is_p(toks, k + 1, "(") {
                    if (t.text == "clone" || t.text == "upgrade") && is_p(toks, k + 2, ")") {
                        chain.push(format!("{}()", t.text));
                        k += 3;
                    } else {
                        ok = false;
                        break;
                    }
                } else {
                    chain.push(t.text.clone());
                    k += 1;
                }
                if is_p(toks, k, ".") {
                    k += 1;
                    continue;
                }
                break;
            } else if t.kind == Kind::Punct && t.text == "&" {
                k += 1;
                continue;
            } else {
                ok = false;
                break;
            }
        }
        let ender = ok
            && !chain.is_empty()
            && k < n
            && (is_p(toks, k, ";") || (wrapped && (is_p(toks, k, "{") || is_i(toks, k, "else"))));
        if ender {
            if let Some(tylist) = self.chain_tylist(&chain) {
                if !tylist.is_empty() {
                    self.cur_mut().env.insert(name, tylist);
                }
            }
            return;
        }
        if j + 3 < n
            && toks[j].kind == Kind::Ident
            && self.az.index.tree_types.contains(&toks[j].text)
            && is_p(toks, j + 1, ":")
            && is_p(toks, j + 2, ":")
            && toks[j + 3].kind == Kind::Ident
            && (toks[j + 3].text == "new" || toks[j + 3].text == "default")
        {
            let ty = toks[j].text.clone();
            self.cur_mut().env.insert(name, vec![ty]);
        }
    }

    // ---- lock sites --------------------------------------------------------

    fn is_lock_call(&self, i: usize) -> bool {
        i >= 1
            && i + 2 < self.toks.len()
            && is_p(self.toks, i - 1, ".")
            && is_p(self.toks, i + 1, "(")
            && is_p(self.toks, i + 2, ")")
    }

    /// Backwards receiver chain of a `.name` at `i`: `a.b.c()` segments.
    fn receiver(&self, i: usize) -> Option<Vec<String>> {
        let toks = self.toks;
        let mut j: isize = i as isize - 2;
        let mut parts: Vec<String> = Vec::new();
        while j >= 0 {
            let ju = j as usize;
            if toks[ju].kind == Kind::Punct && toks[ju].text == ")" && ju >= 1 && is_p(toks, ju - 1, "(") {
                if ju >= 2 && toks[ju - 2].kind == Kind::Ident {
                    parts.push(format!("{}()", toks[ju - 2].text));
                    j -= 3;
                } else {
                    return None;
                }
            } else if toks[ju].kind == Kind::Ident {
                parts.push(toks[ju].text.clone());
                j -= 1;
            } else {
                break;
            }
            if j >= 0 && is_p(toks, j as usize, ".") {
                j -= 1;
                continue;
            }
            break;
        }
        if parts.is_empty() {
            return None;
        }
        parts.reverse();
        Some(parts)
    }

    /// Classify a lock receiver chain against the manifest.  Candidate
    /// precedence: `Owner.field` (typed owner), `type:Inner` (declared
    /// Mutex payload), `path-suffix:receiver`, bare receiver text.
    /// -> (lock name, classified, mutex inner type, candidates tried).
    fn classify(&self, chain: &[String]) -> (String, bool, Option<String>, Vec<String>) {
        let norm: &[String] = if !chain.is_empty() && chain[0] == "self" && chain.len() > 1 {
            &chain[1..]
        } else {
            chain
        };
        let norm_txt = norm.join(".");
        let mut cands: Vec<String> = Vec::new();
        let (owner, field) = self.chain_owner_and_field(chain);
        if let (Some(o), Some(f)) = (&owner, &field) {
            cands.push(format!("{}.{}", o, f));
        }
        let inner = self.mutex_inner_of_chain(chain);
        if let Some(inn) = &inner {
            cands.push(format!("type:{}", inn));
        }
        let mut all_cands = cands.clone();
        all_cands.push(format!("<file-suffix>:{}", norm_txt));
        all_cands.push(norm_txt.clone());
        for want in &cands {
            for ent in &self.az.manifest_locks {
                if ent.matches.iter().any(|m| m == want) {
                    return (ent.name.clone(), true, inner, all_cands);
                }
            }
        }
        for ent in &self.az.manifest_locks {
            for pat in &ent.matches {
                let k = match pat.rfind(':') {
                    Some(k) => k,
                    None => continue,
                };
                if k == 0 || pat.starts_with("type:") {
                    continue;
                }
                let (path, r) = (&pat[..k], &pat[k + 1..]);
                if r == norm_txt && self.file.ends_with(path) {
                    return (ent.name.clone(), true, inner, all_cands);
                }
            }
        }
        for ent in &self.az.manifest_locks {
            if ent.matches.iter().any(|m| *m == norm_txt) {
                return (ent.name.clone(), true, inner, all_cands);
            }
        }
        let impl_ty = if self.cur().impl_type.is_empty() {
            "?".to_string()
        } else {
            self.cur().impl_type.clone()
        };
        let anon = format!("{}:{}:{}", self.file, impl_ty, norm_txt);
        (anon, false, inner, all_cands)
    }

    fn lock_site(&mut self, i: usize, line: u32) -> usize {
        let toks = self.toks;
        let n = toks.len();
        // Skip trailing `.unwrap()` / `.expect(..)` to find the statement end.
        let mut j = i + 3;
        while j + 1 < n
            && is_p(toks, j, ".")
            && toks[j + 1].kind == Kind::Ident
            && (toks[j + 1].text == "unwrap" || toks[j + 1].text == "expect")
        {
            let mut k = j + 2;
            if is_p(toks, k, "(") {
                let mut depth = 1i32;
                k += 1;
                while k < n && depth > 0 {
                    if is_p(toks, k, "(") {
                        depth += 1;
                    } else if is_p(toks, k, ")") {
                        depth -= 1;
                    }
                    k += 1;
                }
                j = k;
            } else {
                break;
            }
        }
        let ends_stmt = is_p(toks, j, ";");
        if self.in_test() {
            return i + 1;
        }
        let chain = self.receiver(i).unwrap_or_else(|| vec!["?".to_string()]);
        let (lock_id, classified, inner, cands) = self.classify(&chain);
        let held = self.held();
        let fn_key = self.cur().fn_key.clone();
        self.az.lock_sites.push(LockSite {
            file: self.file.clone(),
            line,
            lock_id: lock_id.clone(),
            classified,
            held,
            fn_key: fn_key.clone(),
            cands,
        });
        if let Some(fk) = &fn_key {
            if let Some(rec) = self.az.index.fns.get_mut(fk) {
                rec.locks.push((lock_id.clone(), line));
            }
        }
        let bound = ends_stmt && self.pending_let.is_some();
        let binding = if bound { self.pending_let.clone() } else { None };
        self.cur_mut().guards.push(Guard { binding: binding.clone(), lock_id, temp: !bound });
        if bound {
            if let (Some(b), Some(inn)) = (binding, inner) {
                self.cur_mut().env.insert(b, vec![inn]);
            }
        }
        i + 1
    }

    fn handle_drop(&mut self, i: usize) {
        let toks = self.toks;
        if is_kind(toks, i + 2, Kind::Ident) && is_p(toks, i + 3, ")") {
            let name = toks[i + 2].text.clone();
            for sc in self.scopes.iter_mut().rev() {
                for k in (0..sc.guards.len()).rev() {
                    if sc.guards[k].binding.as_deref() == Some(name.as_str()) {
                        sc.guards.remove(k);
                        return;
                    }
                }
            }
        }
    }

    // ---- call sites ---------------------------------------------------------

    fn call_site(&mut self, i: usize, name: &str, line: u32) -> usize {
        let toks = self.toks;
        let is_macro = is_p(toks, i + 1, "!");
        // Leading `a::b::` path of the call, if any.
        let mut path: Vec<String> = Vec::new();
        let mut j: isize = i as isize - 1;
        while j >= 1 && is_p(toks, j as usize, ":") && is_p(toks, (j - 1) as usize, ":") {
            if j >= 2 && toks[(j - 2) as usize].kind == Kind::Ident {
                path.push(toks[(j - 2) as usize].text.clone());
                j -= 3;
            } else {
                break;
            }
        }
        path.reverse();
        if !is_macro && name == "sleep" && path.last().map(|p| p == "thread").unwrap_or(false) {
            self.az.add_finding(
                &self.file,
                line,
                "thread-sleep",
                "std::thread::sleep is banned: route through Clock::sleep, a \
                 WakeupBus wait, or util::clock::real_sleep",
            );
        }
        if is_macro || self.in_test() {
            return i + 1;
        }
        let fk = match self.fn_key_if_indexed() {
            Some(k) => k,
            None => return i + 1,
        };
        if is_keyword(name) || matches!(name, "lock" | "unwrap" | "expect" | "drop") {
            return i + 1;
        }
        if name == "join" && !is_p(toks, i + 2, ")") {
            return i + 1; // join with args is iterator/string join, not thread join
        }
        // Resolve the callee through the type layer: method receivers must
        // type to a tree type (or a trait with recorded impls); path calls
        // resolve when the path head is a tree type; bare calls resolve
        // among tree free functions.  Untyped receivers get NO edges.
        let mut keys: Vec<String> = Vec::new();
        let is_method = i >= 1 && is_p(toks, i - 1, ".");
        if is_method {
            let ty = self.receiver(i).and_then(|chain| self.resolve_chain_type(&chain));
            if let Some(ty) = ty {
                keys = self
                    .az
                    .index
                    .by_type
                    .get(&(ty.clone(), name.to_string()))
                    .cloned()
                    .unwrap_or_default();
                if keys.is_empty() {
                    if let Some(impls) = self.az.index.traits.get(&ty) {
                        for impl_ty in impls.clone() {
                            if let Some(ks) = self.az.index.by_type.get(&(impl_ty, name.to_string())) {
                                keys.extend(ks.iter().cloned());
                            }
                        }
                    }
                }
            }
        } else if let Some(last) = path.last().cloned() {
            if self.az.index.tree_types.contains(&last) {
                keys = self
                    .az
                    .index
                    .by_type
                    .get(&(last.clone(), name.to_string()))
                    .cloned()
                    .unwrap_or_default();
                if keys.is_empty() {
                    if let Some(impls) = self.az.index.traits.get(&last) {
                        for impl_ty in impls.clone() {
                            if let Some(ks) = self.az.index.by_type.get(&(impl_ty, name.to_string())) {
                                keys.extend(ks.iter().cloned());
                            }
                        }
                    }
                }
            } else if last != "thread" {
                keys = self.az.index.free.get(name).cloned().unwrap_or_default();
            }
        } else {
            keys = self.az.index.free.get(name).cloned().unwrap_or_default();
        }
        let held = self.held();
        if let Some(rec) = self.az.index.fns.get_mut(&fk) {
            rec.calls.push((name.to_string(), keys, held, line));
            if is_direct_blocking(name) {
                rec.blocks.push((name.to_string(), line));
            }
        }
        i + 1
    }

    // ---- literals ------------------------------------------------------------

    fn string_lit(&mut self, text: &str, line: u32) {
        let norm = normalize_key(text);
        if key_matches(&norm) {
            let mut encl = String::new();
            for nm in self.paren_names.iter().rev() {
                if let Some(nm) = nm {
                    encl = nm.clone();
                    break;
                }
            }
            let in_test = self.in_test();
            self.az.config_uses.push((self.file.clone(), line, norm, encl, in_test));
        }
        if metric_matches(text) {
            let in_test = self.in_test();
            self.az.metric_uses.push((self.file.clone(), line, metric_family(text), in_test));
        }
    }

    // ---- punctuation / scope transitions ---------------------------------------

    fn punct(&mut self, i: usize, text: &str, line: u32) -> usize {
        let toks = self.toks;
        if text == "#" {
            if is_p(toks, i + 1, "[")
                && is_i(toks, i + 2, "cfg")
                && is_p(toks, i + 3, "(")
                && is_i(toks, i + 4, "test")
                && is_p(toks, i + 5, ")")
            {
                self.pending_cfg_test = true;
            }
            return i + 1;
        }
        if text == "(" || text == "[" {
            let mut nm: Option<String> = None;
            if text == "(" && i >= 1 {
                if toks[i - 1].kind == Kind::Ident {
                    nm = Some(toks[i - 1].text.clone());
                } else if is_p(toks, i - 1, "!") && i >= 2 && toks[i - 2].kind == Kind::Ident {
                    nm = Some(toks[i - 2].text.clone());
                }
            }
            let is_spawn = nm.as_deref() == Some("spawn");
            self.paren_names.push(nm);
            self.cur_mut().paren += 1;
            if is_spawn && self.spawn_paren_depth.is_none() && !self.in_test() {
                self.spawn_paren_depth = Some(self.paren_names.len());
            }
            return i + 1;
        }
        if text == ")" || text == "]" {
            if !self.paren_names.is_empty() {
                if self.spawn_paren_depth == Some(self.paren_names.len()) {
                    self.spawn_paren_depth = None;
                }
                self.paren_names.pop();
            }
            let sc = self.cur_mut();
            sc.paren = sc.paren.saturating_sub(1);
            return i + 1;
        }
        if text == ";" {
            if self.cur().paren == 0 {
                let sc = self.cur_mut();
                sc.guards.retain(|g| !g.temp);
                sc.stmt_kind = None;
                self.pending_let = None;
                self.stmt_start = true;
            }
            return i + 1;
        }
        if text == "{" {
            let parent_fn_key = self.cur().fn_key.clone();
            let mut impl_type = self.cur().impl_type.clone();
            let mut fn_key = parent_fn_key.clone();
            let mut is_test = self.cur().is_test;
            let stmt_kind = self.cur().stmt_kind.clone();
            let mut barrier = false;
            if self.pending_cfg_test {
                is_test = true;
                self.pending_cfg_test = false;
            }
            if let Some(ty) = self.pending_impl.take() {
                impl_type = ty;
            }
            if let Some((bare, fl, _params)) = self.pending_fn.take() {
                fn_key = Some(format!("{}:{}:{}", self.file, fl, bare));
            } else if self.spawn_paren_depth.is_some() && fn_key.is_some() {
                // A closure inside spawn(..): a new thread's body.  It gets
                // a synthetic fn record so its lock/call edges are tracked,
                // and a barrier so the parent's guards don't leak in.
                barrier = true;
                let key = format!("{}::spawn@{}", fn_key.clone().unwrap(), line);
                if !self.az.index.fns.contains_key(&key) && !is_test {
                    self.az.index.fns.insert(
                        key.clone(),
                        FnRec::new(key.clone(), String::new(), impl_type.clone(), self.file.clone(), line, is_test),
                    );
                }
                fn_key = Some(key);
                self.spawn_paren_depth = None;
            }
            let mut sc = Scope::new(impl_type, fn_key.clone(), is_test, barrier);
            if fn_key != parent_fn_key && !barrier {
                if let Some(fk) = &fn_key {
                    if let Some(rec) = self.az.index.fns.get(fk) {
                        for (pn, tyl) in &rec.params {
                            if !tyl.is_empty() {
                                sc.env.insert(pn.clone(), tyl.clone());
                            }
                        }
                    }
                }
            }
            if stmt_kind.as_deref() == Some("match") {
                // Match scrutinee temporaries live for the whole match.
                let parent = self.cur_mut();
                let mut kept: Vec<Guard> = Vec::new();
                let mut temps: Vec<Guard> = Vec::new();
                for g in parent.guards.drain(..) {
                    if g.temp {
                        temps.push(g);
                    } else {
                        kept.push(g);
                    }
                }
                parent.guards = kept;
                sc.guards.extend(temps);
            } else if matches!(stmt_kind.as_deref(), Some("if") | Some("while") | Some("for")) {
                // Condition temporaries die at the block open.
                self.cur_mut().guards.retain(|g| !g.temp);
            }
            self.cur_mut().stmt_kind = None;
            self.scopes.push(sc);
            self.pending_let = None;
            self.stmt_start = true;
            return i + 1;
        }
        if text == "}" {
            if self.scopes.len() > 1 {
                self.scopes.pop();
            }
            self.stmt_start = true;
            return i + 1;
        }
        i + 1
    }
}
