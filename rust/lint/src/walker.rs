//! Shared scope machinery plus pass 1 (`IndexWalker`), which builds the
//! global symbol index: struct fields, type aliases, trait impls, statics,
//! and a registry of every non-test function.

use std::collections::HashMap;

use crate::analyzer::Analyzer;
use crate::index::{collect_type_idents, FnRec, Pair, Param};
use crate::lexer::{Kind, Tok};

pub fn is_kind(toks: &[Tok], i: usize, k: Kind) -> bool {
    i < toks.len() && toks[i].kind == k
}

pub fn is_p(toks: &[Tok], i: usize, s: &str) -> bool {
    i < toks.len() && toks[i].kind == Kind::Punct && toks[i].text == s
}

pub fn is_i(toks: &[Tok], i: usize, s: &str) -> bool {
    i < toks.len() && toks[i].kind == Kind::Ident && toks[i].text == s
}

/// A live lock guard: named (`let g = m.lock()`) or a temporary.
pub struct Guard {
    pub binding: Option<String>,
    pub lock_id: String,
    pub temp: bool,
}

/// One brace scope: impl/fn attribution, guard set, and the local type
/// environment (binding -> declared type-ident list).
pub struct Scope {
    pub impl_type: String,
    pub fn_key: Option<String>,
    pub is_test: bool,
    /// Spawn-closure boundary: guards outside it belong to another thread.
    pub barrier: bool,
    pub guards: Vec<Guard>,
    pub env: HashMap<String, Vec<String>>,
    pub paren: u32,
    pub stmt_kind: Option<String>,
}

impl Scope {
    pub fn new(impl_type: String, fn_key: Option<String>, is_test: bool, barrier: bool) -> Scope {
        Scope {
            impl_type,
            fn_key,
            is_test,
            barrier,
            guards: Vec::new(),
            env: HashMap::new(),
            paren: 0,
            stmt_kind: None,
        }
    }
}

/// `impl` only opens a header when the previous token could end an item.
pub fn impl_header_position(toks: &[Tok], i: usize) -> bool {
    if i == 0 {
        return true;
    }
    let t = &toks[i - 1];
    match t.kind {
        Kind::Punct => matches!(t.text.as_str(), ";" | "{" | "}" | "]"),
        Kind::Ident => matches!(t.text.as_str(), "pub" | "unsafe" | "default"),
        _ => false,
    }
}

pub fn item_position(toks: &[Tok], i: usize) -> bool {
    if i == 0 {
        return true;
    }
    let t = &toks[i - 1];
    match t.kind {
        Kind::Punct => matches!(t.text.as_str(), ";" | "{" | "}" | "]"),
        Kind::Ident => t.text == "pub",
        _ => false,
    }
}

/// At an `impl` token: -> (self type name, trait name if `impl T for U`).
pub fn parse_impl(toks: &[Tok], i: usize) -> (String, Option<String>) {
    let n = toks.len();
    let mut j = i + 1;
    let mut depth = 0i32;
    let mut last_ident: Option<String> = None;
    let mut before_for: Option<String> = None;
    let mut seen_for = false;
    while j < n {
        let t = &toks[j];
        if t.kind == Kind::Punct && t.text == "<" {
            depth += 1;
        } else if t.kind == Kind::Punct && t.text == ">" {
            depth = (depth - 1).max(0);
        } else if t.kind == Kind::Punct && t.text == "{" && depth == 0 {
            break;
        } else if t.kind == Kind::Ident && depth == 0 {
            if t.text == "for" {
                seen_for = true;
                before_for = last_ident.take();
            } else if t.text == "where" {
                break;
            } else {
                last_ident = Some(t.text.clone());
            }
        }
        j += 1;
    }
    if seen_for {
        return (last_ident.unwrap_or_default(), before_for);
    }
    (last_ident.unwrap_or_default(), None)
}

/// At a `fn` token: -> (bare name, line, params), or None when the next
/// token is not the function name.
pub fn parse_fn_sig(toks: &[Tok], i: usize) -> Option<(String, u32, Vec<Param>)> {
    let n = toks.len();
    if i + 1 >= n || toks[i + 1].kind != Kind::Ident {
        return None;
    }
    let bare = toks[i + 1].text.clone();
    let line = toks[i + 1].line;
    let mut j = i + 2;
    let mut depth = 0i32;
    while j < n {
        let t = &toks[j];
        if t.kind == Kind::Punct && t.text == "<" {
            depth += 1;
        } else if t.kind == Kind::Punct && t.text == ">" {
            depth = (depth - 1).max(0);
        } else if t.kind == Kind::Punct && t.text == "(" && depth == 0 {
            break;
        } else if t.kind == Kind::Punct && (t.text == ";" || t.text == "{") {
            return Some((bare, line, Vec::new()));
        }
        j += 1;
    }
    if j >= n {
        return Some((bare, line, Vec::new()));
    }
    let (params, _end) = parse_params(toks, j);
    Some((bare, line, params))
}

/// At the `(` of a param list: parse `[mut] name: Type` params.
pub fn parse_params(toks: &[Tok], mut j: usize) -> (Vec<Param>, usize) {
    let n = toks.len();
    let mut depth = 1i32;
    j += 1;
    let mut segs: Vec<Vec<Pair>> = Vec::new();
    let mut seg: Vec<Pair> = Vec::new();
    while j < n && depth > 0 {
        let t = &toks[j];
        if t.kind == Kind::Punct && (t.text == "(" || t.text == "[" || t.text == "<") {
            depth += 1;
            seg.push((t.kind, t.text.clone()));
        } else if t.kind == Kind::Punct && (t.text == ")" || t.text == "]" || t.text == ">") {
            depth -= 1;
            if depth == 0 {
                if !seg.is_empty() {
                    segs.push(seg);
                    seg = Vec::new();
                }
                break;
            }
            seg.push((t.kind, t.text.clone()));
        } else if t.kind == Kind::Punct && t.text == "," && depth == 1 {
            segs.push(seg);
            seg = Vec::new();
        } else {
            seg.push((t.kind, t.text.clone()));
        }
        j += 1;
    }
    let mut out: Vec<Param> = Vec::new();
    for seg in &segs {
        let mut k = 0usize;
        if k < seg.len() && seg[k].0 == Kind::Ident && seg[k].1 == "mut" {
            k += 1;
        }
        if k + 1 < seg.len()
            && seg[k].0 == Kind::Ident
            && seg[k + 1].0 == Kind::Punct
            && seg[k + 1].1 == ":"
        {
            out.push((seg[k].1.clone(), collect_type_idents(&seg[k + 2..])));
        }
    }
    (out, j + 1)
}

/// Pass 1: populate the symbol index.
pub struct IndexWalker<'a> {
    pub az: &'a mut Analyzer,
    pub file: String,
    pub toks: &'a [Tok],
    pub scopes: Vec<Scope>,
    pub pending_impl: Option<String>,
    pub pending_fn: Option<(String, u32, Vec<Param>)>,
    pub pending_cfg_test: bool,
}

impl<'a> IndexWalker<'a> {
    pub fn new(az: &'a mut Analyzer, file: &str, toks: &'a [Tok], dir_test: bool) -> IndexWalker<'a> {
        IndexWalker {
            az,
            file: file.to_string(),
            toks,
            scopes: vec![Scope::new(String::new(), None, dir_test, false)],
            pending_impl: None,
            pending_fn: None,
            pending_cfg_test: false,
        }
    }

    fn cur(&self) -> &Scope {
        self.scopes.last().unwrap()
    }

    fn cur_mut(&mut self) -> &mut Scope {
        self.scopes.last_mut().unwrap()
    }

    pub fn walk(&mut self) {
        let n = self.toks.len();
        let mut i = 0usize;
        while i < n {
            let kind = self.toks[i].kind;
            if kind == Kind::Punct {
                let text = self.toks[i].text.clone();
                i = self.punct(i, &text);
                continue;
            }
            if kind != Kind::Ident {
                i += 1;
                continue;
            }
            let text = self.toks[i].text.clone();
            if text == "impl" && impl_header_position(self.toks, i) {
                let (ty, trait_name) = parse_impl(self.toks, i);
                self.pending_impl = Some(ty.clone());
                if !ty.is_empty() {
                    self.az.index.tree_types.insert(ty.clone());
                }
                if let Some(tr) = trait_name {
                    self.az.index.traits.entry(tr).or_default().push(ty);
                }
                i += 1;
                continue;
            }
            if text == "struct" && is_kind(self.toks, i + 1, Kind::Ident) {
                i = self.parse_struct(i);
                continue;
            }
            if text == "type" && item_position(self.toks, i) {
                i = self.parse_alias(i);
                continue;
            }
            if text == "static" || text == "const" {
                i = self.parse_static(i);
                continue;
            }
            if text == "fn" {
                if let Some(sig) = parse_fn_sig(self.toks, i) {
                    self.pending_fn = Some(sig);
                }
                i += 2;
                continue;
            }
            i += 1;
        }
    }

    fn parse_struct(&mut self, i: usize) -> usize {
        let toks = self.toks;
        let n = toks.len();
        let name = toks[i + 1].text.clone();
        self.az.index.tree_types.insert(name.clone());
        let mut j = i + 2;
        let mut depth = 0i32;
        while j < n {
            let t = &toks[j];
            if t.kind == Kind::Punct && t.text == "<" {
                depth += 1;
            } else if t.kind == Kind::Punct && t.text == ">" {
                depth = (depth - 1).max(0);
            } else if t.kind == Kind::Punct && depth == 0 && (t.text == ";" || t.text == "(") {
                return j; // tuple / unit struct: no named fields
            } else if t.kind == Kind::Punct && depth == 0 && t.text == "{" {
                break;
            }
            j += 1;
        }
        if j >= n {
            return j;
        }
        // Named fields at brace depth 1: `name: Type,` entries.
        let mut fields: HashMap<String, Vec<String>> = HashMap::new();
        j += 1;
        let mut depth = 1i32;
        let mut field_name: Option<String> = None;
        let mut tybuf: Vec<Pair> = Vec::new();
        // 0 = expecting field name, 1 = expecting `:`, 2 = in type tokens.
        let mut expecting = 0u8;
        while j < n && depth > 0 {
            let t = &toks[j];
            if t.kind == Kind::Punct && matches!(t.text.as_str(), "{" | "(" | "[" | "<") {
                depth += 1;
                if expecting == 2 {
                    tybuf.push((t.kind, t.text.clone()));
                }
                j += 1;
                continue;
            }
            if t.kind == Kind::Punct && matches!(t.text.as_str(), "}" | ")" | "]" | ">") {
                depth -= 1;
                if depth == 0 {
                    break;
                }
                if expecting == 2 {
                    tybuf.push((t.kind, t.text.clone()));
                }
                j += 1;
                continue;
            }
            if depth == 1 {
                if t.kind == Kind::Punct && t.text == ":" && expecting == 1 {
                    expecting = 2;
                } else if t.kind == Kind::Punct && t.text == "," {
                    if let Some(fname) = field_name.take() {
                        if !tybuf.is_empty() {
                            fields.insert(fname, collect_type_idents(&tybuf));
                        }
                    }
                    tybuf = Vec::new();
                    expecting = 0;
                } else if expecting == 2 {
                    tybuf.push((t.kind, t.text.clone()));
                } else if t.kind == Kind::Ident && expecting == 0 && t.text != "pub" {
                    field_name = Some(t.text.clone());
                    expecting = 1;
                }
            } else if expecting == 2 {
                tybuf.push((t.kind, t.text.clone()));
            }
            j += 1;
        }
        if let Some(fname) = field_name {
            if !tybuf.is_empty() {
                fields.insert(fname, collect_type_idents(&tybuf));
            }
        }
        self.az.index.structs.insert(name, fields);
        j
    }

    fn parse_alias(&mut self, i: usize) -> usize {
        let toks = self.toks;
        let n = toks.len();
        if i + 1 >= n || toks[i + 1].kind != Kind::Ident {
            return i + 1;
        }
        let name = toks[i + 1].text.clone();
        let mut j = i + 2;
        let mut tybuf: Vec<Pair> = Vec::new();
        let mut seen_eq = false;
        while j < n {
            let t = &toks[j];
            if t.kind == Kind::Punct && t.text == ";" {
                break;
            }
            if seen_eq {
                tybuf.push((t.kind, t.text.clone()));
            }
            if t.kind == Kind::Punct && t.text == "=" {
                seen_eq = true;
            }
            j += 1;
        }
        if !tybuf.is_empty() {
            self.az.index.aliases.insert(name, collect_type_idents(&tybuf));
        }
        j
    }

    fn parse_static(&mut self, i: usize) -> usize {
        let toks = self.toks;
        let n = toks.len();
        if i + 2 >= n || toks[i + 1].kind != Kind::Ident || !is_p(toks, i + 2, ":") {
            return i + 1;
        }
        let name = toks[i + 1].text.clone();
        let mut j = i + 3;
        let mut tybuf: Vec<Pair> = Vec::new();
        while j < n {
            let t = &toks[j];
            if t.kind == Kind::Punct && (t.text == "=" || t.text == ";") {
                break;
            }
            tybuf.push((t.kind, t.text.clone()));
            j += 1;
        }
        if !tybuf.is_empty() {
            self.az.index.statics.insert((self.file.clone(), name), collect_type_idents(&tybuf));
        }
        j
    }

    fn punct(&mut self, i: usize, text: &str) -> usize {
        let toks = self.toks;
        if text == "#" {
            if is_p(toks, i + 1, "[")
                && is_i(toks, i + 2, "cfg")
                && is_p(toks, i + 3, "(")
                && is_i(toks, i + 4, "test")
                && is_p(toks, i + 5, ")")
            {
                self.pending_cfg_test = true;
            }
            return i + 1;
        }
        if text == ";" {
            if self.cur().paren == 0 {
                self.pending_fn = None; // trait method without a body
            }
            return i + 1;
        }
        if text == "(" || text == "[" {
            self.cur_mut().paren += 1;
            return i + 1;
        }
        if text == ")" || text == "]" {
            let sc = self.cur_mut();
            sc.paren = sc.paren.saturating_sub(1);
            return i + 1;
        }
        if text == "{" {
            let mut impl_type = self.cur().impl_type.clone();
            let mut fn_key = self.cur().fn_key.clone();
            let mut is_test = self.cur().is_test;
            if self.pending_cfg_test {
                is_test = true;
                self.pending_cfg_test = false;
            }
            if let Some(ty) = self.pending_impl.take() {
                impl_type = ty;
            }
            if let Some((bare, fl, params)) = self.pending_fn.take() {
                let key = format!("{}:{}:{}", self.file, fl, bare);
                fn_key = Some(key.clone());
                if !is_test {
                    let mut rec = FnRec::new(key, bare, impl_type.clone(), self.file.clone(), fl, is_test);
                    rec.params = params;
                    self.az.index.add_fn(rec);
                }
            }
            self.scopes.push(Scope::new(impl_type, fn_key, is_test, false));
            return i + 1;
        }
        if text == "}" {
            if self.scopes.len() > 1 {
                self.scopes.pop();
            }
            return i + 1;
        }
        i + 1
    }
}
