//! Hand-rolled Rust lexer: just enough structure for the lint passes.
//!
//! Produces idents, string literals (value only, escapes left raw),
//! numbers, lifetimes, and single-char puncts.  Comments are consumed
//! here, and `// lint:allow(rule, reason = "...")` escapes are parsed
//! out of line comments as a side channel keyed by line number.

use std::collections::HashMap;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Kind {
    Ident,
    Str,
    Num,
    Punct,
    Lifetime,
}

#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    pub line: u32,
}

/// line -> [(rule, has_reason)] for every `lint:allow` clause on it.
pub type Allows = HashMap<u32, Vec<(String, bool)>>;

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

pub fn tokenize(src: &str) -> (Vec<Tok>, Allows) {
    let s: Vec<char> = src.chars().collect();
    let n = s.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut allows: Allows = HashMap::new();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < n {
        let c = s[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == ' ' || c == '\t' || c == '\r' {
            i += 1;
            continue;
        }
        if c == '/' && i + 1 < n && s[i + 1] == '/' {
            let mut j = i;
            while j < n && s[j] != '\n' {
                j += 1;
            }
            let comment: String = s[i..j].iter().collect();
            parse_allow(&comment, line, &mut allows);
            i = j;
            continue;
        }
        if c == '/' && i + 1 < n && s[i + 1] == '*' {
            let mut depth = 1;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if s[j] == '\n' {
                    line += 1;
                    j += 1;
                } else if j + 1 < n && s[j] == '/' && s[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if j + 1 < n && s[j] == '*' && s[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            i = j;
            continue;
        }
        if (c == 'r' || c == 'b') && maybe_raw_string(&s, i) {
            let (ni, nl) = scan_raw_string(&s, i, line, &mut toks);
            i = ni;
            line = nl;
            continue;
        }
        if c == 'b' && i + 1 < n && s[i + 1] == '"' {
            let (ni, nl) = scan_string(&s, i + 1, line, &mut toks);
            i = ni;
            line = nl;
            continue;
        }
        if c == 'b' && i + 1 < n && s[i + 1] == '\'' {
            let (ni, nl) = scan_char(&s, i + 1, line);
            i = ni;
            line = nl;
            continue;
        }
        if c == '"' {
            let (ni, nl) = scan_string(&s, i, line, &mut toks);
            i = ni;
            line = nl;
            continue;
        }
        if c == '\'' {
            if i + 1 < n && s[i + 1] == '\\' {
                let (ni, nl) = scan_char(&s, i, line);
                i = ni;
                line = nl;
                continue;
            }
            if i + 2 < n && s[i + 2] == '\'' && s[i + 1] != '\'' {
                i += 3;
                continue;
            }
            let mut j = i + 1;
            while j < n && is_ident_char(s[j]) {
                j += 1;
            }
            toks.push(Tok { kind: Kind::Lifetime, text: s[i..j].iter().collect(), line });
            i = j;
            continue;
        }
        if is_ident_start(c) {
            let mut j = i;
            while j < n && is_ident_char(s[j]) {
                j += 1;
            }
            toks.push(Tok { kind: Kind::Ident, text: s[i..j].iter().collect(), line });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i;
            while j < n && is_ident_char(s[j]) {
                j += 1;
            }
            if j < n && s[j] == '.' && j + 1 < n && s[j + 1].is_ascii_digit() {
                j += 1;
                while j < n && is_ident_char(s[j]) {
                    j += 1;
                }
            }
            toks.push(Tok { kind: Kind::Num, text: s[i..j].iter().collect(), line });
            i = j;
            continue;
        }
        toks.push(Tok { kind: Kind::Punct, text: c.to_string(), line });
        i += 1;
    }
    (toks, allows)
}

fn maybe_raw_string(s: &[char], i: usize) -> bool {
    let n = s.len();
    let mut j = i;
    if s[j] == 'b' {
        j += 1;
    }
    if j >= n || s[j] != 'r' {
        return false;
    }
    j += 1;
    while j < n && s[j] == '#' {
        j += 1;
    }
    j < n && s[j] == '"'
}

fn scan_raw_string(s: &[char], i: usize, mut line: u32, toks: &mut Vec<Tok>) -> (usize, u32) {
    let n = s.len();
    let start_line = line;
    let mut j = i;
    if s[j] == 'b' {
        j += 1;
    }
    j += 1; // past `r`
    let mut hashes = 0usize;
    while j < n && s[j] == '#' {
        hashes += 1;
        j += 1;
    }
    j += 1; // past opening `"`
    let val_start = j;
    while j < n {
        if s[j] == '\n' {
            line += 1;
            j += 1;
        } else if s[j] == '"' && j + hashes < n && s[j + 1..j + 1 + hashes].iter().all(|&h| h == '#') {
            toks.push(Tok { kind: Kind::Str, text: s[val_start..j].iter().collect(), line: start_line });
            return (j + 1 + hashes, line);
        } else {
            j += 1;
        }
    }
    (j, line)
}

fn scan_string(s: &[char], i: usize, mut line: u32, toks: &mut Vec<Tok>) -> (usize, u32) {
    let n = s.len();
    let start_line = line;
    let mut j = i + 1;
    let val_start = j;
    while j < n {
        if s[j] == '\\' {
            j += 2;
        } else if s[j] == '\n' {
            line += 1;
            j += 1;
        } else if s[j] == '"' {
            toks.push(Tok { kind: Kind::Str, text: s[val_start..j].iter().collect(), line: start_line });
            return (j + 1, line);
        } else {
            j += 1;
        }
    }
    (j, line)
}

fn scan_char(s: &[char], i: usize, line: u32) -> (usize, u32) {
    let n = s.len();
    let mut j = i + 1;
    if j < n && s[j] == '\\' {
        j += 2;
        while j < n && s[j] != '\'' {
            j += 1;
        }
        return (j + 1, line);
    }
    (j + 2, line)
}

const ALLOW_MARK: &str = "lint:allow(";

/// Parse every `lint:allow(rule[, reason = "..."])` clause in a line
/// comment.  A comment that carries the marker but no well-formed clause
/// records a bare empty rule, which the analyzer reports as
/// `allow-unknown-rule` — malformed escapes must not silently suppress.
fn parse_allow(comment: &str, line: u32, allows: &mut Allows) {
    if !comment.contains(ALLOW_MARK) {
        return;
    }
    let cs: Vec<char> = comment.chars().collect();
    let mark: Vec<char> = ALLOW_MARK.chars().collect();
    let mut matched = false;
    let mut pos = 0usize;
    while let Some(start) = find_sub(&cs, &mark, pos) {
        match parse_allow_clause(&cs, start + mark.len()) {
            Some((rule, has_reason, end)) => {
                matched = true;
                allows.entry(line).or_default().push((rule, has_reason));
                pos = end;
            }
            None => {
                pos = start + 1;
            }
        }
    }
    if !matched {
        allows.entry(line).or_default().push((String::new(), false));
    }
}

fn find_sub(hay: &[char], needle: &[char], from: usize) -> Option<usize> {
    if needle.is_empty() || hay.len() < needle.len() {
        return None;
    }
    let mut i = from;
    while i + needle.len() <= hay.len() {
        if &hay[i..i + needle.len()] == needle {
            return Some(i);
        }
        i += 1;
    }
    None
}

fn skip_ws(cs: &[char], mut k: usize) -> usize {
    while k < cs.len() && cs[k].is_whitespace() {
        k += 1;
    }
    k
}

fn is_rule_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_' || c == '-'
}

/// At the char just past `lint:allow(`.  Returns (rule, has_reason, end).
fn parse_allow_clause(cs: &[char], k0: usize) -> Option<(String, bool, usize)> {
    let n = cs.len();
    let mut k = skip_ws(cs, k0);
    let rule_start = k;
    while k < n && is_rule_char(cs[k]) {
        k += 1;
    }
    if k == rule_start {
        return None;
    }
    let rule: String = cs[rule_start..k].iter().collect();
    k = skip_ws(cs, k);
    if k < n && cs[k] == ')' {
        return Some((rule, false, k + 1));
    }
    if k >= n || cs[k] != ',' {
        return None;
    }
    k = skip_ws(cs, k + 1);
    let word: Vec<char> = "reason".chars().collect();
    if k + word.len() > n || cs[k..k + word.len()] != word[..] {
        return None;
    }
    k = skip_ws(cs, k + word.len());
    if k >= n || cs[k] != '=' {
        return None;
    }
    k = skip_ws(cs, k + 1);
    if k >= n || cs[k] != '"' {
        return None;
    }
    k += 1;
    let reason_start = k;
    while k < n && cs[k] != '"' {
        k += 1;
    }
    if k >= n {
        return None;
    }
    let reason: String = cs[reason_start..k].iter().collect();
    k = skip_ws(cs, k + 1);
    if k >= n || cs[k] != ')' {
        return None;
    }
    Some((rule, !reason.trim().is_empty(), k + 1))
}
