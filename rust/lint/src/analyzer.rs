//! The analyzer: drives both walker passes over every file, then runs the
//! lock-graph, blocking-under-lock, and config/metric registry passes.

use std::collections::{BTreeSet, HashMap, HashSet};

use crate::body::BodyWalker;
use crate::index::{
    is_conf_accessor, is_direct_blocking, key_matches, metric_family, rule_severity, Finding,
    Index, LockSite,
};
use crate::lexer::{tokenize, Allows, Tok};
use crate::manifest::LockEnt;
use crate::walker::IndexWalker;

pub struct Analyzer {
    pub manifest_locks: Vec<LockEnt>,
    pub rank: HashMap<String, usize>,
    pub docs_dir: String,
    pub index: Index,
    pub lock_sites: Vec<LockSite>,
    /// (file, line, key, enclosing call, in_test)
    pub config_uses: Vec<(String, u32, String, String, bool)>,
    /// (file, line, family, in_test)
    pub metric_uses: Vec<(String, u32, String, bool)>,
    pub findings: Vec<Finding>,
    pub allows: HashMap<String, Allows>,
}

impl Analyzer {
    pub fn new(manifest_locks: Vec<LockEnt>, rank_order: Vec<String>, docs_dir: &str) -> Analyzer {
        let mut rank = HashMap::new();
        for (i, name) in rank_order.into_iter().enumerate() {
            rank.insert(name, i);
        }
        Analyzer {
            manifest_locks,
            rank,
            docs_dir: docs_dir.to_string(),
            index: Index::default(),
            lock_sites: Vec::new(),
            config_uses: Vec::new(),
            metric_uses: Vec::new(),
            findings: Vec::new(),
            allows: HashMap::new(),
        }
    }

    /// An allow on the finding's own line or the line above suppresses it.
    pub fn allowed(&self, file: &str, line: u32, rule: &str) -> bool {
        if let Some(per) = self.allows.get(file) {
            for ln in [line, line.saturating_sub(1)] {
                if let Some(entries) = per.get(&ln) {
                    for (r, _) in entries {
                        if r == rule {
                            return true;
                        }
                    }
                }
            }
        }
        false
    }

    pub fn add_finding(&mut self, file: &str, line: u32, rule: &str, msg: &str) {
        if self.allowed(file, line, rule) {
            return;
        }
        self.findings.push(Finding {
            file: file.to_string(),
            line,
            rule: rule.to_string(),
            msg: msg.to_string(),
        });
    }

    pub fn run(&mut self, files: &[String]) {
        let mut tokens: HashMap<String, Vec<Tok>> = HashMap::new();
        for f in files {
            let src = std::fs::read_to_string(f).unwrap_or_default();
            let (toks, allows) = tokenize(&src);
            tokens.insert(f.clone(), toks);
            self.allows.insert(f.clone(), allows);
        }
        // Allow hygiene: every escape must name a real rule and a reason.
        for f in files {
            let mut lines: Vec<u32> = self.allows.get(f).map(|a| a.keys().cloned().collect()).unwrap_or_default();
            lines.sort();
            for ln in lines {
                let entries = self.allows.get(f).and_then(|a| a.get(&ln)).cloned().unwrap_or_default();
                for (rule, has_reason) in entries {
                    if rule_severity(&rule).is_none() {
                        self.add_finding(
                            f,
                            ln,
                            "allow-unknown-rule",
                            &format!("lint:allow names unknown rule `{}`", rule),
                        );
                    } else if !has_reason {
                        self.add_finding(
                            f,
                            ln,
                            "allow-without-reason",
                            &format!("lint:allow({}) must carry a non-empty reason = \"...\"", rule),
                        );
                    }
                }
            }
        }
        for f in files {
            let toks = tokens.get(f).cloned().unwrap_or_default();
            IndexWalker::new(self, f, &toks, is_test_path(f)).walk();
        }
        for f in files {
            let toks = tokens.get(f).cloned().unwrap_or_default();
            BodyWalker::new(self, f, &toks, is_test_path(f)).walk();
        }
        self.graph_pass();
        self.blocking_pass();
        self.registry_pass();
        self.findings.sort_by(|a, b| {
            (&a.file, a.line, &a.rule, &a.msg).cmp(&(&b.file, b.line, &b.rule, &b.msg))
        });
    }

    /// Transitive may-acquire set per function (fixpoint over call edges).
    fn mayacq(&self) -> HashMap<String, HashSet<String>> {
        let mut acq: HashMap<String, HashSet<String>> = HashMap::new();
        for (k, f) in &self.index.fns {
            let mut set = HashSet::new();
            for (l, _) in &f.locks {
                set.insert(l.clone());
            }
            acq.insert(k.clone(), set);
        }
        let mut changed = true;
        while changed {
            changed = false;
            for (k, f) in &self.index.fns {
                for (_bare, keys, _held, _line) in &f.calls {
                    for ck in keys {
                        let extra: Vec<String> = match acq.get(ck) {
                            Some(cs) => {
                                let own = acq.get(k).cloned().unwrap_or_default();
                                cs.iter().filter(|l| !own.contains(*l)).cloned().collect()
                            }
                            None => Vec::new(),
                        };
                        if !extra.is_empty() {
                            acq.entry(k.clone()).or_default().extend(extra);
                            changed = true;
                        }
                    }
                }
            }
        }
        acq
    }

    /// Lock pass: unclassified sites, the acquired-while-held edge set,
    /// reentrancy, canonical-order violations, and cycle detection.
    fn graph_pass(&mut self) {
        let acq = self.mayacq();
        let mut edge_seen: HashSet<(String, String)> = HashSet::new();
        let mut edges: Vec<((String, String), (String, u32, Option<String>))> = Vec::new();
        let mut pend: Vec<(String, u32, String)> = Vec::new();
        for s in &self.lock_sites {
            if !s.classified {
                let tried = s
                    .cands
                    .iter()
                    .map(|c| format!("`{}`", c))
                    .collect::<Vec<_>>()
                    .join(", ");
                pend.push((
                    s.file.clone(),
                    s.line,
                    format!(
                        "lock site is not classified in lock-order.toml (candidate \
                         patterns: {}); add a [[lock]] entry (or a lint:allow \
                         with reason)",
                        tried
                    ),
                ));
            }
            for h in &s.held {
                let key = (h.clone(), s.lock_id.clone());
                if edge_seen.insert(key.clone()) {
                    edges.push((key, (s.file.clone(), s.line, None)));
                }
            }
        }
        for (_k, f) in &self.index.fns {
            for (bare, keys, held, line) in &f.calls {
                if held.is_empty() {
                    continue;
                }
                let mut targets: BTreeSet<String> = BTreeSet::new();
                for ck in keys {
                    if let Some(a) = acq.get(ck) {
                        targets.extend(a.iter().cloned());
                    }
                }
                for t in &targets {
                    for h in held {
                        let key = (h.clone(), t.clone());
                        if edge_seen.insert(key.clone()) {
                            edges.push((key, (f.file.clone(), *line, Some(bare.clone()))));
                        }
                    }
                }
            }
        }
        for (file, line, msg) in pend {
            self.add_finding(&file, line, "lock-unclassified", &msg);
        }
        edges.sort_by(|x, y| {
            (&x.1 .0, x.1 .1, &x.0).cmp(&(&y.1 .0, y.1 .1, &y.0))
        });
        let mut adj: HashMap<String, Vec<(String, String, u32, Option<String>)>> = HashMap::new();
        for ((a, b), (file, line, via)) in &edges {
            if a == b {
                let viatxt = match via {
                    Some(v) => format!(" (via call to `{}`)", v),
                    None => String::new(),
                };
                if !self.allowed(file, *line, "lock-reentrant") {
                    self.add_finding(
                        file,
                        *line,
                        "lock-reentrant",
                        &format!(
                            "lock `{}` may be re-acquired while already held{} — \
                             std::sync::Mutex self-deadlocks",
                            a, viatxt
                        ),
                    );
                }
                continue;
            }
            if !self.allowed(file, *line, "lock-order") {
                if let (Some(ra), Some(rb)) = (self.rank.get(a), self.rank.get(b)) {
                    if ra > rb {
                        let viatxt = match via {
                            Some(v) => format!(" via call to `{}`", v),
                            None => String::new(),
                        };
                        self.add_finding(
                            file,
                            *line,
                            "lock-order",
                            &format!(
                                "lock `{}` acquired{} while holding `{}`, but the canonical \
                                 order in lock-order.toml puts `{}` before `{}`",
                                b, viatxt, a, b, a
                            ),
                        );
                    }
                }
            }
            if !self.allowed(file, *line, "lock-cycle") {
                adj.entry(a.clone())
                    .or_default()
                    .push((b.clone(), file.clone(), *line, via.clone()));
            }
        }
        let mut color: HashMap<String, u8> = HashMap::new();
        let mut stack: Vec<(String, String, String, u32, Option<String>)> = Vec::new();
        let mut roots: Vec<String> = adj.keys().cloned().collect();
        roots.sort();
        for u in roots {
            if color.get(&u).copied().unwrap_or(0) == 0 {
                self.cycle_dfs(&u, &adj, &mut color, &mut stack);
            }
        }
    }

    fn cycle_dfs(
        &mut self,
        u: &str,
        adj: &HashMap<String, Vec<(String, String, u32, Option<String>)>>,
        color: &mut HashMap<String, u8>,
        stack: &mut Vec<(String, String, String, u32, Option<String>)>,
    ) {
        color.insert(u.to_string(), 1);
        for (v, file, line, via) in adj.get(u).cloned().unwrap_or_default() {
            let c = color.get(&v).copied().unwrap_or(0);
            if c == 0 {
                stack.push((u.to_string(), v.clone(), file, line, via));
                self.cycle_dfs(&v, adj, color, stack);
                stack.pop();
            } else if c == 1 {
                // Back edge: reconstruct the cycle from the DFS stack.
                let mut cyc: Vec<(String, String, String, u32, Option<String>)> =
                    vec![(u.to_string(), v.clone(), file, line, via)];
                for (a2, b2, f2, l2, v2) in stack.iter().rev() {
                    cyc.push((a2.clone(), b2.clone(), f2.clone(), *l2, v2.clone()));
                    if *a2 == v {
                        break;
                    }
                }
                cyc.reverse();
                let mut path = cyc.iter().map(|e| e.0.clone()).collect::<Vec<_>>().join(" -> ");
                path.push_str(&format!(" -> {}", cyc[cyc.len() - 1].1));
                let sites = cyc
                    .iter()
                    .map(|(_, _, f2, l2, _)| format!("{}:{}", f2, l2))
                    .collect::<Vec<_>>()
                    .join("; ");
                let (file0, line0) = (cyc[0].2.clone(), cyc[0].3);
                self.add_finding(
                    &file0,
                    line0,
                    "lock-cycle",
                    &format!("lock-order cycle: {} (edge sites: {})", path, sites),
                );
            }
        }
        color.insert(u.to_string(), 2);
    }

    /// Which functions may block, with a witness call chain to the
    /// primitive (fixpoint over call edges).
    fn mayblock(&self) -> HashMap<String, (String, Vec<String>)> {
        let mut blk: HashMap<String, (String, Vec<String>)> = HashMap::new();
        for (k, f) in &self.index.fns {
            if let Some((prim, _)) = f.blocks.first() {
                blk.insert(k.clone(), (prim.clone(), Vec::new()));
            }
        }
        let mut changed = true;
        while changed {
            changed = false;
            for (k, f) in &self.index.fns {
                if blk.contains_key(k) {
                    continue;
                }
                for (bare, keys, _held, _line) in &f.calls {
                    if is_direct_blocking(bare) {
                        continue;
                    }
                    let mut hit: Option<String> = None;
                    for ck in keys {
                        if blk.contains_key(ck) {
                            hit = Some(ck.clone());
                            break;
                        }
                    }
                    if let Some(h) = hit {
                        let (prim, chain) = blk.get(&h).cloned().unwrap();
                        let mut new_chain = vec![bare.clone()];
                        new_chain.extend(chain);
                        blk.insert(k.clone(), (prim, new_chain));
                        changed = true;
                        break;
                    }
                }
            }
        }
        blk
    }

    fn blocking_pass(&mut self) {
        let blk = self.mayblock();
        let mut pend: Vec<(String, u32, String)> = Vec::new();
        for (_k, f) in &self.index.fns {
            for (bare, keys, held, line) in &f.calls {
                if held.is_empty() {
                    continue;
                }
                let mut uniq: BTreeSet<String> = BTreeSet::new();
                uniq.extend(held.iter().cloned());
                let locks = uniq.into_iter().collect::<Vec<_>>().join(", ");
                if is_direct_blocking(bare) {
                    pend.push((
                        f.file.clone(),
                        *line,
                        format!("blocking call `{}` while holding lock(s) {}", bare, locks),
                    ));
                    continue;
                }
                let mut hit: Option<String> = None;
                for ck in keys {
                    if blk.contains_key(ck) {
                        hit = Some(ck.clone());
                        break;
                    }
                }
                if let Some(h) = hit {
                    let (prim, chain) = blk.get(&h).cloned().unwrap();
                    let mut via: Vec<String> = vec![bare.clone()];
                    via.extend(chain);
                    via.push(prim);
                    pend.push((
                        f.file.clone(),
                        *line,
                        format!(
                            "call to `{}` may block ({}) while holding lock(s) {}",
                            bare,
                            via.join(" -> "),
                            locks
                        ),
                    ));
                }
            }
        }
        for (file, line, msg) in pend {
            self.add_finding(&file, line, "blocking-under-lock", &msg);
        }
    }

    /// Config-key and metric registry: every production `tony.*` literal
    /// must be documented and read through the configuration layer; every
    /// `tony_*` family must be in docs/METRICS.md; doc drift (documented
    /// but never used) is flagged in the reverse direction too.
    fn registry_pass(&mut self) {
        let conf_doc = self.read_doc("CONFIGURATION.md");
        let metrics_doc = self.read_doc("METRICS.md");
        let feature_docs: &[(&str, &str)] =
            &[("tony.scheduler.", "SCHEDULING.md"), ("tony.trace.", "TRACING.md")];
        let mut feature_cache: HashMap<String, Option<String>> = HashMap::new();
        for (_, doc) in feature_docs {
            let body = self.read_doc(doc);
            feature_cache.insert(doc.to_string(), body);
        }
        let mut used_keys: HashSet<String> = HashSet::new();
        let uses = self.config_uses.clone();
        for (file, line, key, encl, in_test) in &uses {
            used_keys.insert(key.clone());
            if *in_test {
                continue;
            }
            if let Some(doc) = &conf_doc {
                if !doc.contains(key.as_str()) {
                    self.add_finding(
                        file,
                        *line,
                        "config-undocumented",
                        &format!("config key `{}` is not documented in docs/CONFIGURATION.md", key),
                    );
                }
            }
            for (prefix, doc_name) in feature_docs {
                if key.starts_with(*prefix) {
                    if let Some(Some(body)) = feature_cache.get(*doc_name) {
                        if !body.contains(key.as_str()) {
                            self.add_finding(
                                file,
                                *line,
                                "config-undocumented",
                                &format!("config key `{}` is not documented in docs/{}", key, doc_name),
                            );
                        }
                    }
                }
            }
            if !is_conf_accessor(encl) {
                let where_txt = if encl.is_empty() {
                    "no accessor call".to_string()
                } else {
                    format!("`{}(..)`", encl)
                };
                self.add_finding(
                    file,
                    *line,
                    "config-outside-conf",
                    &format!(
                        "config key `{}` used outside a tonyconf accessor ({}); \
                         read it through Configuration::get*/set",
                        key, where_txt
                    ),
                );
            }
        }
        let mut used_families: HashSet<String> = HashSet::new();
        for (_, _, fam, _) in &self.metric_uses {
            used_families.insert(fam.clone());
        }
        let muses = self.metric_uses.clone();
        for (file, line, fam, in_test) in &muses {
            if *in_test {
                continue;
            }
            if let Some(doc) = &metrics_doc {
                if !doc.contains(fam.as_str()) {
                    self.add_finding(
                        file,
                        *line,
                        "metric-undocumented",
                        &format!("metric family `{}` is not documented in docs/METRICS.md", fam),
                    );
                }
            }
        }
        if let Some(doc) = &conf_doc {
            let doc_path = format!("{}/CONFIGURATION.md", self.docs_dir);
            for (ln_no, key) in doc_table_keys(doc) {
                if !used_keys.contains(&key) {
                    self.add_finding(
                        &doc_path,
                        ln_no,
                        "config-stale-doc",
                        &format!("documented config key `{}` is never read by the code", key),
                    );
                }
            }
        }
        if let Some(doc) = &metrics_doc {
            let doc_path = format!("{}/METRICS.md", self.docs_dir);
            for (ln_no, fam) in doc_metric_families(doc) {
                if !used_families.contains(&fam) {
                    self.add_finding(
                        &doc_path,
                        ln_no,
                        "metric-stale-doc",
                        &format!("documented metric family `{}` is never emitted by the code", fam),
                    );
                }
            }
        }
    }

    fn read_doc(&self, name: &str) -> Option<String> {
        std::fs::read_to_string(format!("{}/{}", self.docs_dir, name)).ok()
    }
}

/// Files under a `tests/` or `benches/` directory are test code: lock and
/// blocking analyses skip them (they exercise, not implement, the control
/// plane), though the thread-sleep ban still applies.
/// Paths under `tests/` or `benches/` get the relaxed test-code scope
/// (lock and blocking passes skip them).  A `fixtures/` segment opts back
/// in: the lint's own fixture corpus lives at `rust/lint/tests/fixtures/`
/// and must be analyzed as production code for the seeded violations to
/// fire.
pub fn is_test_path(f: &str) -> bool {
    let norm = f.replace('\\', "/");
    if norm.split('/').any(|p| p == "fixtures") {
        return false;
    }
    norm.split('/').any(|p| p == "tests" || p == "benches")
}

/// First backticked token of each markdown table row, when it is a key.
pub fn doc_table_keys(doc: &str) -> Vec<(u32, String)> {
    let mut out = Vec::new();
    let mut seen: HashSet<String> = HashSet::new();
    for (i, line) in doc.split('\n').enumerate() {
        let t = line.trim();
        if !t.starts_with('|') {
            continue;
        }
        let rest: &str = t[1..].trim_start();
        if !rest.starts_with('`') {
            continue;
        }
        let inner = &rest[1..];
        let end = match inner.find('`') {
            Some(e) => e,
            None => continue,
        };
        let key = &inner[..end];
        if key_matches(key) && !seen.contains(key) {
            seen.insert(key.to_string());
            out.push((i as u32 + 1, key.to_string()));
        }
    }
    out
}

/// Every `tony_*` token mentioned anywhere in the doc, collapsed to
/// families, first-mention line.
pub fn doc_metric_families(doc: &str) -> Vec<(u32, String)> {
    let mut out = Vec::new();
    let mut seen: HashSet<String> = HashSet::new();
    for (i, line) in doc.split('\n').enumerate() {
        let cs: Vec<char> = line.chars().collect();
        let mut k = 0usize;
        while k < cs.len() {
            if cs[k] == 't' && matches_at(&cs, k, "tony_") {
                let mut e = k + "tony_".len();
                while e < cs.len()
                    && (cs[e].is_ascii_lowercase() || cs[e].is_ascii_digit() || cs[e] == '_')
                {
                    e += 1;
                }
                if e > k + "tony_".len() {
                    let tok: String = cs[k..e].iter().collect();
                    let fam = metric_family(&tok);
                    if !seen.contains(&fam) {
                        seen.insert(fam.clone());
                        out.push((i as u32 + 1, fam));
                    }
                    k = e;
                    continue;
                }
            }
            k += 1;
        }
    }
    out
}

fn matches_at(cs: &[char], k: usize, pat: &str) -> bool {
    let pc: Vec<char> = pat.chars().collect();
    k + pc.len() <= cs.len() && cs[k..k + pc.len()] == pc[..]
}

/// Expand paths to a sorted, deduped list of `.rs` files.
pub fn collect_files(paths: &[String]) -> Vec<String> {
    let mut out: Vec<String> = Vec::new();
    for p in paths {
        let is_file = std::fs::metadata(p).map(|m| m.is_file()).unwrap_or(false);
        if is_file {
            out.push(p.clone());
        } else {
            walk_dir(p, &mut out);
        }
    }
    out.sort();
    out.dedup();
    out
}

fn walk_dir(dir: &str, out: &mut Vec<String>) {
    let rd = match std::fs::read_dir(dir) {
        Ok(r) => r,
        Err(_) => return,
    };
    for ent in rd.flatten() {
        let name = ent.file_name().to_string_lossy().to_string();
        let path = format!("{}/{}", dir, name);
        let ft = match ent.file_type() {
            Ok(t) => t,
            Err(_) => continue,
        };
        if ft.is_dir() {
            walk_dir(&path, out);
        } else if name.ends_with(".rs") {
            out.push(path);
        }
    }
}
