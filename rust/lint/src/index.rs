//! Shared data model: rule table, the global symbol index built by
//! pass 1, and the finding / lock-site records the passes emit.

use std::collections::{BTreeMap, HashMap, HashSet};

use crate::lexer::Kind;

/// (rule, severity).  Severity is `error` or `warning`; `--deny warnings`
/// promotes warnings to exit-code failures.
pub const RULES: &[(&str, &str)] = &[
    ("lock-cycle", "error"),
    ("lock-reentrant", "error"),
    ("lock-order", "error"),
    ("lock-unclassified", "warning"),
    ("blocking-under-lock", "warning"),
    ("thread-sleep", "error"),
    ("config-undocumented", "warning"),
    ("config-outside-conf", "warning"),
    ("config-stale-doc", "warning"),
    ("metric-undocumented", "warning"),
    ("metric-stale-doc", "warning"),
    ("allow-without-reason", "error"),
    ("allow-unknown-rule", "error"),
];

pub fn rule_severity(rule: &str) -> Option<&'static str> {
    for (r, s) in RULES {
        if *r == rule {
            return Some(s);
        }
    }
    None
}

/// Method / function names that block the calling thread directly.
pub const DIRECT_BLOCKING: &[&str] = &[
    "real_sleep", "sleep", "wait", "wait_timeout", "wait_while", "park",
    "park_timeout", "recv", "recv_timeout", "recv_deadline", "join",
    "connect", "accept", "read_to_end", "read_to_string", "read_exact",
    "write_all", "sync_all", "sync_data", "wait_until", "wait_seq",
    "pop_wait",
];

pub fn is_direct_blocking(name: &str) -> bool {
    DIRECT_BLOCKING.contains(&name)
}

/// Idents that look like calls lexically but are control flow / patterns.
pub const KEYWORDS: &[&str] = &[
    "if", "while", "for", "match", "return", "loop", "in", "as", "move",
    "ref", "else", "box", "async", "await", "dyn", "let", "fn", "impl",
    "pub", "use", "mod", "where", "unsafe", "Some", "None", "Ok", "Err",
];

pub fn is_keyword(name: &str) -> bool {
    KEYWORDS.contains(&name)
}

/// Enclosing call names under which a `"tony.*"` literal counts as read
/// through the configuration layer (`format` covers key construction).
pub const CONF_ACCESSORS: &[&str] = &[
    "set", "get", "get_raw", "get_or", "get_u64", "get_u32", "get_f64",
    "get_bool", "get_size", "with_prefix", "format",
];

pub fn is_conf_accessor(name: &str) -> bool {
    CONF_ACCESSORS.contains(&name)
}

/// Transparent wrappers skipped when resolving a type-ident chain to a
/// core (possibly tree-defined) type.
pub const WRAPPERS: &[&str] = &[
    "Arc", "Rc", "Box", "Weak", "Mutex", "RwLock", "Option", "RefCell",
    "dyn", "mut", "r#dyn",
];

pub fn is_wrapper(name: &str) -> bool {
    WRAPPERS.contains(&name)
}

/// (kind, text) pair — a token stripped of its line, used for type buffers.
pub type Pair = (Kind, String);

/// Idents from a type-token buffer, skipping `path::` prefix segments so
/// `std::sync::Arc<AmState>` yields `[Arc, AmState]`, not `[std, sync, ..]`.
pub fn collect_type_idents(pairs: &[Pair]) -> Vec<String> {
    let mut out = Vec::new();
    for t in 0..pairs.len() {
        if pairs[t].0 != Kind::Ident {
            continue;
        }
        if t + 2 < pairs.len()
            && pairs[t + 1].0 == Kind::Punct
            && pairs[t + 1].1 == ":"
            && pairs[t + 2].0 == Kind::Punct
            && pairs[t + 2].1 == ":"
        {
            continue;
        }
        out.push(pairs[t].1.clone());
    }
    out
}

/// Parameter: (name, declared type-ident list).
pub type Param = (String, Vec<String>);

/// One function (or spawn-closure pseudo-function) in the tree.
pub struct FnRec {
    pub key: String,
    pub bare: String,
    pub impl_type: String,
    pub file: String,
    pub line: u32,
    pub is_test: bool,
    pub params: Vec<Param>,
    /// (lock name, line) for every lock site in the body.
    pub locks: Vec<(String, u32)>,
    /// (bare callee, resolved fn keys, locks held at the call, line).
    pub calls: Vec<(String, Vec<String>, Vec<String>, u32)>,
    /// (blocking primitive, line) for direct blocking calls in the body.
    pub blocks: Vec<(String, u32)>,
}

impl FnRec {
    pub fn new(key: String, bare: String, impl_type: String, file: String, line: u32, is_test: bool) -> FnRec {
        FnRec {
            key,
            bare,
            impl_type,
            file,
            line,
            is_test,
            params: Vec::new(),
            locks: Vec::new(),
            calls: Vec::new(),
            blocks: Vec::new(),
        }
    }
}

/// Global symbol index built by pass 1 and consulted (and extended with
/// spawn pseudo-fns) by pass 2.
#[derive(Default)]
pub struct Index {
    /// struct name -> field name -> declared type-ident list.
    pub structs: HashMap<String, HashMap<String, Vec<String>>>,
    /// type alias -> aliased type-ident list.
    pub aliases: HashMap<String, Vec<String>>,
    /// trait name -> impl'ing type names (for trait-typed receivers).
    pub traits: HashMap<String, Vec<String>>,
    /// fn key (`file:line:bare`) -> record.  BTreeMap: the fixpoint and
    /// reporting passes iterate in deterministic key order.
    pub fns: BTreeMap<String, FnRec>,
    /// (impl type, bare name) -> fn keys.
    pub by_type: HashMap<(String, String), Vec<String>>,
    /// bare name -> fn keys for free functions.
    pub free: HashMap<String, Vec<String>>,
    /// (file, static/const name) -> declared type-ident list.
    pub statics: HashMap<(String, String), Vec<String>>,
    /// Every type defined (struct) or impl'd in the linted tree.
    pub tree_types: HashSet<String>,
}

impl Index {
    pub fn add_fn(&mut self, rec: FnRec) {
        if !rec.impl_type.is_empty() {
            self.by_type
                .entry((rec.impl_type.clone(), rec.bare.clone()))
                .or_default()
                .push(rec.key.clone());
        } else {
            self.free.entry(rec.bare.clone()).or_default().push(rec.key.clone());
        }
        self.fns.insert(rec.key.clone(), rec);
    }

    /// First non-wrapper ident, with aliases expanded (depth-capped).
    pub fn core_type(&self, tylist: &[String], depth: u32) -> Option<String> {
        if depth > 4 {
            return None;
        }
        for t in tylist {
            if is_wrapper(t) {
                continue;
            }
            if let Some(al) = self.aliases.get(t) {
                let al = al.clone();
                return self.core_type(&al, depth + 1);
            }
            return Some(t.clone());
        }
        None
    }

    /// Core type guarded by the first `Mutex`/`RwLock` in the list, if any.
    pub fn mutex_inner(&self, tylist: &[String], depth: u32) -> Option<String> {
        if depth > 4 {
            return None;
        }
        let mut exp: Vec<String> = Vec::new();
        for t in tylist {
            if depth < 4 {
                if let Some(al) = self.aliases.get(t) {
                    exp.extend(al.iter().cloned());
                    continue;
                }
            }
            exp.push(t.clone());
        }
        for k in 0..exp.len() {
            if exp[k] == "Mutex" || exp[k] == "RwLock" {
                return self.core_type(&exp[k + 1..], depth + 1);
            }
        }
        None
    }

    pub fn field_type(&self, ty: &str, field: &str) -> Option<Vec<String>> {
        self.structs.get(ty)?.get(field).cloned()
    }
}

#[derive(Clone, Debug)]
pub struct Finding {
    pub file: String,
    pub line: u32,
    pub rule: String,
    pub msg: String,
}

impl Finding {
    pub fn severity(&self) -> &'static str {
        rule_severity(&self.rule).unwrap_or("error")
    }

    pub fn render(&self) -> String {
        format!("{}:{} · {} · {} · {}", self.file, self.line, self.rule, self.severity(), self.msg)
    }
}

/// One `.lock()` call site, after classification against the manifest.
pub struct LockSite {
    pub file: String,
    pub line: u32,
    pub lock_id: String,
    pub classified: bool,
    pub held: Vec<String>,
    pub fn_key: Option<String>,
    pub cands: Vec<String>,
}

/// `tony.*` key must match `tony` + dot-separated `[a-z0-9-]+` / `<ty>`
/// segments, at least one.
pub fn key_matches(s: &str) -> bool {
    let parts: Vec<&str> = s.split('.').collect();
    if parts.len() < 2 || parts[0] != "tony" {
        return false;
    }
    for p in &parts[1..] {
        if *p == "<ty>" {
            continue;
        }
        if p.is_empty() || !p.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '-') {
            return false;
        }
    }
    true
}

/// Replace `{...}` format holes with `<ty>` so format!-built keys
/// normalize to one registry entry (`tony.{ty}.instances` and
/// `format!("tony.{}.instances", ty)` both become `tony.<ty>.instances`).
pub fn normalize_key(s: &str) -> String {
    let cs: Vec<char> = s.chars().collect();
    let mut out = String::new();
    let mut i = 0usize;
    while i < cs.len() {
        if cs[i] == '{' {
            let mut j = i + 1;
            while j < cs.len() && cs[j] != '}' {
                j += 1;
            }
            if j < cs.len() {
                out.push_str("<ty>");
                i = j + 1;
                continue;
            }
        }
        out.push(cs[i]);
        i += 1;
    }
    out
}

/// `tony_*` metric literal check (full-string match).
pub fn metric_matches(s: &str) -> bool {
    if !s.starts_with("tony_") || s.len() <= "tony_".len() {
        return false;
    }
    s["tony_".len()..]
        .chars()
        .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

/// Histogram series collapse to their family name.
pub fn metric_family(name: &str) -> String {
    for suf in ["_bucket", "_sum", "_count"] {
        if let Some(fam) = name.strip_suffix(suf) {
            return fam.to_string();
        }
    }
    name.to_string()
}
