//! Fixture: TCP connect while a mutex guard is live ->
//! `blocking-under-lock`.  Never compiled; analyzer input only.

use std::sync::Mutex;

pub struct Queue {
    items: Mutex<Vec<u64>>,
}

impl Queue {
    pub fn drain_slowly(&self) {
        let q = self.items.lock().unwrap();
        let _probe = std::net::TcpStream::connect("127.0.0.1:9000");
        drop(q);
    }
}
