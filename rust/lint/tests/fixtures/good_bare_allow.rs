//! Fixture: the annotated-good twin of bad_bare_allow.rs — the waiver
//! names a real rule and carries a non-empty reason, so the sleep on
//! the next line is suppressed and the allow itself is hygienic.

pub fn nap_with_cause() {
    // lint:allow(thread-sleep, reason = "fixture: demonstrates the documented escape hatch")
    std::thread::sleep(std::time::Duration::from_millis(5));
}
