//! Fixture: two locks acquired in both orders -> `lock-cycle` (and a
//! `lock-order` rank violation on the back edge).  Never compiled; this
//! file is input data for the analyzer tests.

use std::sync::Mutex;

pub struct State {
    alpha: Mutex<u64>,
    beta: Mutex<u64>,
}

impl State {
    pub fn forward(&self) -> u64 {
        let a = self.alpha.lock().unwrap();
        let b = self.beta.lock().unwrap();
        *a + *b
    }

    pub fn backward(&self) -> u64 {
        let b = self.beta.lock().unwrap();
        let a = self.alpha.lock().unwrap();
        *b - *a
    }
}
