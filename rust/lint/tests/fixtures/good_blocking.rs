//! Fixture: the annotated-good twin of bad_blocking.rs.  One variant
//! releases the guard before the blocking call; the other keeps the
//! violation but documents it with a reasoned `lint:allow`, which is
//! the sanctioned escape hatch.

use std::sync::Mutex;

pub struct Queue {
    items: Mutex<Vec<u64>>,
}

impl Queue {
    pub fn drain_politely(&self) {
        let q = self.items.lock().unwrap();
        let target = q.len();
        drop(q);
        let _probe = std::net::TcpStream::connect("127.0.0.1:9000");
        let _ = target;
    }

    pub fn drain_with_waiver(&self) {
        let q = self.items.lock().unwrap();
        // lint:allow(blocking-under-lock, reason = "fixture: demonstrates a reasoned waiver; the probe is bounded")
        let _probe = std::net::TcpStream::connect("127.0.0.1:9000");
        drop(q);
    }
}
