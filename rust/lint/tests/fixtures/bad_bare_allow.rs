//! Fixture: a `lint:allow` with no reason -> `allow-without-reason`
//! (an error: waivers must say why), plus an allow naming a rule the
//! analyzer does not know -> `allow-unknown-rule`.

pub fn nap() {
    // lint:allow(thread-sleep)
    std::thread::sleep(std::time::Duration::from_millis(5));
}

pub fn nap_again() {
    // lint:allow(no-such-rule, reason = "fixture: the rule name is misspelled")
    std::thread::sleep(std::time::Duration::from_millis(5));
}
