//! Fixture: a `tony.*` config literal that is absent from the fixture
//! docs table -> `config-undocumented`.  A second read bypasses the
//! tonyconf accessors -> `config-outside-conf`.

pub fn read_timeout(conf: &Configuration) -> u64 {
    conf.get_u64("tony.fixture.bogus-timeout-ms", 30_000)
}

pub fn read_raw(env: &Env) -> Option<String> {
    env.lookup("tony.fixture.documented-key")
}
