//! Fixture: the annotated-good twin of bad_undocumented_key.rs — the
//! key is listed in fixtures/docs/CONFIGURATION.md and is read through
//! a tonyconf accessor.

pub fn read_timeout(conf: &Configuration) -> u64 {
    conf.get_u64("tony.fixture.documented-key", 30_000)
}
