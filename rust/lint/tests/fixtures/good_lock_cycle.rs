//! Fixture: the annotated-good twin of bad_lock_cycle.rs — both paths
//! take `alpha` before `beta`, matching the manifest rank, so the
//! acquired-while-held graph is acyclic and ordered.

use std::sync::Mutex;

pub struct State {
    alpha: Mutex<u64>,
    beta: Mutex<u64>,
}

impl State {
    pub fn forward(&self) -> u64 {
        let a = self.alpha.lock().unwrap();
        let b = self.beta.lock().unwrap();
        *a + *b
    }

    pub fn also_forward(&self) -> u64 {
        let a = self.alpha.lock().unwrap();
        let b = self.beta.lock().unwrap();
        *a - *b
    }
}
