//! Fixture-corpus tests: each seeded violation under `tests/fixtures/`
//! must fire its rule, and the annotated-good twins must lint clean.
//!
//! Cargo runs integration tests with the package root (`rust/lint`) as
//! the working directory, so all paths here are relative to it.

const MANIFEST: &str = "tests/fixtures/lock-order.toml";
const DOCS: &str = "tests/fixtures/docs";

fn lint(files: &[&str]) -> tony_lint::LintOutcome {
    let paths: Vec<String> = files
        .iter()
        .map(|f| format!("tests/fixtures/{}", f))
        .collect();
    tony_lint::run(MANIFEST, DOCS, &paths)
}

fn rules(out: &tony_lint::LintOutcome) -> Vec<String> {
    out.findings.iter().map(|f| f.rule.clone()).collect()
}

#[test]
fn bad_lock_cycle_fires() {
    let out = lint(&["bad_lock_cycle.rs"]);
    let rs = rules(&out);
    assert!(
        rs.iter().any(|r| r == "lock-cycle"),
        "expected lock-cycle, got: {:?}",
        rs
    );
    assert!(
        rs.iter().any(|r| r == "lock-order"),
        "the alpha-after-beta edge must also violate the manifest rank, got: {:?}",
        rs
    );
    assert!(out.errors > 0, "lock-cycle is an error");
}

#[test]
fn bad_blocking_fires() {
    let out = lint(&["bad_blocking.rs"]);
    let blocking: Vec<&tony_lint::index::Finding> = out
        .findings
        .iter()
        .filter(|f| f.rule == "blocking-under-lock")
        .collect();
    assert!(
        !blocking.is_empty(),
        "expected blocking-under-lock, got: {:?}",
        rules(&out)
    );
    // The message names both the blocking call and the held lock.
    assert!(blocking[0].msg.contains("connect"), "msg: {}", blocking[0].msg);
    assert!(
        blocking[0].msg.contains("queue-items"),
        "held lock must be attributed by manifest name, msg: {}",
        blocking[0].msg
    );
}

#[test]
fn bad_undocumented_key_fires() {
    let out = lint(&["bad_undocumented_key.rs"]);
    let rs = rules(&out);
    assert!(
        rs.iter().any(|r| r == "config-undocumented"),
        "expected config-undocumented, got: {:?}",
        rs
    );
    assert!(
        rs.iter().any(|r| r == "config-outside-conf"),
        "the env.lookup() read must flag config-outside-conf, got: {:?}",
        rs
    );
}

#[test]
fn bad_bare_allow_fires() {
    let out = lint(&["bad_bare_allow.rs"]);
    let rs = rules(&out);
    assert!(
        rs.iter().any(|r| r == "allow-without-reason"),
        "expected allow-without-reason, got: {:?}",
        rs
    );
    assert!(
        rs.iter().any(|r| r == "allow-unknown-rule"),
        "expected allow-unknown-rule for the misspelled rule, got: {:?}",
        rs
    );
    assert!(out.errors >= 2, "allow hygiene violations are errors");
}

#[test]
fn good_fixtures_are_clean() {
    // Linted together so the documented fixture key is also *used*,
    // keeping config-stale-doc quiet — mirroring how the real tree is
    // linted as one sweep.
    let out = lint(&[
        "good_lock_cycle.rs",
        "good_blocking.rs",
        "good_undocumented_key.rs",
        "good_bare_allow.rs",
    ]);
    assert!(
        out.clean(),
        "good fixtures must lint clean, got: {:?}",
        out.findings.iter().map(|f| f.render()).collect::<Vec<_>>()
    );
}

#[test]
fn exit_code_contract() {
    // The bad corpus fails under --deny warnings; the good corpus passes.
    let bad = lint(&["bad_lock_cycle.rs"]);
    assert!(bad.failed(true));
    assert!(bad.failed(false), "errors fail even without --deny");
    let good = lint(&[
        "good_lock_cycle.rs",
        "good_blocking.rs",
        "good_undocumented_key.rs",
        "good_bare_allow.rs",
    ]);
    assert!(!good.failed(true));
}
