//! Self-check: the real TonY tree must lint clean with the real lock
//! manifest and the real docs registry.  This is the same sweep
//! scripts/ci.sh runs (`cargo run -p tony-lint -- --deny warnings ...`),
//! expressed as a test so `cargo test` alone catches drift.
//!
//! Cargo runs integration tests with `rust/lint` as the working
//! directory; the tree paths below are relative to it.  The `tests/` and
//! `benches/` trees get the relaxed test-code scope (no lock/blocking
//! analysis), but allow hygiene and the sleep ban still apply there.

#[test]
fn real_tree_lints_clean() {
    let paths: Vec<String> = ["../src", "../benches", "../tests", "../../examples"]
        .iter()
        .map(|p| p.to_string())
        .collect();
    let out = tony_lint::run("lock-order.toml", "../../docs", &paths);
    assert!(
        out.clean(),
        "the tree must carry zero findings; found {} error(s), {} warning(s):\n{}",
        out.errors,
        out.warnings,
        out.findings
            .iter()
            .map(|f| f.render())
            .collect::<Vec<_>>()
            .join("\n")
    );
}
