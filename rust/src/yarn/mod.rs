//! A faithful-in-structure Hadoop YARN simulator — the cluster scheduler
//! substrate TonY negotiates with (paper §2.2).
//!
//! What is reproduced from YARN:
//! - **ResourceManager (RM)**: application lifecycle (submit → AM launch →
//!   AM registration → allocate heartbeats → finish), container
//!   allocation/release protocol, completed-container notifications, node
//!   tracking and failure propagation.
//! - **CapacityScheduler**: hierarchical queues with capacity /
//!   max-capacity fractions, FIFO within a queue, node-label partitions
//!   (e.g. `gpu`, `high-memory`), heterogeneous resource requests
//!   (memory / vcores / GPUs per ask — §2.2's GPU-workers + CPU-only-PS),
//!   plus gang (all-or-nothing) placement with reservations and
//!   cross-queue capacity preemption (`docs/SCHEDULING.md`).
//! - **NodeManagers (NM)**: per-node capacities, container start/stop,
//!   liveness, failure injection (a killed node kills its containers and
//!   the RM reports them lost to the owning AM).
//!
//! What is simulated: nodes are structs, containers are threads launched
//! with a [`ContainerCtx`] whose kill-flag stands in for SIGKILL, and the
//! client/AM protocols are method calls on `Arc<ResourceManager>` instead
//! of Hadoop RPC.  The *protocol structure* — who asks whom for what, in
//! which order, and what failure events propagate — matches YARN.

pub mod container;
pub mod node;
pub mod resources;
pub mod rm;
pub mod scheduler;

pub use container::{Container, ContainerCtx, ContainerRequest, ContainerStatus, ExitStatus, KillSwitch};
pub use node::{NodeHandle, NodeSpec};
pub use resources::Resource;
pub use rm::{
    AllocateResponse, AppReport, AppSchedState, AppState, QueueStat, ResourceManager, RmConf,
    SubmissionContext,
};
pub use scheduler::{
    AskIntake, CapacityScheduler, ElasticProfile, QueueConf, QueueSnapshot, SchedStats,
    SchedulerConf, VictimCandidate,
};
