//! CapacityScheduler: hierarchical-capacity queue scheduling over
//! label-partitioned nodes, with **gang (all-or-nothing) placement**,
//! **reservations**, and **cross-queue capacity preemption**.
//!
//! Pure logic (no threads, no clock) so it is directly unit- and
//! property-testable: the scheduler owns the node table
//! ([`CapacityScheduler::set_nodes`] and friends) and
//! [`CapacityScheduler::schedule`] returns grants; the RM applies them.
//! Invariants enforced here and checked by
//! `rust/tests/prop_scheduler.rs`:
//!
//! 1. a grant never exceeds the free capacity of its node (no dimension
//!    oversubscribes),
//! 2. label partitions are respected (an ask with label L is only placed
//!    on nodes with label L; unlabeled asks go to unlabeled nodes),
//! 3. a queue's usage never exceeds `max_capacity` × cluster total
//!    (dominant-share),
//! 4. FIFO order within a queue per priority level, and
//! 5. a **gang** (asks sharing a gang id) is granted fully or not at all
//!    — never partially, which is what prevents the classic distributed-
//!    training deadlock where two jobs each hold half their workers and
//!    wait forever for the other half (see `docs/SCHEDULING.md`).
//!
//! # Placement complexity
//!
//! The hot path is indexed so a 10k-node cluster does not pay a linear
//! node scan per candidate (see `docs/SCHEDULING.md` § placement
//! complexity):
//!
//! * **Per-label free-capacity skylines** — for every node label the
//!   scheduler keeps a `BTreeSet<(free_memory_mb, node_index)>` (and a
//!   twin over `capacity` for reservation dry-runs).  Best-fit is the
//!   first fitting entry of `range((ask_mem, 0)..)` — O(log n) to seek,
//!   and the ascending scan stops at the first node whose free vector
//!   fits, which *is* the minimal `(leftover, index)` choice the linear
//!   reference makes.  The index is maintained incrementally by
//!   [`CapacityScheduler::set_free`] on every grant, release, and
//!   preemption free.
//! * **Incremental dominant-share accounting** — each queue caches its
//!   `dominant_share` and relative usage, refreshed only when `used`
//!   or the cluster total changes, so headroom/ceiling/preemption
//!   checks stop recomputing shares per pass.
//! * **Cached gang/reservation counters** — `Queue::gang_asks` (gang id
//!   → pending ask count) and `Queue::reserved` replace the
//!   `pending.iter().any(..)` / `reservations.iter().filter(..)` scans
//!   that gate the singles fast path and feed queue snapshots.
//! * Dry-runs ([`CapacityScheduler::place_asks`]) never touch the live
//!   index: tentative placements go to a small per-gang **overlay** that
//!   shadows the indexed values, so a failed gang placement costs no
//!   index churn.
//!
//! `tony.scheduler.placement-index=false` flips every candidate search
//! back to the retained linear reference scan (same semantics, O(n));
//! the property suite asserts indexed ≡ linear on randomized sequences.
//!
//! Blocked gangs take **reservations**: up to `reservation_limit` gangs
//! that are feasible at node *capacity* but not at current *free* claim
//! the node set a dry-run placement chose; reserved nodes accept no new
//! placements from anyone else, so the gang accumulates claim on
//! draining nodes instead of being starved by a stream of small asks.
//!
//! **Preemption** ([`CapacityScheduler::preemption_plan`]) restores a
//! queue to its guaranteed capacity: when an under-guarantee queue has a
//! placeable-but-blocked gang, victims are selected from queues over
//! their guarantee — newest grants first, whole gangs last — until a
//! simulated placement of the gang succeeds.  A round is all-or-nothing
//! (no victims are proposed unless they actually unblock the gang) and
//! never drives a victim queue below its own guarantee.
//!
//! # Example
//!
//! ```
//! use tony::util::ids::ApplicationId;
//! use tony::yarn::scheduler::SchedNode;
//! use tony::yarn::{CapacityScheduler, ContainerRequest, QueueConf, Resource};
//!
//! let mut sched = CapacityScheduler::new(QueueConf::default_only(), Resource::new(4096, 8, 0));
//! sched.set_nodes(vec![
//!     SchedNode::new(0, None, Resource::new(2048, 4, 0)),
//!     SchedNode::new(1, None, Resource::new(2048, 4, 0)),
//! ]);
//! let app = ApplicationId { cluster_ts: 1, seq: 1 };
//! // A gang of three 1 GiB workers: placed all-or-nothing.
//! let intake = sched.add_asks_gang(
//!     app,
//!     "default",
//!     &[ContainerRequest::new(Resource::new(1024, 1, 0), 3)],
//!     0,
//!     Some(1),
//! );
//! assert!(!intake.remapped);
//! let grants = sched.schedule();
//! assert_eq!(grants.len(), 3, "the whole gang fits, so the whole gang lands");
//! ```

use std::cmp::Reverse;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap, HashMap, VecDeque};
use std::sync::Arc;

use crate::util::ids::{ApplicationId, ContainerId, NodeId};
use crate::xmlconf::Configuration;
use crate::{tdebug, twarn};

use super::container::ContainerRequest;
use super::resources::Resource;

/// Float slack for dominant-share comparisons.
const EPS: f64 = 1e-9;

/// Static queue configuration (fractions of the cluster).
///
/// `capacity` is the queue's *guaranteed* share — what preemption will
/// restore it to when it is starved; `max_capacity` is the hard ceiling
/// a bursting queue may reach while the cluster has slack.
#[derive(Debug, Clone, PartialEq)]
pub struct QueueConf {
    pub name: String,
    /// Guaranteed share of cluster capacity, in [0, 1].
    pub capacity: f64,
    /// Hard ceiling, in [0, 1] (>= capacity).
    pub max_capacity: f64,
}

impl QueueConf {
    pub fn new(name: &str, capacity: f64, max_capacity: f64) -> QueueConf {
        QueueConf { name: name.to_string(), capacity, max_capacity }
    }

    /// A single `default` queue owning the whole cluster.
    pub fn default_only() -> Vec<QueueConf> {
        vec![QueueConf::new("default", 1.0, 1.0)]
    }
}

/// The `tony.scheduler.*` policy knobs (parsed by
/// [`SchedulerConf::from_conf`]; every key is documented in
/// `docs/CONFIGURATION.md` and `docs/SCHEDULING.md`).
#[derive(Debug, Clone, PartialEq)]
pub struct SchedulerConf {
    /// Group each AM allocate round into a gang placed all-or-nothing.
    /// `false` restores the legacy per-container trickle for A/B runs.
    pub gang_mode: bool,
    /// How many blocked gangs may hold node reservations at once.
    pub reservation_limit: usize,
    /// Enable cross-queue capacity preemption.
    pub preemption: bool,
    /// Grace period between the preemption notice and the kill.
    pub preemption_grace_ms: u64,
    /// Most victim containers one preemption round may claim.
    pub preemption_max_victims: usize,
    /// Use the per-label free-capacity indexes for candidate selection
    /// (`false` = retained linear reference scan, same semantics).
    pub placement_index: bool,
    /// Enable the elasticity pass: grow registered elastic jobs into
    /// idle capacity and plan cooperative shrink waves ahead of
    /// preemption.  Safe to leave on — rigid jobs never register an
    /// elastic profile, so the pass is a no-op without one.
    pub elastic: bool,
    /// Quiet period after a resize completes before the same job may be
    /// grown again (shrink is demand-driven and ignores the cooldown).
    pub elastic_cooldown_ms: u64,
    /// Largest worker delta one resize command may carry.
    pub elastic_max_resize: u32,
}

impl Default for SchedulerConf {
    fn default() -> SchedulerConf {
        SchedulerConf {
            gang_mode: true,
            reservation_limit: 2,
            preemption: false,
            preemption_grace_ms: 2_000,
            preemption_max_victims: 8,
            placement_index: true,
            elastic: true,
            elastic_cooldown_ms: 5_000,
            elastic_max_resize: 4,
        }
    }
}

impl SchedulerConf {
    /// Read the `tony.scheduler.*` keys from a site configuration,
    /// falling back to the defaults above for anything unset.
    pub fn from_conf(conf: &Configuration) -> SchedulerConf {
        let d = SchedulerConf::default();
        SchedulerConf {
            gang_mode: conf.get_bool("tony.scheduler.gang-mode", d.gang_mode),
            reservation_limit: conf
                .get_u64("tony.scheduler.reservation-limit", d.reservation_limit as u64)
                as usize,
            preemption: conf.get_bool("tony.scheduler.preemption.enable", d.preemption),
            preemption_grace_ms: conf
                .get_u64("tony.scheduler.preemption.grace-ms", d.preemption_grace_ms),
            preemption_max_victims: conf.get_u64(
                "tony.scheduler.preemption.max-victims-per-round",
                d.preemption_max_victims as u64,
            ) as usize,
            placement_index: conf.get_bool("tony.scheduler.placement-index", d.placement_index),
            elastic: conf.get_bool("tony.elastic.enable", d.elastic),
            elastic_cooldown_ms: conf.get_u64("tony.elastic.cooldown-ms", d.elastic_cooldown_ms),
            elastic_max_resize: conf
                .get_u32("tony.elastic.max-resize-per-round", d.elastic_max_resize),
        }
    }
}

/// One outstanding single-container ask.
#[derive(Debug, Clone, PartialEq)]
pub struct Ask {
    pub app: ApplicationId,
    pub queue: Arc<str>,
    pub resource: Resource,
    pub node_label: Option<String>,
    pub priority: u8,
    /// Opaque correlation id chosen by the asker.
    pub tag: u64,
    /// Gang membership: asks sharing a gang id are placed all-or-nothing
    /// (`None` = legacy per-container placement).
    pub gang: Option<u64>,
}

/// A scheduling decision: place `ask` on `node`.
#[derive(Debug, Clone, PartialEq)]
pub struct Grant {
    pub ask: Ask,
    pub node: NodeId,
}

/// Scheduler's view of one node.
#[derive(Debug, Clone, PartialEq)]
pub struct SchedNode {
    pub id: NodeId,
    pub label: Option<String>,
    /// Capacity not currently granted to anyone.
    pub free: Resource,
    /// Total capacity — what reservations measure feasibility against
    /// (a gang that fits an *empty* node set will fit once it drains).
    pub capacity: Resource,
}

impl SchedNode {
    /// A fully idle node (`free == capacity`).
    pub fn new(id: u32, label: Option<String>, capacity: Resource) -> SchedNode {
        SchedNode { id: NodeId(id), label, free: capacity, capacity }
    }
}

/// Outcome of [`CapacityScheduler::add_asks_gang`].
#[derive(Debug, Clone, PartialEq)]
pub struct AskIntake {
    /// First unused correlation tag (callers thread this forward).
    pub next_tag: u64,
    /// The queue actually charged.
    pub queue: Arc<str>,
    /// True when the requested queue was unknown and the asks fell back
    /// to the first configured queue (also logged + counted in
    /// [`SchedStats::unknown_queue_asks`]).
    pub remapped: bool,
}

/// Monotonic counters kept by the scheduler (observability; see
/// `docs/METRICS.md`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SchedStats {
    /// Asks submitted to an unknown queue and remapped to the first one.
    pub unknown_queue_asks: u64,
    /// Releases naming an unknown queue (capacity silently un-tracked).
    pub unknown_queue_releases: u64,
    /// Gangs committed atomically.
    pub gangs_placed: u64,
    /// Gangs that could never be satisfied atomically (bigger than the
    /// queue ceiling, or infeasible even on an empty cluster) and were
    /// demoted to legacy per-container placement instead of hanging.
    pub gangs_demoted: u64,
    /// Reservations taken by blocked gangs.
    pub reservations_made: u64,
    /// Preemption rounds that produced victims.
    pub preemption_rounds: u64,
    /// Victim containers selected across all rounds.
    pub preemptions: u64,
    /// Workers granted to elastic jobs by grow commands.
    pub elastic_grows: u64,
    /// Shrink rounds that produced a resize plan.
    pub elastic_shrink_rounds: u64,
    /// Workers cooperatively released across all shrink rounds.
    pub elastic_released: u64,
}

/// Per-queue observability snapshot (feeds `ResourceManager::queue_stats`
/// and the `/metrics` endpoints).
#[derive(Debug, Clone, PartialEq)]
pub struct QueueSnapshot {
    pub name: Arc<str>,
    /// Guaranteed share in [0, 1].
    pub capacity: f64,
    /// Hard ceiling in [0, 1].
    pub max_capacity: f64,
    pub used: Resource,
    pub pending_asks: usize,
    /// Distinct gangs still waiting in this queue.
    pub pending_gangs: usize,
    /// Reservations currently held by this queue's gangs.
    pub reservations: usize,
    /// Victim containers taken *from* this queue since startup.
    pub preemptions: u64,
    /// Elastic jobs currently registered in this queue.
    pub elastic_jobs: usize,
    /// Sum of those jobs' current worker counts.
    pub elastic_workers: u64,
    /// Workers granted to this queue's elastic jobs by grow commands.
    pub elastic_grows: u64,
    /// Workers cooperatively released from this queue by shrink waves.
    pub elastic_shrinks: u64,
}

/// Why the scheduler reached a verdict on a gang (decision audit trail —
/// drained by the RM via [`CapacityScheduler::take_decisions`] and routed
/// into the owning job's trace as `sched.decision` spans, which is what
/// makes `WAITING_FOR_GANG` explainable; see `docs/TRACING.md`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecisionReason {
    /// The whole gang committed atomically this pass.
    PlacedAll,
    /// Blocked on its queue's max-capacity ceiling (headroom must drain).
    WaitingHeadroom,
    /// Feasible at node capacity but blocked at current free capacity.
    WaitingFree,
    /// A blocked gang claimed a reservation on its dry-run node set.
    Reserved,
    /// Demoted to per-container placement (can never place atomically).
    Demoted,
    /// A preemption round selected victims to unblock this gang.
    PreemptionPlanned,
    /// The elasticity pass grew an elastic job into idle capacity.
    ElasticGrow,
    /// A shrink round planned cooperative releases (either for the
    /// blocked gang the round unblocks or the elastic job contracting).
    ElasticShrink,
}

impl DecisionReason {
    pub fn as_str(self) -> &'static str {
        match self {
            DecisionReason::PlacedAll => "PLACED_ALL",
            DecisionReason::WaitingHeadroom => "WAITING_HEADROOM",
            DecisionReason::WaitingFree => "WAITING_FREE",
            DecisionReason::Reserved => "RESERVED",
            DecisionReason::Demoted => "DEMOTED",
            DecisionReason::PreemptionPlanned => "PREEMPTION_PLANNED",
            DecisionReason::ElasticGrow => "ELASTIC_GROW",
            DecisionReason::ElasticShrink => "ELASTIC_SHRINK",
        }
    }
}

/// One audited scheduler verdict.  The scheduler is pure (no clock), so
/// decisions carry no timestamp — the RM stamps them with its clock when
/// it drains them into the per-job trace stores.
#[derive(Debug, Clone, PartialEq)]
pub struct SchedDecision {
    pub app: ApplicationId,
    pub gang: Option<u64>,
    pub queue: Arc<str>,
    pub reason: DecisionReason,
    /// Human-readable cause, phrased to complete "gang N waited X s ..."
    /// (e.g. "for queue 'prod' headroom").  Kept stable across passes so
    /// repeat verdicts dedupe into one accruing span.
    pub detail: String,
}

/// A running container offered to [`CapacityScheduler::preemption_plan`]
/// as a potential victim (built by the RM from its live-container table).
#[derive(Debug, Clone, PartialEq)]
pub struct VictimCandidate {
    pub container: ContainerId,
    pub app: ApplicationId,
    pub queue: Arc<str>,
    pub node: NodeId,
    pub resource: Resource,
    pub gang: Option<u64>,
    /// Monotonic grant sequence — higher means more recently granted
    /// (victims are taken newest-first).
    pub seq: u64,
}

/// One elastic job's registration with the elasticity pass: the shape of
/// a single worker plus the `[min, max]` band its worker count may move
/// in.  `current` tracks the *acknowledged* worker count — the RM bumps
/// it only after the AM's resize wave completes, so at most one resize
/// per job is ever in flight.
#[derive(Debug, Clone, PartialEq)]
pub struct ElasticProfile {
    pub queue: Arc<str>,
    /// Resource shape of one worker (grow asks are multiples of this).
    pub resource: Resource,
    pub node_label: Option<String>,
    pub min: u32,
    pub max: u32,
    /// Acknowledged worker count, always within `[min, max]`.
    pub current: u32,
}

#[derive(Debug)]
struct Queue {
    conf: QueueConf,
    /// Shared, allocation-free handle on the queue name (every `Ask`,
    /// snapshot, and stats row clones this `Arc`, not the `String`).
    name: Arc<str>,
    used: Resource,
    /// Cached `used.dominant_share(cluster_total)` — refreshed on every
    /// charge/uncharge/total change, byte-identical to a recompute.
    dom_share: f64,
    /// Cached `dom_share / capacity` (∞ for zero-capacity queues) — the
    /// most-underserved-first scheduling key.
    rel_usage: f64,
    /// Victims preempted from this queue since startup.
    preemptions: u64,
    /// Workers granted to this queue's elastic jobs by grow commands.
    elastic_grows: u64,
    /// Workers cooperatively released from this queue by shrink waves.
    elastic_shrinks: u64,
    /// FIFO of pending asks (stable order; higher priority first is
    /// achieved by scanning priorities descending).
    pending: VecDeque<Ask>,
    /// gang id → number of its asks still pending in this queue
    /// (`len()` = distinct pending gangs; emptiness gates the singles
    /// fast path without scanning `pending`).
    gang_asks: BTreeMap<u64, u32>,
    /// Reservations currently held by this queue's gangs.
    reserved: u32,
}

/// A blocked gang's claim on a set of draining nodes.
#[derive(Debug, Clone)]
struct Reservation {
    gang: u64,
    queue: usize,
    nodes: Vec<NodeId>,
}

/// One schedulable unit: a single ask or a whole gang.
struct Unit {
    prio: u8,
    first: usize,
    idxs: Vec<usize>,
    gang: Option<u64>,
}

/// Which capacity vector a dry-run placement draws from.
#[derive(Clone, Copy, PartialEq, Eq)]
enum PlaceBase {
    /// Current free capacity (real placements).
    Free,
    /// Total capacity (reservation/demotion feasibility).
    Capacity,
}

fn refresh_share(q: &mut Queue, total: &Resource) {
    q.dom_share = q.used.dominant_share(total);
    q.rel_usage =
        if q.conf.capacity <= 0.0 { f64::INFINITY } else { q.dom_share / q.conf.capacity };
}

#[derive(Debug)]
pub struct CapacityScheduler {
    queues: Vec<Queue>,
    /// Queue name → index (Arc<str> keys borrow as &str for lookups).
    qname_ix: HashMap<Arc<str>, usize>,
    cluster_total: Resource,
    reservation_limit: usize,
    reservations: Vec<Reservation>,
    stats: SchedStats,
    /// Gang verdicts audited since the last [`CapacityScheduler::take_decisions`]
    /// drain (the RM drains after every scheduling pass, so this never
    /// outgrows one pass's worth of verdicts).
    decisions: Vec<SchedDecision>,
    /// app → number of its gang asks still pending anywhere (O(1)
    /// `has_pending_gang`).
    app_gangs: HashMap<ApplicationId, u32>,
    /// Elastic job registry (the elasticity pass plans grow/shrink over
    /// these; BTreeMap for deterministic largest-deficit tie-breaking).
    elastic: BTreeMap<ApplicationId, ElasticProfile>,
    /// `true` = bypass the indexes and scan nodes linearly (the
    /// reference implementation the property suite compares against;
    /// `tony.scheduler.placement-index=false`).
    linear_reference: bool,
    // ---- node table + placement indexes ----
    nodes: Vec<SchedNode>,
    node_ix: HashMap<NodeId, usize>,
    /// Interned node labels; `node_label[i]` indexes into this.
    labels: Vec<Option<String>>,
    label_ids: HashMap<Option<String>, u32>,
    node_label: Vec<u32>,
    /// Per-label skyline over free memory: `(free.memory_mb, node idx)`.
    free_by_label: Vec<BTreeSet<(u64, usize)>>,
    /// Per-label skyline over total memory: `(capacity.memory_mb, node idx)`.
    cap_by_label: Vec<BTreeSet<(u64, usize)>>,
}

impl CapacityScheduler {
    pub fn new(queues: Vec<QueueConf>, cluster_total: Resource) -> CapacityScheduler {
        assert!(!queues.is_empty(), "need at least one queue");
        let sum: f64 = queues.iter().map(|q| q.capacity).sum();
        assert!(
            (sum - 1.0).abs() < 1e-6,
            "queue capacities must sum to 1.0, got {sum}"
        );
        let mut qname_ix = HashMap::with_capacity(queues.len());
        let queues: Vec<Queue> = queues
            .into_iter()
            .enumerate()
            .map(|(qi, conf)| {
                let name: Arc<str> = Arc::from(conf.name.as_str());
                qname_ix.insert(name.clone(), qi);
                let mut q = Queue {
                    conf,
                    name,
                    used: Resource::ZERO,
                    dom_share: 0.0,
                    rel_usage: 0.0,
                    preemptions: 0,
                    elastic_grows: 0,
                    elastic_shrinks: 0,
                    pending: VecDeque::new(),
                    gang_asks: BTreeMap::new(),
                    reserved: 0,
                };
                refresh_share(&mut q, &cluster_total);
                q
            })
            .collect();
        CapacityScheduler {
            queues,
            qname_ix,
            cluster_total,
            reservation_limit: SchedulerConf::default().reservation_limit,
            reservations: Vec::new(),
            stats: SchedStats::default(),
            decisions: Vec::new(),
            app_gangs: HashMap::new(),
            elastic: BTreeMap::new(),
            linear_reference: false,
            nodes: Vec::new(),
            node_ix: HashMap::new(),
            labels: Vec::new(),
            label_ids: HashMap::new(),
            node_label: Vec::new(),
            free_by_label: Vec::new(),
            cap_by_label: Vec::new(),
        }
    }

    /// Cap on concurrently reserved gangs
    /// (`tony.scheduler.reservation-limit`).
    pub fn set_reservation_limit(&mut self, limit: usize) {
        self.reservation_limit = limit;
    }

    /// Disable the placement indexes and use the retained linear
    /// reference scan (`tony.scheduler.placement-index=false`).  The
    /// indexes are still maintained — only candidate *selection* changes
    /// — so invariants hold in both modes and the property suite can
    /// flip this per run.
    pub fn set_linear_reference(&mut self, linear: bool) {
        self.linear_reference = linear;
    }

    pub fn set_cluster_total(&mut self, total: Resource) {
        self.cluster_total = total;
        self.refresh_all_shares();
    }

    pub fn cluster_total(&self) -> Resource {
        self.cluster_total
    }

    fn refresh_all_shares(&mut self) {
        let total = self.cluster_total;
        for q in &mut self.queues {
            refresh_share(q, &total);
        }
    }

    // ---- node table lifecycle ------------------------------------------

    /// Replace the node table (startup / tests).  Does **not** touch the
    /// configured cluster total: callers that size queues against a
    /// nominal total may register fewer/smaller nodes.
    pub fn set_nodes(&mut self, nodes: Vec<SchedNode>) {
        self.nodes = nodes;
        self.node_ix.clear();
        self.labels.clear();
        self.label_ids.clear();
        self.node_label.clear();
        self.free_by_label.clear();
        self.cap_by_label.clear();
        for i in 0..self.nodes.len() {
            self.index_node(i);
        }
    }

    /// Register a node joining the cluster; grows the cluster total by
    /// its capacity and refreshes every queue's cached share.
    pub fn add_node(&mut self, node: SchedNode) {
        assert!(
            !self.node_ix.contains_key(&node.id),
            "duplicate node {:?}",
            node.id
        );
        self.cluster_total = self.cluster_total + node.capacity;
        self.nodes.push(node);
        self.index_node(self.nodes.len() - 1);
        self.refresh_all_shares();
    }

    /// Remove a node (lost/killed); shrinks the cluster total by its
    /// capacity.  Returns false when the node is unknown (already
    /// removed) — nothing changes.
    pub fn remove_node(&mut self, id: NodeId) -> bool {
        let Some(&ni) = self.node_ix.get(&id) else { return false };
        let cap = self.nodes[ni].capacity;
        let last = self.nodes.len() - 1;
        let lid = self.node_label[ni] as usize;
        self.free_by_label[lid].remove(&(self.nodes[ni].free.memory_mb, ni));
        self.cap_by_label[lid].remove(&(self.nodes[ni].capacity.memory_mb, ni));
        self.node_ix.remove(&id);
        if ni != last {
            // swap_remove moves the last node into slot ni: re-key its
            // index entries from `last` to `ni`.
            let llid = self.node_label[last] as usize;
            self.free_by_label[llid].remove(&(self.nodes[last].free.memory_mb, last));
            self.cap_by_label[llid].remove(&(self.nodes[last].capacity.memory_mb, last));
            self.nodes.swap_remove(ni);
            self.node_label[ni] = self.node_label[last];
            self.node_label.pop();
            self.free_by_label[llid].insert((self.nodes[ni].free.memory_mb, ni));
            self.cap_by_label[llid].insert((self.nodes[ni].capacity.memory_mb, ni));
            self.node_ix.insert(self.nodes[ni].id, ni);
        } else {
            self.nodes.pop();
            self.node_label.pop();
        }
        self.cluster_total = self.cluster_total - cap;
        self.refresh_all_shares();
        true
    }

    fn label_id(&mut self, label: &Option<String>) -> u32 {
        if let Some(&id) = self.label_ids.get(label) {
            return id;
        }
        let id = self.labels.len() as u32;
        self.labels.push(label.clone());
        self.label_ids.insert(label.clone(), id);
        self.free_by_label.push(BTreeSet::new());
        self.cap_by_label.push(BTreeSet::new());
        id
    }

    fn index_node(&mut self, ni: usize) {
        let label = self.nodes[ni].label.clone();
        let lid = self.label_id(&label);
        debug_assert_eq!(self.node_label.len(), ni);
        self.node_label.push(lid);
        self.free_by_label[lid as usize].insert((self.nodes[ni].free.memory_mb, ni));
        self.cap_by_label[lid as usize].insert((self.nodes[ni].capacity.memory_mb, ni));
        let prev = self.node_ix.insert(self.nodes[ni].id, ni);
        assert!(prev.is_none(), "duplicate node {:?}", self.nodes[ni].id);
    }

    /// The one write path for node free capacity: keeps the per-label
    /// skyline exactly in sync with `nodes[ni].free`.
    fn set_free(&mut self, ni: usize, new_free: Resource) {
        let lid = self.node_label[ni] as usize;
        let old_mem = self.nodes[ni].free.memory_mb;
        if old_mem != new_free.memory_mb {
            let set = &mut self.free_by_label[lid];
            set.remove(&(old_mem, ni));
            set.insert((new_free.memory_mb, ni));
        }
        self.nodes[ni].free = new_free;
    }

    /// The scheduler's node table (read-only view).
    pub fn nodes(&self) -> &[SchedNode] {
        &self.nodes
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Free capacity of one node (None when the node is unknown).
    pub fn node_free(&self, id: NodeId) -> Option<Resource> {
        self.node_ix.get(&id).map(|&i| self.nodes[i].free)
    }

    /// Overwrite a node's free capacity (tests / external simulations).
    /// Panics on an unknown node — a silent no-op here would desync the
    /// caller's model from the index.
    pub fn set_node_free(&mut self, id: NodeId, free: Resource) {
        let ni = *self.node_ix.get(&id).expect("set_node_free: unknown node");
        self.set_free(ni, free);
    }

    /// Return capacity to a node (container completed/released).  An
    /// unknown node is ignored: its capacity left the cluster when the
    /// node did, so there is nothing to credit.
    pub fn add_node_free(&mut self, id: NodeId, r: Resource) {
        if let Some(&ni) = self.node_ix.get(&id) {
            let f = self.nodes[ni].free + r;
            self.set_free(ni, f);
        }
    }

    /// A released/completed container hands back both its queue charge
    /// and its node capacity in one call (the RM's release path).
    pub fn release_container(&mut self, queue: &str, node: NodeId, r: Resource) {
        self.release(queue, r);
        self.add_node_free(node, r);
    }

    // ---- queue accessors -----------------------------------------------

    pub fn queue_names(&self) -> Vec<Arc<str>> {
        self.queues.iter().map(|q| q.name.clone()).collect()
    }

    pub fn queue_used(&self, name: &str) -> Option<Resource> {
        self.qname_ix.get(name).map(|&qi| self.queues[qi].used)
    }

    /// `(name, used)` per queue in one pass (the RM's `queue_usage`).
    pub fn queue_usage(&self) -> Vec<(Arc<str>, Resource)> {
        self.queues.iter().map(|q| (q.name.clone(), q.used)).collect()
    }

    pub fn pending_count(&self) -> usize {
        self.queues.iter().map(|q| q.pending.len()).sum()
    }

    /// Pending asks per queue (observability: the `/metrics` endpoints
    /// expose this as `tony_queue_pending_asks`).  `Arc<str>` names keep
    /// the per-tick sampler allocation-free.
    pub fn pending_per_queue(&self) -> Vec<(Arc<str>, usize)> {
        self.queues.iter().map(|q| (q.name.clone(), q.pending.len())).collect()
    }

    /// Monotonic scheduler counters (see [`SchedStats`]).
    pub fn stats(&self) -> SchedStats {
        self.stats
    }

    /// Number of reservations currently held.
    pub fn reservation_count(&self) -> usize {
        self.reservations.len()
    }

    /// Drain the gang verdicts audited since the last drain.  The RM
    /// calls this after every scheduling pass and routes each decision
    /// into the owning job's trace store.
    pub fn take_decisions(&mut self) -> Vec<SchedDecision> {
        std::mem::take(&mut self.decisions)
    }

    fn audit(
        &mut self,
        app: ApplicationId,
        gang: Option<u64>,
        qi: usize,
        reason: DecisionReason,
        detail: String,
    ) {
        self.decisions.push(SchedDecision {
            app,
            gang,
            queue: self.queues[qi].name.clone(),
            reason,
            detail,
        });
    }

    /// True when `app` has gang asks still waiting (the gateway surfaces
    /// this as the job-level `WAITING_FOR_GANG` state).  O(1) via the
    /// per-app gang-ask counter.
    pub fn has_pending_gang(&self, app: ApplicationId) -> bool {
        self.app_gangs.contains_key(&app)
    }

    /// One observability snapshot per queue — served entirely from the
    /// per-queue counters (no reservation-list or pending scans).
    pub fn queue_snapshots(&self) -> Vec<QueueSnapshot> {
        let mut elastic_jobs = vec![0usize; self.queues.len()];
        let mut elastic_workers = vec![0u64; self.queues.len()];
        for p in self.elastic.values() {
            if let Some(&qi) = self.qname_ix.get(&*p.queue) {
                elastic_jobs[qi] += 1;
                elastic_workers[qi] += p.current as u64;
            }
        }
        self.queues
            .iter()
            .enumerate()
            .map(|(qi, q)| QueueSnapshot {
                name: q.name.clone(),
                capacity: q.conf.capacity,
                max_capacity: q.conf.max_capacity,
                used: q.used,
                pending_asks: q.pending.len(),
                pending_gangs: q.gang_asks.len(),
                reservations: q.reserved as usize,
                preemptions: q.preemptions,
                elastic_jobs: elastic_jobs[qi],
                elastic_workers: elastic_workers[qi],
                elastic_grows: q.elastic_grows,
                elastic_shrinks: q.elastic_shrinks,
            })
            .collect()
    }

    fn charge(&mut self, qi: usize, r: Resource) {
        let total = self.cluster_total;
        let q = &mut self.queues[qi];
        q.used += r;
        refresh_share(q, &total);
    }

    /// Enqueue asks from an AM heartbeat (expanding multi-count requests).
    /// Unknown queues fall back to the first queue (logged + counted; see
    /// [`CapacityScheduler::add_asks_gang`] for the variant that reports
    /// the remap to the caller).
    pub fn add_asks(
        &mut self,
        app: ApplicationId,
        queue: &str,
        requests: &[ContainerRequest],
        tag_start: u64,
    ) -> u64 {
        self.add_asks_gang(app, queue, requests, tag_start, None).next_tag
    }

    /// Enqueue asks, optionally as members of gang `gang` (placed
    /// all-or-nothing).  An unknown queue falls back to the first
    /// configured queue; the remap is logged, counted in
    /// [`SchedStats::unknown_queue_asks`], and reported in the returned
    /// [`AskIntake`] so callers can surface it instead of hiding it.
    pub fn add_asks_gang(
        &mut self,
        app: ApplicationId,
        queue: &str,
        requests: &[ContainerRequest],
        mut tag_start: u64,
        gang: Option<u64>,
    ) -> AskIntake {
        let (qi, remapped) = match self.qname_ix.get(queue) {
            Some(&qi) => (qi, false),
            None => {
                self.stats.unknown_queue_asks += 1;
                twarn!(
                    "sched",
                    "{app} asked unknown queue '{queue}'; remapped to '{}'",
                    self.queues[0].name
                );
                (0, true)
            }
        };
        let qname = self.queues[qi].name.clone();
        for req in requests {
            for _ in 0..req.count {
                self.queues[qi].pending.push_back(Ask {
                    app,
                    queue: qname.clone(),
                    resource: req.resource,
                    node_label: req.node_label.clone(),
                    priority: req.priority,
                    tag: tag_start,
                    gang,
                });
                if let Some(g) = gang {
                    *self.queues[qi].gang_asks.entry(g).or_insert(0) += 1;
                    *self.app_gangs.entry(app).or_insert(0) += 1;
                }
                tag_start += 1;
            }
        }
        AskIntake { next_tag: tag_start, queue: qname, remapped }
    }

    /// Bookkeeping for one gang ask leaving `pending` (granted, demoted,
    /// or removed with its app).
    fn note_gang_ask_removed(&mut self, qi: usize, gang: u64, app: ApplicationId) {
        if let Some(n) = self.queues[qi].gang_asks.get_mut(&gang) {
            *n -= 1;
            if *n == 0 {
                self.queues[qi].gang_asks.remove(&gang);
            }
        }
        if let Some(n) = self.app_gangs.get_mut(&app) {
            *n -= 1;
            if *n == 0 {
                self.app_gangs.remove(&app);
            }
        }
    }

    /// Remove one pending ask, maintaining the gang counters.
    fn take_ask(&mut self, qi: usize, pi: usize) -> Ask {
        let ask = self.queues[qi].pending.remove(pi).expect("pending index in range");
        if let Some(g) = ask.gang {
            self.note_gang_ask_removed(qi, g, ask.app);
        }
        ask
    }

    /// Remove all pending asks of an app (teardown / app finished), and
    /// any reservations its gangs held.
    pub fn remove_app(&mut self, app: ApplicationId) {
        for qi in 0..self.queues.len() {
            let pending = std::mem::take(&mut self.queues[qi].pending);
            let mut kept = VecDeque::with_capacity(pending.len());
            for a in pending {
                if a.app == app {
                    if let Some(g) = a.gang {
                        self.note_gang_ask_removed(qi, g, a.app);
                    }
                } else {
                    kept.push_back(a);
                }
            }
            self.queues[qi].pending = kept;
        }
        self.elastic.remove(&app);
        self.gc_reservations();
    }

    /// Record capacity returned by a released/completed container.  An
    /// unknown queue is logged and counted instead of silently dropping
    /// the capacity accounting on the floor.
    pub fn release(&mut self, queue: &str, resource: Resource) {
        match self.qname_ix.get(queue) {
            Some(&qi) => {
                let total = self.cluster_total;
                let q = &mut self.queues[qi];
                q.used -= resource;
                refresh_share(q, &total);
            }
            None => {
                self.stats.unknown_queue_releases += 1;
                twarn!(
                    "sched",
                    "release of {resource} names unknown queue '{queue}'; usage not adjusted"
                );
            }
        }
    }

    /// Would granting `r` keep queue under its max-capacity ceiling?
    /// (Not servable from the cached share: the dominant dimension of
    /// `used + r` need not be the dominant dimension of `used`.)
    fn queue_headroom_ok(&self, qi: usize, r: &Resource) -> bool {
        let q = &self.queues[qi];
        let after = q.used + *r;
        after.dominant_share(&self.cluster_total) <= q.conf.max_capacity + EPS
    }

    /// One scheduling pass: match pending units (singles and gangs)
    /// against free node capacity.  Queues are visited
    /// most-underserved-first (used/capacity ratio); within a queue,
    /// priorities descend, FIFO within a priority; a gang commits
    /// atomically or not at all.
    ///
    /// Queue selection is a min-heap on the cached relative-usage key
    /// (`f64::to_bits` is order-preserving for the non-negative shares
    /// we store, ties broken by queue index exactly like the old stable
    /// sort).  Only the committed queue's key changes per commit, so
    /// queues that failed this round park and re-arm on progress instead
    /// of being re-sorted every round.
    pub fn schedule(&mut self) -> Vec<Grant> {
        let mut grants = Vec::new();
        self.gc_reservations();
        let mut heap: BinaryHeap<Reverse<(u64, usize)>> = (0..self.queues.len())
            .filter(|&i| !self.queues[i].pending.is_empty())
            .map(|i| Reverse((self.queues[i].rel_usage.to_bits(), i)))
            .collect();
        let mut parked: Vec<usize> = Vec::new();
        while let Some(Reverse((_, qi))) = heap.pop() {
            if self.try_queue(qi, &mut grants) {
                if !self.queues[qi].pending.is_empty() {
                    heap.push(Reverse((self.queues[qi].rel_usage.to_bits(), qi)));
                }
                for p in parked.drain(..) {
                    heap.push(Reverse((self.queues[p].rel_usage.to_bits(), p)));
                }
            } else {
                parked.push(qi);
            }
        }
        grants
    }

    /// The schedulable units of queue `qi`, priority-major (a gang's
    /// priority is its highest member's), FIFO-minor.
    fn units(&self, qi: usize) -> Vec<Unit> {
        let q = &self.queues[qi];
        let mut gangs: BTreeMap<u64, Unit> = BTreeMap::new();
        let mut units = Vec::new();
        for (i, ask) in q.pending.iter().enumerate() {
            match ask.gang {
                Some(g) => {
                    let u = gangs.entry(g).or_insert(Unit {
                        prio: ask.priority,
                        first: i,
                        idxs: Vec::new(),
                        gang: Some(g),
                    });
                    u.prio = u.prio.max(ask.priority);
                    u.idxs.push(i);
                }
                None => {
                    units.push(Unit { prio: ask.priority, first: i, idxs: vec![i], gang: None })
                }
            }
        }
        units.extend(gangs.into_values());
        units.sort_by(|a, b| b.prio.cmp(&a.prio).then(a.first.cmp(&b.first)));
        units
    }

    /// `(resource, label)` of every ask in `unit`, in pending order.
    fn asks_of(&self, qi: usize, unit: &Unit) -> Vec<(Resource, Option<String>)> {
        unit.idxs
            .iter()
            .map(|&i| {
                let a = &self.queues[qi].pending[i];
                (a.resource, a.node_label.clone())
            })
            .collect()
    }

    /// Nodes reserved by gangs *other* than `gang`.
    fn reserved_by_others(&self, gang: Option<u64>) -> BTreeSet<NodeId> {
        self.reservations
            .iter()
            .filter(|r| Some(r.gang) != gang)
            .flat_map(|r| r.nodes.iter().copied())
            .collect()
    }

    fn push_reservation(&mut self, gang: u64, qi: usize, nodes: Vec<NodeId>) {
        self.queues[qi].reserved += 1;
        self.reservations.push(Reservation { gang, queue: qi, nodes });
    }

    fn drop_reservation(&mut self, gang: u64) {
        let queues = &mut self.queues;
        self.reservations.retain(|r| {
            if r.gang == gang {
                queues[r.queue].reserved -= 1;
                false
            } else {
                true
            }
        });
    }

    /// Drop reservations whose gang no longer has pending asks, or that
    /// reference nodes no longer in the cluster — the gang stays pending
    /// and may re-reserve on survivors.
    fn gc_reservations(&mut self) {
        let queues = &mut self.queues;
        let node_ix = &self.node_ix;
        self.reservations.retain(|r| {
            let keep = queues[r.queue].gang_asks.contains_key(&r.gang)
                && r.nodes.iter().all(|id| node_ix.contains_key(id));
            if !keep {
                queues[r.queue].reserved -= 1;
            }
            keep
        });
    }

    /// Try to commit the first placeable unit of queue `qi`.  A blocked
    /// gang may take a reservation instead (not counted as progress).
    /// Skipping unplaceable units keeps later placeable ones flowing
    /// (convoy avoidance on mixed GPU/CPU asks).
    fn try_queue(&mut self, qi: usize, grants: &mut Vec<Grant>) -> bool {
        // Allocation-free fast path for the overwhelmingly common shape
        // (no gangs pending in this queue, no reservations anywhere):
        // both gates are O(1) counter reads, so a pure-singles pass
        // never builds the unit machinery's Vec/BTreeMap per call.
        if self.reservations.is_empty() && self.queues[qi].gang_asks.is_empty() {
            return self.try_queue_singles(qi, grants);
        }
        let units = self.units(qi);
        for unit in units {
            let asks = self.asks_of(qi, &unit);
            let unit_app = self.queues[qi].pending[unit.first].app;
            let total_ask = asks.iter().fold(Resource::ZERO, |a, (r, _)| a + *r);
            // A gang that can NEVER be placed atomically — bigger than
            // its queue's hard ceiling — must not wait forever for a
            // moment that cannot come: demote it to legacy
            // per-container placement (it then trickles through the
            // ceiling the way a plain ask stream would).
            if unit.gang.is_some()
                && total_ask.dominant_share(&self.cluster_total)
                    > self.queues[qi].conf.max_capacity + EPS
            {
                self.demote_gang(qi, &unit, "exceeds its queue's max-capacity ceiling");
                return true; // state changed: rescan with the gang as singles
            }
            if !self.queue_headroom_ok(qi, &total_ask) {
                // Over the ceiling *right now* (but the unit fits under
                // it on its own).  A blocked *single* is skipped (convoy
                // avoidance).  A blocked *gang* instead gates the rest
                // of this queue's units: headroom is queue-local, and if
                // younger same-queue asks kept re-consuming it as it
                // drained, a hole the gang's whole size could never open
                // — the same starvation reservations prevent, but
                // reserving *nodes* here would freeze free capacity
                // other queues could use, so the gang claims the queue's
                // headroom by seniority instead.  Other queues are
                // unaffected.  If node capacity is gone by the time the
                // headroom opens, the node-blocked branch below reserves
                // then.
                if unit.gang.is_some() {
                    self.audit(
                        unit_app,
                        unit.gang,
                        qi,
                        DecisionReason::WaitingHeadroom,
                        format!(
                            "for queue '{}' headroom (gang needs {} MB)",
                            self.queues[qi].name, total_ask.memory_mb
                        ),
                    );
                    break;
                }
                continue;
            }
            let blocked = self.reserved_by_others(unit.gang);
            if let Some(chosen) = self.place_asks(PlaceBase::Free, &blocked, &asks) {
                // Commit atomically: remove the asks back-to-front so
                // earlier pending indices stay valid.
                let mut pairs: Vec<(usize, usize)> =
                    unit.idxs.iter().copied().zip(chosen).collect();
                pairs.sort_by(|a, b| b.0.cmp(&a.0));
                let mut committed = Vec::with_capacity(pairs.len());
                for (pi, ni) in pairs {
                    let ask = self.take_ask(qi, pi);
                    let new_free = self.nodes[ni].free - ask.resource;
                    self.set_free(ni, new_free);
                    self.charge(qi, ask.resource);
                    committed.push(Grant { ask, node: self.nodes[ni].id });
                }
                committed.reverse(); // back to FIFO order
                grants.extend(committed);
                if let Some(g) = unit.gang {
                    self.stats.gangs_placed += 1;
                    self.drop_reservation(g);
                    self.audit(
                        unit_app,
                        Some(g),
                        qi,
                        DecisionReason::PlacedAll,
                        format!("placed {} container(s) atomically", unit.idxs.len()),
                    );
                }
                return true;
            }
            if unit.gang.is_some() {
                // Blocked at current free capacity.  If the gang cannot
                // be placed even on a fully drained cluster (ignoring
                // reservations — nodes only ever disappear), waiting is
                // a guaranteed hang: demote to per-container placement.
                let none = BTreeSet::new();
                if self.place_asks(PlaceBase::Capacity, &none, &asks).is_none() {
                    self.demote_gang(qi, &unit, "infeasible even at full cluster capacity");
                    return true; // state changed: rescan with the gang as singles
                }
                self.audit(
                    unit_app,
                    unit.gang,
                    qi,
                    DecisionReason::WaitingFree,
                    "for free node capacity to drain".to_string(),
                );
                if self.try_reserve(qi, &unit) {
                    let n = self
                        .reservations
                        .iter()
                        .find(|r| Some(r.gang) == unit.gang)
                        .map(|r| r.nodes.len())
                        .unwrap_or(0);
                    self.audit(
                        unit_app,
                        unit.gang,
                        qi,
                        DecisionReason::Reserved,
                        format!("reserved {n} node(s) from a full-capacity dry run"),
                    );
                }
            }
        }
        false
    }

    /// The pre-gang scan, kept as the zero-allocation fast path: place
    /// the highest-priority placeable single (FIFO within a priority),
    /// skipping asks that cannot currently be placed (convoy avoidance).
    /// Semantically identical to the unit path for all-single queues.
    fn try_queue_singles(&mut self, qi: usize, grants: &mut Vec<Grant>) -> bool {
        let plen = self.queues[qi].pending.len();
        let mut best: Option<(usize, usize)> = None; // (pending idx, node idx)
        let mut best_prio = 0u8;
        for i in 0..plen {
            let ask = &self.queues[qi].pending[i];
            if best.is_some() && ask.priority <= best_prio {
                continue;
            }
            if !self.queue_headroom_ok(qi, &ask.resource) {
                continue;
            }
            let prio = ask.priority;
            if let Some(ni) = self.pick_single(&ask.resource, &ask.node_label) {
                best_prio = prio;
                best = Some((i, ni));
            }
        }
        let Some((i, ni)) = best else { return false };
        let ask = self.take_ask(qi, i);
        let new_free = self.nodes[ni].free - ask.resource;
        self.set_free(ni, new_free);
        self.charge(qi, ask.resource);
        grants.push(Grant { ask, node: self.nodes[ni].id });
        true
    }

    /// Best-fit node for a single unreserved ask (fast path; no overlay,
    /// no blocked set).  Indexed: first fitting entry of the label's
    /// free skyline at or above the ask's memory.  Linear reference:
    /// minimal `(free_mem, index)` scan — identical choice.
    fn pick_single(&self, r: &Resource, label: &Option<String>) -> Option<usize> {
        if self.linear_reference {
            let mut best: Option<(u64, usize)> = None;
            for (ni, n) in self.nodes.iter().enumerate() {
                if n.label != *label || !n.free.fits(r) {
                    continue;
                }
                let key = (n.free.memory_mb, ni);
                if best.map_or(true, |b| key < b) {
                    best = Some(key);
                }
            }
            best.map(|(_, ni)| ni)
        } else {
            let &lid = self.label_ids.get(label)?;
            self.free_by_label[lid as usize]
                .range((r.memory_mb, 0usize)..)
                .find(|&&(_, ni)| self.nodes[ni].free.fits(r))
                .map(|&(_, ni)| ni)
        }
    }

    /// Dry-run placement of `asks` against `base` capacity, excluding
    /// `blocked` nodes.  Larger asks are placed first (fewer
    /// fragmentation failures); each ask takes the best-fit node —
    /// matching label, smallest leftover memory, lowest index on ties.
    /// Returns the chosen node index per ask (in `asks` order), or
    /// `None` when any ask cannot be placed — the caller must treat
    /// that as "place nothing".
    ///
    /// Never mutates the live index: tentative placements accumulate in
    /// a small overlay of `(node idx, remaining)` shadowing the indexed
    /// values.
    fn place_asks(
        &self,
        base: PlaceBase,
        blocked: &BTreeSet<NodeId>,
        asks: &[(Resource, Option<String>)],
    ) -> Option<Vec<usize>> {
        let mut order: Vec<usize> = (0..asks.len()).collect();
        order.sort_by(|&a, &b| {
            asks[b]
                .0
                .memory_mb
                .cmp(&asks[a].0.memory_mb)
                .then(asks[b].0.gpus.cmp(&asks[a].0.gpus))
                .then(asks[b].0.vcores.cmp(&asks[a].0.vcores))
                .then(a.cmp(&b))
        });
        let mut overlay: Vec<(usize, Resource)> = Vec::with_capacity(asks.len());
        let mut chosen = vec![usize::MAX; asks.len()];
        for &ai in &order {
            let (r, label) = &asks[ai];
            let ni = self.find_best(base, &overlay, blocked, r, label)?;
            let pos = match overlay.iter().position(|&(i, _)| i == ni) {
                Some(p) => p,
                None => {
                    overlay.push((ni, self.base_free(base, ni)));
                    overlay.len() - 1
                }
            };
            overlay[pos].1 -= *r;
            chosen[ai] = ni;
        }
        Some(chosen)
    }

    fn base_free(&self, base: PlaceBase, ni: usize) -> Resource {
        match base {
            PlaceBase::Free => self.nodes[ni].free,
            PlaceBase::Capacity => self.nodes[ni].capacity,
        }
    }

    /// Best-fit candidate for one ask of a dry run: the minimum
    /// `(remaining memory, node index)` over overlay-touched nodes plus
    /// untouched nodes (indexed skyline seek or linear reference scan).
    fn find_best(
        &self,
        base: PlaceBase,
        overlay: &[(usize, Resource)],
        blocked: &BTreeSet<NodeId>,
        r: &Resource,
        label: &Option<String>,
    ) -> Option<usize> {
        let mut best: Option<(u64, usize)> = None;
        for &(ni, rem) in overlay {
            if self.nodes[ni].label != *label
                || blocked.contains(&self.nodes[ni].id)
                || !rem.fits(r)
            {
                continue;
            }
            let key = (rem.memory_mb, ni);
            if best.map_or(true, |b| key < b) {
                best = Some(key);
            }
        }
        if self.linear_reference {
            for ni in 0..self.nodes.len() {
                if overlay.iter().any(|&(i, _)| i == ni) {
                    continue;
                }
                let n = &self.nodes[ni];
                if n.label != *label || blocked.contains(&n.id) {
                    continue;
                }
                let bf = self.base_free(base, ni);
                if !bf.fits(r) {
                    continue;
                }
                let key = (bf.memory_mb, ni);
                if best.map_or(true, |b| key < b) {
                    best = Some(key);
                }
            }
        } else if let Some(&lid) = self.label_ids.get(label) {
            let set = match base {
                PlaceBase::Free => &self.free_by_label[lid as usize],
                PlaceBase::Capacity => &self.cap_by_label[lid as usize],
            };
            // Ascending (mem, idx) scan: the first entry that clears the
            // overlay/blocked/fits filters is the minimal key among
            // untouched nodes, so one hit ends the scan; the running
            // overlay best prunes it earlier still.
            for &(mem, ni) in set.range((r.memory_mb, 0usize)..) {
                if let Some(b) = best {
                    if (mem, ni) >= b {
                        break;
                    }
                }
                if overlay.iter().any(|&(i, _)| i == ni) {
                    continue;
                }
                if blocked.contains(&self.nodes[ni].id) {
                    continue;
                }
                if !self.base_free(base, ni).fits(r) {
                    continue;
                }
                best = Some((mem, ni));
                break;
            }
        }
        best.map(|(_, ni)| ni)
    }

    /// Strip the gang id off a gang that can never place atomically so
    /// its asks flow through legacy per-container placement instead of
    /// hanging forever.
    fn demote_gang(&mut self, qi: usize, unit: &Unit, why: &str) {
        let gang = unit.gang.expect("only gangs are demoted");
        let app = self.queues[qi].pending[unit.first].app;
        twarn!(
            "sched",
            "gang {gang} ({} asks, queue '{}') {why}; demoted to per-container placement",
            unit.idxs.len(),
            self.queues[qi].name
        );
        for &i in &unit.idxs {
            let (g, a) = {
                let ask = &mut self.queues[qi].pending[i];
                (ask.gang.take().expect("gang member has a gang id"), ask.app)
            };
            self.note_gang_ask_removed(qi, g, a);
        }
        self.drop_reservation(gang);
        self.stats.gangs_demoted += 1;
        self.audit(
            app,
            Some(gang),
            qi,
            DecisionReason::Demoted,
            format!("demoted to per-container placement: {why}"),
        );
    }

    /// Give a blocked gang a claim on the node set a dry-run placement
    /// at full capacity chooses, if a reservation slot is available.
    /// Returns true when a new reservation was taken.
    fn try_reserve(&mut self, qi: usize, unit: &Unit) -> bool {
        let Some(gang) = unit.gang else { return false };
        if self.reservations.iter().any(|r| r.gang == gang) {
            return false;
        }
        if self.reservations.len() >= self.reservation_limit {
            return false;
        }
        let blocked = self.reserved_by_others(Some(gang));
        let asks = self.asks_of(qi, unit);
        if let Some(chosen) = self.place_asks(PlaceBase::Capacity, &blocked, &asks) {
            let set: BTreeSet<NodeId> = chosen.iter().map(|&ni| self.nodes[ni].id).collect();
            tdebug!(
                "sched",
                "gang {gang} (queue '{}') reserves {} node(s)",
                self.queues[qi].name,
                set.len()
            );
            self.push_reservation(gang, qi, set.into_iter().collect());
            self.stats.reservations_made += 1;
            return true;
        }
        false
    }

    /// O(1): cached dominant share vs. guaranteed capacity.
    fn queue_over_guarantee(&self, name: &str) -> bool {
        self.qname_ix.get(name).map_or(false, |&qi| {
            let q = &self.queues[qi];
            q.dom_share > q.conf.capacity + EPS
        })
    }

    /// Plan one cross-queue preemption round.
    ///
    /// Finds the most-underserved queue that is below its guarantee and
    /// has a gang that is placeable at capacity but blocked at current
    /// free, then selects victims from over-guarantee queues —
    /// non-gang containers before gang members, newest grants first —
    /// until a simulated placement of the gang succeeds.  Returns the
    /// victims (empty when nothing qualifies or `max_victims` cannot
    /// unblock the gang: rounds are all-or-nothing, so containers are
    /// never killed without actually freeing the gang).  On success the
    /// demanding gang is force-reserved onto the placement's nodes so
    /// the freed capacity cannot be stolen before it lands.
    ///
    /// The blocked/feasible gates run on the indexes; the victim walk
    /// itself simulates over a free-capacity snapshot with the retained
    /// linear placement (`place_with`) — it is the rare path, and its
    /// what-if frees must not touch the live skyline.
    pub fn preemption_plan(
        &mut self,
        candidates: &[VictimCandidate],
        max_victims: usize,
    ) -> Vec<VictimCandidate> {
        if max_victims == 0 || candidates.is_empty() {
            return Vec::new();
        }
        let total = self.cluster_total;
        let mut order: Vec<usize> = (0..self.queues.len())
            .filter(|&i| !self.queues[i].pending.is_empty())
            .filter(|&i| self.queues[i].dom_share + EPS < self.queues[i].conf.capacity)
            .collect();
        order.sort_by(|&a, &b| self.queues[a].rel_usage.total_cmp(&self.queues[b].rel_usage));
        for qi in order {
            for unit in self.units(qi) {
                let Some(gang) = unit.gang else { continue };
                let unit_app = self.queues[qi].pending[unit.first].app;
                let asks = self.asks_of(qi, &unit);
                let total_ask = asks.iter().fold(Resource::ZERO, |a, (r, _)| a + *r);
                // Preemption only restores a queue *up to* its guarantee;
                // growth beyond that waits for organic free capacity.
                if (self.queues[qi].used + total_ask).dominant_share(&total)
                    > self.queues[qi].conf.capacity + EPS
                {
                    continue;
                }
                let blocked = self.reserved_by_others(Some(gang));
                if self.place_asks(PlaceBase::Free, &blocked, &asks).is_some() {
                    continue; // not blocked — the next schedule pass lands it
                }
                if self.place_asks(PlaceBase::Capacity, &blocked, &asks).is_none() {
                    continue; // not placeable even at capacity
                }
                // From here the unit is the rare preempt-worthy case:
                // snapshot free capacity once and simulate linearly.
                let free: Vec<Resource> = self.nodes.iter().map(|n| n.free).collect();
                let allowed: Vec<bool> =
                    self.nodes.iter().map(|n| !blocked.contains(&n.id)).collect();
                let node_idx: HashMap<NodeId, usize> =
                    self.nodes.iter().enumerate().map(|(i, n)| (n.id, i)).collect();
                // Victims must sit in a partition the gang can use.
                let labels: BTreeSet<Option<String>> =
                    asks.iter().map(|(_, l)| l.clone()).collect();
                let mut pool: Vec<&VictimCandidate> = candidates
                    .iter()
                    .filter(|c| self.queue_over_guarantee(&c.queue))
                    .filter(|c| {
                        node_idx
                            .get(&c.node)
                            .map(|&ni| labels.contains(&self.nodes[ni].label))
                            .unwrap_or(false)
                    })
                    .collect();
                // Whole-gangs-last, newest-first within each class.
                pool.sort_by(|a, b| {
                    (a.gang.is_some() as u8)
                        .cmp(&(b.gang.is_some() as u8))
                        .then(b.seq.cmp(&a.seq))
                });
                // Free capacity with the given victims' resources returned
                // (the one simulation every decision below shares).
                let free_after = |vs: &[VictimCandidate], skip: Option<usize>| -> Vec<Resource> {
                    let mut f = free.clone();
                    for (k, v) in vs.iter().enumerate() {
                        if Some(k) != skip {
                            f[node_idx[&v.node]] += v.resource;
                        }
                    }
                    f
                };
                let mut sim_used: BTreeMap<Arc<str>, Resource> = BTreeMap::new();
                let mut victims: Vec<VictimCandidate> = Vec::new();
                for c in pool {
                    if victims.len() >= max_victims {
                        break;
                    }
                    let Some(&vqi) = self.qname_ix.get(&*c.queue) else {
                        continue;
                    };
                    let cur =
                        sim_used.get(&c.queue).copied().unwrap_or(self.queues[vqi].used);
                    let after = cur - c.resource;
                    // Never drive a victim queue below its own guarantee.
                    if after.dominant_share(&total) + EPS < self.queues[vqi].conf.capacity {
                        continue;
                    }
                    let Some(&ni) = node_idx.get(&c.node) else { continue };
                    if !allowed[ni] {
                        continue; // freeing another gang's reserved node helps no one
                    }
                    sim_used.insert(c.queue.clone(), after);
                    victims.push(c.clone());
                    if place_with(&self.nodes, &free_after(&victims, None), &allowed, &asks)
                        .is_none()
                    {
                        continue;
                    }
                    // The gang fits.  Prune victims whose freed capacity
                    // the placement does not actually need (the greedy
                    // walk may have accumulated containers on nodes the
                    // final placement never touches) — nobody dies for
                    // zero benefit.
                    let mut i = 0;
                    while i < victims.len() {
                        if place_with(
                            &self.nodes,
                            &free_after(&victims, Some(i)),
                            &allowed,
                            &asks,
                        )
                        .is_some()
                        {
                            victims.remove(i);
                        } else {
                            i += 1;
                        }
                    }
                    let chosen =
                        place_with(&self.nodes, &free_after(&victims, None), &allowed, &asks)
                            .expect("placement held after pruning");
                    // Hold the placement for the demanding gang.
                    let set: BTreeSet<NodeId> =
                        chosen.iter().map(|&ni| self.nodes[ni].id).collect();
                    self.drop_reservation(gang);
                    self.push_reservation(gang, qi, set.into_iter().collect());
                    self.stats.preemption_rounds += 1;
                    self.stats.preemptions += victims.len() as u64;
                    self.audit(
                        unit_app,
                        Some(gang),
                        qi,
                        DecisionReason::PreemptionPlanned,
                        format!("{} victim(s) selected to open the gang's hole", victims.len()),
                    );
                    for v in &victims {
                        if let Some(&vqi) = self.qname_ix.get(&*v.queue) {
                            self.queues[vqi].preemptions += 1;
                        }
                    }
                    twarn!(
                        "sched",
                        "preempting {} container(s) to unblock gang {gang} in queue '{}'",
                        victims.len(),
                        self.queues[qi].name
                    );
                    return victims;
                }
                // Budget exhausted without unblocking the gang: propose
                // nothing (all-or-nothing rounds) and try the next unit.
            }
        }
        Vec::new()
    }

    /// Register (or re-register, after an AM attempt restart) an elastic
    /// job with the elasticity pass.  An unknown queue falls back to the
    /// first configured queue, mirroring [`CapacityScheduler::add_asks_gang`].
    /// Bounds are sanitized (`min >= 1`, `max >= min`, `current` clamped)
    /// so the registry invariants hold no matter what the caller sends.
    pub fn register_elastic(
        &mut self,
        app: ApplicationId,
        queue: &str,
        resource: Resource,
        node_label: Option<String>,
        min: u32,
        max: u32,
        current: u32,
    ) {
        let qi = match self.qname_ix.get(queue) {
            Some(&qi) => qi,
            None => {
                twarn!(
                    "sched",
                    "elastic job {app} names unknown queue '{queue}'; remapped to '{}'",
                    self.queues[0].name
                );
                0
            }
        };
        let min = min.max(1);
        let max = max.max(min);
        let current = current.clamp(min, max);
        self.elastic.insert(
            app,
            ElasticProfile { queue: self.queues[qi].name.clone(), resource, node_label, min, max, current },
        );
    }

    /// Drop an elastic job from the registry (app teardown).
    pub fn deregister_elastic(&mut self, app: ApplicationId) {
        self.elastic.remove(&app);
    }

    /// Record the acknowledged worker count after a resize wave
    /// completes (clamped into the job's `[min, max]` band).
    pub fn set_elastic_current(&mut self, app: ApplicationId, current: u32) {
        if let Some(p) = self.elastic.get_mut(&app) {
            p.current = current.clamp(p.min, p.max);
        }
    }

    pub fn elastic_profile(&self, app: ApplicationId) -> Option<&ElasticProfile> {
        self.elastic.get(&app)
    }

    /// Plan one elastic *grow*: pick the registered elastic job with the
    /// largest deficit (`max - current`, app id breaking ties) whose
    /// queue has ceiling headroom for a `+k` worker delta that places on
    /// current free capacity, and return its new target worker count.
    ///
    /// Growth only happens into genuinely idle capacity: the pass is
    /// gated on a quiescent scheduler (no pending asks and no held
    /// reservations anywhere), so a grow can never race a blocked gang
    /// or starve another queue's demand.  `k` is probed largest-first
    /// (capped by `max_delta`), and feasibility runs through the same
    /// [`CapacityScheduler::place_asks`] dry-run machinery as real
    /// placements — byte-identical on the indexed and linear paths.
    /// `eligible` lets the caller veto jobs (resize cooldown).
    pub fn elastic_grow_plan(
        &mut self,
        max_delta: u32,
        eligible: &dyn Fn(ApplicationId) -> bool,
    ) -> Option<(ApplicationId, u32)> {
        if max_delta == 0 || self.elastic.is_empty() {
            return None;
        }
        if self.queues.iter().any(|q| !q.pending.is_empty()) || !self.reservations.is_empty() {
            return None; // demand or claims outstanding — not idle capacity
        }
        let mut order: Vec<(ApplicationId, u32)> = self
            .elastic
            .iter()
            .filter(|(app, p)| p.current < p.max && eligible(**app))
            .map(|(app, p)| (*app, p.max - p.current))
            .collect();
        order.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        for (app, deficit) in order {
            let p = self.elastic[&app].clone();
            let Some(&qi) = self.qname_ix.get(&*p.queue) else { continue };
            for k in (1..=deficit.min(max_delta)).rev() {
                let mut delta = Resource::ZERO;
                for _ in 0..k {
                    delta += p.resource;
                }
                if !self.queue_headroom_ok(qi, &delta) {
                    continue;
                }
                let asks: Vec<(Resource, Option<String>)> =
                    (0..k).map(|_| (p.resource, p.node_label.clone())).collect();
                if self.place_asks(PlaceBase::Free, &BTreeSet::new(), &asks).is_none() {
                    continue;
                }
                self.stats.elastic_grows += k as u64;
                self.queues[qi].elastic_grows += k as u64;
                self.audit(
                    app,
                    None,
                    qi,
                    DecisionReason::ElasticGrow,
                    format!("for +{k} worker(s) of idle capacity"),
                );
                tdebug!(
                    "sched",
                    "elastic grow: {app} {} -> {} worker(s) (queue '{}')",
                    p.current,
                    p.current + k,
                    p.queue
                );
                return Some((app, p.current + k));
            }
        }
        None
    }

    /// Plan one elastic *shrink* round: when an under-guarantee queue
    /// has a gang that is placeable at capacity but blocked at current
    /// free, select victims from over-allocated *elastic* jobs (newest
    /// grants first, never below a job's `min`) until a simulated
    /// placement of the gang succeeds — exactly the
    /// [`CapacityScheduler::preemption_plan`] walk, but the "victims"
    /// are cooperative releases the owning AM performs itself, so no
    /// container is killed and no restart budget burns.  Returns the new
    /// target worker count per shrinking job (empty when no round
    /// qualifies); on success the demanding gang is force-reserved onto
    /// the simulated nodes, mirroring preemption.  The RM runs this
    /// *before* [`CapacityScheduler::preemption_plan`] each pass, which
    /// is what makes shrink strictly preferred over preemption-kill.
    pub fn elastic_shrink_plan(
        &mut self,
        candidates: &[VictimCandidate],
        max_victims: usize,
        max_per_app: u32,
    ) -> Vec<(ApplicationId, u32)> {
        if max_victims == 0 || max_per_app == 0 || candidates.is_empty() || self.elastic.is_empty()
        {
            return Vec::new();
        }
        let total = self.cluster_total;
        // How many workers each elastic job may hand back this round:
        // down to its floor, capped per resize command.
        let full_budget: HashMap<ApplicationId, u32> = self
            .elastic
            .iter()
            .filter(|(_, p)| p.current > p.min)
            .map(|(app, p)| (*app, (p.current - p.min).min(max_per_app)))
            .collect();
        if full_budget.is_empty() {
            return Vec::new();
        }
        let mut order: Vec<usize> = (0..self.queues.len())
            .filter(|&i| !self.queues[i].pending.is_empty())
            .filter(|&i| self.queues[i].dom_share + EPS < self.queues[i].conf.capacity)
            .collect();
        order.sort_by(|&a, &b| self.queues[a].rel_usage.total_cmp(&self.queues[b].rel_usage));
        for qi in order {
            for unit in self.units(qi) {
                let Some(gang) = unit.gang else { continue };
                let unit_app = self.queues[qi].pending[unit.first].app;
                let asks = self.asks_of(qi, &unit);
                let total_ask = asks.iter().fold(Resource::ZERO, |a, (r, _)| a + *r);
                // Like preemption, shrink only restores a queue *up to*
                // its guarantee.
                if (self.queues[qi].used + total_ask).dominant_share(&total)
                    > self.queues[qi].conf.capacity + EPS
                {
                    continue;
                }
                let blocked = self.reserved_by_others(Some(gang));
                if self.place_asks(PlaceBase::Free, &blocked, &asks).is_some() {
                    continue; // not blocked — the next schedule pass lands it
                }
                if self.place_asks(PlaceBase::Capacity, &blocked, &asks).is_none() {
                    continue; // not placeable even at capacity
                }
                let free: Vec<Resource> = self.nodes.iter().map(|n| n.free).collect();
                let allowed: Vec<bool> =
                    self.nodes.iter().map(|n| !blocked.contains(&n.id)).collect();
                let node_idx: HashMap<NodeId, usize> =
                    self.nodes.iter().enumerate().map(|(i, n)| (n.id, i)).collect();
                let labels: BTreeSet<Option<String>> =
                    asks.iter().map(|(_, l)| l.clone()).collect();
                let mut pool: Vec<&VictimCandidate> = candidates
                    .iter()
                    .filter(|c| full_budget.contains_key(&c.app))
                    .filter(|c| self.queue_over_guarantee(&c.queue))
                    .filter(|c| {
                        node_idx
                            .get(&c.node)
                            .map(|&ni| labels.contains(&self.nodes[ni].label))
                            .unwrap_or(false)
                    })
                    .collect();
                // Newest grants first: in grant order those are the
                // highest-index workers — the ones the AM's shrink wave
                // releases.
                pool.sort_by(|a, b| b.seq.cmp(&a.seq));
                let free_after = |vs: &[VictimCandidate], skip: Option<usize>| -> Vec<Resource> {
                    let mut f = free.clone();
                    for (k, v) in vs.iter().enumerate() {
                        if Some(k) != skip {
                            f[node_idx[&v.node]] += v.resource;
                        }
                    }
                    f
                };
                let mut budget = full_budget.clone();
                let mut sim_used: BTreeMap<Arc<str>, Resource> = BTreeMap::new();
                let mut victims: Vec<VictimCandidate> = Vec::new();
                for c in pool {
                    if victims.len() >= max_victims {
                        break;
                    }
                    let Some(&vqi) = self.qname_ix.get(&*c.queue) else {
                        continue;
                    };
                    let b = budget.get_mut(&c.app).expect("pool filtered to budgeted apps");
                    if *b == 0 {
                        continue; // this job is already at its floor
                    }
                    let cur =
                        sim_used.get(&c.queue).copied().unwrap_or(self.queues[vqi].used);
                    let after = cur - c.resource;
                    // Never drive the shrinking queue below its own guarantee.
                    if after.dominant_share(&total) + EPS < self.queues[vqi].conf.capacity {
                        continue;
                    }
                    let Some(&ni) = node_idx.get(&c.node) else { continue };
                    if !allowed[ni] {
                        continue;
                    }
                    *b -= 1;
                    sim_used.insert(c.queue.clone(), after);
                    victims.push(c.clone());
                    if place_with(&self.nodes, &free_after(&victims, None), &allowed, &asks)
                        .is_none()
                    {
                        continue;
                    }
                    // The gang fits; prune releases the placement does
                    // not actually need, exactly like preemption.
                    let mut i = 0;
                    while i < victims.len() {
                        if place_with(
                            &self.nodes,
                            &free_after(&victims, Some(i)),
                            &allowed,
                            &asks,
                        )
                        .is_some()
                        {
                            victims.remove(i);
                        } else {
                            i += 1;
                        }
                    }
                    let chosen =
                        place_with(&self.nodes, &free_after(&victims, None), &allowed, &asks)
                            .expect("placement held after pruning");
                    // Hold the placement for the demanding gang so the
                    // released capacity cannot be stolen before it lands.
                    let set: BTreeSet<NodeId> =
                        chosen.iter().map(|&ni| self.nodes[ni].id).collect();
                    self.drop_reservation(gang);
                    self.push_reservation(gang, qi, set.into_iter().collect());
                    self.stats.elastic_shrink_rounds += 1;
                    self.stats.elastic_released += victims.len() as u64;
                    self.audit(
                        unit_app,
                        Some(gang),
                        qi,
                        DecisionReason::ElasticShrink,
                        format!(
                            "{} cooperative release(s) planned to open the gang's hole",
                            victims.len()
                        ),
                    );
                    let mut per_app: BTreeMap<ApplicationId, u32> = BTreeMap::new();
                    for v in &victims {
                        *per_app.entry(v.app).or_insert(0) += 1;
                        if let Some(&vqi) = self.qname_ix.get(&*v.queue) {
                            self.queues[vqi].elastic_shrinks += 1;
                        }
                    }
                    let mut targets: Vec<(ApplicationId, u32)> = Vec::new();
                    for (app, n) in per_app {
                        let (target, pqueue) = {
                            let p = &self.elastic[&app];
                            (p.current.saturating_sub(n).max(p.min), p.queue.clone())
                        };
                        if let Some(&vqi) = self.qname_ix.get(&*pqueue) {
                            let demand_q = self.queues[qi].name.clone();
                            self.audit(
                                app,
                                None,
                                vqi,
                                DecisionReason::ElasticShrink,
                                format!(
                                    "shrinking {n} worker(s) toward queue '{demand_q}' guarantee"
                                ),
                            );
                        }
                        targets.push((app, target));
                    }
                    tdebug!(
                        "sched",
                        "elastic shrink: {} release(s) across {} job(s) unblock gang {gang} in queue '{}'",
                        victims.len(),
                        targets.len(),
                        self.queues[qi].name
                    );
                    return targets;
                }
                // Budget exhausted without unblocking the gang: propose
                // nothing (all-or-nothing rounds) and try the next unit.
            }
        }
        Vec::new()
    }

    /// Check every index/cache against a from-scratch recompute.  Test
    /// hook (the property suite calls this after every mutation); panics
    /// on the first inconsistency.  Cached shares must be *bit-identical*
    /// to a recompute — they are refreshed by recomputing from `used`,
    /// never by incremental float arithmetic.
    #[doc(hidden)]
    pub fn verify_invariants(&self) {
        // Node table ↔ id map ↔ label table.
        assert_eq!(self.node_ix.len(), self.nodes.len(), "node_ix size");
        assert_eq!(self.node_label.len(), self.nodes.len(), "node_label size");
        assert_eq!(self.labels.len(), self.label_ids.len(), "label intern size");
        assert_eq!(self.labels.len(), self.free_by_label.len(), "free skyline count");
        assert_eq!(self.labels.len(), self.cap_by_label.len(), "cap skyline count");
        for (lid, label) in self.labels.iter().enumerate() {
            assert_eq!(
                self.label_ids.get(label).copied(),
                Some(lid as u32),
                "label intern round-trip"
            );
        }
        let mut free_entries = 0usize;
        let mut cap_entries = 0usize;
        for s in &self.free_by_label {
            free_entries += s.len();
        }
        for s in &self.cap_by_label {
            cap_entries += s.len();
        }
        assert_eq!(free_entries, self.nodes.len(), "stale/missing free skyline entries");
        assert_eq!(cap_entries, self.nodes.len(), "stale/missing cap skyline entries");
        for (i, n) in self.nodes.iter().enumerate() {
            assert_eq!(self.node_ix.get(&n.id).copied(), Some(i), "node_ix[{:?}]", n.id);
            let lid = self.node_label[i] as usize;
            assert_eq!(self.labels[lid], n.label, "node_label[{i}]");
            assert!(
                self.free_by_label[lid].contains(&(n.free.memory_mb, i)),
                "free skyline misses node {i}"
            );
            assert!(
                self.cap_by_label[lid].contains(&(n.capacity.memory_mb, i)),
                "cap skyline misses node {i}"
            );
        }
        // Queue caches.
        let mut app_gangs: HashMap<ApplicationId, u32> = HashMap::new();
        for (qi, q) in self.queues.iter().enumerate() {
            assert_eq!(
                self.qname_ix.get(&*q.name).copied(),
                Some(qi),
                "qname_ix['{}']",
                q.name
            );
            let share = q.used.dominant_share(&self.cluster_total);
            assert_eq!(q.dom_share, share, "queue '{}' cached dominant share", q.name);
            let rel = if q.conf.capacity <= 0.0 { f64::INFINITY } else { share / q.conf.capacity };
            assert_eq!(q.rel_usage, rel, "queue '{}' cached relative usage", q.name);
            let mut gang_asks: BTreeMap<u64, u32> = BTreeMap::new();
            for a in &q.pending {
                if let Some(g) = a.gang {
                    *gang_asks.entry(g).or_insert(0) += 1;
                    *app_gangs.entry(a.app).or_insert(0) += 1;
                }
            }
            assert_eq!(q.gang_asks, gang_asks, "queue '{}' gang-ask counters", q.name);
            let reserved = self.reservations.iter().filter(|r| r.queue == qi).count();
            assert_eq!(q.reserved as usize, reserved, "queue '{}' reservation counter", q.name);
        }
        assert_eq!(self.app_gangs, app_gangs, "per-app gang-ask counters");
        // Elastic registry: bounds sane, current inside the band, queue
        // known (registration remaps unknown names, so drift here means
        // a mutation bypassed register_elastic/set_elastic_current).
        for (app, p) in &self.elastic {
            assert!(p.min >= 1, "elastic {app}: min must be >= 1");
            assert!(p.min <= p.max, "elastic {app}: min {} > max {}", p.min, p.max);
            assert!(
                (p.min..=p.max).contains(&p.current),
                "elastic {app}: current {} outside [{}, {}]",
                p.current,
                p.min,
                p.max
            );
            assert!(
                self.qname_ix.contains_key(&*p.queue),
                "elastic {app}: unknown queue '{}'",
                p.queue
            );
        }
    }
}

/// Dry-run placement of `asks` over `free0` (one entry per node in
/// `nodes`), restricted to `allowed` nodes — the retained linear
/// reference used by the preemption victim walk (and equivalent to
/// [`CapacityScheduler::place_asks`], which the property suite checks).
/// Larger asks are placed first (fewer fragmentation failures); each
/// ask takes the best-fit node — matching label, smallest leftover
/// memory.  Returns the chosen node index per ask (in `asks` order), or
/// `None` when any ask cannot be placed.
fn place_with(
    nodes: &[SchedNode],
    free0: &[Resource],
    allowed: &[bool],
    asks: &[(Resource, Option<String>)],
) -> Option<Vec<usize>> {
    let mut free = free0.to_vec();
    let mut order: Vec<usize> = (0..asks.len()).collect();
    order.sort_by(|&a, &b| {
        asks[b]
            .0
            .memory_mb
            .cmp(&asks[a].0.memory_mb)
            .then(asks[b].0.gpus.cmp(&asks[a].0.gpus))
            .then(asks[b].0.vcores.cmp(&asks[a].0.vcores))
            .then(a.cmp(&b))
    });
    let mut chosen = vec![usize::MAX; asks.len()];
    for &ai in &order {
        let (r, label) = &asks[ai];
        let ni = best_fit(nodes, &free, allowed, r, label)?;
        free[ni] -= *r;
        chosen[ai] = ni;
    }
    Some(chosen)
}

/// Best-fit node choice: among allowed nodes matching the label with
/// room, pick the one whose remaining free memory is smallest after
/// placement (packs tightly, preserving big slots for big asks).
fn best_fit(
    nodes: &[SchedNode],
    free: &[Resource],
    allowed: &[bool],
    r: &Resource,
    label: &Option<String>,
) -> Option<usize> {
    let mut best: Option<(usize, u64)> = None;
    for i in 0..nodes.len() {
        if !allowed[i] || nodes[i].label != *label || !free[i].fits(r) {
            continue;
        }
        let leftover = free[i].memory_mb - r.memory_mb;
        match best {
            Some((_, b)) if leftover >= b => {}
            _ => best = Some((i, leftover)),
        }
    }
    best.map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app(seq: u64) -> ApplicationId {
        ApplicationId { cluster_ts: 1, seq }
    }

    fn nodes2() -> Vec<SchedNode> {
        vec![
            SchedNode::new(0, None, Resource::new(8192, 8, 0)),
            SchedNode::new(1, Some("gpu".into()), Resource::new(8192, 8, 4)),
        ]
    }

    #[test]
    fn grants_respect_capacity_and_labels() {
        let mut s = CapacityScheduler::new(QueueConf::default_only(), Resource::new(16384, 16, 4));
        s.set_nodes(nodes2());
        s.add_asks(
            app(1),
            "default",
            &[
                ContainerRequest::new(Resource::new(2048, 2, 1), 2).with_label("gpu"),
                ContainerRequest::new(Resource::new(2048, 2, 0), 2),
            ],
            0,
        );
        let grants = s.schedule();
        assert_eq!(grants.len(), 4);
        for g in &grants {
            if g.ask.node_label.as_deref() == Some("gpu") {
                assert_eq!(g.node, NodeId(1), "gpu asks must land on the gpu node");
            } else {
                assert_eq!(g.node, NodeId(0), "unlabeled asks stay on the default partition");
            }
        }
        // No oversubscription.
        assert!(s.node_free(NodeId(0)).unwrap().memory_mb <= 8192);
        assert_eq!(s.node_free(NodeId(1)).unwrap().gpus, 2);
        s.verify_invariants();
    }

    #[test]
    fn unsatisfiable_asks_stay_pending() {
        let mut s = CapacityScheduler::new(QueueConf::default_only(), Resource::new(8192, 8, 0));
        s.set_nodes(vec![SchedNode {
            id: NodeId(0),
            label: None,
            free: Resource::new(4096, 4, 0),
            capacity: Resource::new(4096, 4, 0),
        }]);
        s.add_asks(app(1), "default", &[ContainerRequest::new(Resource::new(8192, 1, 0), 1)], 0);
        let grants = s.schedule();
        assert!(grants.is_empty());
        assert_eq!(s.pending_count(), 1);
    }

    #[test]
    fn max_capacity_is_a_ceiling() {
        // Queue limited to 50% of a 8 GiB cluster: second 3 GiB ask must wait.
        let queues = vec![
            QueueConf::new("ml", 0.5, 0.5),
            QueueConf::new("etl", 0.5, 1.0),
        ];
        let mut s = CapacityScheduler::new(queues, Resource::new(8192, 8, 0));
        s.set_nodes(vec![SchedNode::new(0, None, Resource::new(8192, 8, 0))]);
        s.add_asks(app(1), "ml", &[ContainerRequest::new(Resource::new(3072, 1, 0), 2)], 0);
        let grants = s.schedule();
        assert_eq!(grants.len(), 1, "only one 3GiB ask fits under the 50% cap");
        assert_eq!(s.pending_count(), 1);
        // After release, the pending ask can go.
        s.release_container("ml", NodeId(0), Resource::new(3072, 1, 0));
        assert_eq!(s.schedule().len(), 1);
        s.verify_invariants();
    }

    #[test]
    fn capacity_fractions_steer_sharing() {
        // 75/25 split: with both queues asking for everything, ml should
        // end up with ~3x etl's containers.
        let queues = vec![
            QueueConf::new("ml", 0.75, 1.0),
            QueueConf::new("etl", 0.25, 1.0),
        ];
        let mut s = CapacityScheduler::new(queues, Resource::new(8192, 64, 0));
        s.set_nodes(vec![SchedNode::new(0, None, Resource::new(8192, 64, 0))]);
        let shape = ContainerRequest::new(Resource::new(1024, 1, 0), 8);
        s.add_asks(app(1), "ml", &[shape.clone()], 0);
        s.add_asks(app(2), "etl", &[shape], 100);
        let grants = s.schedule();
        assert_eq!(grants.len(), 8, "cluster fits exactly 8 containers");
        let ml = grants.iter().filter(|g| &*g.ask.queue == "ml").count();
        assert_eq!(ml, 6, "75% queue gets 6 of 8");
    }

    #[test]
    fn priority_order_within_queue() {
        let mut s = CapacityScheduler::new(QueueConf::default_only(), Resource::new(4096, 4, 0));
        s.set_nodes(vec![SchedNode::new(0, None, Resource::new(1024, 1, 0))]);
        // Low priority first in FIFO order, then high priority.
        s.add_asks(
            app(1),
            "default",
            &[ContainerRequest::new(Resource::new(1024, 1, 0), 1).with_priority(1)],
            0,
        );
        s.add_asks(
            app(1),
            "default",
            &[ContainerRequest::new(Resource::new(1024, 1, 0), 1).with_priority(5)],
            10,
        );
        let grants = s.schedule();
        assert_eq!(grants.len(), 1);
        assert_eq!(grants[0].ask.priority, 5, "high priority wins the single slot");
    }

    #[test]
    fn remove_app_clears_pending() {
        let mut s = CapacityScheduler::new(QueueConf::default_only(), Resource::new(8192, 8, 0));
        s.add_asks(app(1), "default", &[ContainerRequest::new(Resource::new(1024, 1, 0), 3)], 0);
        s.add_asks(app(2), "default", &[ContainerRequest::new(Resource::new(1024, 1, 0), 2)], 50);
        s.remove_app(app(1));
        assert_eq!(s.pending_count(), 2);
        s.verify_invariants();
    }

    #[test]
    fn best_fit_packs_tightly() {
        let mut s = CapacityScheduler::new(QueueConf::default_only(), Resource::new(12288, 12, 0));
        s.set_nodes(vec![
            SchedNode::new(0, None, Resource::new(8192, 8, 0)),
            SchedNode::new(1, None, Resource::new(2048, 2, 0)),
        ]);
        s.add_asks(app(1), "default", &[ContainerRequest::new(Resource::new(2048, 1, 0), 1)], 0);
        let grants = s.schedule();
        // Best fit: lands on the small node, preserving the big slot.
        assert_eq!(grants[0].node, NodeId(1));
    }

    // ---------------- gang placement ----------------

    #[test]
    fn gang_is_all_or_nothing() {
        let mut s = CapacityScheduler::new(QueueConf::default_only(), Resource::new(4096, 4, 0));
        s.set_nodes(vec![
            SchedNode::new(0, None, Resource::new(2048, 2, 0)),
            SchedNode::new(1, None, Resource::new(2048, 2, 0)),
        ]);
        // A 3-container gang on a cluster that only fits 2 right now
        // (node 1 half-occupied): nothing may be granted.
        s.set_node_free(NodeId(1), Resource::new(1024, 1, 0));
        let intake = s.add_asks_gang(
            app(1),
            "default",
            &[ContainerRequest::new(Resource::new(1024, 1, 0), 3)],
            0,
            Some(7),
        );
        assert_eq!(intake.next_tag, 3);
        assert!(s.schedule().is_empty(), "partial gang placement is forbidden");
        assert_eq!(s.pending_count(), 3);
        // Capacity drains: the whole gang lands at once.
        s.set_node_free(NodeId(1), Resource::new(2048, 2, 0));
        let grants = s.schedule();
        assert_eq!(grants.len(), 3);
        assert!(grants.iter().all(|g| g.ask.gang == Some(7)));
        assert_eq!(s.stats().gangs_placed, 1);
        s.verify_invariants();
    }

    #[test]
    fn interleaved_singles_deadlock_where_gangs_do_not() {
        // The contention pathology gang mode cures: two jobs each need 2
        // containers on a 2-slot cluster.  With per-container asks
        // interleaved, each job gets 1 slot and holds it forever (a
        // distributed-training barrier never forms).  With gangs, job 1
        // lands whole and job 2 waits whole.
        let nodes_fn = || {
            vec![
                SchedNode::new(0, None, Resource::new(1024, 1, 0)),
                SchedNode::new(1, None, Resource::new(1024, 1, 0)),
            ]
        };
        let shape = ContainerRequest::new(Resource::new(1024, 1, 0), 1);

        // Legacy: interleaved single asks -> one slot each (deadlock).
        let mut s = CapacityScheduler::new(QueueConf::default_only(), Resource::new(2048, 2, 0));
        s.set_nodes(nodes_fn());
        s.add_asks(app(1), "default", &[shape.clone()], 0);
        s.add_asks(app(2), "default", &[shape.clone()], 10);
        s.add_asks(app(1), "default", &[shape.clone()], 1);
        s.add_asks(app(2), "default", &[shape.clone()], 11);
        let grants = s.schedule();
        let apps: BTreeSet<u64> = grants.iter().map(|g| g.ask.app.seq).collect();
        assert_eq!(grants.len(), 2);
        assert_eq!(apps.len(), 2, "legacy splits the cluster: each app holds half a gang");

        // Gang mode: app 1's gang commits whole; app 2 waits whole.
        let mut s = CapacityScheduler::new(QueueConf::default_only(), Resource::new(2048, 2, 0));
        s.set_nodes(nodes_fn());
        let shape2 = ContainerRequest::new(Resource::new(1024, 1, 0), 2);
        s.add_asks_gang(app(1), "default", &[shape2.clone()], 0, Some(1));
        s.add_asks_gang(app(2), "default", &[shape2], 10, Some(2));
        let grants = s.schedule();
        assert_eq!(grants.len(), 2);
        assert!(grants.iter().all(|g| g.ask.app == app(1)), "first gang placed whole");
        assert!(s.has_pending_gang(app(2)), "second gang waits whole");
        assert!(!s.has_pending_gang(app(1)), "placed gang no longer pending");
    }

    #[test]
    fn blocked_gang_reserves_and_drains() {
        let mut s = CapacityScheduler::new(QueueConf::default_only(), Resource::new(2048, 2, 0));
        s.set_nodes(vec![
            SchedNode::new(0, None, Resource::new(1024, 1, 0)),
            SchedNode::new(1, None, Resource::new(1024, 1, 0)),
        ]);
        s.set_node_free(NodeId(0), Resource::ZERO); // occupied by someone else
        let gang_shape = ContainerRequest::new(Resource::new(1024, 1, 0), 2);
        s.add_asks_gang(app(1), "default", &[gang_shape], 0, Some(1));
        // A stream of small singles that would otherwise starve the gang.
        s.add_asks(app(2), "default", &[ContainerRequest::new(Resource::new(512, 1, 0), 1)], 10);
        let grants = s.schedule();
        // The gang reserved both nodes, so the small ask gets nothing.
        assert!(grants.is_empty(), "reserved nodes accept no other placements: {grants:?}");
        assert_eq!(s.reservation_count(), 1);
        assert_eq!(s.stats().reservations_made, 1);
        s.verify_invariants();
        // The occupied node drains -> the gang lands, reservation clears,
        // and the small ask flows again.
        s.set_node_free(NodeId(0), Resource::new(1024, 1, 0));
        let grants = s.schedule();
        assert_eq!(grants.len(), 2);
        assert!(grants.iter().all(|g| g.ask.gang == Some(1)));
        assert_eq!(s.reservation_count(), 0);
        s.add_node_free(NodeId(0), Resource::new(1024, 1, 0)); // gang task finished
        let grants = s.schedule();
        assert_eq!(grants.len(), 1, "singles flow once the reservation cleared");
        s.verify_invariants();
    }

    #[test]
    fn reservation_limit_is_respected() {
        let mut s = CapacityScheduler::new(QueueConf::default_only(), Resource::new(2048, 2, 0));
        s.set_reservation_limit(1);
        s.set_nodes(vec![
            SchedNode::new(0, None, Resource::new(1024, 1, 0)),
            SchedNode::new(1, None, Resource::new(1024, 1, 0)),
        ]);
        s.set_node_free(NodeId(0), Resource::ZERO);
        s.set_node_free(NodeId(1), Resource::ZERO);
        let shape = ContainerRequest::new(Resource::new(1024, 1, 0), 2);
        s.add_asks_gang(app(1), "default", &[shape.clone()], 0, Some(1));
        s.add_asks_gang(app(2), "default", &[shape], 10, Some(2));
        assert!(s.schedule().is_empty());
        assert_eq!(s.reservation_count(), 1, "only one reservation slot configured");
    }

    #[test]
    fn unknown_queue_ask_is_remapped_logged_and_counted() {
        let mut s = CapacityScheduler::new(QueueConf::default_only(), Resource::new(4096, 4, 0));
        let intake = s.add_asks_gang(
            app(1),
            "no-such-queue",
            &[ContainerRequest::new(Resource::new(1024, 1, 0), 1)],
            0,
            None,
        );
        assert!(intake.remapped);
        assert_eq!(&*intake.queue, "default");
        assert_eq!(s.stats().unknown_queue_asks, 1);
        // The remapped ask is chargeable and schedulable.
        s.set_nodes(vec![SchedNode::new(0, None, Resource::new(4096, 4, 0))]);
        let grants = s.schedule();
        assert_eq!(grants.len(), 1);
        assert_eq!(&*grants[0].ask.queue, "default");
    }

    #[test]
    fn unknown_queue_release_is_counted_not_dropped_silently() {
        let mut s = CapacityScheduler::new(QueueConf::default_only(), Resource::new(4096, 4, 0));
        s.release("ghost", Resource::new(1024, 1, 0));
        s.release("ghost", Resource::new(1024, 1, 0));
        assert_eq!(s.stats().unknown_queue_releases, 2);
        assert_eq!(s.queue_used("default"), Some(Resource::ZERO), "known queues untouched");
    }

    fn victims_of(grants: &[Grant]) -> Vec<VictimCandidate> {
        grants
            .iter()
            .enumerate()
            .map(|(i, g)| VictimCandidate {
                container: ContainerId { app: g.ask.app, seq: i as u64 + 1 },
                app: g.ask.app,
                queue: g.ask.queue.clone(),
                node: g.node,
                resource: g.ask.resource,
                gang: g.ask.gang,
                seq: i as u64 + 1,
            })
            .collect()
    }

    #[test]
    fn preemption_plan_unblocks_starved_queue_up_to_guarantee() {
        let queues = vec![
            QueueConf::new("ml", 0.75, 1.0),
            QueueConf::new("etl", 0.25, 1.0),
        ];
        let mut s = CapacityScheduler::new(queues, Resource::new(8192, 8, 0));
        s.set_nodes(vec![
            SchedNode::new(0, None, Resource::new(4096, 4, 0)),
            SchedNode::new(1, None, Resource::new(4096, 4, 0)),
        ]);
        // etl bursts to 6 GiB (75% >> its 25% guarantee).
        s.add_asks_gang(
            app(2),
            "etl",
            &[ContainerRequest::new(Resource::new(1024, 1, 0), 6)],
            100,
            Some(1),
        );
        let etl_grants = s.schedule();
        assert_eq!(etl_grants.len(), 6);
        let candidates = victims_of(&etl_grants);
        // ml asks a 4 GiB gang: blocked (only 2 GiB free), under its 75%
        // guarantee, and feasible at capacity -> preemption triggers.
        s.add_asks_gang(
            app(1),
            "ml",
            &[ContainerRequest::new(Resource::new(1024, 1, 0), 4)],
            0,
            Some(2),
        );
        assert!(s.schedule().is_empty(), "gang blocked before preemption");
        let victims = s.preemption_plan(&candidates, 8);
        assert!(!victims.is_empty(), "an under-guarantee queue must claw back capacity");
        // Victims are newest-first and never drive etl below its 25%
        // guarantee (2 GiB): at most 4 of etl's 6 GiB may be taken.
        assert!(victims.len() <= 4, "victims: {victims:?}");
        assert_eq!(victims[0].seq, 6, "newest grant dies first");
        let freed = victims.iter().fold(Resource::ZERO, |a, v| a + v.resource);
        let etl_after = s.queue_used("etl").unwrap() - freed;
        assert!(
            etl_after.dominant_share(&s.cluster_total()) >= 0.25 - 1e-9,
            "victim queue dropped below its guarantee"
        );
        assert_eq!(s.stats().preemption_rounds, 1);
        assert_eq!(s.stats().preemptions, victims.len() as u64);
        s.verify_invariants();
        // Victims' capacity returns -> the gang lands on the reserved nodes.
        for v in &victims {
            s.release_container(&v.queue, v.node, v.resource);
        }
        let grants = s.schedule();
        assert_eq!(grants.len(), 4, "preemption unblocked the whole gang");
        assert!(grants.iter().all(|g| &*g.ask.queue == "ml"));
        s.verify_invariants();
    }

    #[test]
    fn preemption_is_all_or_nothing_per_round() {
        // max_victims too small to unblock the gang: nobody dies.
        let queues = vec![
            QueueConf::new("ml", 0.75, 1.0),
            QueueConf::new("etl", 0.25, 1.0),
        ];
        let mut s = CapacityScheduler::new(queues, Resource::new(8192, 8, 0));
        s.set_nodes(vec![SchedNode::new(0, None, Resource::new(8192, 8, 0))]);
        s.add_asks_gang(
            app(2),
            "etl",
            &[ContainerRequest::new(Resource::new(1024, 1, 0), 6)],
            100,
            Some(1),
        );
        let etl_grants = s.schedule();
        let candidates = victims_of(&etl_grants);
        s.add_asks_gang(
            app(1),
            "ml",
            &[ContainerRequest::new(Resource::new(1024, 1, 0), 4)],
            0,
            Some(2),
        );
        let victims = s.preemption_plan(&candidates, 1);
        assert!(victims.is_empty(), "1 victim cannot unblock a 4-container gang");
        assert_eq!(s.stats().preemptions, 0);
    }

    #[test]
    fn ceiling_blocked_gang_gates_younger_same_queue_singles() {
        // Regression: with the queue at its ceiling, younger singles of
        // the same queue used to re-consume every drained byte of
        // headroom, so a senior gang (which needs the headroom to open
        // by its whole size at once) starved forever.  The gang now
        // gates the queue's younger units until its headroom opens.
        let queues = vec![
            QueueConf::new("ml", 0.5, 0.5),
            QueueConf::new("etl", 0.5, 1.0),
        ];
        let mut s = CapacityScheduler::new(queues, Resource::new(4096, 8, 0));
        s.set_nodes(vec![SchedNode::new(0, None, Resource::new(4096, 8, 0))]);
        let slot = ContainerRequest::new(Resource::new(1024, 1, 0), 1);
        // App A fills ml to its 2 GiB ceiling.
        s.add_asks(app(1), "ml", &[slot.clone(), slot.clone()], 0);
        assert_eq!(s.schedule().len(), 2);
        // App B's senior gang, then younger singles from A.
        s.add_asks_gang(
            app(2),
            "ml",
            &[ContainerRequest::new(Resource::new(1024, 1, 0), 2)],
            10,
            Some(1),
        );
        s.add_asks(app(1), "ml", &[slot.clone(), slot], 20);
        // One of A's containers drains: the freed headroom must be held
        // for the gang, not snapped up by A's younger single.
        s.release_container("ml", NodeId(0), Resource::new(1024, 1, 0));
        assert!(
            s.schedule().is_empty(),
            "younger single re-consumed the gang's draining headroom"
        );
        // Second drain: the gang's whole hole is open — it lands.
        s.release_container("ml", NodeId(0), Resource::new(1024, 1, 0));
        let grants = s.schedule();
        assert_eq!(grants.len(), 2, "{grants:?}");
        assert!(grants.iter().all(|g| g.ask.gang == Some(1)), "the senior gang wins");
        assert_eq!(s.pending_count(), 2, "A's younger singles wait for the next drain");
    }

    #[test]
    fn oversized_gang_demotes_to_per_container_trickle() {
        // adhoc's hard ceiling is 30% of 16 GiB (~4.9 GiB); a 12 GiB
        // gang can never place atomically and must not hang forever —
        // it degrades to the legacy trickle and flows under the ceiling.
        let queues = vec![
            QueueConf::new("prod", 0.75, 1.0),
            QueueConf::new("adhoc", 0.25, 0.3),
        ];
        let mut s = CapacityScheduler::new(queues, Resource::new(16384, 32, 0));
        s.set_nodes(vec![
            SchedNode::new(0, None, Resource::new(8192, 16, 0)),
            SchedNode::new(1, None, Resource::new(8192, 16, 0)),
        ]);
        s.add_asks_gang(
            app(1),
            "adhoc",
            &[ContainerRequest::new(Resource::new(1024, 1, 0), 12)],
            0,
            Some(1),
        );
        let grants = s.schedule();
        assert_eq!(s.stats().gangs_demoted, 1);
        assert_eq!(grants.len(), 4, "trickles up to the 30% ceiling (4 x 1 GiB)");
        assert!(grants.iter().all(|g| g.ask.gang.is_none()), "demoted asks lose the gang id");
        assert!(!s.has_pending_gang(app(1)));
        s.verify_invariants();
    }

    #[test]
    fn capacity_infeasible_gang_demotes_instead_of_hanging() {
        // 3 x 1536 MB can never co-exist on two 2048 MB nodes, even
        // empty: the gang demotes and two containers flow immediately.
        let mut s = CapacityScheduler::new(QueueConf::default_only(), Resource::new(4096, 4, 0));
        s.set_nodes(vec![
            SchedNode::new(0, None, Resource::new(2048, 2, 0)),
            SchedNode::new(1, None, Resource::new(2048, 2, 0)),
        ]);
        s.add_asks_gang(
            app(1),
            "default",
            &[ContainerRequest::new(Resource::new(1536, 1, 0), 3)],
            0,
            Some(1),
        );
        let grants = s.schedule();
        assert_eq!(s.stats().gangs_demoted, 1);
        assert_eq!(grants.len(), 2, "one per node flows right away");
        assert_eq!(s.pending_count(), 1, "the third waits for a release, not forever");
        s.verify_invariants();
    }

    #[test]
    fn decisions_are_audited_and_drained() {
        let mut s = CapacityScheduler::new(QueueConf::default_only(), Resource::new(2048, 2, 0));
        s.set_nodes(vec![
            SchedNode::new(0, None, Resource::new(1024, 1, 0)),
            SchedNode::new(1, None, Resource::new(1024, 1, 0)),
        ]);
        s.set_node_free(NodeId(0), Resource::ZERO);
        s.add_asks_gang(
            app(1),
            "default",
            &[ContainerRequest::new(Resource::new(1024, 1, 0), 2)],
            0,
            Some(1),
        );
        assert!(s.schedule().is_empty());
        let d = s.take_decisions();
        assert!(
            d.iter().any(|x| x.reason == DecisionReason::WaitingFree
                && x.gang == Some(1)
                && x.app == app(1)),
            "{d:?}"
        );
        assert!(d.iter().any(|x| x.reason == DecisionReason::Reserved), "{d:?}");
        assert!(s.take_decisions().is_empty(), "take_decisions drains");
        s.set_node_free(NodeId(0), Resource::new(1024, 1, 0));
        assert_eq!(s.schedule().len(), 2);
        let d = s.take_decisions();
        assert!(d.iter().any(|x| x.reason == DecisionReason::PlacedAll), "{d:?}");
    }

    #[test]
    fn headroom_and_demotion_verdicts_are_audited() {
        // Headroom-blocked gang (fits under the ceiling alone, but the
        // queue is full right now).
        let queues = vec![QueueConf::new("ml", 0.5, 0.5), QueueConf::new("etl", 0.5, 1.0)];
        let mut s = CapacityScheduler::new(queues, Resource::new(4096, 8, 0));
        s.set_nodes(vec![SchedNode::new(0, None, Resource::new(4096, 8, 0))]);
        let slot = ContainerRequest::new(Resource::new(1024, 1, 0), 1);
        s.add_asks(app(1), "ml", &[slot.clone(), slot], 0);
        assert_eq!(s.schedule().len(), 2);
        s.add_asks_gang(
            app(2),
            "ml",
            &[ContainerRequest::new(Resource::new(1024, 1, 0), 2)],
            10,
            Some(1),
        );
        s.take_decisions();
        assert!(s.schedule().is_empty());
        let d = s.take_decisions();
        let wh = d
            .iter()
            .find(|x| x.reason == DecisionReason::WaitingHeadroom)
            .expect("headroom verdict audited");
        assert_eq!(&*wh.queue, "ml");
        assert!(wh.detail.contains("for queue 'ml' headroom"), "{}", wh.detail);
        // Infeasible gang demotes with an audited reason.
        let mut s = CapacityScheduler::new(QueueConf::default_only(), Resource::new(4096, 4, 0));
        s.set_nodes(vec![
            SchedNode::new(0, None, Resource::new(2048, 2, 0)),
            SchedNode::new(1, None, Resource::new(2048, 2, 0)),
        ]);
        s.add_asks_gang(
            app(1),
            "default",
            &[ContainerRequest::new(Resource::new(1536, 1, 0), 3)],
            0,
            Some(1),
        );
        s.schedule();
        let d = s.take_decisions();
        let dem = d
            .iter()
            .find(|x| x.reason == DecisionReason::Demoted)
            .expect("demotion audited");
        assert!(dem.detail.contains("infeasible"), "{}", dem.detail);
    }

    #[test]
    fn queue_snapshots_expose_gang_state() {
        let mut s = CapacityScheduler::new(QueueConf::default_only(), Resource::new(2048, 2, 0));
        s.set_nodes(vec![SchedNode::new(0, None, Resource::new(2048, 2, 0))]);
        s.set_node_free(NodeId(0), Resource::ZERO);
        s.add_asks_gang(
            app(1),
            "default",
            &[ContainerRequest::new(Resource::new(1024, 1, 0), 2)],
            0,
            Some(1),
        );
        assert!(s.schedule().is_empty());
        let snap = &s.queue_snapshots()[0];
        assert_eq!(snap.pending_asks, 2);
        assert_eq!(snap.pending_gangs, 1);
        assert_eq!(snap.reservations, 1);
        assert_eq!(snap.capacity, 1.0);
    }

    // ---------------- index + counter consistency ----------------

    #[test]
    fn snapshots_from_counters_agree_with_ground_truth_mid_preemption() {
        // Regression for the reservation-list walk the counters replace:
        // capture snapshots at the most entangled moment — a preemption
        // round just force-reserved nodes for a blocked gang while the
        // victim queue still holds its capacity — and check them against
        // a recount of the raw state.
        let queues = vec![
            QueueConf::new("ml", 0.75, 1.0),
            QueueConf::new("etl", 0.25, 1.0),
        ];
        let mut s = CapacityScheduler::new(queues, Resource::new(8192, 8, 0));
        s.set_nodes(vec![
            SchedNode::new(0, None, Resource::new(4096, 4, 0)),
            SchedNode::new(1, None, Resource::new(4096, 4, 0)),
        ]);
        s.add_asks_gang(
            app(2),
            "etl",
            &[ContainerRequest::new(Resource::new(1024, 1, 0), 6)],
            100,
            Some(1),
        );
        let etl_grants = s.schedule();
        let candidates = victims_of(&etl_grants);
        s.add_asks_gang(
            app(1),
            "ml",
            &[ContainerRequest::new(Resource::new(1024, 1, 0), 4)],
            0,
            Some(2),
        );
        assert!(s.schedule().is_empty());
        let victims = s.preemption_plan(&candidates, 8);
        assert!(!victims.is_empty());
        // Mid-preemption: victims selected, capacity not yet returned,
        // the ml gang force-reserved.  Counters must match ground truth.
        let snaps = s.queue_snapshots();
        let ml = snaps.iter().find(|q| &*q.name == "ml").unwrap();
        let etl = snaps.iter().find(|q| &*q.name == "etl").unwrap();
        assert_eq!(ml.pending_asks, 4);
        assert_eq!(ml.pending_gangs, 1, "the blocked gang is still pending");
        assert_eq!(ml.reservations, 1, "the force-reservation is counted");
        assert_eq!(etl.reservations, 0);
        assert_eq!(etl.pending_gangs, 0);
        assert_eq!(etl.preemptions, victims.len() as u64);
        assert_eq!(
            snaps.iter().map(|q| q.reservations).sum::<usize>(),
            s.reservation_count(),
            "per-queue reservation counters sum to the reservation list"
        );
        s.verify_invariants();
    }

    #[test]
    fn node_remove_keeps_index_consistent() {
        let mut s = CapacityScheduler::new(QueueConf::default_only(), Resource::new(8192, 8, 4));
        s.set_nodes(vec![
            SchedNode::new(0, None, Resource::new(2048, 2, 0)),
            SchedNode::new(1, Some("gpu".into()), Resource::new(2048, 2, 4)),
            SchedNode::new(2, None, Resource::new(2048, 2, 0)),
            SchedNode::new(3, None, Resource::new(2048, 2, 0)),
        ]);
        s.add_asks(app(1), "default", &[ContainerRequest::new(Resource::new(1024, 1, 0), 3)], 0);
        assert_eq!(s.schedule().len(), 3);
        s.verify_invariants();
        // Remove a middle node: swap_remove moves the last node into its
        // slot; every index entry must follow.
        let total_before = s.cluster_total();
        assert!(s.remove_node(NodeId(2)));
        assert!(!s.remove_node(NodeId(2)), "second removal is a no-op");
        s.verify_invariants();
        assert_eq!(s.node_count(), 3);
        assert_eq!(s.node_free(NodeId(2)), None);
        assert_eq!(
            s.cluster_total().memory_mb,
            total_before.memory_mb - 2048,
            "cluster total shrinks by the removed capacity"
        );
        // Scheduling still works against the compacted table.
        s.add_asks(app(2), "default", &[ContainerRequest::new(Resource::new(1024, 1, 0), 2)], 10);
        let grants = s.schedule();
        assert!(!grants.is_empty());
        assert!(grants.iter().all(|g| g.node != NodeId(2)));
        s.verify_invariants();
    }

    #[test]
    fn indexed_matches_linear_reference() {
        // The same ask/release script must produce bit-identical grants
        // with the skyline index and with the linear reference scan.
        let run = |linear: bool| -> Vec<(u64, u32)> {
            let queues = vec![
                QueueConf::new("ml", 0.6, 1.0),
                QueueConf::new("etl", 0.4, 0.7),
            ];
            let mut s = CapacityScheduler::new(queues, Resource::new(24576, 24, 4));
            s.set_linear_reference(linear);
            s.set_nodes(vec![
                SchedNode::new(0, None, Resource::new(8192, 8, 0)),
                SchedNode::new(1, Some("gpu".into()), Resource::new(8192, 8, 4)),
                SchedNode::new(2, None, Resource::new(4096, 4, 0)),
                SchedNode::new(3, None, Resource::new(4096, 4, 0)),
            ]);
            let mut out = Vec::new();
            s.add_asks(app(1), "ml", &[ContainerRequest::new(Resource::new(1024, 1, 0), 4)], 0);
            s.add_asks_gang(
                app(2),
                "etl",
                &[ContainerRequest::new(Resource::new(2048, 2, 0), 3)],
                100,
                Some(1),
            );
            s.add_asks(
                app(3),
                "ml",
                &[ContainerRequest::new(Resource::new(2048, 2, 1), 2).with_label("gpu")],
                200,
            );
            for g in s.schedule() {
                out.push((g.ask.tag, g.node.0));
            }
            s.release_container("ml", NodeId(0), Resource::new(1024, 1, 0));
            s.add_asks_gang(
                app(4),
                "ml",
                &[ContainerRequest::new(Resource::new(3072, 2, 0), 2)],
                300,
                Some(2),
            );
            for g in s.schedule() {
                out.push((g.ask.tag, g.node.0));
            }
            s.verify_invariants();
            out
        };
        assert_eq!(run(false), run(true), "indexed and linear placements diverge");
    }
}

