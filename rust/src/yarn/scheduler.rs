//! CapacityScheduler: hierarchical-capacity queue scheduling over
//! label-partitioned nodes.
//!
//! Pure logic (no threads, no clock) so it is directly unit- and
//! property-testable: `schedule()` takes the current node free-list and
//! returns grants; the RM applies them.  Invariants enforced here and
//! checked by `rust/tests/prop_scheduler.rs`:
//!
//! 1. a grant never exceeds the free capacity of its node (no dimension
//!    oversubscribes),
//! 2. label partitions are respected (an ask with label L is only placed
//!    on nodes with label L; unlabeled asks go to unlabeled nodes),
//! 3. a queue's usage never exceeds `max_capacity` × cluster total
//!    (dominant-share), and
//! 4. FIFO order within a queue per priority level.

use std::collections::VecDeque;

use crate::util::ids::{ApplicationId, NodeId};

use super::container::ContainerRequest;
use super::resources::Resource;

/// Static queue configuration (fractions of the cluster).
#[derive(Debug, Clone, PartialEq)]
pub struct QueueConf {
    pub name: String,
    /// Guaranteed share of cluster capacity, in [0, 1].
    pub capacity: f64,
    /// Hard ceiling, in [0, 1] (>= capacity).
    pub max_capacity: f64,
}

impl QueueConf {
    pub fn new(name: &str, capacity: f64, max_capacity: f64) -> QueueConf {
        QueueConf { name: name.to_string(), capacity, max_capacity }
    }

    /// A single `default` queue owning the whole cluster.
    pub fn default_only() -> Vec<QueueConf> {
        vec![QueueConf::new("default", 1.0, 1.0)]
    }
}

/// One outstanding single-container ask.
#[derive(Debug, Clone, PartialEq)]
pub struct Ask {
    pub app: ApplicationId,
    pub queue: String,
    pub resource: Resource,
    pub node_label: Option<String>,
    pub priority: u8,
    /// Opaque correlation id chosen by the asker.
    pub tag: u64,
}

/// A scheduling decision: place `ask` on `node`.
#[derive(Debug, Clone, PartialEq)]
pub struct Grant {
    pub ask: Ask,
    pub node: NodeId,
}

/// Scheduler's view of one node.
#[derive(Debug, Clone, PartialEq)]
pub struct SchedNode {
    pub id: NodeId,
    pub label: Option<String>,
    pub free: Resource,
}

#[derive(Debug)]
struct Queue {
    conf: QueueConf,
    used: Resource,
    /// FIFO of pending asks (stable order; higher priority first is
    /// achieved by scanning priorities descending).
    pending: VecDeque<Ask>,
}

#[derive(Debug)]
pub struct CapacityScheduler {
    queues: Vec<Queue>,
    cluster_total: Resource,
}

impl CapacityScheduler {
    pub fn new(queues: Vec<QueueConf>, cluster_total: Resource) -> CapacityScheduler {
        assert!(!queues.is_empty(), "need at least one queue");
        let sum: f64 = queues.iter().map(|q| q.capacity).sum();
        assert!(
            (sum - 1.0).abs() < 1e-6,
            "queue capacities must sum to 1.0, got {sum}"
        );
        CapacityScheduler {
            queues: queues
                .into_iter()
                .map(|conf| Queue { conf, used: Resource::ZERO, pending: VecDeque::new() })
                .collect(),
            cluster_total,
        }
    }

    pub fn set_cluster_total(&mut self, total: Resource) {
        self.cluster_total = total;
    }

    pub fn cluster_total(&self) -> Resource {
        self.cluster_total
    }

    pub fn queue_names(&self) -> Vec<String> {
        self.queues.iter().map(|q| q.conf.name.clone()).collect()
    }

    pub fn queue_used(&self, name: &str) -> Option<Resource> {
        self.queues.iter().find(|q| q.conf.name == name).map(|q| q.used)
    }

    pub fn pending_count(&self) -> usize {
        self.queues.iter().map(|q| q.pending.len()).sum()
    }

    /// Pending asks per queue (observability: the `/metrics` endpoints
    /// expose this as `tony_queue_pending_asks`).
    pub fn pending_per_queue(&self) -> Vec<(String, usize)> {
        self.queues
            .iter()
            .map(|q| (q.conf.name.clone(), q.pending.len()))
            .collect()
    }

    fn queue_mut(&mut self, name: &str) -> Option<&mut Queue> {
        self.queues.iter_mut().find(|q| q.conf.name == name)
    }

    /// Enqueue asks from an AM heartbeat (expanding multi-count requests).
    /// Unknown queues fall back to the first queue.
    pub fn add_asks(
        &mut self,
        app: ApplicationId,
        queue: &str,
        requests: &[ContainerRequest],
        mut tag_start: u64,
    ) -> u64 {
        let qname = if self.queue_mut(queue).is_some() {
            queue.to_string()
        } else {
            self.queues[0].conf.name.clone()
        };
        let q = self.queue_mut(&qname).unwrap();
        for req in requests {
            for _ in 0..req.count {
                q.pending.push_back(Ask {
                    app,
                    queue: qname.clone(),
                    resource: req.resource,
                    node_label: req.node_label.clone(),
                    priority: req.priority,
                    tag: tag_start,
                });
                tag_start += 1;
            }
        }
        tag_start
    }

    /// Remove all pending asks of an app (teardown / app finished).
    pub fn remove_app(&mut self, app: ApplicationId) {
        for q in &mut self.queues {
            q.pending.retain(|a| a.app != app);
        }
    }

    /// Record capacity returned by a released/completed container.
    pub fn release(&mut self, queue: &str, resource: Resource) {
        if let Some(q) = self.queue_mut(queue) {
            q.used -= resource;
        }
    }

    /// Would granting `r` keep queue under its max-capacity ceiling?
    fn queue_headroom_ok(&self, qi: usize, r: &Resource) -> bool {
        let q = &self.queues[qi];
        let after = q.used + *r;
        after.dominant_share(&self.cluster_total) <= q.conf.max_capacity + 1e-9
    }

    /// One scheduling pass: match pending asks against free node capacity.
    /// Queues are visited most-underserved-first (used/capacity ratio);
    /// within a queue, priorities descend, FIFO within a priority.
    pub fn schedule(&mut self, nodes: &mut [SchedNode]) -> Vec<Grant> {
        let mut grants = Vec::new();
        loop {
            // Order queues by relative usage each round so capacity
            // fractions steer who gets the next container.
            let mut order: Vec<usize> = (0..self.queues.len())
                .filter(|&i| !self.queues[i].pending.is_empty())
                .collect();
            if order.is_empty() {
                break;
            }
            order.sort_by(|&a, &b| {
                let ra = self.relative_usage(a);
                let rb = self.relative_usage(b);
                ra.partial_cmp(&rb).unwrap_or(std::cmp::Ordering::Equal)
            });
            let mut made_progress = false;
            for qi in order {
                if let Some(grant) = self.try_queue(qi, nodes) {
                    grants.push(grant);
                    made_progress = true;
                    break; // re-evaluate queue order after every grant
                }
            }
            if !made_progress {
                break;
            }
        }
        grants
    }

    fn relative_usage(&self, qi: usize) -> f64 {
        let q = &self.queues[qi];
        let share = q.used.dominant_share(&self.cluster_total);
        if q.conf.capacity <= 0.0 {
            f64::INFINITY
        } else {
            share / q.conf.capacity
        }
    }

    /// Try to place the first placeable ask of queue `qi` (priority-major,
    /// FIFO-minor).  Skips asks that cannot currently be placed without
    /// blocking later placeable ones (avoids convoy starvation on mixed
    /// GPU/CPU asks, which YARN handles via separate resource-requests).
    fn try_queue(&mut self, qi: usize, nodes: &mut [SchedNode]) -> Option<Grant> {
        let plen = self.queues[qi].pending.len();
        let mut best: Option<(usize, usize)> = None; // (pending idx, node idx)
        let mut best_prio = 0u8;
        for i in 0..plen {
            let ask = &self.queues[qi].pending[i];
            if let Some(existing) = best {
                let _ = existing;
                if ask.priority <= best_prio {
                    continue;
                }
            }
            if !self.queue_headroom_ok(qi, &ask.resource) {
                continue;
            }
            if let Some(ni) = pick_node(nodes, ask) {
                best_prio = ask.priority;
                best = Some((i, ni));
            }
        }
        let (i, ni) = best?;
        let ask = self.queues[qi].pending.remove(i).unwrap();
        nodes[ni].free -= ask.resource;
        self.queues[qi].used += ask.resource;
        Some(Grant { ask, node: nodes[ni].id })
    }
}

/// Best-fit node choice: among nodes matching the label with room, pick
/// the one whose remaining free dominant-share is smallest after
/// placement (packs tightly, preserving big slots for big asks).
fn pick_node(nodes: &[SchedNode], ask: &Ask) -> Option<usize> {
    let mut best: Option<(usize, u64)> = None;
    for (i, n) in nodes.iter().enumerate() {
        if n.label != ask.node_label {
            continue;
        }
        if !n.free.fits(&ask.resource) {
            continue;
        }
        let leftover = n.free.memory_mb - ask.resource.memory_mb;
        match best {
            Some((_, b)) if leftover >= b => {}
            _ => best = Some((i, leftover)),
        }
    }
    best.map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app(seq: u64) -> ApplicationId {
        ApplicationId { cluster_ts: 1, seq }
    }

    fn nodes2() -> Vec<SchedNode> {
        vec![
            SchedNode { id: NodeId(0), label: None, free: Resource::new(8192, 8, 0) },
            SchedNode { id: NodeId(1), label: Some("gpu".into()), free: Resource::new(8192, 8, 4) },
        ]
    }

    #[test]
    fn grants_respect_capacity_and_labels() {
        let mut s = CapacityScheduler::new(QueueConf::default_only(), Resource::new(16384, 16, 4));
        let mut nodes = nodes2();
        s.add_asks(
            app(1),
            "default",
            &[
                ContainerRequest::new(Resource::new(2048, 2, 1), 2).with_label("gpu"),
                ContainerRequest::new(Resource::new(2048, 2, 0), 2),
            ],
            0,
        );
        let grants = s.schedule(&mut nodes);
        assert_eq!(grants.len(), 4);
        for g in &grants {
            if g.ask.node_label.as_deref() == Some("gpu") {
                assert_eq!(g.node, NodeId(1), "gpu asks must land on the gpu node");
            } else {
                assert_eq!(g.node, NodeId(0), "unlabeled asks stay on the default partition");
            }
        }
        // No oversubscription.
        assert!(nodes[0].free.memory_mb <= 8192);
        assert_eq!(nodes[1].free.gpus, 2);
    }

    #[test]
    fn unsatisfiable_asks_stay_pending() {
        let mut s = CapacityScheduler::new(QueueConf::default_only(), Resource::new(8192, 8, 0));
        let mut nodes = vec![SchedNode {
            id: NodeId(0),
            label: None,
            free: Resource::new(4096, 4, 0),
        }];
        s.add_asks(app(1), "default", &[ContainerRequest::new(Resource::new(8192, 1, 0), 1)], 0);
        let grants = s.schedule(&mut nodes);
        assert!(grants.is_empty());
        assert_eq!(s.pending_count(), 1);
    }

    #[test]
    fn max_capacity_is_a_ceiling() {
        // Queue limited to 50% of a 8 GiB cluster: second 3 GiB ask must wait.
        let queues = vec![
            QueueConf::new("ml", 0.5, 0.5),
            QueueConf::new("etl", 0.5, 1.0),
        ];
        let mut s = CapacityScheduler::new(queues, Resource::new(8192, 8, 0));
        let mut nodes = vec![SchedNode {
            id: NodeId(0),
            label: None,
            free: Resource::new(8192, 8, 0),
        }];
        s.add_asks(app(1), "ml", &[ContainerRequest::new(Resource::new(3072, 1, 0), 2)], 0);
        let grants = s.schedule(&mut nodes);
        assert_eq!(grants.len(), 1, "only one 3GiB ask fits under the 50% cap");
        assert_eq!(s.pending_count(), 1);
        // After release, the pending ask can go.
        s.release("ml", Resource::new(3072, 1, 0));
        nodes[0].free += Resource::new(3072, 1, 0);
        assert_eq!(s.schedule(&mut nodes).len(), 1);
    }

    #[test]
    fn capacity_fractions_steer_sharing() {
        // 75/25 split: with both queues asking for everything, ml should
        // end up with ~3x etl's containers.
        let queues = vec![
            QueueConf::new("ml", 0.75, 1.0),
            QueueConf::new("etl", 0.25, 1.0),
        ];
        let mut s = CapacityScheduler::new(queues, Resource::new(8192, 64, 0));
        let mut nodes = vec![SchedNode {
            id: NodeId(0),
            label: None,
            free: Resource::new(8192, 64, 0),
        }];
        let shape = ContainerRequest::new(Resource::new(1024, 1, 0), 8);
        s.add_asks(app(1), "ml", &[shape.clone()], 0);
        s.add_asks(app(2), "etl", &[shape], 100);
        let grants = s.schedule(&mut nodes);
        assert_eq!(grants.len(), 8, "cluster fits exactly 8 containers");
        let ml = grants.iter().filter(|g| g.ask.queue == "ml").count();
        assert_eq!(ml, 6, "75% queue gets 6 of 8");
    }

    #[test]
    fn priority_order_within_queue() {
        let mut s = CapacityScheduler::new(QueueConf::default_only(), Resource::new(4096, 4, 0));
        let mut nodes = vec![SchedNode {
            id: NodeId(0),
            label: None,
            free: Resource::new(1024, 1, 0),
        }];
        // Low priority first in FIFO order, then high priority.
        s.add_asks(
            app(1),
            "default",
            &[ContainerRequest::new(Resource::new(1024, 1, 0), 1).with_priority(1)],
            0,
        );
        s.add_asks(
            app(1),
            "default",
            &[ContainerRequest::new(Resource::new(1024, 1, 0), 1).with_priority(5)],
            10,
        );
        let grants = s.schedule(&mut nodes);
        assert_eq!(grants.len(), 1);
        assert_eq!(grants[0].ask.priority, 5, "high priority wins the single slot");
    }

    #[test]
    fn remove_app_clears_pending() {
        let mut s = CapacityScheduler::new(QueueConf::default_only(), Resource::new(8192, 8, 0));
        s.add_asks(app(1), "default", &[ContainerRequest::new(Resource::new(1024, 1, 0), 3)], 0);
        s.add_asks(app(2), "default", &[ContainerRequest::new(Resource::new(1024, 1, 0), 2)], 50);
        s.remove_app(app(1));
        assert_eq!(s.pending_count(), 2);
    }

    #[test]
    fn best_fit_packs_tightly() {
        let mut s = CapacityScheduler::new(QueueConf::default_only(), Resource::new(12288, 12, 0));
        let mut nodes = vec![
            SchedNode { id: NodeId(0), label: None, free: Resource::new(8192, 8, 0) },
            SchedNode { id: NodeId(1), label: None, free: Resource::new(2048, 2, 0) },
        ];
        s.add_asks(app(1), "default", &[ContainerRequest::new(Resource::new(2048, 1, 0), 1)], 0);
        let grants = s.schedule(&mut nodes);
        // Best fit: lands on the small node, preserving the big slot.
        assert_eq!(grants[0].node, NodeId(1));
    }
}
