//! NodeManager simulation: one struct per cluster node.
//!
//! Containers launch as named threads; `stop_container` flips the
//! container's kill flag (the simulated SIGKILL — launched code is
//! expected to poll it, which our TaskExecutors do on every heartbeat),
//! and a watcher thread reports the exit status upward through the
//! completion callback, standing in for the NM→RM status stream.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::util::ids::{ContainerId, NodeId};

use super::container::{Container, ContainerCtx, ExitStatus, Launchable};
use super::resources::Resource;

/// Static description of a node.
#[derive(Debug, Clone)]
pub struct NodeSpec {
    pub id: NodeId,
    pub capacity: Resource,
    pub label: Option<String>,
}

impl NodeSpec {
    pub fn new(id: u32, capacity: Resource) -> NodeSpec {
        NodeSpec { id: NodeId(id), capacity, label: None }
    }

    pub fn with_label(mut self, label: impl Into<String>) -> NodeSpec {
        self.label = Some(label.into());
        self
    }
}

/// Callback invoked when a container's code returns (or is killed).
pub type CompletionFn = Arc<dyn Fn(NodeId, ContainerId, ExitStatus) + Send + Sync>;

struct Running {
    kill: Arc<super::container::KillSwitch>,
    resource: Resource,
}

/// Live node state: running containers + the alive bit.
pub struct NodeHandle {
    pub spec: NodeSpec,
    alive: AtomicBool,
    running: Mutex<HashMap<ContainerId, Running>>,
    on_complete: CompletionFn,
}

impl NodeHandle {
    pub fn new(spec: NodeSpec, on_complete: CompletionFn) -> NodeHandle {
        NodeHandle {
            spec,
            alive: AtomicBool::new(true),
            running: Mutex::new(HashMap::new()),
            on_complete,
        }
    }

    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::Relaxed)
    }

    pub fn used(&self) -> Resource {
        self.running
            .lock()
            .unwrap()
            .values()
            .fold(Resource::ZERO, |acc, r| acc + r.resource)
    }

    pub fn running_count(&self) -> usize {
        self.running.lock().unwrap().len()
    }

    /// Launch container code on this node.  The RM has already reserved
    /// the capacity; this enforces the node-level invariant again as a
    /// belt-and-braces check (a real NM refuses over-commit too).
    pub fn start_container(
        self: &Arc<Self>,
        container: Container,
        ctx: ContainerCtx,
        launch: Launchable,
    ) -> Result<()> {
        if !self.is_alive() {
            bail!("node {} is dead", self.spec.id);
        }
        let kill = ctx.kill_switch();
        {
            let mut running = self.running.lock().unwrap();
            let used = running
                .values()
                .fold(Resource::ZERO, |acc, r| acc + r.resource);
            if !(self.spec.capacity - used).fits(&container.resource) {
                bail!(
                    "node {} over-commit: capacity {}, used {}, asked {}",
                    self.spec.id,
                    self.spec.capacity,
                    used,
                    container.resource
                );
            }
            running.insert(container.id, Running { kill: kill.clone(), resource: container.resource });
        }
        let node = self.clone();
        let cid = container.id;
        std::thread::Builder::new()
            .name(format!("container-{cid}"))
            .spawn(move || {
                // A panic in task code is a crash of the "process", not of
                // the NM: report exit 137 instead of leaking the container.
                let code = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    launch(ctx)
                }))
                .unwrap_or(137);
                let was_killed = kill.killed();
                let node_dead = !node.is_alive();
                node.running.lock().unwrap().remove(&cid);
                let status = if node_dead {
                    ExitStatus::NodeLost
                } else if was_killed {
                    ExitStatus::Killed
                } else if code == 0 {
                    ExitStatus::Success
                } else {
                    ExitStatus::Failed(code)
                };
                (node.on_complete)(node.spec.id, cid, status);
            })
            .expect("spawn container thread");
        Ok(())
    }

    /// Ask the container to die (kill flag; container code polls it).
    pub fn stop_container(&self, id: ContainerId) -> bool {
        let switch = self.running.lock().unwrap().get(&id).map(|r| r.kill.clone());
        match switch {
            Some(k) => {
                // Flip (and notify waiters) outside the running-map lock:
                // a woken monitor loop may call back into this node.
                k.kill();
                true
            }
            None => false,
        }
    }

    /// Chaos: node dies.  All containers get their kill flag set and will
    /// be reported as `NodeLost`.
    pub fn kill_node(&self) {
        self.alive.store(false, Ordering::Relaxed);
        let switches: Vec<_> =
            self.running.lock().unwrap().values().map(|r| r.kill.clone()).collect();
        for k in switches {
            k.kill();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::SystemClock;
    use crate::util::event::WakeupBus;
    use crate::util::ids::ApplicationId;
    use std::collections::BTreeMap;
    use std::sync::mpsc;
    use std::time::Duration;

    fn mk(cap: Resource) -> (Arc<NodeHandle>, mpsc::Receiver<(ContainerId, ExitStatus)>) {
        let (tx, rx) = mpsc::channel();
        let cb: CompletionFn = Arc::new(move |_n, c, s| {
            let _ = tx.send((c, s));
        });
        (Arc::new(NodeHandle::new(NodeSpec::new(0, cap), cb)), rx)
    }

    /// Event-driven stand-in for "task code that runs until killed":
    /// blocks on the kill switch instead of sleep-polling it.
    fn block_until_killed(ctx: &ContainerCtx) {
        let clock = SystemClock::new();
        let bus = Arc::new(WakeupBus::new());
        ctx.kill_switch().register(&bus);
        while !ctx.killed() {
            bus.wait_until(&clock, clock.now_ms() + 10_000);
        }
    }

    fn container(seq: u64, r: Resource) -> Container {
        let app = ApplicationId { cluster_ts: 9, seq: 1 };
        Container { id: ContainerId { app, seq }, app, node: NodeId(0), resource: r, priority: 1 }
    }

    #[test]
    fn run_to_success() {
        let (node, rx) = mk(Resource::new(1024, 2, 0));
        let c = container(1, Resource::new(512, 1, 0));
        let ctx = ContainerCtx::new(c.clone(), BTreeMap::new());
        node.start_container(c, ctx, Box::new(|_| 0)).unwrap();
        let (cid, status) = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(cid.seq, 1);
        assert_eq!(status, ExitStatus::Success);
        assert_eq!(node.running_count(), 0);
    }

    #[test]
    fn nonzero_exit_is_failure() {
        let (node, rx) = mk(Resource::new(1024, 2, 0));
        let c = container(2, Resource::new(512, 1, 0));
        let ctx = ContainerCtx::new(c.clone(), BTreeMap::new());
        node.start_container(c, ctx, Box::new(|_| 3)).unwrap();
        let (_, status) = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(status, ExitStatus::Failed(3));
    }

    #[test]
    fn stop_container_reports_killed() {
        let (node, rx) = mk(Resource::new(1024, 2, 0));
        let c = container(3, Resource::new(512, 1, 0));
        let ctx = ContainerCtx::new(c.clone(), BTreeMap::new());
        let (started_tx, started_rx) = mpsc::channel();
        node.start_container(
            c.clone(),
            ctx,
            Box::new(move |ctx| {
                let _ = started_tx.send(());
                block_until_killed(&ctx);
                1 // exit code irrelevant once killed
            }),
        )
        .unwrap();
        started_rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(node.stop_container(c.id));
        let (_, status) = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(status, ExitStatus::Killed);
    }

    #[test]
    fn node_kill_reports_node_lost() {
        let (node, rx) = mk(Resource::new(1024, 2, 0));
        let c = container(4, Resource::new(512, 1, 0));
        let ctx = ContainerCtx::new(c.clone(), BTreeMap::new());
        let (started_tx, started_rx) = mpsc::channel();
        node.start_container(
            c,
            ctx,
            Box::new(move |ctx| {
                let _ = started_tx.send(());
                block_until_killed(&ctx);
                0
            }),
        )
        .unwrap();
        started_rx.recv_timeout(Duration::from_secs(2)).unwrap();
        node.kill_node();
        let (_, status) = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(status, ExitStatus::NodeLost);
        // Dead node refuses new containers.
        let c2 = container(5, Resource::new(128, 1, 0));
        let ctx2 = ContainerCtx::new(c2.clone(), BTreeMap::new());
        assert!(node.start_container(c2, ctx2, Box::new(|_| 0)).is_err());
    }

    #[test]
    fn over_commit_refused() {
        let (node, _rx) = mk(Resource::new(1024, 2, 0));
        let c = container(6, Resource::new(2048, 1, 0));
        let ctx = ContainerCtx::new(c.clone(), BTreeMap::new());
        assert!(node.start_container(c, ctx, Box::new(|_| 0)).is_err());
    }
}
