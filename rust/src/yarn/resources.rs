//! Multi-dimensional container resources: memory, vcores, GPUs.
//!
//! The GPU dimension is what makes TonY's heterogeneous requests
//! meaningful: worker containers ask for GPUs, parameter-server containers
//! don't (paper §2.2), and the scheduler must track both without letting
//! either dimension oversubscribe.
//!
//! The scheduler scalarizes multi-dimensional usage with
//! [`Resource::dominant_share`] (DRF-style: a queue's share is its most
//! constrained dimension), which is what queue `capacity` /
//! `max_capacity` fractions and preemption guarantees are measured
//! against — see `docs/SCHEDULING.md`.
//!
//! # Example
//!
//! ```
//! use tony::yarn::Resource;
//!
//! let node = Resource::new(8192, 8, 2);
//! let ask = Resource::new(2048, 2, 1);
//! assert!(node.fits(&ask));
//! // DRF dominant share: GPUs are the scarcest dimension here.
//! assert_eq!(ask.dominant_share(&node), 0.5);
//! assert_eq!(node.checked_sub(&ask), Some(Resource::new(6144, 6, 1)));
//! ```

use std::fmt;
use std::ops::{Add, AddAssign, Sub, SubAssign};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct Resource {
    pub memory_mb: u64,
    pub vcores: u32,
    pub gpus: u32,
}

impl Resource {
    pub const ZERO: Resource = Resource { memory_mb: 0, vcores: 0, gpus: 0 };

    #[inline]
    pub fn new(memory_mb: u64, vcores: u32, gpus: u32) -> Resource {
        Resource { memory_mb, vcores, gpus }
    }

    #[inline]
    pub fn mem_cores(memory_mb: u64, vcores: u32) -> Resource {
        Resource { memory_mb, vcores, gpus: 0 }
    }

    /// True iff every dimension of `other` fits inside `self`.
    ///
    /// ```
    /// use tony::yarn::Resource;
    /// let node = Resource::new(4096, 4, 0);
    /// assert!(node.fits(&Resource::new(4096, 4, 0)));
    /// assert!(!node.fits(&Resource::new(1024, 1, 1)), "every dimension counts");
    /// ```
    #[inline]
    pub fn fits(&self, other: &Resource) -> bool {
        other.memory_mb <= self.memory_mb
            && other.vcores <= self.vcores
            && other.gpus <= self.gpus
    }

    #[inline]
    pub fn is_zero(&self) -> bool {
        *self == Resource::ZERO
    }

    /// Dominant share of `self` within `total` (DRF-style scalarization;
    /// used for queue utilization accounting, the `capacity` /
    /// `max_capacity` queue fractions, and preemption guarantees).
    ///
    /// ```
    /// use tony::yarn::Resource;
    /// let total = Resource::new(10000, 10, 2);
    /// // 10% of memory, 50% of vcores, 50% of gpus -> 0.5 dominates.
    /// assert_eq!(Resource::new(1000, 5, 1).dominant_share(&total), 0.5);
    /// ```
    #[inline]
    pub fn dominant_share(&self, total: &Resource) -> f64 {
        let mut share: f64 = 0.0;
        if total.memory_mb > 0 {
            share = share.max(self.memory_mb as f64 / total.memory_mb as f64);
        }
        if total.vcores > 0 {
            share = share.max(self.vcores as f64 / total.vcores as f64);
        }
        if total.gpus > 0 {
            share = share.max(self.gpus as f64 / total.gpus as f64);
        }
        share
    }

    #[inline]
    pub fn checked_sub(&self, other: &Resource) -> Option<Resource> {
        if !self.fits(other) {
            return None;
        }
        Some(Resource {
            memory_mb: self.memory_mb - other.memory_mb,
            vcores: self.vcores - other.vcores,
            gpus: self.gpus - other.gpus,
        })
    }
}

impl Add for Resource {
    type Output = Resource;

    #[inline]
    fn add(self, o: Resource) -> Resource {
        Resource {
            memory_mb: self.memory_mb + o.memory_mb,
            vcores: self.vcores + o.vcores,
            gpus: self.gpus + o.gpus,
        }
    }
}

impl AddAssign for Resource {
    #[inline]
    fn add_assign(&mut self, o: Resource) {
        *self = *self + o;
    }
}

impl Sub for Resource {
    type Output = Resource;

    #[inline]
    fn sub(self, o: Resource) -> Resource {
        Resource {
            memory_mb: self.memory_mb.saturating_sub(o.memory_mb),
            vcores: self.vcores.saturating_sub(o.vcores),
            gpus: self.gpus.saturating_sub(o.gpus),
        }
    }
}

impl SubAssign for Resource {
    #[inline]
    fn sub_assign(&mut self, o: Resource) {
        *self = *self - o;
    }
}

impl fmt::Display for Resource {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "<mem {}MB, {} vcores, {} gpus>", self.memory_mb, self.vcores, self.gpus)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits_checks_all_dimensions() {
        let node = Resource::new(8192, 8, 2);
        assert!(node.fits(&Resource::new(4096, 4, 1)));
        assert!(node.fits(&node));
        assert!(!node.fits(&Resource::new(9000, 1, 0)));
        assert!(!node.fits(&Resource::new(1024, 9, 0)));
        assert!(!node.fits(&Resource::new(1024, 1, 3)));
    }

    #[test]
    fn arithmetic() {
        let a = Resource::new(4096, 4, 1);
        let b = Resource::new(1024, 1, 1);
        assert_eq!(a + b, Resource::new(5120, 5, 2));
        assert_eq!(a - b, Resource::new(3072, 3, 0));
        assert_eq!(a.checked_sub(&b), Some(Resource::new(3072, 3, 0)));
        assert_eq!(b.checked_sub(&a), None);
        let mut c = a;
        c += b;
        c -= b;
        assert_eq!(c, a);
    }

    #[test]
    fn dominant_share() {
        let total = Resource::new(10000, 10, 2);
        let used = Resource::new(1000, 5, 1);
        // max(0.1, 0.5, 0.5) = 0.5
        assert!((used.dominant_share(&total) - 0.5).abs() < 1e-9);
        assert_eq!(Resource::ZERO.dominant_share(&total), 0.0);
    }
}
