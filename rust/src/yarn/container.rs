//! Containers: the unit of allocation and execution.
//!
//! A [`ContainerRequest`] is what an AM asks the RM for (priority,
//! resources, optional node label — "high-memory", "gpu").  A granted
//! [`Container`] names the node it landed on.  Launched container code
//! receives a [`ContainerCtx`]: the simulated process environment (env
//! map à la YARN's launch context + a kill flag standing in for SIGKILL).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::util::event::{tag, WakerSet, WakeupBus};
use crate::util::ids::{ApplicationId, ContainerId, NodeId};

use super::resources::Resource;

/// The simulated SIGKILL: a flag the NM flips on `stop_container` / node
/// death, plus the wakeup hook that makes a kill an *event* rather than
/// something launched code discovers on its next poll — the container's
/// monitor loop registers its [`WakeupBus`] here and is woken the moment
/// the flag flips.
pub struct KillSwitch {
    flag: AtomicBool,
    wakers: WakerSet,
}

impl Default for KillSwitch {
    fn default() -> Self {
        Self::new()
    }
}

impl KillSwitch {
    pub fn new() -> KillSwitch {
        KillSwitch { flag: AtomicBool::new(false), wakers: WakerSet::new() }
    }

    pub fn killed(&self) -> bool {
        self.flag.load(Ordering::Relaxed)
    }

    /// Flip the switch and wake every registered waiter (`tag::KILL`).
    pub fn kill(&self) {
        self.flag.store(true, Ordering::Relaxed);
        self.wakers.notify_all(tag::KILL);
    }

    /// Register a bus to be notified when the switch flips.  If it
    /// already flipped, notify immediately (no lost-kill window).
    pub fn register(&self, bus: &Arc<WakeupBus>) {
        self.wakers.register(bus);
        if self.killed() {
            bus.notify(tag::KILL);
        }
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ContainerRequest {
    pub priority: u8,
    pub resource: Resource,
    /// Node-label expression; `None` targets the default (unlabeled)
    /// partition, exactly like YARN's default node-label behaviour.
    pub node_label: Option<String>,
    /// How many containers of this shape.
    pub count: u32,
}

impl ContainerRequest {
    pub fn new(resource: Resource, count: u32) -> ContainerRequest {
        ContainerRequest { priority: 1, resource, node_label: None, count }
    }

    pub fn with_label(mut self, label: impl Into<String>) -> ContainerRequest {
        self.node_label = Some(label.into());
        self
    }

    pub fn with_priority(mut self, p: u8) -> ContainerRequest {
        self.priority = p;
        self
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Container {
    pub id: ContainerId,
    pub app: ApplicationId,
    pub node: NodeId,
    pub resource: Resource,
    pub priority: u8,
}

/// Terminal state of a container, mirroring YARN's ContainerExitStatus.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExitStatus {
    Success,
    /// Non-zero exit from the task process.
    Failed(i32),
    /// Killed by the framework (AM teardown, RM kill).
    Killed,
    /// Lost because its node died.
    NodeLost,
    /// Killed by the RM to restore another queue to its guaranteed
    /// capacity (gang preemption).  The owning AM treats this like node
    /// loss: surgical recovery re-requests just the preempted tasks.
    Preempted,
    /// Cooperatively handed back by its AM during an elastic shrink wave
    /// (docs/SCHEDULING.md "Elasticity").  Never a task fault: the AM
    /// already removed the task from its expected set, so the exit burns
    /// no restart budget and survivors just resync via Reconfigure.
    Released,
}

impl ExitStatus {
    pub fn is_success(&self) -> bool {
        matches!(self, ExitStatus::Success)
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ContainerStatus {
    pub id: ContainerId,
    pub exit: ExitStatus,
    pub diagnostics: String,
}

/// The simulated process environment a launched container runs with.
#[derive(Clone)]
pub struct ContainerCtx {
    pub container: Container,
    /// Launch-context environment variables (the AM sets the cluster spec
    /// and task-specific config here — paper §2.2).
    pub env: BTreeMap<String, String>,
    kill: Arc<KillSwitch>,
}

impl ContainerCtx {
    pub fn new(container: Container, env: BTreeMap<String, String>) -> ContainerCtx {
        ContainerCtx { container, env, kill: Arc::new(KillSwitch::new()) }
    }

    /// The kill switch the NM flips on stop_container / node death.
    pub fn kill_switch(&self) -> Arc<KillSwitch> {
        self.kill.clone()
    }

    pub fn killed(&self) -> bool {
        self.kill.killed()
    }

    pub fn env(&self, key: &str) -> Option<&str> {
        self.env.get(key).map(|s| s.as_str())
    }
}

/// Code the AM hands to an NM to run inside a container (stands in for
/// the container launch command).  Returns the process exit code.
pub type Launchable = Box<dyn FnOnce(ContainerCtx) -> i32 + Send + 'static>;

#[cfg(test)]
mod tests {
    use super::*;

    fn cid() -> Container {
        let app = ApplicationId { cluster_ts: 1, seq: 1 };
        Container {
            id: ContainerId { app, seq: 1 },
            app,
            node: NodeId(0),
            resource: Resource::new(1024, 1, 0),
            priority: 1,
        }
    }

    #[test]
    fn request_builders() {
        let r = ContainerRequest::new(Resource::new(2048, 2, 1), 4)
            .with_label("gpu")
            .with_priority(3);
        assert_eq!(r.count, 4);
        assert_eq!(r.node_label.as_deref(), Some("gpu"));
        assert_eq!(r.priority, 3);
    }

    #[test]
    fn ctx_kill_switch_wakes_registered_buses() {
        let ctx = ContainerCtx::new(cid(), BTreeMap::new());
        assert!(!ctx.killed());
        let bus = Arc::new(WakeupBus::new());
        ctx.kill_switch().register(&bus);
        ctx.kill_switch().kill();
        assert!(ctx.killed());
        assert_eq!(bus.take(), tag::KILL, "kill is an event, not a poll");
        // Registering after the flip still delivers the kill.
        let late = Arc::new(WakeupBus::new());
        ctx.kill_switch().register(&late);
        assert_eq!(late.take(), tag::KILL);
    }

    #[test]
    fn exit_status() {
        assert!(ExitStatus::Success.is_success());
        assert!(!ExitStatus::Failed(1).is_success());
        assert!(!ExitStatus::NodeLost.is_success());
    }
}
