//! The ResourceManager: application lifecycle + the AM allocate protocol.
//!
//! Protocol structure mirrors YARN:
//!
//! ```text
//!   client ── submit_application ──▶ RM ── schedules AM container ──▶ NM
//!   AM ── register_application_master ──▶ RM
//!   AM ── allocate(asks, releases) ◀──▶ RM   (heartbeat-style; returns
//!                                             newly granted + completed)
//!   AM ── start_container(grant, env, code) ──▶ NM
//!   AM ── finish_application ──▶ RM
//! ```
//!
//! Failure propagation: a dead node's containers surface in the owning
//! AM's next `allocate` response as `NodeLost`, which is what lets the
//! TonY AM implement the paper's fault-tolerance loop (§2.2: "if any task
//! fails, the TonY AM will automatically tear down the remaining tasks,
//! request new task containers ... and relaunch the tasks").

use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::{Arc, Mutex, Weak};
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::json::Json;
use crate::trace::SpanStore;
use crate::util::clock::{Clock, SystemClock};
use crate::util::event::{tag, WakeupBus};
use crate::util::ids::{ApplicationId, ContainerId, NodeId};
use crate::{tdebug, tinfo, twarn};

use super::container::{Container, ContainerCtx, ContainerRequest, ContainerStatus, ExitStatus, Launchable};
use super::node::{NodeHandle, NodeSpec};
use super::resources::Resource;
use super::scheduler::{
    CapacityScheduler, QueueConf, SchedNode, SchedStats, SchedulerConf, VictimCandidate,
};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AppState {
    Submitted,
    Running,
    Finished,
    Failed,
    Killed,
}

impl AppState {
    pub fn is_terminal(&self) -> bool {
        matches!(self, AppState::Finished | AppState::Failed | AppState::Killed)
    }
}

#[derive(Debug, Clone)]
pub struct AppReport {
    pub id: ApplicationId,
    pub name: String,
    pub queue: String,
    pub state: AppState,
    pub diagnostics: String,
    pub tracking_url: Option<String>,
}

/// Per-queue observability snapshot served by [`ResourceManager::queue_stats`].
#[derive(Debug, Clone, PartialEq)]
pub struct QueueStat {
    pub name: Arc<str>,
    /// Resources currently granted against this queue.
    pub used: Resource,
    /// Container asks still waiting in this queue.
    pub pending: usize,
    /// Dominant-share utilization in [0, 1] (used / cluster total).
    pub utilization: f64,
    /// Guaranteed share of the cluster in [0, 1] (preemption restores a
    /// starved queue up to this).
    pub guaranteed: f64,
    /// Distinct gangs still waiting in this queue.
    pub pending_gangs: usize,
    /// Node reservations currently held by this queue's blocked gangs.
    pub reservations: usize,
    /// Victim containers preempted *from* this queue since startup.
    pub preemptions: u64,
    /// Elastic jobs currently registered in this queue.
    pub elastic_jobs: usize,
    /// Sum of those jobs' acknowledged worker counts.
    pub elastic_workers: u64,
    /// Workers granted to this queue's elastic jobs by grow commands.
    pub elastic_grows: u64,
    /// Workers cooperatively released from this queue by shrink waves.
    pub elastic_shrinks: u64,
}

/// Where an application stands with the gang scheduler — surfaced by the
/// gateway as per-job state (`WAITING_FOR_GANG`, `PREEMPTING`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AppSchedState {
    /// No gang waiting, nothing being preempted.
    Normal,
    /// The app has a gang pending (possibly holding a reservation).
    WaitingForGang,
    /// At least one of the app's containers has a preemption notice.
    Preempting,
}

impl AppSchedState {
    pub fn as_str(&self) -> &'static str {
        match self {
            AppSchedState::Normal => "NORMAL",
            AppSchedState::WaitingForGang => "WAITING_FOR_GANG",
            AppSchedState::Preempting => "PREEMPTING",
        }
    }
}

#[derive(Debug, Clone)]
pub struct SubmissionContext {
    pub name: String,
    pub queue: String,
    pub am_resource: Resource,
}

#[derive(Debug, Default)]
pub struct AllocateResponse {
    pub allocated: Vec<Container>,
    pub completed: Vec<ContainerStatus>,
    /// Containers of this app under a preemption notice: they will exit
    /// `Preempted` once the grace period elapses (mirrors YARN's
    /// preemption message in the allocate response).
    pub preempt_notices: Vec<ContainerId>,
    /// Elastic resize command: the worker count this app should converge
    /// to (the AM answers with a grow delta wave or a cooperative
    /// release of its highest-index workers; see docs/SCHEDULING.md
    /// "Elasticity").  At most one resize per app is in flight at a time.
    pub resize_target: Option<u32>,
}

struct LiveContainer {
    node: NodeId,
    resource: Resource,
    app: ApplicationId,
    queue: Arc<str>,
    started: bool,
    /// Gang this container was granted as part of (victim selection
    /// takes whole gangs last).
    gang: Option<u64>,
    /// Monotonic grant sequence (victim selection is newest-first).
    seq: u64,
}

/// A container the RM decided to preempt: notice issued, kill pending
/// until the grace deadline.  Once the kill is sent, `deadline_ms` is
/// re-armed as the zombie give-up deadline.
struct PreemptState {
    deadline_ms: u64,
    kill_sent: bool,
}

/// How long after its kill a victim may take to actually exit before
/// the RM abandons the preemption notice.  A wedged container ignoring
/// the (cooperative) kill must not pin preemption planning — or the
/// demanding gang's reservation — forever.
const PREEMPT_ZOMBIE_GIVEUP_MS: u64 = 30_000;

struct App {
    name: String,
    queue: String,
    state: AppState,
    diagnostics: String,
    tracking_url: Option<String>,
    am_container: Option<ContainerId>,
    allocated_ready: Vec<Container>,
    completed_ready: Vec<ContainerStatus>,
    /// Preemption notices awaiting the app's next allocate call.
    preempt_ready: Vec<ContainerId>,
    /// Resize target awaiting the app's next allocate call.
    resize_ready: Option<u32>,
}

struct Inner {
    nodes: Vec<Arc<NodeHandle>>,
    /// The scheduler owns the free/capacity view of every node (capacity
    /// minus granted, including grants the AM hasn't started yet —
    /// reservations are held from grant time) behind its per-label
    /// indexes; the RM mutates it only through the scheduler's node API.
    scheduler: CapacityScheduler,
    apps: HashMap<ApplicationId, App>,
    containers: HashMap<ContainerId, LiveContainer>,
    /// AM launchables awaiting their container grant, keyed by ask tag.
    pending_am: HashMap<u64, (ApplicationId, Launchable)>,
    /// Per-application wakeup buses (registered by each AM): notified on
    /// grants / completed containers so the AM monitor loop blocks on
    /// events instead of polling `allocate` on a fixed interval.
    am_wakers: HashMap<ApplicationId, Arc<WakeupBus>>,
    /// Per-application span stores (registered at submit): every
    /// scheduler verdict touching the app is routed here as an audit
    /// span, which is what makes `WAITING_FOR_GANG` explainable.
    traces: HashMap<ApplicationId, Arc<SpanStore>>,
    /// Containers under a preemption notice, keyed by the grace deadline
    /// they will be killed at.
    preempting: HashMap<ContainerId, PreemptState>,
    /// Containers an AM is cooperatively handing back mid-shrink: their
    /// NM `Killed` exits are rewritten to `Released` (mirroring the
    /// `preempting` -> `Preempted` rewrite) so they never read as faults.
    released: HashSet<ContainerId>,
    /// Resize commands in flight, app -> target worker count.  Cleared by
    /// [`ResourceManager::note_resized`] when the AM's wave completes;
    /// while non-empty the elasticity pass stands down, and while a
    /// *shrink* is in flight preemption planning stands down too (the
    /// freed capacity is already on its way).
    resizing: HashMap<ApplicationId, u32>,
    /// Per-app quiet-period end (clock ms): no new grow before this.
    elastic_cooldown_until: HashMap<ApplicationId, u64>,
    next_app_seq: u64,
    next_container_seq: u64,
    next_tag: u64,
    next_gang: u64,
    grant_seq: u64,
}

/// Construction knobs for [`ResourceManager::start_with`].
pub struct RmConf {
    /// The clock every RM deadline runs on (manual clocks make liveness
    /// paths fully test-drivable).
    pub clock: Arc<dyn Clock>,
    /// Slow safety tick: the RM re-runs its scheduler and re-notifies AM
    /// wakers this often even with no events, so a (hypothetical) missed
    /// notification degrades to one tick of latency instead of a hang.
    /// `0` disables the tick — scheduling is then purely event-driven,
    /// which the manual-clock tests use to prove no poll is needed.
    pub fallback_tick_ms: u64,
    /// Gang/reservation/preemption policy (the `tony.scheduler.*` keys;
    /// see [`SchedulerConf::from_conf`] and `docs/SCHEDULING.md`).
    pub scheduler: SchedulerConf,
}

impl Default for RmConf {
    fn default() -> RmConf {
        RmConf {
            clock: SystemClock::shared(),
            fallback_tick_ms: 1_000,
            scheduler: SchedulerConf::default(),
        }
    }
}

/// The simulated cluster: RM + NMs.  Create with [`ResourceManager::start`].
pub struct ResourceManager {
    pub cluster_ts: u64,
    clock: Arc<dyn Clock>,
    /// Self-reference for detached helper threads (preemption grace
    /// waiters) that must not keep the RM alive.
    self_weak: Weak<ResourceManager>,
    /// Gang/preemption policy this RM runs with (immutable for its life).
    sched: SchedulerConf,
    /// Notified (`tag::STATE`) on every application state change;
    /// `wait_for_completion` waiters block on its sequence.
    events: Arc<WakeupBus>,
    /// The fallback-tick thread's bus (None when the tick is disabled):
    /// `Drop` notifies it `tag::SHUTDOWN` so the ticker exits promptly
    /// with the RM instead of waiting out its final nap.
    tick_bus: Option<Arc<WakeupBus>>,
    inner: Mutex<Inner>,
}

impl Drop for ResourceManager {
    fn drop(&mut self) {
        if let Some(bus) = &self.tick_bus {
            bus.notify(tag::SHUTDOWN);
        }
    }
}

impl ResourceManager {
    pub fn start(specs: Vec<NodeSpec>, queues: Vec<QueueConf>) -> Arc<ResourceManager> {
        Self::start_with(specs, queues, RmConf::default())
    }

    pub fn start_with(
        specs: Vec<NodeSpec>,
        queues: Vec<QueueConf>,
        conf: RmConf,
    ) -> Arc<ResourceManager> {
        // Log timestamps follow the control plane's clock (the logger
        // holds only a weak ref, so a test's ManualClock is not kept
        // alive past its scenario).
        crate::util::logging::set_clock(&conf.clock);
        let cluster_ts = 1_700_000_000 + crate::util::ids::next_seq();
        let events = WakeupBus::for_clock(&conf.clock);
        let tick_bus = if conf.fallback_tick_ms > 0 {
            Some(WakeupBus::for_clock(&conf.clock))
        } else {
            None
        };
        let rm = Arc::new_cyclic(|weak: &Weak<ResourceManager>| {
            let self_weak = weak.clone();
            let weak = weak.clone();
            let cb: super::node::CompletionFn = Arc::new(move |node, cid, status| {
                if let Some(rm) = weak.upgrade() {
                    rm.on_container_complete(node, cid, status);
                }
            });
            let total = specs
                .iter()
                .fold(Resource::ZERO, |acc, s| acc + s.capacity);
            let sched_nodes: Vec<SchedNode> = specs
                .iter()
                .map(|s| SchedNode {
                    id: s.id,
                    label: s.label.clone(),
                    free: s.capacity,
                    capacity: s.capacity,
                })
                .collect();
            let nodes = specs
                .into_iter()
                .map(|s| Arc::new(NodeHandle::new(s, cb.clone())))
                .collect();
            let mut scheduler = CapacityScheduler::new(queues, total);
            scheduler.set_reservation_limit(conf.scheduler.reservation_limit);
            scheduler.set_linear_reference(!conf.scheduler.placement_index);
            scheduler.set_nodes(sched_nodes);
            ResourceManager {
                cluster_ts,
                clock: conf.clock.clone(),
                self_weak,
                sched: conf.scheduler.clone(),
                events,
                tick_bus: tick_bus.clone(),
                inner: Mutex::new(Inner {
                    nodes,
                    scheduler,
                    apps: HashMap::new(),
                    containers: HashMap::new(),
                    pending_am: HashMap::new(),
                    am_wakers: HashMap::new(),
                    traces: HashMap::new(),
                    preempting: HashMap::new(),
                    released: HashSet::new(),
                    resizing: HashMap::new(),
                    elastic_cooldown_until: HashMap::new(),
                    next_app_seq: 1,
                    next_container_seq: 1,
                    next_tag: 1,
                    next_gang: 1,
                    grant_seq: 1,
                }),
            }
        });
        if let Some(bus) = tick_bus {
            Self::spawn_fallback_tick(&rm, conf.fallback_tick_ms, bus);
        }
        rm
    }

    /// Convenience: N identical unlabeled nodes, single `default` queue.
    pub fn start_uniform(n_nodes: u32, per_node: Resource) -> Arc<ResourceManager> {
        let specs = (0..n_nodes).map(|i| NodeSpec::new(i, per_node)).collect();
        Self::start(specs, QueueConf::default_only())
    }

    /// The clock this RM (and everything constructed from it — AMs,
    /// gateway, executors) runs deadlines on.
    pub fn clock(&self) -> &Arc<dyn Clock> {
        &self.clock
    }

    /// The app-state event bus (`tag::STATE` on every transition).
    /// Exposed for watchers that want to block on state changes the way
    /// [`ResourceManager::wait_for_completion`] does.
    pub fn events(&self) -> &Arc<WakeupBus> {
        &self.events
    }

    /// Register the wakeup bus of the AM serving `app`: the RM notifies
    /// it on container grants (`tag::GRANT`), completed containers
    /// (`tag::COMPLETED`), app-state changes (`tag::STATE`), and on
    /// every fallback tick (`tag::TICK`).
    pub fn register_am_waker(&self, app: ApplicationId, bus: &Arc<WakeupBus>) {
        self.inner.lock().unwrap().am_wakers.insert(app, bus.clone());
    }

    /// Register the span store tracing `app`'s lifecycle: from now until
    /// teardown, every scheduler verdict about the app (gang waiting /
    /// reserved / demoted / placed / preemption round) lands in it as an
    /// audit span.  Disabled stores swallow the calls, so callers can
    /// register unconditionally.
    pub fn register_trace(&self, app: ApplicationId, store: &Arc<SpanStore>) {
        self.inner.lock().unwrap().traces.insert(app, store.clone());
    }

    /// The liveness backstop: a detached thread (holding only a `Weak`,
    /// so it dies with the RM) that periodically re-runs the scheduler
    /// and re-notifies every AM waker.  Correctness never depends on it;
    /// it turns a missed event into bounded latency.
    fn spawn_fallback_tick(rm: &Arc<ResourceManager>, tick_ms: u64, bus: Arc<WakeupBus>) {
        let weak = Arc::downgrade(rm);
        let clock = rm.clock.clone();
        std::thread::Builder::new()
            .name("rm-tick".into())
            .spawn(move || loop {
                // Nap to the tick deadline; intermediate wakes (manual-
                // clock advances land `tag::TICK` here too) re-check it,
                // so tick_ms is honored under manual time instead of
                // firing on every advance.  `Drop` on the RM notifies
                // SHUTDOWN for a prompt exit.
                let next = clock.now_ms().saturating_add(tick_ms);
                loop {
                    let fired = bus.wait_until(&*clock, next);
                    if fired & tag::SHUTDOWN != 0 {
                        return;
                    }
                    if clock.now_ms() >= next {
                        break;
                    }
                }
                let Some(rm) = weak.upgrade() else { return };
                let mut inner = rm.inner.lock().unwrap();
                rm.schedule_locked(&mut inner);
                for waker in inner.am_wakers.values() {
                    waker.notify(tag::TICK);
                }
            })
            .expect("spawn rm tick thread");
    }

    // ---------------- client protocol ----------------

    /// Submit an application: the RM will schedule the AM container and run
    /// `am_code` in it.  Mirrors `YarnClient.submitApplication`.
    pub fn submit_application(
        self: &Arc<Self>,
        ctx: SubmissionContext,
        am_code: Launchable,
    ) -> Result<ApplicationId> {
        let mut inner = self.inner.lock().unwrap();
        let id = ApplicationId { cluster_ts: self.cluster_ts, seq: inner.next_app_seq };
        inner.next_app_seq += 1;
        inner.apps.insert(
            id,
            App {
                name: ctx.name.clone(),
                queue: ctx.queue.clone(),
                state: AppState::Submitted,
                diagnostics: String::new(),
                tracking_url: None,
                am_container: None,
                allocated_ready: Vec::new(),
                completed_ready: Vec::new(),
                preempt_ready: Vec::new(),
                resize_ready: None,
            },
        );
        let tag = inner.next_tag;
        let am_ask = ContainerRequest::new(ctx.am_resource, 1).with_priority(10);
        inner.next_tag = inner.scheduler.add_asks(id, &ctx.queue, &[am_ask], tag);
        inner.pending_am.insert(tag, (id, am_code));
        tinfo!("rm", "submitted {id} '{}' to queue '{}'", ctx.name, ctx.queue);
        self.schedule_locked(&mut inner);
        Ok(id)
    }

    pub fn app_report(&self, id: ApplicationId) -> Option<AppReport> {
        let inner = self.inner.lock().unwrap();
        inner.apps.get(&id).map(|a| AppReport {
            id,
            name: a.name.clone(),
            queue: a.queue.clone(),
            state: a.state,
            diagnostics: a.diagnostics.clone(),
            tracking_url: a.tracking_url.clone(),
        })
    }

    /// Block until the app reaches a terminal state.  Event-driven: the
    /// waiter sleeps on the RM's state bus and wakes the moment the app
    /// terminalizes, instead of discovering it on a 10 ms poll.
    pub fn wait_for_completion(&self, id: ApplicationId, timeout: Duration) -> Result<AppReport> {
        let deadline = self.clock.deadline_after(timeout);
        loop {
            // Capture the sequence *before* checking state: a transition
            // landing between check and wait bumps the sequence and the
            // wait returns immediately (no lost wakeup).
            let seen = self.events.seq();
            let report = self
                .app_report(id)
                .ok_or_else(|| anyhow!("unknown application {id}"))?;
            if report.state.is_terminal() {
                return Ok(report);
            }
            if self.clock.now_ms() >= deadline {
                bail!("timeout waiting for {id}; state={:?}", report.state);
            }
            self.events.wait_seq(&*self.clock, seen, deadline);
        }
    }

    /// Client-initiated kill (`yarn application -kill`).
    pub fn kill_application(&self, id: ApplicationId) {
        let mut inner = self.inner.lock().unwrap();
        self.teardown_app_locked(&mut inner, id, AppState::Killed, "killed by client");
    }

    // ---------------- AM protocol ----------------

    /// `registerApplicationMaster`.  Transitions Submitted → Running.
    pub fn register_am(&self, id: ApplicationId, tracking_url: Option<String>) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        let app = inner.apps.get_mut(&id).ok_or_else(|| anyhow!("unknown app {id}"))?;
        app.state = AppState::Running;
        if tracking_url.is_some() {
            app.tracking_url = tracking_url;
        }
        tdebug!("rm", "AM registered for {id}");
        drop(inner);
        self.events.notify(tag::STATE);
        Ok(())
    }

    /// The allocate heartbeat: submit new asks, release containers, and
    /// collect newly granted containers + completed-container statuses.
    pub fn allocate(
        &self,
        id: ApplicationId,
        asks: &[ContainerRequest],
        releases: &[ContainerId],
    ) -> Result<AllocateResponse> {
        let mut inner = self.inner.lock().unwrap();
        match inner.apps.get(&id) {
            None => bail!("unknown app {id}"),
            // YARN throws ApplicationAttemptNotRunning here; erroring lets
            // a zombie AM notice its app was killed out from under it.
            Some(app) if app.state.is_terminal() => {
                bail!("app {id} is terminal ({:?})", app.state)
            }
            Some(_) => {}
        }
        // Releases first: they create room for the new asks.
        for cid in releases {
            self.release_container_locked(&mut inner, *cid);
        }
        if !asks.is_empty() {
            let queue = inner.apps[&id].queue.clone();
            let tag = inner.next_tag;
            // Gang mode: every allocate round's asks form one gang — the
            // AM's initial wave and each recovery wave are placed
            // all-or-nothing.  Legacy mode leaves them independent.
            let gang = if self.sched.gang_mode {
                let g = inner.next_gang;
                inner.next_gang += 1;
                Some(g)
            } else {
                None
            };
            inner.next_tag = inner.scheduler.add_asks_gang(id, &queue, asks, tag, gang).next_tag;
        }
        self.schedule_locked(&mut inner);
        let app = inner.apps.get_mut(&id).unwrap();
        Ok(AllocateResponse {
            allocated: std::mem::take(&mut app.allocated_ready),
            completed: std::mem::take(&mut app.completed_ready),
            preempt_notices: std::mem::take(&mut app.preempt_ready),
            resize_target: app.resize_ready.take(),
        })
    }

    // ---------------- elasticity ----------------

    /// Register `id` as elastic: its worker count may move within
    /// `[min, max]` under the elasticity pass (the AM calls this right
    /// after `register_am`; see docs/SCHEDULING.md "Elasticity").
    /// Re-registration after an AM attempt restart resets any stale
    /// in-flight resize left by the previous attempt.
    pub fn register_elastic(
        &self,
        id: ApplicationId,
        resource: Resource,
        node_label: Option<String>,
        min: u32,
        max: u32,
        current: u32,
    ) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        let queue = match inner.apps.get(&id) {
            Some(app) => app.queue.clone(),
            None => bail!("unknown app {id}"),
        };
        inner.resizing.remove(&id);
        inner.elastic_cooldown_until.remove(&id);
        if let Some(app) = inner.apps.get_mut(&id) {
            app.resize_ready = None;
        }
        inner
            .scheduler
            .register_elastic(id, &queue, resource, node_label, min, max, current);
        tinfo!("rm", "{id} registered elastic: workers in [{min}, {max}], current {current}");
        Ok(())
    }

    /// The AM's resize wave completed (or a plain recovery settled): the
    /// app now runs `current` workers.  Clears the in-flight resize,
    /// records the acknowledged count, and stamps the grow cooldown.
    pub fn note_resized(&self, id: ApplicationId, current: u32) {
        let mut inner = self.inner.lock().unwrap();
        let was_resizing = inner.resizing.remove(&id).is_some();
        if inner.scheduler.elastic_profile(id).is_none() {
            return;
        }
        inner.scheduler.set_elastic_current(id, current);
        let until = self.clock.now_ms() + self.sched.elastic_cooldown_ms;
        inner.elastic_cooldown_until.insert(id, until);
        if was_resizing {
            tinfo!("rm", "{id} resize settled at {current} worker(s)");
            // The wave may have freed capacity a waiting gang needs.
            self.schedule_locked(&mut inner);
        }
    }

    /// Cooperative shrink release: the AM hands back `cids` mid-wave.
    /// Their NM exits are rewritten to [`ExitStatus::Released`] so they
    /// never read as task faults (mirrors the preemption rewrite).
    pub fn release_workers(&self, id: ApplicationId, cids: &[ContainerId]) {
        let mut to_stop: Vec<(Arc<NodeHandle>, ContainerId)> = Vec::new();
        {
            let mut inner = self.inner.lock().unwrap();
            for &cid in cids {
                match inner.containers.get(&cid) {
                    Some(live) if live.app == id => {
                        if live.started {
                            let node =
                                inner.nodes.iter().find(|n| n.spec.id == live.node).cloned();
                            inner.released.insert(cid);
                            if let Some(node) = node {
                                to_stop.push((node, cid));
                            }
                        } else {
                            // Never launched: plain release, no exit to
                            // rewrite.
                            self.release_container_locked(&mut inner, cid);
                        }
                    }
                    _ => {}
                }
            }
        }
        // Kill outside the lock: the NM completion callback re-enters
        // `on_container_complete`, which takes `inner`.
        for (node, cid) in to_stop {
            tdebug!("rm", "releasing {cid} for {id} (elastic shrink)");
            node.stop_container(cid);
        }
    }

    /// True while a *shrink* command is in flight (its capacity is
    /// already on its way back, so preemption planning stands down).
    fn shrink_in_flight(&self, inner: &Inner) -> bool {
        inner.resizing.iter().any(|(app, &target)| {
            inner.scheduler.elastic_profile(*app).map_or(false, |p| target < p.current)
        })
    }

    /// Queue a resize command for `id`'s next allocate round.
    fn queue_resize_locked(&self, inner: &mut Inner, id: ApplicationId, target: u32) {
        if let Some(app) = inner.apps.get_mut(&id) {
            app.resize_ready = Some(target);
            inner.resizing.insert(id, target);
            tinfo!("rm", "{id} resize -> {target} worker(s) queued");
            if let Some(bus) = inner.am_wakers.get(&id) {
                bus.notify(tag::RESIZE);
            }
        }
    }

    /// The elasticity pass, run after every placement pass: plan at most
    /// one shrink round (demand-driven, preferred over preemption) or
    /// one grow command (idle capacity only, cooldown-gated).  Stands
    /// down entirely while any resize or preemption is settling.
    fn elastic_locked(&self, inner: &mut Inner) {
        if !self.sched.elastic {
            return;
        }
        if !inner.resizing.is_empty() || !inner.preempting.is_empty() {
            return;
        }
        // Shrink first: a blocked gang in an under-guarantee queue takes
        // cooperative releases over preemption-kills every time.
        let am_containers: HashSet<ContainerId> = inner
            .apps
            .values()
            .filter_map(|a| a.am_container)
            .collect();
        let candidates: Vec<VictimCandidate> = inner
            .containers
            .iter()
            .filter(|(cid, c)| c.started && !am_containers.contains(cid))
            .filter(|(_, c)| inner.scheduler.elastic_profile(c.app).is_some())
            .map(|(cid, c)| VictimCandidate {
                container: *cid,
                app: c.app,
                queue: c.queue.clone(),
                node: c.node,
                resource: c.resource,
                gang: c.gang,
                seq: c.seq,
            })
            .collect();
        let targets = inner.scheduler.elastic_shrink_plan(
            &candidates,
            self.sched.preemption_max_victims,
            self.sched.elastic_max_resize,
        );
        if !targets.is_empty() {
            for (app, target) in targets {
                self.queue_resize_locked(inner, app, target);
            }
            return;
        }
        // No shrink demand: grow the neediest eligible job into idle
        // capacity (quiescence-gated inside the planner).
        let now = self.clock.now_ms();
        let plan = {
            let Inner { scheduler, elastic_cooldown_until, .. } = &mut *inner;
            let eligible = |app: ApplicationId| {
                elastic_cooldown_until.get(&app).map_or(true, |&until| now >= until)
            };
            scheduler.elastic_grow_plan(self.sched.elastic_max_resize, &eligible)
        };
        if let Some((app, target)) = plan {
            self.queue_resize_locked(inner, app, target);
        }
    }

    /// Launch task code in a granted container (NM `startContainer`).
    pub fn start_container(
        &self,
        container: &Container,
        env: BTreeMap<String, String>,
        launch: Launchable,
    ) -> Result<()> {
        let node = {
            let mut inner = self.inner.lock().unwrap();
            let live = inner
                .containers
                .get_mut(&container.id)
                .ok_or_else(|| anyhow!("unknown container {}", container.id))?;
            if live.started {
                bail!("container {} already started", container.id);
            }
            live.started = true;
            let nid = live.node;
            inner
                .nodes
                .iter()
                .find(|n| n.spec.id == nid)
                .cloned()
                .ok_or_else(|| anyhow!("unknown node {nid}"))?
        };
        let ctx = ContainerCtx::new(container.clone(), env);
        node.start_container(container.clone(), ctx, launch)
    }

    /// Ask the NM to kill a running container.
    pub fn stop_container(&self, id: ContainerId) {
        let node = {
            let inner = self.inner.lock().unwrap();
            inner
                .containers
                .get(&id)
                .and_then(|c| inner.nodes.iter().find(|n| n.spec.id == c.node).cloned())
        };
        if let Some(node) = node {
            node.stop_container(id);
        }
    }

    /// `finishApplicationMaster`: terminal state chosen by the AM.
    pub fn finish_application(&self, id: ApplicationId, success: bool, diagnostics: &str) {
        let mut inner = self.inner.lock().unwrap();
        let state = if success { AppState::Finished } else { AppState::Failed };
        self.teardown_app_locked(&mut inner, id, state, diagnostics);
    }

    // ---------------- chaos / introspection ----------------

    /// Kill a node: its containers die (`NodeLost`) and it leaves the
    /// scheduler's free pool.
    pub fn kill_node(&self, node: NodeId) {
        let handle = {
            let mut inner = self.inner.lock().unwrap();
            // Drops the node from the placement indexes and shrinks the
            // cluster total by its capacity in one step.
            inner.scheduler.remove_node(node);
            inner.nodes.iter().find(|n| n.spec.id == node).cloned()
        };
        if let Some(h) = handle {
            twarn!("rm", "node {node} killed (chaos)");
            h.kill_node();
        }
    }

    pub fn node_count(&self) -> usize {
        self.inner.lock().unwrap().nodes.len()
    }

    /// The node a live container sits on (chaos targeting: "kill the
    /// node hosting worker:1's container").
    pub fn container_node(&self, id: ContainerId) -> Option<NodeId> {
        self.inner.lock().unwrap().containers.get(&id).map(|c| c.node)
    }

    pub fn alive_node_count(&self) -> usize {
        self.inner.lock().unwrap().nodes.iter().filter(|n| n.is_alive()).count()
    }

    /// (free, capacity) per node — for the portal and the contention bench.
    pub fn node_usage(&self) -> Vec<(NodeId, Resource, Resource)> {
        let inner = self.inner.lock().unwrap();
        inner
            .nodes
            .iter()
            .map(|n| {
                // Dead nodes have left the scheduler's index; report zero.
                let free = inner.scheduler.node_free(n.spec.id).unwrap_or(Resource::ZERO);
                (n.spec.id, free, n.spec.capacity)
            })
            .collect()
    }

    pub fn queue_usage(&self) -> Vec<(String, Resource)> {
        let inner = self.inner.lock().unwrap();
        inner
            .scheduler
            .queue_usage()
            .into_iter()
            .map(|(n, used)| (n.to_string(), used))
            .collect()
    }

    /// One observability snapshot per queue: used resources, pending
    /// asks/gangs, reservations, preemptions, and dominant-share
    /// utilization against the cluster total.  Feeds the `/metrics`
    /// endpoints and the AM's sampled gauges.
    pub fn queue_stats(&self) -> Vec<QueueStat> {
        let inner = self.inner.lock().unwrap();
        let total = inner.scheduler.cluster_total();
        inner
            .scheduler
            .queue_snapshots()
            .into_iter()
            .map(|s| QueueStat {
                utilization: s.used.dominant_share(&total),
                pending: s.pending_asks,
                used: s.used,
                guaranteed: s.capacity,
                pending_gangs: s.pending_gangs,
                reservations: s.reservations,
                preemptions: s.preemptions,
                elastic_jobs: s.elastic_jobs,
                elastic_workers: s.elastic_workers,
                elastic_grows: s.elastic_grows,
                elastic_shrinks: s.elastic_shrinks,
                name: s.name,
            })
            .collect()
    }

    /// The scheduler's monotonic counters (unknown-queue remaps/releases,
    /// gangs placed, reservations, preemptions) — see
    /// [`SchedStats`].
    pub fn scheduler_stats(&self) -> SchedStats {
        self.inner.lock().unwrap().scheduler.stats()
    }

    /// The scheduler's queue/gang/reservation standing as JSON — what
    /// the gateway embeds in its WAL snapshots (operator forensics: a
    /// crash dump of *why* jobs were waiting rides along with the job
    /// table) and what `docs/DURABILITY.md` documents as the `sched`
    /// snapshot section.
    pub fn sched_state_json(&self) -> Json {
        let mut queues = Vec::new();
        for q in self.queue_stats() {
            let mut o = Json::obj();
            o.set("name", &*q.name);
            o.set("used_mem_mb", q.used.memory_mb);
            o.set("used_vcores", q.used.vcores as u64);
            o.set("used_gpus", q.used.gpus as u64);
            o.set("pending", q.pending as u64);
            o.set("pending_gangs", q.pending_gangs as u64);
            o.set("reservations", q.reservations as u64);
            o.set("preemptions", q.preemptions);
            o.set("utilization", q.utilization);
            o.set("guaranteed", q.guaranteed);
            o.set("elastic_jobs", q.elastic_jobs as u64);
            o.set("elastic_workers", q.elastic_workers);
            o.set("elastic_grows", q.elastic_grows);
            o.set("elastic_shrinks", q.elastic_shrinks);
            queues.push(o);
        }
        let stats = self.scheduler_stats();
        let mut s = Json::obj();
        s.set("gangs_placed", stats.gangs_placed);
        s.set("gangs_demoted", stats.gangs_demoted);
        s.set("reservations_made", stats.reservations_made);
        s.set("preemption_rounds", stats.preemption_rounds);
        s.set("preemptions", stats.preemptions);
        s.set("elastic_grows", stats.elastic_grows);
        s.set("elastic_shrink_rounds", stats.elastic_shrink_rounds);
        s.set("elastic_released", stats.elastic_released);
        s.set("unknown_queue_asks", stats.unknown_queue_asks);
        s.set("unknown_queue_releases", stats.unknown_queue_releases);
        let mut j = Json::obj();
        j.set("queues", Json::Arr(queues));
        j.set("stats", s);
        j
    }

    /// Where `id` stands with the gang scheduler (the gateway surfaces
    /// this as per-job `WAITING_FOR_GANG` / `PREEMPTING` state).
    pub fn app_sched_state(&self, id: ApplicationId) -> AppSchedState {
        let inner = self.inner.lock().unwrap();
        let preempting = inner
            .preempting
            .keys()
            .any(|cid| inner.containers.get(cid).map(|c| c.app == id).unwrap_or(false));
        if preempting {
            AppSchedState::Preempting
        } else if inner.scheduler.has_pending_gang(id) {
            AppSchedState::WaitingForGang
        } else {
            AppSchedState::Normal
        }
    }

    pub fn set_tracking_url(&self, id: ApplicationId, url: String) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(app) = inner.apps.get_mut(&id) {
            app.tracking_url = Some(url);
        }
    }

    // ---------------- internals ----------------

    fn release_container_locked(&self, inner: &mut Inner, cid: ContainerId) {
        if let Some(live) = inner.containers.get(&cid) {
            if live.started {
                // Running: ask the NM to kill; accounting happens on the
                // completion callback.
                let node = inner.nodes.iter().find(|n| n.spec.id == live.node).cloned();
                if let Some(n) = node {
                    n.stop_container(cid);
                }
            } else {
                // Granted but never started: free immediately.
                let live = inner.containers.remove(&cid).unwrap();
                inner.scheduler.release_container(&live.queue, live.node, live.resource);
            }
        }
    }

    fn schedule_locked(&self, inner: &mut Inner) {
        // The scheduler owns the node table and its free-capacity indexes;
        // no per-pass view materialization or write-back.
        let grants = inner.scheduler.schedule();
        for grant in grants {
            let cid = ContainerId { app: grant.ask.app, seq: inner.next_container_seq };
            inner.next_container_seq += 1;
            let container = Container {
                id: cid,
                app: grant.ask.app,
                node: grant.node,
                resource: grant.ask.resource,
                priority: grant.ask.priority,
            };
            let seq = inner.grant_seq;
            inner.grant_seq += 1;
            inner.containers.insert(
                cid,
                LiveContainer {
                    node: grant.node,
                    resource: grant.ask.resource,
                    app: grant.ask.app,
                    queue: grant.ask.queue.clone(),
                    started: false,
                    gang: grant.ask.gang,
                    seq,
                },
            );
            if let Some((app_id, am_code)) = inner.pending_am.remove(&grant.ask.tag) {
                // This grant is an AM container: launch it now.
                let app = inner.apps.get_mut(&app_id).unwrap();
                app.am_container = Some(cid);
                let node = inner
                    .nodes
                    .iter()
                    .find(|n| n.spec.id == grant.node)
                    .cloned()
                    .expect("granted node exists");
                let live = inner.containers.get_mut(&cid).unwrap();
                live.started = true;
                let mut env = BTreeMap::new();
                env.insert("APP_ID".to_string(), app_id.to_string());
                let ctx = ContainerCtx::new(container.clone(), env);
                tdebug!("rm", "launching AM for {app_id} in {cid} on {}", grant.node);
                if let Err(e) = node.start_container(container, ctx, am_code) {
                    twarn!("rm", "AM launch failed for {app_id}: {e}");
                    self.teardown_app_locked(inner, app_id, AppState::Failed, &e.to_string());
                }
            } else if let Some(app) = inner.apps.get_mut(&grant.ask.app) {
                app.allocated_ready.push(container);
                // Grant is an event: wake the owning AM's monitor loop so
                // it collects the container now, not on its next tick.
                if let Some(waker) = inner.am_wakers.get(&grant.ask.app) {
                    waker.notify(tag::GRANT);
                }
            }
        }
        self.elastic_locked(inner);
        self.preempt_locked(inner);
        self.drain_decisions_locked(inner);
    }

    /// Route the verdicts the scheduler audited during this pass into the
    /// owning apps' span stores.  Runs after every scheduling pass so the
    /// audit buffer never accumulates across passes, traced or not.
    fn drain_decisions_locked(&self, inner: &mut Inner) {
        let decisions = inner.scheduler.take_decisions();
        for d in decisions {
            if let Some(store) = inner.traces.get(&d.app) {
                store.scheduler_decision(d.gang, d.reason.as_str(), &d.detail);
            }
        }
    }

    /// Capacity preemption: enforce expired grace deadlines, then plan at
    /// most one new round.  Runs after every scheduling pass (allocate,
    /// release, completion, fallback tick), so under a system clock a
    /// grace deadline expires within one tick of becoming due.
    fn preempt_locked(&self, inner: &mut Inner) {
        if !self.sched.preemption {
            return;
        }
        let now = self.clock.now_ms();
        // 0. Abandon victims that ignored their kill: their capacity is
        //    still booked (they ARE still running), so planning simply
        //    routes around them — but a wedged container must not gate
        //    all future preemption (step 2's settle guard) forever.  If
        //    it ever exits after this, it reports as a plain kill.
        let zombies: Vec<ContainerId> = inner
            .preempting
            .iter()
            .filter(|(_, st)| st.kill_sent && now >= st.deadline_ms)
            .map(|(cid, _)| *cid)
            .collect();
        for cid in zombies {
            let owner = inner
                .containers
                .get(&cid)
                .map(|c| c.app.to_string())
                .unwrap_or_else(|| "<gone>".to_string());
            twarn!(
                "rm",
                "preempted {cid} (app {owner}) never exited; abandoning the preemption notice"
            );
            inner.preempting.remove(&cid);
        }
        // 1. Kill victims whose grace elapsed.  The completion callback
        //    rewrites their exit status to `Preempted`.
        let due: Vec<ContainerId> = inner
            .preempting
            .iter()
            .filter(|(_, st)| !st.kill_sent && now >= st.deadline_ms)
            .map(|(cid, _)| *cid)
            .collect();
        self.preempt_enforce_now_locked(inner, due);
        // 2. Plan a new round — but only once the previous round fully
        //    settled (every victim's completion arrived).  Planning over
        //    in-flight kills would not see their capacity as free yet and
        //    would select extra victims for the same shortfall.
        if !inner.preempting.is_empty() {
            return;
        }
        //    Same settle logic for an in-flight elastic shrink: its
        //    capacity is already on its way back cooperatively, so a
        //    preemption round now would kill containers for a shortfall
        //    the shrink is about to cover.  (In-flight *grows* don't
        //    gate preemption — they free nothing.)
        if self.shrink_in_flight(inner) {
            return;
        }
        //    AM containers are never victims (killing the AM kills the
        //    whole app — far more than one round's worth of capacity).
        let am_containers: std::collections::HashSet<ContainerId> =
            inner.apps.values().filter_map(|a| a.am_container).collect();
        let candidates: Vec<VictimCandidate> = inner
            .containers
            .iter()
            .filter(|(cid, live)| {
                live.started
                    && !inner.preempting.contains_key(*cid)
                    && !am_containers.contains(*cid)
            })
            .map(|(cid, live)| VictimCandidate {
                container: *cid,
                app: live.app,
                queue: live.queue.clone(),
                node: live.node,
                resource: live.resource,
                gang: live.gang,
                seq: live.seq,
            })
            .collect();
        let victims =
            inner.scheduler.preemption_plan(&candidates, self.sched.preemption_max_victims);
        if victims.is_empty() {
            return;
        }
        let deadline = now.saturating_add(self.sched.preemption_grace_ms);
        for v in &victims {
            twarn!(
                "rm",
                "preempting {} (app {}, queue '{}'); grace {} ms",
                v.container,
                v.app,
                v.queue,
                self.sched.preemption_grace_ms
            );
            inner
                .preempting
                .insert(v.container, PreemptState { deadline_ms: deadline, kill_sent: false });
            if let Some(app) = inner.apps.get_mut(&v.app) {
                app.preempt_ready.push(v.container);
            }
            if let Some(waker) = inner.am_wakers.get(&v.app) {
                waker.notify(tag::PREEMPT);
            }
        }
        if self.sched.preemption_grace_ms == 0 {
            // Zero grace: kill in the same pass instead of waiting for
            // the next scheduling event to notice the expired deadline.
            self.preempt_enforce_now_locked(inner, victims.iter().map(|v| v.container).collect());
        } else {
            // Grace enforcement must not depend on another scheduling
            // event happening to land after the deadline (with all
            // fallback ticks disabled, a quiescent cluster would never
            // kill the victims).
            self.spawn_preempt_waiter(deadline);
        }
    }

    /// Detached one-shot preemption timer: naps to `deadline_ms` on a
    /// clock-registered bus (manual clocks wake it on advance), then
    /// re-runs preemption enforcement/planning.  Holds only a `Weak`,
    /// so it dies with the RM.  Used for both the grace deadline (kill
    /// the victims) and the zombie give-up deadline (stop letting a
    /// wedged victim gate future planning).
    fn spawn_preempt_waiter(&self, deadline_ms: u64) {
        let weak = self.self_weak.clone();
        let clock = self.clock.clone();
        let _ = std::thread::Builder::new().name("rm-preempt-timer".into()).spawn(move || {
            let bus = WakeupBus::for_clock(&clock);
            while clock.now_ms() < deadline_ms {
                if weak.upgrade().is_none() {
                    return; // RM gone; nothing left to enforce
                }
                bus.wait_until(&*clock, deadline_ms);
            }
            if let Some(rm) = weak.upgrade() {
                let mut inner = rm.inner.lock().unwrap();
                rm.preempt_locked(&mut inner);
                rm.drain_decisions_locked(&mut inner);
            }
        });
    }

    /// Kill (or free) the given preempting containers right now — the
    /// grace-elapsed path and the zero-grace path share this triage.
    fn preempt_enforce_now_locked(&self, inner: &mut Inner, cids: Vec<ContainerId>) {
        let mut zombie_deadline = None;
        for cid in cids {
            // Triage under a short borrow of the container table, act
            // once it ends.
            let (started, node, owner) = match inner.containers.get(&cid) {
                Some(live) => (
                    Some(live.started),
                    inner.nodes.iter().find(|n| n.spec.id == live.node).cloned(),
                    Some(live.app),
                ),
                None => (None, None, None),
            };
            match started {
                Some(true) => {
                    if let Some(st) = inner.preempting.get_mut(&cid) {
                        st.kill_sent = true;
                        // Re-arm as the zombie give-up deadline.
                        st.deadline_ms =
                            self.clock.now_ms().saturating_add(PREEMPT_ZOMBIE_GIVEUP_MS);
                        zombie_deadline = Some(st.deadline_ms);
                    }
                    let owner = owner.expect("started container has an owner");
                    twarn!("rm", "preempting {cid} (app {owner}): grace over, killing");
                    if let Some(n) = node {
                        n.stop_container(cid);
                    }
                }
                Some(false) => {
                    // Granted but never started: free it synchronously
                    // (no container thread exists to report an exit).
                    inner.preempting.remove(&cid);
                    self.release_container_locked(inner, cid);
                }
                None => {
                    inner.preempting.remove(&cid);
                }
            }
        }
        // The zombie give-up needs its own wakeup for the same reason the
        // grace deadline does: on a quiescent cluster no scheduling event
        // may land after it, and a wedged victim would otherwise gate all
        // future planning forever (step 2's settle guard).
        if let Some(d) = zombie_deadline {
            self.spawn_preempt_waiter(d);
        }
    }

    fn on_container_complete(&self, node: NodeId, cid: ContainerId, status: ExitStatus) {
        let mut inner = self.inner.lock().unwrap();
        let Some(live) = inner.containers.remove(&cid) else { return };
        // A kill that lands while the container is under a preemption
        // notice is reported as `Preempted`, so the owning AM can treat
        // it as node-loss-style recovery rather than a task failure.
        // The same rewrite turns an elastic shrink release's kill into
        // `Released` — a chaos kill of a survivor is in neither set and
        // stays `Killed`/`NodeLost`, i.e. a real fault.
        let was_preempting = inner.preempting.remove(&cid).is_some();
        let was_released = inner.released.remove(&cid);
        let status = if was_preempting && status == ExitStatus::Killed {
            ExitStatus::Preempted
        } else if was_released && status == ExitStatus::Killed {
            ExitStatus::Released
        } else {
            status
        };
        // Return capacity (a dead node has left the index; the queue is
        // still credited, the node-side add is a no-op).
        inner.scheduler.release_container(&live.queue, live.node, live.resource);
        let app_id = live.app;
        let is_am = inner
            .apps
            .get(&app_id)
            .and_then(|a| a.am_container)
            .map(|am| am == cid)
            .unwrap_or(false);
        if is_am {
            // AM exit decides the app outcome unless already terminal.
            let needs_teardown = inner
                .apps
                .get(&app_id)
                .map(|a| !a.state.is_terminal())
                .unwrap_or(false);
            if needs_teardown {
                let (state, diag) = match status {
                    ExitStatus::Success => (AppState::Finished, "AM exited 0".to_string()),
                    other => (AppState::Failed, format!("AM exited abnormally: {other:?}")),
                };
                twarn!("rm", "AM container for {app_id} exited: {status:?}");
                self.teardown_app_locked(&mut inner, app_id, state, &diag);
            }
        } else if let Some(app) = inner.apps.get_mut(&app_id) {
            app.completed_ready.push(ContainerStatus {
                id: cid,
                exit: status,
                diagnostics: format!("container on {node} exited: {status:?}"),
            });
            if let Some(waker) = inner.am_wakers.get(&app_id) {
                waker.notify(tag::COMPLETED);
            }
        }
        // Freed capacity may unblock pending asks.
        self.schedule_locked(&mut inner);
    }

    fn teardown_app_locked(
        &self,
        inner: &mut Inner,
        id: ApplicationId,
        state: AppState,
        diagnostics: &str,
    ) {
        let Some(app) = inner.apps.get_mut(&id) else { return };
        if app.state.is_terminal() {
            return;
        }
        app.state = state;
        app.diagnostics = diagnostics.to_string();
        app.preempt_ready.clear();
        tinfo!("rm", "{id} -> {state:?} ({diagnostics})");
        inner.scheduler.remove_app(id);
        inner.resizing.remove(&id);
        inner.elastic_cooldown_until.remove(&id);
        inner.released.retain(|cid| cid.app != id);
        // Cancel preemption notices for this app's containers — they are
        // about to die as plain teardown kills, not preemptions.
        let doomed: Vec<ContainerId> = inner
            .preempting
            .keys()
            .filter(|cid| inner.containers.get(*cid).map(|c| c.app == id).unwrap_or(false))
            .copied()
            .collect();
        for cid in doomed {
            inner.preempting.remove(&cid);
        }
        // Kill every container of this app that is still alive.
        let to_kill: Vec<(ContainerId, NodeId, bool)> = inner
            .containers
            .iter()
            .filter(|(_, c)| c.app == id)
            .map(|(cid, c)| (*cid, c.node, c.started))
            .collect();
        for (cid, nid, started) in to_kill {
            if started {
                if let Some(n) = inner.nodes.iter().find(|n| n.spec.id == nid).cloned() {
                    n.stop_container(cid);
                }
            } else {
                self.release_container_locked(inner, cid);
            }
        }
        // Terminal verdicts accumulated this pass still belong in the
        // trace; drop the registration after one final drain.
        self.drain_decisions_locked(inner);
        inner.traces.remove(&id);
        // Wake completion waiters AND the app's own AM (its next allocate
        // will error, telling a zombie AM its app was killed under it).
        if let Some(waker) = inner.am_wakers.remove(&id) {
            waker.notify(tag::STATE);
        }
        self.events.notify(tag::STATE);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rm4() -> Arc<ResourceManager> {
        ResourceManager::start_uniform(4, Resource::new(4096, 4, 0))
    }

    #[test]
    fn trivial_am_finishes_app() {
        let rm = rm4();
        let rm2 = rm.clone();
        let id = rm
            .submit_application(
                SubmissionContext {
                    name: "noop".into(),
                    queue: "default".into(),
                    am_resource: Resource::new(512, 1, 0),
                },
                Box::new(move |ctx| {
                    let app = ApplicationId {
                        cluster_ts: rm2.cluster_ts,
                        seq: 1,
                    };
                    assert_eq!(ctx.env("APP_ID").unwrap(), app.to_string());
                    rm2.register_am(app, None).unwrap();
                    rm2.finish_application(app, true, "done");
                    0
                }),
            )
            .unwrap();
        let report = rm.wait_for_completion(id, Duration::from_secs(5)).unwrap();
        assert_eq!(report.state, AppState::Finished);
    }

    #[test]
    fn am_gets_task_containers_and_completions() {
        let rm = rm4();
        let rm2 = rm.clone();
        let id = rm
            .submit_application(
                SubmissionContext {
                    name: "two-tasks".into(),
                    queue: "default".into(),
                    am_resource: Resource::new(512, 1, 0),
                },
                Box::new(move |ctx| {
                    let app = crate::util::ids::ApplicationId {
                        cluster_ts: rm2.cluster_ts,
                        seq: 1,
                    };
                    let _ = ctx;
                    rm2.register_am(app, Some("http://am".into())).unwrap();
                    // Event-driven mini-AM: block on the waker between
                    // allocate calls instead of the old 5 ms retry sleep.
                    let bus = WakeupBus::for_clock(rm2.clock());
                    rm2.register_am_waker(app, &bus);
                    let clock = rm2.clock().clone();
                    let mut got = Vec::new();
                    let asks = vec![ContainerRequest::new(Resource::new(1024, 1, 0), 2)];
                    let mut asked = false;
                    let mut completed = 0;
                    while completed < 2 {
                        let resp = rm2
                            .allocate(app, if asked { &[] } else { &asks }, &[])
                            .unwrap();
                        asked = true;
                        for c in resp.allocated {
                            rm2.start_container(&c, BTreeMap::new(), Box::new(|_| 0)).unwrap();
                            got.push(c);
                        }
                        completed += resp
                            .completed
                            .iter()
                            .filter(|s| s.exit.is_success())
                            .count();
                        if completed < 2 {
                            bus.wait_until(&*clock, clock.now_ms() + 5_000);
                        }
                    }
                    assert_eq!(got.len(), 2);
                    rm2.finish_application(app, true, "all tasks done");
                    0
                }),
            )
            .unwrap();
        let report = rm.wait_for_completion(id, Duration::from_secs(10)).unwrap();
        assert_eq!(report.state, AppState::Finished, "{}", report.diagnostics);
        assert_eq!(report.tracking_url.as_deref(), Some("http://am"));
        // All capacity returned.
        for (_, free, cap) in rm.node_usage() {
            assert_eq!(free, cap);
        }
    }

    #[test]
    fn am_crash_fails_app() {
        let rm = rm4();
        let rm2 = rm.clone();
        let id = rm
            .submit_application(
                SubmissionContext {
                    name: "crash".into(),
                    queue: "default".into(),
                    am_resource: Resource::new(512, 1, 0),
                },
                Box::new(move |_ctx| {
                    let app = ApplicationId { cluster_ts: rm2.cluster_ts, seq: 1 };
                    rm2.register_am(app, None).unwrap();
                    7 // crash
                }),
            )
            .unwrap();
        let report = rm.wait_for_completion(id, Duration::from_secs(5)).unwrap();
        assert_eq!(report.state, AppState::Failed);
    }

    #[test]
    fn oversized_job_waits_and_kill_works() {
        let rm = ResourceManager::start_uniform(1, Resource::new(1024, 1, 0));
        let id = rm
            .submit_application(
                SubmissionContext {
                    name: "too-big".into(),
                    queue: "default".into(),
                    am_resource: Resource::new(4096, 1, 0), // never fits
                },
                Box::new(|_| 0),
            )
            .unwrap();
        // Scheduling is synchronous inside submit_application, so the
        // verdict is already final — no settling sleep needed.
        assert_eq!(rm.app_report(id).unwrap().state, AppState::Submitted);
        rm.kill_application(id);
        assert_eq!(rm.app_report(id).unwrap().state, AppState::Killed);
    }

    /// Manual clock, fallback tick disabled: a release arriving on the
    /// allocate path must trigger the blocked app's grant *by itself* —
    /// proof the scheduler is event-driven, not tick-driven.  Zero real
    /// sleeping anywhere in this test.
    #[test]
    fn release_event_grants_without_fallback_tick() {
        use crate::util::ManualClock;
        let clock = ManualClock::shared();
        let rm = ResourceManager::start_with(
            vec![NodeSpec::new(0, Resource::new(1024, 2, 0))],
            QueueConf::default_only(),
            RmConf { clock: clock.clone(), fallback_tick_ms: 0, ..Default::default() },
        );

        // App A's AM grabs the rest of the node, holds it until told to
        // release, then finishes.
        let (release_tx, release_rx) = std::sync::mpsc::channel::<()>();
        let rm2 = rm.clone();
        let a = rm
            .submit_application(
                SubmissionContext {
                    name: "holder".into(),
                    queue: "default".into(),
                    am_resource: Resource::new(512, 1, 0),
                },
                Box::new(move |_| {
                    let app = ApplicationId { cluster_ts: rm2.cluster_ts, seq: 1 };
                    rm2.register_am(app, None).unwrap();
                    // Grants are produced inline by the same allocate call
                    // that submits the ask — no waiting needed.
                    let asks = vec![ContainerRequest::new(Resource::new(512, 1, 0), 1)];
                    let resp = rm2.allocate(app, &asks, &[]).unwrap();
                    assert_eq!(resp.allocated.len(), 1, "ask event granted inline");
                    let held = resp.allocated[0].id;
                    release_rx.recv().unwrap();
                    // The release event: B's AM container must be granted
                    // and launched by this very call chain.
                    rm2.allocate(app, &[], &[held]).unwrap();
                    rm2.finish_application(app, true, "done");
                    0
                }),
            )
            .unwrap();

        // App B cannot fit until A releases.
        let rm3 = rm.clone();
        let b = rm
            .submit_application(
                SubmissionContext {
                    name: "blocked".into(),
                    queue: "default".into(),
                    am_resource: Resource::new(512, 1, 0),
                },
                Box::new(move |_| {
                    let app = ApplicationId { cluster_ts: rm3.cluster_ts, seq: 2 };
                    rm3.register_am(app, None).unwrap();
                    rm3.finish_application(app, true, "done");
                    0
                }),
            )
            .unwrap();
        assert_eq!(rm.app_report(b).unwrap().state, AppState::Submitted, "B blocked");

        release_tx.send(()).unwrap();
        // With no fallback tick and a frozen manual clock, only the
        // release event can unblock B.  wait_for_completion blocks on the
        // state bus (the manual deadline never elapses on its own), so a
        // real-time watchdog turns a regression into a failure, not a
        // hung test.
        let (done_tx, done_rx) = std::sync::mpsc::channel();
        let rm4 = rm.clone();
        std::thread::spawn(move || {
            let rb = rm4.wait_for_completion(b, Duration::from_secs(600));
            let ra = rm4.wait_for_completion(a, Duration::from_secs(600));
            let _ = done_tx.send((ra, rb));
        });
        let (ra, rb) = done_rx
            .recv_timeout(Duration::from_secs(30))
            .expect("release event never propagated to a grant");
        assert_eq!(rb.unwrap().state, AppState::Finished);
        assert_eq!(ra.unwrap().state, AppState::Finished);
        assert_eq!(clock.now_ms(), 0, "no virtual time consumed either");
    }

    /// `wait_for_completion` timeout is clock-driven: advancing a manual
    /// clock past the deadline fails the wait with zero real sleeping.
    #[test]
    fn wait_for_completion_times_out_on_manual_clock() {
        use crate::util::ManualClock;
        let clock = ManualClock::shared();
        let rm = ResourceManager::start_with(
            vec![NodeSpec::new(0, Resource::new(1024, 1, 0))],
            QueueConf::default_only(),
            RmConf { clock: clock.clone(), fallback_tick_ms: 0, ..Default::default() },
        );
        let id = rm
            .submit_application(
                SubmissionContext {
                    name: "never-fits".into(),
                    queue: "default".into(),
                    am_resource: Resource::new(4096, 1, 0),
                },
                Box::new(|_| 0),
            )
            .unwrap();
        let rm2 = rm.clone();
        let waiter =
            std::thread::spawn(move || rm2.wait_for_completion(id, Duration::from_millis(500)));
        // The only thing that can end the wait is virtual time passing.
        clock.advance_ms(501);
        let err = waiter.join().unwrap().unwrap_err();
        assert!(err.to_string().contains("timeout"), "got: {err:#}");
    }
}
