//! The TaskExecutor (paper §2.2): the per-container agent that
//!
//! 1. allocates a port for its task and registers it with the AM,
//! 2. receives the global cluster spec and materializes it (plus
//!    task-specific config) into the task's environment as TF_CONFIG,
//! 3. spawns the ML task as a child (here: a task thread),
//! 4. monitors it and heartbeats status/metrics to the AM,
//! 5. registers the final exit status with the AM before terminating.
//!
//! The executor for worker:0 additionally starts the visualization UI
//! (TensorBoard stand-in) and registers its URL.

use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use crate::util::clock::Clock;
use crate::util::event::{tag, WakeupBus};

use crate::am::protocol::*;
use crate::framework::protocol::{new_metrics_cell, ClusterSpec, MetricsCell};
use crate::framework::worker::{new_reconfig_cell, ReconfigCell};
use crate::framework::{ps, worker};
use crate::net::rpc::RpcClient;
use crate::net::wire::Wire;
use crate::runtime::Engine;
use crate::tonyconf::{JobSpec, EVALUATOR, PS, WORKER};
use crate::util::ids::{ApplicationId, TaskId};
use crate::util::HostPort;
use crate::yarn::ContainerCtx;
use crate::{tdebug, terror, tinfo, twarn};

/// Everything the AM hands an executor at launch (the closure-captured
/// analogue of the packaged conf + localized resources).
#[derive(Clone)]
pub struct ExecutorParams {
    pub am_addr: HostPort,
    pub job: Arc<JobSpec>,
    pub preset_dir: PathBuf,
    pub task: TaskId,
    pub spec_version: u32,
    /// The control-plane clock (inherited from the AM/RM) every executor
    /// deadline runs on.
    pub clock: Arc<dyn Clock>,
    /// The owning application — every executor log line carries it, so
    /// `grep <app-id>` reconstructs one job's full story across
    /// gateway/RM/AM/executor components.
    pub app: ApplicationId,
}

/// Executor main — the container entrypoint for every task container.
/// Returns the container exit code.
pub fn run_task_executor(ctx: ContainerCtx, params: ExecutorParams) -> i32 {
    match executor_body(&ctx, &params) {
        Ok(code) => code,
        Err(e) => {
            terror!("executor", "{} {} executor error: {e:#}", params.app, params.task);
            // Best-effort final status so the AM learns quickly.
            if let Ok(am) = RpcClient::connect(&params.am_addr) {
                let _ = am.call(
                    AM_FINISHED,
                    &FinishedMsg {
                        task_type: params.task.job_type.clone(),
                        index: params.task.index,
                        spec_version: params.spec_version,
                        exit_code: 1,
                    }
                    .to_bytes(),
                );
            }
            1
        }
    }
}

fn executor_body(ctx: &ContainerCtx, params: &ExecutorParams) -> Result<i32> {
    let task = &params.task;
    let app = params.app;
    // The env set by the AM is the source of truth (paper: executors are
    // configured through the launch context).
    let env_type = ctx.env("TASK_TYPE").unwrap_or(&task.job_type);
    let env_index: u32 = ctx
        .env("TASK_INDEX")
        .and_then(|s| s.parse().ok())
        .unwrap_or(task.index);
    anyhow::ensure!(
        env_type == task.job_type && env_index == task.index,
        "launch env/task mismatch: {env_type}:{env_index} vs {task}"
    );

    // Chaos knob: wedge this executor *before* it registers with the AM,
    // simulating a container that launches but never comes up (the
    // registration-hang regression).  The AM's registration deadline must
    // catch this; without it the attempt hangs forever.
    if let Some(wedge) = params.job.conf.get("tony.chaos.wedge-preregister") {
        if wedge == params.task.to_string() {
            twarn!("executor", "{app} {task} wedging pre-registration (chaos knob)");
            let clock = params.clock.clone();
            let wedge_bus = WakeupBus::for_clock(&clock);
            ctx.kill_switch().register(&wedge_bus);
            while !ctx.killed() {
                wedge_bus.wait_until(&*clock, clock.now_ms().saturating_add(60_000));
            }
            return Ok(137);
        }
    }

    let am = Arc::new(
        RpcClient::connect_timeout(&params.am_addr, Duration::from_secs(5))
            .map_err(|e| anyhow!("connecting to AM at {}: {e}", params.am_addr))?,
    );
    let kill = Arc::new(AtomicBool::new(false));
    let metrics: MetricsCell = new_metrics_cell();
    let clock = params.clock.clone();
    // The executor's monitor loop blocks on this bus: container kills,
    // local stop/abort decisions from the heartbeat thread, and task
    // completions all wake it at event time (the old loop re-polled all
    // three every 2–20 ms).
    let bus = WakeupBus::for_clock(&clock);
    ctx.kill_switch().register(&bus);

    // ---- start the engine with only the artifacts this task needs ----
    let is_chief = task.job_type == WORKER && task.index == 0;
    let artifacts: Vec<&str> = if task.job_type == PS {
        vec!["ps_adam"]
    } else if task.job_type == EVALUATOR {
        vec!["eval_loss"]
    } else if is_chief {
        vec!["worker_step", "init_params", "eval_loss"]
    } else {
        vec!["worker_step"]
    };
    let engine = Engine::start(&params.preset_dir, Some(&artifacts))
        .with_context(|| format!("starting PJRT engine for {task}"))?;
    tdebug!("executor", "{app} {task} engine ready ({} artifacts)", artifacts.len());

    // ---- allocate the task port ----
    // PS: the shard's RPC server binds it for real.  Workers: reserve a
    // port with a live listener so the spec entry is a real endpoint.
    let (port, ps_handle, port_guard): (u16, Option<std::thread::JoinHandle<i32>>, Option<TcpListener>);
    if task.job_type == PS {
        let (port_tx, port_rx) = std::sync::mpsc::sync_channel(1);
        let n_ps = params.job.n_ps();
        let index = task.index;
        let eng = engine.handle();
        let k = kill.clone();
        let m = metrics.clone();
        let exit_bus = bus.clone();
        let handle = std::thread::Builder::new()
            .name(format!("task-ps-{index}"))
            .spawn(move || {
                let code = ps::ps_main(index, n_ps, eng, k, m, move |p| {
                    let _ = port_tx.send(p);
                });
                exit_bus.notify(tag::TASK_EXIT);
                code
            })
            .context("spawning ps task")?;
        let p = port_rx
            .recv_timeout(Duration::from_secs(10))
            .context("ps never reported its port")?;
        (port, ps_handle, port_guard) = (p, Some(handle), None);
    } else {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let p = listener.local_addr()?.port();
        (port, ps_handle, port_guard) = (p, None, Some(listener));
    }

    // ---- worker:0 visualization UI (TensorBoard stand-in) ----
    let ui_url = if is_chief {
        match start_task_ui(metrics.clone(), kill.clone()) {
            Ok(url) => Some(url),
            Err(e) => {
                tdebug!("executor", "{app} {task} UI failed to start: {e}");
                None
            }
        }
    } else {
        None
    };

    // ---- register with the AM ----
    am.call(
        AM_REGISTER,
        &RegisterMsg {
            task_type: task.job_type.clone(),
            index: task.index,
            host: "127.0.0.1".to_string(),
            port,
            ui_url: ui_url.clone(),
            spec_version: params.spec_version,
        }
        .to_bytes(),
    )
    .map_err(|e| anyhow!("registering {task}: {e}"))?;
    tdebug!("executor", "{app} {task} registered port {port}");

    // ---- heartbeat thread (covers spec-wait AND task runtime) ----
    // The AM's liveness check starts at registration, so heartbeats must
    // flow from this moment on, even while we block waiting for the spec.
    // The thread also drives mid-run reconfiguration: on a `Reconfigure`
    // command it re-fetches the patched cluster spec, adopts its version
    // (the ack the AM's recovery barrier waits for), and hands the spec
    // to the task through `reconfig`.
    let hb_done = Arc::new(AtomicBool::new(false));
    // The spec version this executor currently runs at; starts at the
    // launch version and advances as patched specs are adopted.
    let cur_version = Arc::new(AtomicU32::new(params.spec_version));
    let reconfig: ReconfigCell = new_reconfig_cell();
    // Bus the heartbeat thread naps on between beats: a container kill
    // or executor shutdown wakes it instantly, and a manual clock drives
    // the beat cadence by advancing time.
    let hb_bus = WakeupBus::for_clock(&clock);
    ctx.kill_switch().register(&hb_bus);
    let hb_thread = {
        // Dedicated connection: the main thread's blocking GET_SPEC call
        // holds its connection for up to a second at a time, and heartbeats
        // must never queue behind it.
        let am = Arc::new(
            RpcClient::connect_timeout(&params.am_addr, Duration::from_secs(5))
                .map_err(|e| anyhow!("hb connection to AM: {e}"))?,
        );
        let kill = kill.clone();
        let metrics = metrics.clone();
        let done = hb_done.clone();
        let task = task.clone();
        let app = app;
        let cur_version = cur_version.clone();
        let reconfig = reconfig.clone();
        let job_metrics = params.job.metrics.clone();
        let hb_every_ms = params.job.heartbeat_ms.max(5);
        // The Reconfigure spec re-fetch runs on this thread, so it must
        // never block long enough for the AM to miss our heartbeats: cap
        // it at a quarter of the liveness budget.  The AM only sends
        // Reconfigure once the patched spec exists, so the fetch returns
        // immediately unless a further recovery just invalidated it — in
        // which case timing out and retrying next heartbeat is exactly
        // right.
        let spec_fetch_ms = (params.job.heartbeat_ms.max(5)
            * params.job.max_missed_heartbeats as u64
            / 4)
        .clamp(50, 1000);
        let clock = clock.clone();
        let hb_bus = hb_bus.clone();
        let monitor_bus = bus.clone();
        std::thread::Builder::new()
            .name(format!("hb-{task}"))
            .spawn(move || {
                // Heartbeats ship *incremental* loss-history deltas: only
                // the entries newer than the last step successfully
                // delivered go on the wire, so a beat stays O(1) instead
                // of re-serializing the whole curve every interval (the
                // AM re-assembles it; a re-sent delta after an error is
                // deduplicated there).
                let mut sent_hist_step: Option<u64> = None;
                let mut seen_rewound = 0u64;
                let hist_cap = job_metrics.loss_history_cap();
                while !done.load(Ordering::Relaxed) {
                    let m = {
                        let cell = metrics.lock().unwrap();
                        // A sync rollback truncated the local history;
                        // the delivered watermark is void even if
                        // retraining already re-reached it.  Resend the
                        // local curve — capped at what the AM retains
                        // anyway — and let the AM splice it.
                        if cell.history_rewound != seen_rewound {
                            seen_rewound = cell.history_rewound;
                            let hist = &cell.loss_history;
                            sent_hist_step =
                                hist.len().checked_sub(hist_cap + 1).map(|i| hist[i].0);
                        }
                        cell.delta_since(sent_hist_step)
                    };
                    let newest = m.last_history_step().or(sent_hist_step);
                    match am.call(
                        AM_HEARTBEAT,
                        &HeartbeatMsg {
                            task_type: task.job_type.clone(),
                            index: task.index,
                            spec_version: cur_version.load(Ordering::Relaxed),
                            metrics: m,
                        }
                        .to_bytes(),
                    ) {
                        Ok(resp) => {
                            sent_hist_step = newest;
                            match HeartbeatReply::from_bytes(&resp).command {
                                AmCommand::None => {}
                                AmCommand::Reconfigure => {
                                    let want = HeartbeatReply::from_bytes(&resp).spec_version;
                                    if want > cur_version.load(Ordering::Relaxed) {
                                        match am.call(
                                            AM_GET_SPEC,
                                            &GetSpecMsg {
                                                spec_version: want,
                                                timeout_ms: spec_fetch_ms,
                                            }
                                            .to_bytes(),
                                        ) {
                                            Ok(bytes) => {
                                                let text = String::from_utf8_lossy(&bytes);
                                                match ClusterSpec::from_tf_config(&text) {
                                                    Ok((spec, _, _)) => {
                                                        let v = spec.version;
                                                        tinfo!(
                                                            "executor",
                                                            "{app} {task} adopting patched spec v{v}"
                                                        );
                                                        cur_version
                                                            .store(v as u32, Ordering::Relaxed);
                                                        let shrunk = spec
                                                            .endpoints(&task.job_type)
                                                            .len()
                                                            <= task.index as usize;
                                                        *reconfig.lock().unwrap() = Some(spec);
                                                        if shrunk {
                                                            // An elastic shrink removed this
                                                            // task from the spec.  The RM's
                                                            // `Released` kill is normally
                                                            // already in flight; stop cleanly
                                                            // even if that message raced us.
                                                            tinfo!(
                                                                "executor",
                                                                "{app} {task} not in spec v{v}; stopping"
                                                            );
                                                            kill.store(true, Ordering::Relaxed);
                                                            monitor_bus.notify(tag::KILL);
                                                        }
                                                    }
                                                    Err(e) => tdebug!(
                                                        "executor",
                                                        "{app} {task} bad patched spec: {e}; will retry"
                                                    ),
                                                }
                                            }
                                            Err(e) => tdebug!(
                                                "executor",
                                                "{app} {task} spec refetch failed: {e}; will retry"
                                            ),
                                        }
                                    }
                                }
                                AmCommand::Stop | AmCommand::Abort => {
                                    tdebug!("executor", "{app} {task} commanded to stop");
                                    kill.store(true, Ordering::Relaxed);
                                    monitor_bus.notify(tag::KILL);
                                }
                            }
                        }
                        Err(e) => {
                            terror!("executor", "{app} {task} lost AM: {e}");
                            kill.store(true, Ordering::Relaxed);
                            monitor_bus.notify(tag::KILL);
                        }
                    }
                    // Nap until the next beat is due.  Wakes in between
                    // (kill switch, manual-clock advances, shutdown)
                    // re-check the deadline, so the cadence holds even
                    // when the bus is noisy — only `done` cuts it short.
                    let next_beat = clock.now_ms().saturating_add(hb_every_ms);
                    while !done.load(Ordering::Relaxed) && clock.now_ms() < next_beat {
                        hb_bus.wait_until(&*clock, next_beat);
                    }
                }
            })
            .context("spawning heartbeat thread")?
    };

    // ---- fetch the global cluster spec (blocking with retry) ----
    let spec_timeout_ms = params.job.conf.get_u64("tony.task.spec-timeout-ms", 120_000);
    let deadline = clock.now_ms().saturating_add(spec_timeout_ms);
    let spec = loop {
        if ctx.killed() || kill.load(Ordering::Relaxed) {
            hb_done.store(true, Ordering::Relaxed);
            hb_bus.notify(tag::SHUTDOWN);
            let _ = hb_thread.join();
            let v = cur_version.load(Ordering::Relaxed);
            return finish(&am, params, v, 143, ps_handle, kill.clone(), Some(&metrics));
        }
        match am.call(
            AM_GET_SPEC,
            &GetSpecMsg { spec_version: params.spec_version, timeout_ms: 1000 }.to_bytes(),
        ) {
            Ok(bytes) => {
                let text = String::from_utf8_lossy(&bytes);
                let (spec, _, _) = ClusterSpec::from_tf_config(&text)?;
                // The spec handed back may already be newer than the
                // launch version (a recovery raced our startup); adopt
                // whatever version we actually received.
                cur_version.store(spec.version as u32, Ordering::Relaxed);
                break spec;
            }
            Err(_) if clock.now_ms() < deadline => {
                // Pace the retry: `wait_spec` fails fast once the attempt
                // is being torn down, so an unthrottled `continue` would
                // hot-spin RPCs against the AM until our kill switch
                // flips.  A short bus nap keeps the kill wakeup instant
                // (tag::KILL lands on `bus`) without re-adding a poll
                // floor to the happy path, where the server-side wait
                // already blocks until the spec exists.
                bus.wait_until(&*clock, clock.now_ms().saturating_add(50));
                continue;
            }
            Err(e) => return Err(anyhow!("cluster spec never completed: {e}")),
        }
    };
    // Materialize the spec into the task environment, as real TonY does.
    let tf_config = spec.to_tf_config(&task.job_type, task.index);
    tdebug!("executor", "{app} {task} got spec v{} ({} tasks)", spec.version, spec.n_tasks());

    // ---- spawn the ML task ----
    let task_thread: Option<std::thread::JoinHandle<i32>> = if task.job_type == WORKER {
        let wctx = worker::WorkerContext {
            index: task.index,
            n_workers: params.job.n_workers(),
            ps_endpoints: spec.endpoints(PS).to_vec(),
            engine: engine.handle(),
            train: params.job.train.clone(),
            kill: kill.clone(),
            metrics: metrics.clone(),
            spec_version: spec.version,
            reconfig: Some(reconfig.clone()),
            loss_history_cap: params.job.metrics.loss_history_cap(),
        };
        let name = format!("task-worker-{}", task.index);
        let _ = &tf_config; // env formally constructed above
        let exit_bus = bus.clone();
        Some(
            std::thread::Builder::new()
                .name(name)
                .spawn(move || {
                    let code = worker::worker_main(wctx);
                    exit_bus.notify(tag::TASK_EXIT);
                    code
                })
                .context("spawning worker task")?,
        )
    } else if task.job_type == EVALUATOR {
        let eng = engine.handle();
        let train = params.job.train.clone();
        let k = kill.clone();
        let m = metrics.clone();
        let index = task.index;
        let exit_bus = bus.clone();
        Some(
            std::thread::Builder::new()
                .name(format!("task-evaluator-{index}"))
                .spawn(move || {
                    let code = crate::framework::evaluator_main(index, eng, train, k, m);
                    exit_bus.notify(tag::TASK_EXIT);
                    code
                })
                .context("spawning evaluator task")?,
        )
    } else {
        // PS task is already running (its server started before
        // registration so the port could be registered).
        debug_assert!(ps_handle.is_some());
        None
    };
    let mut task_thread = task_thread;
    let mut ps_handle = ps_handle;

    // ---- monitor loop (heartbeats flow from the hb thread) ----
    // Event-driven: task exit wrappers, the kill switch, and the hb
    // thread's stop/abort decisions all notify `bus`; the fallback tick
    // only bounds how long a (hypothetical) missed event could linger.
    // `tony.event.poll-mode` restores the old 2–20 ms poll for benches.
    let fallback_ms = params.job.conf.get_u64("tony.executor.fallback-tick-ms", 250).max(1);
    let poll_mode =
        params.job.conf.get("tony.event.poll-mode").map(|v| v == "true").unwrap_or(false);
    let poll_every = Duration::from_millis(params.job.heartbeat_ms.clamp(2, 20));
    let exit_code: i32 = loop {
        // Container kill (AM teardown, node death, preemption).
        if ctx.killed() {
            kill.store(true, Ordering::Relaxed);
        }
        // Task completion?
        if let Some(t) = &task_thread {
            if t.is_finished() {
                break task_thread.take().unwrap().join().unwrap_or(1);
            }
        } else if let Some(t) = &ps_handle {
            if t.is_finished() {
                break ps_handle.take().unwrap().join().unwrap_or(1);
            }
        }
        if poll_mode {
            clock.sleep(poll_every);
        } else {
            bus.wait_until(&*clock, clock.now_ms().saturating_add(fallback_ms));
        }
    };
    hb_done.store(true, Ordering::Relaxed);
    hb_bus.notify(tag::SHUTDOWN);
    let _ = hb_thread.join();
    drop(port_guard);

    // Graceful stop path: a task killed by Stop reports success for
    // service tasks (ps exits 0 by design) and 143 for workers.  A
    // *container* kill (chaos, preemption, teardown) is different: even a
    // service task that unwinds cleanly must report 143, otherwise the
    // AM reads a chaos-killed PS as a benign exit and never recovers it.
    let exit_code = if ctx.killed() && exit_code == 0 { 143 } else { exit_code };
    let v = cur_version.load(Ordering::Relaxed);
    finish(&am, params, v, exit_code, None, kill, Some(&metrics))
}

fn finish(
    am: &RpcClient,
    params: &ExecutorParams,
    spec_version: u32,
    code: i32,
    ps_handle: Option<std::thread::JoinHandle<i32>>,
    kill: Arc<AtomicBool>,
    metrics: Option<&MetricsCell>,
) -> Result<i32> {
    kill.store(true, Ordering::Relaxed);
    if let Some(h) = ps_handle {
        let _ = h.join();
    }
    // Flush one final metrics heartbeat so the AM's last snapshot of this
    // task (step count, loss, tokens) is exact, not heartbeat-quantized.
    if let Some(m) = metrics {
        let m = m.lock().unwrap().clone();
        let _ = am.call(
            AM_HEARTBEAT,
            &HeartbeatMsg {
                task_type: params.task.job_type.clone(),
                index: params.task.index,
                spec_version,
                metrics: m,
            }
            .to_bytes(),
        );
    }
    let _ = am.call(
        AM_FINISHED,
        &FinishedMsg {
            task_type: params.task.job_type.clone(),
            index: params.task.index,
            spec_version,
            exit_code: code as i64,
        }
        .to_bytes(),
    );
    tinfo!("executor", "{} {} finished with code {code}", params.app, params.task);
    Ok(code)
}

/// Minimal HTTP/1.0 UI serving the chief's live metrics as JSON — the
/// TensorBoard stand-in whose URL flows AM -> RM -> client (§2.2).
fn start_task_ui(metrics: MetricsCell, kill: Arc<AtomicBool>) -> Result<String> {
    use std::io::{Read, Write};
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    std::thread::Builder::new()
        .name("task-ui".into())
        .spawn(move || {
            while !kill.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((mut stream, _)) => {
                        let mut buf = [0u8; 1024];
                        let _ = stream.read(&mut buf);
                        let m = metrics.lock().unwrap().clone();
                        let mut j = crate::json::Json::obj();
                        j.set("step", m.step);
                        j.set("loss", m.loss as f64);
                        j.set("eval_loss", m.eval_loss as f64);
                        j.set("tokens", m.tokens_done);
                        j.set("step_ms_avg", m.step_ms_avg);
                        j.set(
                            "loss_history",
                            crate::json::Json::Arr(
                                m.loss_history
                                    .iter()
                                    .map(|(s, l)| {
                                        let mut e = crate::json::Json::obj();
                                        e.set("step", *s).set("loss", *l as f64);
                                        e
                                    })
                                    .collect(),
                            ),
                        );
                        let body = j.render_pretty();
                        let _ = write!(
                            stream,
                            "HTTP/1.0 200 OK\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{}",
                            body.len(),
                            body
                        );
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        crate::util::clock::real_sleep(Duration::from_millis(50));
                    }
                    Err(_) => break,
                }
            }
        })?;
    Ok(format!("http://{addr}"))
}
