//! Workflow engine (the Azkaban role, paper §2.1): DAG of jobs with
//! dependencies, job types, per-job status, and retries — plus the TonY
//! job-type plugin so a distributed training job slots into a larger
//! pipeline next to data-prep and deploy steps, exactly as §2.1
//! describes ("lets users add distributed ML jobs in the same workflow
//! alongside Spark, MapReduce, and other jobs").

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::client::TonyClient;
use crate::tinfo;
use crate::xmlconf::Configuration;
use crate::yarn::{AppState, ResourceManager};

/// Status of one workflow node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobStatus {
    Pending,
    Running,
    Succeeded,
    Failed,
    Skipped,
}

/// What a workflow node runs.  `Command` stands in for the Spark /
/// MapReduce / shell job types Azkaban hosts; `Tony` is our plugin.
pub enum JobType {
    /// Arbitrary in-process work (the data-prep / deploy stand-in).
    Command(Box<dyn FnMut() -> Result<()> + Send>),
    /// A TonY distributed-training job (the plugin of §2.1).
    Tony { conf: Configuration, preset_dir: std::path::PathBuf },
}

pub struct JobNode {
    pub name: String,
    pub job_type: JobType,
    pub deps: Vec<String>,
    pub retries: u32,
}

/// Execution record for reporting.
#[derive(Debug, Clone)]
pub struct JobRecord {
    pub name: String,
    pub status: JobStatus,
    pub attempts: u32,
    pub duration_ms: u64,
    pub detail: String,
}

pub struct Workflow {
    pub name: String,
    nodes: Vec<JobNode>,
}

impl Workflow {
    pub fn new(name: &str) -> Workflow {
        Workflow { name: name.to_string(), nodes: Vec::new() }
    }

    pub fn add(&mut self, node: JobNode) -> &mut Self {
        self.nodes.push(node);
        self
    }

    pub fn add_command(
        &mut self,
        name: &str,
        deps: &[&str],
        f: impl FnMut() -> Result<()> + Send + 'static,
    ) -> &mut Self {
        self.add(JobNode {
            name: name.to_string(),
            job_type: JobType::Command(Box::new(f)),
            deps: deps.iter().map(|s| s.to_string()).collect(),
            retries: 0,
        })
    }

    pub fn add_tony_job(
        &mut self,
        name: &str,
        deps: &[&str],
        conf: Configuration,
        preset_dir: &std::path::Path,
    ) -> &mut Self {
        self.add(JobNode {
            name: name.to_string(),
            job_type: JobType::Tony { conf, preset_dir: preset_dir.to_path_buf() },
            deps: deps.iter().map(|s| s.to_string()).collect(),
            retries: 0,
        })
    }

    /// Validate the DAG: unique names, known deps, acyclic.
    pub fn validate(&self) -> Result<Vec<String>> {
        let mut names = BTreeSet::new();
        for n in &self.nodes {
            if !names.insert(n.name.clone()) {
                bail!("duplicate job name '{}'", n.name);
            }
        }
        for n in &self.nodes {
            for d in &n.deps {
                if !names.contains(d) {
                    bail!("job '{}' depends on unknown job '{d}'", n.name);
                }
            }
        }
        // Kahn topological sort.
        let mut indeg: BTreeMap<&str, usize> =
            self.nodes.iter().map(|n| (n.name.as_str(), n.deps.len())).collect();
        let mut order = Vec::new();
        let mut ready: Vec<&str> = indeg
            .iter()
            .filter(|(_, d)| **d == 0)
            .map(|(n, _)| *n)
            .collect();
        while let Some(n) = ready.pop() {
            order.push(n.to_string());
            for m in &self.nodes {
                if m.deps.iter().any(|d| d == n) {
                    let e = indeg.get_mut(m.name.as_str()).unwrap();
                    *e -= 1;
                    if *e == 0 {
                        ready.push(&m.name);
                    }
                }
            }
        }
        if order.len() != self.nodes.len() {
            bail!("workflow '{}' has a dependency cycle", self.name);
        }
        Ok(order)
    }

    /// Run the DAG to completion (sequential in topological order; a
    /// failure marks all transitive dependents Skipped).
    pub fn run(mut self, rm: &Arc<ResourceManager>, timeout: Duration) -> Result<Vec<JobRecord>> {
        let order = self.validate()?;
        let mut status: BTreeMap<String, JobStatus> =
            order.iter().map(|n| (n.clone(), JobStatus::Pending)).collect();
        let mut records = Vec::new();
        tinfo!("workflow", "'{}': {} jobs, order {:?}", self.name, order.len(), order);

        for name in &order {
            let node = self.nodes.iter_mut().find(|n| n.name == *name).unwrap();
            // Dependency gate.
            let blocked = node
                .deps
                .iter()
                .any(|d| status[d] != JobStatus::Succeeded);
            if blocked {
                status.insert(name.clone(), JobStatus::Skipped);
                records.push(JobRecord {
                    name: name.clone(),
                    status: JobStatus::Skipped,
                    attempts: 0,
                    duration_ms: 0,
                    detail: "upstream failed".to_string(),
                });
                continue;
            }
            status.insert(name.clone(), JobStatus::Running);
            let started = std::time::Instant::now();
            let mut attempts = 0;
            let mut last_err = String::new();
            let mut ok = false;
            while attempts <= node.retries {
                attempts += 1;
                let result: Result<()> = match &mut node.job_type {
                    JobType::Command(f) => f(),
                    JobType::Tony { conf, preset_dir } => {
                        let client = TonyClient::new(rm.clone());
                        let handle = client.submit(conf, preset_dir)?;
                        let report = handle.wait(timeout)?;
                        if report.state == AppState::Finished {
                            Ok(())
                        } else {
                            Err(anyhow!("tony job failed: {}", report.diagnostics))
                        }
                    }
                };
                match result {
                    Ok(()) => {
                        ok = true;
                        break;
                    }
                    Err(e) => last_err = format!("{e:#}"),
                }
            }
            let st = if ok { JobStatus::Succeeded } else { JobStatus::Failed };
            status.insert(name.clone(), st);
            tinfo!("workflow", "'{}': job '{}' -> {:?}", self.name, name, st);
            records.push(JobRecord {
                name: name.clone(),
                status: st,
                attempts,
                duration_ms: started.elapsed().as_millis() as u64,
                detail: if ok { String::new() } else { last_err },
            });
        }
        Ok(records)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::yarn::Resource;

    fn rm() -> Arc<ResourceManager> {
        ResourceManager::start_uniform(2, Resource::new(4096, 4, 0))
    }

    #[test]
    fn linear_dag_runs_in_order() {
        let log = Arc::new(std::sync::Mutex::new(Vec::new()));
        let mut wf = Workflow::new("linear");
        for (name, dep) in [("a", vec![]), ("b", vec!["a"]), ("c", vec!["b"])] {
            let log = log.clone();
            let n = name.to_string();
            wf.add_command(name, &dep, move || {
                log.lock().unwrap().push(n.clone());
                Ok(())
            });
        }
        let records = wf.run(&rm(), Duration::from_secs(5)).unwrap();
        assert!(records.iter().all(|r| r.status == JobStatus::Succeeded));
        assert_eq!(*log.lock().unwrap(), vec!["a", "b", "c"]);
    }

    #[test]
    fn failure_skips_dependents() {
        let mut wf = Workflow::new("skippy");
        wf.add_command("prep", &[], || Ok(()));
        wf.add_command("bad", &["prep"], || anyhow::bail!("boom"));
        wf.add_command("train", &["bad"], || Ok(()));
        wf.add_command("independent", &["prep"], || Ok(()));
        let records = wf.run(&rm(), Duration::from_secs(5)).unwrap();
        let by_name: BTreeMap<_, _> =
            records.iter().map(|r| (r.name.clone(), r.status)).collect();
        assert_eq!(by_name["prep"], JobStatus::Succeeded);
        assert_eq!(by_name["bad"], JobStatus::Failed);
        assert_eq!(by_name["train"], JobStatus::Skipped);
        assert_eq!(by_name["independent"], JobStatus::Succeeded);
    }

    #[test]
    fn retries_work() {
        let attempts = Arc::new(std::sync::Mutex::new(0));
        let mut wf = Workflow::new("retry");
        let a = attempts.clone();
        wf.add(JobNode {
            name: "flaky".to_string(),
            job_type: JobType::Command(Box::new(move || {
                let mut n = a.lock().unwrap();
                *n += 1;
                if *n < 3 {
                    anyhow::bail!("transient");
                }
                Ok(())
            })),
            deps: vec![],
            retries: 3,
        });
        let records = wf.run(&rm(), Duration::from_secs(5)).unwrap();
        assert_eq!(records[0].status, JobStatus::Succeeded);
        assert_eq!(records[0].attempts, 3);
    }

    #[test]
    fn cycle_and_unknown_dep_detected() {
        let mut wf = Workflow::new("cycle");
        wf.add_command("a", &["b"], || Ok(()));
        wf.add_command("b", &["a"], || Ok(()));
        assert!(wf.validate().is_err());

        let mut wf = Workflow::new("unknown");
        wf.add_command("a", &["ghost"], || Ok(()));
        assert!(wf.validate().is_err());

        let mut wf = Workflow::new("dup");
        wf.add_command("a", &[], || Ok(()));
        wf.add_command("a", &[], || Ok(()));
        assert!(wf.validate().is_err());
    }
}
