//! Micro property-testing harness (proptest is unavailable offline).
//!
//! Deterministic SplitMix64-driven generators plus a runner that, on
//! failure, reports the seed/case index so the exact counterexample can be
//! replayed with `TONY_PROP_SEED`.  Shrinking is approximated by retrying
//! the failing case with "smaller" size hints — crude, but the seeds make
//! every failure exactly reproducible, which is what matters for CI.
//!
//! Used by `rust/tests/prop_*.rs` to check coordinator invariants:
//! scheduler never over-allocates, cluster specs are complete/consistent,
//! the AM state machine terminates under arbitrary failure schedules, and
//! wire/JSON/XML codecs round-trip.

use crate::util::SplitMix64;

/// Generation context handed to property bodies.
pub struct Gen {
    pub rng: SplitMix64,
    /// Size hint in [0, 100]; grows over the run so early cases are small.
    pub size: usize,
}

impl Gen {
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn u32(&mut self) -> u32 {
        self.rng.next_u32()
    }

    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.range_u64(lo, hi)
    }

    pub fn usize_up_to(&mut self, max: usize) -> usize {
        self.rng.range_usize(0, max)
    }

    /// A length scaled by the current size hint (never exceeding `cap`).
    pub fn len(&mut self, cap: usize) -> usize {
        let max = (cap * self.size.max(1) / 100).max(1).min(cap);
        self.rng.range_usize(0, max)
    }

    pub fn f64(&mut self) -> f64 {
        self.rng.next_f64()
    }

    pub fn f32(&mut self) -> f32 {
        // Mix of magnitudes, including negatives and exact zeros.
        match self.rng.next_below(8) {
            0 => 0.0,
            1 => -0.0,
            2 => self.rng.next_f64() as f32,
            3 => -(self.rng.next_f64() as f32),
            4 => (self.rng.next_f64() * 1e6) as f32,
            5 => -(self.rng.next_f64() * 1e6) as f32,
            6 => (self.rng.next_f64() * 1e-6) as f32,
            _ => f32::from_bits(self.rng.next_u32() & 0x7F7F_FFFF), // finite-ish
        }
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Random short ASCII identifier.
    pub fn ident(&mut self, max_len: usize) -> String {
        const CHARS: &[u8] = b"abcdefghijklmnopqrstuvwxyz0123456789_-.";
        let n = self.rng.range_usize(1, max_len.max(1));
        (0..n)
            .map(|_| CHARS[self.rng.next_below(CHARS.len() as u64) as usize] as char)
            .collect()
    }

    /// Random unicode-ish string (exercises escaping paths).
    pub fn string(&mut self, max_len: usize) -> String {
        let n = self.rng.range_usize(0, max_len);
        (0..n)
            .map(|_| match self.rng.next_below(6) {
                0 => '"',
                1 => '\\',
                2 => '\n',
                3 => char::from_u32(self.rng.range_u64(0x20, 0x7E) as u32).unwrap(),
                4 => 'é',
                _ => char::from_u32(self.rng.range_u64(0x20, 0xD7FF) as u32).unwrap_or('x'),
            })
            .collect()
    }

    pub fn vec_f32(&mut self, cap: usize) -> Vec<f32> {
        let n = self.len(cap);
        (0..n).map(|_| self.f32()).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }
}

/// Run `cases` iterations of a property.  Panics with the seed and case
/// index on first failure.
pub fn check<F>(name: &str, cases: u32, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let base_seed = std::env::var("TONY_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x7074_6573_7400u64); // "ptest"
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let size = (case as usize * 100 / cases.max(1) as usize).max(1);
        let mut g = Gen { rng: SplitMix64::new(seed), size };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (replay with TONY_PROP_SEED={base_seed}): {msg}"
            );
        }
    }
}

/// Assert helper producing property-style Err values.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr $(,)?) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err(format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check("count", 50, |_g| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fail'")]
    fn check_reports_failure() {
        check("fail", 10, |g| {
            let v = g.range(0, 100);
            if v > 1 {
                Err(format!("v={v}"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn gen_is_deterministic_per_case() {
        let mut first: Vec<u64> = Vec::new();
        check("det1", 5, |g| {
            first.push(g.u64());
            Ok(())
        });
        let mut second: Vec<u64> = Vec::new();
        check("det2", 5, |g| {
            second.push(g.u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
