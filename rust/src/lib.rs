//! # TonY - an orchestrator for distributed machine learning jobs
//!
//! Full-system reproduction of *TonY: An Orchestrator for Distributed
//! Machine Learning Jobs* (Hsu et al., LinkedIn, OpML '19) as a
//! three-layer Rust + JAX + Pallas stack.  See DESIGN.md for the system
//! inventory and README.md for the quickstart.
//!
//! Layer map:
//! - **L3 (this crate)**: the TonY client / ApplicationMaster /
//!   TaskExecutor orchestration system, the multi-tenant [`gateway`]
//!   daemon that runs many such jobs concurrently, a YARN-compatible
//!   cluster simulator they negotiate with, the parameter-server training
//!   framework the jobs launch, and supporting substrates (RPC, XML
//!   config, JSON, HTTP portal, workflow engine, metrics analyzer,
//!   checkpointing, job history).
//! - **L2/L1 (python/compile/)**: the JAX transformer LM + Pallas kernels,
//!   AOT-lowered once to `artifacts/<preset>/*.hlo.txt` and executed from
//!   `runtime::Engine` via PJRT (`--features pjrt`) or the deterministic
//!   simulation backend (`runtime::sim`, the offline default).  Python
//!   never runs on the job path.

pub mod am;
pub mod chaos;
pub mod checkpoint;
pub mod baseline;
pub mod bench;
pub mod client;
pub mod drelephant;
pub mod gateway;
pub mod portal;
pub mod workflow;
pub mod data;
pub mod executor;
pub mod framework;
pub mod history;
pub mod json;
pub mod metrics;
pub mod tonyconf;
pub mod trace;
pub mod net;
pub mod proptest;
pub mod runtime;
pub mod yarn;
pub mod util;
pub mod xmlconf;
