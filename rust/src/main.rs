//! `tony` — the CLI entrypoint: boot a simulated cluster, submit a job
//! from a tony.xml, watch it, and print the Dr. Elephant report.
//!
//! ```text
//! tony submit --conf job.xml --artifacts artifacts/tiny [--nodes 4]
//!             [--node-mem 8g] [--node-cores 8]
//! tony demo   [--artifacts artifacts/tiny] [--steps 10]
//! tony version
//! ```
//!
//! (Hand-rolled flag parsing — this offline build has no clap.)

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use tony::client::TonyClient;
use tony::drelephant;
use tony::runtime::ArtifactMeta;
use tony::tonyconf::{JobConfBuilder, JobSpec};
use tony::util::bytes::parse_size;
use tony::xmlconf::Configuration;
use tony::yarn::{Resource, ResourceManager};

fn parse_flags(args: &[String]) -> (Vec<String>, BTreeMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = BTreeMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    (pos, flags)
}

fn usage() -> ! {
    eprintln!(
        "usage:\n  tony submit --conf <tony.xml> --artifacts <dir> [--nodes N] \
         [--node-mem 8g] [--node-cores 8] [--node-gpus 0] [--timeout-s 600]\n  \
         tony demo [--artifacts artifacts/tiny] [--steps 10]\n  tony history\n  tony version"
    );
    std::process::exit(2);
}

fn boot_cluster(flags: &BTreeMap<String, String>) -> Arc<ResourceManager> {
    let nodes: u32 = flags.get("nodes").and_then(|s| s.parse().ok()).unwrap_or(4);
    let mem = flags
        .get("node-mem")
        .and_then(|s| parse_size(s))
        .unwrap_or(8 << 30)
        >> 20;
    let cores: u32 = flags.get("node-cores").and_then(|s| s.parse().ok()).unwrap_or(8);
    let gpus: u32 = flags.get("node-gpus").and_then(|s| s.parse().ok()).unwrap_or(0);
    ResourceManager::start_uniform(nodes, Resource::new(mem, cores, gpus))
}

fn run_and_report(
    rm: Arc<ResourceManager>,
    conf: &Configuration,
    artifacts: &PathBuf,
    timeout: Duration,
) -> i32 {
    let client = TonyClient::new(rm.clone());
    let handle = match client.submit(conf, artifacts) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("submit failed: {e:#}");
            return 1;
        }
    };
    println!("submitted {}", handle.app_id);
    if let Some(url) = handle.portal_url() {
        println!("portal (tracking URL): {url}");
    }
    let t0 = std::time::Instant::now();
    let report = match handle.wait(timeout) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("wait failed: {e:#}");
            handle.kill();
            return 1;
        }
    };
    println!("final state: {:?} ({})", report.state, report.diagnostics);
    let store = tony::history::HistoryStore::default_location();
    match handle.record_history(&store, t0.elapsed().as_millis() as u64) {
        Ok(path) => println!("history recorded: {}", path.display()),
        Err(e) => eprintln!("history record failed: {e:#}"),
    }
    if let Some(url) = handle.ui_url() {
        println!("chief UI was at: {url}");
    }
    println!("--- status snapshot ---\n{}", handle.status_json().render_pretty());

    // Dr. Elephant report over the collected telemetry.
    if let (Ok(spec), Ok(meta)) = (JobSpec::from_conf(conf), ArtifactMeta::load(artifacts)) {
        let snap = handle.status_json();
        let mut tasks = Vec::new();
        if let Some(arr) = snap.get("tasks").and_then(|t| t.as_arr()) {
            for t in arr {
                let id = t.get("task").and_then(|v| v.as_str()).unwrap_or("?").to_string();
                let m = tony::framework::TaskMetrics {
                    step: t.get("step").and_then(|v| v.as_u64()).unwrap_or(0),
                    step_ms_avg: t.get("step_ms").and_then(|v| v.as_f64()).unwrap_or(0.0),
                    mem_used_mb: t.get("mem_mb").and_then(|v| v.as_u64()).unwrap_or(0),
                    updates_applied: t.get("updates").and_then(|v| v.as_u64()).unwrap_or(0),
                    ..Default::default()
                };
                tasks.push((id, m));
            }
        }
        let telemetry = drelephant::JobTelemetry::from_job(&spec, &meta, tasks);
        print!("{}", drelephant::render_report(&drelephant::analyze(&telemetry)));
    }
    if report.state == tony::yarn::AppState::Finished {
        0
    } else {
        1
    }
}

fn main() {
    tony::util::logging::init_from_env();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    let (_pos, flags) = parse_flags(&args[1..]);

    let code = match cmd.as_str() {
        "history" => {
            let store = tony::history::HistoryStore::default_location();
            let ids = store.list().unwrap_or_default();
            if ids.is_empty() {
                println!("no recorded jobs at {}", store.dir().display());
            }
            for id in &ids {
                if let Ok(rec) = store.load(id) {
                    println!(
                        "{id}  '{}'  {}  attempts={}  wall={}ms  queue={}",
                        rec.name,
                        if rec.succeeded { "FINISHED" } else { "FAILED" },
                        rec.attempts,
                        rec.wall_ms,
                        rec.queue
                    );
                }
            }
            if let Ok(s) = store.summary() {
                if s.jobs > 0 {
                    println!(
                        "-- {} jobs, {} succeeded, {} total attempts, {} tokens trained",
                        s.jobs, s.succeeded, s.total_attempts, s.total_tokens
                    );
                }
            }
            0
        }
        "version" => {
            println!("tony 0.1.0 (OpML'19 reproduction; rust+jax+pallas, AOT via XLA/PJRT)");
            0
        }
        "submit" => {
            let Some(conf_path) = flags.get("conf") else { usage() };
            let Some(artifacts) = flags.get("artifacts") else { usage() };
            let conf = match Configuration::from_xml_file(std::path::Path::new(conf_path)) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("bad conf {conf_path}: {e:#}");
                    std::process::exit(1);
                }
            };
            let timeout = Duration::from_secs(
                flags.get("timeout-s").and_then(|s| s.parse().ok()).unwrap_or(600),
            );
            let rm = boot_cluster(&flags);
            run_and_report(rm, &conf, &PathBuf::from(artifacts), timeout)
        }
        "demo" => {
            let artifacts = PathBuf::from(
                flags
                    .get("artifacts")
                    .cloned()
                    .unwrap_or_else(|| "artifacts/tiny".to_string()),
            );
            let steps: u64 = flags.get("steps").and_then(|s| s.parse().ok()).unwrap_or(10);
            let ckpt = std::env::temp_dir().join(format!("tony-demo-{}", std::process::id()));
            let conf = JobConfBuilder::new("demo")
                .instances("worker", 2)
                .memory("worker", "1g")
                .instances("ps", 1)
                .memory("ps", "1g")
                .train(artifacts.to_str().unwrap(), "tiny", steps)
                .set("tony.train.checkpoint-dir", ckpt.to_str().unwrap())
                .build();
            let rm = boot_cluster(&flags);
            let code = run_and_report(rm, &conf, &artifacts, Duration::from_secs(600));
            let _ = std::fs::remove_dir_all(&ckpt);
            code
        }
        _ => usage(),
    };
    std::process::exit(code);
}
