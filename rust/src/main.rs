//! `tony` — the CLI entrypoint: boot a simulated cluster, submit a job
//! from a tony.xml, watch it, and print the Dr. Elephant report — or run
//! the multi-tenant gateway daemon and submit to it over HTTP.
//!
//! ```text
//! tony submit --conf job.xml --artifacts artifacts/tiny [--nodes 4]
//!             [--node-mem 8g] [--node-cores 8]
//! tony submit --gateway 127.0.0.1:8080 --conf job.xml [--user alice]
//!             [--priority 3] [--no-wait]
//! tony serve  [--nodes 8] [--port 8080] [--workers 8] [--queue-depth 64]
//!             [--wal-dir DIR] [--wal-snapshot-every 256] [--wal-fsync true|false]
//!             [--recover]  (replay the WAL dir and resume the job table)
//!             [--queues ml:0.6:0.8,etl:0.4:1.0] [--map alice=ml,bob=etl]
//!             [--max-user-active 8] [--artifacts DIR]
//!             [--gang-mode true|false] [--preemption true|false]
//!             [--preemption-grace-ms 2000] [--preemption-max-victims 8]
//!             [--reservation-limit 2]
//! tony demo   [--artifacts artifacts/tiny] [--steps 10]
//! tony trace  <job-id> --gateway 127.0.0.1:8080   (or <app-id> from local history)
//! tony lint   [paths...] [--deny warnings]        (control-plane static analysis)
//! tony history
//! tony version
//! ```
//!
//! (Hand-rolled flag parsing — this offline build has no clap.)

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use tony::client::TonyClient;
use tony::drelephant;
use tony::gateway::{api as gwapi, Gateway, GatewayConf};
use tony::runtime::ArtifactMeta;
use tony::tonyconf::{JobConfBuilder, JobSpec};
use tony::util::bytes::parse_size;
use tony::xmlconf::Configuration;
use tony::yarn::{QueueConf, Resource, ResourceManager, RmConf, SchedulerConf};

fn parse_flags(args: &[String]) -> (Vec<String>, BTreeMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = BTreeMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    (pos, flags)
}

fn usage() -> ! {
    eprintln!(
        "usage:\n  tony submit --conf <tony.xml> --artifacts <dir> [--nodes N] \
         [--node-mem 8g] [--node-cores 8] [--node-gpus 0] [--timeout-s 600]\n  \
         tony submit --gateway <host:port> --conf <tony.xml> [--user U] \
         [--priority 1..10] [--no-wait]\n  \
         tony serve [--nodes 8] [--port 8080] [--workers 8] [--queue-depth 64] \
         [--queues name:cap:max,...] [--map user=queue,...] [--max-user-active 8] \
         [--artifacts DIR] [--gang-mode true|false] [--preemption true|false] \
         [--preemption-grace-ms 2000] [--preemption-max-victims 8] \
         [--reservation-limit 2] [--wal-dir DIR] [--wal-snapshot-every 256] \
         [--wal-fsync true|false] [--recover]\n  \
         tony demo [--artifacts artifacts/tiny] [--steps 10]\n  \
         tony trace <job-id> --gateway <host:port>  (or <app-id> from local history)\n  \
         tony lint [paths...] [--deny warnings] [--manifest rust/lint/lock-order.toml] \
         [--docs docs]\n  \
         tony history\n  tony version"
    );
    std::process::exit(2);
}

/// Parse `ml:0.6:0.8,etl:0.4:1.0` into queue configs (falls back to the
/// single `default` queue on absent/bad input).
fn parse_queues(flags: &BTreeMap<String, String>) -> Vec<QueueConf> {
    let Some(spec) = flags.get("queues") else { return QueueConf::default_only() };
    let mut queues = Vec::new();
    for part in spec.split(',') {
        let fields: Vec<&str> = part.split(':').collect();
        if fields.len() != 3 {
            eprintln!("ignoring malformed queue spec '{part}' (want name:cap:max)");
            return QueueConf::default_only();
        }
        let (cap, max) = match (fields[1].parse::<f64>(), fields[2].parse::<f64>()) {
            (Ok(c), Ok(m)) => (c, m),
            _ => {
                eprintln!("ignoring malformed queue spec '{part}' (bad fractions)");
                return QueueConf::default_only();
            }
        };
        queues.push(QueueConf::new(fields[0], cap, max));
    }
    let sum: f64 = queues.iter().map(|q| q.capacity).sum();
    if queues.is_empty() || (sum - 1.0).abs() > 1e-6 {
        eprintln!("queue capacities must sum to 1.0 (got {sum}); using the default queue");
        return QueueConf::default_only();
    }
    queues
}

fn boot_cluster(flags: &BTreeMap<String, String>) -> Arc<ResourceManager> {
    let nodes: u32 = flags.get("nodes").and_then(|s| s.parse().ok()).unwrap_or(4);
    let mem = flags
        .get("node-mem")
        .and_then(|s| parse_size(s))
        .unwrap_or(8 << 30)
        >> 20;
    let cores: u32 = flags.get("node-cores").and_then(|s| s.parse().ok()).unwrap_or(8);
    let gpus: u32 = flags.get("node-gpus").and_then(|s| s.parse().ok()).unwrap_or(0);
    let specs = (0..nodes)
        .map(|i| tony::yarn::NodeSpec::new(i, Resource::new(mem, cores, gpus)))
        .collect();
    // Scheduler policy flags map onto the `tony.scheduler.*` site keys
    // (docs/SCHEDULING.md); anything unset keeps the built-in default.
    let mut site = Configuration::new();
    for (flag, key) in [
        ("gang-mode", "tony.scheduler.gang-mode"), // lint:allow(config-outside-conf, reason = "flag table; every key is fed to site.set below")
        ("reservation-limit", "tony.scheduler.reservation-limit"), // lint:allow(config-outside-conf, reason = "flag table; every key is fed to site.set below")
        ("preemption", "tony.scheduler.preemption.enable"), // lint:allow(config-outside-conf, reason = "flag table; every key is fed to site.set below")
        ("preemption-grace-ms", "tony.scheduler.preemption.grace-ms"), // lint:allow(config-outside-conf, reason = "flag table; every key is fed to site.set below")
        ("preemption-max-victims", "tony.scheduler.preemption.max-victims-per-round"), // lint:allow(config-outside-conf, reason = "flag table; every key is fed to site.set below")
    ] {
        if let Some(v) = flags.get(flag) {
            site.set(key, v.as_str());
        }
    }
    let conf = RmConf { scheduler: SchedulerConf::from_conf(&site), ..Default::default() };
    ResourceManager::start_with(specs, parse_queues(flags), conf)
}

fn run_and_report(
    rm: Arc<ResourceManager>,
    conf: &Configuration,
    artifacts: &PathBuf,
    timeout: Duration,
) -> i32 {
    let client = TonyClient::new(rm.clone());
    let handle = match client.submit(conf, artifacts) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("submit failed: {e:#}");
            return 1;
        }
    };
    println!("submitted {}", handle.app_id);
    if let Some(url) = handle.portal_url() {
        println!("portal (tracking URL): {url}");
    }
    let t0 = std::time::Instant::now();
    let report = match handle.wait(timeout) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("wait failed: {e:#}");
            handle.kill();
            return 1;
        }
    };
    println!("final state: {:?} ({})", report.state, report.diagnostics);
    let store = tony::history::HistoryStore::default_location();
    match handle.record_history(&store, t0.elapsed().as_millis() as u64) {
        Ok(path) => println!("history recorded: {}", path.display()),
        Err(e) => eprintln!("history record failed: {e:#}"),
    }
    if let Some(url) = handle.ui_url() {
        println!("chief UI was at: {url}");
    }
    println!("--- status snapshot ---\n{}", handle.status_json().render_pretty());

    // Dr. Elephant report over the collected telemetry.
    if let (Ok(spec), Ok(meta)) = (JobSpec::from_conf(conf), ArtifactMeta::load(artifacts)) {
        let snap = handle.status_json();
        let mut tasks = Vec::new();
        if let Some(arr) = snap.get("tasks").and_then(|t| t.as_arr()) {
            for t in arr {
                let id = t.get("task").and_then(|v| v.as_str()).unwrap_or("?").to_string();
                let m = tony::framework::TaskMetrics {
                    step: t.get("step").and_then(|v| v.as_u64()).unwrap_or(0),
                    step_ms_avg: t.get("step_ms").and_then(|v| v.as_f64()).unwrap_or(0.0),
                    mem_used_mb: t.get("mem_mb").and_then(|v| v.as_u64()).unwrap_or(0),
                    updates_applied: t.get("updates").and_then(|v| v.as_u64()).unwrap_or(0),
                    ..Default::default()
                };
                tasks.push((id, m));
            }
        }
        let telemetry = drelephant::JobTelemetry::from_job(&spec, &meta, tasks);
        print!("{}", drelephant::render_report(&drelephant::analyze(&telemetry)));
    }
    if report.state == tony::yarn::AppState::Finished {
        0
    } else {
        1
    }
}

fn main() {
    tony::util::logging::init_from_env();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    let (pos, flags) = parse_flags(&args[1..]);

    let code = match cmd.as_str() {
        "history" => {
            let store = tony::history::HistoryStore::default_location();
            let ids = store.list().unwrap_or_default();
            if ids.is_empty() {
                println!("no recorded jobs at {}", store.dir().display());
            }
            for id in &ids {
                if let Ok(rec) = store.load(id) {
                    println!(
                        "{id}  '{}'  {}  attempts={}  wall={}ms  queue={}",
                        rec.name,
                        if rec.succeeded { "FINISHED" } else { "FAILED" },
                        rec.attempts,
                        rec.wall_ms,
                        rec.queue
                    );
                }
            }
            if let Ok(s) = store.summary() {
                if s.jobs > 0 {
                    println!(
                        "-- {} jobs, {} succeeded, {} total attempts, {} tokens trained",
                        s.jobs, s.succeeded, s.total_attempts, s.total_tokens
                    );
                }
            }
            0
        }
        "trace" => {
            // ASCII timeline of one job's lifecycle trace: per-stage
            // spans, scheduler verdicts, and the critical-path verdict
            // (docs/TRACING.md).  Live or finished jobs via a gateway;
            // finished jobs locally from the history store.
            let Some(id_arg) = pos.first() else { usage() };
            if let Some(gateway) = flags.get("gateway") {
                match id_arg.parse::<u64>() {
                    Err(_) => {
                        eprintln!("gateway job ids are numeric (got '{id_arg}')");
                        2
                    }
                    Ok(id) => match gwapi::trace_remote(gateway, id) {
                        Ok(j) => {
                            print!("{}", tony::trace::render_ascii(&j));
                            0
                        }
                        Err(e) => {
                            eprintln!("trace fetch failed: {e:#}");
                            1
                        }
                    },
                }
            } else {
                let store = tony::history::HistoryStore::default_location();
                match store.load(id_arg) {
                    Ok(rec) if rec.trace.get("spans").is_some() => {
                        print!("{}", tony::trace::render_ascii(&rec.trace));
                        0
                    }
                    Ok(_) => {
                        eprintln!(
                            "'{id_arg}' has no recorded trace (tracing disabled or \
                             tony.trace.export=false)"
                        );
                        1
                    }
                    Err(e) => {
                        eprintln!("no history record for '{id_arg}': {e:#}");
                        1
                    }
                }
            }
        }
        "version" => {
            println!("tony 0.1.0 (OpML'19 reproduction; rust+jax+pallas, AOT via XLA/PJRT)");
            0
        }
        "lint" => {
            // Control-plane static analysis (docs/LINTS.md): lock order,
            // blocking-under-lock, config/metric drift, sleep hygiene.
            let mut largs: Vec<String> = Vec::new();
            if flags.get("deny").map(String::as_str) == Some("warnings") {
                largs.push("--deny".to_string());
                largs.push("warnings".to_string());
            }
            if let Some(m) = flags.get("manifest") {
                largs.push("--manifest".to_string());
                largs.push(m.clone());
            }
            if let Some(d) = flags.get("docs") {
                largs.push("--docs".to_string());
                largs.push(d.clone());
            }
            if pos.is_empty() {
                for p in ["rust/src", "rust/benches", "rust/tests", "examples"] {
                    largs.push(p.to_string());
                }
            } else {
                largs.extend(pos.iter().cloned());
            }
            tony_lint::cli_main(&largs)
        }
        "submit" => {
            let Some(conf_path) = flags.get("conf") else { usage() };
            let conf = match Configuration::from_xml_file(std::path::Path::new(conf_path)) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("bad conf {conf_path}: {e:#}");
                    std::process::exit(1);
                }
            };
            let timeout = Duration::from_secs(
                flags.get("timeout-s").and_then(|s| s.parse().ok()).unwrap_or(600),
            );
            if let Some(gateway) = flags.get("gateway") {
                // Client mode: ship the conf to a running `tony serve`.
                let user = flags
                    .get("user")
                    .cloned()
                    .or_else(|| std::env::var("USER").ok())
                    .unwrap_or_else(|| "anonymous".to_string());
                let priority: u8 =
                    flags.get("priority").and_then(|s| s.parse().ok()).unwrap_or(1);
                match gwapi::submit_remote(gateway, &user, priority, &conf) {
                    Err(e) => {
                        eprintln!("gateway submit failed: {e:#}");
                        1
                    }
                    Ok((id, state)) => {
                        println!("job {id} submitted as '{user}' -> {state}");
                        println!("status: http://{gateway}/api/v1/jobs/{id}");
                        if flags.contains_key("no-wait") {
                            0
                        } else {
                            match gwapi::wait_remote(gateway, id, timeout) {
                                Ok((state, j)) => {
                                    println!("final state: {state}");
                                    println!("{}", j.render_pretty());
                                    if state == "FINISHED" {
                                        0
                                    } else {
                                        1
                                    }
                                }
                                Err(e) => {
                                    eprintln!("wait failed: {e:#}");
                                    1
                                }
                            }
                        }
                    }
                }
            } else {
                let Some(artifacts) = flags.get("artifacts") else { usage() };
                let rm = boot_cluster(&flags);
                run_and_report(rm, &conf, &PathBuf::from(artifacts), timeout)
            }
        }
        "serve" => {
            let rm = boot_cluster(&flags);
            let artifacts = flags
                .get("artifacts")
                .cloned()
                .unwrap_or_else(|| "artifacts/tiny".to_string());
            let mut gconf = GatewayConf::new(&artifacts);
            if let Some(w) = flags.get("workers").and_then(|s| s.parse().ok()) {
                gconf.workers = w;
            }
            if let Some(d) = flags.get("queue-depth").and_then(|s| s.parse().ok()) {
                gconf.queue_depth = d;
            }
            if let Some(n) = flags.get("max-user-active").and_then(|s| s.parse().ok()) {
                gconf.quotas.max_active_per_user = n;
            }
            if let Some(n) = flags.get("max-queue-active").and_then(|s| s.parse().ok()) {
                gconf.quotas.max_active_per_queue = Some(n);
            }
            if let Some(n) = flags.get("attempts").and_then(|s| s.parse().ok()) {
                gconf.max_submit_attempts = n;
            }
            if let Some(s) = flags.get("timeout-s").and_then(|s| s.parse().ok()) {
                gconf.job_timeout = Duration::from_secs(s);
            }
            if let Some(map) = flags.get("map") {
                for pair in map.split(',') {
                    if let Some((user, queue)) = pair.split_once('=') {
                        gconf
                            .quotas
                            .user_queues
                            .insert(user.trim().to_string(), queue.trim().to_string());
                    }
                }
            }
            // Durability flags ride through the site-conf path so the
            // same keys work from XML and from the command line.
            let mut site = Configuration::new();
            if let Some(dir) = flags.get("wal-dir") {
                site.set("tony.wal.enable", "true");
                site.set("tony.wal.dir", dir.as_str());
            }
            if let Some(n) = flags.get("wal-snapshot-every") {
                site.set("tony.wal.snapshot-every", n.as_str());
            }
            if let Some(b) = flags.get("wal-fsync") {
                site.set("tony.wal.fsync", b.as_str());
            }
            gconf.apply_site_conf(&site);
            let recover = flags.get("recover").map(|s| s == "true").unwrap_or(false);
            if recover && !gconf.wal.enable {
                eprintln!("--recover requires --wal-dir (nothing to replay without a WAL)");
                std::process::exit(2);
            }
            let port: u16 = flags.get("port").and_then(|s| s.parse().ok()).unwrap_or(8080);
            let boot = if recover { Gateway::recover(rm, gconf) } else { Gateway::start(rm, gconf) };
            let gw = match boot {
                Ok(g) => g,
                Err(e) => {
                    eprintln!("gateway failed to start: {e:#}");
                    std::process::exit(1);
                }
            };
            let api = match gwapi::GatewayApi::start(gw.clone(), port) {
                Ok(a) => a,
                Err(e) => {
                    eprintln!("gateway API failed to bind: {e:#}");
                    std::process::exit(1);
                }
            };
            println!("tony gateway up at {}", api.url());
            println!("  POST   {}/api/v1/jobs", api.url());
            println!("  GET    {}/api/v1/jobs", api.url());
            println!("  GET    {}/api/v1/jobs/<id>", api.url());
            println!("  DELETE {}/api/v1/jobs/<id>", api.url());
            println!("  GET    {}/api/v1/jobs/<id>/metrics", api.url());
            println!("  GET    {}/api/v1/jobs/<id>/trace", api.url());
            println!("  GET    {}/api/v1/cluster", api.url());
            println!("  GET    {}/metrics  (Prometheus, all running jobs)", api.url());
            println!("submit with: tony submit --gateway {} --conf job.xml", api.addr);
            loop {
                // Serve forever; the daemon is fully event-driven, so the
                // main thread just parks.
                std::thread::park();
            }
        }
        "demo" => {
            let mut artifacts = PathBuf::from(
                flags
                    .get("artifacts")
                    .cloned()
                    .unwrap_or_else(|| "artifacts/tiny".to_string()),
            );
            // No real artifacts around?  Sim builds fall back to a
            // generated synthetic preset so the demo always runs.
            if !artifacts.join("meta.json").exists()
                && tony::runtime::synthetic::sim_backend_active()
            {
                match tony::runtime::synthetic::default_dir() {
                    Ok(d) => {
                        println!("artifacts missing at {}; using synthetic preset {}",
                            artifacts.display(), d.display());
                        artifacts = d;
                    }
                    Err(e) => eprintln!("synthetic preset unavailable: {e:#}"),
                }
            }
            let steps: u64 = flags.get("steps").and_then(|s| s.parse().ok()).unwrap_or(10);
            let ckpt = std::env::temp_dir().join(format!("tony-demo-{}", std::process::id()));
            let conf = JobConfBuilder::new("demo")
                .instances("worker", 2)
                .memory("worker", "1g")
                .instances("ps", 1)
                .memory("ps", "1g")
                .train(artifacts.to_str().unwrap(), "tiny", steps)
                .set("tony.train.checkpoint-dir", ckpt.to_str().unwrap())
                .build();
            let rm = boot_cluster(&flags);
            let code = run_and_report(rm, &conf, &artifacts, Duration::from_secs(600));
            let _ = std::fs::remove_dir_all(&ckpt);
            code
        }
        _ => usage(),
    };
    std::process::exit(code);
}
