//! The gateway's HTTP JSON API, reusing the portal's hand-rolled HTTP
//! plumbing (`portal::read_http_request` / `portal::http_response`).
//!
//! Routes:
//!
//! - `POST   /api/v1/jobs`              — submit (`{"user", "priority", "conf": {...}}`)
//! - `GET    /api/v1/jobs`              — every job + its admission decision
//! - `GET    /api/v1/jobs/<id>`         — one job (running jobs include live
//!   `phase` + streaming Dr. Elephant `findings`)
//! - `GET    /api/v1/jobs/<id>/metrics` — the job's time series as JSON
//!   (live registry while running, down-sampled history record after)
//! - `GET    /api/v1/jobs/<id>/trace`   — the job's lifecycle span tree +
//!   critical-path analysis (live span store while running, exported
//!   record from history after; see `docs/TRACING.md`)
//! - `DELETE /api/v1/jobs/<id>`         — kill (queued or running)
//! - `GET    /api/v1/cluster`           — RM utilization + gateway counters
//! - `GET    /metrics`                  — Prometheus text format aggregated
//!   across every running tenant job (`job`/`id`/`user`/`queue` labels),
//!   plus per-queue cluster gauges and gateway counters (`docs/METRICS.md`)
//!
//! Status codes: 201 accepted, 400 spec problems (invalid / too large /
//! unknown queue), 429 retryable refusals (quota, backpressure), 404
//! unknown route or id — always with a JSON `{"code", "error"}` body.
//! Reject bodies carry a stable `code` from [`RejectReason::code`].

use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use super::{Gateway, RejectReason, SubmitOutcome};
use crate::json::Json;
use crate::portal::{
    error_body, http_request, http_response, read_http_request, respond_not_found,
    PROM_CONTENT_TYPE,
};
use crate::util::HostPort;
use crate::xmlconf::Configuration;
use crate::{tinfo, twarn};

pub struct GatewayApi {
    pub addr: HostPort,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

/// A parsed submission request body.
pub struct SubmitBody {
    pub user: String,
    pub priority: u8,
    pub conf: Configuration,
}

/// Parse `{"user": ..., "priority": ..., "name": ..., "conf": {...}}`.
/// Conf values may be JSON strings or numbers (rendered verbatim).
pub fn parse_submit_body(body: &str) -> Result<SubmitBody, String> {
    let j = Json::parse(body).map_err(|e| format!("bad JSON: {e}"))?;
    let user = j
        .get("user")
        .and_then(|u| u.as_str())
        .unwrap_or("anonymous")
        .to_string();
    let priority = j.get("priority").and_then(|p| p.as_u64()).unwrap_or(1).min(10) as u8;
    let conf_obj = j
        .get("conf")
        .and_then(|c| c.as_obj())
        .ok_or_else(|| "missing 'conf' object".to_string())?;
    let mut conf = Configuration::new();
    for (k, v) in conf_obj {
        let val = match v {
            Json::Str(s) => s.clone(),
            other => other.render(),
        };
        conf.set(k, val);
    }
    if let Some(name) = j.get("name").and_then(|n| n.as_str()) {
        conf.set("tony.application.name", name);
    }
    Ok(SubmitBody { user, priority, conf })
}

/// Encode a conf + identity as the wire body `parse_submit_body` reads.
pub fn render_submit_body(user: &str, priority: u8, conf: &Configuration) -> String {
    let mut c = Json::obj();
    for k in conf.keys() {
        if let Some(v) = conf.get(k) {
            c.set(k, v);
        }
    }
    let mut j = Json::obj();
    j.set("user", user);
    j.set("priority", priority as u64);
    j.set("conf", c);
    j.render()
}

fn reject_status(reason: &RejectReason) -> &'static str {
    if reason.is_retryable() {
        "429 Too Many Requests"
    } else {
        "400 Bad Request"
    }
}

fn job_id_from_path(path: &str, prefix: &str) -> Option<u64> {
    path.strip_prefix(prefix).and_then(|rest| rest.parse().ok())
}

fn handle(gw: &Gateway, stream: &mut std::net::TcpStream) {
    let req = match read_http_request(stream) {
        Ok(r) => r,
        Err(e) => {
            let msg = e.to_string();
            let (status, code) = if msg.contains("exceeds") {
                ("413 Payload Too Large", "payload-too-large")
            } else {
                ("400 Bad Request", "bad-request")
            };
            http_response(stream, status, "application/json", &error_body(code, &msg));
            return;
        }
    };
    let method = req.method.as_str();
    let path = req.path.as_str();
    match (method, path) {
        ("POST", "/api/v1/jobs") => match parse_submit_body(&req.body) {
            Err(msg) => {
                let mut j = Json::obj();
                j.set("error", msg.as_str());
                j.set("code", "bad-request");
                http_response(stream, "400 Bad Request", "application/json", &j.render_pretty());
            }
            Ok(body) => {
                let requested_queue = body
                    .conf
                    .get("tony.application.queue")
                    .unwrap_or_else(|| "default".to_string());
                match gw.submit_conf(&body.user, body.priority, body.conf) {
                    SubmitOutcome::Accepted { id } => {
                        let mut j = Json::obj();
                        j.set("id", id);
                        j.set("state", "PENDING");
                        // Surface the admission queue mapping so a job
                        // landing somewhere other than the queue it named
                        // is visible in the submit response, not silent.
                        let queue =
                            gw.job_queue(id).unwrap_or_else(|| requested_queue.clone());
                        j.set("queue_remapped", queue != requested_queue);
                        j.set("requested_queue", requested_queue.as_str());
                        j.set("queue", queue);
                        http_response(
                            stream,
                            "201 Created",
                            "application/json",
                            &j.render_pretty(),
                        );
                    }
                    SubmitOutcome::Rejected { id, reason } => {
                        let mut j = Json::obj();
                        j.set("id", id);
                        j.set("state", "REJECTED");
                        j.set("error", reason.to_string());
                        j.set("code", reason.code());
                        http_response(
                            stream,
                            reject_status(&reason),
                            "application/json",
                            &j.render_pretty(),
                        );
                    }
                }
            }
        },
        ("GET", "/api/v1/jobs") => {
            http_response(stream, "200 OK", "application/json", &gw.jobs_json().render_pretty());
        }
        ("GET", "/api/v1/cluster") => {
            http_response(stream, "200 OK", "application/json", &gw.cluster_json().render_pretty());
        }
        ("GET", "/metrics") => {
            http_response(stream, "200 OK", PROM_CONTENT_TYPE, &gw.metrics_prometheus());
        }
        ("GET", p) if p.starts_with("/api/v1/jobs/") && p.ends_with("/metrics") => {
            let id = p
                .strip_prefix("/api/v1/jobs/")
                .and_then(|rest| rest.strip_suffix("/metrics"))
                .and_then(|s| s.parse::<u64>().ok());
            match id.and_then(|id| gw.job_series_json(id)) {
                Some(j) => http_response(stream, "200 OK", "application/json", &j.render_pretty()),
                None => respond_not_found(stream, "no such job"),
            }
        }
        ("GET", p) if p.starts_with("/api/v1/jobs/") && p.ends_with("/trace") => {
            let id = p
                .strip_prefix("/api/v1/jobs/")
                .and_then(|rest| rest.strip_suffix("/trace"))
                .and_then(|s| s.parse::<u64>().ok());
            match id.and_then(|id| gw.job_trace_json(id)) {
                Some(j) => http_response(stream, "200 OK", "application/json", &j.render_pretty()),
                None => respond_not_found(stream, "no such job"),
            }
        }
        ("GET", p) if p.starts_with("/api/v1/jobs/") => {
            match job_id_from_path(p, "/api/v1/jobs/").and_then(|id| gw.job_json(id)) {
                Some(j) => http_response(stream, "200 OK", "application/json", &j.render_pretty()),
                None => respond_not_found(stream, "no such job"),
            }
        }
        ("DELETE", p) if p.starts_with("/api/v1/jobs/") => {
            let killed = job_id_from_path(p, "/api/v1/jobs/").and_then(|id| {
                gw.kill(id).map(|state| (id, state))
            });
            match killed {
                Some((id, state)) => {
                    let mut j = Json::obj();
                    j.set("id", id);
                    j.set("state", state.as_str());
                    j.set("kill", "requested");
                    http_response(stream, "200 OK", "application/json", &j.render_pretty());
                }
                None => respond_not_found(stream, "no such job"),
            }
        }
        _ => respond_not_found(stream, "not found"),
    }
}

impl GatewayApi {
    /// Bind `127.0.0.1:port` (0 = ephemeral) and serve the API.  The
    /// bound URL is installed as the gateway's tracking-URL base.
    pub fn start(gw: Arc<Gateway>, port: u16) -> Result<GatewayApi> {
        let listener = TcpListener::bind(("127.0.0.1", port))
            .with_context(|| format!("binding gateway API on port {port}"))?;
        let addr = HostPort::from_addr(listener.local_addr()?);
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        gw.set_api_url(format!("http://{addr}"));
        let thread = std::thread::Builder::new().name("gw-api".into()).spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((mut stream, _)) => {
                        // Thread-per-connection: one slow or malicious
                        // client must not starve every other tenant's
                        // submit/status/kill calls.
                        let g = gw.clone();
                        let _ = std::thread::Builder::new()
                            .name("gw-api-conn".into())
                            .spawn(move || handle(&g, &mut stream));
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        crate::util::clock::real_sleep(Duration::from_millis(10));
                    }
                    Err(e) => {
                        twarn!("gateway", "api accept error: {e}");
                        break;
                    }
                }
            }
        })?;
        tinfo!("gateway", "API listening at http://{addr}");
        Ok(GatewayApi { addr, stop, thread: Some(thread) })
    }

    pub fn url(&self) -> String {
        format!("http://{}", self.addr)
    }

    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

impl Drop for GatewayApi {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

// ---------------- client side (used by `tony submit --gateway`) ----------------

/// Submit a conf to a remote gateway.  Returns (job id, state) on accept;
/// rejects surface as errors carrying the server's reason.
pub fn submit_remote(
    gateway: &str,
    user: &str,
    priority: u8,
    conf: &Configuration,
) -> Result<(u64, String)> {
    let body = render_submit_body(user, priority, conf);
    let (status, resp) =
        http_request("POST", &format!("http://{gateway}/api/v1/jobs"), &body)?;
    let j = Json::parse(&resp).map_err(|e| anyhow!("bad gateway response: {e}"))?;
    if status != 201 {
        let err = j.get("error").and_then(|e| e.as_str()).unwrap_or("unknown reason");
        anyhow::bail!("gateway rejected the job (HTTP {status}): {err}");
    }
    let id = j
        .get("id")
        .and_then(|i| i.as_u64())
        .ok_or_else(|| anyhow!("gateway response missing job id"))?;
    let state = j.get("state").and_then(|s| s.as_str()).unwrap_or("PENDING").to_string();
    Ok((id, state))
}

/// Fetch one job's JSON from a remote gateway.
pub fn job_remote(gateway: &str, id: u64) -> Result<Json> {
    let (status, resp) = http_request("GET", &format!("http://{gateway}/api/v1/jobs/{id}"), "")?;
    if status != 200 {
        anyhow::bail!("gateway returned HTTP {status} for job {id}");
    }
    Json::parse(&resp).map_err(|e| anyhow!("bad gateway response: {e}"))
}

/// Fetch one job's lifecycle trace (span tree + critical path) from a
/// remote gateway — what `tony trace <job-id>` renders.
pub fn trace_remote(gateway: &str, id: u64) -> Result<Json> {
    let (status, resp) =
        http_request("GET", &format!("http://{gateway}/api/v1/jobs/{id}/trace"), "")?;
    if status != 200 {
        anyhow::bail!("gateway returned HTTP {status} for job {id}'s trace");
    }
    Json::parse(&resp).map_err(|e| anyhow!("bad gateway response: {e}"))
}

/// Poll a remote gateway until the job reaches a terminal state.
pub fn wait_remote(gateway: &str, id: u64, timeout: Duration) -> Result<(String, Json)> {
    let deadline = std::time::Instant::now() + timeout;
    loop {
        let j = job_remote(gateway, id)?;
        let state = j
            .get("state")
            .and_then(|s| s.as_str())
            .unwrap_or("UNKNOWN")
            .to_string();
        match state.as_str() {
            "PENDING" | "RUNNING" => {}
            _ => return Ok((state, j)),
        }
        if std::time::Instant::now() > deadline {
            anyhow::bail!("timed out waiting for job {id} (last state {state})");
        }
        // Remote HTTP polling: the gateway is another process from this
        // client's point of view, so real-time polling is all there is.
        crate::util::clock::real_sleep(Duration::from_millis(100));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gateway::GatewayConf;
    use crate::tonyconf::JobConfBuilder;
    use crate::yarn::{NodeSpec, QueueConf, Resource, ResourceManager};

    fn gw(tag: &str) -> Arc<Gateway> {
        let base = std::env::temp_dir().join(format!(
            "tony-apitest-{tag}-{}-{}",
            std::process::id(),
            crate::util::ids::next_seq()
        ));
        let mut conf = GatewayConf::new(base.join("artifacts"));
        conf.history_dir = base.join("history");
        conf.workers = 2;
        let rm = ResourceManager::start_uniform(2, Resource::new(4096, 8, 0));
        Gateway::start(rm, conf).unwrap()
    }

    fn job_conf(name: &str) -> Configuration {
        JobConfBuilder::new(name)
            .instances("worker", 1)
            .memory("worker", "512m")
            .instances("ps", 1)
            .memory("ps", "512m")
            .set("tony.am.memory", "256m")
            .set("tony.train.steps", "2")
            .build()
    }

    #[test]
    fn submit_body_round_trips() {
        let conf = job_conf("rt");
        let body = render_submit_body("alice", 3, &conf);
        let parsed = parse_submit_body(&body).unwrap();
        assert_eq!(parsed.user, "alice");
        assert_eq!(parsed.priority, 3);
        assert_eq!(parsed.conf.get("tony.worker.instances"), conf.get("tony.worker.instances"));
        assert!(parse_submit_body("{\"user\": \"x\"}").is_err(), "conf is required");
        assert!(parse_submit_body("not json").is_err());
    }

    #[test]
    fn api_end_to_end_over_http() {
        let gw = gw("http");
        let api = GatewayApi::start(gw.clone(), 0).unwrap();
        let hostport = api.addr.to_string();

        // Submit, watch it finish, see it in the listing.
        let (id, state) = submit_remote(&hostport, "alice", 2, &job_conf("via-http")).unwrap();
        assert_eq!(state, "PENDING");
        let (final_state, j) = wait_remote(&hostport, id, Duration::from_secs(120)).unwrap();
        assert_eq!(final_state, "FINISHED", "job json: {}", j.render_pretty());
        assert_eq!(j.get("user").and_then(|u| u.as_str()), Some("alice"));

        let (status, body) =
            http_request("GET", &format!("http://{hostport}/api/v1/jobs"), "").unwrap();
        assert_eq!(status, 200);
        let listing = Json::parse(&body).unwrap();
        assert_eq!(listing.get("jobs").and_then(|a| a.as_arr()).unwrap().len(), 1);

        // Cluster view includes the gateway block.
        let (status, body) =
            http_request("GET", &format!("http://{hostport}/api/v1/cluster"), "").unwrap();
        assert_eq!(status, 200);
        let cluster = Json::parse(&body).unwrap();
        assert!(cluster.get("gateway").is_some());
        assert!(cluster.get("nodes").is_some());

        // Rejects carry a code and the right status class.
        let big = JobConfBuilder::new("big").instances("worker", 64).memory("worker", "8g").build();
        let err = submit_remote(&hostport, "bob", 1, &big).unwrap_err();
        assert!(format!("{err:#}").contains("HTTP 400"), "{err:#}");

        // Unknown job id → 404.
        let (status, _) =
            http_request("GET", &format!("http://{hostport}/api/v1/jobs/999"), "").unwrap();
        assert_eq!(status, 404);

        // DELETE is a no-op state echo for a finished job.
        let (status, body) =
            http_request("DELETE", &format!("http://{hostport}/api/v1/jobs/{id}"), "").unwrap();
        assert_eq!(status, 200);
        assert_eq!(
            Json::parse(&body).unwrap().get("state").and_then(|s| s.as_str()),
            Some("FINISHED")
        );

        gw.shutdown();
    }

    /// Contract for `GET /api/v1/jobs/<id>/trace`: unknown ids get the
    /// standard JSON 404, jobs with tracing disabled get the empty
    /// `{"enabled": false, "spans": []}` shape, and a completed job's
    /// span tree replays from its history record (the live store is
    /// dropped at terminalization).
    #[test]
    fn trace_endpoint_contract() {
        let gw = gw("trace");
        let api = GatewayApi::start(gw.clone(), 0).unwrap();
        let hostport = api.addr.to_string();

        // Unknown job id → JSON 404 with the stable code.
        let (status, body) =
            http_request("GET", &format!("http://{hostport}/api/v1/jobs/999/trace"), "").unwrap();
        assert_eq!(status, 404);
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("code").and_then(|c| c.as_str()), Some("not-found"));
        assert!(j.get("error").is_some());

        // One job with tracing off, one with the default (on + export).
        let mut off = job_conf("untraced");
        off.set("tony.trace.enable", "false");
        let (id_off, _) = submit_remote(&hostport, "alice", 1, &off).unwrap();
        let (id_on, _) = submit_remote(&hostport, "bob", 1, &job_conf("traced")).unwrap();
        wait_remote(&hostport, id_off, Duration::from_secs(120)).unwrap();
        wait_remote(&hostport, id_on, Duration::from_secs(120)).unwrap();

        let off_trace = trace_remote(&hostport, id_off).unwrap();
        assert_eq!(off_trace.get("enabled").and_then(|b| b.as_bool()), Some(false));
        assert_eq!(
            off_trace.get("spans").and_then(|s| s.as_arr()).map(|a| a.len()),
            Some(0),
            "disabled jobs must serve the empty shape: {}",
            off_trace.render_pretty()
        );

        // Post-completion replay: the live handle is gone, so this span
        // tree came back out of the history record.
        let on_trace = trace_remote(&hostport, id_on).unwrap();
        assert_eq!(
            on_trace.get("enabled").and_then(|b| b.as_bool()),
            Some(true),
            "{}",
            on_trace.render_pretty()
        );
        assert!(!on_trace.get("spans").and_then(|s| s.as_arr()).unwrap().is_empty());
        let dominant = on_trace.at(&["critical_path", "dominant_stage"]).and_then(|d| d.as_str());
        assert!(dominant.is_some(), "critical path must name a stage: {}", on_trace.render_pretty());
        // `tony trace <job-id>` renders this same document.
        let text = crate::trace::render_ascii(&on_trace);
        assert!(text.contains("critical path"), "{text}");

        gw.shutdown();
    }

    /// Regression: a job landing on a different queue than it asked for
    /// (user→queue mapping, or the scheduler's unknown-queue fallback)
    /// used to be invisible at submit time.  The submit response now
    /// names the final queue and flags the remap.
    #[test]
    fn submit_response_surfaces_queue_mapping() {
        let base = std::env::temp_dir().join(format!(
            "tony-apitest-remap-{}-{}",
            std::process::id(),
            crate::util::ids::next_seq()
        ));
        let mut conf = GatewayConf::new(base.join("artifacts"));
        conf.history_dir = base.join("history");
        conf.workers = 1;
        conf.quotas.user_queues.insert("alice".to_string(), "ml".to_string());
        let rm = ResourceManager::start(
            vec![
                NodeSpec::new(0, Resource::new(4096, 8, 0)),
                NodeSpec::new(1, Resource::new(4096, 8, 0)),
            ],
            vec![
                QueueConf::new("default", 0.5, 1.0),
                QueueConf::new("ml", 0.5, 1.0),
            ],
        );
        let gw = Gateway::start(rm, conf).unwrap();
        let api = GatewayApi::start(gw.clone(), 0).unwrap();
        let hostport = api.addr.to_string();

        // alice's job names no queue -> her mapping moves it to 'ml'.
        let body = render_submit_body("alice", 1, &job_conf("mapped"));
        let (status, resp) =
            http_request("POST", &format!("http://{hostport}/api/v1/jobs"), &body).unwrap();
        assert_eq!(status, 201, "{resp}");
        let j = Json::parse(&resp).unwrap();
        assert_eq!(j.at(&["queue"]).and_then(|q| q.as_str()), Some("ml"));
        assert_eq!(j.at(&["requested_queue"]).and_then(|q| q.as_str()), Some("default"));
        assert_eq!(j.at(&["queue_remapped"]).and_then(|b| b.as_bool()), Some(true));

        // bob has no mapping: default stays default, no remap flag.
        let body = render_submit_body("bob", 1, &job_conf("plain"));
        let (status, resp) =
            http_request("POST", &format!("http://{hostport}/api/v1/jobs"), &body).unwrap();
        assert_eq!(status, 201, "{resp}");
        let j = Json::parse(&resp).unwrap();
        assert_eq!(j.at(&["queue"]).and_then(|q| q.as_str()), Some("default"));
        assert_eq!(j.at(&["queue_remapped"]).and_then(|b| b.as_bool()), Some(false));

        assert!(gw.wait_idle(Duration::from_secs(120)));
        gw.shutdown();
    }
}
