//! Admission control for the gateway: spec validation, queue mapping,
//! and per-user / per-queue quotas, with a machine-readable reject
//! reason for every refusal (the paper's shared-cluster story depends on
//! the scheduler seeing only *plausible* work; hopeless or abusive specs
//! are bounced at the front door).

use std::collections::BTreeMap;
use std::fmt;

use crate::tonyconf::JobSpec;
use crate::yarn::Resource;

/// Static quota configuration.
#[derive(Debug, Clone)]
pub struct QuotaConf {
    /// Max jobs per user that may be pending or running at once.
    pub max_active_per_user: u32,
    /// Max jobs per scheduler queue that may be pending or running at
    /// once (None = no per-queue job cap).
    pub max_active_per_queue: Option<u32>,
    /// Aggregate in-flight resources (tasks + AM) a single user may hold
    /// (None = unlimited).
    pub max_user_resource: Option<Resource>,
    /// User → queue mapping applied when a spec leaves its queue at
    /// `default` (LinkedIn-style org queues).
    pub user_queues: BTreeMap<String, String>,
}

impl Default for QuotaConf {
    fn default() -> QuotaConf {
        QuotaConf {
            max_active_per_user: 8,
            max_active_per_queue: None,
            max_user_resource: None,
            user_queues: BTreeMap::new(),
        }
    }
}

/// Why a submission was refused.  `code()` is stable for API clients;
/// Display is the human version.
#[derive(Debug, Clone, PartialEq)]
pub enum RejectReason {
    InvalidSpec(String),
    JobTooLarge { needed: Resource, cluster: Resource },
    UnknownQueue(String),
    UserQuotaExceeded { user: String, active: u32, limit: u32 },
    QueueQuotaExceeded { queue: String, active: u32, limit: u32 },
    UserResourceExceeded { user: String, needed: Resource, limit: Resource },
    Backpressure(String),
}

impl RejectReason {
    pub fn code(&self) -> &'static str {
        match self {
            RejectReason::InvalidSpec(_) => "invalid-spec",
            RejectReason::JobTooLarge { .. } => "job-too-large",
            RejectReason::UnknownQueue(_) => "unknown-queue",
            RejectReason::UserQuotaExceeded { .. } => "user-quota",
            RejectReason::QueueQuotaExceeded { .. } => "queue-quota",
            RejectReason::UserResourceExceeded { .. } => "user-resources",
            RejectReason::Backpressure(_) => "backpressure",
        }
    }

    /// Whether a client could succeed by simply retrying later (quota /
    /// backpressure rejects) as opposed to fixing the spec.
    pub fn is_retryable(&self) -> bool {
        matches!(
            self,
            RejectReason::UserQuotaExceeded { .. }
                | RejectReason::QueueQuotaExceeded { .. }
                | RejectReason::UserResourceExceeded { .. }
                | RejectReason::Backpressure(_)
        )
    }
}

impl fmt::Display for RejectReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RejectReason::InvalidSpec(e) => write!(f, "invalid job spec: {e}"),
            RejectReason::JobTooLarge { needed, cluster } => write!(
                f,
                "job needs {needed} but the whole cluster is only {cluster}"
            ),
            RejectReason::UnknownQueue(q) => write!(f, "queue '{q}' is not configured"),
            RejectReason::UserQuotaExceeded { user, active, limit } => write!(
                f,
                "user '{user}' already has {active}/{limit} jobs in flight"
            ),
            RejectReason::QueueQuotaExceeded { queue, active, limit } => write!(
                f,
                "queue '{queue}' already has {active}/{limit} jobs in flight"
            ),
            RejectReason::UserResourceExceeded { user, needed, limit } => write!(
                f,
                "user '{user}' in-flight resources would exceed {limit} (requested {needed})"
            ),
            RejectReason::Backpressure(msg) => write!(f, "{msg}"),
        }
    }
}

/// The gateway state admission decides against (built under the
/// gateway's lock, so decisions are atomic with the bookkeeping).
pub struct AdmissionView<'a> {
    pub user_active: &'a BTreeMap<String, u32>,
    pub queue_active: &'a BTreeMap<String, u32>,
    pub user_resources: &'a BTreeMap<String, Resource>,
}

#[derive(Debug, Clone, Default)]
pub struct AdmissionController {
    pub quotas: QuotaConf,
}

impl AdmissionController {
    pub fn new(quotas: QuotaConf) -> AdmissionController {
        AdmissionController { quotas }
    }

    /// Resolve the scheduler queue for `(user, spec)`.  A spec that names
    /// a queue explicitly must name a configured one; a spec on
    /// `default` follows the user mapping when present.
    pub fn map_queue(
        &self,
        user: &str,
        spec: &JobSpec,
        known_queues: &[String],
    ) -> Result<String, RejectReason> {
        let wants = if spec.queue == "default" {
            self.quotas.user_queues.get(user).cloned().unwrap_or_else(|| spec.queue.clone())
        } else {
            spec.queue.clone()
        };
        if known_queues.iter().any(|q| *q == wants) {
            Ok(wants)
        } else {
            Err(RejectReason::UnknownQueue(wants))
        }
    }

    /// The full admission decision: returns the target queue, or the
    /// first reason to refuse.
    pub fn decide(
        &self,
        user: &str,
        spec: &JobSpec,
        cluster_total: Resource,
        known_queues: &[String],
        view: &AdmissionView<'_>,
    ) -> Result<String, RejectReason> {
        // 1. The job must be satisfiable at all: transient contention
        //    queues, impossible jobs bounce (paper §1).
        let needed = spec.total_task_resources() + spec.am_resource;
        if !cluster_total.fits(&needed) {
            return Err(RejectReason::JobTooLarge { needed, cluster: cluster_total });
        }

        // 2. Queue mapping + existence.
        let queue = self.map_queue(user, spec, known_queues)?;

        // 3. Per-user job-count quota.
        let active = view.user_active.get(user).copied().unwrap_or(0);
        if active >= self.quotas.max_active_per_user {
            return Err(RejectReason::UserQuotaExceeded {
                user: user.to_string(),
                active,
                limit: self.quotas.max_active_per_user,
            });
        }

        // 4. Per-queue job-count quota.
        if let Some(limit) = self.quotas.max_active_per_queue {
            let qactive = view.queue_active.get(&queue).copied().unwrap_or(0);
            if qactive >= limit {
                return Err(RejectReason::QueueQuotaExceeded {
                    queue,
                    active: qactive,
                    limit,
                });
            }
        }

        // 5. Per-user aggregate resource quota.
        if let Some(limit) = self.quotas.max_user_resource {
            let held = view.user_resources.get(user).copied().unwrap_or(Resource::ZERO);
            let after = held + needed;
            if !limit.fits(&after) {
                return Err(RejectReason::UserResourceExceeded {
                    user: user.to_string(),
                    needed,
                    limit,
                });
            }
        }

        Ok(queue)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tonyconf::{JobConfBuilder, JobSpec};

    fn spec(queue: &str, workers: u32, mem: &str) -> JobSpec {
        let conf = JobConfBuilder::new("j")
            .queue(queue)
            .instances("worker", workers)
            .memory("worker", mem)
            .build();
        JobSpec::from_conf(&conf).unwrap()
    }

    fn empty_view() -> (BTreeMap<String, u32>, BTreeMap<String, u32>, BTreeMap<String, Resource>)
    {
        (BTreeMap::new(), BTreeMap::new(), BTreeMap::new())
    }

    fn queues() -> Vec<String> {
        vec!["default".to_string(), "ml".to_string()]
    }

    #[test]
    fn admits_reasonable_job() {
        let ac = AdmissionController::default();
        let (ua, qa, ur) = empty_view();
        let view = AdmissionView { user_active: &ua, queue_active: &qa, user_resources: &ur };
        let q = ac
            .decide("alice", &spec("ml", 2, "1g"), Resource::new(65536, 64, 0), &queues(), &view)
            .unwrap();
        assert_eq!(q, "ml");
    }

    #[test]
    fn rejects_oversized_job() {
        let ac = AdmissionController::default();
        let (ua, qa, ur) = empty_view();
        let view = AdmissionView { user_active: &ua, queue_active: &qa, user_resources: &ur };
        let err = ac
            .decide("alice", &spec("ml", 64, "8g"), Resource::new(4096, 4, 0), &queues(), &view)
            .unwrap_err();
        assert_eq!(err.code(), "job-too-large");
        assert!(!err.is_retryable());
    }

    #[test]
    fn rejects_unknown_queue_and_maps_users() {
        let mut quotas = QuotaConf::default();
        quotas.user_queues.insert("alice".to_string(), "ml".to_string());
        let ac = AdmissionController::new(quotas);
        let (ua, qa, ur) = empty_view();
        let view = AdmissionView { user_active: &ua, queue_active: &qa, user_resources: &ur };
        let total = Resource::new(65536, 64, 0);

        // Explicit unknown queue: bounced.
        let err =
            ac.decide("bob", &spec("etl", 1, "1g"), total, &queues(), &view).unwrap_err();
        assert_eq!(err, RejectReason::UnknownQueue("etl".to_string()));

        // alice's default-queue jobs land on her mapped queue.
        let q = ac.decide("alice", &spec("default", 1, "1g"), total, &queues(), &view).unwrap();
        assert_eq!(q, "ml");
        // bob has no mapping: stays on default.
        let q = ac.decide("bob", &spec("default", 1, "1g"), total, &queues(), &view).unwrap();
        assert_eq!(q, "default");
    }

    #[test]
    fn enforces_user_and_queue_quotas() {
        let quotas = QuotaConf {
            max_active_per_user: 2,
            max_active_per_queue: Some(3),
            ..QuotaConf::default()
        };
        let ac = AdmissionController::new(quotas);
        let total = Resource::new(65536, 64, 0);
        let mut ua = BTreeMap::new();
        ua.insert("alice".to_string(), 2u32);
        let mut qa = BTreeMap::new();
        qa.insert("ml".to_string(), 3u32);
        let ur = BTreeMap::new();
        let view = AdmissionView { user_active: &ua, queue_active: &qa, user_resources: &ur };

        let err = ac.decide("alice", &spec("default", 1, "1g"), total, &queues(), &view);
        assert_eq!(err.unwrap_err().code(), "user-quota");

        let err = ac.decide("bob", &spec("ml", 1, "1g"), total, &queues(), &view);
        assert_eq!(err.unwrap_err().code(), "queue-quota");
        // Another queue still admits bob.
        assert!(ac.decide("bob", &spec("default", 1, "1g"), total, &queues(), &view).is_ok());
    }

    #[test]
    fn enforces_user_resource_quota() {
        let quotas = QuotaConf {
            max_user_resource: Some(Resource::new(4096, 8, 0)),
            ..QuotaConf::default()
        };
        let ac = AdmissionController::new(quotas);
        let total = Resource::new(65536, 64, 0);
        let ua = BTreeMap::new();
        let qa = BTreeMap::new();
        let mut ur = BTreeMap::new();
        ur.insert("alice".to_string(), Resource::new(3584, 2, 0));
        let view = AdmissionView { user_active: &ua, queue_active: &qa, user_resources: &ur };
        // 1g worker + 512m AM on top of 3.5g held busts the 4g cap.
        let err = ac.decide("alice", &spec("default", 1, "1g"), total, &queues(), &view);
        let err = err.unwrap_err();
        assert_eq!(err.code(), "user-resources");
        assert!(err.is_retryable());
        // A fresh user is fine.
        assert!(ac.decide("bob", &spec("default", 1, "1g"), total, &queues(), &view).is_ok());
    }
}
