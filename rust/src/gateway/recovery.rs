//! Crash recovery: rebuild the gateway control plane from its WAL
//! directory (snapshot + log-chain replay) — `docs/DURABILITY.md`.
//!
//! The replay state machine ([`RecoveredState`]) is deliberately pure
//! (records in, job table out, no I/O beyond [`replay_dir`]) so the
//! property tests in `rust/tests/prop_wal.rs` can drive it directly and
//! check the compaction invariant: *snapshot + tail replay ≡ full-log
//! replay* on arbitrary record sequences.
//!
//! [`Gateway::recover`] then maps the replayed table back onto a live
//! gateway: pending jobs are re-queued in their original priority order,
//! jobs that were RUNNING are re-attached to their application if the RM
//! still knows it (same `ApplicationId`, so no duplicate containers), or
//! relaunched with a fresh restart budget if the RM restarted too, and
//! jobs that terminalized while the gateway was down are finalized from
//! the RM's report.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use anyhow::{anyhow, ensure, Context, Result};

use crate::history::JobRecord;
use crate::json::Json;
use crate::tonyconf::JobSpec;
use crate::util::ids::ApplicationId;
use crate::xmlconf::Configuration;
use crate::yarn::{AppState, Resource, ResourceManager};
use crate::{tinfo, twarn};

use super::wal::{self, WalRecord};
use super::{Gateway, GatewayConf, Job, JobState};

/// One non-terminal job as reconstructed from the log.
#[derive(Debug, Clone, PartialEq)]
pub struct RecoveredJob {
    pub id: u64,
    pub user: String,
    pub name: String,
    pub queue: String,
    pub priority: u8,
    /// A `Started` record was seen (the job had an application).
    pub running: bool,
    pub app_id: Option<String>,
    pub attempts: u32,
    pub kill_requested: bool,
    /// Full job configuration, replayed verbatim into the new table.
    pub conf_xml: String,
}

/// The replay state machine: fold [`WalRecord`]s (oldest first) into the
/// table a restarted gateway boots from.
///
/// Per-record application is **idempotent** — re-applying a record whose
/// effect is already present leaves the state unchanged — because the
/// snapshot epoch rotation intentionally lets a snapshot and the
/// retiring log's tail overlap (see `wal.rs`).  Records for ids the
/// state has never admitted are ignored (`Started`/`KillRequested`) or
/// folded as tombstones (`Terminal`): the submit path acks `Admitted`
/// before a job can produce any other record, so per job the log is
/// always admission-first.
#[derive(Debug, Clone, PartialEq)]
pub struct RecoveredState {
    /// Non-terminal jobs by id.
    pub jobs: BTreeMap<u64, RecoveredJob>,
    /// Terminal tombstones seen during this replay (id → final state).
    /// Transient: snapshots do not persist them — a terminal job needs no
    /// recovery, and id reuse is prevented by `next_id` alone.
    pub completed: BTreeMap<u64, String>,
    /// Strictly above every id ever admitted (acked ids are never reused
    /// across restarts — duplicate-detection in the crash tests relies
    /// on this).
    pub next_id: u64,
}

impl RecoveredState {
    pub fn new() -> RecoveredState {
        RecoveredState { jobs: BTreeMap::new(), completed: BTreeMap::new(), next_id: 1 }
    }

    /// Fold one record into the table.
    pub fn apply(&mut self, rec: &WalRecord) {
        match rec {
            WalRecord::Admitted { id, user, name, queue, priority, conf_xml } => {
                self.next_id = self.next_id.max(id + 1);
                if self.completed.contains_key(id) {
                    return;
                }
                self.jobs.insert(
                    *id,
                    RecoveredJob {
                        id: *id,
                        user: user.clone(),
                        name: name.clone(),
                        queue: queue.clone(),
                        priority: *priority,
                        running: false,
                        app_id: None,
                        attempts: 0,
                        kill_requested: false,
                        conf_xml: conf_xml.clone(),
                    },
                );
            }
            WalRecord::Started { id, app_id, attempt } => {
                if let Some(j) = self.jobs.get_mut(id) {
                    j.running = true;
                    j.app_id = Some(app_id.clone());
                    j.attempts = j.attempts.max(*attempt);
                }
            }
            WalRecord::KillRequested { id } => {
                if let Some(j) = self.jobs.get_mut(id) {
                    j.kill_requested = true;
                }
            }
            WalRecord::Terminal { id, state, .. } => {
                self.next_id = self.next_id.max(id + 1);
                self.jobs.remove(id);
                self.completed.insert(*id, state.clone());
            }
        }
    }

    /// Serialize for the snapshot file (`wal_epoch` and the scheduler
    /// summary are attached by the writer).
    pub fn to_snapshot_json(&self) -> Json {
        let mut jobs = Vec::new();
        for j in self.jobs.values() {
            let mut o = Json::obj();
            o.set("id", j.id);
            o.set("user", j.user.as_str());
            o.set("name", j.name.as_str());
            o.set("queue", j.queue.as_str());
            o.set("priority", j.priority as u64);
            o.set("running", j.running);
            match &j.app_id {
                Some(a) => o.set("app_id", a.as_str()),
                None => o.set("app_id", Json::Null),
            };
            o.set("attempts", j.attempts as u64);
            o.set("kill_requested", j.kill_requested);
            o.set("conf_xml", j.conf_xml.as_str());
            jobs.push(o);
        }
        let mut s = Json::obj();
        s.set("version", 1u64);
        s.set("next_id", self.next_id);
        s.set("jobs", Json::Arr(jobs));
        s
    }

    pub fn from_snapshot_json(j: &Json) -> Result<RecoveredState> {
        let mut st = RecoveredState::new();
        st.next_id = j.get("next_id").and_then(|v| v.as_u64()).unwrap_or(1);
        for item in j.get("jobs").and_then(|v| v.as_arr()).unwrap_or(&[]) {
            let id = item
                .get("id")
                .and_then(|v| v.as_u64())
                .ok_or_else(|| anyhow!("snapshot job missing 'id'"))?;
            let s = |k: &str| item.get(k).and_then(|v| v.as_str()).map(str::to_string);
            st.jobs.insert(
                id,
                RecoveredJob {
                    id,
                    user: s("user").unwrap_or_default(),
                    name: s("name").unwrap_or_default(),
                    queue: s("queue").unwrap_or_default(),
                    priority: item.get("priority").and_then(|v| v.as_u64()).unwrap_or(1) as u8,
                    running: item.get("running").and_then(|v| v.as_bool()).unwrap_or(false),
                    app_id: s("app_id"),
                    attempts: item.get("attempts").and_then(|v| v.as_u64()).unwrap_or(0) as u32,
                    kill_requested: item
                        .get("kill_requested")
                        .and_then(|v| v.as_bool())
                        .unwrap_or(false),
                    conf_xml: s("conf_xml")
                        .ok_or_else(|| anyhow!("snapshot job {id} missing 'conf_xml'"))?,
                },
            );
        }
        Ok(st)
    }
}

/// Everything [`replay_dir`] learned from one WAL directory.
#[derive(Debug, Clone)]
pub struct Replay {
    pub state: RecoveredState,
    /// Epoch the replay started from (the snapshot's, or 0).
    pub base_epoch: u64,
    pub had_snapshot: bool,
    /// Log records applied across the whole chain.
    pub log_records: usize,
    /// False when a torn/corrupt tail was dropped (records past it were
    /// staged but never durable — by the ack invariant, never acked).
    pub clean_tail: bool,
}

/// Replay one WAL directory: published snapshot (if any), then the log
/// chain `wal-<E>.log`, `wal-<E+1>.log`, … — a crash between the epoch
/// bump and the snapshot rename leaves records split across two epochs,
/// which the chain covers.  The chain stops at the first torn tail: any
/// later epoch's records were staged strictly after the torn ones and
/// must not leapfrog them.
pub fn replay_dir(dir: &Path) -> Result<Replay> {
    let snap_path = dir.join("snapshot.json");
    let (mut state, base_epoch, had_snapshot) = match std::fs::read_to_string(&snap_path) {
        Ok(text) => {
            let j = Json::parse(&text)
                .map_err(|e| anyhow!("parsing {}: {e:?}", snap_path.display()))?;
            let epoch = j.get("wal_epoch").and_then(|v| v.as_u64()).unwrap_or(0);
            let state = RecoveredState::from_snapshot_json(&j)
                .with_context(|| format!("loading {}", snap_path.display()))?;
            (state, epoch, true)
        }
        Err(_) => (RecoveredState::new(), 0, false),
    };
    let mut clean_tail = true;
    let mut log_records = 0usize;
    let mut epoch = base_epoch;
    loop {
        let bytes = match std::fs::read(wal::log_path(dir, epoch)) {
            Ok(b) => b,
            Err(_) => break,
        };
        let (recs, clean) = wal::decode_stream(&bytes);
        for r in &recs {
            state.apply(r);
        }
        log_records += recs.len();
        if !clean {
            clean_tail = false;
            break;
        }
        epoch += 1;
    }
    Ok(Replay { state, base_epoch, had_snapshot, log_records, clean_tail })
}

/// What `restore` decided to do with each replayed job; executed by
/// `apply_restore_plan` once the recovery snapshot is durable.
pub(super) struct RestorePlan {
    /// `(priority, id)` — re-queued in original priority order.
    readmit: Vec<(u8, u64)>,
    /// Jobs re-attached to a still-live application: a monitor thread
    /// per job waits for completion exactly like a worker would.
    reattach: Vec<(u64, ApplicationId, bool)>,
    /// Jobs that terminalized (or became unrunnable) while we were down.
    finish: Vec<FinishPlan>,
}

struct FinishPlan {
    id: u64,
    state: JobState,
    detail: String,
    ident: (String, String, String),
    /// RM-reported app id when the job actually ran (history record key);
    /// `None` for jobs that never produced a report.
    app_id: Option<String>,
    attempts: u32,
}

impl Gateway {
    /// Rebuild a gateway from its WAL directory: replay snapshot + log
    /// chain, then boot with the replayed table.  Pending jobs re-enter
    /// the queue in original priority order; RUNNING jobs re-attach to
    /// their application when the RM still reports it (same
    /// `ApplicationId` — no duplicate containers) and are otherwise
    /// relaunched with a fresh restart budget.  The first act of the
    /// recovered gateway is publishing a fresh snapshot, so a torn log
    /// tail from the crash is rotated away before any new append.
    ///
    /// Gateway stats (accepted/finished/…) restart from zero: they are
    /// process-lifetime counters, not durable state.  Relaunching is
    /// at-least-once execution — a job whose application died with the
    /// process runs again from its last checkpoint.
    pub fn recover(rm: Arc<ResourceManager>, conf: GatewayConf) -> Result<Arc<Gateway>> {
        ensure!(conf.wal.enable, "Gateway::recover requires the WAL (tony.wal.enable=true)");
        let replay = replay_dir(&conf.wal.dir)
            .with_context(|| format!("replaying WAL dir {}", conf.wal.dir.display()))?;
        tinfo!(
            "gateway",
            "recovering from {}: {} live job(s), {} tombstone(s), {} log record(s), snapshot={}, clean_tail={}",
            conf.wal.dir.display(),
            replay.state.jobs.len(),
            replay.state.completed.len(),
            replay.log_records,
            replay.had_snapshot,
            replay.clean_tail
        );
        Self::boot(rm, conf, Some(replay))
    }

    /// Map the replayed table into the live job table (single lock pass)
    /// and decide each job's disposition.  No WAL writes happen here —
    /// the caller publishes the recovery snapshot first, then executes
    /// the returned plan.
    pub(super) fn restore(&self, rep: &Replay) -> RestorePlan {
        let mut plan =
            RestorePlan { readmit: Vec::new(), reattach: Vec::new(), finish: Vec::new() };
        // Pre-lock pass: parse confs and query the RM per job.
        let mut inserts: Vec<(Job, Option<Disposition>)> = Vec::new();
        enum Disposition {
            Readmit,
            Reattach(ApplicationId, bool),
            Finish(JobState, String, Option<String>),
        }
        for rec in rep.state.jobs.values() {
            let ident = (rec.user.clone(), rec.name.clone(), rec.queue.clone());
            let (conf, needed) = match Configuration::from_xml_str(&rec.conf_xml)
                .and_then(|c| JobSpec::from_conf(&c).map(|s| (c, s)))
            {
                Ok((c, spec)) => (c, spec.total_task_resources() + spec.am_resource),
                Err(e) => {
                    twarn!("gateway", "recovered job {} has unusable conf: {e:#}", rec.id);
                    plan.finish.push(FinishPlan {
                        id: rec.id,
                        state: JobState::Failed,
                        detail: format!("recovery: unusable job conf: {e:#}"),
                        ident,
                        app_id: None,
                        attempts: rec.attempts,
                    });
                    continue;
                }
            };
            let mut job = Job {
                id: rec.id,
                user: rec.user.clone(),
                name: rec.name.clone(),
                queue: rec.queue.clone(),
                priority: rec.priority,
                state: JobState::Pending,
                detail: String::new(),
                app_id: None,
                attempts: rec.attempts,
                wall_ms: 0,
                resources: needed,
                kill_requested: rec.kill_requested,
                conf,
                // Observability handles are process-local and do not
                // survive the restart: a re-attached job serves history
                // series/trace once it completes, like any finished job.
                live: None,
                trace: None,
            };
            let app = rec.app_id.as_deref().and_then(ApplicationId::parse);
            let disposition = if !rec.running {
                if rec.kill_requested {
                    Disposition::Finish(
                        JobState::Killed,
                        "killed while queued (recovered)".to_string(),
                        None,
                    )
                } else {
                    job.detail = "recovered: re-admitted".to_string();
                    Disposition::Readmit
                }
            } else {
                match app.and_then(|a| self.rm.app_report(a).map(|r| (a, r))) {
                    Some((a, report)) if !report.state.is_terminal() => {
                        job.state = JobState::Running;
                        job.app_id = Some(a);
                        job.detail = format!("recovered: re-attached to {a}");
                        Disposition::Reattach(a, rec.kill_requested)
                    }
                    Some((a, report)) => {
                        // Terminalized while we were down: fold the RM's
                        // verdict in (insert as Running so finalize runs
                        // the normal quota/stats release).
                        job.state = JobState::Running;
                        job.app_id = Some(a);
                        let state = match report.state {
                            AppState::Finished => JobState::Finished,
                            AppState::Killed => JobState::Killed,
                            _ => JobState::Failed,
                        };
                        Disposition::Finish(state, report.diagnostics, Some(a.to_string()))
                    }
                    None => {
                        if rec.kill_requested {
                            Disposition::Finish(
                                JobState::Killed,
                                "kill honored across restart".to_string(),
                                None,
                            )
                        } else {
                            // The RM restarted too (or the app predates
                            // it): relaunch through the normal worker
                            // path with a fresh restart budget.
                            job.app_id = None;
                            job.detail = "recovered: relaunching (application lost)".to_string();
                            Disposition::Readmit
                        }
                    }
                }
            };
            inserts.push((job, Some(disposition)));
        }
        {
            let mut inner = self.inner.lock().unwrap();
            inner.next_id = inner.next_id.max(rep.state.next_id);
            for (job, disposition) in inserts {
                let (id, prio) = (job.id, job.priority);
                *inner.user_active.entry(job.user.clone()).or_insert(0) += 1;
                *inner.queue_active.entry(job.queue.clone()).or_insert(0) += 1;
                let held =
                    inner.user_resources.entry(job.user.clone()).or_insert(Resource::ZERO);
                *held += job.resources;
                inner.jobs.insert(id, job);
                match disposition {
                    Some(Disposition::Readmit) => plan.readmit.push((prio, id)),
                    Some(Disposition::Reattach(a, kill)) => plan.reattach.push((id, a, kill)),
                    Some(Disposition::Finish(state, detail, app_id)) => {
                        let j = &inner.jobs[&id];
                        plan.finish.push(FinishPlan {
                            id,
                            state,
                            detail,
                            ident: (j.user.clone(), j.name.clone(), j.queue.clone()),
                            app_id,
                            attempts: j.attempts,
                        });
                    }
                    None => {}
                }
            }
        }
        // Original admission order within a priority class: job ids are
        // monotonic, so (priority desc, id asc) is the original order.
        plan.readmit.sort_by_key(|&(prio, id)| (std::cmp::Reverse(prio), id));
        plan
    }

    /// Execute the restore plan (after the recovery snapshot is durable):
    /// finalize dead jobs, start re-attach monitors, re-queue the rest.
    pub(super) fn apply_restore_plan(self: &Arc<Gateway>, plan: RestorePlan) {
        for f in plan.finish {
            match &f.app_id {
                Some(app) => {
                    let _ = self.history.record(&JobRecord {
                        app_id: app.clone(),
                        name: f.ident.1.clone(),
                        queue: f.ident.2.clone(),
                        succeeded: f.state == JobState::Finished,
                        attempts: f.attempts,
                        wall_ms: 0,
                        diagnostics: format!("[user {}] {}", f.ident.0, f.detail),
                        tasks: Vec::new(),
                        series: Json::obj(),
                        trace: Json::obj(),
                    });
                }
                None => self.record_unran(f.id, f.ident.clone(), f.attempts, 0, &f.detail),
            }
            self.finalize(f.id, f.state, &f.detail, 0);
        }
        let mut monitors = Vec::new();
        for (id, app, kill) in plan.reattach {
            tinfo!("gateway", "job {id} re-attached to {app}");
            let g = self.clone();
            match std::thread::Builder::new()
                .name(format!("gw-reattach-{id}"))
                .spawn(move || g.reattach_loop(id, app))
            {
                Ok(h) => monitors.push(h),
                Err(e) => {
                    twarn!("gateway", "cannot spawn re-attach monitor for job {id}: {e}");
                    self.finalize(id, JobState::Failed, "recovery: monitor spawn failed", 0);
                    continue;
                }
            }
            if kill {
                // The user killed it before the crash; honor that now.
                self.rm.kill_application(app);
            }
        }
        if !monitors.is_empty() {
            self.workers.lock().unwrap().extend(monitors);
        }
        for (prio, id) in plan.readmit {
            if let Err(e) = self.queue.try_push(prio, id) {
                twarn!("gateway", "re-admission of job {id} failed: {e}");
                self.finalize(id, JobState::Failed, &format!("recovery re-admission failed: {e}"), 0);
            }
        }
    }

    /// Monitor one re-attached application to completion — the recovery
    /// analogue of the tail of `run_job` (no retry loop: the restart
    /// budget belongs to freshly launched attempts).
    fn reattach_loop(self: Arc<Gateway>, id: u64, app: ApplicationId) {
        let (state, detail) = match self.rm.wait_for_completion(app, self.conf.job_timeout) {
            Ok(report) => {
                let state = match report.state {
                    AppState::Finished => JobState::Finished,
                    AppState::Killed => JobState::Killed,
                    _ => JobState::Failed,
                };
                (state, report.diagnostics)
            }
            Err(e) => {
                if self.halted.load(Ordering::SeqCst) {
                    return;
                }
                self.rm.kill_application(app);
                (JobState::Failed, format!("timed out after re-attach: {e:#}"))
            }
        };
        if self.halted.load(Ordering::SeqCst) {
            return;
        }
        let ident = {
            let inner = self.inner.lock().unwrap();
            inner
                .jobs
                .get(&id)
                .map(|j| (j.user.clone(), j.name.clone(), j.queue.clone(), j.attempts))
        };
        if let Some((user, name, queue, attempts)) = ident {
            let _ = self.history.record(&JobRecord {
                app_id: app.to_string(),
                name,
                queue,
                succeeded: state == JobState::Finished,
                attempts,
                wall_ms: 0,
                diagnostics: format!("[user {user}] {detail}"),
                tasks: Vec::new(),
                series: Json::obj(),
                trace: Json::obj(),
            });
        }
        self.finalize(id, state, &detail, 0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn admitted(id: u64, prio: u8) -> WalRecord {
        WalRecord::Admitted {
            id,
            user: "u".into(),
            name: format!("j{id}"),
            queue: "default".into(),
            priority: prio,
            conf_xml: "<configuration></configuration>".into(),
        }
    }

    #[test]
    fn replay_folds_lifecycle_records() {
        let mut st = RecoveredState::new();
        st.apply(&admitted(1, 3));
        st.apply(&admitted(2, 1));
        st.apply(&WalRecord::Started { id: 1, app_id: "application_9_0001".into(), attempt: 1 });
        st.apply(&WalRecord::Terminal {
            id: 2,
            state: "FINISHED".into(),
            detail: String::new(),
            wall_ms: 4,
        });
        assert_eq!(st.next_id, 3);
        assert_eq!(st.jobs.len(), 1);
        let j = &st.jobs[&1];
        assert!(j.running);
        assert_eq!(j.app_id.as_deref(), Some("application_9_0001"));
        assert_eq!(st.completed.get(&2).map(String::as_str), Some("FINISHED"));
        // Idempotent reapplication (snapshot/tail overlap).
        let before = st.clone();
        st.apply(&admitted(1, 3));
        st.apply(&WalRecord::Started { id: 1, app_id: "application_9_0001".into(), attempt: 1 });
        assert_eq!(st, before);
    }

    #[test]
    fn records_for_unknown_ids_are_tolerated() {
        let mut st = RecoveredState::new();
        st.apply(&WalRecord::Started { id: 9, app_id: "application_1_0001".into(), attempt: 1 });
        st.apply(&WalRecord::KillRequested { id: 9 });
        assert!(st.jobs.is_empty());
        // A terminal tombstone suppresses a (stale) re-admission replay.
        st.apply(&WalRecord::Terminal {
            id: 4,
            state: "KILLED".into(),
            detail: String::new(),
            wall_ms: 0,
        });
        st.apply(&admitted(4, 1));
        assert!(st.jobs.is_empty());
        assert_eq!(st.next_id, 10);
    }

    #[test]
    fn snapshot_json_round_trip() {
        let mut st = RecoveredState::new();
        st.apply(&admitted(1, 3));
        st.apply(&WalRecord::Started { id: 1, app_id: "application_7_0002".into(), attempt: 2 });
        st.apply(&WalRecord::KillRequested { id: 1 });
        st.apply(&admitted(5, 1));
        let back = RecoveredState::from_snapshot_json(&st.to_snapshot_json()).unwrap();
        assert_eq!(back.jobs, st.jobs);
        assert_eq!(back.next_id, st.next_id);
    }

    #[test]
    fn replay_dir_without_snapshot_or_logs_is_empty() {
        let dir = std::env::temp_dir().join(format!(
            "tony-recovery-empty-{}-{}",
            std::process::id(),
            crate::util::ids::next_seq()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let rep = replay_dir(&dir).unwrap();
        assert!(rep.state.jobs.is_empty());
        assert!(!rep.had_snapshot);
        assert!(rep.clean_tail);
        assert_eq!(rep.state.next_id, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
