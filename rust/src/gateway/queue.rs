//! The gateway's pending-job queue: bounded (backpressure, not OOM),
//! priority-ordered, FIFO within a priority level, with kill-from-queue
//! support and a close signal that wakes every waiting worker.
//!
//! Pure data structure + condvar; no knowledge of jobs beyond their id,
//! so it is directly unit-testable.

use std::cmp::Reverse;
use std::collections::BTreeMap;
use std::fmt;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Why a push was refused.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PushError {
    /// The queue is at capacity: the caller should surface backpressure
    /// (HTTP 429) instead of buffering unboundedly.
    Full { capacity: usize },
    /// The gateway is shutting down.
    Closed,
}

impl fmt::Display for PushError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PushError::Full { capacity } => {
                write!(f, "pending queue full ({capacity} jobs); retry later")
            }
            PushError::Closed => write!(f, "gateway is shutting down"),
        }
    }
}

struct Inner {
    /// Keyed by (Reverse(priority), seq): iteration order is highest
    /// priority first, then submission order (fair FIFO within priority).
    entries: BTreeMap<(Reverse<u8>, u64), u64>,
    next_seq: u64,
    closed: bool,
}

pub struct PendingQueue {
    inner: Mutex<Inner>,
    cv: Condvar,
    capacity: usize,
}

impl PendingQueue {
    pub fn new(capacity: usize) -> PendingQueue {
        PendingQueue {
            inner: Mutex::new(Inner {
                entries: BTreeMap::new(),
                next_seq: 0,
                closed: false,
            }),
            cv: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enqueue a job id at `priority` (higher pops first).  Fails fast
    /// when full or closed — admission turns that into a reject.
    pub fn try_push(&self, priority: u8, job: u64) -> Result<(), PushError> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return Err(PushError::Closed);
        }
        if inner.entries.len() >= self.capacity {
            return Err(PushError::Full { capacity: self.capacity });
        }
        let seq = inner.next_seq;
        inner.next_seq += 1;
        inner.entries.insert((Reverse(priority), seq), job);
        drop(inner);
        self.cv.notify_one();
        Ok(())
    }

    /// Pop the highest-priority, oldest job, waiting up to `timeout`.
    /// Returns None on timeout or when the queue is closed and drained.
    pub fn pop_timeout(&self, timeout: Duration) -> Option<u64> {
        let deadline = std::time::Instant::now() + timeout;
        let mut inner = self.inner.lock().unwrap();
        loop {
            let head = inner.entries.keys().next().copied();
            if let Some(key) = head {
                return inner.entries.remove(&key);
            }
            if inner.closed {
                return None;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            // lint:allow(blocking-under-lock, reason = "Condvar::wait_timeout atomically releases the queue guard while parked")
            let (guard, _res) = self.cv.wait_timeout(inner, deadline - now).unwrap();
            inner = guard;
        }
    }

    /// Pop the highest-priority, oldest job, blocking indefinitely on the
    /// queue's condvar — zero idle CPU, woken by push or close.  Returns
    /// `None` only once the queue is closed *and* drained, so a worker
    /// loop `while let Some(id) = q.pop_wait()` serves until shutdown and
    /// still finishes everything accepted before the close.
    pub fn pop_wait(&self) -> Option<u64> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            let head = inner.entries.keys().next().copied();
            if let Some(key) = head {
                return inner.entries.remove(&key);
            }
            if inner.closed {
                return None;
            }
            // lint:allow(blocking-under-lock, reason = "Condvar::wait atomically releases the queue guard while parked")
            inner = self.cv.wait(inner).unwrap();
        }
    }

    /// Remove a specific pending job (kill-before-run).  Returns whether
    /// it was still queued.
    pub fn remove(&self, job: u64) -> bool {
        let mut inner = self.inner.lock().unwrap();
        let key = inner
            .entries
            .iter()
            .find(|(_, j)| **j == job)
            .map(|(k, _)| *k);
        match key {
            Some(k) => {
                inner.entries.remove(&k);
                true
            }
            None => false,
        }
    }

    /// Stop accepting pushes and wake all waiting poppers; once drained,
    /// every `pop_timeout` returns None immediately.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn priority_major_fifo_minor() {
        let q = PendingQueue::new(16);
        q.try_push(1, 10).unwrap();
        q.try_push(5, 20).unwrap();
        q.try_push(5, 21).unwrap();
        q.try_push(3, 30).unwrap();
        let order: Vec<u64> =
            (0..4).filter_map(|_| q.pop_timeout(Duration::from_millis(1))).collect();
        assert_eq!(order, vec![20, 21, 30, 10]);
        assert!(q.pop_timeout(Duration::from_millis(1)).is_none());
    }

    #[test]
    fn bounded_with_backpressure() {
        let q = PendingQueue::new(2);
        q.try_push(1, 1).unwrap();
        q.try_push(1, 2).unwrap();
        assert_eq!(q.try_push(1, 3), Err(PushError::Full { capacity: 2 }));
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), Some(1));
        q.try_push(1, 3).unwrap();
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn remove_only_hits_queued_jobs() {
        let q = PendingQueue::new(4);
        q.try_push(2, 7).unwrap();
        assert!(q.remove(7));
        assert!(!q.remove(7));
        assert!(q.pop_timeout(Duration::from_millis(1)).is_none());
    }

    #[test]
    fn close_wakes_blocked_poppers_and_rejects_pushes() {
        let q = Arc::new(PendingQueue::new(4));
        let q2 = q.clone();
        let t = std::thread::spawn(move || q2.pop_timeout(Duration::from_secs(30)));
        crate::util::clock::real_sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(t.join().unwrap(), None);
        assert_eq!(q.try_push(1, 1), Err(PushError::Closed));
    }

    #[test]
    fn close_still_drains_queued_work() {
        let q = PendingQueue::new(4);
        q.try_push(1, 9).unwrap();
        q.close();
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), Some(9));
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), None);
    }

    #[test]
    fn pop_wait_blocks_until_push_and_drains_through_close() {
        let q = Arc::new(PendingQueue::new(4));
        let q2 = q.clone();
        let t = std::thread::spawn(move || (q2.pop_wait(), q2.pop_wait(), q2.pop_wait()));
        q.try_push(1, 1).unwrap();
        q.try_push(1, 2).unwrap();
        q.close();
        // The waiter gets both queued jobs, then None once drained+closed.
        assert_eq!(t.join().unwrap(), (Some(1), Some(2), None));
        assert_eq!(q.pop_wait(), None, "closed+empty returns immediately");
    }
}
