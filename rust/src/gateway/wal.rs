//! Control-plane write-ahead log: the durability layer under the
//! gateway's job table (`docs/DURABILITY.md`).
//!
//! Every state transition that must survive a gateway crash — admission,
//! job start, kill requests, terminal outcomes — is appended here as a
//! length-prefixed, checksummed record *before* the transition is acked
//! to the caller.  A periodic snapshot (built from the live job table)
//! compacts the log: the snapshot is published with the same
//! fsync + atomic-rename discipline [`crate::history::HistoryStore`]
//! uses for job records, and each snapshot starts a new log *epoch*
//! (`wal-<N>.log`) so replay is always "one snapshot + its log tail".
//!
//! Layout of one record frame:
//!
//! ```text
//!   [u32 LE payload length][u64 LE FNV-1a of payload][payload JSON]
//! ```
//!
//! Replay ([`super::recovery`]) stops cleanly at the first frame whose
//! length or checksum does not verify — a torn tail (crash mid-write)
//! loses only records that were never acked, never earlier ones.
//!
//! Writer architecture (group commit): appenders stage encoded frames
//! into an in-memory buffer and wait on a condvar until the dedicated
//! flusher thread — the only thread that touches the file — has written
//! and fsynced past their record.  Concurrent submitters therefore share
//! one fsync per wave instead of paying one each, and no file I/O ever
//! happens under a lock.
//!
//! Deterministic crash-point injection (`tony.chaos.crash-point`, see
//! [`crate::chaos::CrashSite`]) panics the process at named sites in
//! this file's append/snapshot paths; `rust/tests/crash_recovery.rs`
//! drives every site and asserts the ack invariant.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, Context, Result};

use crate::chaos::{CrashSite, CRASH_PANIC};
use crate::json::Json;
use crate::xmlconf::Configuration;
use crate::{tinfo, twarn};

/// First bytes of every log file; a file without it is treated as torn.
pub const MAGIC: &[u8; 8] = b"TONYWAL1";

/// WAL configuration (`tony.wal.*`, see docs/CONFIGURATION.md).
#[derive(Debug, Clone)]
pub struct WalConf {
    /// Master switch; off by default (benches compare both sides).
    pub enable: bool,
    /// Directory owned by exactly one gateway: snapshot + epoch logs.
    pub dir: PathBuf,
    /// Records appended since the last snapshot before a new snapshot
    /// compacts the log (0 disables count-triggered snapshots).
    pub snapshot_every: u64,
    /// When true (default), an append is acked only after fsync; when
    /// false, after staging (crash may lose the unsynced tail).
    pub fsync: bool,
}

impl WalConf {
    pub fn disabled() -> WalConf {
        WalConf {
            enable: false,
            dir: std::env::temp_dir().join("tony-wal"),
            snapshot_every: 256,
            fsync: true,
        }
    }

    /// Read the `tony.wal.*` keys from a site configuration.
    pub fn from_conf(conf: &Configuration) -> WalConf {
        let mut w = WalConf::disabled();
        w.enable = conf.get_bool("tony.wal.enable", w.enable);
        if let Some(dir) = conf.get("tony.wal.dir") {
            w.dir = PathBuf::from(dir);
        }
        w.snapshot_every = conf.get_u64("tony.wal.snapshot-every", w.snapshot_every);
        w.fsync = conf.get_bool("tony.wal.fsync", w.fsync);
        w
    }
}

impl Default for WalConf {
    fn default() -> WalConf {
        WalConf::disabled()
    }
}

/// One durable control-plane state transition.  Per job, records are
/// appended in lifecycle order (`Admitted` is acked before the job can
/// produce any other record), so replay never sees a job's later records
/// before its admission.
#[derive(Debug, Clone, PartialEq)]
pub enum WalRecord {
    /// The job passed admission; written (and synced) before the submit
    /// call returns — the ack point of the durability invariant.
    Admitted {
        id: u64,
        user: String,
        name: String,
        queue: String,
        priority: u8,
        /// Full job configuration (`Configuration::to_xml`) so recovery
        /// can re-admit or relaunch without any other source of truth.
        conf_xml: String,
    },
    /// A worker submitted the application to the RM.
    Started { id: u64, app_id: String, attempt: u32 },
    /// A kill was accepted for a running job (recovery must not
    /// resurrect a job the user already killed).
    KillRequested { id: u64 },
    /// The job reached a terminal state; replay drops it from the table.
    Terminal { id: u64, state: String, detail: String, wall_ms: u64 },
}

impl WalRecord {
    pub fn job_id(&self) -> u64 {
        match self {
            WalRecord::Admitted { id, .. }
            | WalRecord::Started { id, .. }
            | WalRecord::KillRequested { id }
            | WalRecord::Terminal { id, .. } => *id,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        match self {
            WalRecord::Admitted { id, user, name, queue, priority, conf_xml } => {
                j.set("type", "admitted");
                j.set("id", *id);
                j.set("user", user.as_str());
                j.set("name", name.as_str());
                j.set("queue", queue.as_str());
                j.set("priority", *priority as u64);
                j.set("conf_xml", conf_xml.as_str());
            }
            WalRecord::Started { id, app_id, attempt } => {
                j.set("type", "started");
                j.set("id", *id);
                j.set("app_id", app_id.as_str());
                j.set("attempt", *attempt as u64);
            }
            WalRecord::KillRequested { id } => {
                j.set("type", "kill-requested");
                j.set("id", *id);
            }
            WalRecord::Terminal { id, state, detail, wall_ms } => {
                j.set("type", "terminal");
                j.set("id", *id);
                j.set("state", state.as_str());
                j.set("detail", detail.as_str());
                j.set("wall_ms", *wall_ms);
            }
        }
        j
    }

    pub fn from_json(j: &Json) -> Result<WalRecord> {
        let ty = j
            .get("type")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("wal record missing 'type'"))?;
        let id = j
            .get("id")
            .and_then(|v| v.as_u64())
            .ok_or_else(|| anyhow!("wal record missing 'id'"))?;
        let s = |k: &str| j.get(k).and_then(|v| v.as_str()).map(str::to_string);
        Ok(match ty {
            "admitted" => WalRecord::Admitted {
                id,
                user: s("user").ok_or_else(|| anyhow!("admitted record missing 'user'"))?,
                name: s("name").unwrap_or_default(),
                queue: s("queue").unwrap_or_default(),
                priority: j.get("priority").and_then(|v| v.as_u64()).unwrap_or(1) as u8,
                conf_xml: s("conf_xml")
                    .ok_or_else(|| anyhow!("admitted record missing 'conf_xml'"))?,
            },
            "started" => WalRecord::Started {
                id,
                app_id: s("app_id").unwrap_or_default(),
                attempt: j.get("attempt").and_then(|v| v.as_u64()).unwrap_or(1) as u32,
            },
            "kill-requested" => WalRecord::KillRequested { id },
            "terminal" => WalRecord::Terminal {
                id,
                state: s("state").unwrap_or_else(|| "FAILED".to_string()),
                detail: s("detail").unwrap_or_default(),
                wall_ms: j.get("wall_ms").and_then(|v| v.as_u64()).unwrap_or(0),
            },
            other => return Err(anyhow!("unknown wal record type '{other}'")),
        })
    }

    /// One on-disk frame: length + checksum + JSON payload.
    pub fn encode(&self) -> Vec<u8> {
        frame(self.to_json().render().as_bytes())
    }
}

/// 64-bit FNV-1a — hand-rolled because the offline build has no checksum
/// crate; collision resistance is irrelevant here (we detect *torn*
/// writes, not adversaries).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Frame one payload: `[u32 len][u64 fnv1a][payload]`.
pub fn frame(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(12 + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&fnv1a64(payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Decode every intact record from one log file's bytes, stopping cleanly
/// at the first frame that fails the length, checksum, or parse check.
/// Returns `(records, clean)`: `clean == false` means a torn/corrupt tail
/// was dropped.  Never panics on arbitrary input — the property tests
/// (`rust/tests/prop_wal.rs`) fuzz this directly.
pub fn decode_stream(bytes: &[u8]) -> (Vec<WalRecord>, bool) {
    let mut recs = Vec::new();
    if bytes.is_empty() {
        // A log created but never written past creation (or not yet
        // magic-stamped) holds no records and nothing was lost.
        return (recs, true);
    }
    if bytes.len() < MAGIC.len() || &bytes[..MAGIC.len()] != MAGIC {
        return (recs, false);
    }
    let mut i = MAGIC.len();
    let mut clean = true;
    while i < bytes.len() {
        if i + 12 > bytes.len() {
            clean = false;
            break;
        }
        let len = u32::from_le_bytes([bytes[i], bytes[i + 1], bytes[i + 2], bytes[i + 3]]) as usize;
        let mut sum = [0u8; 8];
        sum.copy_from_slice(&bytes[i + 4..i + 12]);
        let sum = u64::from_le_bytes(sum);
        let start = i + 12;
        let end = match start.checked_add(len) {
            Some(e) if e <= bytes.len() => e,
            _ => {
                clean = false;
                break;
            }
        };
        let payload = &bytes[start..end];
        if fnv1a64(payload) != sum {
            clean = false;
            break;
        }
        let rec = std::str::from_utf8(payload)
            .ok()
            .and_then(|s| Json::parse(s).ok())
            .and_then(|j| WalRecord::from_json(&j).ok());
        match rec {
            Some(r) => recs.push(r),
            None => {
                clean = false;
                break;
            }
        }
        i = end;
    }
    (recs, clean)
}

/// Path of the log file for one epoch.
pub fn log_path(dir: &Path, epoch: u64) -> PathBuf {
    dir.join(format!("wal-{epoch}.log"))
}

fn parse_log_epoch(name: &str) -> Option<u64> {
    name.strip_prefix("wal-")?.strip_suffix(".log")?.parse().ok()
}

/// Remove crash-orphaned temp files.  Unlike the history store's
/// age-gated sweep (its directory is shared by concurrent writers), the
/// WAL directory is owned by exactly one gateway, so any temp file found
/// at open is by definition an orphan.
fn sweep_tmp(dir: &Path) -> usize {
    let Ok(entries) = std::fs::read_dir(dir) else { return 0 };
    let mut removed = 0;
    for ent in entries.flatten() {
        let name = ent.file_name().to_string_lossy().into_owned();
        if name.starts_with('.') && name.ends_with(".tmp") && std::fs::remove_file(ent.path()).is_ok()
        {
            removed += 1;
        }
    }
    removed
}

/// Retire every log epoch below `keep_from` (their records are covered by
/// the published snapshot).
fn sweep_old_logs(dir: &Path, keep_from: u64) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for ent in entries.flatten() {
        let name = ent.file_name().to_string_lossy().into_owned();
        if let Some(epoch) = parse_log_epoch(&name) {
            if epoch < keep_from {
                let _ = std::fs::remove_file(ent.path());
            }
        }
    }
}

/// Highest epoch with a log file on disk, if any.
fn max_log_epoch(dir: &Path) -> Option<u64> {
    let entries = std::fs::read_dir(dir).ok()?;
    entries
        .flatten()
        .filter_map(|e| parse_log_epoch(&e.file_name().to_string_lossy()))
        .max()
}

struct WalState {
    /// Encoded frames staged but not yet handed to the flusher.
    buf: Vec<u8>,
    /// Sequence of the last staged record.
    staged: u64,
    /// Sequence the flusher has durably written through.
    synced: u64,
    since_snapshot: u64,
    epoch: u64,
    snapshotting: bool,
    /// The writer is permanently down (flush error or simulated crash);
    /// appenders fail fast instead of waiting forever.
    crashed: bool,
    closed: bool,
}

/// The gateway's write-ahead log writer.  See the module docs for the
/// record framing, epoch lifecycle, and group-commit design.
pub struct Wal {
    dir: PathBuf,
    conf: WalConf,
    /// Whether `open` found a snapshot or any log on disk.  A boot over
    /// pre-existing state writes a clean-slate snapshot to rotate past
    /// whatever tail the previous incarnation left; a boot over an empty
    /// directory skips it (there is nothing to rotate past).
    existing: bool,
    state: Mutex<WalState>,
    cv: Condvar,
    flusher: Mutex<Option<std::thread::JoinHandle<()>>>,
    /// Shared with the owning gateway: once flipped (simulated crash),
    /// nothing may be written — a dead process writes no bytes.
    halted: Arc<AtomicBool>,
    crash_point: Option<CrashSite>,
}

impl Wal {
    /// Open (or create) the WAL directory and start the flusher thread.
    /// Sweeps temp-file orphans unconditionally and retires log epochs
    /// already covered by the published snapshot.  Appends resume on the
    /// highest epoch present so a pre-existing tail is never overwritten;
    /// the gateway writes a fresh snapshot at boot, which rotates past
    /// any torn tail before the first new append.
    pub fn open(
        conf: WalConf,
        halted: Arc<AtomicBool>,
        crash_point: Option<CrashSite>,
    ) -> Result<Arc<Wal>> {
        std::fs::create_dir_all(&conf.dir)
            .with_context(|| format!("creating wal dir {}", conf.dir.display()))?;
        let removed = sweep_tmp(&conf.dir);
        if removed > 0 {
            tinfo!("wal", "swept {removed} orphaned temp file(s) from {}", conf.dir.display());
        }
        let snap_text = std::fs::read_to_string(conf.dir.join("snapshot.json")).ok();
        let snap_epoch = snap_text
            .as_deref()
            .and_then(|text| Json::parse(text).ok())
            .and_then(|j| j.get("wal_epoch").and_then(|v| v.as_u64()))
            .unwrap_or(0);
        sweep_old_logs(&conf.dir, snap_epoch);
        let max_log = max_log_epoch(&conf.dir);
        let existing = snap_text.is_some() || max_log.is_some();
        let epoch = max_log.unwrap_or(0).max(snap_epoch);
        let dir = conf.dir.clone();
        let wal = Arc::new(Wal {
            dir,
            conf,
            existing,
            state: Mutex::new(WalState {
                buf: Vec::new(),
                staged: 0,
                synced: 0,
                since_snapshot: 0,
                epoch,
                snapshotting: false,
                crashed: false,
                closed: false,
            }),
            cv: Condvar::new(),
            flusher: Mutex::new(None),
            halted,
            crash_point,
        });
        let w = wal.clone();
        let handle = std::thread::Builder::new()
            .name("gw-wal".into())
            .spawn(move || w.flusher_loop())
            .context("spawning wal flusher")?;
        *wal.flusher.lock().unwrap() = Some(handle);
        Ok(wal)
    }

    /// Poison-tolerant lock: injected crash points panic on purpose (with
    /// no WAL lock held), but a defensive writer beats a poisoned-lock
    /// cascade in every other unexpected-panic case.
    fn lock_state(&self) -> std::sync::MutexGuard<'_, WalState> {
        self.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    pub fn epoch(&self) -> u64 {
        self.lock_state().epoch
    }

    /// Whether `open` found a snapshot or log files from a previous
    /// incarnation in the directory.
    pub fn had_existing_state(&self) -> bool {
        self.existing
    }

    pub fn records_since_snapshot(&self) -> u64 {
        self.lock_state().since_snapshot
    }

    /// Whether enough records accumulated for a count-triggered snapshot.
    pub fn snapshot_due(&self) -> bool {
        let st = self.lock_state();
        !st.snapshotting && self.conf.snapshot_every > 0 && st.since_snapshot >= self.conf.snapshot_every
    }

    /// Append one record.  With `fsync` on, returns only once the record
    /// is durably on disk (group commit: concurrent appenders share the
    /// flusher's fsync).  Errors when the writer is down — the caller
    /// must then fail the transition instead of acking it.
    pub fn append(&self, rec: &WalRecord) -> Result<()> {
        if self.halted.load(Ordering::SeqCst) {
            return Err(anyhow!("wal halted (simulated dead process)"));
        }
        let bytes = rec.encode();
        if let Some(site @ (CrashSite::WalBeforeFsync | CrashSite::WalAfterFsync)) =
            self.crash_point
        {
            self.crash_append(&bytes, site);
        }
        let mut st = self.lock_state();
        if st.crashed || st.closed {
            return Err(anyhow!("wal writer is down"));
        }
        st.buf.extend_from_slice(&bytes);
        st.staged += 1;
        st.since_snapshot += 1;
        let mine = st.staged;
        self.cv.notify_all();
        if self.conf.fsync {
            while st.synced < mine {
                if st.crashed {
                    return Err(anyhow!("wal writer died before the record was durable"));
                }
                // lint:allow(blocking-under-lock, reason = "Condvar::wait atomically releases the WAL staging guard while parked (group-commit durability ack)")
                st = self.cv.wait(st).unwrap_or_else(std::sync::PoisonError::into_inner);
            }
        }
        Ok(())
    }

    /// Publish a snapshot built by `build` and start a new log epoch.
    /// The epoch is bumped *before* the content is captured, so every
    /// record flushed to the retiring log has its effect inside the
    /// snapshot, and every record staged afterwards lands in the new
    /// log (replay is idempotent per record, so overlap is harmless).
    /// Returns Ok(()) without writing when a snapshot is already in
    /// flight or the writer is down.
    pub fn install_snapshot<F: FnOnce() -> Json>(&self, build: F) -> Result<()> {
        if self.halted.load(Ordering::SeqCst) {
            return Ok(());
        }
        let new_epoch = {
            let mut st = self.lock_state();
            if st.crashed || st.closed || st.snapshotting {
                return Ok(());
            }
            st.snapshotting = true;
            st.epoch += 1;
            st.since_snapshot = 0;
            st.epoch
        };
        let res = self.write_snapshot_file(new_epoch, build());
        self.lock_state().snapshotting = false;
        res
    }

    fn write_snapshot_file(&self, new_epoch: u64, mut content: Json) -> Result<()> {
        content.set("wal_epoch", new_epoch);
        let bytes = content.render_pretty().into_bytes();
        let tmp = self.dir.join(format!(
            ".snapshot.{}-{}.tmp",
            std::process::id(),
            crate::util::ids::next_seq()
        ));
        let path = self.dir.join("snapshot.json");
        {
            use std::io::Write;
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            if self.crash_point == Some(CrashSite::MidSnapshot) {
                // Crash with only half the document written: recovery must
                // ignore the torn temp file and replay from the previous
                // snapshot (or from scratch) plus the full log chain.
                let _ = f.write_all(&bytes[..bytes.len() / 2]);
                let _ = f.sync_all();
                drop(f);
                self.crash(CrashSite::MidSnapshot);
            }
            f.write_all(&bytes)?;
            f.sync_all()?;
        }
        if self.crash_point == Some(CrashSite::BeforeRename) {
            // The full document is durable under the temp name but never
            // published: recovery must behave exactly like mid-snapshot.
            self.crash(CrashSite::BeforeRename);
        }
        if let Err(e) = std::fs::rename(&tmp, &path) {
            let _ = std::fs::remove_file(&tmp);
            return Err(e).with_context(|| format!("publishing {}", path.display()));
        }
        sweep_old_logs(&self.dir, new_epoch);
        tinfo!("wal", "snapshot published (epoch {new_epoch})");
        Ok(())
    }

    /// Flush whatever is staged and stop the flusher (graceful shutdown).
    /// After close, the log on disk is complete and replayable.
    pub fn close(&self) {
        {
            let mut st = self.lock_state();
            st.closed = true;
        }
        self.cv.notify_all();
        let handle = self.flusher.lock().unwrap().take();
        if let Some(h) = handle {
            let _ = h.join();
        }
    }

    /// Mark the writer permanently down (simulated crash): wakes every
    /// waiting appender with an error and stops the flusher before it
    /// writes another byte.
    pub(crate) fn mark_crashed(&self) {
        {
            let mut st = self.lock_state();
            st.crashed = true;
        }
        self.cv.notify_all();
    }

    fn open_log(&self, epoch: u64) -> std::io::Result<std::fs::File> {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(log_path(&self.dir, epoch))?;
        if f.metadata()?.len() == 0 {
            f.write_all(MAGIC)?;
            f.sync_all()?;
        }
        Ok(f)
    }

    /// The only thread that touches the log file: drains the staging
    /// buffer, writes + fsyncs outside any lock, then publishes the new
    /// durable sequence.  Reopens the file when a snapshot rotated the
    /// epoch (chunks are epoch-stamped at drain time, and epochs only
    /// grow, so file assignment preserves record order).
    fn flusher_loop(&self) {
        let mut open: Option<(u64, std::fs::File)> = None;
        loop {
            let (chunk, target, epoch) = {
                let mut st = self.lock_state();
                loop {
                    if st.crashed {
                        return;
                    }
                    if !st.buf.is_empty() {
                        break;
                    }
                    if st.closed {
                        return;
                    }
                    // lint:allow(blocking-under-lock, reason = "Condvar::wait atomically releases the WAL staging guard while parked")
                    st = self.cv.wait(st).unwrap_or_else(std::sync::PoisonError::into_inner);
                }
                (std::mem::take(&mut st.buf), st.staged, st.epoch)
            };
            if self.halted.load(Ordering::SeqCst) {
                return;
            }
            if open.as_ref().map(|(e, _)| *e) != Some(epoch) {
                match self.open_log(epoch) {
                    Ok(f) => open = Some((epoch, f)),
                    Err(e) => {
                        twarn!("wal", "cannot open log epoch {epoch}: {e}");
                        self.mark_crashed();
                        return;
                    }
                }
            }
            let (_, file) = open.as_mut().expect("log just opened");
            let res = {
                use std::io::Write;
                file.write_all(&chunk)
                    .and_then(|()| if self.conf.fsync { file.sync_data() } else { Ok(()) })
            };
            match res {
                Ok(()) => {
                    let mut st = self.lock_state();
                    st.synced = st.synced.max(target);
                }
                Err(e) => {
                    twarn!("wal", "append flush failed: {e}");
                    self.mark_crashed();
                    return;
                }
            }
            self.cv.notify_all();
        }
    }

    /// Injected crash in the append path.  Bypasses the flusher (which is
    /// marked dead first) and writes directly so the on-disk outcome is
    /// deterministic: `wal-before-fsync` persists a torn half-frame (what
    /// a crash between write and fsync can leave behind);
    /// `wal-after-fsync` persists the full frame durably — the crash
    /// lands after the fsync but before the submitter is acked.
    fn crash_append(&self, frame_bytes: &[u8], site: CrashSite) -> ! {
        let epoch = {
            let mut st = self.lock_state();
            st.crashed = true;
            st.epoch
        };
        self.cv.notify_all();
        if let Ok(mut f) = self.open_log(epoch) {
            use std::io::Write;
            let cut = match site {
                CrashSite::WalBeforeFsync => frame_bytes.len() / 2,
                _ => frame_bytes.len(),
            };
            let _ = f.write_all(&frame_bytes[..cut]);
            let _ = f.sync_all();
        }
        self.halted.store(true, Ordering::SeqCst);
        panic!("{}: {}", CRASH_PANIC, site.as_str());
    }

    /// Injected crash in the snapshot path (no direct file work beyond
    /// what the caller already did).  All locks are released before the
    /// panic so the abandoned gateway's mutexes stay clean.
    fn crash(&self, site: CrashSite) -> ! {
        self.mark_crashed();
        self.halted.store(true, Ordering::SeqCst);
        panic!("{}: {}", CRASH_PANIC, site.as_str());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "tony-waltest-{tag}-{}-{}",
            std::process::id(),
            crate::util::ids::next_seq()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn admitted(id: u64) -> WalRecord {
        WalRecord::Admitted {
            id,
            user: "alice".into(),
            name: format!("job{id}"),
            queue: "default".into(),
            priority: 3,
            conf_xml: "<configuration></configuration>".into(),
        }
    }

    #[test]
    fn record_json_round_trip() {
        let recs = [
            admitted(7),
            WalRecord::Started { id: 7, app_id: "application_1_0001".into(), attempt: 2 },
            WalRecord::KillRequested { id: 7 },
            WalRecord::Terminal {
                id: 7,
                state: "FINISHED".into(),
                detail: "ok".into(),
                wall_ms: 1234,
            },
        ];
        for r in &recs {
            let back = WalRecord::from_json(&r.to_json()).unwrap();
            assert_eq!(&back, r);
            assert_eq!(back.job_id(), 7);
        }
    }

    #[test]
    fn append_fsync_then_decode() {
        let d = dir("append");
        let mut conf = WalConf::disabled();
        conf.enable = true;
        conf.dir = d.clone();
        let halted = Arc::new(AtomicBool::new(false));
        let wal = Wal::open(conf, halted, None).unwrap();
        wal.append(&admitted(1)).unwrap();
        wal.append(&WalRecord::Terminal {
            id: 1,
            state: "FINISHED".into(),
            detail: String::new(),
            wall_ms: 5,
        })
        .unwrap();
        wal.close();
        let bytes = std::fs::read(log_path(&d, 0)).unwrap();
        let (recs, clean) = decode_stream(&bytes);
        assert!(clean);
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0], admitted(1));
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn torn_tail_is_dropped_cleanly() {
        let mut bytes = MAGIC.to_vec();
        bytes.extend_from_slice(&admitted(1).encode());
        let full = admitted(2).encode();
        bytes.extend_from_slice(&full[..full.len() / 2]);
        let (recs, clean) = decode_stream(&bytes);
        assert!(!clean);
        assert_eq!(recs, vec![admitted(1)]);
        // Corrupt checksum: same clean stop.
        let mut bytes = MAGIC.to_vec();
        bytes.extend_from_slice(&admitted(1).encode());
        let n = bytes.len();
        bytes[n - 1] ^= 0xFF;
        let (recs, clean) = decode_stream(&bytes);
        assert!(!clean);
        assert!(recs.is_empty());
    }

    #[test]
    fn snapshot_rotates_epoch_and_retires_old_log() {
        let d = dir("rotate");
        let mut conf = WalConf::disabled();
        conf.enable = true;
        conf.dir = d.clone();
        let halted = Arc::new(AtomicBool::new(false));
        let wal = Wal::open(conf, halted, None).unwrap();
        wal.append(&admitted(1)).unwrap();
        wal.install_snapshot(|| {
            let mut j = Json::obj();
            j.set("next_id", 2u64);
            j.set("jobs", Json::Arr(Vec::new()));
            j
        })
        .unwrap();
        assert_eq!(wal.epoch(), 1);
        assert!(!log_path(&d, 0).exists(), "retired log must be deleted");
        wal.append(&admitted(2)).unwrap();
        wal.close();
        let (recs, clean) = decode_stream(&std::fs::read(log_path(&d, 1)).unwrap());
        assert!(clean);
        assert_eq!(recs, vec![admitted(2)], "post-snapshot appends land in the new epoch");
        let snap = Json::parse(&std::fs::read_to_string(d.join("snapshot.json")).unwrap()).unwrap();
        assert_eq!(snap.get("wal_epoch").and_then(|v| v.as_u64()), Some(1));
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn open_sweeps_orphaned_tmp_files() {
        let d = dir("sweep");
        std::fs::create_dir_all(&d).unwrap();
        std::fs::write(d.join(".snapshot.1-1.tmp"), b"torn").unwrap();
        let mut conf = WalConf::disabled();
        conf.enable = true;
        conf.dir = d.clone();
        let wal = Wal::open(conf, Arc::new(AtomicBool::new(false)), None).unwrap();
        assert!(!d.join(".snapshot.1-1.tmp").exists(), "orphan must be swept at open");
        wal.close();
        let _ = std::fs::remove_dir_all(&d);
    }
}
