//! The TonY gateway: a long-running, multi-tenant job-submission service
//! (the paper's L3 coordination contribution, scaled from one
//! `TonyClient` invocation to a shared daemon).
//!
//! One gateway process owns the [`ResourceManager`] and runs many TonY
//! jobs concurrently against it:
//!
//! ```text
//!   users ── POST /api/v1/jobs ─▶ admission ─▶ pending queue ─▶ worker pool
//!                                   │ reject              (N concurrent AM
//!                                   ▼ with reason          lifecycles)
//!                               job table ◀── state updates ── TonyClient
//!                                   │                             │
//!            GET /api/v1/jobs ◀─────┘            HistoryStore ◀───┘
//! ```
//!
//! - [`admission`]: spec validation, queue mapping, per-user/per-queue
//!   quotas — every refusal carries a machine-readable reason;
//! - [`queue`]: bounded priority queue with backpressure and fair FIFO
//!   within a priority level;
//! - [`api`]: the HTTP JSON API (`/api/v1/jobs`, `/api/v1/cluster`,
//!   `/metrics`), reusing the portal's hand-rolled HTTP plumbing;
//! - this module: the job table, the worker pool that drives each
//!   accepted job through its full AM lifecycle (with gateway-level
//!   retry on AM failure), kill propagation, automatic [`HistoryStore`]
//!   recording for every job that ran, and the live-observability
//!   aggregation: every running job's AM metrics registry is scraped
//!   through one `GET /metrics` with `job`/`id`/`user`/`queue` labels
//!   (see `docs/METRICS.md`), and streaming Dr. Elephant findings are
//!   embedded in per-job status while the job runs.
//!
//! # Example
//!
//! ```no_run
//! use std::time::Duration;
//! use tony::gateway::{Gateway, GatewayApi, GatewayConf, SubmitOutcome};
//! use tony::tonyconf::JobConfBuilder;
//! use tony::yarn::{Resource, ResourceManager};
//!
//! let rm = ResourceManager::start_uniform(2, Resource::new(4096, 8, 0));
//! let gw = Gateway::start(rm, GatewayConf::new("artifacts/tiny")).unwrap();
//! let api = GatewayApi::start(gw.clone(), 0).unwrap();
//! let conf = JobConfBuilder::new("demo").instances("worker", 1).build();
//! match gw.submit_conf("alice", 1, conf) {
//!     SubmitOutcome::Accepted { id } => {
//!         println!("watch {}/api/v1/jobs/{id}, scrape {}/metrics", api.url(), api.url());
//!     }
//!     SubmitOutcome::Rejected { reason, .. } => eprintln!("rejected: {reason}"),
//! }
//! gw.wait_idle(Duration::from_secs(60));
//! gw.shutdown();
//! ```

pub mod admission;
pub mod api;
pub mod queue;
pub mod recovery;
pub mod wal;

pub use admission::{AdmissionController, AdmissionView, QuotaConf, RejectReason};
pub use api::GatewayApi;
pub use queue::{PendingQueue, PushError};
pub use recovery::{replay_dir, RecoveredJob, RecoveredState, Replay};
pub use wal::{Wal, WalConf, WalRecord};

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::chaos::CrashSite;
use crate::client::{SubmitOpts, TonyClient};
use crate::history::{HistoryStore, JobRecord};
use crate::json::Json;
use crate::metrics::Histogram;
use crate::tonyconf::JobSpec;
use crate::trace::{SpanStore, Stage};
use crate::util::clock::Clock;
use crate::util::event::{tag, WakeupBus};
use crate::util::ids::ApplicationId;
use crate::xmlconf::Configuration;
use crate::yarn::{AppState, Resource, ResourceManager};
use crate::{tinfo, twarn};

/// Gateway-side job lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Pending,
    Running,
    Finished,
    Failed,
    Killed,
    Rejected,
}

impl JobState {
    pub fn is_terminal(&self) -> bool {
        !matches!(self, JobState::Pending | JobState::Running)
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            JobState::Pending => "PENDING",
            JobState::Running => "RUNNING",
            JobState::Finished => "FINISHED",
            JobState::Failed => "FAILED",
            JobState::Killed => "KILLED",
            JobState::Rejected => "REJECTED",
        }
    }
}

/// Gateway configuration.
#[derive(Debug, Clone)]
pub struct GatewayConf {
    /// Worker-pool size: how many jobs run their AM lifecycle at once.
    pub workers: usize,
    /// Bound on the pending queue (admission backpressure past this).
    pub queue_depth: usize,
    /// Admission quotas.
    pub quotas: QuotaConf,
    /// Gateway-level retries when an application ends FAILED (the AM
    /// already retries task failures internally; this re-runs the whole
    /// application, e.g. after an AM crash).
    pub max_submit_attempts: u32,
    /// AOT artifacts the jobs execute (synthetic preset generated here
    /// when missing, sim builds only).
    pub artifacts_dir: PathBuf,
    /// Where finished jobs are recorded.
    pub history_dir: PathBuf,
    /// Per-attempt wall-clock ceiling.
    pub job_timeout: Duration,
    /// Retention cap for the in-memory job table: once exceeded, the
    /// oldest *terminal* entries are evicted (the daemon runs forever;
    /// an unbounded table would let reject spam grow memory without
    /// limit).  Live jobs are never evicted.
    pub max_retained_jobs: usize,
    /// Control-plane write-ahead log (off by default); when enabled,
    /// every admission is durable before it is acked and
    /// [`Gateway::recover`] can rebuild the job table after a crash.
    pub wal: WalConf,
    /// Deterministic crash injection (`tony.chaos.crash-point`): panic
    /// the gateway at a named durability site.  Test-only; `None` in any
    /// real deployment.
    pub crash_point: Option<CrashSite>,
}

impl GatewayConf {
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> GatewayConf {
        GatewayConf {
            workers: 8,
            queue_depth: 64,
            quotas: QuotaConf::default(),
            max_submit_attempts: 2,
            artifacts_dir: artifacts_dir.into(),
            history_dir: std::env::temp_dir().join("tony-history"),
            job_timeout: Duration::from_secs(600),
            max_retained_jobs: 10_000,
            wal: WalConf::disabled(),
            crash_point: None,
        }
    }

    /// Fold the site-level durability/chaos keys (`tony.wal.*`,
    /// `tony.chaos.crash-point`) from a site configuration into this
    /// conf — the path `tony serve` and the crash tests use.
    pub fn apply_site_conf(&mut self, site: &Configuration) {
        self.wal = WalConf::from_conf(site);
        self.crash_point = site.get("tony.chaos.crash-point").and_then(|s| {
            let parsed = CrashSite::parse(&s);
            if parsed.is_none() {
                twarn!("gateway", "ignoring unknown tony.chaos.crash-point '{s}'");
            }
            parsed
        });
    }
}

/// Counters exposed on `/api/v1/cluster`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GatewayStats {
    pub accepted: u64,
    pub rejected: u64,
    pub finished: u64,
    pub failed: u64,
    pub killed: u64,
}

struct Job {
    id: u64,
    user: String,
    name: String,
    queue: String,
    priority: u8,
    state: JobState,
    detail: String,
    app_id: Option<ApplicationId>,
    attempts: u32,
    wall_ms: u64,
    /// Tasks + AM, for per-user resource quota release.
    resources: Resource,
    kill_requested: bool,
    conf: Configuration,
    /// The running job's AM state — the live-observability handle the
    /// gateway's `/metrics` aggregation and per-job series/findings
    /// endpoints read.  Set when the worker submits the application,
    /// cleared when the job terminalizes (history keeps the series).
    live: Option<Arc<crate::am::AmState>>,
    /// Lifecycle span store, minted at admission so the `queued` stage
    /// covers the whole pending-queue wait.  Serves `/trace` while the
    /// job is in the table; cleared at terminalization (history keeps
    /// the exported span tree, mirroring `live`/`series`).
    trace: Option<Arc<SpanStore>>,
}

struct GwInner {
    jobs: BTreeMap<u64, Job>,
    next_id: u64,
    user_active: BTreeMap<String, u32>,
    queue_active: BTreeMap<String, u32>,
    user_resources: BTreeMap<String, Resource>,
    stats: GatewayStats,
}

/// The accept/reject verdict for one submission.
#[derive(Debug, Clone, PartialEq)]
pub enum SubmitOutcome {
    Accepted { id: u64 },
    Rejected { id: u64, reason: RejectReason },
}

pub struct Gateway {
    rm: Arc<ResourceManager>,
    conf: GatewayConf,
    admission: AdmissionController,
    queue: PendingQueue,
    history: HistoryStore,
    inner: Mutex<GwInner>,
    /// Stage-latency histograms (`tony_stage_seconds`), fed from each
    /// traced job's critical-path breakdown at terminalization and
    /// rendered on `GET /metrics`.  Own lock, taken strictly after (or
    /// without) the job-table lock.
    stage_hist: Mutex<BTreeMap<&'static str, Histogram>>,
    api_url: Mutex<Option<String>>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// Clock shared with the RM: every gateway deadline runs on it.
    clock: Arc<dyn Clock>,
    /// Notified (`tag::STATE`) on every job-state transition;
    /// `wait_idle` / `wait_for_state` waiters ride its sequence instead
    /// of polling the job table every 10 ms.
    events: Arc<WakeupBus>,
    /// Control-plane WAL; `None` when `tony.wal.enable` is off.
    wal: Option<Arc<Wal>>,
    /// Flipped by [`Gateway::simulate_crash`] (and by injected crash
    /// points): the process is "dead" — leftover threads must neither
    /// write WAL bytes nor mutate the job table, so a recovered gateway
    /// sharing the RM observes exactly what a real crash leaves behind.
    halted: Arc<AtomicBool>,
}

impl Gateway {
    /// Start the gateway: verify/generate artifacts and spin up the
    /// worker pool.  Callers must invoke [`Gateway::shutdown`] when done
    /// (the worker threads hold `Arc<Gateway>` references).
    pub fn start(rm: Arc<ResourceManager>, conf: GatewayConf) -> Result<Arc<Gateway>> {
        Self::boot(rm, conf, None)
    }

    /// Shared construction path for [`Gateway::start`] and
    /// [`Gateway::recover`].  With a replay, the recovered table is
    /// installed and a fresh snapshot is published (rotating past any
    /// torn log tail) *before* workers run or re-admissions are queued.
    /// Without one, an enabled WAL still snapshots at boot *if* the
    /// directory holds state from a previous incarnation, so stale
    /// records can never bleed into this incarnation's log; a pristine
    /// directory has nothing to rotate past and skips the write.
    fn boot(
        rm: Arc<ResourceManager>,
        conf: GatewayConf,
        recovered: Option<recovery::Replay>,
    ) -> Result<Arc<Gateway>> {
        crate::runtime::synthetic::ensure_preset(&conf.artifacts_dir)
            .context("preparing artifacts for the gateway")?;
        let clock = rm.clock().clone();
        let events = WakeupBus::for_clock(&clock);
        let halted = Arc::new(AtomicBool::new(false));
        let wal = match conf.wal.enable {
            true => Some(Wal::open(conf.wal.clone(), halted.clone(), conf.crash_point)?),
            false => None,
        };
        let history = HistoryStore::new(&conf.history_dir);
        // A crash between a record's create and rename leaves a temp
        // orphan behind; sweep ones old enough to be certainly dead.
        history.sweep_orphans(Duration::from_secs(3600));
        let gw = Arc::new(Gateway {
            rm,
            admission: AdmissionController::new(conf.quotas.clone()),
            queue: PendingQueue::new(conf.queue_depth),
            history,
            inner: Mutex::new(GwInner {
                jobs: BTreeMap::new(),
                next_id: 1,
                user_active: BTreeMap::new(),
                queue_active: BTreeMap::new(),
                user_resources: BTreeMap::new(),
                stats: GatewayStats::default(),
            }),
            stage_hist: Mutex::new(BTreeMap::new()),
            api_url: Mutex::new(None),
            workers: Mutex::new(Vec::new()),
            clock,
            events,
            conf,
            wal,
            halted,
        });
        let plan = recovered.as_ref().map(|rep| gw.restore(rep));
        if let Some(w) = &gw.wal {
            if plan.is_some() || w.had_existing_state() {
                gw.write_snapshot();
            }
        }
        let n = gw.conf.workers.max(1);
        let mut handles = Vec::with_capacity(n);
        for i in 0..n {
            let g = gw.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("gw-worker-{i}"))
                    .spawn(move || g.worker_loop())
                    .context("spawning gateway worker")?,
            );
        }
        gw.workers.lock().unwrap().extend(handles);
        if let Some(plan) = plan {
            gw.apply_restore_plan(plan);
        }
        tinfo!(
            "gateway",
            "gateway up: {} workers, queue depth {}, wal {}",
            n,
            gw.conf.queue_depth,
            if gw.wal.is_some() { "on" } else { "off" }
        );
        Ok(gw)
    }

    /// Whether [`Gateway::simulate_crash`] (or an injected crash point)
    /// has "killed" this gateway.
    pub fn is_halted(&self) -> bool {
        self.halted.load(Ordering::SeqCst)
    }

    /// Kill this gateway the way a crash would: no further WAL bytes, no
    /// further job-table transitions, workers released.  Unlike
    /// [`Gateway::shutdown`] nothing is flushed or drained — whatever the
    /// WAL already made durable is all a subsequent [`Gateway::recover`]
    /// gets, which is exactly what the crash tests need from a
    /// same-process "kill -9".
    pub fn simulate_crash(&self) {
        self.halted.store(true, Ordering::SeqCst);
        if let Some(w) = &self.wal {
            w.mark_crashed();
        }
        self.queue.close();
        self.events.notify(tag::SHUTDOWN | tag::STATE);
    }

    /// Panic mid-operation when this gateway was armed with `site` —
    /// the gateway-level injection point (`post-admit-pre-ack`); the
    /// WAL-level sites live in `wal.rs`.
    fn chaos_crash_if(&self, site: CrashSite) {
        if self.conf.crash_point == Some(site) {
            self.halted.store(true, Ordering::SeqCst);
            if let Some(w) = &self.wal {
                w.mark_crashed();
            }
            self.queue.close();
            panic!("{}: {}", crate::chaos::CRASH_PANIC, site.as_str());
        }
    }

    pub fn rm(&self) -> &Arc<ResourceManager> {
        &self.rm
    }

    /// The gateway's job-state event bus (`tag::STATE` per transition).
    pub fn events(&self) -> &Arc<WakeupBus> {
        &self.events
    }

    /// Live AM states of currently running jobs, `(job id, state)` —
    /// the observability handle `/metrics` aggregation uses, exposed for
    /// benches/tests that measure monitor-loop behaviour directly.
    pub fn live_am_states(&self) -> Vec<(u64, Arc<crate::am::AmState>)> {
        let inner = self.inner.lock().unwrap();
        inner
            .jobs
            .values()
            .filter_map(|j| j.live.as_ref().map(|s| (j.id, s.clone())))
            .collect()
    }

    pub fn history(&self) -> &HistoryStore {
        &self.history
    }

    pub fn conf(&self) -> &GatewayConf {
        &self.conf
    }

    pub fn set_api_url(&self, url: String) {
        *self.api_url.lock().unwrap() = Some(url);
    }

    pub fn api_url(&self) -> Option<String> {
        self.api_url.lock().unwrap().clone()
    }

    /// Submit a job on behalf of `user`.  Runs admission, records the
    /// decision in the job table either way, and enqueues on accept.
    pub fn submit_conf(&self, user: &str, priority: u8, conf: Configuration) -> SubmitOutcome {
        let mut conf = conf;
        let spec = match JobSpec::from_conf(&conf) {
            Ok(s) => s,
            Err(e) => {
                return self.reject(user, priority, &conf, RejectReason::InvalidSpec(
                    format!("{e:#}"),
                ))
            }
        };
        let cluster_total = self.cluster_total();
        let known: Vec<String> =
            self.rm.queue_usage().into_iter().map(|(name, _)| name).collect();
        let needed = spec.total_task_resources() + spec.am_resource;

        let mut inner = self.inner.lock().unwrap();
        let view = AdmissionView {
            user_active: &inner.user_active,
            queue_active: &inner.queue_active,
            user_resources: &inner.user_resources,
        };
        let queue = match self.admission.decide(user, &spec, cluster_total, &known, &view) {
            Ok(q) => q,
            Err(reason) => {
                drop(inner);
                return self.reject(user, priority, &conf, reason);
            }
        };

        let id = inner.next_id;
        inner.next_id += 1;
        // Multi-tenant hygiene: pin the job to its mapped queue and give
        // it a private checkpoint dir unless the user chose one.
        conf.set("tony.application.queue", queue.as_str());
        if conf.get("tony.train.checkpoint-dir").is_none() {
            // Unique per process AND per gateway instance: job ids restart
            // at 1 for every gateway, so they alone would collide.
            let ckpt = std::env::temp_dir().join(format!(
                "tony-gateway-ckpt-{}-{}",
                std::process::id(),
                crate::util::ids::next_seq()
            ));
            conf.set("tony.train.checkpoint-dir", ckpt.to_string_lossy().to_string());
        }
        // Mint the lifecycle trace at admission: the `queued` stage opens
        // here, so the span tree covers the pending-queue wait the AM
        // never sees.  A disabled store (tony.trace.enable=false) swallows
        // every call without taking a lock.
        let trace = SpanStore::new(&spec.trace, self.clock.clone(), id);
        trace.start_stage(Stage::Queued);
        let job = Job {
            id,
            user: user.to_string(),
            name: spec.name.clone(),
            queue: queue.clone(),
            priority,
            state: JobState::Pending,
            detail: String::new(),
            app_id: None,
            attempts: 0,
            wall_ms: 0,
            resources: needed,
            kill_requested: false,
            conf,
            live: None,
            trace: Some(trace),
        };
        // Durable-before-acked: the admission record must hit the WAL
        // before the job is visible to a worker OR acked to the caller,
        // so the id is minted and the table/quota entry installed here,
        // but the queue push waits until after the append.  Capture the
        // record while the job is still ours to read.
        let wal_admit = self.wal.as_ref().map(|_| WalRecord::Admitted {
            id,
            user: user.to_string(),
            name: spec.name.clone(),
            queue: queue.clone(),
            priority,
            conf_xml: job.conf.to_xml(),
        });
        *inner.user_active.entry(user.to_string()).or_insert(0) += 1;
        *inner.queue_active.entry(queue.clone()).or_insert(0) += 1;
        let held = inner.user_resources.entry(user.to_string()).or_insert(Resource::ZERO);
        *held += needed;
        inner.jobs.insert(id, job);
        inner.stats.accepted += 1;
        self.prune_locked(&mut inner);
        drop(inner);
        if let Some(rec) = wal_admit {
            if let Err(e) = self.wal.as_ref().expect("wal record implies wal").append(&rec) {
                // A control plane that cannot persist admissions must not
                // accept work: fail closed, retryably.
                let reason =
                    RejectReason::Backpressure(format!("control-plane WAL unavailable: {e:#}"));
                self.undo_admit(id, &reason);
                return SubmitOutcome::Rejected { id, reason };
            }
            if self.wal.as_ref().expect("wal record implies wal").snapshot_due() {
                self.write_snapshot();
            }
        }
        self.chaos_crash_if(CrashSite::PostAdmitPreAck);
        if let Err(e) = self.queue.try_push(priority, id) {
            // Backpressure: the admission record is already durable, so a
            // matching terminal record keeps the log's story straight.
            let reason = RejectReason::Backpressure(e.to_string());
            self.undo_admit(id, &reason);
            self.wal_append(&WalRecord::Terminal {
                id,
                state: JobState::Rejected.as_str().to_string(),
                detail: reason.to_string(),
                wall_ms: 0,
            });
            return SubmitOutcome::Rejected { id, reason };
        }
        tinfo!("gateway", "job {id} accepted for '{user}' on queue '{queue}' (prio {priority})");
        SubmitOutcome::Accepted { id }
    }

    /// Roll back an admission whose ack could not complete (WAL append or
    /// queue push failed): the job flips to Rejected and every counter
    /// the accept bumped is released.  Deliberately not `finalize_locked`
    /// — this is an un-accept (`rejected += 1`), not a failed run.
    fn undo_admit(&self, id: u64, reason: &RejectReason) {
        let mut inner = self.inner.lock().unwrap();
        let Some(job) = inner.jobs.get_mut(&id) else { return };
        job.state = JobState::Rejected;
        job.detail = reason.to_string();
        if let Some(t) = job.trace.take() {
            t.end_all();
        }
        let (user, queue, resources) = (job.user.clone(), job.queue.clone(), job.resources);
        if let Some(n) = inner.user_active.get_mut(&user) {
            *n = n.saturating_sub(1);
        }
        if let Some(n) = inner.queue_active.get_mut(&queue) {
            *n = n.saturating_sub(1);
        }
        if let Some(held) = inner.user_resources.get_mut(&user) {
            *held = held.checked_sub(&resources).unwrap_or(Resource::ZERO);
        }
        inner.stats.accepted = inner.stats.accepted.saturating_sub(1);
        inner.stats.rejected += 1;
        tinfo!("gateway", "job {id} un-admitted: {reason}");
        self.events.notify(tag::STATE);
    }

    /// Best-effort WAL append for post-admission lifecycle records
    /// (start/kill/terminal).  Unlike the admission append this never
    /// fails the operation: the transition already happened against the
    /// RM, and losing a lifecycle record only costs recovery precision
    /// (a re-attach or duplicate-finalize check), never an acked job.
    fn wal_append(&self, rec: &WalRecord) {
        let Some(w) = &self.wal else { return };
        if self.is_halted() {
            return;
        }
        if let Err(e) = w.append(rec) {
            twarn!("gateway", "wal append failed for job {}: {e:#}", rec.job_id());
        } else if w.snapshot_due() {
            self.write_snapshot();
        }
    }

    /// Build + publish a WAL snapshot of the current control-plane state
    /// (no-op without a WAL).  Public so operators/tests can force
    /// compaction at a known point instead of waiting for the
    /// record-count trigger.
    pub fn force_snapshot(&self) {
        self.write_snapshot();
    }

    fn write_snapshot(&self) {
        let Some(w) = &self.wal else { return };
        if let Err(e) = w.install_snapshot(|| self.snapshot_content()) {
            twarn!("gateway", "wal snapshot failed: {e:#}");
        }
    }

    /// Snapshot document: the non-terminal job table (via the same
    /// [`RecoveredState`] shape replay produces) plus the RM's
    /// queue/gang/reservation summary for operator forensics.
    fn snapshot_content(&self) -> Json {
        let mut state = recovery::RecoveredState::new();
        {
            let inner = self.inner.lock().unwrap();
            state.next_id = inner.next_id;
            for job in inner.jobs.values() {
                if job.state.is_terminal() {
                    continue;
                }
                state.jobs.insert(
                    job.id,
                    recovery::RecoveredJob {
                        id: job.id,
                        user: job.user.clone(),
                        name: job.name.clone(),
                        queue: job.queue.clone(),
                        priority: job.priority,
                        running: job.state == JobState::Running,
                        app_id: job.app_id.map(|a| a.to_string()),
                        attempts: job.attempts,
                        kill_requested: job.kill_requested,
                        conf_xml: job.conf.to_xml(),
                    },
                );
            }
        }
        let mut j = state.to_snapshot_json();
        j.set("sched", self.rm.sched_state_json());
        j
    }

    /// Evict the oldest terminal entries once the table outgrows the
    /// retention cap (history keeps the durable record; this is only the
    /// serving view).
    fn prune_locked(&self, inner: &mut GwInner) {
        let cap = self.conf.max_retained_jobs.max(1);
        while inner.jobs.len() > cap {
            let victim = inner
                .jobs
                .iter()
                .find(|(_, j)| j.state.is_terminal())
                .map(|(id, _)| *id);
            match victim {
                Some(id) => {
                    inner.jobs.remove(&id);
                }
                None => break, // everything live: never evict running work
            }
        }
    }

    fn reject(
        &self,
        user: &str,
        priority: u8,
        conf: &Configuration,
        reason: RejectReason,
    ) -> SubmitOutcome {
        let name = conf.get_or("tony.application.name", "?");
        let queue = conf.get_or("tony.application.queue", "default");
        let mut inner = self.inner.lock().unwrap();
        let id = inner.next_id;
        inner.next_id += 1;
        inner.jobs.insert(
            id,
            Job {
                id,
                user: user.to_string(),
                name,
                queue,
                priority,
                state: JobState::Rejected,
                detail: reason.to_string(),
                app_id: None,
                attempts: 0,
                wall_ms: 0,
                resources: Resource::ZERO,
                kill_requested: false,
                conf: conf.clone(),
                live: None,
                trace: None,
            },
        );
        inner.stats.rejected += 1;
        self.prune_locked(&mut inner);
        tinfo!("gateway", "job {id} rejected for '{user}': {reason}");
        SubmitOutcome::Rejected { id, reason }
    }

    /// Kill a job: drop it from the queue if still pending, or kill the
    /// live application.  Returns the state observed (the worker finishes
    /// the transition for running jobs).  None = unknown id.
    pub fn kill(&self, id: u64) -> Option<JobState> {
        let mut inner = self.inner.lock().unwrap();
        let job = inner.jobs.get_mut(&id)?;
        let state = job.state;
        match state {
            JobState::Pending => {
                job.kill_requested = true;
                if self.queue.remove(id) {
                    let ident = (job.user.clone(), job.name.clone(), job.queue.clone());
                    let did =
                        self.finalize_locked(&mut inner, id, JobState::Killed, "killed while queued", 0);
                    drop(inner);
                    if did {
                        self.wal_terminal(id, JobState::Killed, "killed while queued", 0);
                    }
                    // Even a job that never ran leaves a terminal history
                    // record (regression: these used to vanish from the
                    // durable record entirely).
                    self.record_unran(id, ident, 0, 0, "killed while queued");
                    Some(JobState::Killed)
                } else {
                    // A worker already popped it; the flag is honored there.
                    Some(JobState::Pending)
                }
            }
            JobState::Running => {
                job.kill_requested = true;
                let app = job.app_id;
                drop(inner);
                // Durable intent: if we crash between here and the RM
                // kill taking effect, recovery honors the kill instead of
                // resurrecting the job.
                self.wal_append(&WalRecord::KillRequested { id });
                if let Some(app) = app {
                    self.rm.kill_application(app);
                }
                Some(JobState::Running)
            }
            s => Some(s),
        }
    }

    pub fn job_state(&self, id: u64) -> Option<JobState> {
        self.inner.lock().unwrap().jobs.get(&id).map(|j| j.state)
    }

    /// The scheduler queue the job was admitted to (after user→queue
    /// mapping) — what the submit response surfaces so a remap is never
    /// silent.
    pub fn job_queue(&self, id: u64) -> Option<String> {
        self.inner.lock().unwrap().jobs.get(&id).map(|j| j.queue.clone())
    }

    pub fn stats(&self) -> GatewayStats {
        self.inner.lock().unwrap().stats
    }

    /// Live (pending or running) job count per user — the quantity the
    /// per-user quota bounds.
    pub fn user_active_counts(&self) -> BTreeMap<String, u32> {
        self.inner.lock().unwrap().user_active.clone()
    }

    /// (pending, running) counts.
    pub fn live_counts(&self) -> (usize, usize) {
        let inner = self.inner.lock().unwrap();
        let pending = inner.jobs.values().filter(|j| j.state == JobState::Pending).count();
        let running = inner.jobs.values().filter(|j| j.state == JobState::Running).count();
        (pending, running)
    }

    /// Wait until every tracked job reached a terminal state.
    /// Notification-driven: wakes on each job-state transition (including
    /// those finalized by `shutdown`'s drain), so it returns at event
    /// time and coexists race-free with a concurrent `shutdown()`.
    pub fn wait_idle(&self, timeout: Duration) -> bool {
        let deadline = self.clock.deadline_after(timeout);
        loop {
            // Seq before predicate: a transition landing in between bumps
            // the sequence and the wait returns immediately.
            let seen = self.events.seq();
            {
                let inner = self.inner.lock().unwrap();
                if inner.jobs.values().all(|j| j.state.is_terminal()) {
                    return true;
                }
            }
            if self.clock.now_ms() >= deadline {
                return false;
            }
            self.events.wait_seq(&*self.clock, seen, deadline);
        }
    }

    /// Block until job `id` reaches `want` or any terminal state
    /// (whichever first), or until `timeout`.  Returns the state
    /// observed; `None` for an unknown id.  Event-driven like
    /// [`Gateway::wait_idle`].
    pub fn wait_for_state(&self, id: u64, want: JobState, timeout: Duration) -> Option<JobState> {
        let deadline = self.clock.deadline_after(timeout);
        loop {
            let seen = self.events.seq();
            let cur = self.job_state(id)?;
            if cur == want || cur.is_terminal() || self.clock.now_ms() >= deadline {
                return Some(cur);
            }
            self.events.wait_seq(&*self.clock, seen, deadline);
        }
    }

    /// Stop accepting work, drain the workers, and join them.  Closing
    /// the queue wakes every idle worker immediately (they block in
    /// `pop_wait`, not on a poll), each drains what was already accepted,
    /// and every resulting transition notifies `wait_idle` waiters — so
    /// shutdown racing a pending→running transition neither hangs nor
    /// loses a job.
    pub fn shutdown(&self) {
        self.queue.close();
        let handles = std::mem::take(&mut *self.workers.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
        // Workers are quiet: flush + stop the WAL so the log on disk is
        // complete and replayable (no open-but-unsynced tail).
        if let Some(w) = &self.wal {
            w.close();
        }
        self.events.notify(tag::SHUTDOWN | tag::STATE);
    }

    // ---------------- JSON views (served by api.rs) ----------------

    fn job_to_json(job: &Job) -> Json {
        let mut j = Json::obj();
        j.set("id", job.id);
        j.set("user", job.user.as_str());
        j.set("name", job.name.as_str());
        j.set("queue", job.queue.as_str());
        j.set("priority", job.priority as u64);
        j.set("state", job.state.as_str());
        j.set("detail", job.detail.as_str());
        match job.app_id {
            Some(app) => j.set("app_id", app.to_string()),
            None => j.set("app_id", Json::Null),
        };
        j.set("attempts", job.attempts as u64);
        j.set("wall_ms", job.wall_ms);
        j.set("mem_mb", job.resources.memory_mb);
        j.set("vcores", job.resources.vcores as u64);
        j.set("gpus", job.resources.gpus as u64);
        // Elastic bounds (docs/SCHEDULING.md "Elasticity"); min == max
        // for rigid jobs.  The live worker count rides in job_json.
        let instances = job.conf.get_u32("tony.worker.instances", 0);
        j.set("workers_min", job.conf.get_u32("tony.task.workers.min", instances) as u64);
        j.set("workers_max", job.conf.get_u32("tony.task.workers.max", instances) as u64);
        j
    }

    pub fn jobs_json(&self) -> Json {
        let inner = self.inner.lock().unwrap();
        let jobs: Vec<Json> = inner.jobs.values().map(Self::job_to_json).collect();
        let mut j = Json::obj();
        j.set("jobs", Json::Arr(jobs));
        j.set("stats", Self::stats_json(&inner.stats));
        j
    }

    pub fn job_json(&self, id: u64) -> Option<Json> {
        // Snapshot under the gateway lock; the live AM state (its own
        // mutex, hammered by heartbeats) is only touched after release
        // so one status request cannot stall submits/kills/finalizes.
        let (mut j, live, app_id) = {
            let inner = self.inner.lock().unwrap();
            let job = inner.jobs.get(&id)?;
            (Self::job_to_json(job), job.live.clone(), job.app_id)
        };
        // Gang-scheduler standing: WAITING_FOR_GANG while the job's
        // wave can't yet be placed whole, PREEMPTING while the RM is
        // clawing its containers back for a starved queue.  Keyed on the
        // application, not the live handle: a job re-attached after
        // gateway recovery has no AmState but its gang standing is still
        // real (and asserted by the crash tests).
        if let Some(app) = app_id {
            if self.job_state(id).map(|s| !s.is_terminal()).unwrap_or(false) {
                j.set("sched_state", self.rm.app_sched_state(app).as_str());
            }
        }
        if let Some(state) = live {
            j.set("phase", format!("{:?}", state.phase()));
            // The worker count the AM currently converges on — moves
            // between workers_min and workers_max as resize waves land.
            j.set("workers_current", state.expected_workers() as u64);
            // Streaming Dr. Elephant verdicts for the running job —
            // stragglers are visible in gateway job status mid-run.
            let findings = crate::drelephant::analyze_live(&state);
            j.set("findings", crate::drelephant::findings_json(&findings));
        }
        Some(j)
    }

    /// Time series for one job as JSON: the live registry while the job
    /// runs, the down-sampled history record once it finished.  `None`
    /// means the job id is unknown.
    pub fn job_series_json(&self, id: u64) -> Option<Json> {
        let (live, app_id) = {
            let inner = self.inner.lock().unwrap();
            let job = inner.jobs.get(&id)?;
            (job.live.clone(), job.app_id)
        };
        if let Some(state) = live {
            return Some(state.metrics_registry().series_json());
        }
        let record = app_id.and_then(|app| self.history.load(&app.to_string()).ok());
        Some(match record {
            Some(rec) => rec.series.clone(),
            // Never ran (e.g. rejected) or history is gone: empty series
            // in the same shape live responses use.
            None => {
                let mut j = Json::obj();
                j.set("tasks", Json::obj());
                j.set("queues", Json::obj());
                j
            }
        })
    }

    /// The job's lifecycle trace as JSON: the live span store while the
    /// job is in the table, the exported span tree from its history
    /// record once evicted or terminal.  `None` means the job id is
    /// unknown.  Jobs that never traced (disabled, never ran, or records
    /// predating the tracing plane) get the same `{"enabled": false,
    /// "spans": []}` shape a disabled live store serves.
    pub fn job_trace_json(&self, id: u64) -> Option<Json> {
        let (trace, app_id) = {
            let inner = self.inner.lock().unwrap();
            let job = inner.jobs.get(&id)?;
            (job.trace.clone(), job.app_id)
        };
        if let Some(t) = trace {
            return Some(t.trace_json());
        }
        let record = app_id.and_then(|app| self.history.load(&app.to_string()).ok());
        Some(match record {
            Some(rec) if rec.trace.get("spans").is_some() => rec.trace.clone(),
            _ => {
                let mut j = Json::obj();
                j.set("enabled", false);
                j.set("spans", Json::Arr(Vec::new()));
                j
            }
        })
    }

    /// Fold one finished job's per-stage wall-clock into the gateway's
    /// `tony_stage_seconds` histograms.  Disabled stores report no
    /// stages, so untraced jobs never touch the histogram lock.
    fn observe_stages(&self, trace: &SpanStore) {
        let stages = trace.stage_millis();
        if stages.is_empty() {
            return;
        }
        let mut hist = self.stage_hist.lock().unwrap();
        for (stage, ms) in stages {
            hist.entry(stage.as_str())
                .or_insert_with(Histogram::stage_seconds)
                .observe(ms as f64 / 1000.0);
        }
    }

    /// The gateway's `GET /metrics` body: every running job's per-task
    /// gauges (labelled `job`/`id`/`user`/`queue`), the cluster's
    /// per-queue scheduler gauges, and the gateway's own counters.
    pub fn metrics_prometheus(&self) -> String {
        use crate::metrics::PromText;
        let mut prom = PromText::new();
        // Snapshot the live set under the lock, render outside it.
        let live: Vec<(u64, String, String, String, Arc<crate::am::AmState>)> = {
            let inner = self.inner.lock().unwrap();
            inner
                .jobs
                .values()
                .filter_map(|j| {
                    j.live
                        .as_ref()
                        .map(|s| (j.id, j.name.clone(), j.user.clone(), j.queue.clone(), s.clone()))
                })
                .collect()
        };
        // Every job's rows are collected first so each metric family is
        // emitted as one contiguous group across all tenant jobs.
        let mut rows = Vec::new();
        for (id, name, user, queue, state) in &live {
            let id_str = id.to_string();
            let labels = [
                ("job", name.as_str()),
                ("id", id_str.as_str()),
                ("user", user.as_str()),
                ("queue", queue.as_str()),
            ];
            rows.extend(crate::metrics::task_rows(state.task_metrics(), &labels));
        }
        crate::metrics::render_task_metrics(&mut prom, &rows);
        crate::metrics::render_cluster_metrics(&mut prom, &self.rm);
        {
            let hist = self.stage_hist.lock().unwrap();
            crate::metrics::render_stage_histograms(&mut prom, &hist);
        }
        let stats = self.stats();
        let (pending, running) = self.live_counts();
        prom.header(
            "tony_gateway_jobs_total",
            "counter",
            "Jobs by admission/terminal outcome since the gateway started.",
        );
        for (outcome, n) in [
            ("accepted", stats.accepted),
            ("rejected", stats.rejected),
            ("finished", stats.finished),
            ("failed", stats.failed),
            ("killed", stats.killed),
        ] {
            prom.sample("tony_gateway_jobs_total", &[("outcome", outcome)], n as f64);
        }
        prom.header("tony_gateway_jobs_pending", "gauge", "Jobs waiting in the gateway queue.");
        prom.sample("tony_gateway_jobs_pending", &[], pending as f64);
        prom.header("tony_gateway_jobs_running", "gauge", "Jobs currently running an AM.");
        prom.sample("tony_gateway_jobs_running", &[], running as f64);
        prom.finish()
    }

    fn stats_json(stats: &GatewayStats) -> Json {
        let mut s = Json::obj();
        s.set("accepted", stats.accepted);
        s.set("rejected", stats.rejected);
        s.set("finished", stats.finished);
        s.set("failed", stats.failed);
        s.set("killed", stats.killed);
        s
    }

    /// RM utilization plus gateway counters.
    pub fn cluster_json(&self) -> Json {
        let mut j = crate::portal::cluster_json(&self.rm);
        let (pending, running) = self.live_counts();
        let mut gw = Json::obj();
        gw.set("workers", self.conf.workers as u64);
        gw.set("queue_depth", self.conf.queue_depth as u64);
        gw.set("pending", pending as u64);
        gw.set("running", running as u64);
        gw.set("stats", Self::stats_json(&self.stats()));
        let mut wal = Json::obj();
        wal.set("enabled", self.wal.is_some());
        if let Some(w) = &self.wal {
            wal.set("epoch", w.epoch());
            wal.set("records_since_snapshot", w.records_since_snapshot());
        }
        gw.set("wal", wal);
        j.set("gateway", gw);
        j
    }

    // ---------------- worker pool ----------------

    fn cluster_total(&self) -> Resource {
        self.rm
            .node_usage()
            .iter()
            .fold(Resource::ZERO, |acc, (_, _, cap)| acc + *cap)
    }

    fn worker_loop(&self) {
        // Blocking pop: an idle worker costs zero CPU (the old loop woke
        // every 100 ms per worker just to re-check a flag).  `pop_wait`
        // returns `None` only once the queue is closed AND drained, so
        // shutdown still finishes everything accepted before the close.
        while let Some(id) = self.queue.pop_wait() {
            if self.is_halted() {
                // Simulated-dead gateway: drain without running so the
                // worker exits promptly once the queue closes.
                continue;
            }
            self.run_job(id);
        }
    }

    /// Drive one accepted job through its full AM lifecycle, retrying
    /// failed applications up to `max_submit_attempts`, and record the
    /// outcome in the history store.
    fn run_job(&self, id: u64) {
        let (conf, ident, trace) = {
            let mut inner = self.inner.lock().unwrap();
            let Some(job) = inner.jobs.get_mut(&id) else { return };
            let ident = (job.user.clone(), job.name.clone(), job.queue.clone());
            if job.kill_requested {
                let did =
                    self.finalize_locked(&mut inner, id, JobState::Killed, "killed before start", 0);
                drop(inner);
                if did {
                    self.wal_terminal(id, JobState::Killed, "killed before start", 0);
                }
                self.record_unran(id, ident, 0, 0, "killed before start");
                return;
            }
            job.state = JobState::Running;
            (job.conf.clone(), ident, job.trace.clone())
        };
        // Pending -> Running is an event `wait_for_state` watchers (and
        // the submit->RUNNING latency bench) observe at wakeup time.
        self.events.notify(tag::STATE);

        let t0 = Instant::now();
        let max_attempts = self.conf.max_submit_attempts.max(1);
        let mut attempt = 0u32;
        let mut final_state = JobState::Failed;
        let mut detail = String::new();
        let mut recorded = false;

        while attempt < max_attempts {
            attempt += 1;
            let client = TonyClient::new(self.rm.clone());
            let opts = SubmitOpts {
                start_portal: false,
                tracking_url: self.api_url().map(|u| format!("{u}/api/v1/jobs/{id}")),
                // Same store across gateway retries: attempt boundaries
                // show up as repeated scheduling/launching stage spans.
                trace: trace.clone(),
            };
            let handle = match client.submit_opts(&conf, &self.conf.artifacts_dir, opts) {
                Ok(h) => h,
                Err(e) => {
                    detail = format!("submit failed: {e:#}");
                    break;
                }
            };
            let kill_raced = {
                let mut inner = self.inner.lock().unwrap();
                match inner.jobs.get_mut(&id) {
                    Some(job) => {
                        job.app_id = Some(handle.app_id);
                        job.attempts = attempt;
                        // Publish the AM state so `/metrics` and the
                        // per-job series/findings endpoints see this job
                        // while it runs.
                        job.live = Some(handle.am_state.clone());
                        job.kill_requested
                    }
                    None => false,
                }
            };
            if kill_raced {
                handle.kill();
            }
            // The attempt is real from the RM's point of view the moment
            // submit returned: record it so recovery can re-attach to
            // this exact application instead of launching a duplicate.
            self.wal_append(&WalRecord::Started {
                id,
                app_id: handle.app_id.to_string(),
                attempt,
            });
            let wall = || t0.elapsed().as_millis() as u64;
            let report = match handle.wait(self.conf.job_timeout) {
                Ok(r) => r,
                Err(e) => {
                    twarn!("gateway", "job {id} attempt {attempt} timed out: {e:#}");
                    handle.kill();
                    let _ = self
                        .rm
                        .wait_for_completion(handle.app_id, Duration::from_secs(10));
                    let _ = handle.record_history(&self.history, wall());
                    recorded = true;
                    detail = format!("timed out after {:?}", self.conf.job_timeout);
                    break;
                }
            };
            if handle.record_history(&self.history, wall()).is_ok() {
                recorded = true;
            }
            detail = report.diagnostics.clone();
            match report.state {
                AppState::Finished => {
                    final_state = JobState::Finished;
                    break;
                }
                AppState::Killed => {
                    final_state = JobState::Killed;
                    break;
                }
                _ => {
                    let killed = {
                        let inner = self.inner.lock().unwrap();
                        inner.jobs.get(&id).map(|j| j.kill_requested).unwrap_or(false)
                    };
                    if killed {
                        final_state = JobState::Killed;
                        break;
                    }
                    if attempt < max_attempts {
                        twarn!(
                            "gateway",
                            "job {id} attempt {attempt}/{max_attempts} failed ({}); retrying",
                            report.diagnostics
                        );
                        continue;
                    }
                    final_state = JobState::Failed;
                }
            }
        }

        if self.is_halted() {
            // Crash simulation fired while this job ran: the recovered
            // gateway owns its terminalization (via re-attach) now.
            return;
        }
        let wall_ms = t0.elapsed().as_millis() as u64;
        if !recorded {
            // The application never produced a report (e.g. submission
            // itself failed) — still leave a trace in the history store.
            self.record_unran(id, ident, attempt, wall_ms, &detail);
        }
        self.finalize(id, final_state, &detail, wall_ms);
    }

    /// Durable trace for a job that never produced an application report
    /// (killed while queued / before start, or submission failure): every
    /// terminal job leaves a history record, run or not.  The caller
    /// captures `(user, name, queue)` under the job-table lock *before*
    /// the job terminalizes — once terminal, a concurrent submit's
    /// `prune_locked` may evict the entry and the identity would be gone.
    fn record_unran(
        &self,
        id: u64,
        (user, name, queue): (String, String, String),
        attempts: u32,
        wall_ms: u64,
        detail: &str,
    ) {
        let _ = self.history.record(&JobRecord {
            app_id: format!("gateway-job-{id:06}"),
            name,
            queue,
            succeeded: false,
            attempts,
            wall_ms,
            diagnostics: format!("[user {user}] {detail}"),
            tasks: Vec::new(),
            series: Json::obj(),
            trace: Json::obj(),
        });
    }

    /// [`Gateway::finalize_locked`] plus the WAL terminal record: the
    /// lock-free entry point for every post-boot terminalization.
    fn finalize(&self, id: u64, state: JobState, detail: &str, wall_ms: u64) {
        if self.is_halted() {
            // A "dead" gateway's leftover threads must not mutate state a
            // recovered incarnation now owns.
            return;
        }
        let did = {
            let mut inner = self.inner.lock().unwrap();
            self.finalize_locked(&mut inner, id, state, detail, wall_ms)
        };
        if did {
            self.wal_terminal(id, state, detail, wall_ms);
        }
    }

    fn wal_terminal(&self, id: u64, state: JobState, detail: &str, wall_ms: u64) {
        self.wal_append(&WalRecord::Terminal {
            id,
            state: state.as_str().to_string(),
            detail: detail.to_string(),
            wall_ms,
        });
    }

    /// Terminalize a job and release its quota bookkeeping.  Idempotent:
    /// only the Pending/Running → terminal edge mutates counters.
    /// Returns whether this call performed the transition (the caller
    /// owes the WAL a terminal record exactly when it did).
    fn finalize_locked(
        &self,
        inner: &mut GwInner,
        id: u64,
        state: JobState,
        detail: &str,
        wall_ms: u64,
    ) -> bool {
        let Some(job) = inner.jobs.get_mut(&id) else { return false };
        if job.state.is_terminal() {
            return false;
        }
        job.state = state;
        job.detail = detail.to_string();
        job.wall_ms = wall_ms;
        // Drop the live observability handle; finished jobs stay
        // inspectable through the down-sampled series in the history
        // store (see `HistoryStore::record_from`).
        job.live = None;
        // Close any span still open (a no-op when the AM already ran its
        // own end_all), fold the stage breakdown into the gateway-wide
        // latency histograms, and drop the live trace handle — the
        // exported span tree lives on in the history record, like the
        // series.
        if let Some(trace) = job.trace.take() {
            trace.end_all();
            self.observe_stages(&trace);
        }
        let (user, queue, resources) = (job.user.clone(), job.queue.clone(), job.resources);
        if let Some(n) = inner.user_active.get_mut(&user) {
            *n = n.saturating_sub(1);
        }
        if let Some(n) = inner.queue_active.get_mut(&queue) {
            *n = n.saturating_sub(1);
        }
        if let Some(held) = inner.user_resources.get_mut(&user) {
            *held = held.checked_sub(&resources).unwrap_or(Resource::ZERO);
        }
        match state {
            JobState::Finished => inner.stats.finished += 1,
            JobState::Killed => inner.stats.killed += 1,
            _ => inner.stats.failed += 1,
        }
        tinfo!("gateway", "job {id} -> {} ({detail})", state.as_str());
        // Terminalization wakes wait_idle / wait_for_state / kill
        // watchers at event time.
        self.events.notify(tag::STATE);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tonyconf::JobConfBuilder;

    fn test_conf(tag: &str) -> GatewayConf {
        let base = std::env::temp_dir().join(format!(
            "tony-gwtest-{tag}-{}-{}",
            std::process::id(),
            crate::util::ids::next_seq()
        ));
        let mut conf = GatewayConf::new(base.join("artifacts"));
        conf.history_dir = base.join("history");
        conf.workers = 2;
        conf.job_timeout = Duration::from_secs(60);
        conf
    }

    fn job_xml(name: &str, steps: u64) -> Configuration {
        JobConfBuilder::new(name)
            .instances("worker", 1)
            .memory("worker", "512m")
            .instances("ps", 1)
            .memory("ps", "512m")
            .set("tony.am.memory", "256m")
            .set("tony.train.steps", &steps.to_string())
            .build()
    }

    #[test]
    fn accepted_job_runs_to_finished_and_lands_in_history() {
        let rm = crate::yarn::ResourceManager::start_uniform(2, Resource::new(4096, 8, 0));
        let gw = Gateway::start(rm, test_conf("e2e")).unwrap();
        let out = gw.submit_conf("alice", 1, job_xml("one", 2));
        let SubmitOutcome::Accepted { id } = out else { panic!("expected accept: {out:?}") };
        assert!(gw.wait_idle(Duration::from_secs(120)), "job never settled");
        assert_eq!(gw.job_state(id), Some(JobState::Finished));
        // Capacity fully returned and the job is in the history store.
        for (_, free, cap) in gw.rm().node_usage() {
            assert_eq!(free, cap, "capacity leaked");
        }
        let ids = gw.history().list().unwrap();
        assert_eq!(ids.len(), 1, "history: {ids:?}");
        assert!(gw.history().load(&ids[0]).unwrap().succeeded);
        gw.shutdown();
    }

    #[test]
    fn rejects_are_recorded_with_reasons() {
        let rm = crate::yarn::ResourceManager::start_uniform(1, Resource::new(4096, 8, 0));
        let mut conf = test_conf("rej");
        conf.quotas.max_active_per_user = 1;
        let gw = Gateway::start(rm, conf).unwrap();

        // Too large for the 4 GiB cluster.
        let big = JobConfBuilder::new("big")
            .instances("worker", 4)
            .memory("worker", "8g")
            .build();
        let out = gw.submit_conf("alice", 1, big);
        let SubmitOutcome::Rejected { id, reason } = out else { panic!("expected reject") };
        assert_eq!(reason.code(), "job-too-large");
        assert_eq!(gw.job_state(id), Some(JobState::Rejected));

        // Invalid spec (no workers).
        let out = gw.submit_conf("alice", 1, JobConfBuilder::new("empty").build());
        let SubmitOutcome::Rejected { reason, .. } = out else { panic!("expected reject") };
        assert_eq!(reason.code(), "invalid-spec");

        // Quota: one active job per user, second submission bounces.
        let out1 = gw.submit_conf("alice", 1, job_xml("a", 2));
        assert!(matches!(out1, SubmitOutcome::Accepted { .. }));
        let out2 = gw.submit_conf("alice", 1, job_xml("b", 2));
        let SubmitOutcome::Rejected { reason, .. } = out2 else { panic!("expected reject") };
        assert_eq!(reason.code(), "user-quota");
        assert!(reason.is_retryable());

        assert!(gw.wait_idle(Duration::from_secs(120)));
        assert_eq!(gw.stats().rejected, 3);
        gw.shutdown();
    }

    #[test]
    fn kill_pending_and_running_jobs() {
        let rm = crate::yarn::ResourceManager::start_uniform(2, Resource::new(4096, 8, 0));
        let mut conf = test_conf("kill");
        conf.workers = 1; // serialize: the second job stays queued
        let gw = Gateway::start(rm, conf).unwrap();
        let SubmitOutcome::Accepted { id: run } =
            gw.submit_conf("alice", 5, job_xml("long", 400))
        else {
            panic!()
        };
        let SubmitOutcome::Accepted { id: queued } =
            gw.submit_conf("bob", 1, job_xml("queued", 2))
        else {
            panic!()
        };
        // The queued job dies immediately.
        assert_eq!(gw.kill(queued), Some(JobState::Killed));
        // Wait for the first to actually start (notification-driven),
        // then kill it.
        assert_eq!(
            gw.wait_for_state(run, JobState::Running, Duration::from_secs(30)),
            Some(JobState::Running)
        );
        gw.kill(run);
        assert!(gw.wait_idle(Duration::from_secs(60)), "killed job never settled");
        assert_eq!(gw.job_state(run), Some(JobState::Killed));
        for (_, free, cap) in gw.rm().node_usage() {
            assert_eq!(free, cap, "capacity leaked after kill");
        }
        gw.shutdown();
    }

    /// Tentpole acceptance: while a gang-mode job is held behind a full
    /// node, its live `/trace` view names the blocking scheduler verdict
    /// and attributes the wait to the scheduling stage; after completion
    /// the span tree replays from history and the stage histograms land
    /// on the gateway scrape.
    #[test]
    fn live_trace_names_blocking_gang_decision() {
        let rm = crate::yarn::ResourceManager::start_uniform(1, Resource::new(2048, 8, 0));
        let gw = Gateway::start(rm, test_conf("gangtrace")).unwrap();

        // Job A (AM 256 + worker 512 + ps 512 = 1280 MB) fills most of
        // the single node and runs long enough to observe B waiting.
        let SubmitOutcome::Accepted { id: hog } = gw.submit_conf("alice", 5, job_xml("hog", 5000))
        else {
            panic!()
        };
        let deadline = Instant::now() + Duration::from_secs(60);
        loop {
            let free = gw.rm().node_usage()[0].1.memory_mb;
            if free <= 768 {
                break;
            }
            assert!(Instant::now() < deadline, "job A never placed (free {free} MB)");
            crate::util::clock::real_sleep(Duration::from_millis(20));
        }

        // B's AM (256 MB) fits in the leftover, but its worker+ps gang
        // (1024 MB) cannot be placed whole until A exits.
        let SubmitOutcome::Accepted { id: blocked } =
            gw.submit_conf("bob", 1, job_xml("blocked", 2))
        else {
            panic!()
        };
        let deadline = Instant::now() + Duration::from_secs(60);
        let trace = loop {
            let t = gw.job_trace_json(blocked).unwrap();
            let waiting = t
                .get("spans")
                .and_then(|s| s.as_arr())
                .map(|spans| {
                    spans.iter().any(|s| {
                        s.get("name").and_then(|n| n.as_str()) == Some("sched.decision")
                            && s.at(&["attrs", "reason"])
                                .and_then(|r| r.as_str())
                                .map(|r| r.starts_with("WAITING"))
                                .unwrap_or(false)
                    })
                })
                .unwrap_or(false);
            let dominant =
                t.at(&["critical_path", "dominant_stage"]).and_then(|d| d.as_str());
            if waiting && dominant == Some("scheduling") {
                break t;
            }
            assert!(
                Instant::now() < deadline,
                "no blocking decision surfaced: {}",
                t.render_pretty()
            );
            crate::util::clock::real_sleep(Duration::from_millis(20));
        };
        let blocking = trace
            .at(&["critical_path", "blocking_decision"])
            .and_then(|b| b.as_str())
            .expect("blocking decision rendered")
            .to_string();
        assert!(blocking.contains("waited"), "got: {blocking}");

        // Free the node: A dies, B's gang places, everything settles.
        gw.kill(hog);
        assert!(gw.wait_idle(Duration::from_secs(120)), "jobs never settled");
        assert_eq!(gw.job_state(blocked), Some(JobState::Finished));

        // The finished job replays from its history record...
        let replay = gw.job_trace_json(blocked).unwrap();
        assert_eq!(replay.get("enabled").and_then(|b| b.as_bool()), Some(true));
        assert!(!replay.get("spans").and_then(|s| s.as_arr()).unwrap().is_empty());
        // ...and the stage histograms made it onto the scrape.
        let prom = gw.metrics_prometheus();
        assert!(prom.contains("tony_stage_seconds_bucket"), "{prom}");
        assert!(prom.contains("stage=\"running\""), "{prom}");
        gw.shutdown();
    }

    /// Regression for the shutdown() vs wait_idle() race: a shutdown
    /// issued while one job is mid-flight and another is still pending
    /// must (a) not hang either call, (b) terminalize every job, and
    /// (c) leave a terminal history record even for jobs that never ran
    /// (killed while queued) — those used to vanish from history.
    #[test]
    fn shutdown_during_pending_to_running_keeps_history_and_drains() {
        let rm = crate::yarn::ResourceManager::start_uniform(2, Resource::new(4096, 8, 0));
        let mut conf = test_conf("race");
        conf.workers = 1; // serialize: later jobs stay queued
        let gw = Gateway::start(rm, conf).unwrap();
        let SubmitOutcome::Accepted { id: running } =
            gw.submit_conf("alice", 5, job_xml("busy", 30))
        else {
            panic!()
        };
        let SubmitOutcome::Accepted { id: queued } = gw.submit_conf("bob", 1, job_xml("q1", 2))
        else {
            panic!()
        };
        let SubmitOutcome::Accepted { id: doomed } = gw.submit_conf("carol", 1, job_xml("q2", 2))
        else {
            panic!()
        };
        // Kill one job while it is still queued: terminal immediately AND
        // it must leave a history record.
        assert_eq!(gw.kill(doomed), Some(JobState::Killed));

        // Shutdown from another thread while the pending->running
        // transitions are in flight; wait_idle concurrently from here.
        let gw2 = gw.clone();
        let shut = std::thread::spawn(move || gw2.shutdown());
        assert!(
            gw.wait_idle(Duration::from_secs(120)),
            "wait_idle hung across a concurrent shutdown: {:?}",
            gw.live_counts()
        );
        shut.join().unwrap();

        for id in [running, queued, doomed] {
            let state = gw.job_state(id).unwrap();
            assert!(state.is_terminal(), "job {id} not terminal: {state:?}");
        }
        assert_eq!(gw.job_state(doomed), Some(JobState::Killed));
        // Every job left a durable record: the two that ran under their
        // app ids, the killed-while-queued one under its gateway id.
        let ids = gw.history().list().unwrap();
        assert_eq!(ids.len(), 3, "history records: {ids:?}");
        assert!(
            ids.iter().any(|i| i.starts_with("gateway-job-")),
            "killed-before-run job missing from history: {ids:?}"
        );
        for (_, free, cap) in gw.rm().node_usage() {
            assert_eq!(free, cap, "capacity leaked");
        }
    }
}
