//! TonY configuration: the `tony.xml` key schema and its typed view.
//!
//! Paper §2.1: users describe the resources their job needs in an XML
//! file — worker/PS instance counts, memory, GPUs per instance, plus
//! scheduler settings (queue, node label).  This module defines the key
//! namespace (mirroring the real TonY's `tony.*` keys), parses a
//! [`crate::xmlconf::Configuration`] into a validated [`JobSpec`], and
//! carries the training-job settings the framework tasks consume.


use anyhow::{bail, Result};

use crate::xmlconf::Configuration;
use crate::yarn::{ContainerRequest, Resource};

/// Well-known task types (any other string is allowed too; these get
/// defaults).  `worker:0` doubles as the chief unless a `chief` type is
/// configured, matching TonY's behaviour.
pub const WORKER: &str = "worker";
pub const PS: &str = "ps";
pub const CHIEF: &str = "chief";
pub const EVALUATOR: &str = "evaluator";

/// Resource + placement demands for one task type.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskTypeSpec {
    pub name: String,
    pub instances: u32,
    pub resource: Resource,
    pub node_label: Option<String>,
    /// Untracked types don't gate job completion (e.g. TensorBoard).
    pub tracked: bool,
}

impl TaskTypeSpec {
    pub fn to_request(&self) -> ContainerRequest {
        let mut req = ContainerRequest::new(self.resource, self.instances);
        if let Some(l) = &self.node_label {
            req = req.with_label(l.clone());
        }
        req
    }
}

/// Parsed + validated job description.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub name: String,
    pub queue: String,
    pub am_resource: Resource,
    pub task_types: Vec<TaskTypeSpec>,
    /// Elastic worker-count bounds (`tony.task.workers.{min,max}`).
    /// Both default to the configured worker instance count, which keeps
    /// the job rigid; `min < max` lets the RM grow/shrink the worker set
    /// mid-run (docs/SCHEDULING.md "Elasticity").
    pub workers_min: u32,
    pub workers_max: u32,
    /// Whole-job restart budget on task failure (paper §2.2 relaunch).
    pub max_attempts: u32,
    pub heartbeat_ms: u64,
    pub max_missed_heartbeats: u32,
    pub train: TrainSpec,
    /// Live-observability knobs (the `tony.metrics.*` keys).
    pub metrics: MetricsSpec,
    /// Causal-tracing knobs (the `tony.trace.*` keys; see `docs/TRACING.md`).
    pub trace: crate::trace::TraceConf,
    /// The raw configuration (executors receive it verbatim, like the
    /// packaged conf archive in real TonY).
    pub conf: Configuration,
}

/// Training-workload settings consumed by the framework tasks.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainSpec {
    pub artifacts_dir: String,
    pub preset: String,
    pub steps: u64,
    pub lr: f64,
    pub seed: u64,
    pub checkpoint_dir: String,
    pub checkpoint_every: u64,
    pub eval_every: u64,
    /// "sync" (barrier data-parallel) or "async" (hogwild-style).
    pub mode: String,
    pub grad_clip: f64,
}

/// Settings for the AM's live metrics registry (see [`crate::metrics`]).
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSpec {
    /// Minimum milliseconds between stored samples per series; 0 turns
    /// time-series collection off entirely (heartbeats still update the
    /// latest-value snapshot the portal serves).
    pub sample_interval_ms: u64,
    /// Ring-buffer capacity of every stored series.
    pub retention_points: usize,
    /// Points per series persisted into the history store at completion.
    pub history_points: usize,
}

impl MetricsSpec {
    pub fn from_conf(conf: &Configuration) -> MetricsSpec {
        MetricsSpec {
            sample_interval_ms: conf.get_u64("tony.metrics.sample-interval-ms", 500),
            retention_points: conf.get_u64("tony.metrics.retention-points", 256) as usize,
            history_points: conf.get_u64("tony.metrics.history-points", 64) as usize,
        }
    }

    /// Bound on the loss-history curve the AM accumulates per task (and
    /// on what an executor re-sends after a rollback — anything longer
    /// would be discarded at the AM anyway).
    pub fn loss_history_cap(&self) -> usize {
        self.retention_points.max(1024)
    }
}

impl JobSpec {
    pub fn from_conf(conf: &Configuration) -> Result<JobSpec> {
        let name = conf.get_or("tony.application.name", "tony-job");
        let queue = conf.get_or("tony.application.queue", "default");
        let am_resource = Resource::new(
            conf.get_size("tony.am.memory", 512 << 20) >> 20,
            conf.get_u32("tony.am.vcores", 1),
            0,
        );
        let mut task_types = Vec::new();
        for ty in [WORKER, PS, CHIEF, EVALUATOR] {
            let instances = conf.get_u32(&format!("tony.{ty}.instances"), 0);
            if instances == 0 {
                continue;
            }
            task_types.push(TaskTypeSpec {
                name: ty.to_string(),
                instances,
                resource: Resource::new(
                    conf.get_size(&format!("tony.{ty}.memory"), 1 << 30) >> 20,
                    conf.get_u32(&format!("tony.{ty}.vcores"), 1),
                    conf.get_u32(&format!("tony.{ty}.gpus"), 0),
                ),
                node_label: conf.get(&format!("tony.{ty}.node-label")),
                // Job completion gates on *tracked* types only: workers
                // (and chief).  PS/evaluator tasks are service-like and get a
                // Stop command once the tracked set succeeds — mirroring
                // TonY's tracked/untracked job types.
                tracked: conf.get_bool(
                    &format!("tony.{ty}.tracked"),
                    matches!(ty, WORKER | CHIEF),
                ),
            });
        }
        if task_types.is_empty() {
            bail!("job must configure at least one task type (tony.worker.instances etc.)");
        }
        if !task_types.iter().any(|t| t.name == WORKER && t.instances > 0) {
            bail!("job must have at least one worker (tony.worker.instances)");
        }
        let train = TrainSpec {
            artifacts_dir: conf.get_or("tony.train.artifacts-dir", "artifacts"),
            preset: conf.get_or("tony.train.preset", "tiny"),
            steps: conf.get_u64("tony.train.steps", 50),
            lr: conf.get_f64("tony.train.lr", 1e-3),
            seed: conf.get_u64("tony.train.seed", 0),
            checkpoint_dir: conf.get_or("tony.train.checkpoint-dir", "/tmp/tony-ckpt"),
            checkpoint_every: conf.get_u64("tony.train.checkpoint-every", 25),
            eval_every: conf.get_u64("tony.train.eval-every", 0),
            mode: conf.get_or("tony.train.mode", "sync"),
            grad_clip: conf.get_f64("tony.train.grad-clip", 0.0),
        };
        if train.mode != "sync" && train.mode != "async" {
            bail!("tony.train.mode must be 'sync' or 'async', got '{}'", train.mode);
        }
        let instances = task_types
            .iter()
            .find(|t| t.name == WORKER)
            .map(|t| t.instances)
            .unwrap_or(0);
        let workers_min = conf.get_u32("tony.task.workers.min", instances);
        let workers_max = conf.get_u32("tony.task.workers.max", instances);
        if workers_min < 1 {
            bail!("tony.task.workers.min must be >= 1, got {workers_min}");
        }
        if workers_min > instances || instances > workers_max {
            bail!(
                "worker instances ({instances}) must sit inside \
                 tony.task.workers.[min={workers_min}, max={workers_max}]"
            );
        }
        Ok(JobSpec {
            name,
            queue,
            am_resource,
            task_types,
            workers_min,
            workers_max,
            max_attempts: conf.get_u32("tony.application.max-attempts", 3),
            heartbeat_ms: conf.get_u64("tony.task.heartbeat-ms", 50),
            max_missed_heartbeats: conf.get_u32("tony.task.max-missed-heartbeats", 20),
            train,
            metrics: MetricsSpec::from_conf(conf),
            trace: crate::trace::TraceConf::from_conf(conf),
            conf: conf.clone(),
        })
    }

    pub fn task_type(&self, name: &str) -> Option<&TaskTypeSpec> {
        self.task_types.iter().find(|t| t.name == name)
    }

    pub fn total_tasks(&self) -> u32 {
        self.task_types.iter().map(|t| t.instances).sum()
    }

    pub fn tracked_tasks(&self) -> u32 {
        self.task_types.iter().filter(|t| t.tracked).map(|t| t.instances).sum()
    }

    pub fn n_workers(&self) -> u32 {
        self.task_type(WORKER).map(|t| t.instances).unwrap_or(0)
    }

    /// True when the worker set may be resized mid-run (min < max).
    pub fn is_elastic(&self) -> bool {
        self.workers_min < self.workers_max
    }

    pub fn n_ps(&self) -> u32 {
        self.task_type(PS).map(|t| t.instances).unwrap_or(0)
    }

    /// Aggregate resources (excluding AM) — used by the client for a
    /// fits-in-cluster sanity check and by Dr. Elephant.
    pub fn total_task_resources(&self) -> Resource {
        self.task_types.iter().fold(Resource::ZERO, |acc, t| {
            let mut r = Resource::ZERO;
            for _ in 0..t.instances {
                r += t.resource;
            }
            acc + r
        })
    }
}

/// Builder for job configurations in code (examples/tests); writes the
/// same `tony.*` keys an XML file would.
#[derive(Debug, Default, Clone)]
pub struct JobConfBuilder {
    conf: Configuration,
}

impl JobConfBuilder {
    pub fn new(name: &str) -> JobConfBuilder {
        let mut conf = Configuration::new();
        conf.set("tony.application.name", name);
        JobConfBuilder { conf }
    }

    pub fn queue(mut self, q: &str) -> Self {
        self.conf.set("tony.application.queue", q);
        self
    }

    pub fn instances(mut self, ty: &str, n: u32) -> Self {
        self.conf.set(&format!("tony.{ty}.instances"), n.to_string());
        self
    }

    pub fn memory(mut self, ty: &str, mem: &str) -> Self {
        self.conf.set(&format!("tony.{ty}.memory"), mem);
        self
    }

    pub fn gpus(mut self, ty: &str, n: u32) -> Self {
        self.conf.set(&format!("tony.{ty}.gpus"), n.to_string());
        self
    }

    pub fn node_label(mut self, ty: &str, label: &str) -> Self {
        self.conf.set(&format!("tony.{ty}.node-label"), label);
        self
    }

    /// Declare the elastic worker-count bounds (`tony.task.workers.*`).
    pub fn elastic_workers(mut self, min: u32, max: u32) -> Self {
        self.conf.set("tony.task.workers.min", min.to_string());
        self.conf.set("tony.task.workers.max", max.to_string());
        self
    }

    pub fn set(mut self, key: &str, value: &str) -> Self {
        self.conf.set(key, value);
        self
    }

    pub fn train(mut self, artifacts_dir: &str, preset: &str, steps: u64) -> Self {
        self.conf.set("tony.train.artifacts-dir", artifacts_dir);
        self.conf.set("tony.train.preset", preset);
        self.conf.set("tony.train.steps", steps.to_string());
        self
    }

    pub fn build(self) -> Configuration {
        self.conf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Configuration {
        JobConfBuilder::new("mnist")
            .queue("ml")
            .instances(WORKER, 4)
            .memory(WORKER, "4g")
            .gpus(WORKER, 1)
            .node_label(WORKER, "gpu")
            .instances(PS, 2)
            .memory(PS, "2g")
            .train("artifacts", "tiny", 100)
            .build()
    }

    #[test]
    fn parse_job_spec() {
        let spec = JobSpec::from_conf(&sample()).unwrap();
        assert_eq!(spec.name, "mnist");
        assert_eq!(spec.queue, "ml");
        assert_eq!(spec.n_workers(), 4);
        assert_eq!(spec.n_ps(), 2);
        let w = spec.task_type(WORKER).unwrap();
        assert_eq!(w.resource, Resource::new(4096, 1, 1));
        assert_eq!(w.node_label.as_deref(), Some("gpu"));
        assert!(w.tracked);
        let ps = spec.task_type(PS).unwrap();
        assert_eq!(ps.resource.gpus, 0, "PS stays CPU-only (heterogeneous asks)");
        assert_eq!(spec.total_tasks(), 6);
        assert_eq!(spec.train.steps, 100);
    }

    #[test]
    fn requests_carry_labels() {
        let spec = JobSpec::from_conf(&sample()).unwrap();
        let req = spec.task_type(WORKER).unwrap().to_request();
        assert_eq!(req.count, 4);
        assert_eq!(req.node_label.as_deref(), Some("gpu"));
    }

    #[test]
    fn rejects_empty_and_workerless() {
        assert!(JobSpec::from_conf(&Configuration::new()).is_err());
        let only_ps = JobConfBuilder::new("x").instances(PS, 2).build();
        assert!(JobSpec::from_conf(&only_ps).is_err());
    }

    #[test]
    fn rejects_bad_mode() {
        let c = JobConfBuilder::new("x")
            .instances(WORKER, 1)
            .set("tony.train.mode", "chaotic")
            .build();
        assert!(JobSpec::from_conf(&c).is_err());
    }

    #[test]
    fn xml_round_trip_preserves_spec() {
        let conf = sample();
        let xml = conf.to_xml();
        let conf2 = Configuration::from_xml_str(&xml).unwrap();
        let a = JobSpec::from_conf(&conf).unwrap();
        let b = JobSpec::from_conf(&conf2).unwrap();
        assert_eq!(a.task_types, b.task_types);
        assert_eq!(a.train, b.train);
    }

    #[test]
    fn total_resources() {
        let spec = JobSpec::from_conf(&sample()).unwrap();
        let total = spec.total_task_resources();
        assert_eq!(total.memory_mb, 4 * 4096 + 2 * 2048);
        assert_eq!(total.gpus, 4);
    }

    #[test]
    fn metrics_spec_defaults_and_overrides() {
        let spec = JobSpec::from_conf(&sample()).unwrap();
        assert_eq!(spec.metrics.sample_interval_ms, 500);
        assert_eq!(spec.metrics.retention_points, 256);
        assert_eq!(spec.metrics.history_points, 64);
        let c = JobConfBuilder::new("m")
            .instances(WORKER, 1)
            .set("tony.metrics.sample-interval-ms", "0")
            .set("tony.metrics.retention-points", "16")
            .set("tony.metrics.history-points", "8")
            .build();
        let spec = JobSpec::from_conf(&c).unwrap();
        assert_eq!(spec.metrics.sample_interval_ms, 0, "0 disables collection");
        assert_eq!(spec.metrics.retention_points, 16);
        assert_eq!(spec.metrics.history_points, 8);
    }

    #[test]
    fn trace_spec_defaults_and_overrides() {
        let spec = JobSpec::from_conf(&sample()).unwrap();
        assert!(spec.trace.enable, "tracing on by default");
        assert_eq!(spec.trace.max_spans_per_job, 256);
        assert!(spec.trace.export);
        let c = JobConfBuilder::new("t")
            .instances(WORKER, 1)
            .set("tony.trace.enable", "false")
            .set("tony.trace.max-spans-per-job", "32")
            .set("tony.trace.export", "false")
            .build();
        let spec = JobSpec::from_conf(&c).unwrap();
        assert!(!spec.trace.enable);
        assert_eq!(spec.trace.max_spans_per_job, 32);
        assert!(!spec.trace.export);
    }

    #[test]
    fn elastic_bounds_default_rigid() {
        let spec = JobSpec::from_conf(&sample()).unwrap();
        assert_eq!(spec.workers_min, 4);
        assert_eq!(spec.workers_max, 4);
        assert!(!spec.is_elastic(), "min == max keeps the job rigid");
    }

    #[test]
    fn elastic_bounds_parse_and_validate() {
        let c = JobConfBuilder::new("e")
            .instances(WORKER, 2)
            .elastic_workers(1, 6)
            .build();
        let spec = JobSpec::from_conf(&c).unwrap();
        assert_eq!((spec.workers_min, spec.workers_max), (1, 6));
        assert!(spec.is_elastic());

        // min must be >= 1 and instances must sit inside [min, max].
        let zero_min = JobConfBuilder::new("e")
            .instances(WORKER, 2)
            .elastic_workers(0, 4)
            .build();
        assert!(JobSpec::from_conf(&zero_min).is_err());
        let outside = JobConfBuilder::new("e")
            .instances(WORKER, 8)
            .elastic_workers(1, 4)
            .build();
        assert!(JobSpec::from_conf(&outside).is_err());
        let inverted = JobConfBuilder::new("e")
            .instances(WORKER, 2)
            .elastic_workers(3, 2)
            .build();
        assert!(JobSpec::from_conf(&inverted).is_err());
    }

    #[test]
    fn evaluator_untracked_by_default() {
        let c = JobConfBuilder::new("x")
            .instances(WORKER, 1)
            .instances(EVALUATOR, 1)
            .build();
        let spec = JobSpec::from_conf(&c).unwrap();
        assert!(!spec.task_type(EVALUATOR).unwrap().tracked);
        assert_eq!(spec.tracked_tasks(), 1);
    }
}
