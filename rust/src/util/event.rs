//! The event layer of the control plane: a [`WakeupBus`] (condvar-backed
//! notifier with typed event tags) and a [`TimerWheel`] driven by the
//! [`Clock`] trait.
//!
//! Together they replace the fixed-interval sleep-poll loops that used to
//! put a 10–20 ms floor under every control-plane reaction (RM grant →
//! AM launch, task exit → recovery, job finish → client wakeup): a
//! producer calls [`WakeupBus::notify`] at the moment something happens,
//! and the consumer blocked in [`WakeupBus::wait_until`] wakes at event
//! time.  Deadlines (registration timeouts, liveness budgets, fallback
//! ticks) are armed on the wheel, whose next deadline bounds the wait.
//!
//! Determinism: every bus is registered with its [`Clock`] (see
//! [`Clock::register_bus`]); a [`crate::util::ManualClock`] notifies its
//! registered buses whenever a test advances time, so deadline waits
//! re-check virtual time without any real sleeping.  This is what lets
//! liveness paths (registration deadline, recovery timeout, gateway
//! drain) run under a manual clock with zero `thread::sleep`.
//!
//! Concurrency contract: [`WakeupBus::wait_until`] *drains* the pending
//! tag mask and therefore belongs to exactly one consumer thread per bus
//! (the AM monitor loop, the executor monitor loop, ...).  Any number of
//! additional threads may use the non-draining [`WakeupBus::wait_seq`],
//! which only observes the monotonic notification sequence (the RM's
//! `wait_for_completion` waiters, gateway `wait_idle`, spec long-polls).

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use super::clock::Clock;

/// Typed event tags.  Events coalesce into a bit mask — a thousand
/// heartbeats between two consumer wakeups cost one set bit, which is
/// why the bus needs no queue (and no queue cap) to stay O(1) per event.
pub mod tag {
    /// A timer fired, the fallback tick elapsed, or a manual clock advanced.
    pub const TICK: u32 = 1 << 0;
    /// The RM granted container(s) to the waiter's application.
    pub const GRANT: u32 = 1 << 1;
    /// Completed-container statuses are ready to collect.
    pub const COMPLETED: u32 = 1 << 2;
    /// A task executor registered its endpoint.
    pub const REGISTERED: u32 = 1 << 3;
    /// A heartbeat advanced meaningful state (e.g. a spec-version ack).
    pub const HEARTBEAT: u32 = 1 << 4;
    /// A task reported its final exit status.
    pub const TASK_EXIT: u32 = 1 << 5;
    /// The cluster spec was (re)built.
    pub const SPEC: u32 = 1 << 6;
    /// An application/job changed state.
    pub const STATE: u32 = 1 << 7;
    /// A kill switch was flipped.
    pub const KILL: u32 = 1 << 8;
    /// The owning daemon is shutting down.
    pub const SHUTDOWN: u32 = 1 << 9;
    /// The RM issued a preemption notice for one of the waiter's
    /// containers (a `Preempted` exit follows after the grace period).
    pub const PREEMPT: u32 = 1 << 10;
    /// The RM queued an elastic resize target for the waiter's
    /// application (delivered on its next allocate round).
    pub const RESIZE: u32 = 1 << 11;

    /// Human-readable rendering of a tag mask (diagnostics/log lines).
    pub fn names(mask: u32) -> String {
        const ALL: [(u32, &str); 12] = [
            (TICK, "tick"),
            (GRANT, "grant"),
            (COMPLETED, "completed"),
            (REGISTERED, "registered"),
            (HEARTBEAT, "heartbeat"),
            (TASK_EXIT, "task-exit"),
            (SPEC, "spec"),
            (STATE, "state"),
            (KILL, "kill"),
            (SHUTDOWN, "shutdown"),
            (PREEMPT, "preempt"),
            (RESIZE, "resize"),
        ];
        let parts: Vec<&str> =
            ALL.iter().filter(|(bit, _)| mask & bit != 0).map(|(_, n)| *n).collect();
        if parts.is_empty() { "none".to_string() } else { parts.join("|") }
    }
}

/// Upper bound on one condvar nap.  A safety backstop only: a bus whose
/// producer forgets a notify (or that was never registered with a manual
/// clock) degrades to a 1 Hz re-check instead of hanging forever.
const MAX_NAP: Duration = Duration::from_millis(1000);

struct BusInner {
    /// Monotonic notification counter ([`WakeupBus::wait_seq`] observes it).
    seq: u64,
    /// Coalesced tags not yet drained by the consumer.
    pending: u32,
}

/// Condvar-backed wakeup notifier with typed, coalescing event tags.
pub struct WakeupBus {
    inner: Mutex<BusInner>,
    cv: Condvar,
}

impl Default for WakeupBus {
    fn default() -> Self {
        Self::new()
    }
}

impl WakeupBus {
    pub fn new() -> WakeupBus {
        WakeupBus { inner: Mutex::new(BusInner { seq: 0, pending: 0 }), cv: Condvar::new() }
    }

    /// New bus already registered with `clock` (manual clocks will wake
    /// it on every time advance).  The normal way to create one.
    pub fn for_clock(clock: &Arc<dyn Clock>) -> Arc<WakeupBus> {
        let bus = Arc::new(WakeupBus::new());
        clock.register_bus(&bus);
        bus
    }

    /// Publish events: OR `tags` into the pending mask, bump the
    /// sequence, and wake every waiter.  O(1); never blocks on consumers.
    pub fn notify(&self, tags: u32) {
        debug_assert!(tags != 0, "notify with empty tag mask");
        let mut g = self.inner.lock().unwrap();
        g.seq += 1;
        g.pending |= tags;
        drop(g);
        self.cv.notify_all();
    }

    /// Drain pending tags without waiting.
    pub fn take(&self) -> u32 {
        std::mem::take(&mut self.inner.lock().unwrap().pending)
    }

    /// Current notification sequence (pair with [`WakeupBus::wait_seq`]:
    /// capture the seq *before* checking your predicate, so a notify
    /// landing between check and wait is never lost).
    pub fn seq(&self) -> u64 {
        self.inner.lock().unwrap().seq
    }

    /// Single-consumer wait: block until any tag is pending or
    /// `clock.now_ms() >= deadline_ms`, then drain and return the pending
    /// mask (0 = deadline reached with no events).
    pub fn wait_until(&self, clock: &dyn Clock, deadline_ms: u64) -> u32 {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.pending != 0 {
                return std::mem::take(&mut g.pending);
            }
            let now = clock.now_ms();
            if now >= deadline_ms {
                return 0;
            }
            let nap = Duration::from_millis(deadline_ms - now).min(MAX_NAP);
            // lint:allow(blocking-under-lock, reason = "Condvar::wait_timeout atomically releases the bus guard while parked")
            let (ng, _) = self.cv.wait_timeout(g, nap).unwrap();
            g = ng;
        }
    }

    /// Multi-waiter wait: block until the notification sequence moves
    /// past `seen` or the deadline passes.  Returns the latest sequence.
    /// Never touches the pending mask, so any number of predicate loops
    /// can share a bus with its draining consumer.
    pub fn wait_seq(&self, clock: &dyn Clock, seen: u64, deadline_ms: u64) -> u64 {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.seq != seen {
                return g.seq;
            }
            let now = clock.now_ms();
            if now >= deadline_ms {
                return g.seq;
            }
            let nap = Duration::from_millis(deadline_ms - now).min(MAX_NAP);
            // lint:allow(blocking-under-lock, reason = "Condvar::wait_timeout atomically releases the bus guard while parked")
            let (ng, _) = self.cv.wait_timeout(g, nap).unwrap();
            g = ng;
        }
    }
}

/// A registry of weakly-held wakeup buses: one producer-side notify
/// fan-out, shared by every "flip a flag and wake the registered
/// waiters" site (manual-clock advances, kill switches) so the
/// retain/upgrade/prune pattern has a single audited home.
#[derive(Default)]
pub struct WakerSet {
    wakers: Mutex<Vec<std::sync::Weak<WakeupBus>>>,
}

impl WakerSet {
    pub fn new() -> WakerSet {
        WakerSet::default()
    }

    /// Register a bus to be notified on [`WakerSet::notify_all`].
    pub fn register(&self, bus: &Arc<WakeupBus>) {
        self.wakers.lock().unwrap().push(Arc::downgrade(bus));
    }

    /// Notify every registered (and still-alive) bus with `tags`,
    /// pruning dropped ones.
    pub fn notify_all(&self, tags: u32) {
        let mut wakers = self.wakers.lock().unwrap();
        wakers.retain(|w| match w.upgrade() {
            Some(bus) => {
                bus.notify(tags);
                true
            }
            None => false,
        });
    }
}

/// Handle to one armed timer (cancelable until it fires).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TimerId(u64);

/// One fired timer, as reported by [`TimerWheel::poll`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fired {
    pub id: TimerId,
    pub deadline_ms: u64,
    pub tags: u32,
}

struct WheelInner {
    /// (deadline, id) → tags; BTreeMap iteration order IS firing order.
    entries: BTreeMap<(u64, u64), u32>,
    /// id → deadline, for O(log n) cancellation.
    by_id: HashMap<u64, u64>,
    next_id: u64,
}

/// Deadline collection driven by a [`Clock`]: arm absolute/relative
/// deadlines, cancel them, ask for the next one (to bound an event
/// wait), and [`TimerWheel::poll`] everything due.
///
/// Capacity-bounded: arming past `capacity` fails (returns `None`) so a
/// timer leak surfaces as a loud failure instead of unbounded memory —
/// the `tony.event.timer-capacity` key sizes it.
pub struct TimerWheel {
    clock: Arc<dyn Clock>,
    inner: Mutex<WheelInner>,
    capacity: usize,
}

impl TimerWheel {
    pub fn new(clock: Arc<dyn Clock>, capacity: usize) -> TimerWheel {
        TimerWheel {
            clock,
            inner: Mutex::new(WheelInner {
                entries: BTreeMap::new(),
                by_id: HashMap::new(),
                next_id: 1,
            }),
            capacity: capacity.max(1),
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Arm a timer at absolute clock time `deadline_ms` carrying `tags`.
    /// `None` when the wheel is at capacity.
    pub fn arm_at(&self, deadline_ms: u64, tags: u32) -> Option<TimerId> {
        let mut g = self.inner.lock().unwrap();
        if g.entries.len() >= self.capacity {
            return None;
        }
        let id = g.next_id;
        g.next_id += 1;
        g.entries.insert((deadline_ms, id), tags);
        g.by_id.insert(id, deadline_ms);
        Some(TimerId(id))
    }

    /// Arm a timer `delay_ms` from now.
    pub fn arm(&self, delay_ms: u64, tags: u32) -> Option<TimerId> {
        self.arm_at(self.clock.now_ms().saturating_add(delay_ms), tags)
    }

    /// Cancel an armed timer.  False when it already fired or never existed.
    pub fn cancel(&self, id: TimerId) -> bool {
        let mut g = self.inner.lock().unwrap();
        match g.by_id.remove(&id.0) {
            Some(deadline) => g.entries.remove(&(deadline, id.0)).is_some(),
            None => false,
        }
    }

    /// Earliest armed deadline (bound your event wait with it).
    pub fn next_deadline(&self) -> Option<u64> {
        self.inner.lock().unwrap().entries.keys().next().map(|(d, _)| *d)
    }

    /// Remove and return everything due at `clock.now_ms()`, in deadline
    /// order (ties fire in arm order).  Same-deadline entries coalesce
    /// into one poll result.
    pub fn poll(&self) -> Vec<Fired> {
        let now = self.clock.now_ms();
        let mut g = self.inner.lock().unwrap();
        let mut fired = Vec::new();
        while let Some((&(deadline, id), &tags)) = g.entries.iter().next() {
            if deadline > now {
                break;
            }
            g.entries.remove(&(deadline, id));
            g.by_id.remove(&id);
            fired.push(Fired { id: TimerId(id), deadline_ms: deadline, tags });
        }
        fired
    }

    /// OR of every due timer's tags (the common "wake hint" form).
    pub fn poll_tags(&self) -> u32 {
        self.poll().iter().fold(0, |acc, f| acc | f.tags)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::ManualClock;

    fn manual() -> (Arc<ManualClock>, Arc<dyn Clock>) {
        let m = ManualClock::shared();
        let c: Arc<dyn Clock> = m.clone();
        (m, c)
    }

    #[test]
    fn bus_notify_drains_and_coalesces() {
        let (_, clock) = manual();
        let bus = WakeupBus::for_clock(&clock);
        bus.notify(tag::GRANT);
        bus.notify(tag::GRANT | tag::TASK_EXIT);
        // Coalesced into one mask; wait returns instantly, no clock needed.
        assert_eq!(bus.wait_until(&*clock, 0), tag::GRANT | tag::TASK_EXIT);
        assert_eq!(bus.take(), 0, "drained");
        // Deadline already passed and nothing pending -> 0.
        assert_eq!(bus.wait_until(&*clock, 0), 0);
    }

    #[test]
    fn bus_wait_until_honors_manual_deadline_without_sleeping() {
        let (m, clock) = manual();
        let bus = WakeupBus::for_clock(&clock);
        let b = bus.clone();
        let c = clock.clone();
        let t = std::thread::spawn(move || b.wait_until(&*c, 500));
        // Advancing the manual clock wakes the waiter (no tags pending):
        // it re-checks virtual time and returns 0 on deadline.  The TICK
        // the clock injects is drained as part of the same wake.
        m.advance_ms(500);
        let got = t.join().unwrap();
        assert!(got == 0 || got == tag::TICK, "deadline return, got {got:#b}");
        assert_eq!(clock.now_ms(), 500);
    }

    #[test]
    fn bus_wait_seq_wakes_on_notify_and_never_drains() {
        let (_, clock) = manual();
        let bus = WakeupBus::for_clock(&clock);
        let seen = bus.seq();
        let b = bus.clone();
        let c = clock.clone();
        let t = std::thread::spawn(move || b.wait_seq(&*c, seen, u64::MAX));
        bus.notify(tag::STATE);
        assert_eq!(t.join().unwrap(), seen + 1);
        // Pending mask untouched by seq waiters: the drainer still sees it.
        assert_eq!(bus.take(), tag::STATE);
    }

    #[test]
    fn wheel_fires_in_deadline_order() {
        let (m, clock) = manual();
        let wheel = TimerWheel::new(clock, 16);
        let a = wheel.arm_at(30, tag::TICK).unwrap();
        let b = wheel.arm_at(10, tag::STATE).unwrap();
        let c = wheel.arm_at(20, tag::KILL).unwrap();
        assert_eq!(wheel.next_deadline(), Some(10));
        assert!(wheel.poll().is_empty(), "nothing due at t=0");
        m.advance_ms(25);
        let fired = wheel.poll();
        assert_eq!(
            fired.iter().map(|f| f.id).collect::<Vec<_>>(),
            vec![b, c],
            "deadline order, not arm order"
        );
        assert_eq!(wheel.next_deadline(), Some(30));
        m.advance_ms(10);
        assert_eq!(wheel.poll_tags(), tag::TICK);
        assert_eq!(wheel.poll(), vec![], "each timer fires exactly once");
        let _ = a;
    }

    #[test]
    fn wheel_cancellation() {
        let (m, clock) = manual();
        let wheel = TimerWheel::new(clock, 16);
        let a = wheel.arm(10, tag::TICK).unwrap();
        let b = wheel.arm(10, tag::STATE).unwrap();
        assert!(wheel.cancel(a));
        assert!(!wheel.cancel(a), "double cancel is a no-op");
        m.advance_ms(10);
        let fired = wheel.poll();
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].id, b);
        assert!(!wheel.cancel(b), "fired timers cannot be canceled");
    }

    #[test]
    fn wheel_coalesces_same_deadline_entries_into_one_poll() {
        let (m, clock) = manual();
        let wheel = TimerWheel::new(clock, 16);
        wheel.arm_at(50, tag::TICK).unwrap();
        wheel.arm_at(50, tag::STATE).unwrap();
        wheel.arm_at(50, tag::KILL).unwrap();
        m.advance_ms(50);
        // One poll returns all three, tags OR-able by the caller.
        assert_eq!(wheel.poll_tags(), tag::TICK | tag::STATE | tag::KILL);
        assert!(wheel.is_empty());
    }

    #[test]
    fn wheel_capacity_bounds_armed_timers() {
        let (_, clock) = manual();
        let wheel = TimerWheel::new(clock, 2);
        assert!(wheel.arm(1, tag::TICK).is_some());
        assert!(wheel.arm(2, tag::TICK).is_some());
        assert!(wheel.arm(3, tag::TICK).is_none(), "cap enforced");
        assert_eq!(wheel.len(), 2);
    }

    #[test]
    fn tag_names_render() {
        assert_eq!(tag::names(0), "none");
        assert_eq!(tag::names(tag::GRANT | tag::KILL), "grant|kill");
    }
}
