//! Cluster entity identifiers, modeled on YARN's id scheme:
//! `application_<clusterTs>_<seq>`, `container_<appSeq>_<seq>`, plus TonY
//! task ids `<jobtype>:<index>` (e.g. `worker:0`, `ps:1`).

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide monotonic sequence (cheap unique ids inside the sim).
static GLOBAL_SEQ: AtomicU64 = AtomicU64::new(1);

pub fn next_seq() -> u64 {
    GLOBAL_SEQ.fetch_add(1, Ordering::Relaxed)
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ApplicationId {
    pub cluster_ts: u64,
    pub seq: u64,
}

impl ApplicationId {
    /// Parse the `application_<clusterTs>_<seq>` rendering (inverse of
    /// `Display`; zero-padding on the sequence is accepted but not
    /// required).  Used by gateway crash recovery, which persists app
    /// ids as strings in its WAL.
    pub fn parse(s: &str) -> Option<ApplicationId> {
        let rest = s.strip_prefix("application_")?;
        let (ts, seq) = rest.split_once('_')?;
        Some(ApplicationId { cluster_ts: ts.parse().ok()?, seq: seq.parse().ok()? })
    }
}

impl fmt::Display for ApplicationId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "application_{}_{:04}", self.cluster_ts, self.seq)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ContainerId {
    pub app: ApplicationId,
    pub seq: u64,
}

impl fmt::Display for ContainerId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "container_{}_{:04}_{:06}", self.app.cluster_ts, self.app.seq, self.seq)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

impl fmt::Display for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "node{:03}", self.0)
    }
}

/// A TonY task identity: job type ("worker", "ps", "chief", "evaluator")
/// plus index within the type — exactly how TF_CONFIG addresses tasks.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskId {
    pub job_type: String,
    pub index: u32,
}

impl TaskId {
    pub fn new(job_type: impl Into<String>, index: u32) -> Self {
        TaskId { job_type: job_type.into(), index }
    }

    pub fn parse(s: &str) -> Option<TaskId> {
        let (ty, idx) = s.rsplit_once(':')?;
        if ty.is_empty() {
            return None;
        }
        Some(TaskId { job_type: ty.to_string(), index: idx.parse().ok()? })
    }
}

impl fmt::Display for TaskId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}", self.job_type, self.index)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let app = ApplicationId { cluster_ts: 1700000000, seq: 12 };
        assert_eq!(app.to_string(), "application_1700000000_0012");
        let c = ContainerId { app, seq: 3 };
        assert_eq!(c.to_string(), "container_1700000000_0012_000003");
        assert_eq!(NodeId(5).to_string(), "node005");
    }

    #[test]
    fn application_id_parse_round_trip() {
        let app = ApplicationId { cluster_ts: 1700000000, seq: 12 };
        assert_eq!(ApplicationId::parse(&app.to_string()), Some(app));
        assert_eq!(
            ApplicationId::parse("application_5_7"),
            Some(ApplicationId { cluster_ts: 5, seq: 7 })
        );
        assert_eq!(ApplicationId::parse("container_1_0001_000001"), None);
        assert_eq!(ApplicationId::parse("application_x_1"), None);
        assert_eq!(ApplicationId::parse("application_1"), None);
    }

    #[test]
    fn task_id_round_trip() {
        let t = TaskId::new("worker", 3);
        assert_eq!(t.to_string(), "worker:3");
        assert_eq!(TaskId::parse("worker:3"), Some(t));
        assert_eq!(TaskId::parse("ps:0"), Some(TaskId::new("ps", 0)));
        assert_eq!(TaskId::parse("nope"), None);
        assert_eq!(TaskId::parse(":1"), None);
        assert_eq!(TaskId::parse("worker:x"), None);
    }

    #[test]
    fn seq_monotonic() {
        let a = next_seq();
        let b = next_seq();
        assert!(b > a);
    }
}
