//! SplitMix64: tiny, fast, deterministic PRNG.
//!
//! Used by the synthetic-data generator, the chaos (failure-injection)
//! schedules, the contention workload generator, and the property-test
//! harness.  Deterministic seeding is what makes the benches and property
//! tests reproducible run-to-run.

#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`; `n` must be > 0.
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Rejection sampling to avoid modulo bias for large n.
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.next_below(hi - lo + 1)
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.next_below(xs.len() as u64) as usize]
    }

    /// Fork an independent stream (for per-component determinism).
    pub fn fork(&mut self) -> SplitMix64 {
        SplitMix64::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(7);
        let mut b = SplitMix64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn known_vector() {
        // SplitMix64 reference outputs for seed 0 (from the original
        // Steele/Lea/Flood appendix).
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(r.next_u64(), 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn next_below_in_range() {
        let mut r = SplitMix64::new(1);
        for _ in 0..1000 {
            assert!(r.next_below(10) < 10);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(2);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(3);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
