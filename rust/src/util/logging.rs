//! Minimal leveled logger with per-component tags.
//!
//! Every daemon in the simulated cluster (RM, NMs, AM, TaskExecutors, PS
//! and worker tasks) logs through this so integration tests and the
//! examples produce a single interleaved, timestamped trace — the moral
//! equivalent of the per-container log files a YARN cluster would give
//! you, which the TonY portal links back to (paper §2.2).

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, Mutex, OnceLock, Weak};
use std::time::Instant;

use super::clock::Clock;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Trace = 0,
    Debug = 1,
    Info = 2,
    Warn = 3,
    Error = 4,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Trace => "TRACE",
            Level::Debug => "DEBUG",
            Level::Info => "INFO",
            Level::Warn => "WARN",
            Level::Error => "ERROR",
        }
    }

    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "trace" => Some(Level::Trace),
            "debug" => Some(Level::Debug),
            "info" => Some(Level::Info),
            "warn" | "warning" => Some(Level::Warn),
            "error" => Some(Level::Error),
            _ => None,
        }
    }
}

static MIN_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static START: OnceLock<Instant> = OnceLock::new();
/// Optional capture sink used by tests to assert on log output.
static CAPTURE: OnceLock<Mutex<Option<Vec<String>>>> = OnceLock::new();
/// The clock timestamps are read from once one is registered (see
/// [`set_clock`]).  Held weakly: the process-global logger must never
/// keep a test's clock alive past its scenario.
static CLOCK: OnceLock<Mutex<Weak<dyn Clock>>> = OnceLock::new();

/// Retention cap for the capture sink: a long-running capture (or a test
/// that forgets `capture_take`) keeps the newest lines instead of
/// growing without bound.
const CAPTURE_CAP: usize = 4096;

fn start() -> Instant {
    *START.get_or_init(Instant::now)
}

/// Route log timestamps through `clock` — registerable like a
/// [`crate::util::event::WakeupBus`] on a clock.  The RM registers its
/// control-plane clock at startup, so a `ManualClock` scenario logs
/// *virtual* time instead of silently reverting to the real `Instant`
/// the process started at.  When the registered clock is dropped the
/// logger falls back to the `Instant` baseline.
pub fn set_clock(clock: &Arc<dyn Clock>) {
    let ck = CLOCK.get_or_init(|| {
        let none: Weak<dyn Clock> = Weak::<super::clock::SystemClock>::new();
        Mutex::new(none)
    });
    *ck.lock().unwrap() = Arc::downgrade(clock);
}

fn now_secs() -> f64 {
    CLOCK
        .get()
        .and_then(|ck| ck.lock().unwrap().upgrade())
        .map(|c| c.now_ms() as f64 / 1000.0)
        .unwrap_or_else(|| start().elapsed().as_secs_f64())
}

/// Initialize from `TONY_LOG` (trace|debug|info|warn|error); idempotent.
pub fn init_from_env() {
    start();
    if let Ok(v) = std::env::var("TONY_LOG") {
        if let Some(l) = Level::parse(&v) {
            set_level(l);
        }
    }
}

pub fn set_level(l: Level) {
    MIN_LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match MIN_LEVEL.load(Ordering::Relaxed) {
        0 => Level::Trace,
        1 => Level::Debug,
        2 => Level::Info,
        3 => Level::Warn,
        _ => Level::Error,
    }
}

pub fn enabled(l: Level) -> bool {
    l >= level()
}

/// Begin capturing log lines (in addition to stderr). Tests only.
pub fn capture_start() {
    let cap = CAPTURE.get_or_init(|| Mutex::new(None));
    *cap.lock().unwrap() = Some(Vec::new());
}

/// Stop capturing and return the captured lines.
pub fn capture_take() -> Vec<String> {
    let cap = CAPTURE.get_or_init(|| Mutex::new(None));
    cap.lock().unwrap().take().unwrap_or_default()
}

pub fn log(l: Level, component: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let line = format!("[{:>9.3}s {:5} {}] {}", now_secs(), l.as_str(), component, msg);
    if let Some(cap) = CAPTURE.get() {
        if let Some(buf) = cap.lock().unwrap().as_mut() {
            if buf.len() >= CAPTURE_CAP {
                buf.remove(0);
            }
            buf.push(line.clone());
        }
    }
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "{line}");
}

#[macro_export]
macro_rules! tlog {
    ($lvl:expr, $comp:expr, $($arg:tt)*) => {
        $crate::util::logging::log($lvl, $comp, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! tinfo {
    ($comp:expr, $($arg:tt)*) => { $crate::tlog!($crate::util::logging::Level::Info, $comp, $($arg)*) };
}

#[macro_export]
macro_rules! twarn {
    ($comp:expr, $($arg:tt)*) => { $crate::tlog!($crate::util::logging::Level::Warn, $comp, $($arg)*) };
}

#[macro_export]
macro_rules! terror {
    ($comp:expr, $($arg:tt)*) => { $crate::tlog!($crate::util::logging::Level::Error, $comp, $($arg)*) };
}

#[macro_export]
macro_rules! tdebug {
    ($comp:expr, $($arg:tt)*) => { $crate::tlog!($crate::util::logging::Level::Debug, $comp, $($arg)*) };
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The capture sink and clock registration are process-global, so
    /// the tests that poke them must not interleave.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn level_parse_and_order() {
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("bogus"), None);
        assert!(Level::Trace < Level::Error);
    }

    #[test]
    fn capture_records_lines() {
        let _g = TEST_LOCK.lock().unwrap();
        let old = level();
        set_level(Level::Info);
        capture_start();
        crate::tinfo!("test", "hello {}", 42);
        let lines = capture_take();
        set_level(old);
        assert!(lines.iter().any(|l| l.contains("hello 42")), "{lines:?}");
    }

    #[test]
    fn capture_is_bounded() {
        let _g = TEST_LOCK.lock().unwrap();
        let old = level();
        set_level(Level::Info);
        capture_start();
        for i in 0..CAPTURE_CAP + 10 {
            crate::tinfo!("bound-test", "line {}", i);
        }
        let lines = capture_take();
        set_level(old);
        assert_eq!(lines.len(), CAPTURE_CAP);
        let newest = format!("line {}", CAPTURE_CAP + 9);
        assert!(lines.iter().any(|l| l.ends_with(&newest)), "newest line missing");
        assert!(
            !lines.iter().any(|l| l.contains("bound-test") && l.ends_with("line 0")),
            "oldest line should have been evicted"
        );
    }

    #[test]
    fn timestamps_follow_a_registered_manual_clock() {
        let _g = TEST_LOCK.lock().unwrap();
        let old = level();
        set_level(Level::Info);
        let manual = crate::util::clock::ManualClock::shared();
        manual.set_ms(12_345);
        let clock: Arc<dyn Clock> = manual.clone();
        // Tests in other modules may start an RM concurrently, which
        // re-registers its own clock; retry so the registration and the
        // log line land without an overwrite in between.
        let mut seen = false;
        for _ in 0..16 {
            set_clock(&clock);
            capture_start();
            crate::tinfo!("clock-test", "tick");
            let lines = capture_take();
            if lines
                .iter()
                .any(|l| l.contains("clock-test") && l.contains("12.345s"))
            {
                seen = true;
                break;
            }
        }
        // Release the manual clock: once the strong refs drop, the weak
        // registration dies and the logger reverts to the Instant base.
        drop(clock);
        drop(manual);
        set_level(old);
        assert!(seen, "no captured line carried the manual-clock timestamp");
    }
}
