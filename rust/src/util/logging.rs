//! Minimal leveled logger with per-component tags.
//!
//! Every daemon in the simulated cluster (RM, NMs, AM, TaskExecutors, PS
//! and worker tasks) logs through this so integration tests and the
//! examples produce a single interleaved, timestamped trace — the moral
//! equivalent of the per-container log files a YARN cluster would give
//! you, which the TonY portal links back to (paper §2.2).

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Trace = 0,
    Debug = 1,
    Info = 2,
    Warn = 3,
    Error = 4,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Trace => "TRACE",
            Level::Debug => "DEBUG",
            Level::Info => "INFO",
            Level::Warn => "WARN",
            Level::Error => "ERROR",
        }
    }

    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "trace" => Some(Level::Trace),
            "debug" => Some(Level::Debug),
            "info" => Some(Level::Info),
            "warn" | "warning" => Some(Level::Warn),
            "error" => Some(Level::Error),
            _ => None,
        }
    }
}

static MIN_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static START: OnceLock<Instant> = OnceLock::new();
/// Optional capture sink used by tests to assert on log output.
static CAPTURE: OnceLock<Mutex<Option<Vec<String>>>> = OnceLock::new();

fn start() -> Instant {
    *START.get_or_init(Instant::now)
}

/// Initialize from `TONY_LOG` (trace|debug|info|warn|error); idempotent.
pub fn init_from_env() {
    start();
    if let Ok(v) = std::env::var("TONY_LOG") {
        if let Some(l) = Level::parse(&v) {
            set_level(l);
        }
    }
}

pub fn set_level(l: Level) {
    MIN_LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match MIN_LEVEL.load(Ordering::Relaxed) {
        0 => Level::Trace,
        1 => Level::Debug,
        2 => Level::Info,
        3 => Level::Warn,
        _ => Level::Error,
    }
}

pub fn enabled(l: Level) -> bool {
    l >= level()
}

/// Begin capturing log lines (in addition to stderr). Tests only.
pub fn capture_start() {
    let m = CAPTURE.get_or_init(|| Mutex::new(None));
    *m.lock().unwrap() = Some(Vec::new());
}

/// Stop capturing and return the captured lines.
pub fn capture_take() -> Vec<String> {
    let m = CAPTURE.get_or_init(|| Mutex::new(None));
    m.lock().unwrap().take().unwrap_or_default()
}

pub fn log(l: Level, component: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let elapsed = start().elapsed();
    let line = format!(
        "[{:>9.3}s {:5} {}] {}",
        elapsed.as_secs_f64(),
        l.as_str(),
        component,
        msg
    );
    if let Some(m) = CAPTURE.get() {
        if let Some(buf) = m.lock().unwrap().as_mut() {
            buf.push(line.clone());
        }
    }
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "{line}");
}

#[macro_export]
macro_rules! tlog {
    ($lvl:expr, $comp:expr, $($arg:tt)*) => {
        $crate::util::logging::log($lvl, $comp, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! tinfo {
    ($comp:expr, $($arg:tt)*) => { $crate::tlog!($crate::util::logging::Level::Info, $comp, $($arg)*) };
}

#[macro_export]
macro_rules! twarn {
    ($comp:expr, $($arg:tt)*) => { $crate::tlog!($crate::util::logging::Level::Warn, $comp, $($arg)*) };
}

#[macro_export]
macro_rules! terror {
    ($comp:expr, $($arg:tt)*) => { $crate::tlog!($crate::util::logging::Level::Error, $comp, $($arg)*) };
}

#[macro_export]
macro_rules! tdebug {
    ($comp:expr, $($arg:tt)*) => { $crate::tlog!($crate::util::logging::Level::Debug, $comp, $($arg)*) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse_and_order() {
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("bogus"), None);
        assert!(Level::Trace < Level::Error);
    }

    #[test]
    fn capture_records_lines() {
        let old = level();
        set_level(Level::Info);
        capture_start();
        crate::tinfo!("test", "hello {}", 42);
        let lines = capture_take();
        set_level(old);
        assert!(lines.iter().any(|l| l.contains("hello 42")), "{lines:?}");
    }
}
