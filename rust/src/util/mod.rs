//! Small shared utilities: logging, clocks, deterministic PRNG, id
//! generation, host:port parsing, human-readable byte sizes.
//!
//! This repo builds fully offline on `std` + the vendored `xla`/`anyhow`
//! crates only, so these are hand-rolled rather than pulled from crates.io.

pub mod bytes;
pub mod clock;
pub mod event;
pub mod hostport;
pub mod ids;
pub mod logging;
pub mod prng;

pub use clock::{Clock, ManualClock, SystemClock};
pub use event::{tag, TimerWheel, WakeupBus};
pub use hostport::HostPort;
pub use prng::SplitMix64;
