//! Human-readable byte quantities ("4g", "2048m") as used by tony.xml
//! resource settings, mirroring Hadoop's configuration conventions.

/// Parse "512", "512k", "64m", "4g", "1t" (case-insensitive) into bytes.
pub fn parse_size(s: &str) -> Option<u64> {
    let s = s.trim();
    if s.is_empty() {
        return None;
    }
    let (num, mult) = match s.chars().last().unwrap().to_ascii_lowercase() {
        'k' => (&s[..s.len() - 1], 1u64 << 10),
        'm' => (&s[..s.len() - 1], 1u64 << 20),
        'g' => (&s[..s.len() - 1], 1u64 << 30),
        't' => (&s[..s.len() - 1], 1u64 << 40),
        c if c.is_ascii_digit() => (s, 1),
        _ => return None,
    };
    let num = num.trim();
    if let Ok(v) = num.parse::<u64>() {
        return v.checked_mul(mult);
    }
    // Accept decimals like "1.5g" (format_size emits these).
    let v: f64 = num.parse().ok()?;
    if !(v.is_finite() && v >= 0.0) {
        return None;
    }
    Some((v * mult as f64).round() as u64)
}

/// Format bytes with the largest exact-ish unit, e.g. 4294967296 -> "4.0g".
pub fn format_size(bytes: u64) -> String {
    const UNITS: [(&str, u64); 4] =
        [("t", 1 << 40), ("g", 1 << 30), ("m", 1 << 20), ("k", 1 << 10)];
    for (suffix, mult) in UNITS {
        if bytes >= mult {
            return format!("{:.1}{}", bytes as f64 / mult as f64, suffix);
        }
    }
    format!("{bytes}b")
}

/// Format a duration in ms as "1.2s" / "340ms" / "2m03s".
pub fn format_ms(ms: u64) -> String {
    if ms >= 60_000 {
        format!("{}m{:02}s", ms / 60_000, (ms % 60_000) / 1000)
    } else if ms >= 1000 {
        format!("{:.1}s", ms as f64 / 1000.0)
    } else {
        format!("{ms}ms")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sizes() {
        assert_eq!(parse_size("512"), Some(512));
        assert_eq!(parse_size("2k"), Some(2048));
        assert_eq!(parse_size("64m"), Some(64 << 20));
        assert_eq!(parse_size("4G"), Some(4 << 30));
        assert_eq!(parse_size("1t"), Some(1 << 40));
        assert_eq!(parse_size(""), None);
        assert_eq!(parse_size("x"), None);
        assert_eq!(parse_size("4x"), None);
    }

    #[test]
    fn format_sizes() {
        assert_eq!(format_size(512), "512b");
        assert_eq!(format_size(4 << 30), "4.0g");
        assert_eq!(format_size(1536), "1.5k");
    }

    #[test]
    fn format_durations() {
        assert_eq!(format_ms(340), "340ms");
        assert_eq!(format_ms(1200), "1.2s");
        assert_eq!(format_ms(123_000), "2m03s");
    }

    #[test]
    fn size_round_trippish() {
        for v in [1u64 << 10, 1 << 20, 1 << 30] {
            assert_eq!(parse_size(&format_size(v)).unwrap(), v);
        }
    }
}
