//! Clock abstraction: real time for daemons, manual time for deterministic
//! scheduler / liveness-expiry unit tests.
//!
//! This file is also the **only** place in `rust/src/` allowed to call
//! `std::thread::sleep` (CI greps for strays): control-plane code blocks
//! on [`crate::util::event::WakeupBus`] waits bounded by clock deadlines,
//! and the handful of genuinely real-time paths (non-blocking accept
//! backoff, simulated child-task cadences, remote HTTP polling) route
//! through [`real_sleep`] so every such site is explicit and auditable.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::event::{tag, WakerSet, WakeupBus};

/// Milliseconds-since-start monotonic clock.
pub trait Clock: Send + Sync {
    fn now_ms(&self) -> u64;
    /// Sleep (real clocks) or no-op (manual clocks, which tests advance).
    fn sleep(&self, d: Duration);
    /// Register a wakeup bus with this clock.  Manual clocks notify every
    /// registered bus (`tag::TICK`) when time advances, so deadline waits
    /// re-check virtual time immediately; real clocks need no hook.
    fn register_bus(&self, _bus: &Arc<WakeupBus>) {}

    /// `now_ms() + d`, saturating at both the `u128→u64` narrowing and
    /// the addition — the one audited home for turning a `Duration`
    /// timeout into an absolute clock deadline.
    fn deadline_after(&self, d: Duration) -> u64 {
        self.now_ms().saturating_add(d.as_millis().min(u64::MAX as u128) as u64)
    }
}

/// Real-time sleep for the few paths that are *about* wall time rather
/// than control-plane events: non-blocking accept-loop backoff, simulated
/// child-task poll cadences (the stand-ins for real child processes),
/// remote-HTTP client polling, and timing-sensitive tests.  Lives here so
/// the CI no-stray-sleep grep has exactly one allowed home.
pub fn real_sleep(d: Duration) {
    // lint:allow(thread-sleep, reason = "the one allowed home for real sleeps; everything else routes through here or Clock::sleep")
    std::thread::sleep(d);
}

/// Wall-clock-backed implementation.
pub struct SystemClock {
    start: Instant,
}

impl SystemClock {
    pub fn new() -> Self {
        SystemClock { start: Instant::now() }
    }

    pub fn shared() -> Arc<dyn Clock> {
        Arc::new(SystemClock::new())
    }
}

impl Default for SystemClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for SystemClock {
    fn now_ms(&self) -> u64 {
        self.start.elapsed().as_millis() as u64
    }

    fn sleep(&self, d: Duration) {
        // lint:allow(thread-sleep, reason = "SystemClock is the wall-clock backend; Clock::sleep must really sleep here")
        std::thread::sleep(d);
    }
}

/// Manually-advanced clock for deterministic tests.  Advancing time
/// notifies every bus registered via [`Clock::register_bus`], which is
/// what lets event-driven liveness paths (registration deadlines,
/// recovery timeouts, fallback ticks) fire under test control with zero
/// real sleeping.
pub struct ManualClock {
    now: AtomicU64,
    wakers: WakerSet,
}

impl ManualClock {
    pub fn new() -> Self {
        ManualClock { now: AtomicU64::new(0), wakers: WakerSet::new() }
    }

    pub fn shared() -> Arc<ManualClock> {
        Arc::new(ManualClock::new())
    }

    pub fn advance_ms(&self, ms: u64) {
        self.now.fetch_add(ms, Ordering::SeqCst);
        self.wakers.notify_all(tag::TICK);
    }

    pub fn set_ms(&self, ms: u64) {
        self.now.store(ms, Ordering::SeqCst);
        self.wakers.notify_all(tag::TICK);
    }
}

impl Default for ManualClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for ManualClock {
    fn now_ms(&self) -> u64 {
        self.now.load(Ordering::SeqCst)
    }

    fn sleep(&self, _d: Duration) {}

    fn register_bus(&self, bus: &Arc<WakeupBus>) {
        self.wakers.register(bus);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manual_clock_advances() {
        let c = ManualClock::new();
        assert_eq!(c.now_ms(), 0);
        c.advance_ms(150);
        assert_eq!(c.now_ms(), 150);
        c.set_ms(42);
        assert_eq!(c.now_ms(), 42);
    }

    #[test]
    fn system_clock_monotonic() {
        let c = SystemClock::new();
        let a = c.now_ms();
        c.sleep(Duration::from_millis(2));
        let b = c.now_ms();
        assert!(b >= a);
    }

    #[test]
    fn manual_clock_advance_notifies_registered_buses() {
        let clock = ManualClock::shared();
        let as_dyn: Arc<dyn Clock> = clock.clone();
        let bus = WakeupBus::for_clock(&as_dyn);
        clock.advance_ms(10);
        assert_eq!(bus.take(), tag::TICK, "advance wakes registered buses");
        // Dropped buses are pruned, not notified.
        drop(bus);
        clock.advance_ms(1); // must not panic on the dead weak ref
    }
}
