//! Clock abstraction: real time for daemons, manual time for deterministic
//! scheduler / liveness-expiry unit tests.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Milliseconds-since-start monotonic clock.
pub trait Clock: Send + Sync {
    fn now_ms(&self) -> u64;
    /// Sleep (real clocks) or no-op (manual clocks, which tests advance).
    fn sleep(&self, d: Duration);
}

/// Wall-clock-backed implementation.
pub struct SystemClock {
    start: Instant,
}

impl SystemClock {
    pub fn new() -> Self {
        SystemClock { start: Instant::now() }
    }

    pub fn shared() -> Arc<dyn Clock> {
        Arc::new(SystemClock::new())
    }
}

impl Default for SystemClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for SystemClock {
    fn now_ms(&self) -> u64 {
        self.start.elapsed().as_millis() as u64
    }

    fn sleep(&self, d: Duration) {
        std::thread::sleep(d);
    }
}

/// Manually-advanced clock for deterministic tests.
pub struct ManualClock {
    now: AtomicU64,
}

impl ManualClock {
    pub fn new() -> Self {
        ManualClock { now: AtomicU64::new(0) }
    }

    pub fn shared() -> Arc<ManualClock> {
        Arc::new(ManualClock::new())
    }

    pub fn advance_ms(&self, ms: u64) {
        self.now.fetch_add(ms, Ordering::SeqCst);
    }

    pub fn set_ms(&self, ms: u64) {
        self.now.store(ms, Ordering::SeqCst);
    }
}

impl Default for ManualClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for ManualClock {
    fn now_ms(&self) -> u64 {
        self.now.load(Ordering::SeqCst)
    }

    fn sleep(&self, _d: Duration) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manual_clock_advances() {
        let c = ManualClock::new();
        assert_eq!(c.now_ms(), 0);
        c.advance_ms(150);
        assert_eq!(c.now_ms(), 150);
        c.set_ms(42);
        assert_eq!(c.now_ms(), 42);
    }

    #[test]
    fn system_clock_monotonic() {
        let c = SystemClock::new();
        let a = c.now_ms();
        c.sleep(Duration::from_millis(2));
        let b = c.now_ms();
        assert!(b >= a);
    }
}
