//! `host:port` endpoints as passed around in cluster specs.

use std::fmt;
use std::net::SocketAddr;

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct HostPort {
    pub host: String,
    pub port: u16,
}

impl HostPort {
    pub fn new(host: impl Into<String>, port: u16) -> Self {
        HostPort { host: host.into(), port }
    }

    pub fn localhost(port: u16) -> Self {
        HostPort::new("127.0.0.1", port)
    }

    pub fn parse(s: &str) -> Option<HostPort> {
        let (h, p) = s.rsplit_once(':')?;
        if h.is_empty() {
            return None;
        }
        Some(HostPort { host: h.to_string(), port: p.parse().ok()? })
    }

    pub fn from_addr(a: SocketAddr) -> Self {
        HostPort { host: a.ip().to_string(), port: a.port() }
    }
}

impl fmt::Display for HostPort {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}", self.host, self.port)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let hp = HostPort::localhost(8080);
        assert_eq!(hp.to_string(), "127.0.0.1:8080");
        assert_eq!(HostPort::parse("127.0.0.1:8080"), Some(hp));
        assert_eq!(HostPort::parse("nohost"), None);
        assert_eq!(HostPort::parse(":80"), None);
        assert_eq!(HostPort::parse("h:notaport"), None);
    }
}
