//! Job-history store: persistent records of finished applications, the
//! TonY-history-server / Dr. Elephant-ingest role.  Each finished job is
//! written as one JSON document; the store can list, load, and aggregate
//! them (e.g. feeding `drelephant::analyze` after the fact), and the CLI
//! renders them.  Since the live-metrics pipeline landed, a record also
//! carries a down-sampled copy of the job's per-task time series (see
//! [`crate::metrics`]), so finished jobs stay inspectable through the
//! gateway's `/api/v1/jobs/{id}/metrics` endpoint.
//!
//! # Example
//!
//! ```
//! use tony::history::{HistoryStore, JobRecord};
//! use tony::json::Json;
//!
//! let dir = std::env::temp_dir().join(format!("tony-hist-doc-{}", std::process::id()));
//! let store = HistoryStore::new(&dir);
//! store
//!     .record(&JobRecord {
//!         app_id: "application_1_0001".into(),
//!         name: "doc".into(),
//!         queue: "default".into(),
//!         succeeded: true,
//!         attempts: 1,
//!         wall_ms: 1200,
//!         diagnostics: String::new(),
//!         tasks: Vec::new(),
//!         series: Json::obj(),
//!         trace: Json::obj(),
//!     })
//!     .unwrap();
//! assert!(store.load("application_1_0001").unwrap().succeeded);
//! std::fs::remove_dir_all(&dir).unwrap();
//! ```

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::framework::TaskMetrics;
use crate::json::Json;
use crate::util::ids::ApplicationId;

#[derive(Debug, Clone, PartialEq)]
pub struct JobRecord {
    pub app_id: String,
    pub name: String,
    pub queue: String,
    pub succeeded: bool,
    pub attempts: u32,
    pub wall_ms: u64,
    pub diagnostics: String,
    /// (task id, metrics) snapshots at completion.
    pub tasks: Vec<(String, TaskMetrics)>,
    /// Down-sampled time series captured at completion, in the same
    /// `{"tasks": {...}, "queues": {...}}` shape the live endpoints
    /// serve (see [`crate::metrics::Registry::downsampled_json`]).
    /// Empty object for jobs that never ran or predate the pipeline.
    pub series: Json,
    /// The job's lifecycle trace (span tree + critical path) captured at
    /// completion, in the shape `SpanStore::trace_json` serves live (see
    /// [`crate::trace`]).  Empty object when tracing was off, export was
    /// disabled (`tony.trace.export=false`), or the record predates the
    /// tracing plane.
    pub trace: Json,
}

impl JobRecord {
    pub fn to_json(&self) -> Json {
        let mut tasks = Vec::new();
        for (id, m) in &self.tasks {
            let mut t = Json::obj();
            t.set("task", id.as_str());
            t.set("step", m.step);
            t.set("loss", m.loss as f64);
            t.set("eval_loss", m.eval_loss as f64);
            t.set("tokens", m.tokens_done);
            t.set("step_ms_avg", m.step_ms_avg);
            t.set("mem_used_mb", m.mem_used_mb);
            t.set("updates_applied", m.updates_applied);
            t.set("finished", m.finished);
            tasks.push(t);
        }
        let mut j = Json::obj();
        j.set("app_id", self.app_id.as_str());
        j.set("name", self.name.as_str());
        j.set("queue", self.queue.as_str());
        j.set("succeeded", self.succeeded);
        j.set("attempts", self.attempts as u64);
        j.set("wall_ms", self.wall_ms);
        j.set("diagnostics", self.diagnostics.as_str());
        j.set("tasks", Json::Arr(tasks));
        j.set("series", self.series.clone());
        j.set("trace", self.trace.clone());
        j
    }

    pub fn from_json(j: &Json) -> Result<JobRecord> {
        let s = |k: &str| -> Result<String> {
            j.get(k)
                .and_then(|v| v.as_str())
                .map(str::to_string)
                .ok_or_else(|| anyhow!("history record missing '{k}'"))
        };
        let mut tasks = Vec::new();
        for t in j.get("tasks").and_then(|t| t.as_arr()).unwrap_or(&[]) {
            let id = t
                .get("task")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("task record missing id"))?
                .to_string();
            tasks.push((
                id,
                TaskMetrics {
                    step: t.get("step").and_then(|v| v.as_u64()).unwrap_or(0),
                    loss: t.get("loss").and_then(|v| v.as_f64()).unwrap_or(0.0) as f32,
                    eval_loss: t.get("eval_loss").and_then(|v| v.as_f64()).unwrap_or(0.0) as f32,
                    tokens_done: t.get("tokens").and_then(|v| v.as_u64()).unwrap_or(0),
                    step_ms_avg: t.get("step_ms_avg").and_then(|v| v.as_f64()).unwrap_or(0.0),
                    mem_used_mb: t.get("mem_used_mb").and_then(|v| v.as_u64()).unwrap_or(0),
                    updates_applied: t
                        .get("updates_applied")
                        .and_then(|v| v.as_u64())
                        .unwrap_or(0),
                    finished: t.get("finished").and_then(|v| v.as_bool()).unwrap_or(false),
                    ..Default::default()
                },
            ));
        }
        Ok(JobRecord {
            app_id: s("app_id")?,
            name: s("name")?,
            queue: s("queue")?,
            succeeded: j.get("succeeded").and_then(|v| v.as_bool()).unwrap_or(false),
            attempts: j.get("attempts").and_then(|v| v.as_u64()).unwrap_or(0) as u32,
            wall_ms: j.get("wall_ms").and_then(|v| v.as_u64()).unwrap_or(0),
            diagnostics: s("diagnostics").unwrap_or_default(),
            tasks,
            // Records written before the metrics pipeline have no series,
            // and ones before the tracing plane have no trace.
            series: j.get("series").cloned().unwrap_or_else(Json::obj),
            trace: j.get("trace").cloned().unwrap_or_else(Json::obj),
        })
    }
}

/// Directory-backed history store.
#[derive(Debug, Clone)]
pub struct HistoryStore {
    dir: PathBuf,
}

impl HistoryStore {
    pub fn new(dir: impl Into<PathBuf>) -> HistoryStore {
        HistoryStore { dir: dir.into() }
    }

    pub fn default_location() -> HistoryStore {
        HistoryStore::new(std::env::temp_dir().join("tony-history"))
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Persist one record, crash- and concurrency-safe: the document is
    /// written to a temp file named uniquely per writer (pid + process
    /// sequence), fsynced, then atomically renamed into place.  Concurrent
    /// gateway jobs — or two attempts racing on the same app id — can
    /// therefore never interleave bytes or leave a torn record; readers
    /// observe either the old document or the new one.  Orphaned `.tmp`
    /// files from a crash are invisible to `list`/`load` (wrong suffix)
    /// and are swept here once they are old enough that no live writer
    /// can still own them.
    pub fn record(&self, rec: &JobRecord) -> Result<PathBuf> {
        std::fs::create_dir_all(&self.dir)?;
        self.sweep_stale_tmp();
        let path = self.dir.join(format!("{}.json", rec.app_id));
        let tmp = self.dir.join(format!(
            ".{}.{}-{}.tmp",
            rec.app_id,
            std::process::id(),
            crate::util::ids::next_seq()
        ));
        {
            use std::io::Write;
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(rec.to_json().render_pretty().as_bytes())?;
            f.sync_all()?;
        }
        if let Err(e) = std::fs::rename(&tmp, &path) {
            let _ = std::fs::remove_file(&tmp);
            return Err(e).with_context(|| format!("publishing {}", path.display()));
        }
        Ok(path)
    }

    /// Best-effort removal of temp files abandoned by crashed writers.
    /// Only files untouched for an hour are removed, so a concurrent
    /// writer's in-flight temp file is never yanked out from under its
    /// rename.
    fn sweep_stale_tmp(&self) {
        self.sweep_orphans(std::time::Duration::from_secs(3600));
    }

    /// Remove temp files abandoned by crashed writers (a crash between
    /// create and rename leaks the `.{app}.{pid}-{seq}.tmp` file forever
    /// otherwise) once they are at least `min_age` old.  Called with an
    /// hour's grace on every `record` and at gateway boot; tests pass
    /// `Duration::ZERO` to sweep unconditionally.  Returns how many
    /// orphans were removed.
    pub fn sweep_orphans(&self, min_age: std::time::Duration) -> usize {
        let Ok(entries) = std::fs::read_dir(&self.dir) else { return 0 };
        let mut removed = 0;
        for ent in entries.flatten() {
            let name = ent.file_name().to_string_lossy().into_owned();
            if !(name.starts_with('.') && name.ends_with(".tmp")) {
                continue;
            }
            let stale = min_age.is_zero()
                || ent
                    .metadata()
                    .and_then(|m| m.modified())
                    .ok()
                    .and_then(|t| t.elapsed().ok())
                    .map(|age| age >= min_age)
                    .unwrap_or(false);
            if stale && std::fs::remove_file(ent.path()).is_ok() {
                removed += 1;
            }
        }
        removed
    }

    /// Capture a record from a live job handle + RM report.
    pub fn record_from(
        &self,
        app_id: ApplicationId,
        report: &crate::yarn::AppReport,
        am_state: &crate::am::AmState,
        wall_ms: u64,
    ) -> Result<PathBuf> {
        let snap = am_state.snapshot_json();
        let mut tasks = Vec::new();
        if let Some(arr) = snap.get("tasks").and_then(|t| t.as_arr()) {
            for t in arr {
                let id = t.get("task").and_then(|v| v.as_str()).unwrap_or("?").to_string();
                tasks.push((
                    id,
                    TaskMetrics {
                        step: t.get("step").and_then(|v| v.as_u64()).unwrap_or(0),
                        loss: t.get("loss").and_then(|v| v.as_f64()).unwrap_or(0.0) as f32,
                        step_ms_avg: t.get("step_ms").and_then(|v| v.as_f64()).unwrap_or(0.0),
                        mem_used_mb: t.get("mem_mb").and_then(|v| v.as_u64()).unwrap_or(0),
                        updates_applied: t.get("updates").and_then(|v| v.as_u64()).unwrap_or(0),
                        tokens_done: t.get("tokens").and_then(|v| v.as_u64()).unwrap_or(0),
                        ..Default::default()
                    },
                ));
            }
        }
        self.record(&JobRecord {
            app_id: app_id.to_string(),
            name: report.name.clone(),
            queue: report.queue.clone(),
            succeeded: report.state == crate::yarn::AppState::Finished,
            attempts: am_state.attempt(),
            wall_ms,
            diagnostics: report.diagnostics.clone(),
            tasks,
            // Persist the live series, down-sampled to the configured
            // budget, so the job stays inspectable after completion.
            series: am_state
                .metrics_registry()
                .downsampled_json(am_state.job_spec().metrics.history_points),
            // Persist the span tree only when the job opted in
            // (`tony.trace.export`); the empty object keeps old readers
            // working and marks "no trace" for the /trace endpoint.
            trace: match am_state.trace() {
                Some(t) if t.export() => t.trace_json(),
                _ => Json::obj(),
            },
        })
    }

    pub fn list(&self) -> Result<Vec<String>> {
        let mut out = Vec::new();
        let entries = match std::fs::read_dir(&self.dir) {
            Ok(e) => e,
            Err(_) => return Ok(out),
        };
        for ent in entries.flatten() {
            let name = ent.file_name().to_string_lossy().into_owned();
            if let Some(id) = name.strip_suffix(".json") {
                out.push(id.to_string());
            }
        }
        out.sort();
        Ok(out)
    }

    pub fn load(&self, app_id: &str) -> Result<JobRecord> {
        let path = self.dir.join(format!("{app_id}.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        JobRecord::from_json(&Json::parse(&text)?)
    }

    /// Aggregate success-rate / attempt statistics across all records —
    /// the fleet-level view a Dr. Elephant dashboard would chart.
    pub fn summary(&self) -> Result<HistorySummary> {
        let mut s = HistorySummary::default();
        for id in self.list()? {
            let rec = self.load(&id)?;
            s.jobs += 1;
            if rec.succeeded {
                s.succeeded += 1;
            }
            s.total_attempts += rec.attempts as u64;
            s.total_wall_ms += rec.wall_ms;
            s.total_tokens += rec
                .tasks
                .iter()
                .filter(|(id, _)| id.starts_with("worker"))
                .map(|(_, m)| m.tokens_done)
                .sum::<u64>();
        }
        Ok(s)
    }
}

#[derive(Debug, Clone, Default, PartialEq)]
pub struct HistorySummary {
    pub jobs: u64,
    pub succeeded: u64,
    pub total_attempts: u64,
    pub total_wall_ms: u64,
    pub total_tokens: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store(tag: &str) -> HistoryStore {
        let d = std::env::temp_dir().join(format!(
            "tony-hist-{tag}-{}-{}",
            std::process::id(),
            crate::util::ids::next_seq()
        ));
        let _ = std::fs::remove_dir_all(&d);
        HistoryStore::new(d)
    }

    fn sample(id: &str, ok: bool) -> JobRecord {
        JobRecord {
            app_id: id.to_string(),
            name: "j".into(),
            queue: "default".into(),
            succeeded: ok,
            attempts: 2,
            wall_ms: 1000,
            diagnostics: "d".into(),
            tasks: vec![(
                "worker:0".into(),
                TaskMetrics { step: 10, loss: 2.0, tokens_done: 2560, ..Default::default() },
            )],
            series: Json::obj(),
            trace: Json::obj(),
        }
    }

    #[test]
    fn sweep_orphans_removes_stale_tmp_only() {
        let s = store("orphans");
        s.record(&sample("application_1_0001", true)).unwrap();
        // A fake orphan: what a writer crashing between create and
        // rename leaves behind.
        let orphan = s.dir().join(".application_1_0002.12345-1.tmp");
        std::fs::write(&orphan, b"torn half-record").unwrap();
        // Freshly written — the hour-graced sweep must leave it alone
        // (a live writer could still own it).
        assert_eq!(s.sweep_orphans(std::time::Duration::from_secs(3600)), 0);
        assert!(orphan.exists());
        // The unconditional sweep (boot-time semantics in tests) removes
        // exactly the orphan; the real record is untouched.
        assert_eq!(s.sweep_orphans(std::time::Duration::ZERO), 1);
        assert!(!orphan.exists());
        assert_eq!(s.list().unwrap().len(), 1);
        assert!(s.load("application_1_0001").is_ok());
        let _ = std::fs::remove_dir_all(s.dir());
    }

    #[test]
    fn record_load_round_trip() {
        let s = store("rt");
        let rec = sample("application_1_0001", true);
        s.record(&rec).unwrap();
        let back = s.load("application_1_0001").unwrap();
        assert_eq!(back.app_id, rec.app_id);
        assert_eq!(back.succeeded, rec.succeeded);
        assert_eq!(back.tasks.len(), 1);
        assert_eq!(back.tasks[0].1.tokens_done, 2560);
        let _ = std::fs::remove_dir_all(s.dir());
    }

    #[test]
    fn list_and_summary() {
        let s = store("sum");
        s.record(&sample("application_1_0001", true)).unwrap();
        s.record(&sample("application_1_0002", false)).unwrap();
        assert_eq!(s.list().unwrap().len(), 2);
        let sum = s.summary().unwrap();
        assert_eq!(sum.jobs, 2);
        assert_eq!(sum.succeeded, 1);
        assert_eq!(sum.total_attempts, 4);
        assert_eq!(sum.total_tokens, 2 * 2560);
        let _ = std::fs::remove_dir_all(s.dir());
    }

    #[test]
    fn concurrent_records_never_tear() {
        let s = store("conc");
        let mut handles = Vec::new();
        for w in 0..8u32 {
            let s2 = s.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..20u32 {
                    let mut rec = sample("application_9_0001", w % 2 == 0);
                    rec.wall_ms = (w * 100 + i) as u64;
                    s2.record(&rec).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // The surviving record parses cleanly: concurrent writers can race
        // on who wins, but never interleave or tear the document.
        let rec = s.load("application_9_0001").unwrap();
        assert_eq!(rec.app_id, "application_9_0001");
        // And no stray temp files are visible to the store.
        assert_eq!(s.list().unwrap(), vec!["application_9_0001".to_string()]);
        let _ = std::fs::remove_dir_all(s.dir());
    }

    #[test]
    fn series_round_trips_through_the_store() {
        let s = store("series");
        // Build a real registry series and persist its down-sampled form.
        let reg = crate::metrics::Registry::new(32, 1);
        for i in 0..16u64 {
            reg.observe_task("worker:0", i, (16 - i) as f64, 8.0, 128, true);
        }
        let mut rec = sample("application_5_0001", true);
        rec.series = reg.downsampled_json(8);
        s.record(&rec).unwrap();
        let back = s.load("application_5_0001").unwrap();
        assert_eq!(back.series, rec.series, "series must survive the JSON round-trip");
        let loss = back
            .series
            .at(&["tasks", "worker:0", "loss"])
            .and_then(|a| a.as_arr())
            .expect("loss series present");
        assert!(loss.len() <= 8, "down-sampled to the budget");
        let last = loss.last().unwrap().as_arr().unwrap();
        assert_eq!(last[1].as_f64(), Some(1.0), "newest point kept");
        // Records without a series block (pre-pipeline) still load.
        let legacy = rec.to_json();
        let mut stripped = legacy.as_obj().unwrap().clone();
        stripped.remove("series");
        let legacy_rec = JobRecord::from_json(&Json::Obj(stripped)).unwrap();
        assert_eq!(legacy_rec.series, Json::obj());
        let _ = std::fs::remove_dir_all(s.dir());
    }

    #[test]
    fn missing_record_errors() {
        let s = store("missing");
        assert!(s.load("nope").is_err());
        assert_eq!(s.list().unwrap().len(), 0);
        assert_eq!(s.summary().unwrap(), HistorySummary::default());
    }
}
