//! Checkpoint store: atomic, versioned snapshots of the flat parameter
//! vector (+ optimizer moments), enabling the paper's restart semantics
//! ("the ML tasks can then restore from the last checkpoint and continue
//! training", §2.2).
//!
//! Format (little-endian):
//! ```text
//!   magic "TONYCKPT" | u32 version | u64 step | u64 n | f32[n] params
//!   | u8 has_moments | (u64 n, f32[n] m, f32[n] v)?
//!   | u64 fletcher-ish checksum over the payload
//! ```
//! Writes go to `ckpt-<step>.tony.tmp` then rename — a torn write never
//! shadows the previous checkpoint.  `latest()` picks the highest step
//! whose checksum validates, so a corrupt file falls back to the previous
//! snapshot instead of failing the restore.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 8] = b"TONYCKPT";
const FORMAT_VERSION: u32 = 1;

#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub params: Vec<f32>,
    /// Adam moments per parameter (kept so restores are exact).
    pub moments: Option<(Vec<f32>, Vec<f32>)>,
}

fn checksum(bytes: &[u8]) -> u64 {
    // Fletcher-style rolling sum; fast and adequate for torn-write
    // detection (not cryptographic).
    let (mut a, mut b) = (1u64, 0u64);
    for chunk in bytes.chunks(4096) {
        for &x in chunk {
            a = a.wrapping_add(x as u64);
            b = b.wrapping_add(a);
        }
        a %= 0xFFFF_FFFB;
        b %= 0xFFFF_FFFB;
    }
    (b << 32) | a
}

fn push_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.extend_from_slice(&(xs.len() as u64).to_le_bytes());
    let raw = unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4) };
    out.extend_from_slice(raw);
}

fn read_u64(b: &[u8], pos: &mut usize) -> Result<u64> {
    if *pos + 8 > b.len() {
        bail!("truncated checkpoint");
    }
    let v = u64::from_le_bytes(b[*pos..*pos + 8].try_into().unwrap());
    *pos += 8;
    Ok(v)
}

fn read_f32s(b: &[u8], pos: &mut usize) -> Result<Vec<f32>> {
    let n = read_u64(b, pos)? as usize;
    let bytes = n.checked_mul(4).context("overflow")?;
    if *pos + bytes > b.len() {
        bail!("truncated checkpoint payload");
    }
    let mut out = vec![0f32; n];
    unsafe {
        std::ptr::copy_nonoverlapping(b[*pos..].as_ptr(), out.as_mut_ptr() as *mut u8, bytes);
    }
    *pos += bytes;
    Ok(out)
}

impl Checkpoint {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(32 + self.params.len() * 4);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        out.extend_from_slice(&self.step.to_le_bytes());
        push_f32s(&mut out, &self.params);
        match &self.moments {
            None => out.push(0),
            Some((m, v)) => {
                out.push(1);
                push_f32s(&mut out, m);
                push_f32s(&mut out, v);
            }
        }
        let sum = checksum(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    pub fn decode(bytes: &[u8]) -> Result<Checkpoint> {
        if bytes.len() < MAGIC.len() + 4 + 8 + 8 {
            bail!("checkpoint too short");
        }
        if &bytes[..8] != MAGIC {
            bail!("bad checkpoint magic");
        }
        let (payload, sum_bytes) = bytes.split_at(bytes.len() - 8);
        let stored = u64::from_le_bytes(sum_bytes.try_into().unwrap());
        if checksum(payload) != stored {
            bail!("checkpoint checksum mismatch");
        }
        let mut pos = 8;
        let ver = u32::from_le_bytes(payload[pos..pos + 4].try_into().unwrap());
        pos += 4;
        if ver != FORMAT_VERSION {
            bail!("unsupported checkpoint version {ver}");
        }
        let step = read_u64(payload, &mut pos)?;
        let params = read_f32s(payload, &mut pos)?;
        let moments = match payload.get(pos) {
            Some(0) => {
                pos += 1;
                None
            }
            Some(1) => {
                pos += 1;
                let m = read_f32s(payload, &mut pos)?;
                let v = read_f32s(payload, &mut pos)?;
                if m.len() != params.len() || v.len() != params.len() {
                    bail!("moment length mismatch");
                }
                Some((m, v))
            }
            _ => bail!("truncated moments flag"),
        };
        if pos != payload.len() {
            bail!("trailing bytes in checkpoint");
        }
        Ok(Checkpoint { step, params, moments })
    }
}

/// Directory of versioned checkpoints.
#[derive(Debug, Clone)]
pub struct CheckpointStore {
    dir: PathBuf,
    /// Keep at most this many snapshots (oldest pruned). 0 = unlimited.
    pub keep: usize,
}

impl CheckpointStore {
    pub fn new(dir: impl Into<PathBuf>) -> CheckpointStore {
        CheckpointStore { dir: dir.into(), keep: 3 }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn path_for(&self, step: u64) -> PathBuf {
        self.dir.join(format!("ckpt-{step:012}.tony"))
    }

    /// Atomic write (tmp + rename) and prune.
    pub fn save(&self, ckpt: &Checkpoint) -> Result<PathBuf> {
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("creating {}", self.dir.display()))?;
        let final_path = self.path_for(ckpt.step);
        // lint:allow(config-undocumented, reason = "atomic-write temp suffix, not a config key") lint:allow(config-outside-conf, reason = "ditto")
        let tmp = final_path.with_extension("tony.tmp");
        std::fs::write(&tmp, ckpt.encode())?;
        std::fs::rename(&tmp, &final_path)?;
        if self.keep > 0 {
            let mut steps = self.list()?;
            while steps.len() > self.keep {
                let oldest = steps.remove(0);
                let _ = std::fs::remove_file(self.path_for(oldest));
            }
        }
        Ok(final_path)
    }

    /// All checkpoint steps, ascending.
    pub fn list(&self) -> Result<Vec<u64>> {
        let mut steps = Vec::new();
        let entries = match std::fs::read_dir(&self.dir) {
            Ok(e) => e,
            Err(_) => return Ok(steps),
        };
        for ent in entries.flatten() {
            let name = ent.file_name().to_string_lossy().into_owned();
            if let Some(rest) = name.strip_prefix("ckpt-") {
                if let Some(num) = rest.strip_suffix(".tony") {
                    if let Ok(step) = num.parse::<u64>() {
                        steps.push(step);
                    }
                }
            }
        }
        steps.sort_unstable();
        Ok(steps)
    }

    /// Newest checkpoint that decodes cleanly (corrupt ones are skipped).
    pub fn latest(&self) -> Result<Option<Checkpoint>> {
        let steps = self.list()?;
        for step in steps.into_iter().rev() {
            let path = self.path_for(step);
            match std::fs::read(&path).map_err(anyhow::Error::from).and_then(|b| Checkpoint::decode(&b)) {
                Ok(c) => return Ok(Some(c)),
                Err(e) => {
                    crate::twarn!("ckpt", "skipping corrupt {}: {e}", path.display());
                }
            }
        }
        Ok(None)
    }

    pub fn clear(&self) -> Result<()> {
        if self.dir.exists() {
            std::fs::remove_dir_all(&self.dir)?;
        }
        Ok(())
    }

    // ---- restore markers (per-generation recovery audit trail) ----
    //
    // Every time a chief (re)seeds the parameter servers — initial
    // launch, full-attempt restart, or a surgical PS recovery — it
    // records the cluster-spec version it did so at and the step it
    // restored from.  A surgical *worker* recovery seeds nothing, so it
    // leaves no marker: tests and benches use the marker count to prove
    // survivors were never rolled back.

    fn marker_path(&self, version: u64) -> PathBuf {
        self.dir.join(format!("restore-v{version:06}.marker"))
    }

    /// Record that the incarnation at cluster-spec `version` (re)seeded
    /// training state from `step`.  Idempotent per version (atomic
    /// tmp+rename, same torn-write discipline as snapshots).
    pub fn mark_restore(&self, version: u64, step: u64) -> Result<PathBuf> {
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("creating {}", self.dir.display()))?;
        let path = self.marker_path(version);
        let tmp = path.with_extension("marker.tmp");
        std::fs::write(&tmp, format!("{step}\n"))?;
        std::fs::rename(&tmp, &path)?;
        Ok(path)
    }

    /// All restore markers as (spec version, restored-from step),
    /// ascending by version.
    pub fn restore_markers(&self) -> Result<Vec<(u64, u64)>> {
        let mut out = Vec::new();
        let entries = match std::fs::read_dir(&self.dir) {
            Ok(e) => e,
            Err(_) => return Ok(out),
        };
        for ent in entries.flatten() {
            let name = ent.file_name().to_string_lossy().into_owned();
            let Some(rest) = name.strip_prefix("restore-v") else { continue };
            let Some(num) = rest.strip_suffix(".marker") else { continue };
            let Ok(version) = num.parse::<u64>() else { continue };
            let step = std::fs::read_to_string(ent.path())
                .ok()
                .and_then(|s| s.trim().parse::<u64>().ok())
                .unwrap_or(0);
            out.push((version, step));
        }
        out.sort_unstable();
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "tony-ckpt-{tag}-{}-{}",
            std::process::id(),
            crate::util::ids::next_seq()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample(step: u64, n: usize) -> Checkpoint {
        Checkpoint {
            step,
            params: (0..n).map(|i| (i as f32 * 0.1).sin()).collect(),
            moments: Some((vec![0.1; n], vec![0.2; n])),
        }
    }

    #[test]
    fn encode_decode_round_trip() {
        let c = sample(42, 1000);
        assert_eq!(Checkpoint::decode(&c.encode()).unwrap(), c);
        let no_moments = Checkpoint { moments: None, ..sample(7, 10) };
        assert_eq!(Checkpoint::decode(&no_moments.encode()).unwrap(), no_moments);
    }

    #[test]
    fn corruption_detected() {
        let c = sample(1, 100);
        let mut b = c.encode();
        let mid = b.len() / 2;
        b[mid] ^= 0xFF;
        assert!(Checkpoint::decode(&b).is_err());
        assert!(Checkpoint::decode(&b[..b.len() - 3]).is_err());
        assert!(Checkpoint::decode(b"short").is_err());
    }

    #[test]
    fn store_save_list_latest() {
        let dir = tmpdir("store");
        let store = CheckpointStore::new(&dir);
        for step in [10, 20, 30] {
            store.save(&sample(step, 50)).unwrap();
        }
        assert_eq!(store.list().unwrap(), vec![10, 20, 30]);
        assert_eq!(store.latest().unwrap().unwrap().step, 30);
        store.clear().unwrap();
        assert!(store.latest().unwrap().is_none());
    }

    #[test]
    fn prune_keeps_newest() {
        let dir = tmpdir("prune");
        let mut store = CheckpointStore::new(&dir);
        store.keep = 2;
        for step in 1..=5 {
            store.save(&sample(step, 10)).unwrap();
        }
        assert_eq!(store.list().unwrap(), vec![4, 5]);
        store.clear().unwrap();
    }

    #[test]
    fn restore_markers_round_trip() {
        let dir = tmpdir("markers");
        let store = CheckpointStore::new(&dir);
        assert!(store.restore_markers().unwrap().is_empty());
        store.mark_restore(1, 0).unwrap();
        store.mark_restore(4, 10).unwrap();
        // Re-marking the same version overwrites, not duplicates.
        store.mark_restore(4, 10).unwrap();
        assert_eq!(store.restore_markers().unwrap(), vec![(1, 0), (4, 10)]);
        // Markers do not pollute the snapshot listing.
        store.save(&sample(20, 10)).unwrap();
        assert_eq!(store.list().unwrap(), vec![20]);
        assert_eq!(store.latest().unwrap().unwrap().step, 20);
        store.clear().unwrap();
    }

    #[test]
    fn corrupt_latest_falls_back() {
        let dir = tmpdir("fallback");
        let store = CheckpointStore::new(&dir);
        store.save(&sample(10, 20)).unwrap();
        store.save(&sample(20, 20)).unwrap();
        // Corrupt the newest file on disk.
        let newest = dir.join("ckpt-000000000020.tony");
        let mut bytes = std::fs::read(&newest).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x55;
        std::fs::write(&newest, bytes).unwrap();
        let latest = store.latest().unwrap().unwrap();
        assert_eq!(latest.step, 10, "falls back past the corrupt snapshot");
        store.clear().unwrap();
    }
}
