//! Failure injection: deterministic kill schedules against running jobs.
//!
//! Exercises the fault-tolerance loop (§2.2 + surgical recovery): kill a
//! task container or a whole node at a chosen moment and let the AM
//! relaunch just the dead tasks (or, on escalation, tear down and
//! relaunch the whole attempt).  Used by `examples/fault_tolerance.rs`,
//! the recovery benches, and the integration tests.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::am::AmState;
use crate::util::ids::NodeId;
use crate::util::SplitMix64;
use crate::yarn::ResourceManager;
use crate::{tinfo, twarn};

/// Marker carried by every panic raised from an injected crash point so
/// test harnesses (and panic hooks) can tell a simulated process death
/// from a real bug.
pub const CRASH_PANIC: &str = "tony-chaos-crash";

/// Named control-plane crash sites (`tony.chaos.crash-point=<site>`).
///
/// Unlike [`Fault`], which kills *containers* of a running job, a crash
/// site kills the **gateway process itself** — deterministically, at a
/// named instant in the WAL append or snapshot path — so the crash
/// recovery suite (`rust/tests/crash_recovery.rs`) can assert the
/// durability invariant at every window: acked submissions survive,
/// unacked ones are absent or re-admitted exactly once, never
/// duplicated.  See docs/DURABILITY.md for what each site leaves on disk.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CrashSite {
    /// Die in the WAL append after staging a torn half-frame: the record
    /// was never durable and must vanish on replay.
    WalBeforeFsync,
    /// Die after the frame is durable but before the submitter is acked:
    /// the record survives; recovery re-admits it exactly once.
    WalAfterFsync,
    /// Die with the new snapshot fully written + fsynced under its temp
    /// name but never renamed into place.
    BeforeRename,
    /// Die with only half the snapshot document written.
    MidSnapshot,
    /// Die after the admission record is durable but before the job is
    /// queued or the caller acked.
    PostAdmitPreAck,
}

impl CrashSite {
    /// Every site, for exhaustive test matrices.
    pub const ALL: [CrashSite; 5] = [
        CrashSite::WalBeforeFsync,
        CrashSite::WalAfterFsync,
        CrashSite::BeforeRename,
        CrashSite::MidSnapshot,
        CrashSite::PostAdmitPreAck,
    ];

    /// Parse the `tony.chaos.crash-point` value; unknown names are `None`
    /// (the caller warns — chaos keys must never fail a real boot).
    pub fn parse(s: &str) -> Option<CrashSite> {
        match s.trim() {
            "wal-before-fsync" => Some(CrashSite::WalBeforeFsync),
            "wal-after-fsync" => Some(CrashSite::WalAfterFsync),
            "before-rename" => Some(CrashSite::BeforeRename),
            "mid-snapshot" => Some(CrashSite::MidSnapshot),
            "post-admit-pre-ack" => Some(CrashSite::PostAdmitPreAck),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            CrashSite::WalBeforeFsync => "wal-before-fsync",
            CrashSite::WalAfterFsync => "wal-after-fsync",
            CrashSite::BeforeRename => "before-rename",
            CrashSite::MidSnapshot => "mid-snapshot",
            CrashSite::PostAdmitPreAck => "post-admit-pre-ack",
        }
    }
}

impl std::fmt::Display for CrashSite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One planned failure.
#[derive(Debug, Clone, PartialEq)]
pub enum Fault {
    /// Kill the container of task `type:index` once its chief passes
    /// `after_step` (or after `after_ms` if the job has no step signal).
    KillTask { task_type: String, index: u32, after_step: u64 },
    /// Kill a whole node after the chief passes `after_step`.
    KillNode { node: u32, after_step: u64 },
}

/// Outcome record for reporting (EXPERIMENTS.md / benches).
#[derive(Debug, Clone)]
pub struct InjectionRecord {
    pub fault: Fault,
    pub injected_at_ms: u64,
    pub chief_step_at_injection: u64,
    /// Cluster-spec version the job was at when the fault fired.
    pub version_at_injection: u32,
}

/// Watches a job's AM state and fires faults per schedule.  Runs on its
/// own thread; returns records through `join`.
pub struct ChaosInjector {
    handle: Option<std::thread::JoinHandle<Vec<InjectionRecord>>>,
}

impl ChaosInjector {
    pub fn start(
        rm: Arc<ResourceManager>,
        am_state: Arc<AmState>,
        schedule: Vec<Fault>,
    ) -> ChaosInjector {
        let handle = std::thread::Builder::new()
            .name("chaos".into())
            .spawn(move || {
                let t0 = Instant::now();
                let mut records = Vec::new();
                let mut pending = schedule;
                // At most one fault per cluster-spec version: a surgical
                // recovery bumps the version without starting a new
                // attempt, so gating on the version lets faults fire
                // *within* a surviving attempt (kill, recover, kill
                // again) while still never double-killing one
                // incarnation.
                let mut last_fired_version = 0u32;
                while !pending.is_empty() {
                    let phase = am_state.phase();
                    if matches!(
                        phase,
                        crate::am::JobPhase::Succeeded | crate::am::JobPhase::Failed
                    ) {
                        twarn!("chaos", "job ended with {} faults unfired", pending.len());
                        break;
                    }
                    let version = am_state.spec_version();
                    if version == last_fired_version || phase != crate::am::JobPhase::Running {
                        crate::util::clock::real_sleep(Duration::from_millis(10));
                        continue;
                    }
                    let step = am_state.chief_metrics().map(|m| m.step).unwrap_or(0);
                    let mut fired = Vec::new();
                    for (i, fault) in pending.iter().enumerate() {
                        if !fired.is_empty() {
                            break; // one per spec version
                        }
                        let due = match fault {
                            Fault::KillTask { after_step, .. }
                            | Fault::KillNode { after_step, .. } => step >= *after_step,
                        };
                        if !due {
                            continue;
                        }
                        match fault {
                            Fault::KillTask { task_type, index, .. } => {
                                let task = crate::util::ids::TaskId::new(task_type.clone(), *index);
                                if let Some(cid) = am_state
                                    .live_containers_for(&task)
                                {
                                    tinfo!("chaos", "killing {task} (container {cid}) at step {step}");
                                    rm.stop_container(cid);
                                    fired.push(i);
                                }
                            }
                            Fault::KillNode { node, .. } => {
                                tinfo!("chaos", "killing node{node} at step {step}");
                                rm.kill_node(NodeId(*node));
                                fired.push(i);
                            }
                        }
                    }
                    if !fired.is_empty() {
                        last_fired_version = version;
                    }
                    for &i in fired.iter().rev() {
                        records.push(InjectionRecord {
                            fault: pending.remove(i),
                            injected_at_ms: t0.elapsed().as_millis() as u64,
                            chief_step_at_injection: step,
                            version_at_injection: version,
                        });
                    }
                    // Chaos is a test harness watching real training
                    // progress; its step-watch cadence stays real time.
                    crate::util::clock::real_sleep(Duration::from_millis(10));
                }
                records
            })
            .expect("spawn chaos thread");
        ChaosInjector { handle: Some(handle) }
    }

    pub fn join(mut self) -> Vec<InjectionRecord> {
        self.handle.take().map(|h| h.join().unwrap_or_default()).unwrap_or_default()
    }
}

/// Random fault schedule generator (property tests / soak runs).
pub fn random_schedule(seed: u64, n_workers: u32, n_faults: usize, max_step: u64) -> Vec<Fault> {
    let mut rng = SplitMix64::new(seed);
    (0..n_faults)
        .map(|_| {
            if rng.chance(0.7) {
                Fault::KillTask {
                    task_type: "worker".to_string(),
                    index: rng.next_below(n_workers.max(1) as u64) as u32,
                    after_step: rng.range_u64(1, max_step.max(2)),
                }
            } else {
                Fault::KillNode {
                    node: rng.next_below(4) as u32,
                    after_step: rng.range_u64(1, max_step.max(2)),
                }
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crash_site_names_round_trip() {
        for site in CrashSite::ALL {
            assert_eq!(CrashSite::parse(site.as_str()), Some(site));
        }
        assert_eq!(CrashSite::parse("no-such-site"), None);
    }

    #[test]
    fn random_schedule_is_deterministic_and_bounded() {
        let a = random_schedule(7, 4, 10, 50);
        let b = random_schedule(7, 4, 10, 50);
        assert_eq!(a, b);
        assert_eq!(a.len(), 10);
        for f in &a {
            match f {
                Fault::KillTask { index, after_step, .. } => {
                    assert!(*index < 4);
                    assert!((1..=50).contains(after_step));
                }
                Fault::KillNode { node, after_step } => {
                    assert!(*node < 4);
                    assert!((1..=50).contains(after_step));
                }
            }
        }
    }
}
